"""Churn-replay benchmark: warm incremental replanning vs from-scratch.

    PYTHONPATH=src python -m benchmarks.bench_churn [--quick]

Replays a pinned seeded churn trace (16 nodes, preemptions, returns,
link degradations, stragglers) under both replanning policies and
compares them on the **throughput integral** — samples produced over the
whole trace, downtime included — the churn issue's acceptance metric.

Three gates, all enforced with a non-zero exit:

* **integral** — the warm incremental policy (projected warm-start,
  stay/aligned candidates, ``latency + migration_weight * downtime``
  selection) must beat from-scratch replanning on total samples;
* **downtime** — warm must spend no more migration downtime than cold
  (its wins must come from avoided reshards, not luckier step times);
* **accounting** — each policy's summed :class:`~repro.core.migration.
  PlanDiff` (ranks moved, bytes migrated) must agree with the
  independent :class:`~repro.runtime.churn.ResidentState` ledger that
  tracks which shard every base GPU holds across the whole trace.

``--quick`` replays the single pinned trace at a tighter SA budget for
CI; the full run adds a second trace seed and a larger budget.
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import sys
import time

from repro import configs
from repro.core import MID_RANGE, Workload
from repro.runtime.churn import (COLD_POLICY, WARM_POLICY, generate_trace,
                                 simulate_churn)

N_NODES = 16
HORIZON_S = 1800.0
MIN_NODES = 12
PREEMPT_INTERVAL_S = 450.0


def _workload() -> Workload:
    return Workload(configs.get("gpt-1.1b").reduced(), seq=2048,
                    bs_global=128)


def _consistent(rep) -> bool:
    """PlanDiff totals vs the resident-state ledger: exact on ranks,
    relative 1e-6 on bytes (non-integer tp shards accumulate in a
    different order)."""
    return (rep.ranks_moved == rep.resident_moved
            and math.isclose(rep.bytes_migrated, rep.resident_bytes,
                             rel_tol=1e-6, abs_tol=1.0))


def replay_gate(trace_seed: int, sa_iters: int) -> bool:
    """Replay one pinned trace under both policies; apply the gates."""
    spec = MID_RANGE.with_nodes(N_NODES)
    w = _workload()
    trace = generate_trace(spec, horizon_s=HORIZON_S, seed=trace_seed,
                           min_nodes=MIN_NODES,
                           preempt_interval_s=PREEMPT_INTERVAL_S)
    kinds: dict = {}
    for ev in trace.events:
        kinds[ev.kind] = kinds.get(ev.kind, 0) + 1
    print(f"== trace seed={trace_seed}: {len(trace.events)} events "
          f"{kinds} over {HORIZON_S:.0f}s, {N_NODES} nodes ==")

    reports = {}
    for policy in (dataclasses.replace(WARM_POLICY, sa_iters=sa_iters,
                                       sa_seconds=0.1),
                   dataclasses.replace(COLD_POLICY, sa_iters=sa_iters,
                                       sa_seconds=0.1)):
        t0 = time.perf_counter()
        rep = simulate_churn(w, spec, trace, policy)
        wall = time.perf_counter() - t0
        reports[policy.name] = rep
        print(f"  {policy.name:<5} {rep.samples:14.0f} samples  "
              f"downtime {rep.downtime_s:6.1f}s  "
              f"moved {rep.ranks_moved:5d} ranks  "
              f"{rep.bytes_migrated / 1e9:7.2f} GB  "
              f"({rep.replans} replans, wall {wall:5.1f}s)")

    warm, cold = reports["warm"], reports["cold"]
    margin = warm.samples / cold.samples - 1.0
    print(f"  warm/cold margin: {margin * 100:+.3f}%   "
          f"downtime saved: {cold.downtime_s - warm.downtime_s:.1f}s")
    ok = True
    if warm.samples <= cold.samples:
        print("  FAIL: warm incremental replanning lost the throughput "
              "integral to from-scratch replanning")
        ok = False
    if warm.downtime_s > cold.downtime_s:
        print("  FAIL: warm replanning spent MORE downtime than cold")
        ok = False
    for name, rep in reports.items():
        if not _consistent(rep):
            print(f"  FAIL: {name} PlanDiff accounting disagrees with the "
                  f"resident-state ledger "
                  f"(moved {rep.ranks_moved} vs {rep.resident_moved}, "
                  f"bytes {rep.bytes_migrated:.0f} vs "
                  f"{rep.resident_bytes:.0f})")
            ok = False
    if ok:
        print("  gate passed")
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: one pinned trace, tighter SA budget")
    args = ap.parse_args(argv)

    if args.quick:
        runs = [(13, 100)]
    else:
        runs = [(13, 150), (0, 150)]
    ok = True
    for trace_seed, sa_iters in runs:
        ok = replay_gate(trace_seed, sa_iters) and ok
    if not ok:
        print("bench_churn: GATE FAILED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
