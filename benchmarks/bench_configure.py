"""Configurator-overhead benchmark: the batched enumerate->prune pipeline
vs the seed's per-candidate scalar path, plus the end-to-end ``configure()``
phase breakdown.

    PYTHONPATH=src python -m benchmarks.bench_configure \
        [--nodes 16] [--quick] [--max-cp 4]

Phase A times memory pruning of the whole enumeration (MID_RANGE @ 16
nodes): the seed path paid one un-jitted one-row JAX forward per candidate
(dispatch-dominated), the new path one jitted ``predict_batch`` call on the
(N, F) feature matrix.  It also times profile construction the seed way
(every enumerated conf, before the memory check) vs the new way (survivors
only, memoized per ``(pp, tp, cp, bs_micro)``).

Phase B runs the full ``configure()`` search and prints the overhead
breakdown, exhaustive vs ``sa_topk``.

``--max-cp N`` (4D mode) opens the context-parallel axis: the enumeration
grows by the cp divisors of the sequence length, and the same batched
pipeline absorbs the larger candidate set — the point of ISSUE 3.  The
benchmark prints the 3D vs 4D candidate counts alongside the timings.

``--mixed-tier`` switches the cluster to the seeded mixed A100/V100 fleet
and appends Phase C: compute-aware vs compute-blind SA dedication of the
same configuration on the 16-node mixed fleet, both played back in the
discrete-event simulator at each rank's true speed — the heterogeneous-
compute headline (aware must be strictly faster).

Acceptance target (ISSUE 2): >= 5x on the enumerate+prune phase.

``--huge`` replaces all of the above with the 10k-GPU scaling curve
(ISSUE 6): full 4D plans of a seeded mixed A100/V100 fleet at 1k / 2k /
5k / 10k GPUs (``--quick``: 1k + 2k only, the CI smoke size), each size
planned by both SA backends of the unified core.  Per size it records the
plan wall-clock (numpy; jax cold; jax warm — second run with the
persistent XLA compilation cache populated), verifies the two backends
produced bit-identical plans, and measures full-re-score throughput
(``DedicationEngine.score`` loop vs the vmapped
``JaxDedicationEngine.score_batch``, steady state).  Gates, both fatal
(exit code 1):

* at every size >= 1024 GPUs the jitted batch scorer must not be slower
  than the NumPy engine at full re-scores (the vmapped core must earn its
  dispatch; the *plan*-level wall-clock is recorded un-gated — the
  incremental delta-scoring NumPy executor is expected to stay the better
  single-core-CPU choice, while the jitted path is the batched-rescore /
  accelerator story);
* when the 10240-GPU size runs, its (numpy-backend) plan must finish
  under ``--limit-s`` seconds (default 10 — the ROADMAP "plan a 10k-GPU
  cluster in seconds" target).

``--json PATH`` writes the machine-readable curve (the CI artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from repro.core import (MID_RANGE, ProfileCache, Workload,
                        anneal_multistart, build_profile, configure,
                        enumerate_confs, fit_memory_estimator,
                        true_bandwidth_matrix)
from repro.core.cluster import (A100_TIER, V100_TIER, mixed_fleet_spec,
                                profile_bandwidth)
from repro.core.memory import _features, analytical_estimate
from repro.core.mlp import mlp_forward
from repro.core.simulator import Conf, default_mapping, measure
from repro.configs.gpt_paper import GPT_3_1B
from repro.models.config import ModelConfig

SEQ = 2048
BS_GLOBAL = 256


def scalar_predict_seed(est, cfg, conf) -> float:
    """The seed-era ``MemoryEstimator.predict``: per-call feature build and
    an un-jitted one-row MLP forward (one JAX dispatch per candidate)."""
    import jax.numpy as jnp
    x = (_features(cfg, conf, with_cp=est.with_cp) - est.x_mean) / est.x_std
    y = float(mlp_forward(est.params,
                          jnp.asarray(x[None], jnp.float32))[0, 0])
    pred = float(np.exp(y * est.y_std + est.y_mean))
    if est.residual:
        w = Workload(cfg, est.workload_seq, conf.bs_global)
        pred *= analytical_estimate(w, conf)
    return pred


# Row names used both for printing and for the speedup computation below.
# Keeping them as module constants (instead of free-floating strings looked
# up in a dict at report time) means a renamed row fails loudly at
# definition time, not as a KeyError after the benchmark already ran.
ROW_PRUNE_SEED = "prune scalar-predict (seed)"
ROW_PRUNE_COLD = "prune batched, cold (compile)"
ROW_PRUNE_BATCHED = "prune batched (new)"
ROW_PROFILES_SEED = "profiles seed (all, pre-prune)"
ROW_PROFILES_NEW = "profiles new (survivors, memoized)"


def bench_prune(w, spec, est, *, max_micro: int = 16, repeats: int = 3,
                max_cp: int = 1):
    """Enumerate+prune wall-clock, seed scalar path vs batched path.

    Yields ``(name, seconds, n_in, n_out)`` rows; the batched row is
    steady-state (first call pays the one-off XLA compile, reported as its
    own row).  The limit matches what ``run_search`` budgets — the
    tightest device tier (``mem_floor``; == ``gpu_mem`` when homogeneous),
    so the --mixed-tier survivor counts mirror the real pipeline's."""
    limit = spec.mem_floor * est.soft_margin

    def enumerate_filtered():
        return [c for c in enumerate_confs(spec.n_gpus, w.bs_global,
                                           n_layers=w.cfg.n_layers,
                                           max_cp=max_cp, seq=w.seq)
                if c.bs_micro <= max_micro]

    # seed path: one JAX dispatch per enumerated candidate
    best_scalar = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        confs = enumerate_filtered()
        kept = [c for c in confs
                if scalar_predict_seed(est, w.cfg, c) <= limit]
        dt = time.perf_counter() - t0
        best_scalar = dt if best_scalar is None else min(best_scalar, dt)
    yield (ROW_PRUNE_SEED, best_scalar, len(confs), len(kept))

    # batched path: cold call first (XLA compile), then steady state
    t0 = time.perf_counter()
    confs = enumerate_filtered()
    preds = est.predict_batch(w.cfg, confs)
    cold = time.perf_counter() - t0
    yield (ROW_PRUNE_COLD, cold, len(confs),
           int((preds <= limit).sum()))
    best_batch = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        confs = enumerate_filtered()
        preds = est.predict_batch(w.cfg, confs)
        kept_b = [c for c, k in zip(confs, preds <= limit) if k]
        dt = time.perf_counter() - t0
        best_batch = dt if best_batch is None else min(best_batch, dt)
    yield (ROW_PRUNE_BATCHED, best_batch, len(confs), len(kept_b))

    # profile construction: seed built one per enumerated conf *before* the
    # memory check; the new pipeline builds survivors only, memoized
    t0 = time.perf_counter()
    for c in confs:
        build_profile(w, spec, c)
    yield (ROW_PROFILES_SEED, time.perf_counter() - t0,
           len(confs), len(confs))
    t0 = time.perf_counter()
    cache = ProfileCache(w, spec)
    for c in kept_b:
        cache.get(c)
    yield (ROW_PROFILES_NEW, time.perf_counter() - t0,
           len(kept_b), len(cache._full))


def bench_search(w, spec, est, bw, *, sa_iters: int, max_micro: int,
                 sa_topk: int, max_cp: int = 1):
    """Full ``configure()`` wall-clock and phase breakdown, exhaustive SA vs
    the ``sa_topk`` concentration knob.  Yields ``(name, res)`` pairs."""
    kw = dict(estimator=est, sa_seconds=60.0, sa_iters=sa_iters,
              max_micro=max_micro, max_cp=max_cp, seed=0)
    yield ("configure() exhaustive SA", configure(w, spec, bw, **kw))
    yield (f"configure() sa_topk={sa_topk}",
           configure(w, spec, bw, sa_topk=sa_topk, **kw))


def bench_hetero_dedication(*, quick: bool):
    """Phase C: compute-aware vs compute-blind dedication on the seeded
    mixed A100/V100 16-node (single-GPU nodes) scenario, both simulated at
    true per-rank speed.  Prints the simulated latencies and a PASS /
    REGRESSION verdict (aware must be strictly faster than blind)."""
    gpt12 = ModelConfig(name="g12", family="dense", n_layers=12,
                        d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
                        vocab_size=32000)
    spec = mixed_fleet_spec("mixed-a100-v100-16x1", 16,
                            (A100_TIER, V100_TIER), (0.5, 0.5),
                            gpus_per_node=1, seed=47)
    w = Workload(gpt12, 2048, 32)
    conf = Conf(8, 1, 2, 2, 32)         # 4 heavy + 4 light (1-layer) stages
    bw, _ = profile_bandwidth(spec)
    bw_true = true_bandwidth_matrix(spec)
    prof = build_profile(w, spec, conf)
    iters = 10_000 if quick else 40_000
    kw = dict(n_chains=4, time_limit_s=60.0, max_iters=iters, seed=0)
    t0 = time.perf_counter()
    aware = anneal_multistart(conf, bw, prof, spec, **kw)
    blind = anneal_multistart(conf, bw, prof, spec, compute_aware=False,
                              **kw)
    wall = time.perf_counter() - t0
    sim_aware = measure(conf, aware.mapping, w, spec, bw_true, seed=1)
    sim_blind = measure(conf, blind.mapping, w, spec, bw_true, seed=1)
    sim_default = measure(conf, default_mapping(conf), w, spec, bw_true,
                          seed=1)
    print()
    print(f"# phase C: hetero dedication on {spec.name} "
          f"({conf}, {iters} SA iters x2, {wall:.1f}s)")
    print("mapping,sim_latency_s")
    print(f"compute-aware SA,{sim_aware:.6f}")
    print(f"compute-blind SA,{sim_blind:.6f}")
    print(f"default (node-major),{sim_default:.6f}")
    gain = (1 - sim_aware / sim_blind) * 100
    verdict = "PASS" if sim_aware < sim_blind else "REGRESSION"
    print(f"compute-aware vs blind: {gain:+.1f}% simulated ({verdict})")
    return sim_aware < sim_blind


def bench_partition(*, quick: bool):
    """Phase D: DP layer partition vs the honest uniform split on the two
    non-uniform-cost configs (hybrid-attention zamba2, MoE kimi-k2), both
    played back in the discrete-event simulator at pp=8.  "Honest" means
    the uniform side also runs through the per-stage cost path (an explicit
    ceil-first :class:`Partition`), so the comparison isolates the split,
    not the cost model.  Prints per-model simulated latencies and a PASS /
    REGRESSION verdict (DP must be no slower than uniform on both)."""
    from repro.core import make_partition, uniform_partition
    from repro.configs.kimi_k2_1t_a32b import CONFIG as KIMI
    from repro.configs.zamba2_7b import CONFIG as ZAMBA

    spec = MID_RANGE.with_nodes(16)
    bw_true = true_bandwidth_matrix(spec)
    bs_global = 64 if quick else 256
    ok = True
    print()
    print(f"# phase D: DP vs uniform layer partition on {spec.name} "
          f"(pp=8, seq={SEQ}, bs_global={bs_global})")
    print("model,partition,stage_layers,sim_latency_s")
    for cfg in (ZAMBA, KIMI):
        w = Workload(cfg, SEQ, bs_global)
        conf = Conf(8, 4, 4, 2, bs_global)
        m = default_mapping(conf)
        part_u = uniform_partition(cfg.n_layers, conf.pp)
        part_dp = make_partition(cfg, conf.pp, SEQ, "dp")
        sim_u = measure(conf, m, w, spec, bw_true, seed=1,
                        partition=part_u)
        sim_dp = measure(conf, m, w, spec, bw_true, seed=1,
                         partition=part_dp)
        for label, part, sim in (("uniform", part_u, sim_u),
                                 ("dp", part_dp, sim_dp)):
            sizes = "/".join(str(s) for s in part.sizes)
            print(f"{cfg.name},{label},{sizes},{sim:.6f}")
        gain = (1 - sim_dp / sim_u) * 100
        verdict = "PASS" if sim_dp <= sim_u else "REGRESSION"
        print(f"{cfg.name}: dp vs uniform {gain:+.1f}% simulated "
              f"({verdict})")
        ok = ok and sim_dp <= sim_u
    return ok


# --------------------------------------------------------------------------
# --huge: the 10k-GPU scaling curve (ISSUE 6)
# --------------------------------------------------------------------------

#: 40 transformer layers so pipeline degrees with a factor of 5 are open
#: (10240 = 2^11 * 5 forces pp in {5, 10, 20, 40} once tp and dp take the
#: powers of two) — a GPT-13B-like shape.
M40 = ModelConfig(name="m40-13b", family="dense", n_layers=40, d_model=5120,
                  n_heads=40, n_kv_heads=40, d_ff=20480, vocab_size=32000)

HUGE_SIZES = (1024, 2048, 5120, 10240)
HUGE_QUICK_SIZES = (1024, 2048)
HUGE_BS_GLOBAL = 2048
RESCORE_BATCH = 16


def _huge_spec(n_gpus: int):
    return mixed_fleet_spec(f"huge-a100-v100-{n_gpus // 8}x8", n_gpus // 8,
                            (A100_TIER, V100_TIER), (0.5, 0.5),
                            gpus_per_node=8, seed=1234)


def _huge_plan(w, spec, bw, backend: str, *, sa_iters: int, n_chains: int):
    """One full 4D plan through the declarative API; returns
    ``(plan, wall_s)``.  Iteration-bound budget (the wall-clock guard can
    never bite) so numpy and jax runs are byte-comparable."""
    from repro.core import (Budget, Planner, PlanRequest, PipetteStrategy,
                            SearchSpace)
    req = PlanRequest(
        workload=w, spec=spec,
        space=SearchSpace(max_tp=8, max_cp=2, fixed_micro=1),
        budget=Budget(sa_seconds=3600.0, sa_iters=sa_iters,
                      n_chains=n_chains, sa_topk=2, backend=backend),
        seed=7)
    t0 = time.perf_counter()
    plan = Planner(PipetteStrategy()).plan(req, bw)
    return plan, time.perf_counter() - t0


def _bench_rescore(w, spec, bw, conf):
    """Steady-state full-re-score throughput of both engines on ``conf``:
    a Python loop of ``DedicationEngine.score`` vs one vmapped
    ``JaxDedicationEngine.score_batch`` dispatch over the same random
    permutations.  Returns ``(numpy_sps, jax_sps, jax_compile_s)`` in
    scores/second; asserts the two engines agree bitwise."""
    from repro.core import DedicationEngine, PairCache, build_profile
    from repro.core.jax_engine import JaxDedicationEngine
    prof = build_profile(w, spec, conf)
    pairs = PairCache.build(bw, spec.gpus_per_node)
    eng = DedicationEngine(conf, bw, prof, spec, pairs=pairs)
    jeng = JaxDedicationEngine([conf], [prof], bw, spec, pairs=pairs)
    rng = np.random.default_rng(0)
    perms = np.stack([rng.permutation(conf.n_gpus)
                      for _ in range(RESCORE_BATCH)])
    t_np = None
    for _ in range(3):
        t0 = time.perf_counter()
        np_vals = [eng.score(p) for p in perms]
        dt = time.perf_counter() - t0
        t_np = dt if t_np is None else min(t_np, dt)
    t0 = time.perf_counter()
    jax_vals = jeng.score_batch(perms)          # cold: pays the compile
    compile_s = time.perf_counter() - t0
    t_jx = None
    for _ in range(3):
        t0 = time.perf_counter()
        jax_vals = jeng.score_batch(perms)
        dt = time.perf_counter() - t0
        t_jx = dt if t_jx is None else min(t_jx, dt)
    assert all(float(a).hex() == float(b).hex()
               for a, b in zip(np_vals, jax_vals)), \
        "jax batch re-score diverged from the NumPy engine"
    return RESCORE_BATCH / t_np, RESCORE_BATCH / t_jx, compile_s


def bench_huge(args) -> None:
    """The ISSUE 6 scaling curve + gates; writes the ``--json`` artifact."""
    import jax
    cache_dir = args.jax_cache_dir or os.path.join(
        tempfile.gettempdir(), "repro-jax-cache")
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)

    sizes = [int(s) for s in args.sizes.split(",")] if args.sizes else \
        list(HUGE_QUICK_SIZES if args.quick else HUGE_SIZES)
    w = Workload(M40, SEQ, HUGE_BS_GLOBAL)
    failures: list[str] = []
    curve = []
    print(f"# --huge: {M40.name} seq={SEQ} bs_global={HUGE_BS_GLOBAL}, "
          f"mixed A100/V100 fleet, sa_iters={args.sa_iters} "
          f"n_chains={args.chains} sa_topk=2")
    print("n_gpus,bw_profile_s,numpy_plan_s,jax_cold_plan_s,"
          "jax_warm_plan_s,numpy_sa_s,jax_warm_sa_s,numpy_rescore_per_s,"
          "jax_rescore_per_s,latency_s,conf")
    for n in sizes:
        spec = _huge_spec(n)
        t0 = time.perf_counter()
        bw, _ = profile_bandwidth(spec)
        bw_s = time.perf_counter() - t0
        np_plan, np_s = _huge_plan(w, spec, bw, "numpy",
                                   sa_iters=args.sa_iters,
                                   n_chains=args.chains)
        jx_plan, jx_cold_s = _huge_plan(w, spec, bw, "jax",
                                        sa_iters=args.sa_iters,
                                        n_chains=args.chains)
        jx_plan2, jx_warm_s = _huge_plan(w, spec, bw, "jax",
                                         sa_iters=args.sa_iters,
                                         n_chains=args.chains)
        if (np_plan.conf != jx_plan.conf
                or float(np_plan.latency).hex()
                != float(jx_plan.latency).hex()
                or float(jx_plan2.latency).hex()
                != float(jx_plan.latency).hex()):
            failures.append(f"n={n}: backends disagree "
                            f"(numpy {np_plan.conf} {np_plan.latency!r} vs "
                            f"jax {jx_plan.conf} {jx_plan.latency!r})")
        np_sps, jx_sps, compile_s = _bench_rescore(w, spec, bw,
                                                   np_plan.conf)
        c = np_plan.conf
        cstr = f"pp{c.pp}.tp{c.tp}.cp{c.cp}.dp{c.dp}"
        print(f"{n},{bw_s:.2f},{np_s:.2f},{jx_cold_s:.2f},{jx_warm_s:.2f},"
              f"{np_plan.overhead.sa_s:.2f},{jx_plan2.overhead.sa_s:.2f},"
              f"{np_sps:.1f},{jx_sps:.1f},{np_plan.latency:.3f},{cstr}")
        curve.append({
            "n_gpus": n, "n_nodes": spec.n_nodes,
            "bw_profile_s": round(bw_s, 3),
            "numpy": {"plan_s": round(np_s, 3),
                      "sa_s": round(np_plan.overhead.sa_s, 3),
                      "rescore_per_s": round(np_sps, 1)},
            "jax": {"cold_plan_s": round(jx_cold_s, 3),
                    "warm_plan_s": round(jx_warm_s, 3),
                    "warm_sa_s": round(jx_plan2.overhead.sa_s, 3),
                    "rescore_per_s": round(jx_sps, 1),
                    "rescore_compile_s": round(compile_s, 3)},
            "latency_s": float(np_plan.latency), "conf": cstr,
            "n_enumerated": np_plan.overhead.n_enumerated,
        })
        # gate 1: the jitted batch scorer must not be slower than the
        # NumPy engine at full re-scores from 1k GPUs up
        if n >= 1024 and jx_sps < np_sps:
            failures.append(
                f"n={n}: jitted re-score slower than NumPy "
                f"({jx_sps:.1f} vs {np_sps:.1f} scores/s)")
        # gate 2: the 10k plan must land inside the ROADMAP budget
        if n >= 10240 and np_s > args.limit_s:
            failures.append(f"n={n}: plan took {np_s:.2f}s "
                            f"(limit {args.limit_s:.0f}s)")

    artifact = {
        "bench": "huge-scaling-curve", "model": M40.name, "seq": SEQ,
        "bs_global": HUGE_BS_GLOBAL, "sa_iters": args.sa_iters,
        "n_chains": args.chains, "sa_topk": 2, "seed": 7,
        "limit_s": args.limit_s, "sizes": sizes, "curve": curve,
        "gate_failures": failures,
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"# artifact -> {args.json}")
    if failures:
        raise SystemExit("--huge gate failures:\n  "
                         + "\n  ".join(failures))
    print(f"# gates PASS (jitted re-score >= NumPy at every size; "
          f"10k plan limit {args.limit_s:.0f}s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=16,
                    help="cluster size in 8-GPU nodes (default 16)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: small estimator, tiny SA budget "
                         "(with --huge: the 1k+2k curve only)")
    ap.add_argument("--max-cp", type=int, default=1,
                    help="open the 4D context-parallel axis up to this "
                         "degree (default 1 = the 3D space)")
    ap.add_argument("--mixed-tier", action="store_true",
                    help="run on the seeded mixed A100/V100 fleet and "
                         "report compute-aware vs compute-blind dedication")
    ap.add_argument("--partition", action="store_true",
                    help="run only phase D: DP vs uniform layer partition "
                         "on the hybrid/MoE configs, simulated at pp=8")
    ap.add_argument("--huge", action="store_true",
                    help="run the 10k-GPU scaling curve instead of phases "
                         "A-C (see module docstring)")
    ap.add_argument("--sizes", default=None,
                    help="with --huge: comma-separated GPU counts "
                         "overriding the default curve")
    ap.add_argument("--sa-iters", type=int, default=200,
                    help="with --huge: SA refinement iterations per "
                         "candidate (default 200 — islands make the coarse "
                         "solution strong, refinement is a polish)")
    ap.add_argument("--chains", type=int, default=4,
                    help="with --huge: SA chains per candidate (default 4)")
    ap.add_argument("--limit-s", type=float, default=10.0,
                    help="with --huge: wall-clock budget for the 10k-GPU "
                         "plan (default 10s)")
    ap.add_argument("--json", default=None,
                    help="with --huge: write the scaling-curve artifact "
                         "to this path")
    ap.add_argument("--jax-cache-dir", default=None,
                    help="with --huge: persistent XLA compilation cache "
                         "directory (default: a tempdir location)")
    args = ap.parse_args()

    if args.huge:
        bench_huge(args)
        return

    if args.partition:
        if not bench_partition(quick=args.quick):
            raise SystemExit(
                "partition regression: the DP split did not match or beat "
                "the uniform split in the simulator")
        return

    if args.mixed_tier:
        spec = mixed_fleet_spec("mixed-a100-v100", args.nodes,
                                (A100_TIER, V100_TIER), (0.5, 0.5),
                                gpus_per_node=8, intra_bw=300e9,
                                inter_bw=12.5e9, seed=47)
    else:
        spec = MID_RANGE.with_nodes(args.nodes)
    w = Workload(GPT_3_1B, SEQ, BS_GLOBAL)
    steps = 1000 if args.quick else 4000
    t0 = time.perf_counter()
    est = fit_memory_estimator([w], spec, fit_nodes=2, steps=steps,
                               residual=True, max_cp=args.max_cp)
    print(f"# estimator fit ({steps} steps, max_cp={args.max_cp}): "
          f"{time.perf_counter() - t0:.1f}s")
    if args.max_cp > 1:
        n3 = len(enumerate_confs(spec.n_gpus, w.bs_global,
                                 n_layers=w.cfg.n_layers))
        n4 = len(enumerate_confs(spec.n_gpus, w.bs_global,
                                 n_layers=w.cfg.n_layers,
                                 max_cp=args.max_cp, seq=w.seq))
        print(f"# 4D mode: search space {n3} (3D) -> {n4} confs "
              f"({n4 / max(n3, 1):.1f}x)")

    print("benchmark,wall_s,n_in,n_out")
    rows = {}
    for name, sec, n_in, n_out in bench_prune(w, spec, est,
                                              max_cp=args.max_cp):
        rows[name] = sec
        print(f"{name},{sec:.4f},{n_in},{n_out}")
    speedup = rows[ROW_PRUNE_SEED] / rows[ROW_PRUNE_BATCHED]
    prof_speedup = (rows[ROW_PROFILES_SEED]
                    / max(rows[ROW_PROFILES_NEW], 1e-9))
    print(f"enumerate+prune speedup: {speedup:.1f}x")
    print(f"profile-construction speedup: {prof_speedup:.1f}x")

    print()
    print("benchmark,total_s,sa_s,mem_estimator_s,profile_s,prescore_s,"
          "n_enumerated,n_candidates")
    bw = true_bandwidth_matrix(spec)
    sa_iters = 30 if args.quick else 150
    max_micro = 2 if args.quick else 4
    for name, res in bench_search(w, spec, est, bw, sa_iters=sa_iters,
                                  max_micro=max_micro, sa_topk=8,
                                  max_cp=args.max_cp):
        # typed Overhead attributes: a mistyped field is an AttributeError
        # here, not a KeyError swallowed into a half-printed CSV row
        o = res.overhead
        print(f"{name},{o.total_s:.2f},{o.sa_s:.2f},"
              f"{o.mem_estimator_s:.4f},{o.profile_s:.4f},"
              f"{o.prescore_s:.4f},{o.n_enumerated},"
              f"{o.n_candidates}")

    print()
    verdict = "PASS" if speedup >= 5.0 else "BELOW TARGET"
    print(f"enumerate+prune speedup {speedup:.1f}x (target >= 5x): {verdict}")

    if args.mixed_tier:
        ok = bench_hetero_dedication(quick=args.quick)
        if not ok:
            raise SystemExit(
                "mixed-tier regression: compute-aware dedication did not "
                "beat compute-blind in the simulator")


if __name__ == "__main__":
    main()
