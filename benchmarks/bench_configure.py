"""Configurator-overhead benchmark: the batched enumerate->prune pipeline
vs the seed's per-candidate scalar path, plus the end-to-end ``configure()``
phase breakdown.

    PYTHONPATH=src python -m benchmarks.bench_configure \
        [--nodes 16] [--quick] [--max-cp 4]

Phase A times memory pruning of the whole enumeration (MID_RANGE @ 16
nodes): the seed path paid one un-jitted one-row JAX forward per candidate
(dispatch-dominated), the new path one jitted ``predict_batch`` call on the
(N, F) feature matrix.  It also times profile construction the seed way
(every enumerated conf, before the memory check) vs the new way (survivors
only, memoized per ``(pp, tp, cp, bs_micro)``).

Phase B runs the full ``configure()`` search and prints the overhead
breakdown, exhaustive vs ``sa_topk``.

``--max-cp N`` (4D mode) opens the context-parallel axis: the enumeration
grows by the cp divisors of the sequence length, and the same batched
pipeline absorbs the larger candidate set — the point of ISSUE 3.  The
benchmark prints the 3D vs 4D candidate counts alongside the timings.

``--mixed-tier`` switches the cluster to the seeded mixed A100/V100 fleet
and appends Phase C: compute-aware vs compute-blind SA dedication of the
same configuration on the 16-node mixed fleet, both played back in the
discrete-event simulator at each rank's true speed — the heterogeneous-
compute headline (aware must be strictly faster).

Acceptance target (ISSUE 2): >= 5x on the enumerate+prune phase.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (MID_RANGE, ProfileCache, Workload,
                        anneal_multistart, build_profile, configure,
                        enumerate_confs, fit_memory_estimator,
                        true_bandwidth_matrix)
from repro.core.cluster import (A100_TIER, V100_TIER, mixed_fleet_spec,
                                profile_bandwidth)
from repro.core.memory import _features, analytical_estimate
from repro.core.mlp import mlp_forward
from repro.core.simulator import Conf, default_mapping, measure
from repro.configs.gpt_paper import GPT_3_1B
from repro.models.config import ModelConfig

SEQ = 2048
BS_GLOBAL = 256


def scalar_predict_seed(est, cfg, conf) -> float:
    """The seed-era ``MemoryEstimator.predict``: per-call feature build and
    an un-jitted one-row MLP forward (one JAX dispatch per candidate)."""
    import jax.numpy as jnp
    x = (_features(cfg, conf, with_cp=est.with_cp) - est.x_mean) / est.x_std
    y = float(mlp_forward(est.params,
                          jnp.asarray(x[None], jnp.float32))[0, 0])
    pred = float(np.exp(y * est.y_std + est.y_mean))
    if est.residual:
        w = Workload(cfg, est.workload_seq, conf.bs_global)
        pred *= analytical_estimate(w, conf)
    return pred


# Row names used both for printing and for the speedup computation below.
# Keeping them as module constants (instead of free-floating strings looked
# up in a dict at report time) means a renamed row fails loudly at
# definition time, not as a KeyError after the benchmark already ran.
ROW_PRUNE_SEED = "prune scalar-predict (seed)"
ROW_PRUNE_COLD = "prune batched, cold (compile)"
ROW_PRUNE_BATCHED = "prune batched (new)"
ROW_PROFILES_SEED = "profiles seed (all, pre-prune)"
ROW_PROFILES_NEW = "profiles new (survivors, memoized)"


def bench_prune(w, spec, est, *, max_micro: int = 16, repeats: int = 3,
                max_cp: int = 1):
    """Enumerate+prune wall-clock, seed scalar path vs batched path.

    Yields ``(name, seconds, n_in, n_out)`` rows; the batched row is
    steady-state (first call pays the one-off XLA compile, reported as its
    own row).  The limit matches what ``run_search`` budgets — the
    tightest device tier (``mem_floor``; == ``gpu_mem`` when homogeneous),
    so the --mixed-tier survivor counts mirror the real pipeline's."""
    limit = spec.mem_floor * est.soft_margin

    def enumerate_filtered():
        return [c for c in enumerate_confs(spec.n_gpus, w.bs_global,
                                           n_layers=w.cfg.n_layers,
                                           max_cp=max_cp, seq=w.seq)
                if c.bs_micro <= max_micro]

    # seed path: one JAX dispatch per enumerated candidate
    best_scalar = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        confs = enumerate_filtered()
        kept = [c for c in confs
                if scalar_predict_seed(est, w.cfg, c) <= limit]
        dt = time.perf_counter() - t0
        best_scalar = dt if best_scalar is None else min(best_scalar, dt)
    yield (ROW_PRUNE_SEED, best_scalar, len(confs), len(kept))

    # batched path: cold call first (XLA compile), then steady state
    t0 = time.perf_counter()
    confs = enumerate_filtered()
    preds = est.predict_batch(w.cfg, confs)
    cold = time.perf_counter() - t0
    yield (ROW_PRUNE_COLD, cold, len(confs),
           int((preds <= limit).sum()))
    best_batch = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        confs = enumerate_filtered()
        preds = est.predict_batch(w.cfg, confs)
        kept_b = [c for c, k in zip(confs, preds <= limit) if k]
        dt = time.perf_counter() - t0
        best_batch = dt if best_batch is None else min(best_batch, dt)
    yield (ROW_PRUNE_BATCHED, best_batch, len(confs), len(kept_b))

    # profile construction: seed built one per enumerated conf *before* the
    # memory check; the new pipeline builds survivors only, memoized
    t0 = time.perf_counter()
    for c in confs:
        build_profile(w, spec, c)
    yield (ROW_PROFILES_SEED, time.perf_counter() - t0,
           len(confs), len(confs))
    t0 = time.perf_counter()
    cache = ProfileCache(w, spec)
    for c in kept_b:
        cache.get(c)
    yield (ROW_PROFILES_NEW, time.perf_counter() - t0,
           len(kept_b), len(cache._full))


def bench_search(w, spec, est, bw, *, sa_iters: int, max_micro: int,
                 sa_topk: int, max_cp: int = 1):
    """Full ``configure()`` wall-clock and phase breakdown, exhaustive SA vs
    the ``sa_topk`` concentration knob.  Yields ``(name, res)`` pairs."""
    kw = dict(estimator=est, sa_seconds=60.0, sa_iters=sa_iters,
              max_micro=max_micro, max_cp=max_cp, seed=0)
    yield ("configure() exhaustive SA", configure(w, spec, bw, **kw))
    yield (f"configure() sa_topk={sa_topk}",
           configure(w, spec, bw, sa_topk=sa_topk, **kw))


def bench_hetero_dedication(*, quick: bool):
    """Phase C: compute-aware vs compute-blind dedication on the seeded
    mixed A100/V100 16-node (single-GPU nodes) scenario, both simulated at
    true per-rank speed.  Prints the simulated latencies and a PASS /
    REGRESSION verdict (aware must be strictly faster than blind)."""
    gpt12 = ModelConfig(name="g12", family="dense", n_layers=12,
                        d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
                        vocab_size=32000)
    spec = mixed_fleet_spec("mixed-a100-v100-16x1", 16,
                            (A100_TIER, V100_TIER), (0.5, 0.5),
                            gpus_per_node=1, seed=47)
    w = Workload(gpt12, 2048, 32)
    conf = Conf(8, 1, 2, 2, 32)         # 4 heavy + 4 light (1-layer) stages
    bw, _ = profile_bandwidth(spec)
    bw_true = true_bandwidth_matrix(spec)
    prof = build_profile(w, spec, conf)
    iters = 10_000 if quick else 40_000
    kw = dict(n_chains=4, time_limit_s=60.0, max_iters=iters, seed=0)
    t0 = time.perf_counter()
    aware = anneal_multistart(conf, bw, prof, spec, **kw)
    blind = anneal_multistart(conf, bw, prof, spec, compute_aware=False,
                              **kw)
    wall = time.perf_counter() - t0
    sim_aware = measure(conf, aware.mapping, w, spec, bw_true, seed=1)
    sim_blind = measure(conf, blind.mapping, w, spec, bw_true, seed=1)
    sim_default = measure(conf, default_mapping(conf), w, spec, bw_true,
                          seed=1)
    print()
    print(f"# phase C: hetero dedication on {spec.name} "
          f"({conf}, {iters} SA iters x2, {wall:.1f}s)")
    print("mapping,sim_latency_s")
    print(f"compute-aware SA,{sim_aware:.6f}")
    print(f"compute-blind SA,{sim_blind:.6f}")
    print(f"default (node-major),{sim_default:.6f}")
    gain = (1 - sim_aware / sim_blind) * 100
    verdict = "PASS" if sim_aware < sim_blind else "REGRESSION"
    print(f"compute-aware vs blind: {gain:+.1f}% simulated ({verdict})")
    return sim_aware < sim_blind


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=16,
                    help="cluster size in 8-GPU nodes (default 16)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: small estimator, tiny SA budget")
    ap.add_argument("--max-cp", type=int, default=1,
                    help="open the 4D context-parallel axis up to this "
                         "degree (default 1 = the 3D space)")
    ap.add_argument("--mixed-tier", action="store_true",
                    help="run on the seeded mixed A100/V100 fleet and "
                         "report compute-aware vs compute-blind dedication")
    args = ap.parse_args()

    if args.mixed_tier:
        spec = mixed_fleet_spec("mixed-a100-v100", args.nodes,
                                (A100_TIER, V100_TIER), (0.5, 0.5),
                                gpus_per_node=8, intra_bw=300e9,
                                inter_bw=12.5e9, seed=47)
    else:
        spec = MID_RANGE.with_nodes(args.nodes)
    w = Workload(GPT_3_1B, SEQ, BS_GLOBAL)
    steps = 1000 if args.quick else 4000
    t0 = time.perf_counter()
    est = fit_memory_estimator([w], spec, fit_nodes=2, steps=steps,
                               residual=True, max_cp=args.max_cp)
    print(f"# estimator fit ({steps} steps, max_cp={args.max_cp}): "
          f"{time.perf_counter() - t0:.1f}s")
    if args.max_cp > 1:
        n3 = len(enumerate_confs(spec.n_gpus, w.bs_global,
                                 n_layers=w.cfg.n_layers))
        n4 = len(enumerate_confs(spec.n_gpus, w.bs_global,
                                 n_layers=w.cfg.n_layers,
                                 max_cp=args.max_cp, seq=w.seq))
        print(f"# 4D mode: search space {n3} (3D) -> {n4} confs "
              f"({n4 / max(n3, 1):.1f}x)")

    print("benchmark,wall_s,n_in,n_out")
    rows = {}
    for name, sec, n_in, n_out in bench_prune(w, spec, est,
                                              max_cp=args.max_cp):
        rows[name] = sec
        print(f"{name},{sec:.4f},{n_in},{n_out}")
    speedup = rows[ROW_PRUNE_SEED] / rows[ROW_PRUNE_BATCHED]
    prof_speedup = (rows[ROW_PROFILES_SEED]
                    / max(rows[ROW_PROFILES_NEW], 1e-9))
    print(f"enumerate+prune speedup: {speedup:.1f}x")
    print(f"profile-construction speedup: {prof_speedup:.1f}x")

    print()
    print("benchmark,total_s,sa_s,mem_estimator_s,profile_s,prescore_s,"
          "n_enumerated,n_candidates")
    bw = true_bandwidth_matrix(spec)
    sa_iters = 30 if args.quick else 150
    max_micro = 2 if args.quick else 4
    for name, res in bench_search(w, spec, est, bw, sa_iters=sa_iters,
                                  max_micro=max_micro, sa_topk=8,
                                  max_cp=args.max_cp):
        # typed Overhead attributes: a mistyped field is an AttributeError
        # here, not a KeyError swallowed into a half-printed CSV row
        o = res.overhead
        print(f"{name},{o.total_s:.2f},{o.sa_s:.2f},"
              f"{o.mem_estimator_s:.4f},{o.profile_s:.4f},"
              f"{o.prescore_s:.4f},{o.n_enumerated},"
              f"{o.n_candidates}")

    print()
    verdict = "PASS" if speedup >= 5.0 else "BELOW TARGET"
    print(f"enumerate+prune speedup {speedup:.1f}x (target >= 5x): {verdict}")

    if args.mixed_tier:
        ok = bench_hetero_dedication(quick=args.quick)
        if not ok:
            raise SystemExit(
                "mixed-tier regression: compute-aware dedication did not "
                "beat compute-blind in the simulator")


if __name__ == "__main__":
    main()
