"""Dedication-engine benchmark: SA moves/sec of the incremental vectorized
engine vs the pure-Python reference scorer, plus end-to-end ``configure()``
wall-clock with both scoring paths.

    PYTHONPATH=src python -m benchmarks.bench_dedication [--nodes 8]

Acceptance target (ISSUE 1): >= 10x moves/sec on a 64-GPU cluster.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (MID_RANGE, Conf, Workload, build_profile, configure,
                        true_bandwidth_matrix)
from repro.core.dedication import DedicationEngine, _move_span, \
    perm_to_mapping
from repro.core.latency import pipette_latency_ref
from repro.models.config import ModelConfig

GPT = ModelConfig(name="bench-gpt", family="dense", n_layers=32,
                  d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
                  vocab_size=51200)


def moves_per_sec_reference(conf, bw, prof, spec, n_moves: int,
                            seed: int = 0) -> float:
    """Full per-move re-scoring with the pure-Python reference scorer (the
    pre-vectorization hot loop of ``anneal``)."""
    rng = np.random.default_rng(seed)
    perm = np.arange(conf.n_gpus)
    t0 = time.perf_counter()
    for _ in range(n_moves):
        cand, _ = _move_span(perm, rng)
        pipette_latency_ref(conf, perm_to_mapping(cand, conf), bw, prof,
                            spec)
        perm = cand
    return n_moves / (time.perf_counter() - t0)


def moves_per_sec_engine(conf, bw, prof, spec, n_moves: int,
                         seed: int = 0) -> float:
    """Incremental delta-scoring with :class:`DedicationEngine`."""
    rng = np.random.default_rng(seed)
    perm = np.arange(conf.n_gpus)
    engine = DedicationEngine(conf, bw, prof, spec)
    engine.score(perm)
    t0 = time.perf_counter()
    for _ in range(n_moves):
        cand, touched = _move_span(perm, rng)
        _, pending = engine.propose(cand, touched)
        engine.commit(pending)
        perm = cand
    return n_moves / (time.perf_counter() - t0)


def bench_moves(nodes: int = 8, ref_moves: int = 400,
                engine_moves: int = 20_000, repeats: int = 3):
    """Moves/sec on an ``8 * nodes``-GPU cluster for a few (pp, tp, dp)
    shapes (best of ``repeats`` to damp machine noise).  The first shape is
    the primary acceptance configuration — a Megatron-style pp4 layout, the
    paper's typical 64-GPU regime.  Yields rows
    ``(name, ref_mps, engine_mps, speedup)``."""
    spec = MID_RANGE.with_nodes(nodes)
    bw = true_bandwidth_matrix(spec)
    g = spec.n_gpus
    shapes = [(4, 8, g // 32), (8, 4, g // 32), (2, 8, g // 16)]
    for pp, tp, dp in shapes:
        conf = Conf(pp, tp, dp, 2, 16 * dp)
        prof = build_profile(Workload(GPT, 2048, conf.bs_global), spec, conf)
        # pair each repeat's measurements back-to-back so transient machine
        # load cancels in the ratio; report the best pair
        best = None
        for k in range(repeats):
            r = moves_per_sec_reference(conf, bw, prof, spec, ref_moves,
                                        seed=k)
            e = moves_per_sec_engine(conf, bw, prof, spec, engine_moves,
                                     seed=k)
            if best is None or e / r > best[2]:
                best = (r, e, e / r)
        yield (f"moves/s pp{pp}·tp{tp}·dp{dp} ({g} GPUs)",
               best[0], best[1], best[2])


def bench_configure(nodes: int = 4, sa_iters: int = 400):
    """End-to-end ``configure()`` wall-clock before/after: the engine path
    vs the pre-vectorization behaviour (``anneal`` with a full-rescore
    ``pipette_latency_ref`` objective), on identical SA budgets."""
    spec = MID_RANGE.with_nodes(nodes)
    bw = true_bandwidth_matrix(spec)
    w = Workload(GPT, 2048, 128)
    kw = dict(sa_seconds=60.0, sa_iters=sa_iters, max_micro=2, seed=0)

    t0 = time.perf_counter()
    res_fast = configure(w, spec, bw, **kw)
    fast_s = time.perf_counter() - t0
    yield ("configure() engine", fast_s, res_fast.best.latency,
           res_fast.overhead.n_candidates)

    def ref_objective_for(conf, prof):
        def objective(p):
            return pipette_latency_ref(conf, perm_to_mapping(p, conf), bw,
                                       prof, spec)
        return objective

    from repro.core import enumerate_confs
    from repro.core.dedication import anneal

    t0 = time.perf_counter()
    best = None
    n = 0
    for conf in enumerate_confs(spec.n_gpus, w.bs_global,
                                n_layers=GPT.n_layers):
        if conf.bs_micro > kw["max_micro"]:
            continue
        prof = build_profile(w, spec, conf)
        res = anneal(conf, bw, prof, spec, time_limit_s=kw["sa_seconds"],
                     max_iters=sa_iters, seed=0,
                     objective=ref_objective_for(conf, prof))
        n += 1
        if best is None or res.latency < best:
            best = res.latency
    ref_s = time.perf_counter() - t0
    yield ("configure() reference-rescore", ref_s, best, n)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=8,
                    help="cluster size in 8-GPU nodes (default 8 = 64 GPUs)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: fewer moves/repeats, skip the "
                         "slow reference-rescore configure() pass")
    args = ap.parse_args()

    if args.quick:
        move_kw = dict(ref_moves=100, engine_moves=5_000, repeats=2)
    else:
        move_kw = dict()

    print("benchmark,ref_moves_per_s,engine_moves_per_s,speedup")
    speedups = []
    for name, r, e, s in bench_moves(args.nodes, **move_kw):
        speedups.append(s)
        print(f"{name},{r:.0f},{e:.0f},{s:.1f}x")
    print()
    print("benchmark,wall_s,best_latency_s,n_candidates")
    cfg_rows = [] if args.quick else list(bench_configure())
    for name, sec, lat, n in cfg_rows:
        print(f"{name},{sec:.2f},{lat:.4f},{n}")
    if len(cfg_rows) == 2:
        print(f"configure() end-to-end speedup: "
              f"{cfg_rows[1][1] / cfg_rows[0][1]:.1f}x")
    print()
    primary = speedups[0]
    verdict = "PASS" if primary >= 10.0 else "BELOW TARGET"
    print(f"primary-config speedup {primary:.1f}x "
          f"(target >= 10x): {verdict}; all shapes: "
          + ", ".join(f"{s:.1f}x" for s in speedups))


if __name__ == "__main__":
    main()
