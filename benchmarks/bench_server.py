"""Plan-server benchmark: cold vs cache-hit vs warm-start, and the
concurrent-throughput curve.

    PYTHONPATH=src python -m benchmarks.bench_server [--quick]

Phase A (latency) runs one in-process :class:`~repro.service.PlanServer`
and times the three response classes of the service request path:

* **cold** — full Algorithm 1 search (enumerate -> prune -> profile ->
  pre-score -> SA dedication);
* **cache hit** — the same request again: fingerprint lookup + verifier
  admission, byte-identical bytes back, no Strategy invoked;
* **warm start** — a distance-0 neighbor (same workload, wider microbatch
  cap): a cold search whose SA chains are seeded from the cached
  incumbent's mapping.

Phase B (the warm-start economy gate) replays the pinned seeded
comparison of ``tests/test_service.py`` at benchmark scale: the warm
search must reach a plan **at least as good** as the cold search of the
same request while accepting **strictly fewer** improving moves (or
landing on the identical best).  The benchmark **exits non-zero** if the
warm search loses — this is the acceptance gate of the service issue,
kept hot in CI via ``--quick``.

Phase C (throughput) drives the server with N concurrent pipelined
clients replaying cache hits and prints the requests/sec curve, plus a
coalescing probe: N identical cold requests land concurrently and the
server must run exactly ONE search for all of them.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core import (MID_RANGE, Budget, PlanRequest, SearchSpace,
                        Workload, mapping_to_perm, profile_bandwidth,
                        run_search)
from repro.models.config import ModelConfig
from repro.service import PlanClient, PlanServer

GPT = ModelConfig(name="g", family="dense", n_layers=16, d_model=1024,
                  n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=32000)


def _request(spec, *, max_micro: int, sa_iters: int, seed: int = 7,
             warm_start=None) -> PlanRequest:
    return PlanRequest(
        workload=Workload(GPT, 2048, 32), spec=spec,
        space=SearchSpace(max_micro=max_micro),
        budget=Budget(sa_seconds=600.0, sa_iters=sa_iters, sa_topk=2,
                      warm_start=warm_start),
        seed=seed)


def bench_latency(sa_iters: int):
    """Cold / hit / warm round-trip latency through a live server."""
    spec = MID_RANGE.with_nodes(1)
    server = PlanServer(port=0)
    server.start_in_thread()
    client = PlanClient(port=server.port)
    rows = []
    try:
        req = _request(spec, max_micro=2, sa_iters=sa_iters)
        t0 = time.perf_counter()
        cold = client.submit(req)
        rows.append(("cold search", time.perf_counter() - t0, cold))

        t0 = time.perf_counter()
        hit = client.submit(req)
        rows.append(("cache hit", time.perf_counter() - t0, hit))

        neighbor = _request(spec, max_micro=4, sa_iters=sa_iters)
        t0 = time.perf_counter()
        warm = client.submit(neighbor)
        rows.append(("warm-started search", time.perf_counter() - t0, warm))
    finally:
        server.stop()

    print("== phase A: response-class latency (one server, one client) ==")
    for name, dt, resp in rows:
        meta = resp["meta"]
        extra = (f" warm_start_from={meta['warm_start_from'][:12]}..."
                 if meta.get("warm_start_from") else "")
        print(f"  {name:<22} {dt * 1e3:9.2f} ms   "
              f"cache={meta['cache']}{extra}")
    ok = True
    if hit["plan"] != cold["plan"]:
        print("  FAIL: cache hit was not byte-identical to the cold plan")
        ok = False
    if hit["meta"]["cache"] != "hit" or not warm["meta"].get(
            "warm_start_from"):
        print("  FAIL: expected a cache hit and a warm-started neighbor")
        ok = False
    cold_s, hit_s = rows[0][1], rows[1][1]
    print(f"  hit speedup over cold: {cold_s / hit_s:8.1f}x")
    return ok


def bench_warm_gate(sa_iters: int):
    """The acceptance gate: warm SA is never worse, and cheaper."""
    spec = MID_RANGE.with_nodes(2)
    bw = profile_bandwidth(spec)[0]
    seed_req = _request(spec, max_micro=2, sa_iters=sa_iters)
    incumbent = run_search(seed_req, bw)
    perm = tuple(int(x) for x in mapping_to_perm(incumbent.best.mapping))

    neighbor = _request(spec, max_micro=4, sa_iters=sa_iters)
    t0 = time.perf_counter()
    cold = run_search(neighbor, bw)
    cold_s = time.perf_counter() - t0
    warm_req = dataclasses.replace(
        neighbor, budget=dataclasses.replace(neighbor.budget,
                                             warm_start=perm))
    t0 = time.perf_counter()
    warm = run_search(warm_req, bw)
    warm_s = time.perf_counter() - t0

    same_best = (warm.best.conf == cold.best.conf
                 and np.array_equal(warm.best.mapping, cold.best.mapping))
    print("== phase B: warm-start economy "
          "(same request, cold vs seeded SA) ==")
    print(f"  cold: latency {cold.best.latency:.6f}s  "
          f"accepted-to-best {cold.overhead.sa_accepted_to_best:4d}  "
          f"wall {cold_s:6.2f}s")
    print(f"  warm: latency {warm.best.latency:.6f}s  "
          f"accepted-to-best {warm.overhead.sa_accepted_to_best:4d}  "
          f"wall {warm_s:6.2f}s")
    ok = True
    if warm.best.latency > cold.best.latency:
        print("  FAIL: warm-started search found a WORSE plan")
        ok = False
    if (warm.overhead.sa_accepted_to_best
            >= cold.overhead.sa_accepted_to_best and not same_best):
        print("  FAIL: warm start spent >= accepted moves without "
              "matching the cold best")
        ok = False
    if ok:
        print("  gate passed: plan >= cold's at "
              f"{warm.overhead.sa_accepted_to_best} vs "
              f"{cold.overhead.sa_accepted_to_best} accepted moves"
              + (" (identical best)" if same_best else ""))
    return ok


def bench_throughput(sa_iters: int, levels, hits_per_client: int):
    """Requests/sec of cache hits under N concurrent pipelined clients,
    plus the coalescing probe (N identical cold requests, one search)."""
    spec = MID_RANGE.with_nodes(1)
    server = PlanServer(port=0)
    server.start_in_thread()
    try:
        req = _request(spec, max_micro=2, sa_iters=sa_iters)
        PlanClient(port=server.port).submit(req)        # populate the cache

        print("== phase C: concurrent cache-hit throughput ==")
        for n in levels:
            def one_client():
                client = PlanClient(port=server.port)
                return client.submit_many([req] * hits_per_client)

            t0 = time.perf_counter()
            with ThreadPoolExecutor(max_workers=n) as pool:
                for resp in pool.map(lambda _: one_client(), range(n)):
                    assert all(r["meta"]["cache"] == "hit" for r in resp)
            dt = time.perf_counter() - t0
            total = n * hits_per_client
            print(f"  {n:3d} client(s) x {hits_per_client} hits: "
                  f"{total / dt:9.0f} req/s  ({dt * 1e3:7.1f} ms total)")
    finally:
        server.stop()

    # coalescing probe: fresh server, N identical cold requests at once
    server = PlanServer(port=0)
    server.start_in_thread()
    try:
        n = max(levels)
        cold_req = _request(spec, max_micro=2, sa_iters=sa_iters, seed=11)
        client = PlanClient(port=server.port)
        resps = client.submit_many([cold_req] * n)
        kinds = sorted(r["meta"]["cache"] for r in resps)
        searches = server.counters["searches_run"]
        print(f"  coalescing probe: {n} identical cold requests -> "
              f"{searches} search(es), "
              f"{kinds.count('coalesced')} coalesced")
        if searches != 1 or len({r["plan"] for r in resps}) != 1:
            print("  FAIL: identical concurrent requests did not share "
                  "one search")
            return False
    finally:
        server.stop()
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (fewer SA iters, fewer clients)")
    args = ap.parse_args(argv)

    sa_iters = 40 if args.quick else 200
    levels = (1, 4, 8) if args.quick else (1, 2, 4, 8, 16)
    hits = 50 if args.quick else 200

    ok = bench_latency(sa_iters)
    ok = bench_warm_gate(sa_iters) and ok
    ok = bench_throughput(sa_iters, levels, hits) and ok
    if not ok:
        print("bench_server: GATE FAILED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
