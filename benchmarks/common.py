"""Shared benchmark setup: clusters, paper GPT workloads, cached memory
estimators, and the AMP/Varuna 'try recommendations one by one' protocol
from §VII-A."""
from __future__ import annotations

import functools
import time

import numpy as np

from repro.configs.gpt_paper import GPT_1_1B, GPT_3_1B, GPT_8_1B, GPT_11_1B
from repro.core import (HIGH_END, MID_RANGE, Workload, fit_memory_estimator,
                        ground_truth_memory, measure, profile_bandwidth,
                        true_bandwidth_matrix)

SEQ = 2048
CLUSTERS = {"mid-range": MID_RANGE, "high-end": HIGH_END}
# paper: models sized to reach the memory limit per cluster (§VII-A)
CLUSTER_MODEL = {("mid-range", 8): GPT_1_1B, ("mid-range", 16): GPT_3_1B,
                 ("high-end", 8): GPT_8_1B, ("high-end", 16): GPT_11_1B}


def workload(cluster: str, nodes: int, bs_global: int = 256) -> Workload:
    return Workload(CLUSTER_MODEL[(cluster, nodes)], SEQ, bs_global)


@functools.lru_cache(maxsize=8)
def matrices(cluster: str, nodes: int, day: int = 0):
    spec = CLUSTERS[cluster].with_nodes(nodes)
    bw_true = true_bandwidth_matrix(spec, day)
    bw_meas, cost = profile_bandwidth(spec, day)
    return spec, bw_true, bw_meas, cost


_EST_CACHE = {}


def memory_estimator(cluster: str, *, steps: int = 12_000, residual=True):
    """Per-cluster MLP estimator trained on <=4-node configs (paper §VI)."""
    key = (cluster, steps, residual)
    if key not in _EST_CACHE:
        spec = CLUSTERS[cluster]
        models = [CLUSTER_MODEL[(cluster, 8)], CLUSTER_MODEL[(cluster, 16)]]
        ws = [Workload(m, SEQ, bsg) for m in models
              for bsg in (32, 64, 128, 256, 512)]
        _EST_CACHE[key] = fit_memory_estimator(
            ws, spec, fit_nodes=4, steps=steps, residual=residual)
    return _EST_CACHE[key]


def first_runnable(ranked, w, spec):
    """The paper's AMP/Varuna protocol: walk the recommendation list,
    'run' each on the cluster, stop at the first that does not OOM.
    Returns (candidate, n_trials).  The OOM check is physical: on a tiered
    fleet the *smallest* GPU overflows first (``mem_floor``, == ``gpu_mem``
    when homogeneous).  Twin of examples/configure_cluster.py's copy —
    keep the two in sync."""
    for i, c in enumerate(ranked):
        if ground_truth_memory(w, c.conf, spec) <= spec.mem_floor:
            return c, i + 1
    return None, len(ranked)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0

    @property
    def us(self):
        return self.s * 1e6
