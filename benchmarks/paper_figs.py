"""One benchmark per paper table/figure (§VII).  Each returns a list of
(name, us_per_call, derived) CSV rows."""
from __future__ import annotations

import numpy as np

from repro.core import (Conf, amp_configure, amp_latency, build_profile,
                        configure, default_mapping, ground_truth_memory,
                        measure, mlm_configure, pipette_latency,
                        true_bandwidth_matrix, varuna_configure)
from repro.core.memory import analytical_estimate, enumerate_confs, mape

from .common import (CLUSTERS, Timer, first_runnable, matrices,
                     memory_estimator, workload)


# ---------------------------------------------------------------------------
# Fig. 3 — interconnect heterogeneity over time
# ---------------------------------------------------------------------------

def fig3_heterogeneity():
    rows = []
    with Timer() as t:
        spec = CLUSTERS["high-end"].with_nodes(8)
        spreads, drifts = [], []
        day0 = None
        for day in range(8):      # 40 days in the paper; 8 samples here
            bw = true_bandwidth_matrix(spec, day)
            inter = bw[bw < spec.intra_bw * 0.5]
            spreads.append(inter.max() / inter.min())
            if day0 is None:
                day0 = inter
            else:
                drifts.append(float(np.mean(np.abs(inter - day0) / day0)))
    rows.append(("fig3_link_spread_max_over_min", t.us,
                 f"{np.mean(spreads):.2f}"))
    rows.append(("fig3_day_to_day_drift_pct", t.us,
                 f"{100 * np.mean(drifts):.1f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 5a — latency estimation MAPE (Pipette vs AMP model)
# ---------------------------------------------------------------------------

def fig5a_latency_mape():
    rows = []
    for cluster in ("mid-range", "high-end"):
        spec, bw_true, bw_meas, _ = matrices(cluster, 16)
        w = workload(cluster, 16)
        errs_p, errs_a = [], []
        with Timer() as t:
            sample = [c for c in enumerate_confs(spec.n_gpus, w.bs_global,
                                                 n_layers=w.cfg.n_layers)
                      if c.bs_micro <= 8][::2][:30]
            for conf in sample:
                prof = build_profile(w, spec, conf)
                m = default_mapping(conf)
                truth = measure(conf, m, w, spec, bw_true)
                errs_p.append(abs(pipette_latency(conf, m, bw_meas, prof,
                                                  spec) - truth) / truth)
                errs_a.append(abs(amp_latency(conf, m, spec, prof) - truth)
                              / truth)
        rows.append((f"fig5a_mape_pipette_{cluster}", t.us,
                     f"{100 * np.mean(errs_p):.2f}"))
        rows.append((f"fig5a_mape_amp_{cluster}", t.us,
                     f"{100 * np.mean(errs_a):.2f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 5b — OOM count among the top-10 recommendations
# ---------------------------------------------------------------------------

def fig5b_top10_oom():
    rows = []
    cluster, nodes = "mid-range", 16
    spec, bw_true, bw_meas, _ = matrices(cluster, nodes)
    w = workload(cluster, nodes)

    def oom_count(ranked):
        return sum(ground_truth_memory(w, c.conf, spec) > spec.gpu_mem  # repro: noqa DET004 -- counting booleans: integer addition is order-independent
                   for c in ranked[:10])

    with Timer() as t:
        amp = amp_configure(w, spec)
        vr = varuna_configure(w, spec)
        est = memory_estimator(cluster)
        ppt = configure(w, spec, bw_meas, estimator=est,
                        mem_limit=spec.gpu_mem, dedicate=False)
    rows.append(("fig5b_oom_top10_amp", t.us, str(oom_count(amp.ranked))))
    rows.append(("fig5b_oom_top10_varuna", t.us, str(oom_count(vr.ranked))))
    rows.append(("fig5b_oom_top10_pipette", t.us, str(oom_count(ppt.ranked))))
    return rows


# ---------------------------------------------------------------------------
# Fig. 6 — training time and speedup vs MLM / Varuna / AMP (+ablation)
# ---------------------------------------------------------------------------

def fig6_speedup():
    rows = []
    for cluster in ("mid-range", "high-end"):
        spec, bw_true, bw_meas, _ = matrices(cluster, 16)
        w = workload(cluster, 16)
        est = memory_estimator(cluster)
        with Timer() as t:
            mlm = mlm_configure(w, spec, bw_true)
            t_mlm = mlm.best.latency

            amp = amp_configure(w, spec)
            amp_c, trials = first_runnable(amp.ranked, w, spec)
            t_amp = measure(amp_c.conf, amp_c.mapping, w, spec, bw_true)

            vr = varuna_configure(w, spec)
            vr_c, _ = first_runnable(vr.ranked, w, spec)
            t_vr = measure(vr_c.conf, vr_c.mapping, w, spec, bw_true)

            # PPT-L: latency+memory estimators, identity mapping
            pl = configure(w, spec, bw_meas, estimator=est,
                           mem_limit=spec.gpu_mem, dedicate=False)
            t_pl = measure(pl.best.conf, pl.best.mapping, w, spec, bw_true)

            # PPT-LF: + fine-grained worker dedication
            plf = configure(w, spec, bw_meas, estimator=est,
                            mem_limit=spec.gpu_mem, sa_seconds=0.25,
                            sa_iters=4000, seed=1)
            t_plf = measure(plf.best.conf, plf.best.mapping, w, spec,
                            bw_true)
        rows += [
            (f"fig6_{cluster}_iter_ms_mlm", t.us, f"{t_mlm*1e3:.1f}"),
            (f"fig6_{cluster}_iter_ms_varuna", t.us, f"{t_vr*1e3:.1f}"),
            (f"fig6_{cluster}_iter_ms_amp", t.us, f"{t_amp*1e3:.1f}"),
            (f"fig6_{cluster}_iter_ms_ppt_l", t.us, f"{t_pl*1e3:.1f}"),
            (f"fig6_{cluster}_iter_ms_ppt_lf", t.us, f"{t_plf*1e3:.1f}"),
            (f"fig6_{cluster}_speedup_ppt_lf_over_amp", t.us,
             f"{t_amp/t_plf:.3f}"),
            (f"fig6_{cluster}_speedup_ppt_lf_over_mlm", t.us,
             f"{t_mlm/t_plf:.3f}"),
            (f"fig6_{cluster}_speedup_ppt_l_over_vr", t.us,
             f"{t_vr/t_pl:.3f}"),
            (f"fig6_{cluster}_amp_trials_until_runnable", t.us,
             str(trials)),
        ]
    return rows


# ---------------------------------------------------------------------------
# Fig. 7 — memory estimation MAPE (MLP vs analytical [20])
# ---------------------------------------------------------------------------

def fig7_memory_mape():
    rows = []
    for cluster in ("mid-range", "high-end"):
        spec = CLUSTERS[cluster]
        est = memory_estimator(cluster)
        w = workload(cluster, 16)
        with Timer() as t:
            preds, anas, trues = [], [], []
            confs = [c for c in enumerate_confs(
                spec.n_gpus, w.bs_global, n_layers=w.cfg.n_layers)
                if c.bs_micro <= 8]
            for conf in confs[:215]:     # paper: 215 data points
                trues.append(ground_truth_memory(w, conf, spec))
                preds.append(est.predict(w.cfg, conf))
                anas.append(analytical_estimate(w, conf))
        rows.append((f"fig7_mape_mlp_{cluster}", t.us,
                     f"{mape(preds, trues):.2f}"))
        rows.append((f"fig7_mape_analytical_{cluster}", t.us,
                     f"{mape(anas, trues):.2f}"))
        rows.append((f"fig7_n_points_{cluster}", t.us, str(len(trues))))
    return rows


# ---------------------------------------------------------------------------
# Table II — configuration overhead
# ---------------------------------------------------------------------------

def table2_overhead():
    rows = []
    for cluster, nodes in (("mid-range", 8), ("mid-range", 16),
                           ("high-end", 8), ("high-end", 16)):
        spec, bw_true, bw_meas, profile_cost = matrices(cluster, nodes)
        w = workload(cluster, nodes)
        est = memory_estimator(cluster)
        with Timer() as t:
            res = configure(w, spec, bw_meas, estimator=est,
                            mem_limit=spec.gpu_mem, sa_seconds=0.15,
                            sa_iters=2500)
        t_iter = measure(res.best.conf, res.best.mapping, w, spec, bw_true)
        # paper's overhead metric: conf time / full 300K-iteration training
        total_train_s = t_iter * 300_000
        conf_s = profile_cost + res.overhead.total_s
        rows += [
            (f"table2_{cluster}_{nodes}n_profiling_s", t.us,
             f"{profile_cost:.1f}"),
            (f"table2_{cluster}_{nodes}n_sa_s", t.us,
             f"{res.overhead.sa_s:.1f}"),
            (f"table2_{cluster}_{nodes}n_memest_s", t.us,
             f"{res.overhead.mem_estimator_s:.3f}"),
            (f"table2_{cluster}_{nodes}n_overhead_pct", t.us,
             f"{100 * conf_s / total_train_s:.4f}"),
        ]
    return rows


# ---------------------------------------------------------------------------
# Fig. 8 — cluster/model size scalability (weak scaling)
# ---------------------------------------------------------------------------

def fig8_scalability():
    rows = []
    from repro.configs.gpt_paper import GPT_1_1B, GPT_3_1B, GPT_8_1B
    from repro.core import Workload
    scale_model = {4: GPT_1_1B, 8: GPT_1_1B, 16: GPT_3_1B}
    for nodes in (4, 8, 16):
        cluster = "mid-range"
        spec, bw_true, bw_meas, _ = matrices(cluster, nodes)
        w = Workload(scale_model[nodes], 2048, 256)
        est = memory_estimator(cluster)
        with Timer() as t:
            amp = amp_configure(w, spec)
            amp_c, _ = first_runnable(amp.ranked, w, spec)
            t_amp = measure(amp_c.conf, amp_c.mapping, w, spec, bw_true)
            ppt = configure(w, spec, bw_meas, estimator=est,
                            mem_limit=spec.gpu_mem, sa_seconds=0.2,
                            sa_iters=3000, seed=2)
            t_ppt = measure(ppt.best.conf, ppt.best.mapping, w, spec,
                            bw_true)
        rows.append((f"fig8_speedup_over_amp_{nodes*8}gpus", t.us,
                     f"{t_amp/t_ppt:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 9 — micro/minibatch size sensitivity
# ---------------------------------------------------------------------------

def fig9_batch_sensitivity():
    rows = []
    cluster, nodes = "mid-range", 16
    spec, bw_true, bw_meas, _ = matrices(cluster, nodes)
    est = memory_estimator(cluster)
    from repro.core import Workload
    cfg = workload(cluster, nodes).cfg

    def best_with(w, fixed_micro=None):
        res_a = amp_configure(w, spec, max_micro=fixed_micro or 16)
        ranked = [c for c in res_a.ranked
                  if fixed_micro is None or c.conf.bs_micro == fixed_micro]
        amp_c, _ = first_runnable(ranked, w, spec)
        t_amp = measure(amp_c.conf, amp_c.mapping, w, spec, bw_true)
        res_p = configure(w, spec, bw_meas, estimator=est,
                          mem_limit=spec.gpu_mem, sa_seconds=0.12,
                          sa_iters=2000, fixed_micro=fixed_micro, seed=3)
        best = res_p.best
        t_ppt = measure(best.conf, best.mapping, w, spec, bw_true)
        return t_amp / t_ppt

    with Timer() as t:
        micro = [(mb, best_with(Workload(cfg, 2048, 256), fixed_micro=mb))
                 for mb in (1, 2, 4, 8)]          # fixed minibatch 256
        mini = [(bsg, best_with(Workload(cfg, 2048, bsg), fixed_micro=8))
                for bsg in (128, 256, 512)]       # fixed microbatch 8
    for mb, s in micro:
        rows.append((f"fig9_speedup_microbatch_{mb}", t.us, f"{s:.3f}"))
    for bsg, s in mini:
        rows.append((f"fig9_speedup_minibatch_{bsg}", t.us, f"{s:.3f}"))
    return rows
