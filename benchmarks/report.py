"""Generate the §Dry-run and §Roofline sections of EXPERIMENTS.md from
the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.report > /tmp/roofline_sections.md
"""
from __future__ import annotations

import json
from collections import defaultdict
from pathlib import Path

from .roofline import load_cells, markdown_table, roofline_rows


def dryrun_section(cells):
    out = ["## §Dry-run", ""]
    n_ok = sum(1 for c in cells if "skipped" not in c)
    n_skip = sum(1 for c in cells if "skipped" in c)
    out.append(f"{n_ok} cells lowered+compiled, {n_skip} documented skips "
               f"(spec: long_500k on pure full-attention archs).")
    out.append("")
    out.append("| cell | compile s | HLO MB | args+temp GiB/dev | "
               "fits 16G | collective GB/dev |")
    out.append("|---|---|---|---|---|---|")
    for d in sorted(cells, key=lambda d: (d["arch"], d["shape"], d["mesh"])):
        name = f"{d['arch']}\\|{d['shape']}\\|{d['mesh']}"
        if "skipped" in d:
            out.append(f"| {name} | — | — | — | SKIP | — |")
            continue
        out.append(
            f"| {name} | {d['compile_s']:.0f} | "
            f"{d['hlo_bytes_len']/1e6:.1f} | "
            f"{d['bytes_per_device']/2**30:.2f} | "
            f"{'yes' if d['fits_v5e_16g'] else 'NO'} | "
            f"{d['collective_bytes_per_dev']/1e9:.1f} |")
    out.append("")
    return "\n".join(out)


def roofline_section(cells):
    out = ["## §Roofline", ""]
    out.append("Terms per device per step (TPU v5e: 197 TFLOP/s bf16, "
               "819 GB/s HBM, 50 GB/s/link ICI): "
               "`t_compute = HLO_FLOPs/peak`, `t_memory = HLO_bytes/bw`, "
               "`t_collective = collective_bytes/link_bw`; FLOPs/bytes from "
               "the structured HLO walk (launch/hlo_cost.py) with while-loop "
               "trip counts applied; `6ND/HLO` = MODEL_FLOPS / total HLO "
               "FLOPs (remat/redundancy waste).")
    for mesh in ("16x16", "2x16x16"):
        out.append("")
        out.append(f"### mesh {mesh}")
        out.append("")
        out.append(markdown_table(roofline_rows(cells, mesh)))
    return "\n".join(out)


def bottleneck_summary(cells):
    out = ["", "### Bottleneck summary (single-pod)", ""]
    rows = [r for r in roofline_rows(cells, "16x16") if "skipped" not in r]
    by = defaultdict(list)
    for r in rows:
        by[r["bottleneck"]].append(r)
    for b, rs in sorted(by.items()):
        cells_s = ", ".join(r["cell"].split("|")[0] + ":" +
                            r["cell"].split("|")[1] for r in rs[:6])
        more = f" (+{len(rs)-6} more)" if len(rs) > 6 else ""
        out.append(f"- **{b}-bound** ({len(rs)} cells): {cells_s}{more}")
    worst = sorted(rows, key=lambda r: r["roofline_frac"])[:5]
    out.append("")
    out.append("Worst roofline fractions: " +
               ", ".join(f"{r['cell']} ({r['roofline_frac']:.3f})"
                         for r in worst))
    return "\n".join(out)


def main():
    cells = load_cells()
    print(dryrun_section(cells))
    print(roofline_section(cells))
    print(bottleneck_summary(cells))


if __name__ == "__main__":
    main()
