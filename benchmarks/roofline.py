"""Roofline report (deliverable g): reads the dry-run artifacts and emits
the per-(arch x shape x mesh) three-term table + markdown for
EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

ART = Path("artifacts/dryrun")

V5E = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}


def load_cells(art_dir: Path = ART, tag: str = "") -> List[Dict]:
    cells = []
    for p in sorted(art_dir.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("tag", "") != tag:
            continue
        cells.append(d)
    return cells


def roofline_rows(cells: List[Dict], mesh: Optional[str] = "16x16"):
    rows = []
    for d in cells:
        if mesh and d.get("mesh") != mesh:
            continue
        name = f"{d['arch']}|{d['shape']}|{d['mesh']}"
        if "skipped" in d:
            rows.append({"cell": name, "skipped": d["skipped"]})
            continue
        t = {k: d[k] for k in ("t_compute", "t_memory", "t_collective")}
        dom = max(t, key=t.get)
        bound = max(t.values())
        frac = d["t_compute"] / bound if bound else 0.0
        rows.append({
            "cell": name,
            "t_compute": d["t_compute"], "t_memory": d["t_memory"],
            "t_collective": d["t_collective"], "bottleneck": dom[2:],
            "roofline_frac": frac,
            "useful_flops_ratio": d.get("useful_flops_ratio", 0.0),
            "bytes_per_dev_gb": d.get("bytes_per_device", 0) / 2 ** 30,
            "fits_v5e": d.get("fits_v5e_16g"),
        })
    return rows


def markdown_table(rows: List[Dict]) -> str:
    out = ["| cell | compute s | memory s | collective s | bottleneck | "
           "roofline frac | 6ND/HLO | bytes/dev GiB | fits 16G |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['cell']} | — | — | — | SKIP | — | — | — | — |")
            continue
        out.append(
            f"| {r['cell']} | {r['t_compute']:.3f} | {r['t_memory']:.3f} | "
            f"{r['t_collective']:.3f} | {r['bottleneck']} | "
            f"{r['roofline_frac']:.3f} | {r['useful_flops_ratio']:.3f} | "
            f"{r['bytes_per_dev_gb']:.1f} | "
            f"{'yes' if r['fits_v5e'] else 'NO'} |")
    return "\n".join(out)


def bench_rows():
    """CSV rows for benchmarks.run."""
    cells = load_cells()
    rows = []
    for mesh in ("16x16", "2x16x16"):
        rr = roofline_rows(cells, mesh)
        live = [r for r in rr if "skipped" not in r]
        if not live:
            continue
        worst = min(live, key=lambda r: r["roofline_frac"])
        rows.append((f"roofline_{mesh}_n_cells", 0.0, str(len(rr))))
        rows.append((f"roofline_{mesh}_n_skipped", 0.0,
                     str(len(rr) - len(live))))
        rows.append((f"roofline_{mesh}_median_frac", 0.0,
                     f"{sorted(r['roofline_frac'] for r in live)[len(live)//2]:.3f}"))
        rows.append((f"roofline_{mesh}_worst_cell", 0.0,
                     f"{worst['cell']}:{worst['roofline_frac']:.3f}"))
        for b in ("compute", "memory", "collective"):
            n = sum(r["bottleneck"] == b for r in live)  # repro: noqa DET004 -- counting booleans: integer addition is order-independent
            rows.append((f"roofline_{mesh}_{b}_bound_cells", 0.0, str(n)))
    return rows


if __name__ == "__main__":
    cells = load_cells()
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### mesh {mesh}\n")
        print(markdown_table(roofline_rows(cells, mesh)))
