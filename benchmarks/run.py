"""Benchmark harness (deliverable d): one function per paper table/figure
plus the roofline report.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only fig6,roofline]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark name filter")
    args = ap.parse_args()

    from . import paper_figs, roofline
    benches = [
        ("fig3", paper_figs.fig3_heterogeneity),
        ("fig5a", paper_figs.fig5a_latency_mape),
        ("fig5b", paper_figs.fig5b_top10_oom),
        ("fig6", paper_figs.fig6_speedup),
        ("fig7", paper_figs.fig7_memory_mape),
        ("table2", paper_figs.table2_overhead),
        ("fig8", paper_figs.fig8_scalability),
        ("fig9", paper_figs.fig9_batch_sensitivity),
        ("roofline", roofline.bench_rows),
    ]
    only = {s for s in args.only.split(",") if s}
    print("name,us_per_call,derived")
    failures = []
    for name, fn in benches:
        if only and name not in only:
            continue
        try:
            for row in fn():
                print(f"{row[0]},{row[1]:.0f},{row[2]}")
            sys.stdout.flush()
        except Exception as e:                      # pragma: no cover
            failures.append((name, e))
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {[n for n, _ in failures]}")


if __name__ == '__main__':
    main()
