"""The paper's headline experiment at full scale: configure GPT-3.1B
training on the simulated 128-GPU mid-range cluster and compare Pipette
(PPT-L / PPT-LF) against Megatron-LM, Varuna and AMP (Fig. 6) — all five
configurators running behind the single Planner API, as one loop over
strategies instead of four bespoke call sites.

``--cluster mid-range-degraded`` runs the same pipeline on a partially-
degraded fleet (a quarter of the hosts thermally throttled to half speed,
seeded): the search prices each pipeline stage at its slowest member GPU,
and a closing demo compares compute-aware vs compute-blind worker
dedication of the winning configuration in the simulator.

    PYTHONPATH=src python examples/configure_cluster.py [--cluster high-end]
"""
import argparse
import time

from repro.core import (HIGH_END, MID_RANGE, MID_RANGE_DEGRADED,
                        AMPStrategy, Budget, ExhaustiveStrategy,
                        MegatronStrategy, Planner, PlanRequest,
                        PipetteStrategy, SearchSpace, VarunaStrategy,
                        Workload, anneal_multistart, build_profile,
                        fit_memory_estimator, ground_truth_memory, measure,
                        profile_bandwidth, true_bandwidth_matrix)
from repro.configs.gpt_paper import GPT_3_1B, GPT_11_1B

CLUSTERS = {"mid-range": MID_RANGE, "high-end": HIGH_END,
            "mid-range-degraded": MID_RANGE_DEGRADED}


def first_runnable(ranked, w, spec):
    for i, c in enumerate(ranked):
        if ground_truth_memory(w, c.conf, spec) <= spec.mem_floor:
            return c, i + 1
    return None, len(ranked)


def degraded_host_demo(base_w, spec, bw_meas, bw_true, *, seed=0):
    """Where per-GPU compute awareness pays on a degraded fleet.

    A deep pipeline over a layer count ``pp`` does not divide leaves
    *light* stages (fewer layers) beside heavy ones — the one place a
    throttled host can serve without pacing the whole pipeline.  The demo
    dedicates a pp=16 configuration of a 24-layer variant two ways —
    node-major default (tier-blind) vs compute-aware placement (slow hosts
    onto the light stages, then SA polish) — and plays both back in the
    simulator at true per-rank speed.
    """
    import dataclasses

    import numpy as np

    from repro.core import compute_slowdowns
    from repro.core.simulator import Conf

    cfg24 = dataclasses.replace(base_w.cfg, name=base_w.cfg.name + "-24L",
                                n_layers=24)
    w = Workload(cfg24, base_w.seq, 32)
    conf = Conf(16, 8, 1, 2, 32)          # 8 heavy + 8 light (1-layer) stages
    prof = build_profile(w, spec, conf)
    slow = compute_slowdowns(spec)
    # compute-aware placement: fastest GPUs serve the heavy leading stages,
    # throttled hosts sink to the light trailing ones; SA polishes comm
    greedy = np.argsort(slow, kind="stable")
    aware = anneal_multistart(conf, bw_meas, prof, spec, n_chains=2,
                              time_limit_s=10.0, max_iters=10_000,
                              seed=seed, init_perm=greedy)
    sim_aware = measure(conf, aware.mapping, w, spec, bw_true, seed=1)
    from repro.core import default_mapping
    sim_blind = measure(conf, default_mapping(conf), w, spec, bw_true,
                        seed=1)
    deg = [i for i, t in enumerate(spec.node_tiers) if t == 1]
    print(f"\n[degraded] throttled nodes (half speed): {deg}")
    print(f"[degraded] dedication of {conf} ({cfg24.n_layers} layers -> "
          f"8 heavy + 8 light stages), simulated:")
    print(f"  tier-blind node-major {sim_blind * 1e3:9.1f} ms/iter")
    print(f"  compute-aware + SA    {sim_aware * 1e3:9.1f} ms/iter "
          f"({(1 - sim_aware / sim_blind) * 100:+.1f}%)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster", choices=sorted(CLUSTERS),
                    default="mid-range")
    ap.add_argument("--sa-seconds", type=float, default=1.0)
    ap.add_argument("--save-plan", default=None, metavar="PATH",
                    help="write the PPT-LF Plan JSON artifact here")
    args = ap.parse_args()

    spec = CLUSTERS[args.cluster]
    model = GPT_11_1B if args.cluster == "high-end" else GPT_3_1B
    w = Workload(model, 2048, 256)
    print(f"cluster: {spec.name} ({spec.n_gpus} GPUs), model {model.name}")

    bw_true = true_bandwidth_matrix(spec)
    bw_meas, cost = profile_bandwidth(spec)
    print(f"[profile] bandwidth matrix measured "
          f"(~{cost:.0f}s on the real cluster)")

    t0 = time.time()
    est = fit_memory_estimator(
        [Workload(model, 2048, bsg) for bsg in (64, 128, 256, 512)], spec,
        fit_nodes=4, steps=12_000, residual=True)
    print(f"[memest] MLP fitted on <=4-node profiles in {time.time()-t0:.0f}s")

    # one declarative request, five strategies behind one interface
    req = PlanRequest(
        workload=w, spec=spec, space=SearchSpace(),
        budget=Budget(sa_seconds=args.sa_seconds, sa_iters=20_000),
        seed=1)
    strategies = [
        # the Megatron heuristic's trial runs execute on the real cluster
        # (the ground-truth matrix), not the profiled snapshot
        ("Megatron-LM (tp=8 heuristic)", MegatronStrategy(bw_true=bw_true)),
        ("Varuna (pp-only)", VarunaStrategy()),
        ("AMP", AMPStrategy()),
        ("Pipette PPT-L", ExhaustiveStrategy(estimator=est,
                                             mem_limit=spec.mem_floor)),
        ("Pipette PPT-LF", PipetteStrategy(estimator=est,
                                           mem_limit=spec.mem_floor)),
    ]

    rows, ppt_plan, ppt_best, sa_time = [], None, None, 0.0
    for label, strategy in strategies:
        t0 = time.time()
        plan = Planner(strategy).plan(req, bw_meas)
        elapsed = time.time() - t0
        # memory-unaware baselines: a human walks the ranking until one
        # actually fits — count those trial runs against them
        best, trials = first_runnable(plan.result.ranked, w, spec)
        if trials > 1:
            label = f"{label} (runnable after {trials} trials)"
        t_iter = measure(best.conf, best.mapping, w, spec, bw_true)
        rows.append((label, best.conf, t_iter))
        if strategy.name == "pipette":
            ppt_plan, ppt_best, sa_time = plan, best, elapsed

    base = next(t for name, _, t in rows if name.startswith("AMP"))
    print(f"\n{'method':38s} {'config':28s} {'iter ms':>9s} {'vs AMP':>7s}")
    for name, conf, t in rows:
        print(f"{name:38s} {str(conf):28s} {t*1e3:9.1f} {base/t:7.2f}x")
    print(f"\n[pipette] total search time {sa_time:.0f}s "
          f"(SA dedication per candidate config)")
    # ppt_best is the candidate the table row measured (== plan.conf unless
    # the estimator under-predicted and first_runnable stepped down the
    # ranking) — print the dedication of what we reported, not blindly
    # ranked[0]
    print(f"[pipette] worker dedication for {ppt_best.conf} "
          "(GPU ids, stages x (tp*dp)):")
    print(ppt_best.mapping.reshape(ppt_best.conf.pp, -1))
    if args.save_plan:
        if ppt_best.conf != ppt_plan.conf:
            # index into the full ranking first_runnable searched, not the
            # top-k the artifact keeps (the fallback may sit below rank 10)
            rank = [c.conf for c in ppt_plan.result.ranked] \
                .index(ppt_best.conf)
            print(f"[pipette] note: artifact best {ppt_plan.conf} was not "
                  f"runnable; the measured row used fallback ranked[{rank}]")
        print(f"[pipette] plan artifact -> {ppt_plan.save(args.save_plan)}")

    if spec.has_tiers:
        degraded_host_demo(w, spec, bw_meas, bw_true)


if __name__ == "__main__":
    main()
