"""The paper's headline experiment at full scale: configure GPT-3.1B
training on the simulated 128-GPU mid-range cluster and compare Pipette
(PPT-L / PPT-LF) against Megatron-LM, Varuna and AMP (Fig. 6).

    PYTHONPATH=src python examples/configure_cluster.py [--cluster high-end]
"""
import argparse
import time

from repro.core import (HIGH_END, MID_RANGE, Workload, amp_configure,
                        configure, fit_memory_estimator,
                        ground_truth_memory, measure, mlm_configure,
                        profile_bandwidth, true_bandwidth_matrix,
                        varuna_configure)
from repro.configs.gpt_paper import GPT_3_1B, GPT_11_1B


def first_runnable(ranked, w, spec):
    for i, c in enumerate(ranked):
        if ground_truth_memory(w, c.conf, spec) <= spec.gpu_mem:
            return c, i + 1
    return None, len(ranked)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster", choices=["mid-range", "high-end"],
                    default="mid-range")
    ap.add_argument("--sa-seconds", type=float, default=1.0)
    args = ap.parse_args()

    spec = MID_RANGE if args.cluster == "mid-range" else HIGH_END
    model = GPT_3_1B if args.cluster == "mid-range" else GPT_11_1B
    w = Workload(model, 2048, 256)
    print(f"cluster: {spec.name} ({spec.n_gpus} GPUs), model {model.name}")

    bw_true = true_bandwidth_matrix(spec)
    bw_meas, cost = profile_bandwidth(spec)
    print(f"[profile] bandwidth matrix measured "
          f"(~{cost:.0f}s on the real cluster)")

    t0 = time.time()
    est = fit_memory_estimator(
        [Workload(model, 2048, bsg) for bsg in (64, 128, 256, 512)], spec,
        fit_nodes=4, steps=12_000, residual=True)
    print(f"[memest] MLP fitted on <=4-node profiles in {time.time()-t0:.0f}s")

    rows = []
    mlm = mlm_configure(w, spec, bw_true)
    rows.append(("Megatron-LM (tp=8 heuristic)", mlm.best.conf,
                 mlm.best.latency))
    vr, _ = first_runnable(varuna_configure(w, spec).ranked, w, spec)
    rows.append(("Varuna (pp-only)", vr.conf,
                 measure(vr.conf, vr.mapping, w, spec, bw_true)))
    amp, trials = first_runnable(amp_configure(w, spec).ranked, w, spec)
    rows.append((f"AMP (runnable after {trials} trials)", amp.conf,
                 measure(amp.conf, amp.mapping, w, spec, bw_true)))
    pl = configure(w, spec, bw_meas, estimator=est, mem_limit=spec.gpu_mem,
                   dedicate=False)
    rows.append(("Pipette PPT-L", pl.best.conf,
                 measure(pl.best.conf, pl.best.mapping, w, spec, bw_true)))
    t0 = time.time()
    plf = configure(w, spec, bw_meas, estimator=est, mem_limit=spec.gpu_mem,
                    sa_seconds=args.sa_seconds, sa_iters=20_000, seed=1)
    sa_time = time.time() - t0
    rows.append(("Pipette PPT-LF", plf.best.conf,
                 measure(plf.best.conf, plf.best.mapping, w, spec, bw_true)))

    base = rows[2][2]   # AMP
    print(f"\n{'method':38s} {'config':28s} {'iter ms':>9s} {'vs AMP':>7s}")
    for name, conf, t in rows:
        print(f"{name:38s} {str(conf):28s} {t*1e3:9.1f} {base/t:7.2f}x")
    print(f"\n[pipette] total search time {sa_time:.0f}s "
          f"(SA dedication per candidate config)")
    print("[pipette] worker dedication for the best config "
          "(GPU ids, stages x (tp*dp)):")
    print(plf.best.mapping.reshape(plf.best.conf.pp, -1))


if __name__ == "__main__":
    main()
