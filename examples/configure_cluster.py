"""The paper's headline experiment at full scale: configure GPT-3.1B
training on the simulated 128-GPU mid-range cluster and compare Pipette
(PPT-L / PPT-LF) against Megatron-LM, Varuna and AMP (Fig. 6) — all five
configurators running behind the single Planner API, as one loop over
strategies instead of four bespoke call sites.

    PYTHONPATH=src python examples/configure_cluster.py [--cluster high-end]
"""
import argparse
import time

from repro.core import (HIGH_END, MID_RANGE, AMPStrategy, Budget,
                        ExhaustiveStrategy, MegatronStrategy, Planner,
                        PlanRequest, PipetteStrategy, SearchSpace,
                        VarunaStrategy, Workload, fit_memory_estimator,
                        ground_truth_memory, measure, profile_bandwidth,
                        true_bandwidth_matrix)
from repro.configs.gpt_paper import GPT_3_1B, GPT_11_1B


def first_runnable(ranked, w, spec):
    for i, c in enumerate(ranked):
        if ground_truth_memory(w, c.conf, spec) <= spec.gpu_mem:
            return c, i + 1
    return None, len(ranked)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster", choices=["mid-range", "high-end"],
                    default="mid-range")
    ap.add_argument("--sa-seconds", type=float, default=1.0)
    ap.add_argument("--save-plan", default=None, metavar="PATH",
                    help="write the PPT-LF Plan JSON artifact here")
    args = ap.parse_args()

    spec = MID_RANGE if args.cluster == "mid-range" else HIGH_END
    model = GPT_3_1B if args.cluster == "mid-range" else GPT_11_1B
    w = Workload(model, 2048, 256)
    print(f"cluster: {spec.name} ({spec.n_gpus} GPUs), model {model.name}")

    bw_true = true_bandwidth_matrix(spec)
    bw_meas, cost = profile_bandwidth(spec)
    print(f"[profile] bandwidth matrix measured "
          f"(~{cost:.0f}s on the real cluster)")

    t0 = time.time()
    est = fit_memory_estimator(
        [Workload(model, 2048, bsg) for bsg in (64, 128, 256, 512)], spec,
        fit_nodes=4, steps=12_000, residual=True)
    print(f"[memest] MLP fitted on <=4-node profiles in {time.time()-t0:.0f}s")

    # one declarative request, five strategies behind one interface
    req = PlanRequest(
        workload=w, spec=spec, space=SearchSpace(),
        budget=Budget(sa_seconds=args.sa_seconds, sa_iters=20_000),
        seed=1)
    strategies = [
        # the Megatron heuristic's trial runs execute on the real cluster
        # (the ground-truth matrix), not the profiled snapshot
        ("Megatron-LM (tp=8 heuristic)", MegatronStrategy(bw_true=bw_true)),
        ("Varuna (pp-only)", VarunaStrategy()),
        ("AMP", AMPStrategy()),
        ("Pipette PPT-L", ExhaustiveStrategy(estimator=est,
                                             mem_limit=spec.gpu_mem)),
        ("Pipette PPT-LF", PipetteStrategy(estimator=est,
                                           mem_limit=spec.gpu_mem)),
    ]

    rows, ppt_plan, ppt_best, sa_time = [], None, None, 0.0
    for label, strategy in strategies:
        t0 = time.time()
        plan = Planner(strategy).plan(req, bw_meas)
        elapsed = time.time() - t0
        # memory-unaware baselines: a human walks the ranking until one
        # actually fits — count those trial runs against them
        best, trials = first_runnable(plan.result.ranked, w, spec)
        if trials > 1:
            label = f"{label} (runnable after {trials} trials)"
        t_iter = measure(best.conf, best.mapping, w, spec, bw_true)
        rows.append((label, best.conf, t_iter))
        if strategy.name == "pipette":
            ppt_plan, ppt_best, sa_time = plan, best, elapsed

    base = next(t for name, _, t in rows if name.startswith("AMP"))
    print(f"\n{'method':38s} {'config':28s} {'iter ms':>9s} {'vs AMP':>7s}")
    for name, conf, t in rows:
        print(f"{name:38s} {str(conf):28s} {t*1e3:9.1f} {base/t:7.2f}x")
    print(f"\n[pipette] total search time {sa_time:.0f}s "
          f"(SA dedication per candidate config)")
    # ppt_best is the candidate the table row measured (== plan.conf unless
    # the estimator under-predicted and first_runnable stepped down the
    # ranking) — print the dedication of what we reported, not blindly
    # ranked[0]
    print(f"[pipette] worker dedication for {ppt_best.conf} "
          "(GPU ids, stages x (tp*dp)):")
    print(ppt_best.mapping.reshape(ppt_best.conf.pp, -1))
    if args.save_plan:
        if ppt_best.conf != ppt_plan.conf:
            # index into the full ranking first_runnable searched, not the
            # top-k the artifact keeps (the fallback may sit below rank 10)
            rank = [c.conf for c in ppt_plan.result.ranked] \
                .index(ppt_best.conf)
            print(f"[pipette] note: artifact best {ppt_plan.conf} was not "
                  f"runnable; the measured row used fallback ranked[{rank}]")
        print(f"[pipette] plan artifact -> {ppt_plan.save(args.save_plan)}")


if __name__ == "__main__":
    main()
