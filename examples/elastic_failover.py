"""Fault-tolerance showcase: train, lose a node, let Pipette re-plan for
the degraded cluster, reshard the checkpoint, and keep training.

    PYTHONPATH=src python examples/elastic_failover.py
"""
import jax

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.core import MID_RANGE, Workload
from repro.data.pipeline import DataLoader, LoaderConfig, SyntheticCorpus
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.models.sharding import ShardCtx
from repro.optim.adamw import AdamW
from repro.runtime.elastic import replan


def main():
    cfg = configs.get("qwen2-7b").reduced()
    ctx = ShardCtx()
    opt = AdamW(lr=1e-3, weight_decay=0.0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    loader = DataLoader(SyntheticCorpus(cfg.vocab_size, 0, noise=0.02),
                        LoaderConfig(8, 64))
    mgr = CheckpointManager("checkpoints/elastic", keep=2, async_save=False)

    w = Workload(cfg, 64, 64)
    plan = replan(w, MID_RANGE, healthy_nodes=4, sa_seconds=0.2)
    print(f"[plan] 4 nodes healthy: {plan.result.best.conf} "
          f"est {plan.result.best.latency*1e3:.1f} ms/iter")

    step = jax.jit(make_train_step(cfg, ctx, opt,
                                   n_micro=min(4, plan.result.best.conf.n_mb)))
    for s in range(20):
        params, state, m = step(params, state, loader.batch_at(s))
    mgr.save(20, (params, state))
    print(f"[train] 20 steps done, loss {float(m['loss']):.3f}; "
          f"checkpoint saved")

    # --- node failure: only 3 nodes healthy now -------------------------
    print("[fault] node lost! re-planning for 3 nodes...")
    plan2 = replan(w, MID_RANGE, healthy_nodes=3, sa_seconds=0.2)
    best = plan2.result.best
    print(f"[plan] degraded cluster: {best.conf} "
          f"est {best.latency*1e3:.1f} ms/iter "
          f"(mapping over {best.conf.n_gpus} GPUs)")
    # the re-plan is a serializable artifact: persist it with the ckpt so
    # the restarted job knows exactly what it is running
    print(f"[plan] artifact -> "
          f"{plan2.plan.save('checkpoints/elastic/plan.json')}")

    # restore + reshard (same host here; on a pod the shardings change)
    (params, state), at = mgr.restore((params, state))
    step2 = jax.jit(make_train_step(cfg, ctx, opt,
                                    n_micro=min(4, best.conf.n_mb)))
    for s in range(at, at + 10):
        params, state, m = step2(params, state, loader.batch_at(s))
    print(f"[train] resumed at step {at}, continued to {at+10}, "
          f"loss {float(m['loss']):.3f} — elastic failover complete")


if __name__ == "__main__":
    main()
