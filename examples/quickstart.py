"""Quickstart: configure -> train -> generate in one minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import (MID_RANGE, Budget, Planner, PlanRequest,
                        PipetteStrategy, Workload, profile_bandwidth)
from repro.data.pipeline import DataLoader, LoaderConfig, SyntheticCorpus
from repro.launch.steps import make_decode_step, make_train_step
from repro.models import model as M
from repro.models.sharding import ShardCtx
from repro.optim.adamw import AdamW


def main():
    # 1) Pipette: pick (pp, tp, dp, bs_micro) + worker mapping for a
    #    simulated 4-node cluster — one declarative PlanRequest through
    #    the Planner; the Plan artifact is JSON-serializable
    #    (`python -m repro.plan` builds the same thing from the CLI).
    cfg = configs.get("qwen2-7b").reduced()
    spec = MID_RANGE.with_nodes(4)
    w = Workload(cfg, seq=128, bs_global=64)
    bw, cost_s = profile_bandwidth(spec)
    req = PlanRequest(workload=w, spec=spec,
                      budget=Budget(sa_seconds=0.2, sa_iters=2000))
    plan = Planner(PipetteStrategy()).plan(req, bw)
    res = plan.result
    print(f"[pipette] profiled {spec.n_gpus} GPUs (~{cost_s:.0f}s on a real "
          f"cluster); best: {plan.conf} "
          f"est {plan.latency*1e3:.1f} ms/iter "
          f"(strategy {plan.provenance.strategy})")

    # 2) Train the reduced arch on the synthetic corpus, microbatched by
    #    Pipette's bs_micro.
    ctx = ShardCtx()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=2e-3, weight_decay=0.0)
    state = opt.init(params)
    n_micro = max(1, min(4, res.best.conf.n_mb))
    step = jax.jit(make_train_step(cfg, ctx, opt, n_micro=n_micro),
                   donate_argnums=(0, 1))
    loader = DataLoader(SyntheticCorpus(cfg.vocab_size, seed=0, noise=0.02),
                        LoaderConfig(8, 64))
    for s in range(40):
        params, state, m = step(params, state, loader.batch_at(s))
        if s % 10 == 0:
            print(f"[train] step {s:3d} loss {float(m['loss']):.3f}")

    # 3) Serve: prefill + a few greedy decode steps with a donated cache.
    toks = loader.batch_at(100)["tokens"][:2, :32]
    last, cache = M.prefill(params, cfg, ctx, jnp.asarray(toks))
    cache = {k: (jnp.pad(v, [(0, 0), (0, 0), (0, 8)] + [(0, 0)] * (v.ndim - 3))
                 if k in ("k", "v") else v) for k, v in cache.items()}
    decode = jax.jit(make_decode_step(cfg, ctx), donate_argnums=(1,))
    tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    for i in range(5):
        tok, _, cache = decode(params, cache, tok, jnp.int32(32 + i))
        out.append(int(tok[0, 0]))
    print("[generate] greedy continuation:", out)


if __name__ == "__main__":
    main()
