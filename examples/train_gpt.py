"""End-to-end driver (deliverable b): train a GPT on the synthetic corpus
for a few hundred steps with checkpointing and fault recovery.

Default is a ~20M-param GPT (CPU-friendly); ``--full`` trains ~110M
params as in the assignment's "train ~100M model" scenario (slower).

    PYTHONPATH=src python examples/train_gpt.py --steps 200
    PYTHONPATH=src python examples/train_gpt.py --steps 200 --fail-at 120
    # ^ crashes at step 120; run again with --resume to continue bitwise
"""
import argparse

from repro.launch import train as train_cli
from repro.models.config import ModelConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="~110M params instead of ~20M")
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    import repro.configs as configs
    size = dict(n_layers=6, d_model=384, n_heads=6) if not args.full else \
        dict(n_layers=12, d_model=768, n_heads=12)
    gpt = ModelConfig(name="gpt-demo", family="dense",
                      n_kv_heads=size["n_heads"], d_ff=4 * size["d_model"],
                      vocab_size=4096, dtype="float32", remat=False,
                      **size)
    configs.PAPER_GPTS[gpt.name] = gpt      # register for the CLI

    argv = ["--arch", "gpt-demo", "--steps", str(args.steps),
            "--global-batch", "8", "--seq-len", "256", "--n-micro", "2",
            "--ckpt-dir", "checkpoints/gpt-demo", "--ckpt-every", "40",
            "--configure", "--metrics", "checkpoints/gpt-demo-metrics.jsonl"]
    if args.fail_at is not None:
        argv += ["--fail-at", str(args.fail_at)]
    if args.resume:
        argv += ["--resume"]
    raise SystemExit(train_cli.main(argv))


if __name__ == "__main__":
    main()
