"""Static analysis for the reproduction: a determinism linter + plan verifier.

The repo's correctness story rests on bit-exactness — golden Plan
fixtures, hex-float regression suites, chain-for-chain NumPy/JAX parity —
but those suites only catch a determinism break *after* it lands.  This
package enforces the invariants that make bit-exactness possible, before
any search runs:

1. the **determinism linter** (``python -m repro.analysis``): an AST-based
   checker with a rule registry (:mod:`~repro.analysis.rules` — unseeded
   RNG, wall-clock reads, order-dependent float accumulation, float
   equality, unordered-container iteration, host effects inside jitted
   functions), per-rule configuration in ``pyproject.toml``
   (``[tool.repro.analysis]``) and *reasoned* inline suppressions
   (``# repro: noqa DET002 -- why this one is safe``);
2. the **static plan verifier** (:mod:`~repro.analysis.plan_verifier`,
   surfaced as ``python -m repro.plan lint``): checks a serialized
   :class:`~repro.core.plan.Plan` against a
   :class:`~repro.core.cluster.ClusterSpec` without re-running the search
   — Pipette's critique of prior configurators is that they recommend
   plans that cannot execute, and a cached or hand-edited artifact can
   drift into exactly that state.
"""
from .config import AnalysisConfig, load_config
from .diagnostics import Diagnostic, render_json, render_text
from .linter import lint_file, lint_paths
from .plan_verifier import PlanIssue, verify_plan_dict, verify_plan_file
from .rules import RULES, Rule

__all__ = [
    "AnalysisConfig", "Diagnostic", "PlanIssue", "RULES", "Rule",
    "lint_file", "lint_paths", "load_config", "render_json", "render_text",
    "verify_plan_dict", "verify_plan_file",
]
