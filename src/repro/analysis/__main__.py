import os
import sys

from .cli import main

try:
    rc = main()
except BrokenPipeError:
    # downstream pager/head closed the pipe; point stdout at devnull so
    # interpreter shutdown doesn't print a second traceback
    os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
    rc = 0
raise SystemExit(rc)
