"""The determinism-linter CLI: ``python -m repro.analysis [paths...]``.

    # lint the library (CI gate: exit 1 on any unsuppressed finding)
    python -m repro.analysis src/

    # machine-readable audit trail, suppressed findings included
    python -m repro.analysis src/ --format json

    # one rule only, against an explicit config
    python -m repro.analysis src/ --select DET002 --config pyproject.toml

Exit codes: 0 clean, 1 unsuppressed findings, 2 usage error.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

from .config import find_pyproject, load_config
from .diagnostics import render_json, render_text
from .linter import lint_paths
from .rules import RULES


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Determinism linter: enforce the invariants behind "
                    "the repo's bit-exactness guarantees.")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files and/or directories to lint")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--select", action="append", metavar="RULE",
                    help="only run these rule ids (repeatable)")
    ap.add_argument("--ignore", action="append", metavar="RULE",
                    help="skip these rule ids (repeatable)")
    ap.add_argument("--config", type=Path, default=None,
                    help="explicit pyproject.toml (default: nearest one "
                         "above the first path)")
    ap.add_argument("--no-config", action="store_true",
                    help="built-in defaults only; ignore pyproject.toml")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="include suppressed findings in text output "
                         "(JSON always includes them)")
    ap.add_argument("--relative-to", type=Path, default=None,
                    help="report paths relative to this root")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = _build_parser().parse_args(argv)
    if args.list_rules:
        for r in RULES.values():
            print(f"{r.id}  {r.name:26s} {r.summary}")
        return 0
    if not args.paths:
        print("error: no paths given (or use --list-rules)",
              file=sys.stderr)
        return 2
    for p in args.paths:
        if not p.exists():
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2

    if args.no_config:
        cfg_path = None
    elif args.config is not None:
        if not args.config.is_file():
            print(f"error: config not found: {args.config}",
                  file=sys.stderr)
            return 2
        cfg_path = args.config
    else:
        cfg_path = find_pyproject(args.paths[0])
    config = load_config(cfg_path)

    unknown = [r for r in (args.select or []) + (args.ignore or [])
               if r not in RULES]
    if unknown:
        print(f"error: unknown rule id(s): {', '.join(unknown)} "
              f"(see --list-rules)", file=sys.stderr)
        return 2
    disable = set(config.disable) | set(args.ignore or [])
    if args.select:
        disable |= set(RULES) - set(args.select)
    if disable != set(config.disable):
        import dataclasses
        config = dataclasses.replace(config, disable=frozenset(disable))

    diags = lint_paths(args.paths, config,
                       relative_to=args.relative_to)
    open_diags = [d for d in diags if not d.suppressed]
    if args.format == "json":
        sys.stdout.write(render_json(diags))
    else:
        for line in render_text(diags,
                                show_suppressed=args.show_suppressed):
            print(line)
        n_sup = sum(1 for d in diags if d.suppressed)
        print(f"[repro.analysis] {len(open_diags)} finding(s), "
              f"{n_sup} suppressed with reasons "
              f"(config: {config.source})", file=sys.stderr)
    return 1 if open_diags else 0


if __name__ == "__main__":                         # pragma: no cover
    raise SystemExit(main())
