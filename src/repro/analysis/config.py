"""Per-rule linter configuration from ``pyproject.toml``.

Read from ``[tool.repro.analysis]``:

.. code-block:: toml

    [tool.repro.analysis]
    disable = ["DET006"]               # rule ids switched off entirely
    exclude = ["**/generated/*.py"]    # files the linter skips
    # DET003 (pairwise-summation) only applies to these scoring modules —
    # everywhere else ndarray sums are ordinary numerics, not something a
    # JAX replica must replay association-order-exactly.
    det003-paths = ["**/core/latency.py"]
    # DET002 wall-clock tuning: extend or shrink the banned set.
    wall-clock-ban = ["arrow.utcnow"]
    wall-clock-allow = ["time.localtime"]

TOML parsing uses :mod:`tomllib` (3.11+) with a ``tomli`` fallback for
3.10; with neither available, explicit ``--config`` fails loudly while
``--no-config`` / built-in defaults keep the linter usable.
"""
from __future__ import annotations

import fnmatch
from dataclasses import dataclass
from pathlib import Path
from typing import FrozenSet, Optional, Tuple

#: Wall-clock reads banned by DET002.  Monotonic timers
#: (``perf_counter`` / ``monotonic`` / ``process_time``) are deliberately
#: absent: they are the *allowlisted overhead timers* — meaningless across
#: processes, so nothing bit-reproducible can be derived from them.
DEFAULT_WALL_CLOCK_BAN = frozenset({
    "time.time", "time.time_ns", "time.ctime", "time.asctime",
    "time.localtime", "time.gmtime", "time.strftime",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
})


@dataclass(frozen=True)
class AnalysisConfig:
    """Resolved linter configuration (defaults when no file is found)."""
    disable: FrozenSet[str] = frozenset()
    exclude: Tuple[str, ...] = ()
    det003_paths: Tuple[str, ...] = ()
    wall_clock_ban: FrozenSet[str] = DEFAULT_WALL_CLOCK_BAN
    source: str = "<defaults>"

    def rule_enabled(self, rule_id: str) -> bool:
        return rule_id not in self.disable

    def is_excluded(self, path: str) -> bool:
        return _any_glob(path, self.exclude)

    def det003_applies(self, path: str) -> bool:
        """DET003 is scoped: active only for configured scoring modules."""
        return _any_glob(path, self.det003_paths)


def _any_glob(path: str, globs: Tuple[str, ...]) -> bool:
    norm = Path(path).as_posix()
    return any(fnmatch.fnmatch(norm, g) or fnmatch.fnmatch(Path(norm).name, g)
               for g in globs)


def _load_toml(path: Path) -> dict:
    try:
        import tomllib
    except ImportError:                                   # Python 3.10
        try:
            import tomli as tomllib
        except ImportError as e:
            raise RuntimeError(
                f"cannot read {path}: no TOML parser available "
                f"(need Python >= 3.11 or the tomli package); "
                f"run with --no-config to use built-in defaults") from e
    with open(path, "rb") as f:
        return tomllib.load(f)


def find_pyproject(start: Path) -> Optional[Path]:
    """Walk up from ``start`` to the nearest ``pyproject.toml``."""
    cur = start.resolve()
    if cur.is_file():
        cur = cur.parent
    for d in (cur, *cur.parents):
        cand = d / "pyproject.toml"
        if cand.is_file():
            return cand
    return None


def load_config(path: Optional[Path]) -> AnalysisConfig:
    """Load ``[tool.repro.analysis]`` from ``path`` (defaults if None or
    the table is absent)."""
    if path is None:
        return AnalysisConfig()
    data = _load_toml(Path(path))
    table = data.get("tool", {}).get("repro", {}).get("analysis", {})
    ban = set(DEFAULT_WALL_CLOCK_BAN)
    ban |= set(table.get("wall-clock-ban", ()))
    ban -= set(table.get("wall-clock-allow", ()))
    return AnalysisConfig(
        disable=frozenset(table.get("disable", ())),
        exclude=tuple(table.get("exclude", ())),
        det003_paths=tuple(table.get("det003-paths", ())),
        wall_clock_ban=frozenset(ban),
        source=str(path))
