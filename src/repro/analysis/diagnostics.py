"""Diagnostic records and the two output renderers (text / JSON).

A :class:`Diagnostic` is one finding of one rule at one source location.
Suppressed findings are *kept* (with ``suppressed=True`` and the
suppression's reason) rather than dropped: the JSON output is a complete
audit trail — every exception to a determinism invariant is visible next
to its justification, which is what the golden-diagnostics test pins.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, replace
from typing import List, Sequence


@dataclass(frozen=True, order=True)
class Diagnostic:
    """One rule finding at one source location.

    Attributes:
        path: file the finding is in (as passed to the linter; the CLI
            normalizes to ``/``-separated relative paths for stable output).
        line / col: 1-based line and 0-based column of the offending node.
        rule: rule id (``DET001`` ... ``SUP002``).
        message: human-readable description with the resolved symbol.
        end_line: last physical line of the offending statement —
            suppression comments anywhere in ``[line, end_line]`` apply.
        suppressed: True when a valid reasoned ``# repro: noqa`` matched.
        reason: the suppression's stated reason (empty when unsuppressed).
    """
    path: str
    line: int
    col: int
    rule: str
    message: str
    end_line: int = 0
    suppressed: bool = field(default=False, compare=False)
    reason: str = field(default="", compare=False)

    def suppress(self, reason: str) -> "Diagnostic":
        return replace(self, suppressed=True, reason=reason)


def render_text(diags: Sequence[Diagnostic], *,
                show_suppressed: bool = False) -> List[str]:
    """flake8-style one-line-per-finding text output, sorted by location."""
    lines = []
    for d in sorted(diags):
        if d.suppressed and not show_suppressed:
            continue
        tag = f" [suppressed: {d.reason}]" if d.suppressed else ""
        lines.append(f"{d.path}:{d.line}:{d.col + 1}: {d.rule} "
                     f"{d.message}{tag}")
    return lines


def render_json(diags: Sequence[Diagnostic]) -> str:
    """Canonical JSON: sorted findings, sorted keys, trailing newline —
    byte-stable for identical findings (the golden-diagnostics fixture
    relies on this)."""
    out = [{"path": d.path, "line": d.line, "col": d.col, "rule": d.rule,
            "message": d.message, "suppressed": d.suppressed,
            "reason": d.reason}
           for d in sorted(diags)]
    return json.dumps(out, sort_keys=True, indent=2) + "\n"
