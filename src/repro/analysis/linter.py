"""Linter driver: walk files, parse, run rules, apply suppressions."""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List, Optional

from .config import AnalysisConfig
from .diagnostics import Diagnostic
from .rules import DeterminismVisitor
from .suppress import apply_suppressions, scan_suppressions


def lint_source(source: str, path: str,
                config: Optional[AnalysisConfig] = None) -> List[Diagnostic]:
    """Lint one module given as text (the unit the tests drive)."""
    config = config or AnalysisConfig()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Diagnostic(path=path, line=e.lineno or 1,
                           col=(e.offset or 1) - 1, rule="SYN001",
                           message=f"file does not parse: {e.msg}",
                           end_line=e.lineno or 1)]
    diags = DeterminismVisitor(path, config).run(tree)
    supps, malformed = scan_suppressions(source, path)
    diags = apply_suppressions(diags, supps, path)
    return diags + malformed


def lint_file(path: Path,
              config: Optional[AnalysisConfig] = None,
              display_path: Optional[str] = None) -> List[Diagnostic]:
    """Lint one file; ``display_path`` overrides the path recorded on
    diagnostics (the CLI passes a normalized relative path)."""
    text = Path(path).read_text(encoding="utf-8")
    return lint_source(text, display_path or str(path), config)


def iter_python_files(paths: Iterable[Path]) -> List[Path]:
    """Expand files/directories into a sorted list of ``.py`` files."""
    out = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        else:
            out.append(p)
    return out


def lint_paths(paths: Iterable[Path],
               config: Optional[AnalysisConfig] = None,
               relative_to: Optional[Path] = None) -> List[Diagnostic]:
    """Lint every ``.py`` file under ``paths`` (recursing directories).

    Args:
        paths: files and/or directories.
        config: resolved :class:`AnalysisConfig` (defaults when None).
        relative_to: when given, diagnostics carry ``/``-separated paths
            relative to this root — stable output for golden fixtures.
    """
    config = config or AnalysisConfig()
    diags: List[Diagnostic] = []
    for f in iter_python_files(paths):
        display = f.as_posix()
        if relative_to is not None:
            try:
                display = f.resolve().relative_to(
                    Path(relative_to).resolve()).as_posix()
            except ValueError:
                pass
        if config.is_excluded(display):
            continue
        diags.extend(lint_file(f, config, display_path=display))
    return sorted(diags)
