"""Static Plan verifier: check a serialized Plan without re-searching.

Pipette's core critique of prior configurators is that they "recommend
solutions that could not be executed"; a *cached* or hand-edited Plan
artifact can drift into exactly that state (the cluster re-tiered, the
schema evolved, a mapping corrupted in transit).  This module re-checks
the executability invariants of a Plan JSON against a
:class:`~repro.core.cluster.ClusterSpec` in milliseconds — the gate a
plan-server must run before serving a cached plan.

Surfaced as ``python -m repro.plan lint``.  Verifier rule ids:

=======  ===========================================================
PLN000   artifact malformed (missing/ill-typed required fields)
PLN001   unknown plan schema version
PLN002   conf arithmetic: pp*tp*cp*dp must equal n_gpus, batch
         divisibility must hold (Conf.valid)
PLN003   unschedulable: 1F1B needs n_mb >= pp (Conf.schedulable)
PLN004   mapping: shape must match (pp, tp[, cp], dp), dtype must be
         integral, and the data must be a permutation of range(G)
PLN005   memory: predicted peak bytes must fit under the cluster's
         mem_floor (tightest device tier)
PLN006   bandwidth digest: malformed, or mismatching a provided
         profiled matrix
PLN007   tier provenance: recorded digest must match the recorded
         table (and the spec's live fingerprint when a spec is given)
PLN008   cluster mismatch: plan's n_gpus / cluster name vs the spec
         it is being checked against
PLN009   partition/schedule: schedule name must be known, consistent
         with the conf's vpp; a recorded partition must carry strictly
         increasing boundaries covering exactly n_layers with
         pp*vpp stage chunks
=======  ===========================================================

All checks run on the *raw JSON dict* — a plan that fails
``Plan.load`` (e.g. unknown schema) still gets a diagnosis instead of a
traceback.  Severities: ``error`` findings gate (CLI exit 1);
``warning`` is suspicious but runnable; ``note`` records skipped checks
so "passed" is never silently "didn't look".
"""
from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass
from pathlib import Path
from typing import List, Optional

_HEX64 = re.compile(r"^[0-9a-f]{64}$")


@dataclass(frozen=True)
class PlanIssue:
    """One verifier finding.

    Attributes:
        rule: ``PLN000`` ... ``PLN009``.
        severity: ``error`` (gates), ``warning``, or ``note``.
        where: which artifact part ("best", "ranked[3]", "provenance").
        message: human-readable description.
    """
    rule: str
    severity: str
    where: str
    message: str

    def __str__(self):
        return f"{self.severity.upper():7s} {self.rule} [{self.where}] " \
               f"{self.message}"


def _err(rule, where, msg):
    return PlanIssue(rule, "error", where, msg)


def _warn(rule, where, msg):
    return PlanIssue(rule, "warning", where, msg)


def _note(rule, where, msg):
    return PlanIssue(rule, "note", where, msg)


def _check_conf(conf: dict, n_gpus: int, where: str) -> List[PlanIssue]:
    issues: List[PlanIssue] = []
    try:
        pp, tp, dp = int(conf["pp"]), int(conf["tp"]), int(conf["dp"])
        cp = int(conf.get("cp", 1))
        bs_micro = int(conf["bs_micro"])
        bs_global = int(conf["bs_global"])
    except (KeyError, TypeError, ValueError) as e:
        return [_err("PLN000", where, f"conf is malformed: {e!r}")]
    if min(pp, tp, cp, dp, bs_micro, bs_global) < 1:
        issues.append(_err("PLN002", where,
                           f"conf degrees must be >= 1, got (pp={pp}, "
                           f"tp={tp}, cp={cp}, dp={dp}, "
                           f"bs_micro={bs_micro}, bs_global={bs_global})"))
        return issues
    used = pp * tp * cp * dp
    if used != n_gpus:
        issues.append(_err("PLN002", where,
                           f"conf uses pp*tp*cp*dp = {used} GPUs but the "
                           f"cluster has {n_gpus} — this plan cannot be "
                           f"dedicated onto the fleet"))
    if bs_global % dp != 0:
        issues.append(_err("PLN002", where,
                           f"bs_global={bs_global} is not divisible by "
                           f"dp={dp}"))
        return issues
    bs_mini = bs_global // dp
    if bs_mini % bs_micro != 0:
        issues.append(_err("PLN002", where,
                           f"minibatch {bs_mini} is not divisible by "
                           f"bs_micro={bs_micro}"))
        return issues
    n_mb = bs_mini // bs_micro
    if n_mb < 1:
        issues.append(_err("PLN002", where,
                           f"n_mb = {n_mb}: microbatch larger than the "
                           f"minibatch"))
    elif n_mb < pp:
        issues.append(_err("PLN003", where,
                           f"unschedulable: 1F1B needs n_mb >= pp, got "
                           f"n_mb={n_mb} < pp={pp} (Eq. 3-6 would score "
                           f"a schedule that cannot exist)"))
    return issues


def _check_mapping(mapping: dict, conf: dict, n_gpus: int,
                   where: str) -> List[PlanIssue]:
    issues: List[PlanIssue] = []
    try:
        shape = [int(s) for s in mapping["shape"]]
        data = list(mapping["data"])
        dtype = str(mapping["dtype"])
        pp, tp, dp = int(conf["pp"]), int(conf["tp"]), int(conf["dp"])
        cp = int(conf.get("cp", 1))
    except (KeyError, TypeError, ValueError) as e:
        return [_err("PLN000", where, f"mapping is malformed: {e!r}")]
    if not dtype.startswith(("int", "uint")):
        issues.append(_err("PLN004", where,
                           f"mapping dtype must be integral (GPU ids), "
                           f"got {dtype!r}"))
    # stride/axis consistency: the mapping must factor exactly as the
    # conf's parallel degrees — 4D (pp, tp, cp, dp), or legacy 3D
    # (pp, tp, dp) only while cp == 1
    if shape not in ([pp, tp, cp, dp],
                     [pp, tp, dp] if cp == 1 else [pp, tp, cp, dp]):
        issues.append(_err("PLN004", where,
                           f"mapping shape {shape} is inconsistent with "
                           f"conf (pp={pp}, tp={tp}, cp={cp}, dp={dp}): "
                           f"expected {[pp, tp, cp, dp]}"
                           + (f" or legacy {[pp, tp, dp]}" if cp == 1
                              else "")))
    if math.prod(shape) != len(data):
        issues.append(_err("PLN004", where,
                           f"mapping carries {len(data)} entries but its "
                           f"shape {shape} implies {math.prod(shape)}"))
    if sorted(data) != list(range(n_gpus)):
        issues.append(_err("PLN004", where,
                           f"mapping is not a permutation of the {n_gpus} "
                           f"GPU ids: some GPU is either unused or "
                           f"dedicated to two workers"))
    return issues


def _check_partition(cand: dict, where: str) -> List[PlanIssue]:
    """PLN009: schedule name + vpp consistency + partition coverage."""
    from ..core.partition import SCHEDULES

    issues: List[PlanIssue] = []
    conf = cand.get("conf")
    if not isinstance(conf, dict):
        return []                       # already a PLN000 elsewhere
    try:
        pp = int(conf.get("pp", 0))
        vpp = int(conf.get("vpp", 1))
    except (TypeError, ValueError):
        return []                       # already a PLN000 elsewhere
    schedule = cand.get("schedule", "1f1b")
    if schedule not in SCHEDULES:
        issues.append(_err("PLN009", where,
                           f"unknown schedule {schedule!r}; this build "
                           f"knows {SCHEDULES}"))
        return issues
    expected = "interleaved-1f1b" if vpp > 1 else "1f1b"
    if schedule != expected:
        issues.append(_err("PLN009", where,
                           f"schedule {schedule!r} is inconsistent with "
                           f"vpp={vpp}: expected {expected!r}"))
    part = cand.get("partition")
    if part is None:
        return issues
    try:
        n_layers = int(part["n_layers"])
        bounds = [int(b) for b in part["boundaries"]]
    except (KeyError, TypeError, ValueError) as e:
        issues.append(_err("PLN009", where,
                           f"partition is malformed: {e!r}"))
        return issues
    if pp >= 1 and len(bounds) != pp * vpp:
        issues.append(_err("PLN009", where,
                           f"partition has {len(bounds)} stage chunks but "
                           f"the conf implies pp*vpp = {pp * vpp}"))
    if not bounds or bounds[0] < 1 \
            or any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
        issues.append(_err("PLN009", where,
                           f"partition boundaries {bounds} must be "
                           f"strictly increasing with every stage chunk "
                           f"owning >= 1 layer"))
    elif bounds[-1] != n_layers:
        issues.append(_err("PLN009", where,
                           f"partition boundaries end at {bounds[-1]} but "
                           f"must cover exactly n_layers = {n_layers} — "
                           f"some layers would be unassigned or assigned "
                           f"twice"))
    return issues


def _mem_floor_from(d: dict, spec) -> Optional[float]:
    """Tightest per-GPU memory: live spec first, else recorded tiers."""
    if spec is not None:
        return float(spec.mem_floor)
    tiers = (d.get("provenance") or {}).get("tiers")
    if not tiers:
        return None
    try:
        used = sorted(set(int(t) for t in tiers["node_tiers"]))
        return min(float(tiers["tiers"][i]["mem"]) for i in used)
    except (KeyError, TypeError, ValueError, IndexError):
        return None


def verify_plan_dict(d: dict, spec=None,
                     bw=None) -> List[PlanIssue]:
    """Statically verify a raw Plan JSON dict.

    Args:
        d: the parsed artifact (``json.load`` of a ``Plan.save`` file).
        spec: optional live :class:`~repro.core.cluster.ClusterSpec` to
            cross-check against (sizes, mem floor, tier fingerprint).
        bw: optional ``(G, G)`` profiled bandwidth matrix; when given the
            recorded digest must match its fingerprint.

    Returns:
        List of :class:`PlanIssue`, errors first.  An empty error set
        means "this artifact can execute on that cluster as far as
        static checks can tell".
    """
    from ..core.cluster import tier_fingerprint, tier_table_fingerprint
    from ..core.plan import PLAN_SCHEMA_VERSION, bw_fingerprint

    issues: List[PlanIssue] = []
    if not isinstance(d, dict):
        return [_err("PLN000", "artifact", "top level is not an object")]

    version = d.get("version")
    if version != PLAN_SCHEMA_VERSION:
        issues.append(_err("PLN001", "artifact",
                           f"unknown plan schema version {version!r} "
                           f"(this build reads version "
                           f"{PLAN_SCHEMA_VERSION}); refusing to trust "
                           f"field semantics"))

    prov = d.get("provenance")
    if not isinstance(prov, dict):
        issues.append(_err("PLN000", "provenance",
                           "provenance block is missing"))
        return issues
    try:
        n_gpus = int(prov["n_gpus"])
    except (KeyError, TypeError, ValueError):
        issues.append(_err("PLN000", "provenance",
                           "provenance.n_gpus is missing or not an int"))
        return issues

    # -- cluster cross-checks (PLN008) ------------------------------------
    if spec is not None:
        if spec.n_gpus != n_gpus:
            issues.append(_err("PLN008", "provenance",
                               f"plan was computed for {n_gpus} GPUs but "
                               f"the spec has {spec.n_gpus}"))
        if prov.get("cluster") != spec.name:
            issues.append(_warn("PLN008", "provenance",
                                f"plan records cluster "
                                f"{prov.get('cluster')!r}, checking "
                                f"against {spec.name!r}"))

    # -- bandwidth digest (PLN006) ----------------------------------------
    digest = prov.get("bw_digest")
    if not isinstance(digest, str) or not _HEX64.match(digest):
        issues.append(_err("PLN006", "provenance",
                           f"bw_digest {digest!r} is not a sha256 hex "
                           f"digest"))
    elif bw is not None:
        live = bw_fingerprint(bw)
        if live != digest:
            issues.append(_err("PLN006", "provenance",
                               f"bandwidth digest mismatch: plan was "
                               f"scored on sha256:{digest[:16]}… but the "
                               f"given matrix is sha256:{live[:16]}… — "
                               f"the interconnect snapshot changed; the "
                               f"plan is stale"))
    else:
        issues.append(_note("PLN006", "provenance",
                            "no bandwidth matrix given; digest checked "
                            "for format only"))

    # -- tier provenance (PLN007) -----------------------------------------
    tiers = prov.get("tiers")
    if tiers is not None:
        try:
            table = [(t["flops"], t["mem"], t["efficiency"], t["name"])
                     for t in tiers["tiers"]]
            node_tiers = [int(t) for t in tiers["node_tiers"]]
            recorded = tiers["digest"]
        except (KeyError, TypeError, ValueError):
            issues.append(_err("PLN000", "provenance.tiers",
                               "tier table is malformed"))
            table = None
        if table is not None:
            if any(not 0 <= t < len(table) for t in node_tiers):
                issues.append(_err("PLN007", "provenance.tiers",
                                   f"node_tiers index out of range "
                                   f"[0, {len(table)})"))
            if node_tiers and n_gpus % len(node_tiers) != 0:
                issues.append(_err("PLN007", "provenance.tiers",
                                   f"{len(node_tiers)} nodes cannot "
                                   f"evenly host {n_gpus} GPUs"))
            if tier_table_fingerprint(table, node_tiers) != recorded:
                issues.append(_err("PLN007", "provenance.tiers",
                                   "tier digest does not match the "
                                   "recorded tier table — the table or "
                                   "the digest was edited after planning"))
            if spec is not None:
                live = tier_fingerprint(spec)
                if live != recorded:
                    issues.append(_err("PLN007", "provenance.tiers",
                                       "plan's fleet composition differs "
                                       "from the spec's live tier "
                                       "fingerprint (node swapped or "
                                       "re-tiered); the plan is stale"))
    elif spec is not None and spec.has_tiers:
        issues.append(_err("PLN007", "provenance.tiers",
                           "spec is tiered but the plan records no tier "
                           "provenance — planned for a homogeneous "
                           "fleet"))

    # -- best + ranked candidates (PLN002/3/4/5) --------------------------
    best = d.get("best")
    if best is None:
        issues.append(_note("PLN002", "best",
                            "infeasible plan (no best candidate): "
                            "nothing to execute, executability checks "
                            "skipped"))
    candidates = ([("best", best)] if best is not None else []) \
        + [(f"ranked[{i}]", c)
           for i, c in enumerate(d.get("ranked") or [])]
    mem_floor = _mem_floor_from(d, spec)
    for where, cand in candidates:
        if not isinstance(cand, dict) or "conf" not in cand \
                or "mapping" not in cand:
            issues.append(_err("PLN000", where,
                               "candidate is missing conf/mapping"))
            continue
        issues.extend(_check_conf(cand["conf"], n_gpus, where))
        issues.extend(_check_mapping(cand["mapping"], cand["conf"],
                                     n_gpus, where))
        issues.extend(_check_partition(cand, where))
        mem_pred = cand.get("mem_pred")
        if mem_pred is None:
            if where == "best":
                issues.append(_note("PLN005", where,
                                    "no memory prediction recorded "
                                    "(memory-unaware strategy); OOM "
                                    "check skipped"))
        elif mem_floor is None:
            if where == "best":
                issues.append(_note("PLN005", where,
                                    "no memory floor derivable (no spec "
                                    "given and no tier provenance); OOM "
                                    "check skipped"))
        elif float(mem_pred) > mem_floor:
            issues.append(_err("PLN005", where,
                               f"predicted peak {float(mem_pred) / 1e9:.2f} "
                               f"GB exceeds the cluster's memory floor "
                               f"{mem_floor / 1e9:.2f} GB — this plan "
                               f"OOMs on its tightest device tier"))

    order = {"error": 0, "warning": 1, "note": 2}
    return sorted(issues, key=lambda i: (order[i.severity], i.rule,
                                         i.where))


def verify_plan_file(path, spec=None, bw=None) -> List[PlanIssue]:
    """:func:`verify_plan_dict` on a file; unreadable/unparsable files
    become ``PLN000`` errors instead of exceptions."""
    try:
        with open(path) as f:
            d = json.load(f)
    except OSError as e:
        return [_err("PLN000", "artifact", f"cannot read {path}: {e}")]
    except json.JSONDecodeError as e:
        return [_err("PLN000", "artifact",
                     f"{Path(path).name} is not valid JSON: {e}")]
    return verify_plan_dict(d, spec=spec, bw=bw)
