"""The determinism rule registry and its AST checkers.

Every rule is a :class:`Rule` in :data:`RULES`; the
:class:`DeterminismVisitor` walks one module's AST with an import-alias
resolver (so ``np.random.rand`` and ``from numpy import random as r;
r.rand`` both resolve to ``numpy.random.rand``) and emits
:class:`~repro.analysis.diagnostics.Diagnostic` findings.

Rule ids (stable — suppression comments reference them):

=======  ==========================================================
DET001   unseeded or process-global RNG (legacy ``np.random.*``,
         stdlib ``random`` module functions, ``default_rng()`` with
         no seed)
DET002   wall-clock read outside the allowlisted overhead timers
DET003   ``np.sum`` / ``ndarray.sum`` in a scoring module where
         ``np_pairwise_sum`` is the required reduction (scoped via
         ``det003-paths``)
DET004   builtin ``sum()`` over potentially-float values
         (left-fold, order-dependent; use ``math.fsum`` or
         ``np_pairwise_sum``)
DET005   ``==`` / ``!=`` against a float literal on computed values
DET006   iteration over a set expression feeding order-sensitive
         accumulation
DET007   host-side effect (print / wall clock / global RNG / IO)
         inside a jitted function
SYN001   file does not parse (reported by the linter driver)
SUP001   malformed suppression comment (see ``suppress.py``)
SUP002   unused suppression comment (see ``suppress.py``)
=======  ==========================================================

Known limitations (documented, deliberate): resolution is lexical, so a
set/RNG/clock reached through a *variable* (``s = set(xs); for x in s``)
or re-exported helper is not seen, and DET004's integer-sum escape only
recognizes ``len(...)`` elements.  The rules are a cheap gate in front of
the expensive bit-exactness suites, not a soundness proof — the same
split as AMP's validity pruning before real evaluation.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, List, Optional

from .config import AnalysisConfig
from .diagnostics import Diagnostic


@dataclass(frozen=True)
class Rule:
    """One registry entry: id, short name, and the one-line summary that
    the CLI's ``--list-rules`` and the docs table show."""
    id: str
    name: str
    summary: str


RULES: Dict[str, Rule] = {r.id: r for r in [
    Rule("DET001", "unseeded-rng",
         "unseeded or process-global RNG (legacy np.random.*, stdlib "
         "random.*, default_rng() without a seed)"),
    Rule("DET002", "wall-clock-read",
         "wall-clock read (time.time, datetime.now, ...) outside the "
         "allowlisted monotonic overhead timers"),
    Rule("DET003", "non-pairwise-reduction",
         "np.sum/ndarray.sum in a scoring module where np_pairwise_sum "
         "is the required (association-order-pinned) reduction"),
    Rule("DET004", "order-dependent-sum",
         "builtin sum() over potentially-float values — a left fold "
         "whose rounding depends on operand order (use math.fsum)"),
    Rule("DET005", "float-equality",
         "== / != against a float literal; computed floats differ in "
         "the last ulp across backends"),
    Rule("DET006", "unordered-iteration",
         "iterating a set expression into order-sensitive accumulation "
         "(set order varies with PYTHONHASHSEED)"),
    Rule("DET007", "host-effect-in-jit",
         "host-side effect (print, wall clock, global RNG, IO) inside "
         "a jitted function — runs at trace time, not step time"),
    Rule("SYN001", "syntax-error", "file does not parse"),
    Rule("SUP001", "malformed-suppression",
         "suppression comment missing rule codes or a reason"),
    Rule("SUP002", "unused-suppression",
         "suppression comment that matches no finding"),
]}

#: Legacy process-global numpy RNG entry points (DET001).
_NP_LEGACY_RNG = frozenset({
    "seed", "rand", "randn", "randint", "random", "random_sample",
    "ranf", "sample", "choice", "shuffle", "permutation", "uniform",
    "normal", "standard_normal", "binomial", "poisson", "exponential",
    "get_state", "set_state",
})
#: Stdlib ``random`` module-level functions (process-global Mersenne state).
_STDLIB_RNG = frozenset({
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "betavariate",
    "seed", "getrandbits", "randbytes", "triangular",
})
#: Consumers for which set-iteration order cannot matter (DET006).
_ORDER_FREE_CONSUMERS = frozenset({
    "min", "max", "any", "all", "len", "sorted", "set", "frozenset",
    "math.fsum",  # fsum is exact: result independent of operand order
})
#: Decorator spellings that mark a function as jitted (DET007).
_JIT_NAMES = frozenset({"jax.jit", "jax.pmap", "jax.pjit",
                        "jax.experimental.pjit.pjit"})
#: Host-effect calls banned inside jitted bodies (beyond wall clock/RNG).
_JIT_HOST_EFFECTS = frozenset({"print", "input", "open", "breakpoint"})


class _ImportResolver:
    """Lexical alias map: resolves an expression node to a dotted name.

    ``import numpy as np`` makes ``np.random.rand`` resolve to
    ``numpy.random.rand``; ``from time import time as now`` makes
    ``now`` resolve to ``time.time``.  Names assigned in the module are
    dropped from the map (a local ``sum = ...`` shadows the builtin).
    """

    def __init__(self):
        self.aliases: Dict[str, str] = {}
        self.shadowed: set = set()

    def add_import(self, node: ast.Import) -> None:
        for a in node.names:
            self.aliases[a.asname or a.name.split(".")[0]] = \
                a.name if a.asname else a.name.split(".")[0]

    def add_import_from(self, node: ast.ImportFrom) -> None:
        mod = ("." * node.level) + (node.module or "")
        for a in node.names:
            if a.name == "*":
                continue
            self.aliases[a.asname or a.name] = f"{mod}.{a.name}"

    def shadow(self, name: str) -> None:
        self.shadowed.add(name)
        self.aliases.pop(name, None)

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Dotted name of ``node`` with import aliases expanded, or None
        for non-name expressions (calls, subscripts, literals)."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = node.id
        if base in self.shadowed:
            # a rebound local: no alias expansion, and a bare name (e.g. a
            # local called ``sum``) no longer refers to the builtin
            return ".".join([base, *reversed(parts)]) if parts else None
        root = self.aliases.get(base, base)
        return ".".join([root, *reversed(parts)])


def _is_float_literal(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and isinstance(node.value, float)


def _is_len_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id == "len")


def _is_set_expr(node: ast.AST, resolver: _ImportResolver) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        return resolver.resolve(node.func) in ("set", "frozenset")
    return False


def _int_elements_only(call: ast.Call) -> bool:
    """True when every summed element is an obvious integer — the one
    escape DET004 recognizes is ``sum(len(x) for x in ...)`` (and sums of
    integer literals); everything else needs a reasoned suppression."""
    if not call.args:
        return True
    arg = call.args[0]
    if isinstance(arg, (ast.GeneratorExp, ast.ListComp)):
        return _is_len_call(arg.elt) or (
            isinstance(arg.elt, ast.Constant)
            and isinstance(arg.elt.value, int))
    if isinstance(arg, (ast.List, ast.Tuple)):
        return all(_is_len_call(e) or
                   (isinstance(e, ast.Constant) and isinstance(e.value, int))
                   for e in arg.elts)
    return False


class DeterminismVisitor(ast.NodeVisitor):
    """Single-pass visitor running every enabled DET rule over one module."""

    def __init__(self, path: str, config: AnalysisConfig):
        self.path = path
        self.config = config
        self.resolver = _ImportResolver()
        self.diags: List[Diagnostic] = []
        self._jit_depth = 0          # > 0 while inside a jitted function
        self._parents: Dict[int, ast.AST] = {}

    # -- plumbing ----------------------------------------------------------

    def run(self, tree: ast.Module) -> List[Diagnostic]:
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[id(child)] = parent
        self.visit(tree)
        return self.diags

    def _emit(self, rule_id: str, node: ast.AST, message: str) -> None:
        if not self.config.rule_enabled(rule_id):
            return
        self.diags.append(Diagnostic(
            path=self.path, line=node.lineno, col=node.col_offset,
            rule=rule_id, message=message,
            end_line=getattr(node, "end_lineno", node.lineno)))

    def _parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(id(node))

    # -- imports and shadowing --------------------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        self.resolver.add_import(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        self.resolver.add_import_from(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                self.resolver.shadow(tgt.id)
        self.generic_visit(node)

    # -- jit context (DET007) ---------------------------------------------

    def _is_jit_decorator(self, dec: ast.AST) -> bool:
        name = self.resolver.resolve(dec)
        if name in _JIT_NAMES or (name or "").split(".")[-1] == "jit":
            return True
        # @partial(jax.jit, ...) / @functools.partial(jit, ...)
        if isinstance(dec, ast.Call):
            fn = self.resolver.resolve(dec.func)
            if fn in ("functools.partial", "partial") and dec.args:
                return self._is_jit_decorator(dec.args[0])
            return self._is_jit_decorator(dec.func)
        return False

    def _visit_function(self, node) -> None:
        for a in [*node.args.args, *node.args.kwonlyargs,
                  *node.args.posonlyargs]:
            self.resolver.shadow(a.arg)
        jitted = any(self._is_jit_decorator(d) for d in node.decorator_list)
        self._jit_depth += 1 if jitted else 0
        self.generic_visit(node)
        self._jit_depth -= 1 if jitted else 0

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    # -- call-site rules ---------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        name = self.resolver.resolve(node.func)
        if name is not None:
            self._check_rng(node, name)
            self._check_wall_clock(node, name)
            self._check_array_sum(node, name)
            self._check_builtin_sum(node, name)
            if self._jit_depth > 0 and name in _JIT_HOST_EFFECTS:
                self._emit("DET007", node,
                           f"host-side effect '{name}()' inside a jitted "
                           f"function: executes at trace time only, and "
                           f"breaks purity of the compiled computation")
        self.generic_visit(node)

    def _check_rng(self, node: ast.Call, name: str) -> None:
        if name.startswith("numpy.random.") and \
                name.split(".")[-1] in _NP_LEGACY_RNG:
            self._emit("DET001", node,
                       f"process-global legacy RNG '{name}': draws depend "
                       f"on hidden module state; use a seeded "
                       f"np.random.default_rng(seed) passed explicitly")
        elif name.startswith("random.") and \
                name.split(".")[-1] in _STDLIB_RNG:
            self._emit("DET001", node,
                       f"process-global stdlib RNG '{name}': use a seeded "
                       f"np.random.default_rng(seed) or random.Random(seed)")
        elif name in ("numpy.random.default_rng", "random.Random") \
                and not node.args and not node.keywords:
            self._emit("DET001", node,
                       f"'{name}()' without a seed draws entropy from the "
                       f"OS; pass an explicit seed")
        if self._jit_depth > 0 and (name.startswith("numpy.random.")
                                    or name.startswith("random.")):
            self._emit("DET007", node,
                       f"host RNG '{name}' inside a jitted function: "
                       f"evaluated once at trace time, then baked into "
                       f"the compiled graph as a constant")

    def _check_wall_clock(self, node: ast.Call, name: str) -> None:
        if name in self.config.wall_clock_ban:
            det7 = self._jit_depth > 0
            self._emit("DET007" if det7 else "DET002", node,
                       f"wall-clock read '{name}' "
                       + ("inside a jitted function"
                          if det7 else
                          "outside the allowlisted overhead timers: "
                          "wall time must never reach a scored or "
                          "serialized value (inject timestamps; use "
                          "time.perf_counter for overhead measurement)"))

    def _check_array_sum(self, node: ast.Call, name: str) -> None:
        if not self.config.det003_applies(self.path):
            return
        is_np = name in ("numpy.sum", "jax.numpy.sum")
        is_method = (isinstance(node.func, ast.Attribute)
                     and node.func.attr == "sum" and not is_np)
        if not (is_np or is_method):
            return
        # ``int(x.sum())`` is self-documenting: an integer reduction is
        # exact, so association order cannot change the value
        parent = self._parent(node)
        if isinstance(parent, ast.Call) \
                and self.resolver.resolve(parent.func) == "int":
            return
        self._emit("DET003", node,
                   "array sum in a scoring module: reductions on this "
                   "path must replay NumPy's pairwise association "
                   "order exactly (np_pairwise_sum) or carry a reason "
                   "why order cannot matter here")

    def _check_builtin_sum(self, node: ast.Call, name: str) -> None:
        if name != "sum" or _int_elements_only(node):
            return
        self._emit("DET004", node,
                   "builtin sum() is a left fold — float rounding depends "
                   "on operand order; use math.fsum (order-independent) "
                   "or np_pairwise_sum, or suppress with a reason if the "
                   "operands are provably integers")

    # -- comparison / iteration rules -------------------------------------

    def visit_Compare(self, node: ast.Compare) -> None:
        if any(isinstance(op, (ast.Eq, ast.NotEq)) for op in node.ops) and \
                any(_is_float_literal(c) for c in
                    [node.left, *node.comparators]):
            self._emit("DET005", node,
                       "exact ==/!= against a float literal: computed "
                       "floats differ in the last ulp across backends and "
                       "reduction orders; compare with a tolerance, or "
                       "suppress with a reason if the value is an exact "
                       "sentinel (never computed)")
        self.generic_visit(node)

    def _comprehension_consumer_ok(self, node: ast.AST) -> bool:
        parent = self._parent(node)
        if isinstance(parent, ast.Call) and len(parent.args) >= 1 \
                and parent.args[0] is node:
            return self.resolver.resolve(parent.func) \
                in _ORDER_FREE_CONSUMERS
        # feeding a set/dict comprehension result stays unordered anyway
        return isinstance(parent, (ast.SetComp, ast.DictComp))

    def _check_comp_iters(self, node) -> None:
        for gen in node.generators:
            if _is_set_expr(gen.iter, self.resolver) and \
                    not self._comprehension_consumer_ok(node):
                self._emit("DET006", node,
                           "comprehension over a set expression feeding "
                           "an order-sensitive consumer: set order varies "
                           "with PYTHONHASHSEED; iterate sorted(...) "
                           "instead")
        self.generic_visit(node)

    visit_GeneratorExp = _check_comp_iters
    visit_ListComp = _check_comp_iters

    def visit_SetComp(self, node: ast.SetComp) -> None:
        self.generic_visit(node)                 # result is unordered; fine

    def visit_For(self, node: ast.For) -> None:
        if _is_set_expr(node.iter, self.resolver):
            self._emit("DET006", node,
                       "for-loop over a set expression: iteration order "
                       "varies with PYTHONHASHSEED, so any order-sensitive "
                       "body (float accumulation, list building, dict "
                       "insertion) is non-deterministic; iterate "
                       "sorted(...) instead")
        self.generic_visit(node)
