"""Reasoned inline suppressions: ``# repro: noqa DET002 -- why``.

The policy is deliberately stricter than flake8's bare ``# noqa``:

* a suppression must name the rule(s) it silences (no blanket waivers),
* it must carry a non-empty reason after ``--`` (the *why* is reviewed,
  not just the *what*), and
* it must actually match a finding — stale suppressions rot into silent
  blanket waivers, so an unused one is itself a violation (``SUP002``).

Malformed suppressions (missing codes, missing reason) are ``SUP001``
violations rather than being ignored: a typo'd noqa that silently fails
open is worse than no noqa at all.
"""
from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass, field
from typing import List, Tuple

from .diagnostics import Diagnostic

#: Matches the suppression marker anywhere in a comment.
_NOQA_RE = re.compile(r"#\s*repro:\s*noqa\b(?P<rest>[^\n]*)")
#: codes, then `` -- reason``; codes are comma/space separated rule ids.
_REST_RE = re.compile(
    r"^\s*(?P<codes>[A-Z]{3}\d{3}(?:\s*,\s*[A-Z]{3}\d{3})*)"
    r"\s*--\s*(?P<reason>\S.*)$")


@dataclass
class Suppression:
    """One parsed ``# repro: noqa`` comment."""
    line: int
    codes: Tuple[str, ...]
    reason: str
    used: bool = field(default=False, compare=False)


def scan_suppressions(source: str,
                      path: str) -> Tuple[List[Suppression],
                                          List[Diagnostic]]:
    """Extract suppressions from source text.

    Only real ``#`` comments count (the source is tokenized, so a noqa
    *example* inside a docstring or string literal is inert).  Returns
    ``(valid_suppressions, malformed_diagnostics)`` — malformed markers
    become ``SUP001`` findings at their own location.
    """
    supps: List[Suppression] = []
    bad: List[Diagnostic] = []
    comments = []
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                comments.append((tok.start[0], tok.start[1], tok.string))
    except (tokenize.TokenError, IndentationError):
        # unparsable files already carry a SYN001 from the linter driver
        return supps, bad
    for lineno, col, text in comments:
        m = _NOQA_RE.search(text)
        if m is None:
            continue
        rest = _REST_RE.match(m.group("rest"))
        if rest is None:
            bad.append(Diagnostic(
                path=path, line=lineno, col=col + m.start(), rule="SUP001",
                message="malformed suppression: expected "
                        "'# repro: noqa <RULE[,RULE...]> -- <reason>' "
                        "(rule codes and a non-empty reason are both "
                        "required)", end_line=lineno))
            continue
        codes = tuple(c.strip() for c in rest.group("codes").split(","))
        supps.append(Suppression(line=lineno, codes=codes,
                                 reason=rest.group("reason").strip()))
    return supps, bad


def apply_suppressions(diags: List[Diagnostic], supps: List[Suppression],
                       path: str) -> List[Diagnostic]:
    """Match suppressions to findings; flag unused ones as ``SUP002``.

    A suppression on physical line L silences a finding whose statement
    spans ``[line, end_line]`` containing L — so the comment can sit at
    the end of any line of a multi-line call.
    """
    out: List[Diagnostic] = []
    for d in diags:
        hit = None
        for s in supps:
            if d.rule in s.codes and \
                    d.line <= s.line <= max(d.end_line, d.line):
                hit = s
                break
        if hit is not None:
            hit.used = True
            out.append(d.suppress(hit.reason))
        else:
            out.append(d)
    for s in supps:
        if not s.used:
            out.append(Diagnostic(
                path=path, line=s.line, col=0, rule="SUP002",
                message=f"unused suppression for "
                        f"{', '.join(s.codes)}: no matching finding on "
                        f"this statement (stale noqa — remove it)",
                end_line=s.line))
    return out
