"""Sharded checkpointing with atomic writes, keep-k retention, async save
and resume (fault-tolerance substrate).

Layout:  <dir>/step_<N>/
           arrays.npz      flattened leaves (gathered to host)
           meta.json       tree structure, step, dtypes, optional timestamp
         <dir>/LATEST      atomically-renamed pointer file

Manifests are byte-reproducible by default: ``save`` takes an *injectable*
``timestamp`` (``None`` unless the caller passes one), so two identical
deterministic runs emit identical ``meta.json`` files.  Callers that want
wall time in the manifest pass ``timestamp=time.time()`` explicitly —
the clock read happens at the call site, never inside this module.

Restore reshards onto the current mesh via device_put with the target
shardings — this is what makes elastic re-plans (different G after a node
failure) work: Pipette picks a new Conf, the runtime rebuilds the mesh,
and the checkpoint reloads against the new partition specs.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _to_numpy(x) -> Tuple[np.ndarray, str]:
    """npz-safe encoding; bfloat16 round-trips bitwise via a uint16 view."""
    a = np.asarray(x)
    if a.dtype.name == "bfloat16":
        return a.view(np.uint16), "bfloat16"
    return a, a.dtype.name


def _from_numpy(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name == "bfloat16":
        import ml_dtypes
        return a.view(ml_dtypes.bfloat16)
    return a


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Dict[str, str], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    arrays, dtypes = {}, {}
    for i, x in enumerate(leaves):
        arrays[f"leaf_{i}"], dtypes[f"leaf_{i}"] = _to_numpy(x)
    return arrays, dtypes, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------
    def save(self, step: int, tree: Any, *, block: bool = False,
             timestamp: Optional[float] = None) -> Path:
        """Write ``step_<step>/``.  ``timestamp`` is recorded verbatim in
        the manifest (``None`` by default — a wall-clock read here would
        make byte-identical training runs emit differing checkpoints)."""
        arrays, dtypes, treedef = _flatten(tree)   # gathers to host
        meta = {"step": int(step), "treedef": str(treedef),
                "n_leaves": len(arrays), "dtypes": dtypes,
                "time": timestamp}

        def _write():
            tmp = self.dir / f".tmp_step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz", **arrays)
            (tmp / "meta.json").write_text(json.dumps(meta))
            final = self.dir / f"step_{step}"
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            latest_tmp = self.dir / ".LATEST.tmp"
            latest_tmp.write_text(f"step_{step}")
            os.rename(latest_tmp, self.dir / "LATEST")
            self._gc()

        if self.async_save and not block:
            self.wait()
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
        return self.dir / f"step_{step}"

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore ------------------------------------------------------
    def steps(self):
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                if (p / "meta.json").exists()]

    def latest_step(self) -> Optional[int]:
        ptr = self.dir / "LATEST"
        if ptr.exists():
            name = ptr.read_text().strip()
            path = self.dir / name
            if (path / "meta.json").exists():
                return int(name.split("_")[1])
        steps = self.steps()
        return max(steps) if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, int]:
        """Restore into the structure of ``like``; reshard onto
        ``shardings`` (or the shardings carried by ``like``) if given."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        data = np.load(self.dir / f"step_{step}" / "arrays.npz")
        meta = json.loads((self.dir / f"step_{step}" / "meta.json").read_text())
        dtypes = meta.get("dtypes", {})
        leaves, treedef = jax.tree.flatten(like)
        if len(leaves) != len(data.files):
            raise ValueError(
                f"checkpoint has {len(data.files)} leaves, expected "
                f"{len(leaves)} — config/topology mismatch")
        new_leaves = [_from_numpy(data[f"leaf_{i}"],
                                  dtypes.get(f"leaf_{i}", ""))
                      for i in range(len(leaves))]
        tree = jax.tree.unflatten(treedef, new_leaves)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, step
