"""Architecture registry: one module per assigned architecture (exact
published numbers) + the paper's own GPT sizes.  ``get(name)`` /
``--arch <id>`` select them."""
from __future__ import annotations

from ..models.config import SHAPES, ModelConfig, ShapeSpec
from .llava_next_mistral_7b import CONFIG as LLAVA_NEXT_MISTRAL_7B
from .musicgen_large import CONFIG as MUSICGEN_LARGE
from .kimi_k2_1t_a32b import CONFIG as KIMI_K2_1T_A32B
from .granite_moe_3b_a800m import CONFIG as GRANITE_MOE_3B_A800M
from .qwen2_7b import CONFIG as QWEN2_7B
from .command_r_plus_104b import CONFIG as COMMAND_R_PLUS_104B
from .qwen15_4b import CONFIG as QWEN15_4B
from .gemma3_12b import CONFIG as GEMMA3_12B
from .falcon_mamba_7b import CONFIG as FALCON_MAMBA_7B
from .zamba2_7b import CONFIG as ZAMBA2_7B
from .gpt_paper import GPT_1_1B, GPT_3_1B, GPT_8_1B, GPT_11_1B

ARCHS = {c.name: c for c in [
    LLAVA_NEXT_MISTRAL_7B, MUSICGEN_LARGE, KIMI_K2_1T_A32B,
    GRANITE_MOE_3B_A800M, QWEN2_7B, COMMAND_R_PLUS_104B, QWEN15_4B,
    GEMMA3_12B, FALCON_MAMBA_7B, ZAMBA2_7B,
]}
PAPER_GPTS = {c.name: c for c in [GPT_1_1B, GPT_3_1B, GPT_8_1B, GPT_11_1B]}


def get(name: str) -> ModelConfig:
    if name in ARCHS:
        return ARCHS[name]
    if name in PAPER_GPTS:
        return PAPER_GPTS[name]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS) + sorted(PAPER_GPTS)}")


def cells():
    """The 40 (arch x shape) assignment cells with applicability flags."""
    out = []
    for a in ARCHS.values():
        for s in SHAPES.values():
            skip = ""
            if s.name == "long_500k" and not a.is_subquadratic:
                skip = "pure full-attention arch: 500k dense KV cache excluded per spec"
            out.append((a, s, skip))
    return out
