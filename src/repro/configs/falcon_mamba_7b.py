"""falcon-mamba-7b [ssm] — attention-free Mamba1.  [arXiv:2410.05355; unverified]

64L, d4096 (d_inner 8192), ssm_state 16, vocab 65024.  Constant-memory
decode state -> runs the long_500k cell.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=65024,
    ssm_variant="mamba1", ssm_state=16, ssm_conv=4, ssm_expand=2,
)
