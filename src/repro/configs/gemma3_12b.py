"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

48L, d3840, 16H GQA kv=8, head_dim 256 (public gemma3 config; d_model/H
would give 240), ff15360, vocab 262144.  Local layers use a 1024-token
sliding window (theta 10k); every 6th layer is global (theta 1M).  Decode
keeps ring-buffer caches for local layers — the reason this arch runs the
long_500k cell.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262144,
    local_global_period=6, sliding_window=1024,
    rope_theta=1e4, rope_theta_global=1e6,
)
