"""GPT configurations matching the paper's evaluation sizes (§VII):
1.1B / 3.1B on the mid-range cluster, 8.1B / 11.1B on the high-end one.
Layer/width chosen to hit the stated parameter counts with the standard
GPT-2/3 shape rules (params ~= 12 L d^2 + vocab d)."""
from ..models.config import ModelConfig


def _gpt(name, n_layers, d_model, n_heads):
    return ModelConfig(
        name=name, family="dense", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=n_heads, d_ff=4 * d_model,
        vocab_size=51200)


GPT_1_1B = _gpt("gpt-1.1b", 24, 1920, 20)
GPT_3_1B = _gpt("gpt-3.1b", 32, 2816, 22)
GPT_8_1B = _gpt("gpt-8.1b", 40, 4096, 32)
GPT_11_1B = _gpt("gpt-11.1b", 48, 4352, 32)
