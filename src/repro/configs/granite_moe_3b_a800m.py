"""granite-moe-3b-a800m [moe] — 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

32L, d1536, 24H GQA kv=8, expert ff 512, vocab 49155, 40e top-8.
vocab % 16 != 0 -> the embedding shards over d_model instead (sharding.py).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    n_experts=40, experts_per_token=8,
)
