"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE (paper-table).
[arXiv:2501.kimi2; unverified]

61L, d7168, 64H GQA kv=8, expert ff 2048, vocab 163840, 384 experts top-8.
Expert-parallel over the model axis + FSDP over the data axis (see
DESIGN.md §4): at 512 v5e chips the optimizer state alone exceeds HBM —
the dry-run reports the honest per-device bytes.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab_size=163840,
    n_experts=384, experts_per_token=8,
)
