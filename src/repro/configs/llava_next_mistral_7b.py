"""llava-next-mistral-7b [vlm] — anyres tiling backbone.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

Backbone = Mistral-7B (32L, d4096, 32H GQA kv=8, ff14336, vocab 32000).
The vision frontend is a STUB: input_specs() provides 2880 precomputed
anyres patch embeddings (4 tiles + base image x 576 patches), already
projected to d_model.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    frontend="vlm", n_img_tokens=2880,
    rope_theta=1e6,
)
