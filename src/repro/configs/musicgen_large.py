"""musicgen-large [audio] — decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]

48L, d2048, 32H (kv=32 => MHA), ff8192, codebook vocab 2048.  The EnCodec
frontend is a STUB: input_specs() provides the token stream (the real
model interleaves 4 codebooks with a delay pattern; the backbone shapes
are identical).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048,
    frontend="audio",
)
