"""zamba2-7b [hybrid] — Mamba2 blocks + weight-tied shared attention.
[arXiv:2411.15242; unverified]

81L, d3584, Mamba2 (ssm_state 64, head_dim 64) with a single shared
attention+MLP block (32H kv=32, ff14336) applied every 6th layer —
the Zamba2 shared-block pattern (DESIGN.md §4).  Runs long_500k.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000,
    ssm_variant="mamba2", ssm_state=64, ssm_head_dim=64, ssm_conv=4,
    ssm_expand=2, hybrid_attn_period=6,
)
