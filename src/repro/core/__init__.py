"""Pipette core: the paper's automatic fine-grained parallel-training
configurator — latency estimator (Eq. 3-6), MLP memory estimator (§VI),
SA worker dedication (§IV), Algorithm 1 search, the discrete-event cluster
simulator used as the real-cluster stand-in, and the AMP/Varuna/Megatron
baselines.

The search space is 4D: (pp, tp, cp, dp) with context parallelism (ring
attention over sequence shards) as the fourth axis via
``SearchSpace(max_cp=...)``; ``cp == 1`` reproduces the paper's 3D setting
bit-for-bit, and the baselines deliberately stay 3D.

Clusters may be heterogeneous in *compute* as well as interconnect:
``ClusterSpec`` carries an optional per-node :class:`~repro.core.cluster.
DeviceTier` table (``mixed_fleet_spec`` / ``degraded_host_spec`` build
seeded mixed-generation and degraded-host fleets), priced per pipeline
stage by the slowest member GPU throughout the model, engine, and
simulator.  Homogeneous specs keep the historical scalars bit-for-bit,
and the baselines additionally stay compute-blind.

Pipeline stages may carry non-uniform layer counts: ``partition.py``
solves a balanced min-max dynamic program over per-layer cost vectors
(``SearchSpace(partition="dp")``), and interleaved-1F1B virtual-pipeline
scheduling opens via ``SearchSpace(max_vpp=...)``; the uniform split with
plain 1F1B (``Conf.vpp == 1``, ``Profile.partition is None``) reproduces
the historical estimates bit-for-bit.

The public entry point is the Planner API (``plan.py``):
``Planner(strategy).plan(PlanRequest(...), bw)`` returns a serializable
:class:`~repro.core.plan.Plan` artifact; the legacy ``configure()`` kwarg
pile remains as a bit-exact shim over ``Planner(PipetteStrategy())``."""

from .cluster import (ClusterSpec, DeviceTier, HIGH_END, MID_RANGE,
                      MID_RANGE_DEGRADED, MIXED_A100_V100, TPU_POD,
                      compute_slowdowns, degraded_host_spec,
                      min_group_bw, min_group_bw_batch, mixed_fleet_spec,
                      profile_bandwidth, tier_fingerprint,
                      true_bandwidth_matrix)
from .partition import (PARTITION_MODES, SCHEDULES, Partition,
                        PartitionCache, balanced_partition, make_partition,
                        resolve_partition, uniform_partition)
from .simulator import (Conf, Profile, ProfileCache, Workload, build_profile,
                        default_mapping, dp_allreduce_times,
                        dp_allreduce_times_ref, measure)
from .latency import (amp_latency, default_mapping_latencies, pipette_latency,
                      pipette_latency_ref, varuna_latency)
from .memory import (MemoryEstimator, analytical_estimate, enumerate_confs,
                     fit_memory_estimator, ground_truth_memory, mape,
                     rank_state_bytes)
from .dedication import (DedicationEngine, GroupIndex, PairCache, SAResult,
                         anneal, anneal_multistart, mapping_to_perm,
                         perm_to_mapping, project_perm)
from .migration import (DEFAULT_RESTART_S, PlanDiff, diff_assignments,
                        resolve_model, state_keys)
from .annealing import (MovePlan, build_islands, coarse_assign,
                        coarse_orderings, dedicate_candidates,
                        make_move_plan)
from .search import (BatchSearchContext, Candidate, Overhead, SearchResult,
                     configure, run_search)
from .baselines import amp_configure, mlm_configure, varuna_configure
from .plan import (STRATEGIES, AMPStrategy, Budget, ExhaustiveStrategy,
                   MegatronStrategy, Plan, PlanLoadError, Planner,
                   PlanRequest, PipetteStrategy, Provenance, SearchSpace,
                   Strategy, VarunaStrategy, bw_fingerprint)
