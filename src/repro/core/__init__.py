"""Pipette core: the paper's automatic fine-grained parallel-training
configurator — latency estimator (Eq. 3-6), MLP memory estimator (§VI),
SA worker dedication (§IV), Algorithm 1 search, the discrete-event cluster
simulator used as the real-cluster stand-in, and the AMP/Varuna/Megatron
baselines.

The search space is 4D: (pp, tp, cp, dp) with context parallelism (ring
attention over sequence shards) as the fourth axis via
``SearchSpace(max_cp=...)``; ``cp == 1`` reproduces the paper's 3D setting
bit-for-bit, and the baselines deliberately stay 3D.

The public entry point is the Planner API (``plan.py``):
``Planner(strategy).plan(PlanRequest(...), bw)`` returns a serializable
:class:`~repro.core.plan.Plan` artifact; the legacy ``configure()`` kwarg
pile remains as a bit-exact shim over ``Planner(PipetteStrategy())``."""

from .cluster import (ClusterSpec, HIGH_END, MID_RANGE, TPU_POD,
                      min_group_bw, min_group_bw_batch, profile_bandwidth,
                      true_bandwidth_matrix)
from .simulator import (Conf, Profile, ProfileCache, Workload, build_profile,
                        default_mapping, dp_allreduce_times,
                        dp_allreduce_times_ref, measure)
from .latency import (amp_latency, default_mapping_latencies, pipette_latency,
                      pipette_latency_ref, varuna_latency)
from .memory import (MemoryEstimator, analytical_estimate, enumerate_confs,
                     fit_memory_estimator, ground_truth_memory, mape)
from .dedication import (DedicationEngine, GroupIndex, SAResult, anneal,
                         anneal_multistart, perm_to_mapping)
from .search import Candidate, Overhead, SearchResult, configure, run_search
from .baselines import amp_configure, mlm_configure, varuna_configure
from .plan import (STRATEGIES, AMPStrategy, Budget, ExhaustiveStrategy,
                   MegatronStrategy, Plan, Planner, PlanRequest,
                   PipetteStrategy, Provenance, SearchSpace, Strategy,
                   VarunaStrategy, bw_fingerprint)
