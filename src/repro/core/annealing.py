"""Backend-selectable multi-chain SA core with hierarchical island search.

This is the unified dedication driver behind ``Budget(backend=...)``:
the full move schedule of every chain — move kinds, positions, accept
thresholds, per-chain iteration budgets — is precomputed on the host as a
:class:`MovePlan`, and then *executed* by one of two interchangeable
engines:

* ``backend="numpy"`` — the incremental
  :class:`~repro.core.dedication.DedicationEngine`, one Python loop per
  chain (fast at small fleets, where per-move work is tiny);
* ``backend="jax"`` — :class:`~repro.core.jax_engine.JaxDedicationEngine`,
  a jitted ``lax.scan`` vmapped across chains *and* same-shape candidate
  configurations (fast at large fleets, where the vectorized full
  re-score amortises and Python dispatch would dominate).

Because the RNG stream lives entirely in the MovePlan and both engines
score bit-identically (float64 everywhere, matching reduction order), the
two backends produce **byte-identical plans** chain for chain — pinned by
``tests/test_backend_determinism.py``.  ``backend=None`` (the default) is
not handled here at all: ``run_search`` keeps the historical per-candidate
``anneal``/``anneal_multistart`` path, bit-exact with its regression
fixtures.

Scale comes from the *hierarchical* mode layered on top: nodes are
clustered into tier/bandwidth islands (:func:`build_islands`), the
inter-island arrangement is solved coarsely (:func:`coarse_assign` scores
a few whole-island orderings), and the SA chains then refine *within*
islands — every move draws its two positions inside one island, so the
move schedule stays valid under any island ordering and the refined
solution can never be worse than the coarse one (SA tracks
best-so-far starting from the coarse permutation).  A single-island
decomposition degenerates to the flat path bit-exactly: the identity
ordering is the only coarse candidate and the MovePlan draws identical
streams (the island-selection draw is skipped when there is only one).

Budget split across chains (also the :func:`~repro.core.dedication.
anneal_multistart` contract after the fix shipped with this module): with
``base, rem = divmod(sa_iters, n_chains)``, chain ``k`` runs
``base + 1`` iterations if ``k < rem`` else ``base`` — totals are exact,
and chains beyond ``sa_iters`` run zero moves, contributing the initial
permutation's score.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cluster import ClusterSpec, compute_slowdowns
from .dedication import (DedicationEngine, GroupIndex, PairCache, SAResult,
                         perm_to_mapping)
from .simulator import Conf, Profile

#: ``Budget.hierarchical=None`` resolves to hierarchical search at and
#: above this fleet size (flat SA mixing time degrades far earlier, but
#: below this the flat path is still competitive and simpler to audit).
HIER_AUTO_GPUS = 2048

#: Temperature probes per chain (the initial-temperature estimate of
#: ``dedication.anneal``, kept at the same count).
N_PROBES = 8

#: Island size cap in GPUs: islands are chunks of whole same-tier nodes
#: with at most this many GPUs (capacity re-expressed in nodes, >= 1).
MAX_ISLAND_GPUS = 256

_ALPHA = 0.999


# ---------------------------------------------------------------------------
# host-precomputed move schedule
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MovePlan:
    """The complete, backend-agnostic move schedule of every SA chain.

    All randomness of the unified driver lives here: chain ``k`` draws from
    ``np.random.default_rng(seed * 100003 + k)`` (the historical
    multi-start chain-seed convention) in a fixed order — probe draws
    first, then the iteration draws, each as whole-array calls:
    island (skipped when there is a single island), kind, first position,
    second position, accept uniform.  Positions are *island-relative*
    (``isl``/``oa``/``ob``); the executing backend adds the per-candidate
    island offsets of the coarse arrangement.  Accept thresholds are stored
    as ``-log(u)`` so the device loop needs no transcendentals: the
    Metropolis test ``u < exp(-delta/temp)`` becomes
    ``delta < temp * thresh``.

    Attributes:
        island_sizes: sizes of the islands the plan was drawn for (every
            size >= 2 — a move needs two distinct positions).
        chain_iters: ``(K,)`` per-chain iteration budgets (exact divmod
            split of ``max_iters``; see module docstring).
        kind / isl / oa / ob / thresh: ``(K, T)`` iteration draws, where
            ``T = chain_iters.max()`` — rows are padded, ``valid`` masks
            the pad.
        valid: ``(K, T)`` boolean execution mask.
        probe_kind / probe_isl / probe_oa / probe_ob: ``(K, P)``
            temperature-probe draws.
    """
    island_sizes: Tuple[int, ...]
    chain_iters: np.ndarray
    kind: np.ndarray
    isl: np.ndarray
    oa: np.ndarray
    ob: np.ndarray
    thresh: np.ndarray
    valid: np.ndarray
    probe_kind: np.ndarray
    probe_isl: np.ndarray
    probe_oa: np.ndarray
    probe_ob: np.ndarray

    @property
    def n_chains(self) -> int:
        return len(self.chain_iters)

    @property
    def n_probes(self) -> int:
        return self.probe_kind.shape[1]


def make_move_plan(island_sizes: Sequence[int], max_iters: int,
                   n_chains: int, seed: int,
                   n_probes: int = N_PROBES) -> MovePlan:
    """Draw the full move schedule for ``n_chains`` chains.

    Deterministic in ``seed``; independent of backend, candidate and
    coarse island ordering (positions are island-relative).
    """
    sizes = np.asarray(island_sizes, dtype=np.int64)
    if sizes.size == 0 or (sizes < 2).any():
        raise ValueError("every island needs >= 2 positions to draw moves")
    if n_chains < 1:
        raise ValueError("n_chains must be >= 1")
    base, rem = divmod(max(max_iters, 0), n_chains)
    chain_iters = base + (np.arange(n_chains) < rem).astype(np.int64)
    t_max = int(chain_iters.max())
    multi = sizes.size > 1

    def draw(rng, count):
        isl = (rng.integers(sizes.size, size=count) if multi
               else np.zeros(count, dtype=np.int64))
        kind = rng.integers(3, size=count)
        length = sizes[isl]
        oa = rng.integers(length)
        ob = rng.integers(length - 1)
        ob += (ob >= oa)          # second position distinct from the first
        return isl, kind, oa, ob

    shape_t, shape_p = (n_chains, t_max), (n_chains, n_probes)
    kind = np.zeros(shape_t, np.int64)
    isl = np.zeros(shape_t, np.int64)
    oa = np.zeros(shape_t, np.int64)
    ob = np.ones(shape_t, np.int64)
    thresh = np.zeros(shape_t)
    p_kind = np.zeros(shape_p, np.int64)
    p_isl = np.zeros(shape_p, np.int64)
    p_oa = np.zeros(shape_p, np.int64)
    p_ob = np.ones(shape_p, np.int64)
    for k in range(n_chains):
        rng = np.random.default_rng(seed * 100003 + k)
        p_isl[k], p_kind[k], p_oa[k], p_ob[k] = draw(rng, n_probes)
        isl[k], kind[k], oa[k], ob[k] = draw(rng, t_max)
        with np.errstate(divide="ignore"):
            thresh[k] = -np.log(rng.random(t_max))
    valid = np.arange(t_max)[None, :] < chain_iters[:, None]
    return MovePlan(tuple(int(s) for s in sizes), chain_iters, kind, isl,
                    oa, ob, thresh, valid, p_kind, p_isl, p_oa, p_ob)


# ---------------------------------------------------------------------------
# island decomposition + coarse inter-island assignment
# ---------------------------------------------------------------------------

def build_islands(spec: ClusterSpec, *, hierarchical: bool,
                  max_island_gpus: int = MAX_ISLAND_GPUS) -> List[np.ndarray]:
    """Partition the GPU ids ``0..n-1`` into refinement islands.

    Islands are chunks of whole nodes sharing a device tier (tiers are the
    dominant compute/bandwidth discontinuity of a mixed fleet), capped at
    ``max_island_gpus`` GPUs; islands that end up with fewer than two
    positions are merged into a neighbour.  ``hierarchical=False`` (the
    flat path) returns the single island ``[0..n-1]``.  The islands are
    always an exact *partition* of ``0..n-1`` in whole nodes (sorting the
    concatenation round-trips to ``arange(n)``), but same-tier nodes are
    grouped together, so with interleaved tiers the concatenation order
    differs from id order (pinned by ``tests/test_hierarchical_search``).
    """
    n = spec.n_gpus
    if not hierarchical:
        return [np.arange(n, dtype=np.int64)]
    gpn = spec.gpus_per_node
    tiers = spec.node_tiers if spec.node_tiers else (0,) * spec.n_nodes
    cap = max(1, max_island_gpus // gpn)
    islands: List[np.ndarray] = []
    for t in sorted(set(tiers)):
        nodes = [u for u, tu in enumerate(tiers) if tu == t]
        for s in range(0, len(nodes), cap):
            islands.append(np.concatenate(
                [np.arange(u * gpn, (u + 1) * gpn, dtype=np.int64)
                 for u in nodes[s:s + cap]]))
    merged: List[np.ndarray] = []
    for isl in islands:
        if merged and (len(isl) < 2 or len(merged[-1]) < 2):
            merged[-1] = np.concatenate([merged[-1], isl])
        else:
            merged.append(isl)
    return merged


def coarse_orderings(islands: List[np.ndarray],
                     spec: ClusterSpec) -> List[Tuple[int, ...]]:
    """Candidate whole-island arrangements for the coarse solve.

    Identity, plus the islands sorted by their max member compute slowdown
    ascending and descending (on tiered fleets, putting same-speed islands
    into the same pipeline stages is the dominant coarse decision — the
    per-stage straggler term of Eq. 4).  Deduplicated; identity only for a
    single island.
    """
    k = len(islands)
    if k == 1:
        return [(0,)]
    slow = compute_slowdowns(spec)
    key = ([0.0] * k if slow is None
           else [float(slow[isl].max()) for isl in islands])
    cands = [tuple(range(k)),
             tuple(sorted(range(k), key=lambda i: (key[i], i))),
             tuple(sorted(range(k), key=lambda i: (-key[i], i)))]
    out: List[Tuple[int, ...]] = []
    for o in cands:
        if o not in out:
            out.append(o)
    return out


def coarse_assign(engine, islands: List[np.ndarray],
                  orderings: List[Tuple[int, ...]]):
    """Pick the best whole-island arrangement for one candidate conf.

    Scores each candidate ordering with ``engine.score`` — each backend
    uses its own scorer here (the NumPy engine, or a
    :class:`_JaxCandScorer` wrapping the shared JAX engine); the scores
    are bit-identical on CPU, so both backends pick identical initial
    permutations — and keeps the strictly-best, first wins on ties.

    Returns:
        ``(init_perm, offsets, value)`` — the coarse permutation, the
        position offset of each island under the chosen ordering
        (``offsets[i] + local`` maps an island-relative draw to an
        absolute position), and the coarse score.
    """
    best = None
    for o in orderings:
        perm = np.concatenate([islands[i] for i in o])
        val = engine.score(perm)
        if best is None or val < best[0]:
            best = (val, perm, o)
    val, perm, order = best
    offsets = np.zeros(len(islands), dtype=np.int64)
    pos = 0
    for i in order:
        offsets[i] = pos
        pos += len(islands[i])
    return perm, offsets, val


# ---------------------------------------------------------------------------
# NumPy execution of a MovePlan
# ---------------------------------------------------------------------------

def _move_numpy(perm: np.ndarray, kind: int, pa: int,
                pb: int) -> Tuple[np.ndarray, np.ndarray]:
    """Apply one scheduled move; returns ``(new_perm, touched)``.

    Shared semantics with ``jax_engine._apply_move`` (see there): with
    ``i = min(pa, pb) < j = max(pa, pb)`` — migration (0) removes the
    element at ``i`` and reinserts it at ``j``, swap (1) exchanges ``i``
    and ``j``, reverse (2) reverses ``[i, j]``.
    """
    i, j = (pa, pb) if pa < pb else (pb, pa)
    p = perm.copy()
    if kind == 0:
        el = p[i]
        p[i:j] = p[i + 1:j + 1].copy()
        p[j] = el
        touched = np.arange(i, j + 1)
    elif kind == 1:
        p[i], p[j] = p[j], p[i]
        touched = np.array((i, j))
    else:
        p[i:j + 1] = p[i:j + 1][::-1]
        touched = np.arange(i, j + 1)
    return p, touched


def _run_chain_numpy(engine: DedicationEngine, init_perm: np.ndarray,
                     offsets: np.ndarray, plan: MovePlan, k: int,
                     alpha: float):
    """Execute chain ``k`` of ``plan`` with the incremental NumPy engine.

    Bit-for-bit the computation ``JaxDedicationEngine.anneal`` performs for
    the same chain: same probes, same ``temp0 = max(max|delta|,
    cur*1e-3, 1e-12)``, same accept rule ``delta <= 0 or
    delta < temp * thresh``, same best-so-far tracking.
    """
    iters_k = int(plan.chain_iters[k])
    perm = init_perm.copy()
    cur = engine.score(perm)
    best, best_perm = cur, perm.copy()
    if iters_k == 0:        # zero-budget chain: init score only
        return best, best_perm, 0, 0, 0
    mx = 0.0
    for p in range(plan.n_probes):
        off = offsets[plan.probe_isl[k, p]]
        cand, touched = _move_numpy(perm, int(plan.probe_kind[k, p]),
                                    int(off + plan.probe_oa[k, p]),
                                    int(off + plan.probe_ob[k, p]))
        val, _ = engine.propose(cand, touched)
        mx = max(mx, abs(val - cur))
    temp = max(mx, cur * 1e-3, 1e-12)
    acc = acc_best = 0
    for t in range(iters_k):
        off = offsets[plan.isl[k, t]]
        cand, touched = _move_numpy(perm, int(plan.kind[k, t]),
                                    int(off + plan.oa[k, t]),
                                    int(off + plan.ob[k, t]))
        val, pending = engine.propose(cand, touched)
        delta = val - cur
        if delta <= 0 or delta < temp * plan.thresh[k, t]:
            perm, cur = cand, val
            engine.commit(pending)
            acc += 1
            if cur < best:
                best, best_perm = cur, perm.copy()
                acc_best = acc
        temp *= alpha
    return best, best_perm, iters_k, acc, acc_best


# ---------------------------------------------------------------------------
# the unified driver
# ---------------------------------------------------------------------------

class _JaxCandScorer:
    """``coarse_assign``-compatible view of one candidate of a
    :class:`~repro.core.jax_engine.JaxDedicationEngine` — lets the jax
    backend solve the coarse arrangement without ever building the NumPy
    engines (whose O(G^2) setup would dwarf the SA itself at 10k GPUs)."""

    def __init__(self, jeng, cand: int):
        self._jeng, self._cand = jeng, cand

    def score(self, perm: np.ndarray) -> float:
        return self._jeng.score(perm, self._cand)


def _abs_positions(plan: MovePlan, offsets: np.ndarray):
    """Island-relative draws -> absolute positions for one candidate's
    coarse island ordering: ``(pas, pbs, probe_pas, probe_pbs)``."""
    pas = offsets[plan.isl] + plan.oa
    pbs = offsets[plan.isl] + plan.ob
    ppas = offsets[plan.probe_isl] + plan.probe_oa
    ppbs = offsets[plan.probe_isl] + plan.probe_ob
    return pas, pbs, ppas, ppbs


def dedicate_candidates(survivors: Sequence[Conf],
                        profiles: Sequence[Profile],
                        sa_idx: Sequence[int], bw: np.ndarray,
                        spec: ClusterSpec, budget, seed: int, *,
                        compute_aware: bool = True,
                        kernels: str = "auto") -> Dict[int, SAResult]:
    """Stage-5 dedication through the unified backend-selectable core.

    Runs SA dedication for the survivor indices in ``sa_idx`` and returns
    ``{index: SAResult}``.  Candidates are grouped by (pp, tp, cp, dp, vpp)
    shape; the ``"jax"`` backend advances every chain of every candidate
    in a group with one vmapped dispatch, the ``"numpy"`` backend loops —
    both execute the identical :class:`MovePlan`, so results are
    byte-identical (see module docstring).

    ``budget.sa_seconds`` is a per-candidate wall-clock guard on the NumPy
    backend (chains still pending when it expires contribute the coarse
    permutation's score, like the historical driver); the JAX backend is
    iteration-bound only — a single dispatch cannot be interrupted — so
    byte-parity across backends holds whenever the time guard does not
    bite (use iteration-bound budgets for reproducible plans, as the
    golden tests do).

    ``budget.warm_start`` (a flat GPU permutation, e.g. recovered from a
    cached neighbour Plan via :func:`~repro.core.dedication.
    mapping_to_perm`) seeds every candidate's chains from the incumbent
    arrangement instead of the coarse assignment whenever the incumbent
    scores strictly better — the same comparison on both backends (their
    scorers are bit-identical), so warm-started plans keep byte parity
    too.  SA tracks best-so-far from the chosen init, so a warm-started
    candidate can never score worse than its seed permutation.
    """
    backend = budget.backend
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unified driver needs backend numpy|jax, "
                         f"got {backend!r}")
    hier = budget.hierarchical
    if hier is None:
        hier = spec.n_gpus >= HIER_AUTO_GPUS
    islands = build_islands(spec, hierarchical=hier)
    plan = make_move_plan([len(i) for i in islands], budget.sa_iters,
                          budget.n_chains, seed)
    orderings = coarse_orderings(islands, spec)
    warm = getattr(budget, "warm_start", None)
    warm_perm = (None if warm is None
                 else np.asarray(warm, dtype=np.int64))

    def pick_init(scorer, coarse):
        """Coarse assignment vs warm incumbent — strictly-better wins,
        coarse on ties (identical branch on both backends)."""
        init_perm, offsets, cval = coarse
        if warm_perm is not None:
            wval = scorer.score(warm_perm)
            if wval < cval:
                return warm_perm, offsets, wval
        return init_perm, offsets, cval

    # vpp joins the shape key: vpp variants of one (pp, tp, cp, dp) carry
    # different stage_work/partition profiles, which the engines share
    # per group
    groups: Dict[Tuple[int, int, int, int, int], List[int]] = {}
    for i in sa_idx:
        c = survivors[i]
        groups.setdefault((c.pp, c.tp, c.cp, c.dp, c.vpp), []).append(i)

    # The O(G^2) pair matrices depend only on (bw, spec): build them once
    # and share across every engine of every shape group (the jax groups
    # additionally share the big device buffers via ``device_pairs``).
    pairs = PairCache.build(bw, spec.gpus_per_node)
    device_pairs = None

    results: Dict[int, SAResult] = {}
    for shape, idxs in groups.items():
        t0 = time.perf_counter()
        if backend == "jax":
            from .jax_engine import JaxDedicationEngine
            jeng = JaxDedicationEngine([survivors[i] for i in idxs],
                                       [profiles[i] for i in idxs], bw,
                                       spec, kernels=kernels,
                                       compute_aware=compute_aware,
                                       pairs=pairs,
                                       device_pairs=device_pairs)
            device_pairs = jeng.device_pairs
            coarse = {i: pick_init(_JaxCandScorer(jeng, ci),
                                   coarse_assign(_JaxCandScorer(jeng, ci),
                                                 islands, orderings))
                      for ci, i in enumerate(idxs)}
            init = np.stack([coarse[i][0] for i in idxs])
            abs_pos = [_abs_positions(plan, coarse[i][1]) for i in idxs]
            pas = np.stack([a[0] for a in abs_pos])
            pbs = np.stack([a[1] for a in abs_pos])
            ppas = np.stack([a[2] for a in abs_pos])
            ppbs = np.stack([a[3] for a in abs_pos])
            bests, best_perms, _, accs, accbs = jeng.anneal(
                init, pas, pbs, plan.kind, plan.thresh, plan.valid,
                ppas, ppbs, plan.probe_kind, alpha=_ALPHA)
            elapsed = time.perf_counter() - t0
            iters = int(plan.chain_iters.sum())
            for ci, i in enumerate(idxs):
                lats = [float(v) for v in bests[ci]]
                win = int(np.argmin(lats))     # strict <, first occurrence
                results[i] = _to_result(survivors[i], best_perms[ci][win],
                                        lats[win], coarse[i][2], iters,
                                        elapsed / len(idxs), lats,
                                        int(accs[ci].sum()),
                                        int(accbs[ci][win]))
        else:
            gidx = GroupIndex.build(survivors[idxs[0]])
            engines = {i: DedicationEngine(survivors[i], bw, profiles[i],
                                           spec, index=gidx,
                                           compute_aware=compute_aware,
                                           pairs=pairs)
                       for i in idxs}
            coarse = {i: pick_init(engines[i],
                                   coarse_assign(engines[i], islands,
                                                 orderings))
                      for i in idxs}
            for i in idxs:
                tc = time.perf_counter()
                deadline = tc + budget.sa_seconds
                init_perm, offsets, cval = coarse[i]
                lats, perms, iters, accs, accbs = [], [], 0, [], []
                for k in range(plan.n_chains):
                    if time.perf_counter() >= deadline and lats:
                        break                  # out of wall-clock budget
                    b, p, it, ac, ab = _run_chain_numpy(
                        engines[i], init_perm, offsets, plan, k, _ALPHA)
                    lats.append(b)
                    perms.append(p)
                    iters += it
                    accs.append(ac)
                    accbs.append(ab)
                win = int(np.argmin(lats))
                results[i] = _to_result(survivors[i], perms[win],
                                        float(lats[win]), cval, iters,
                                        time.perf_counter() - tc,
                                        [float(v) for v in lats],
                                        sum(accs), accbs[win])  # repro: noqa DET004 -- accepted-move counters are ints; integer addition is order-independent
    return results


def _to_result(conf: Conf, perm: np.ndarray, latency: float, coarse: float,
               iters: int, seconds: float, chain_lats: List[float],
               accepted: int = 0, accepted_to_best: int = 0) -> SAResult:
    perm = np.asarray(perm, dtype=np.int64)
    return SAResult(perm_to_mapping(perm, conf), perm, latency, iters,
                    seconds, trace=[(0, float(coarse)), (iters, latency)],
                    chain_latencies=(chain_lats if len(chain_lats) > 1
                                     else None),
                    accepted=accepted, accepted_to_best=accepted_to_best)
