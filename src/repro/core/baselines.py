"""Baseline configurators: AMP [8], Varuna [12], and the Megatron-LM
manual heuristic [14] — as characterised in the paper's evaluation.

All three deliberately search the 3D (pp, tp, dp) space only: none of the
prior art models context parallelism, which is exactly the comparison point
for Pipette's 4D search (``configure(max_cp > 1)``) on long-context
workloads.  They do share the schedule-validity gate (``n_mb >= pp``) —
a config 1F1B cannot fill would be rejected on any real cluster.

Behind the Planner API these functions are re-homed as strategies
(:class:`~repro.core.plan.AMPStrategy`, ``VarunaStrategy``,
``MegatronStrategy``) so all four configurators run behind the single
``Planner(strategy).plan(request, bw)`` interface."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from .cluster import ClusterSpec
from .latency import amp_latency, varuna_latency
from .memory import enumerate_confs, ground_truth_memory
from .search import Candidate, Overhead, SearchResult
from .simulator import Workload, build_profile, default_mapping, measure


def amp_configure(w: Workload, spec: ClusterSpec, *, max_micro: int = 16) -> SearchResult:
    """AMP: Eq. 1 latency model, nominal bandwidths, memory-unaware,
    identity GPU assignment.

    Args:
        w: workload (model config, sequence length, global batch).
        spec: cluster description (nominal bandwidths only are used).
        max_micro: skip configurations with ``bs_micro`` above this.

    Returns:
        :class:`~repro.core.search.SearchResult` ranked by Eq. 1 latency
        (``mem_pred`` is ``nan`` — AMP does not model memory).
    """
    t0 = time.perf_counter()
    cands = []
    n_enum = 0
    for conf in enumerate_confs(spec.n_gpus, w.bs_global, n_layers=w.cfg.n_layers):
        n_enum += 1
        if conf.bs_micro > max_micro:
            continue
        prof = build_profile(w, spec, conf)
        lat = amp_latency(conf, default_mapping(conf), spec, prof)
        cands.append(Candidate(conf, default_mapping(conf), lat, float("nan")))
    cands.sort(key=lambda c: c.latency)
    return SearchResult(best=cands[0] if cands else None, ranked=cands,
                        overhead=Overhead(total_s=time.perf_counter() - t0,
                                          n_enumerated=n_enum,
                                          n_candidates=len(cands)))


def varuna_configure(w: Workload, spec: ClusterSpec, *, max_micro: int = 16) -> SearchResult:
    """Varuna: pipeline+data parallelism only (tp = 1), memory-unaware.

    Args:
        w: workload (model config, sequence length, global batch).
        spec: cluster description (nominal bandwidths only are used).
        max_micro: skip configurations with ``bs_micro`` above this.

    Returns:
        :class:`~repro.core.search.SearchResult` ranked by the Varuna-style
        estimate (``mem_pred`` is ``nan``).
    """
    t0 = time.perf_counter()
    cands = []
    n_enum = 0
    for conf in enumerate_confs(spec.n_gpus, w.bs_global, n_layers=w.cfg.n_layers):
        n_enum += 1
        if conf.tp != 1 or conf.bs_micro > max_micro:
            continue
        prof = build_profile(w, spec, conf)
        lat = varuna_latency(conf, spec, prof)
        cands.append(Candidate(conf, default_mapping(conf), lat, float("nan")))
    cands.sort(key=lambda c: c.latency)
    return SearchResult(best=cands[0] if cands else None, ranked=cands,
                        overhead=Overhead(total_s=time.perf_counter() - t0,
                                          n_enumerated=n_enum,
                                          n_candidates=len(cands)))


def mlm_configure(w: Workload, spec: ClusterSpec, bw_true: np.ndarray, *,
                  max_micro: int = 16, trials: int = 6,
                  seed: int = 0) -> SearchResult:
    """Megatron-LM manual tuning: tp = gpus-per-node, then try promising
    (pp, mb) combinations one by one on the cluster (here: the simulator)
    until the fastest runnable one is found — i.e. actual manual labour,
    memory-checked by construction.

    Args:
        w: workload (model config, sequence length, global batch).
        spec: cluster description.
        bw_true: ground-truth bandwidth matrix the trial runs execute on.
        max_micro: skip configurations with ``bs_micro`` above this.
        trials: how many promising configs the "expert" actually runs.
        seed: simulator seed for the trial runs.

    Returns:
        :class:`~repro.core.search.SearchResult` over the tried configs,
        ranked by *measured* (simulated) iteration time.
    """
    t0 = time.perf_counter()
    tp = spec.gpus_per_node
    cands: List[Candidate] = []
    n_enum = 0
    for conf in enumerate_confs(spec.n_gpus, w.bs_global, max_tp=tp,
                                n_layers=w.cfg.n_layers):
        n_enum += 1
        if conf.tp != tp or conf.bs_micro > max_micro:
            continue
        # the trial run is physical: on a tiered fleet it OOMs as soon as
        # the *smallest* GPU overflows (mem_floor == gpu_mem when
        # homogeneous); the heuristic itself stays compute-blind
        if ground_truth_memory(w, conf, spec) > spec.mem_floor:
            continue                      # a human discards the OOM run
        cands.append(Candidate(conf, default_mapping(conf), float("inf"),
                               float("nan")))
    # the expert tries the most promising handful, smallest pp first
    cands.sort(key=lambda c: (c.conf.pp, -c.conf.bs_micro))
    tried = cands[:trials]
    for c in tried:
        c.latency = measure(c.conf, c.mapping, w, spec, bw_true, seed=seed)
    tried.sort(key=lambda c: c.latency)
    return SearchResult(best=tried[0] if tried else None, ranked=tried,
                        overhead=Overhead(total_s=time.perf_counter() - t0,
                                          n_enumerated=n_enum,
                                          n_candidates=len(tried)))
