"""Cluster description, heterogeneous bandwidth matrices and profiling.

The paper's key observation (§IV, Fig. 3) is that attained link bandwidth in
real clusters is heterogeneous and drifts over time, even when nominal specs
are identical.  On real hardware ``profile_bandwidth`` would time p2p
transfers (the JAX analogue of NCCL-tests / mpiGraph); in this CPU container
we generate *measured-like* matrices whose spread is calibrated to Fig. 3
(≈2-3x between slowest and fastest inter-node pairs, near-symmetric
bidirectional rates, day-to-day drift).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ClusterSpec:
    name: str
    n_nodes: int
    gpus_per_node: int = 8
    intra_bw: float = 300e9          # bytes/s (NVLink)
    inter_bw: float = 12.5e9         # bytes/s (IB EDR 100 Gb/s)
    gpu_flops: float = 112e12        # attainable tensor FLOP/s
    gpu_mem: float = 32e9            # bytes
    efficiency: float = 0.45         # fraction of peak reached by GEMMs
    heterogeneity: float = 0.28      # lognormal sigma of inter-node factors
    slow_frac: float = 0.08          # fraction of node pairs that straggle
    seed: int = 0

    @property
    def n_gpus(self) -> int:
        return self.n_nodes * self.gpus_per_node

    def node_of(self, g: int) -> int:
        return g // self.gpus_per_node

    def with_nodes(self, n: int) -> "ClusterSpec":
        return dataclasses.replace(self, n_nodes=n)


# The paper's two evaluation environments (Table I).
MID_RANGE = ClusterSpec("mid-range", n_nodes=16, intra_bw=300e9,
                        inter_bw=12.5e9, gpu_flops=112e12, gpu_mem=32e9,
                        seed=11)
HIGH_END = ClusterSpec("high-end", n_nodes=16, intra_bw=600e9,
                       inter_bw=25e9, gpu_flops=280e12, gpu_mem=80e9,
                       seed=23)

# TPU-pod flavoured cluster: "nodes" are ICI neighbourhoods, the inter-node
# tier is the slower multi-hop/DCN path (DESIGN.md §2 hardware adaptation).
TPU_POD = ClusterSpec("tpu-v5e-pod", n_nodes=16, gpus_per_node=16,
                      intra_bw=50e9, inter_bw=25e9, gpu_flops=197e12,
                      gpu_mem=16e9, efficiency=0.55, seed=31)


def true_bandwidth_matrix(spec: ClusterSpec, day: int = 0) -> np.ndarray:
    """Ground-truth attained bandwidth (bytes/s) between every GPU pair.

    Inter-node factors are near-symmetric lognormals with a straggler tail;
    intra-node links jitter mildly.  ``day`` shifts the realisation to model
    the temporal drift of Fig. 3.

    Args:
        spec: cluster description (sizes, nominal bandwidths, heterogeneity).
        day: realisation index modelling day-to-day drift.

    Returns:
        ``(n_gpus, n_gpus)`` bytes/s matrix; the diagonal (self-transfer) is
        effectively free.
    """
    rng = np.random.default_rng(spec.seed * 1000003 + day)
    g = spec.n_gpus
    nn = spec.n_nodes
    # per-node-pair factor
    f = np.exp(rng.normal(0.0, spec.heterogeneity, (nn, nn)))
    f = np.clip(f, 0.35, 1.15)
    slow = rng.random((nn, nn)) < spec.slow_frac
    f = np.where(slow, f * 0.5, f)
    f = np.minimum(f, f.T * rng.uniform(0.96, 1.04, (nn, nn)))  # ~symmetric
    np.fill_diagonal(f, 1.0)

    bw = np.empty((g, g))
    node = np.arange(g) // spec.gpus_per_node
    same = node[:, None] == node[None, :]
    intra_jit = rng.uniform(0.92, 1.0, (g, g))
    bw = np.where(same, spec.intra_bw * intra_jit,
                  spec.inter_bw * f[node[:, None], node[None, :]])
    np.fill_diagonal(bw, spec.intra_bw * 4)     # self: effectively free
    return bw


def profile_bandwidth(spec: ClusterSpec, day: int = 0,
                      noise: float = 0.01) -> tuple[np.ndarray, float]:
    """'network_profile()' of Algorithm 1 line 1.

    Args:
        spec: cluster description.
        day: realisation index (see :func:`true_bandwidth_matrix`).
        noise: relative measurement noise (~1% default).

    Returns:
        ``(measured_matrix, profiling_wall_seconds)``.  The cost model is
        calibrated to the paper's Table II (58 s @ 8 nodes, 239 s @ 16
        nodes — all-pairs mpiGraph grows with n_nodes^2).
    """
    rng = np.random.default_rng(spec.seed * 7919 + day + 1)
    truth = true_bandwidth_matrix(spec, day)
    measured = truth * rng.normal(1.0, noise, truth.shape)
    cost_s = 0.934 * spec.n_nodes ** 2
    return measured, cost_s


def profile_bandwidth_live(devices=None, msg_bytes: int = 1 << 20) -> np.ndarray:
    """Actually time device-to-device transfers with JAX (for real clusters).

    On a single-host CPU container this degenerates to one device; it exists
    so the profiling interface is exercised end-to-end in tests.
    """
    import jax
    import jax.numpy as jnp

    devices = devices or jax.devices()
    n = len(devices)
    x = jnp.ones((msg_bytes // 4,), jnp.float32)
    bw = np.zeros((n, n))
    for i, di in enumerate(devices):
        xi = jax.device_put(x, di)
        xi.block_until_ready()
        for j, dj in enumerate(devices):
            if i == j:
                bw[i, j] = float("inf")
                continue
            t0 = time.perf_counter()
            y = jax.device_put(xi, dj)
            y.block_until_ready()
            dt = max(time.perf_counter() - t0, 1e-9)
            bw[i, j] = msg_bytes / dt
    return bw


def ring_allreduce_time(msg_bytes: float, group_bw: float, n: int,
                        phases: int = 2) -> float:
    """Thakur et al. ring all-reduce: phases * (n-1)/n * msg / bw.

    Args:
        msg_bytes: bytes contributed by each rank.
        group_bw: bottleneck link bandwidth of the ring, bytes/s.  Must be
            finite and positive for real rings (``n > 1``): the ``inf``
            that :func:`min_group_bw` returns for singleton groups would
            otherwise silently price a 0-second collective for a ring that
            supposedly spans multiple GPUs.
        n: ring size.  ``n == 1`` (and 0) is an explicit early-out: a
            single rank performs no communication, so the result is exactly
            0.0 *before* ``group_bw`` is touched — pairing this with a
            singleton :func:`min_group_bw` (``inf``) is therefore safe.
        phases: 2 for reduce-scatter + all-gather over one message pass,
            4 for the hierarchical intra-node stage.

    Returns:
        Seconds for the collective.

    Raises:
        ValueError: ``n > 1`` with a non-finite or non-positive
            ``group_bw`` (a singleton-group bandwidth leaking into a real
            ring).
    """
    if n <= 1:
        return 0.0
    if not np.isfinite(group_bw) or group_bw <= 0:
        raise ValueError(
            f"ring of {n} ranks needs a finite positive bottleneck "
            f"bandwidth, got {group_bw!r} (singleton-group inf leaking in?)")
    return phases * (n - 1) / n * msg_bytes / group_bw


def min_group_bw(bw: np.ndarray, gpus) -> float:
    """Slowest pairwise link inside a communicator group (Eq. 6 denominator).

    Args:
        bw: ``(G, G)`` bandwidth matrix in bytes/s.
        gpus: iterable of GPU indices forming the group.

    Returns:
        Minimum off-diagonal entry of the group's bandwidth submatrix
        (both directions considered); ``inf`` for groups of size <= 1 — a
        singleton has no links, and ``inf`` makes downstream guards
        explicit.  Callers must special-case that ``inf``: the latency
        scalers (``_tp_scale``/``_cp_scale``) treat non-finite group
        bandwidth as scale 1.0, and :func:`ring_allreduce_time` never sees
        it because its ``n <= 1`` early-out fires first (it raises if a
        non-finite bandwidth reaches a real ring).
    """
    gpus = list(gpus)
    if len(gpus) <= 1:
        return float("inf")
    sub = bw[np.ix_(gpus, gpus)].copy()
    np.fill_diagonal(sub, np.inf)
    return float(sub.min())


def min_group_bw_batch(bw: np.ndarray, groups: np.ndarray) -> np.ndarray:
    """Batched :func:`min_group_bw`: slowest intra-group link per group.

    Args:
        bw: ``(G, G)`` bandwidth matrix in bytes/s.
        groups: ``(n_groups, m)`` integer array of GPU ids, one group per row.

    Returns:
        ``(n_groups,)`` array of the minimum off-diagonal submatrix entry per
        group (``inf`` when ``m <= 1``).  Bit-identical to calling
        :func:`min_group_bw` row by row.
    """
    ids = np.asarray(groups, dtype=np.intp)
    n_groups, m = ids.shape
    if m <= 1:
        return np.full(n_groups, np.inf)
    sub = bw[ids[:, :, None], ids[:, None, :]]
    eye = np.eye(m, dtype=bool)
    return np.where(eye[None, :, :], np.inf, sub).min(axis=(1, 2))
