"""Cluster description, heterogeneous bandwidth matrices and profiling.

The paper's key observation (§IV, Fig. 3) is that attained link bandwidth in
real clusters is heterogeneous and drifts over time, even when nominal specs
are identical.  On real hardware ``profile_bandwidth`` would time p2p
transfers (the JAX analogue of NCCL-tests / mpiGraph); in this CPU container
we generate *measured-like* matrices whose spread is calibrated to Fig. 3
(≈2-3x between slowest and fastest inter-node pairs, near-symmetric
bidirectional rates, day-to-day drift).
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import time
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class DeviceTier:
    """One device class in a heterogeneous fleet.

    A tier is an *absolute* description (attainable FLOP/s, memory bytes,
    GEMM efficiency) of one GPU generation / health state — e.g. the A100
    and V100 tiers of a mixed fleet, or the "healthy" and "degraded" tiers
    of a partially-throttled cluster.  Nodes are whole-tier: every GPU on a
    node belongs to the node's tier (mixed fleets are procured per node,
    and a thermally-degraded host throttles all of its GPUs).

    Attributes:
        flops: attainable tensor FLOP/s of one GPU of this tier.
        mem: device memory in bytes.
        efficiency: fraction of ``flops`` reached by real GEMMs.
        name: label for provenance / reports ("a100", "degraded", ...).
    """
    flops: float
    mem: float
    efficiency: float = 0.45
    name: str = ""

    def __post_init__(self):
        if not (self.flops > 0 and self.mem > 0 and 0 < self.efficiency <= 1):
            raise ValueError(
                f"DeviceTier needs flops > 0, mem > 0, 0 < efficiency <= 1; "
                f"got flops={self.flops!r}, mem={self.mem!r}, "
                f"efficiency={self.efficiency!r}")

    @property
    def throughput(self) -> float:
        """Attained GEMM throughput (``flops * efficiency``), FLOP/s."""
        return self.flops * self.efficiency


@dataclass(frozen=True)
class ClusterSpec:
    """Cluster description: sizes, interconnect, and per-GPU compute/memory.

    The scalar ``gpu_flops`` / ``gpu_mem`` / ``efficiency`` fields describe
    a *homogeneous* fleet — and double as the **reference device** (the one
    profiling runs on) when the optional tier table is set.  Heterogeneous
    compute is expressed with ``tiers`` (a table of :class:`DeviceTier`)
    plus ``node_tiers`` (one tier index per node); the seeded generators
    :func:`mixed_fleet_spec` and :func:`degraded_host_spec` build such
    specs with the reference scalars pinned to the fastest tier, so
    per-GPU slowdowns are >= 1.  A spec whose tiers all match the reference
    scalars is *indistinguishable* from a scalar spec everywhere
    (:func:`compute_slowdowns` returns ``None`` and every consumer takes
    the historical bit-exact path).

    All fields are validated on construction — a bad spec fails here with
    a named field, not deep inside the bandwidth generator.
    """
    name: str
    n_nodes: int
    gpus_per_node: int = 8
    intra_bw: float = 300e9          # bytes/s (NVLink)
    inter_bw: float = 12.5e9         # bytes/s (IB EDR 100 Gb/s)
    gpu_flops: float = 112e12        # attainable tensor FLOP/s
    gpu_mem: float = 32e9            # bytes
    efficiency: float = 0.45         # fraction of peak reached by GEMMs
    heterogeneity: float = 0.28      # lognormal sigma of inter-node factors
    slow_frac: float = 0.08          # fraction of node pairs that straggle
    seed: int = 0
    # --- heterogeneous compute (empty = homogeneous, the historical case) ---
    tiers: Tuple[DeviceTier, ...] = ()
    node_tiers: Tuple[int, ...] = ()   # node -> index into ``tiers``

    def __post_init__(self):
        # normalise list inputs so the spec stays hashable
        if not isinstance(self.tiers, tuple):
            object.__setattr__(self, "tiers", tuple(self.tiers))
        if not isinstance(self.node_tiers, tuple):
            object.__setattr__(self, "node_tiers", tuple(self.node_tiers))
        if self.n_nodes < 1:
            raise ValueError(f"n_nodes must be >= 1, got {self.n_nodes}")
        if self.gpus_per_node < 1:
            raise ValueError(
                f"gpus_per_node must be >= 1, got {self.gpus_per_node}")
        for field in ("intra_bw", "inter_bw", "gpu_flops", "gpu_mem"):
            v = getattr(self, field)
            if not v > 0:
                raise ValueError(f"{field} must be > 0, got {v!r}")
        if not 0 < self.efficiency <= 1:
            raise ValueError(
                f"efficiency must be in (0, 1], got {self.efficiency!r}")
        if self.heterogeneity < 0 or not 0 <= self.slow_frac <= 1:
            raise ValueError(
                "heterogeneity must be >= 0 and slow_frac in [0, 1]; got "
                f"heterogeneity={self.heterogeneity!r}, "
                f"slow_frac={self.slow_frac!r}")
        if bool(self.tiers) != bool(self.node_tiers):
            raise ValueError(
                "tiers and node_tiers must be given together (a tier table "
                "without a node assignment, or vice versa, is ambiguous)")
        if self.tiers:
            if len(self.node_tiers) != self.n_nodes:
                raise ValueError(
                    f"node_tiers must assign every node: expected "
                    f"{self.n_nodes} entries, got {len(self.node_tiers)}")
            bad = [t for t in self.node_tiers
                   if not 0 <= int(t) < len(self.tiers)]
            if bad:
                raise ValueError(
                    f"node_tiers out of range [0, {len(self.tiers)}): {bad}")

    @property
    def n_gpus(self) -> int:
        return self.n_nodes * self.gpus_per_node

    def node_of(self, g: int) -> int:
        return g // self.gpus_per_node

    def with_nodes(self, n: int) -> "ClusterSpec":
        """Resize to ``n`` nodes.  A tiered spec keeps its tier *pattern*:
        the node -> tier assignment is truncated when shrinking and cycled
        when growing (so a half-A100/half-V100 fleet stays mixed on both
        the shrink and the grow path — a joined node inherits the tier the
        pattern assigns to its slot)."""
        nt = self.node_tiers
        if self.tiers:
            reps = -(-n // len(nt))
            nt = (nt * reps)[:n]
        return dataclasses.replace(self, n_nodes=n, node_tiers=nt)

    def with_node_subset(self, nodes: Sequence[int]) -> "ClusterSpec":
        """The spec containing exactly ``nodes`` (ids in *this* spec), in
        the given order.

        This is the event-stream mutation behind churn simulation:
        preempting node 3 of 16 keeps nodes ``[0..2, 4..15]`` *with their
        own tiers* — unlike :meth:`with_nodes`, which models a planned
        resize by truncating/extending the tier pattern.  A returning node
        re-enters by reappearing in ``nodes``.

        Args:
            nodes: surviving node ids — non-empty, unique, each in
                ``[0, n_nodes)``.

        Returns:
            A validated spec with ``len(nodes)`` nodes; node ``i`` of the
            result is node ``nodes[i]`` of ``self`` (tier kept).
        """
        nodes = [int(i) for i in nodes]
        if not nodes:
            raise ValueError("with_node_subset needs at least one node")
        bad = [i for i in nodes if not 0 <= i < self.n_nodes]
        if bad:
            raise ValueError(
                f"node ids out of range [0, {self.n_nodes}): {bad}")
        if len(set(nodes)) != len(nodes):
            raise ValueError(f"duplicate node ids: {nodes}")
        nt = self.node_tiers
        if self.tiers:
            nt = tuple(self.node_tiers[i] for i in nodes)
        return dataclasses.replace(self, n_nodes=len(nodes), node_tiers=nt)

    def with_compute_factors(self,
                             factors: Sequence[float]) -> "ClusterSpec":
        """Scale each node's compute by a factor (stragglers / throttling).

        Node ``i``'s attainable FLOP/s is multiplied by ``factors[i]``
        (``1.0`` = healthy; a 0.5 straggler runs at half speed).  The
        result is a tiered spec whose tier table holds one entry per
        distinct (base tier, factor) pair — the reference scalars are
        untouched, so per-GPU slowdowns stay >= 1 for factors <= 1.  All
        factors exactly 1.0 return ``self`` unchanged (the bit-exact
        scalar path for compute-uniform fleets).
        """
        factors = [float(f) for f in factors]
        if len(factors) != self.n_nodes:
            raise ValueError(
                f"need one factor per node: expected {self.n_nodes}, "
                f"got {len(factors)}")
        if any(not f > 0 for f in factors):
            raise ValueError(f"factors must be > 0, got {factors}")
        if all(f == 1.0 for f in factors):  # repro: noqa DET005 -- 1.0 is the exact "healthy, untouched" sentinel callers pass literally; only that exact value may take the unchanged-spec path
            return self
        table: list = []
        index: dict = {}
        node_tiers = []
        for i, f in enumerate(factors):
            base = self.tiers[self.node_tiers[i]] if self.tiers else \
                DeviceTier(self.gpu_flops, self.gpu_mem, self.efficiency,
                           name="base")
            key = (base.flops, base.mem, base.efficiency, base.name, f)
            t = index.get(key)
            if t is None:
                t = index[key] = len(table)
                healthy = f == 1.0  # repro: noqa DET005 -- 1.0 is the exact healthy sentinel (see above); factor-1 nodes keep the base tier name
                name = base.name if healthy else \
                    f"{base.name or 'base'}*{f:g}"
                table.append(DeviceTier(base.flops * f, base.mem,
                                        base.efficiency, name=name))
            node_tiers.append(t)
        return dataclasses.replace(self, tiers=tuple(table),
                                   node_tiers=tuple(node_tiers))

    def node_gpus(self, node: int) -> Tuple[int, ...]:
        """The flat GPU ids hosted on ``node``."""
        lo = node * self.gpus_per_node
        return tuple(range(lo, lo + self.gpus_per_node))

    # -- per-GPU device views (scalar-backed when no tiers are set) --------

    @property
    def has_tiers(self) -> bool:
        return bool(self.tiers)

    def tier_of(self, g: int) -> DeviceTier:
        """The :class:`DeviceTier` of GPU ``g`` (a scalar-backed pseudo-tier
        for homogeneous specs)."""
        if not self.tiers:
            return DeviceTier(self.gpu_flops, self.gpu_mem, self.efficiency)
        return self.tiers[self.node_tiers[self.node_of(g)]]

    def _per_gpu(self, values: Sequence[float], scalar: float) -> np.ndarray:
        if not self.tiers:
            return np.full(self.n_gpus, scalar)
        per_node = np.asarray(values)[np.asarray(self.node_tiers, np.intp)]
        return np.repeat(per_node, self.gpus_per_node)

    def per_gpu_flops(self) -> np.ndarray:
        """``(n_gpus,)`` attainable FLOP/s per GPU."""
        return self._per_gpu([t.flops for t in self.tiers], self.gpu_flops)

    def per_gpu_mem(self) -> np.ndarray:
        """``(n_gpus,)`` device-memory bytes per GPU."""
        return self._per_gpu([t.mem for t in self.tiers], self.gpu_mem)

    def per_gpu_throughput(self) -> np.ndarray:
        """``(n_gpus,)`` attained GEMM FLOP/s (``flops * efficiency``)."""
        return self._per_gpu([t.throughput for t in self.tiers],
                             self.gpu_flops * self.efficiency)

    @property
    def mem_floor(self) -> float:
        """The tightest per-GPU memory capacity — what a single cluster-wide
        memory budget must respect when every GPU hosts a worker.  Exactly
        ``gpu_mem`` for homogeneous specs."""
        if not self.tiers:
            return self.gpu_mem
        return min(self.tiers[t].mem for t in set(self.node_tiers))


def compute_slowdowns(spec: ClusterSpec) -> Optional[np.ndarray]:
    """Per-GPU compute slowdown vs the spec's reference device, or ``None``.

    The reference is the scalar ``gpu_flops * efficiency`` the profiles are
    priced at; GPU ``g``'s slowdown is ``reference / throughput_g`` (> 1 for
    slower tiers).  Returns ``None`` — the signal every consumer uses to
    take the historical scalar path, bit-for-bit — when the spec has no
    tier table *or* when every tier matches the reference exactly (a
    single-tier spec built from the scalars degenerates here by design).
    """
    if not spec.tiers:
        return None
    slow = (spec.gpu_flops * spec.efficiency) / spec.per_gpu_throughput()
    if np.all(slow == 1.0):  # repro: noqa DET005 -- designed degeneration test: a tier built from the reference scalars divides to exactly 1.0, and only that exact case may take the scalar path
        return None
    return slow


def tier_table_fingerprint(tiers, node_tiers) -> str:
    """SHA-256 of a raw tier table + node assignment.

    One hash recipe shared by :func:`tier_fingerprint` (live specs) and
    the static plan verifier (serialized provenance) — each entry is a
    ``(flops, mem, efficiency, name)`` tuple, hashed in table order,
    followed by the node -> tier index tuple."""
    h = hashlib.sha256()
    for flops, mem, efficiency, name in tiers:
        h.update(repr((flops, mem, efficiency, name)).encode())
    h.update(repr(tuple(int(t) for t in node_tiers)).encode())
    return h.hexdigest()


def tier_fingerprint(spec: ClusterSpec) -> Optional[str]:
    """SHA-256 digest of the tier table + node assignment (``None`` for
    homogeneous specs).  Recorded in Plan provenance so a plan can be
    matched against the fleet composition it was computed for."""
    if not spec.tiers:
        return None
    return tier_table_fingerprint(
        [(t.flops, t.mem, t.efficiency, t.name) for t in spec.tiers],
        spec.node_tiers)


def mixed_fleet_spec(name: str, n_nodes: int,
                     tiers: Sequence[DeviceTier],
                     fractions: Optional[Sequence[float]] = None, *,
                     gpus_per_node: int = 8, intra_bw: float = 300e9,
                     inter_bw: float = 12.5e9, heterogeneity: float = 0.28,
                     slow_frac: float = 0.08, seed: int = 0) -> ClusterSpec:
    """Seeded mixed-generation fleet: nodes drawn from ``tiers``.

    Node counts follow ``fractions`` (equal split by default, remainders to
    the leading tiers) and the assignment order is a seeded permutation —
    mixed fleets rarely rack their generations contiguously.  The reference
    scalars (``gpu_flops``/``gpu_mem``/``efficiency``) are pinned to the
    highest-throughput tier, so every per-GPU slowdown is >= 1.

    Args:
        name: spec name.
        n_nodes: fleet size in nodes.
        tiers: device classes present in the fleet.
        fractions: fraction of nodes per tier (normalised; default equal).
        gpus_per_node / intra_bw / inter_bw / heterogeneity / slow_frac /
            seed: as on :class:`ClusterSpec` (``seed`` also drives the
            node-assignment shuffle).

    Returns:
        A validated heterogeneous :class:`ClusterSpec`.
    """
    tiers = tuple(tiers)
    if not tiers:
        raise ValueError("mixed_fleet_spec needs at least one tier")
    if fractions is None:
        fractions = [1.0 / len(tiers)] * len(tiers)
    if len(fractions) != len(tiers) or any(f < 0 for f in fractions):
        raise ValueError("fractions must be non-negative, one per tier")
    # fsum: the normalizer must not depend on the order the caller lists
    # tiers in (a left-fold sum would round differently per permutation)
    total = math.fsum(fractions)
    if total <= 0:
        raise ValueError("fractions must sum to a positive value")
    counts = [int(f / total * n_nodes) for f in fractions]
    # remainder nodes go to the leading tiers the caller actually asked
    # for — a tier with fraction 0.0 must stay absent from the fleet
    present = [i for i, f in enumerate(fractions) if f > 0]
    for k in range(n_nodes - sum(counts)):  # repro: noqa DET004 -- counts are ints; integer sum is exact in any order
        counts[present[k % len(present)]] += 1
    assignment = np.repeat(np.arange(len(tiers)), counts)
    rng = np.random.default_rng(seed * 999983 + 7)
    rng.shuffle(assignment)
    ref = max(tiers, key=lambda t: t.throughput)
    return ClusterSpec(name, n_nodes, gpus_per_node=gpus_per_node,
                       intra_bw=intra_bw, inter_bw=inter_bw,
                       gpu_flops=ref.flops, gpu_mem=ref.mem,
                       efficiency=ref.efficiency,
                       heterogeneity=heterogeneity, slow_frac=slow_frac,
                       seed=seed, tiers=tiers,
                       node_tiers=tuple(int(t) for t in assignment))


def degraded_host_spec(base: ClusterSpec, *, degraded_frac: float = 0.25,
                       flops_factor: float = 0.5, mem_factor: float = 1.0,
                       seed: int = 0) -> ClusterSpec:
    """Seeded partially-degraded fleet: ``base`` with a fraction of its
    hosts throttled (thermal issues, a dying HBM stack, MIG leftovers).

    Tier 0 is the healthy base device; tier 1 scales its flops by
    ``flops_factor`` and its memory by ``mem_factor``.  The degraded node
    set is a seeded choice, at least one node when ``degraded_frac > 0``.

    Args:
        base: homogeneous spec to degrade (must not already carry tiers).
        degraded_frac: fraction of nodes to throttle.
        flops_factor / mem_factor: multipliers applied to the degraded tier.
        seed: drives the degraded-node choice.

    Returns:
        A heterogeneous :class:`ClusterSpec` named ``<base.name>-degraded``.
    """
    if base.tiers:
        raise ValueError("degraded_host_spec expects a homogeneous base")
    if not 0 < degraded_frac <= 1:
        raise ValueError(f"degraded_frac must be in (0, 1], got "
                         f"{degraded_frac!r}")
    healthy = DeviceTier(base.gpu_flops, base.gpu_mem, base.efficiency,
                         name="healthy")
    degraded = DeviceTier(base.gpu_flops * flops_factor,
                          base.gpu_mem * mem_factor, base.efficiency,
                          name="degraded")
    n_deg = max(1, int(round(degraded_frac * base.n_nodes)))
    rng = np.random.default_rng(seed * 424243 + 1)
    deg_nodes = set(int(i) for i in
                    rng.choice(base.n_nodes, size=n_deg, replace=False))
    node_tiers = tuple(1 if i in deg_nodes else 0
                       for i in range(base.n_nodes))
    return dataclasses.replace(base, name=f"{base.name}-degraded",
                               tiers=(healthy, degraded),
                               node_tiers=node_tiers)


# The paper's two evaluation environments (Table I).
MID_RANGE = ClusterSpec("mid-range", n_nodes=16, intra_bw=300e9,
                        inter_bw=12.5e9, gpu_flops=112e12, gpu_mem=32e9,
                        seed=11)
HIGH_END = ClusterSpec("high-end", n_nodes=16, intra_bw=600e9,
                       inter_bw=25e9, gpu_flops=280e12, gpu_mem=80e9,
                       seed=23)

# TPU-pod flavoured cluster: "nodes" are ICI neighbourhoods, the inter-node
# tier is the slower multi-hop/DCN path (DESIGN.md §2 hardware adaptation).
TPU_POD = ClusterSpec("tpu-v5e-pod", n_nodes=16, gpus_per_node=16,
                      intra_bw=50e9, inter_bw=25e9, gpu_flops=197e12,
                      gpu_mem=16e9, efficiency=0.55, seed=31)

# Device tiers of the mixed-fleet presets: the A100 tier matches HIGH_END's
# per-GPU numbers, the V100 tier MID_RANGE's — so the mixed fleet sits
# exactly between the paper's two evaluation environments.
A100_TIER = DeviceTier(flops=280e12, mem=80e9, efficiency=0.45, name="a100")
V100_TIER = DeviceTier(flops=112e12, mem=32e9, efficiency=0.45, name="v100")

# 16-node mixed-generation fleet, half A100 / half V100 nodes in a seeded
# shuffle — the headline heterogeneous-compute scenario (compute-aware
# dedication must beat compute-blind assignment here, see
# tests/test_hetero_dedication.py and benchmarks/bench_configure.py).
MIXED_A100_V100 = mixed_fleet_spec("mixed-a100-v100", 16,
                                   (A100_TIER, V100_TIER), (0.5, 0.5),
                                   intra_bw=300e9, inter_bw=12.5e9, seed=47)

# MID_RANGE with a quarter of its hosts thermally throttled to half speed —
# the degraded-host preset (examples/configure_cluster.py demos it).
MID_RANGE_DEGRADED = degraded_host_spec(MID_RANGE, degraded_frac=0.25,
                                        flops_factor=0.5, seed=53)


def true_bandwidth_matrix(spec: ClusterSpec, day: int = 0) -> np.ndarray:
    """Ground-truth attained bandwidth (bytes/s) between every GPU pair.

    Inter-node factors are near-symmetric lognormals with a straggler tail;
    intra-node links jitter mildly.  ``day`` shifts the realisation to model
    the temporal drift of Fig. 3.

    Args:
        spec: cluster description (sizes, nominal bandwidths, heterogeneity).
        day: realisation index modelling day-to-day drift.

    Returns:
        ``(n_gpus, n_gpus)`` bytes/s matrix; the diagonal (self-transfer) is
        effectively free.
    """
    rng = np.random.default_rng(spec.seed * 1000003 + day)
    g = spec.n_gpus
    nn = spec.n_nodes
    # per-node-pair factor
    f = np.exp(rng.normal(0.0, spec.heterogeneity, (nn, nn)))
    f = np.clip(f, 0.35, 1.15)
    slow = rng.random((nn, nn)) < spec.slow_frac
    f = np.where(slow, f * 0.5, f)
    f = np.minimum(f, f.T * rng.uniform(0.96, 1.04, (nn, nn)))  # ~symmetric
    np.fill_diagonal(f, 1.0)

    bw = np.empty((g, g))
    node = np.arange(g) // spec.gpus_per_node
    same = node[:, None] == node[None, :]
    intra_jit = rng.uniform(0.92, 1.0, (g, g))
    bw = np.where(same, spec.intra_bw * intra_jit,
                  spec.inter_bw * f[node[:, None], node[None, :]])
    np.fill_diagonal(bw, spec.intra_bw * 4)     # self: effectively free
    return bw


def profile_bandwidth(spec: ClusterSpec, day: int = 0,
                      noise: float = 0.01) -> tuple[np.ndarray, float]:
    """'network_profile()' of Algorithm 1 line 1.

    Args:
        spec: cluster description.
        day: realisation index (see :func:`true_bandwidth_matrix`).
        noise: relative measurement noise (~1% default).

    Returns:
        ``(measured_matrix, profiling_wall_seconds)``.  The cost model is
        calibrated to the paper's Table II (58 s @ 8 nodes, 239 s @ 16
        nodes — all-pairs mpiGraph grows with n_nodes^2).
    """
    rng = np.random.default_rng(spec.seed * 7919 + day + 1)
    truth = true_bandwidth_matrix(spec, day)
    measured = truth * rng.normal(1.0, noise, truth.shape)
    cost_s = 0.934 * spec.n_nodes ** 2
    return measured, cost_s


def profile_bandwidth_live(devices=None, msg_bytes: int = 1 << 20) -> np.ndarray:
    """Actually time device-to-device transfers with JAX (for real clusters).

    On a single-host CPU container this degenerates to one device; it exists
    so the profiling interface is exercised end-to-end in tests.
    """
    import jax
    import jax.numpy as jnp

    devices = devices or jax.devices()
    n = len(devices)
    x = jnp.ones((msg_bytes // 4,), jnp.float32)
    bw = np.zeros((n, n))
    for i, di in enumerate(devices):
        xi = jax.device_put(x, di)
        xi.block_until_ready()
        for j, dj in enumerate(devices):
            if i == j:
                bw[i, j] = float("inf")
                continue
            t0 = time.perf_counter()
            y = jax.device_put(xi, dj)
            y.block_until_ready()
            dt = max(time.perf_counter() - t0, 1e-9)
            bw[i, j] = msg_bytes / dt
    return bw


def ring_allreduce_time(msg_bytes: float, group_bw: float, n: int,
                        phases: int = 2) -> float:
    """Thakur et al. ring all-reduce: phases * (n-1)/n * msg / bw.

    Args:
        msg_bytes: bytes contributed by each rank.
        group_bw: bottleneck link bandwidth of the ring, bytes/s.  Must be
            finite and positive for real rings (``n > 1``): the ``inf``
            that :func:`min_group_bw` returns for singleton groups would
            otherwise silently price a 0-second collective for a ring that
            supposedly spans multiple GPUs.
        n: ring size.  ``n == 1`` (and 0) is an explicit early-out: a
            single rank performs no communication, so the result is exactly
            0.0 *before* ``group_bw`` is touched — pairing this with a
            singleton :func:`min_group_bw` (``inf``) is therefore safe.
        phases: 2 for reduce-scatter + all-gather over one message pass,
            4 for the hierarchical intra-node stage.

    Returns:
        Seconds for the collective.

    Raises:
        ValueError: ``n > 1`` with a non-finite or non-positive
            ``group_bw`` (a singleton-group bandwidth leaking into a real
            ring).
    """
    if n <= 1:
        return 0.0
    if not np.isfinite(group_bw) or group_bw <= 0:
        raise ValueError(
            f"ring of {n} ranks needs a finite positive bottleneck "
            f"bandwidth, got {group_bw!r} (singleton-group inf leaking in?)")
    return phases * (n - 1) / n * msg_bytes / group_bw


def min_group_bw(bw: np.ndarray, gpus) -> float:
    """Slowest pairwise link inside a communicator group (Eq. 6 denominator).

    Args:
        bw: ``(G, G)`` bandwidth matrix in bytes/s.
        gpus: iterable of GPU indices forming the group.

    Returns:
        Minimum off-diagonal entry of the group's bandwidth submatrix
        (both directions considered); ``inf`` for groups of size <= 1 — a
        singleton has no links, and ``inf`` makes downstream guards
        explicit.  Callers must special-case that ``inf``: the latency
        scalers (``_tp_scale``/``_cp_scale``) treat non-finite group
        bandwidth as scale 1.0, and :func:`ring_allreduce_time` never sees
        it because its ``n <= 1`` early-out fires first (it raises if a
        non-finite bandwidth reaches a real ring).
    """
    gpus = list(gpus)
    if len(gpus) <= 1:
        return float("inf")
    sub = bw[np.ix_(gpus, gpus)].copy()
    np.fill_diagonal(sub, np.inf)
    return float(sub.min())


def min_group_bw_batch(bw: np.ndarray, groups: np.ndarray) -> np.ndarray:
    """Batched :func:`min_group_bw`: slowest intra-group link per group.

    Args:
        bw: ``(G, G)`` bandwidth matrix in bytes/s.
        groups: ``(n_groups, m)`` integer array of GPU ids, one group per row.

    Returns:
        ``(n_groups,)`` array of the minimum off-diagonal submatrix entry per
        group (``inf`` when ``m <= 1``).  Bit-identical to calling
        :func:`min_group_bw` row by row.
    """
    ids = np.asarray(groups, dtype=np.intp)
    n_groups, m = ids.shape
    if m <= 1:
        return np.full(n_groups, np.inf)
    sub = bw[ids[:, :, None], ids[:, None, :]]
    eye = np.eye(m, dtype=bool)
    return np.where(eye[None, :, :], np.inf, sub).min(axis=(1, 2))
