"""Fine-grained worker dedication (§IV): simulated annealing over the 1:1
logical-worker -> GPU mapping.

Moves (paper §IV): *migration* (remove one element, reinsert at a random
position), *swap* (exchange two elements) and *reverse* (reverse a
substring — exploits the near-symmetric bidirectional bandwidths).
Temperature decay alpha = 0.999; the budget is wall-clock seconds with an
iteration cap so tests stay fast.

The hot loop is driven by :class:`DedicationEngine`, an incremental
vectorized scorer: the three SA moves touch a known set of permutation
positions, and only the TP groups / pipeline chains / first-stage DP groups
(and, for 4D configurations, the context-parallel ring groups; on tiered
clusters, the pipeline stages whose compute-slowness changed) containing
those positions are re-gathered and re-reduced — everything else
comes from per-group caches.  Scores are bit-identical to the full
:func:`repro.core.latency.pipette_latency` (and its pure-Python reference).
:func:`anneal_multistart` adds best-of-``n_chains`` restarts on top.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .cluster import ClusterSpec, compute_slowdowns
from .latency import _hetero_combine, pipette_latency
from .simulator import Conf, Profile


def perm_to_mapping(perm: np.ndarray, conf: Conf) -> np.ndarray:
    """Flat permutation -> (pp, tp[, cp], dp) worker mapping.

    Flattening keeps tp fastest (then cp, then dp, then pp) so contiguous
    GPUs (same node) serve one tensor-parallel group in the identity
    permutation.

    Args:
        perm: ``(n_gpus,)`` permutation of GPU ids; position ``p`` holds the
            GPU serving logical worker ``(x, y, k, z)`` with
            ``p = x*dp*cp*tp + z*cp*tp + k*tp + y`` (``k = 0`` collapses to
            the historical 3D layout when ``cp == 1``).
        conf: parallelism configuration.

    Returns:
        ``(pp, tp, dp)`` integer mapping array when ``cp == 1`` (the
        historical shape), else ``(pp, tp, cp, dp)``.
    """
    if conf.cp == 1:
        return perm.reshape(conf.pp, conf.dp, conf.tp).transpose(0, 2, 1)
    return perm.reshape(conf.pp, conf.dp, conf.cp,
                        conf.tp).transpose(0, 3, 2, 1)


def mapping_to_perm(mapping: np.ndarray) -> np.ndarray:
    """Inverse of :func:`perm_to_mapping`: worker mapping -> flat permutation.

    Round-trips exactly (``mapping_to_perm(perm_to_mapping(p, conf)) == p``)
    for both the 3D ``(pp, tp, dp)`` and 4D ``(pp, tp, cp, dp)`` shapes.
    This is how a saved Plan's best mapping becomes a
    ``Budget.warm_start`` seed permutation for a neighbouring request —
    the flat GPU ordering is shape-agnostic, so it can warm-start SA on
    any candidate configuration of the same fleet.
    """
    m = np.asarray(mapping)
    if m.ndim == 3:
        return np.ascontiguousarray(m.transpose(0, 2, 1)).reshape(-1)
    if m.ndim == 4:
        return np.ascontiguousarray(m.transpose(0, 3, 2, 1)).reshape(-1)
    raise ValueError(
        f"mapping must be 3D (pp, tp, dp) or 4D (pp, tp, cp, dp), "
        f"got ndim={m.ndim}")


def project_perm(perm: np.ndarray, survivors: Sequence[int],
                 n_new: int) -> np.ndarray:
    """Project an incumbent permutation onto a resized fleet.

    The elastic warm-start rule: keep the incumbent's *relative* GPU
    ordering over the GPUs that survived the churn event, renumber them
    into the new fleet's contiguous id space, and append any brand-new
    GPUs in id order at the tail (they have no incumbent position).  The
    result is a valid ``(n_new,)`` permutation usable as
    ``Budget.warm_start`` for any candidate configuration of the new
    fleet.

    Args:
        perm: incumbent flat permutation over the old fleet's GPU ids.
        survivors: old GPU ids still present, in new-id order — new GPU
            ``i`` (for ``i < len(survivors)``) is old GPU
            ``survivors[i]``.  Must be unique and within the old fleet.
        n_new: GPU count of the new fleet (``>= len(survivors)``).

    Returns:
        ``(n_new,)`` int permutation of ``0..n_new-1``.
    """
    perm = np.asarray(perm)
    survivors = np.asarray(list(survivors), dtype=np.int64)
    n_old = perm.shape[0]
    if survivors.size and (survivors.min() < 0 or survivors.max() >= n_old):
        raise ValueError(
            f"survivors must be old GPU ids in [0, {n_old}), "
            f"got {survivors.tolist()}")
    if np.unique(survivors).size != survivors.size:
        raise ValueError(f"duplicate survivor ids: {survivors.tolist()}")
    if n_new < survivors.size:
        raise ValueError(
            f"n_new={n_new} smaller than {survivors.size} survivors")
    # old id -> new id (or -1 for a departed GPU); vectorised so the
    # output order is the incumbent's, never a set-iteration order.
    old_to_new = np.full(n_old, -1, dtype=np.int64)
    old_to_new[survivors] = np.arange(survivors.size)
    kept = old_to_new[perm]
    kept = kept[kept >= 0]
    fresh = np.arange(survivors.size, n_new, dtype=np.int64)
    return np.concatenate([kept, fresh])


@dataclass
class SAResult:
    """Outcome of one (or a multi-start batch of) annealing run(s).

    Attributes:
        mapping: best ``(pp, tp, dp)`` worker -> GPU dedication found.
        perm: the flat permutation behind ``mapping``.
        latency: estimated seconds/iteration of ``mapping``.
        iters: total SA iterations executed (summed over chains).
        seconds: total wall-clock seconds spent annealing.
        trace: ``[(iter, best_so_far), ...]`` of the winning chain.
        chain_latencies: per-chain best latencies (multi-start only).
        accepted: accepted moves, summed over chains.
        accepted_to_best: accepted moves the winning chain needed to first
            reach its best value (0 = the initial permutation was never
            improved on) — the warm-start economy metric: a chain seeded
            from a good incumbent reaches the same quality in strictly
            fewer accepted moves than a cold chain.

    Example:
        >>> res = anneal(conf, bw, prof, spec, time_limit_s=0.5, seed=0)
        >>> res.latency <= pipette_latency(conf, default_mapping(conf),
        ...                                bw, prof, spec)
        True
        >>> res.mapping.shape == (conf.pp, conf.tp, conf.dp)
        True
    """
    mapping: np.ndarray
    perm: np.ndarray
    latency: float
    iters: int
    seconds: float
    trace: list
    chain_latencies: Optional[List[float]] = None
    accepted: int = 0
    accepted_to_best: int = 0


# ---------------------------------------------------------------------------
# moves
# ---------------------------------------------------------------------------

def _move_span(perm: np.ndarray,
               rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray]:
    """One SA move plus the positions it touched.

    Returns:
        ``(new_perm, touched)`` where ``touched`` is the array of permutation
        positions whose GPU changed (a superset is allowed; migration and
        reverse report the contiguous affected span, swap exactly two).
    """
    n = len(perm)
    p = perm.copy()
    kind, i, j = (int(v) for v in rng.integers((3, n, n - 1)))
    if j >= i:
        j += 1
    if i > j:
        i, j = j, i
    if kind == 0:          # migration: remove at i, reinsert at j % (n-1)
        jj = j % (n - 1)
        el = p[i]
        if jj >= i:
            p[i:jj] = p[i + 1:jj + 1].copy()
            p[jj] = el
            touched = np.arange(i, jj + 1)
        else:
            p[jj + 1:i + 1] = p[jj:i].copy()
            p[jj] = el
            touched = np.arange(jj, i + 1)
    elif kind == 1:        # swap
        p[i], p[j] = p[j], p[i]
        touched = np.array((i, j))
    else:                  # reverse
        p[i:j + 1] = p[i:j + 1][::-1]
        touched = np.arange(i, j + 1)
    return p, touched


def _move(perm: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """One SA move (migration / swap / reverse); returns the new permutation."""
    return _move_span(perm, rng)[0]


# ---------------------------------------------------------------------------
# incremental vectorized scoring engine
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GroupIndex:
    """Precomputed permutation-position tensors for a (pp, tp, cp, dp)
    shape.

    Positions follow the :func:`perm_to_mapping` layout
    ``p = x*dp*cp*tp + z*cp*tp + k*tp + y``; the tensors depend only on the
    shape, never on the permutation or bandwidth, so
    :func:`repro.core.search.configure` shares one instance across every
    microbatch variant of a parallelism shape.

    Attributes:
        pos_tp: ``(pp*cp*dp, tp)`` positions of each tensor-parallel group.
        pos_pp_src / pos_pp_dst: ``(pp-1, tp*cp*dp)`` positions of the
            sender / receiver of every inter-stage hop, one column per
            chain.
        pos_dp0: ``(tp*cp, dp)`` positions of the stage-0 data-parallel
            groups (the only DP groups on the Eq. 6 critical path).
        pos_cp: ``(pp*tp*dp, cp)`` positions of each context-parallel (ring
            KV-exchange) group; ``None`` when ``cp == 1``.
        cp_group_of: ``(n_gpus,)`` position -> cp-group-row lookup used by
            the incremental move re-scorer; ``None`` when ``cp == 1``.
    """
    pp: int
    tp: int
    dp: int
    pos_tp: np.ndarray
    pos_pp_src: np.ndarray
    pos_pp_dst: np.ndarray
    pos_dp0: np.ndarray
    cp: int = 1
    pos_cp: Optional[np.ndarray] = None
    cp_group_of: Optional[np.ndarray] = None

    @staticmethod
    def build(conf: Conf) -> "GroupIndex":
        """Construct the index tensors for ``conf``'s (pp, tp, cp, dp)
        shape."""
        pp, tp, cp, dp = conf.pp, conf.tp, conf.cp, conf.dp
        nc = tp * cp * dp                      # positions per stage
        base = (np.arange(pp)[:, None] * (dp * cp) +
                np.arange(dp * cp)[None, :]) * tp
        pos_tp = base.reshape(-1, 1) + np.arange(tp)[None, :]
        chains = np.arange(nc)
        stages = np.arange(max(pp - 1, 1))[:, None] * nc
        pos_pp_src = stages + chains[None, :]
        pos_pp_dst = pos_pp_src + nc
        pos_dp0 = np.arange(dp)[None, :] * (tp * cp) \
            + np.arange(tp * cp)[:, None]
        pos_cp = cp_group_of = None
        if cp > 1:
            # cp group row g = (x*dp + z)*tp + y holds positions
            # p(k) = x*dp*cp*tp + z*cp*tp + k*tp + y
            xz = (np.arange(pp)[:, None] * dp +
                  np.arange(dp)[None, :]) * (cp * tp)
            gbase = xz.reshape(-1, 1) + np.arange(tp)[None, :]
            pos_cp = gbase.reshape(-1, 1) + np.arange(cp)[None, :] * tp
            pos = np.arange(pp * nc)
            cp_group_of = (pos // (dp * cp * tp) * dp
                           + pos % (dp * cp * tp) // (cp * tp)) * tp \
                + pos % tp
        return GroupIndex(pp, tp, dp, pos_tp, pos_pp_src, pos_pp_dst,
                          pos_dp0, cp, pos_cp, cp_group_of)


@dataclass(frozen=True)
class PairCache:
    """Configuration-independent GPU-pair matrices shared across engines.

    All ``(G, G)`` tensors an engine gathers from depend only on the
    profiled bandwidth matrix and the node width — never on the candidate
    configuration — so one instance serves every engine of a search (every
    microbatch/shape variant, and the JAX engine's host-side mirror).  At
    10k GPUs each matrix is ~800 MB; building them once instead of per
    candidate is the difference between seconds and minutes of planning
    time.

    Attributes:
        bw: the bandwidth matrix as contiguous float64 (the canonical copy
            every sharing engine gathers from).
        bw_noself: ``bw`` with the diagonal forced to ``inf`` (masks
            self-links out of group-min reductions).
        sym_intra: ``min(bw[i,j], bw[j,i])`` on distinct same-node pairs,
            ``inf`` elsewhere — finite exactly where the hierarchical
            all-reduce intra-node term applies.
        gpus_per_node: node width the same-node blocks were built for.
    """
    bw: np.ndarray
    bw_noself: np.ndarray
    sym_intra: np.ndarray
    gpus_per_node: int

    @classmethod
    def build(cls, bw: np.ndarray, gpus_per_node: int) -> "PairCache":
        """Build the shared matrices with O(G^2) *memory passes*, not
        O(G^2) boolean-mask algebra: ``bw_noself`` is a copy plus a
        diagonal fill, and ``sym_intra`` only ever has finite values in
        the per-node diagonal blocks, so it is an ``inf`` canvas with
        ``n_nodes`` tiny ``gpn x gpn`` block writes.  Values are
        bit-identical to the historical full-matrix ``np.where`` /
        transpose construction."""
        bw64 = np.ascontiguousarray(bw, dtype=float)
        g = bw64.shape[0]
        bw_noself = bw64.copy()
        np.fill_diagonal(bw_noself, np.inf)
        sym_intra = np.full((g, g), np.inf)
        for a in range(0, g, gpus_per_node):
            b = min(a + gpus_per_node, g)
            blk = np.minimum(bw64[a:b, a:b], bw64[a:b, a:b].T)
            np.fill_diagonal(blk, np.inf)
            sym_intra[a:b, a:b] = blk
        return cls(bw64, bw_noself, sym_intra, gpus_per_node)


class DedicationEngine:
    """Vectorized pipette-latency scorer with incremental move re-scoring.

    ``score()`` evaluates a permutation from scratch and fills per-group
    caches (TP-group slowdowns, pipeline-chain times, stage-0 DP all-reduce
    times, and — on tiered clusters — per-stage compute slowdowns).
    ``propose()`` re-gathers only the groups containing positions a
    move touched and combines them with the cached remainder; ``commit()``
    promotes a proposal to the new committed state.  All values are
    bit-identical to :func:`repro.core.latency.pipette_latency` on the
    corresponding mapping.  ``compute_aware=False`` ignores device tiers
    (every GPU priced at reference speed) — the compute-blind baseline the
    heterogeneous evaluation compares against.

    Example:
        >>> eng = DedicationEngine(conf, bw, prof, spec)
        >>> cur = eng.score(np.arange(conf.n_gpus))
        >>> cand, touched = _move_span(np.arange(conf.n_gpus), rng)
        >>> val, pending = eng.propose(cand, touched)
        >>> eng.commit(pending)          # accept the move
    """

    def __init__(self, conf: Conf, bw: np.ndarray, prof: Profile,
                 spec: ClusterSpec, index: Optional[GroupIndex] = None,
                 compute_aware: bool = True,
                 pairs: Optional[PairCache] = None):
        if index is not None and \
                (index.pp, index.tp, index.cp, index.dp) != \
                (conf.pp, conf.tp, conf.cp, conf.dp):
            raise ValueError("GroupIndex shape mismatch")
        self.conf = conf
        self.prof = prof
        self.spec = spec
        self.idx = index if index is not None else GroupIndex.build(conf)
        # Heterogeneous compute: per-GPU slowdowns (None on compute-uniform
        # specs — the scalar Eq. 3-4 path, bit-exact with history).
        # ``compute_aware=False`` forces the blind path even on tiered
        # specs: the ablation/baseline that prices every GPU at reference
        # speed (the comparison point for the compute-aware win).
        self._slow = compute_slowdowns(spec) if compute_aware else None
        # Non-uniform partitions / interleaved schedules need the per-stage
        # combination even on homogeneous fleets (unit compute scales, but
        # stage_work varies); mirrors latency._combine_eq34's trigger.
        self._uniform_stage_scale = (
            np.ones(conf.pp)
            if self._slow is None and (prof.partition is not None
                                       or conf.vpp > 1)
            else None)
        # Pair matrices (the only O(G^2) state): shared via ``pairs`` when
        # the caller scores many candidates against one fleet, else built
        # here.  The cache must have been built from this same ``bw`` and
        # node width — ``dedicate_candidates`` owns that invariant.
        if pairs is None:
            pairs = PairCache.build(bw, spec.gpus_per_node)
        elif pairs.gpus_per_node != spec.gpus_per_node or \
                pairs.bw.shape != np.shape(bw):
            raise ValueError("PairCache does not match bw/spec")
        self.bw = pairs.bw
        self._bw_noself = pairs.bw_noself
        self._sym_intra = pairs.sym_intra
        # Per-conf move-loop constants (all O(dp), built per engine):
        #   _hopf — 2 * msg_pp, the per-hop pipeline numerator (the divide
        #     by the gathered link bandwidth happens in _chain_times)
        #   _intra/_inter_coef — ring coefficients phases*(n-1)/n*msg by
        #     integer group size, computed with the reference op order
        if conf.pp > 1:
            self._hopf = 2.0 * prof.msg_pp
        self._jlt_dp = (np.arange(conf.dp)[None, :] <
                        np.arange(conf.dp)[:, None])
        self._intra_coef = np.array(
            [4 * (c - 1) / c * prof.msg_dp if c else 0.0
             for c in range(conf.dp + 1)])
        self._inter_coef = np.array(
            [2 * (c - 1) / c * prof.msg_dp if c else 0.0
             for c in range(conf.dp + 1)])
        self._tp_vals: Optional[np.ndarray] = None
        self._chain_vals: Optional[np.ndarray] = None
        self._dp0_vals: Optional[np.ndarray] = None
        self._cp_vals: Optional[np.ndarray] = None
        self._stage_vals: Optional[np.ndarray] = None

    # -- per-group recomputation (vectorized gathers over a group subset) --

    def _tp_scales(self, perm: np.ndarray, gsel) -> np.ndarray:
        ids = perm[self.idx.pos_tp[gsel]]
        gbw = self._bw_noself[ids[:, :, None], ids[:, None, :]].min(axis=(1, 2))
        # same degenerate-link guard as latency._tp_scale (scale 1.0 when a
        # group's min link is 0 or non-finite, e.g. user-supplied matrices)
        ok = np.isfinite(gbw) & (gbw > 0)
        return np.divide(self.prof.tp_ref_bw, gbw,
                         out=np.ones_like(gbw), where=ok)

    def _cp_scales(self, perm: np.ndarray, gsel) -> np.ndarray:
        # ring KV-exchange slowdown per cp group — the cp analogue of
        # _tp_scales, gathered over the GroupIndex.pos_cp rows
        ids = perm[self.idx.pos_cp[gsel]]
        gbw = self._bw_noself[ids[:, :, None], ids[:, None, :]].min(axis=(1, 2))
        ok = np.isfinite(gbw) & (gbw > 0)
        return np.divide(self.prof.cp_ref_bw, gbw,
                         out=np.ones_like(gbw), where=ok)

    def _chain_times(self, perm: np.ndarray, csel) -> np.ndarray:
        # gather the hop links, then divide — elementwise identical to the
        # historical full (G, G) ``2*msg_pp/bw`` precompute, without the
        # O(G^2) pass (and 800 MB at 10k GPUs) per engine
        src = perm[self.idx.pos_pp_src[:, csel]]
        dst = perm[self.idx.pos_pp_dst[:, csel]]
        with np.errstate(divide="ignore"):
            t = self._hopf / self.bw[src[0], dst[0]]
            for x in range(1, self.conf.pp - 1):
                t = t + self._hopf / self.bw[src[x], dst[x]]
        return t

    def _stage_scales(self, perm: np.ndarray, xsel) -> np.ndarray:
        # max member-GPU compute slowdown per pipeline stage — stage x owns
        # the contiguous position block [x*nc, (x+1)*nc), so the gather is
        # a plain reshape (same values as latency._stage_compute_scale's
        # mapping4 gather: max over the same member set)
        nc = self.conf.tp * self.conf.cp * self.conf.dp
        ids = perm.reshape(self.conf.pp, nc)[xsel]
        return self._slow[ids].max(axis=1)

    def _dp0_times(self, perm: np.ndarray, ysel) -> np.ndarray:
        # Specialised hier_allreduce_batch with pair matrices and ring
        # coefficients hoisted to __init__; arithmetic is identical (see that
        # function for the derivation).  Size-1 node clusters / single-node
        # groups fall out as coef 0 / inf bandwidth -> 0 seconds.
        ids = perm[self.idx.pos_dp0[ysel]]
        ii, jj = ids[:, :, None], ids[:, None, :]
        sym = self._sym_intra[ii, jj]
        member_min = sym.min(axis=2)
        # sym is finite exactly on distinct same-node pairs, so the same-node
        # mask falls out of the float gather (+1 restores the self member)
        same = np.isfinite(sym)
        counts = same.sum(axis=2) + 1  # repro: noqa DET003 -- boolean mask count: integer reduction, exact in any association order
        intra = (self._intra_coef[counts] / member_min).max(axis=1)
        is_rep = ~(same & self._jlt_dp).any(axis=2)
        n_reps = is_rep.sum(axis=1)  # repro: noqa DET003 -- boolean mask count: integer reduction, exact in any association order
        pair = is_rep[:, :, None] & is_rep[:, None, :]
        rep_min = np.where(pair, self._bw_noself[ii, jj], np.inf) \
            .min(axis=(1, 2))
        inter = self._inter_coef[n_reps] / rep_min
        return intra + inter

    # -- scoring --

    def _combine(self, tp_vals, chain_vals, dp0_vals, cp_vals,
                 stage_vals=None) -> float:
        conf, prof = self.conf, self.prof
        c = prof.c_fwd + prof.c_bwd
        scale = 1.0 if conf.tp == 1 else float(max(1.0, tp_vals.max()))
        t_tp = (prof.t_tp_fwd + prof.t_tp_bwd) * scale
        cscale = 1.0 if conf.cp == 1 else float(max(1.0, cp_vals.max()))
        t_cm = t_tp + (prof.t_cp_fwd + prof.t_cp_bwd) * cscale
        t_pp = 0.0 if conf.pp == 1 else float(max(0.0, chain_vals.max()))
        t_dp = float(max(0.0, dp0_vals.max()))
        if stage_vals is None:
            stage_vals = self._uniform_stage_scale
        if stage_vals is not None:
            # tiered cluster (or non-uniform partition / vpp > 1 with unit
            # scales): shared per-stage combination (bit-identical to
            # pipette_latency via the same _hetero_combine arithmetic)
            return _hetero_combine(conf, prof, t_cm, t_pp, t_dp, stage_vals)
        t_bubble = conf.pp * (c + t_cm) + t_pp
        t_straggler = (conf.pp - 1) * (c + t_cm)
        return t_bubble * (conf.n_mb / conf.pp) + t_straggler + t_dp

    def score(self, perm: np.ndarray) -> float:
        """Full evaluation of ``perm``; (re)initialises the caches.

        Returns the same value as
        ``pipette_latency(conf, perm_to_mapping(perm, conf), bw, prof, spec)``.
        """
        conf = self.conf
        perm = np.asarray(perm, dtype=np.intp)
        self._tp_vals = (self._tp_scales(perm, slice(None))
                         if conf.tp > 1 else np.ones(1))
        self._chain_vals = (self._chain_times(perm, slice(None))
                            if conf.pp > 1 else np.zeros(1))
        self._dp0_vals = self._dp0_times(perm, slice(None))
        self._cp_vals = (self._cp_scales(perm, slice(None))
                         if conf.cp > 1 else np.ones(1))
        self._stage_vals = (self._stage_scales(perm, slice(None))
                            if self._slow is not None else None)
        return self._combine(self._tp_vals, self._chain_vals,
                             self._dp0_vals, self._cp_vals,
                             self._stage_vals)

    def propose(self, cand: np.ndarray, touched: np.ndarray):
        """Score candidate ``cand`` that differs from the committed
        permutation only at positions ``touched``.

        Only the groups intersecting ``touched`` are re-gathered; the rest
        come from the caches filled by the last ``score()``/``commit()``.

        Returns:
            ``(value, pending)`` — ``value`` is the candidate's latency and
            ``pending`` the cache state to pass to :meth:`commit` if the move
            is accepted.
        """
        conf = self.conf
        tp, tpc = conf.tp, conf.tp * conf.cp
        nc = tpc * conf.dp           # positions per pipeline stage
        lo, hi, n_t = int(touched[0]), int(touched[-1]), len(touched)
        span = hi - lo + 1 == n_t    # contiguous (migration/reverse) or swap

        tp_vals = self._tp_vals
        if tp > 1:
            if span:
                gidx = slice(lo // tp, hi // tp + 1)
            else:                    # swap: at most two groups
                gi, gj = lo // tp, hi // tp
                gidx = np.array((gi,) if gi == gj else (gi, gj))
            tp_vals = self._tp_vals.copy()
            tp_vals[gidx] = self._tp_scales(cand, gidx)

        chain_vals = self._chain_vals
        if conf.pp > 1:
            if span:
                if n_t >= nc:
                    cidx = slice(None)
                elif lo // nc == hi // nc:     # span inside one stage block
                    cidx = slice(lo % nc, hi % nc + 1)
                else:       # a span shorter than nc has distinct residues
                    cidx = touched % nc
            else:
                ci, cj = lo % nc, hi % nc
                cidx = np.array((ci,) if ci == cj else (ci, cj))
            chain_vals = self._chain_vals.copy()
            chain_vals[cidx] = self._chain_times(cand, cidx)

        dp0_vals = self._dp0_vals
        if lo < nc:                  # move touches stage-0 positions
            # stage-0 DP group of position p is p % tpc (blocks of tp*cp)
            if span:
                hi0 = min(hi, nc - 1)
                if hi0 - lo + 1 >= tpc:
                    ysel = slice(None)
                elif lo // tpc == hi0 // tpc:  # span inside one tp*cp block
                    ysel = slice(lo % tpc, hi0 % tpc + 1)
                else:
                    ysel = np.arange(lo, hi0 + 1) % tpc
            else:
                yi = lo % tpc
                if hi < nc:
                    yj = hi % tpc
                    ysel = np.array((yi,) if yi == yj else (yi, yj))
                else:
                    ysel = np.array((yi,))
            dp0_vals = self._dp0_vals.copy()
            dp0_vals[ysel] = self._dp0_times(cand, ysel)

        cp_vals = self._cp_vals
        if conf.cp > 1:
            # cp groups interleave with stride tp, so a span does not map to
            # contiguous group rows; the O(|touched|) lookup + unique is
            # still tiny next to the gathers it saves
            gsel = np.unique(self.idx.cp_group_of[touched])
            cp_vals = self._cp_vals.copy()
            cp_vals[gsel] = self._cp_scales(cand, gsel)

        stage_vals = self._stage_vals
        if self._slow is not None:
            # stage of position p is p // nc; a move touches at most the
            # [lo // nc, hi // nc] stage range (contiguous by construction)
            xi, xj = lo // nc, hi // nc
            xsel = slice(xi, xj + 1) if span else \
                np.array((xi,) if xi == xj else (xi, xj))
            stage_vals = self._stage_vals.copy()
            stage_vals[xsel] = self._stage_scales(cand, xsel)

        val = self._combine(tp_vals, chain_vals, dp0_vals, cp_vals,
                            stage_vals)
        return val, (tp_vals, chain_vals, dp0_vals, cp_vals, stage_vals)

    def commit(self, pending) -> None:
        """Promote a :meth:`propose` result to the committed state."""
        (self._tp_vals, self._chain_vals, self._dp0_vals,
         self._cp_vals, self._stage_vals) = pending


# ---------------------------------------------------------------------------
# annealing drivers
# ---------------------------------------------------------------------------

def anneal(conf: Conf, bw: np.ndarray, prof: Profile, spec: ClusterSpec, *,
           objective: Optional[Callable[[np.ndarray], float]] = None,
           time_limit_s: float = 2.0, max_iters: int = 20_000,
           alpha: float = 0.999, seed: int = 0,
           init_perm: Optional[np.ndarray] = None,
           engine: Optional[DedicationEngine] = None,
           compute_aware: bool = True) -> SAResult:
    """Simulated-annealing worker dedication (Algorithm 1, line 7).

    Args:
        conf: parallelism configuration to dedicate workers for.
        bw: ``(G, G)`` profiled bandwidth matrix, bytes/s.
        prof: profiled per-microbatch quantities.
        spec: cluster description.
        objective: optional custom ``perm -> cost``; when given, the generic
            (non-incremental) path is used.  Default scores with the
            incremental :class:`DedicationEngine` — same values, ~10-100x
            more moves/sec.
        time_limit_s: wall-clock budget.
        max_iters: iteration cap (keeps tests fast).
        alpha: geometric temperature decay per move.
        seed: RNG seed; runs are deterministic given (seed, inputs).
        init_perm: starting permutation (identity when ``None``).
        engine: reuse a pre-built engine (e.g. shared index tensors).
        compute_aware: forwarded to :class:`DedicationEngine` when one is
            built here; ``False`` anneals compute-blind on tiered specs
            (ignored when ``engine`` is given).

    Returns:
        :class:`SAResult` with the best mapping found and its trace.
    """
    rng = np.random.default_rng(seed)
    n = conf.n_gpus
    perm = np.arange(n) if init_perm is None else init_perm.copy()

    use_engine = objective is None
    if use_engine:
        if engine is None:
            engine = DedicationEngine(conf, bw, prof, spec,
                                      compute_aware=compute_aware)
        cur = engine.score(perm)
    else:
        cur = objective(perm)

    best_perm, best = perm.copy(), cur
    # initial temperature from the spread of a few random proposals
    probes = []
    for _ in range(8):
        cand, touched = _move_span(perm, rng)
        val = engine.propose(cand, touched)[0] if use_engine \
            else objective(cand)
        probes.append(abs(val - cur))
    temp = max(max(probes), cur * 1e-3, 1e-12)

    t0 = time.perf_counter()
    it = 0
    acc = acc_best = 0
    trace = [(0, best)]
    while it < max_iters and (time.perf_counter() - t0) < time_limit_s:
        cand, touched = _move_span(perm, rng)
        if use_engine:
            val, pending = engine.propose(cand, touched)
        else:
            val = objective(cand)
        delta = val - cur
        if delta <= 0 or rng.random() < math.exp(-delta / max(temp, 1e-15)):
            perm, cur = cand, val
            acc += 1
            if use_engine:
                engine.commit(pending)
            if cur < best:
                best_perm, best = perm.copy(), cur
                acc_best = acc
                trace.append((it, best))
        temp *= alpha
        it += 1
    return SAResult(perm_to_mapping(best_perm, conf), best_perm, best, it,
                    time.perf_counter() - t0, trace,
                    accepted=acc, accepted_to_best=acc_best)


def anneal_multistart(conf: Conf, bw: np.ndarray, prof: Profile,
                      spec: ClusterSpec, *, n_chains: int = 4,
                      time_limit_s: float = 2.0, max_iters: int = 20_000,
                      alpha: float = 0.999, seed: int = 0,
                      init_perm: Optional[np.ndarray] = None,
                      engine: Optional[DedicationEngine] = None,
                      compute_aware: bool = True) -> SAResult:
    """Best-of-``n_chains`` independent annealing restarts.

    The budgets are split across chains so the total cost matches a single
    :func:`anneal` call with the same budgets — *exactly*: with
    ``base, rem = divmod(max_iters, n_chains)``, chain ``k`` runs
    ``base + 1`` iterations when ``k < rem`` else ``base`` (the historical
    ``max(1, max_iters // n_chains)`` silently ran up to ``n_chains - 1``
    extra iterations, and a full ``n_chains`` extra when
    ``n_chains > max_iters``).  Edge cases are defined, not accidental:
    a chain whose share is zero iterations runs no moves and contributes
    its initial permutation's score; ``time_limit_s = 0`` gives every
    chain a zero wall-clock budget, so all chains are score-only and the
    result is the initial permutation.  Chain ``k`` runs with seed
    ``seed * 100003 + k``, making the whole driver deterministic in
    ``seed``.

    Returns:
        :class:`SAResult` of the winning chain, with ``iters``/``seconds``
        summed over all chains and ``chain_latencies`` listing every chain's
        best.
    """
    if n_chains < 1:
        raise ValueError("n_chains must be >= 1")
    if engine is None:
        engine = DedicationEngine(conf, bw, prof, spec,
                                  compute_aware=compute_aware)
    per_t = time_limit_s / n_chains
    base_it, rem_it = divmod(max_iters, n_chains)
    best: Optional[SAResult] = None
    iters, seconds, lats, acc = 0, 0.0, [], 0
    for k in range(n_chains):
        res = anneal(conf, bw, prof, spec, time_limit_s=per_t,
                     max_iters=base_it + (1 if k < rem_it else 0),
                     alpha=alpha,
                     seed=seed * 100003 + k, init_perm=init_perm,
                     engine=engine)
        iters += res.iters
        seconds += res.seconds
        lats.append(res.latency)
        acc += res.accepted
        if best is None or res.latency < best.latency:
            best = res
    return SAResult(best.mapping, best.perm, best.latency, iters, seconds,
                    best.trace, chain_latencies=lats, accepted=acc,
                    accepted_to_best=best.accepted_to_best)
