"""Fine-grained worker dedication (§IV): simulated annealing over the 1:1
logical-worker -> GPU mapping.

Moves (paper §IV): *migration* (remove one element, reinsert at a random
position), *swap* (exchange two elements) and *reverse* (reverse a
substring — exploits the near-symmetric bidirectional bandwidths).
Temperature decay alpha = 0.999; the budget is wall-clock seconds with an
iteration cap so tests stay fast.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import numpy as np

from .cluster import ClusterSpec
from .latency import pipette_latency
from .simulator import Conf, Profile


def perm_to_mapping(perm: np.ndarray, conf: Conf) -> np.ndarray:
    """Flat permutation -> (pp, tp, dp) worker mapping.

    Flattening keeps tp fastest so contiguous GPUs (same node) serve one
    tensor-parallel group in the identity permutation."""
    return perm.reshape(conf.pp, conf.dp, conf.tp).transpose(0, 2, 1)


@dataclass
class SAResult:
    mapping: np.ndarray
    perm: np.ndarray
    latency: float
    iters: int
    seconds: float
    trace: list


def _move(perm: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    n = len(perm)
    p = perm.copy()
    kind = rng.integers(0, 3)
    i, j = sorted(rng.choice(n, 2, replace=False))
    if kind == 0:          # migration
        el = p[i]
        p = np.delete(p, i)
        p = np.insert(p, j % (n - 1), el)
    elif kind == 1:        # swap
        p[i], p[j] = p[j], p[i]
    else:                  # reverse
        p[i:j + 1] = p[i:j + 1][::-1]
    return p


def anneal(conf: Conf, bw: np.ndarray, prof: Profile, spec: ClusterSpec, *,
           objective: Optional[Callable[[np.ndarray], float]] = None,
           time_limit_s: float = 2.0, max_iters: int = 20_000,
           alpha: float = 0.999, seed: int = 0,
           init_perm: Optional[np.ndarray] = None) -> SAResult:
    rng = np.random.default_rng(seed)
    n = conf.n_gpus
    perm = np.arange(n) if init_perm is None else init_perm.copy()

    if objective is None:
        def objective(p):
            return pipette_latency(conf, perm_to_mapping(p, conf), bw, prof, spec)

    cur = objective(perm)
    best_perm, best = perm.copy(), cur
    # initial temperature from the spread of a few random proposals
    probes = [abs(objective(_move(perm, rng)) - cur) for _ in range(8)]
    temp = max(max(probes), cur * 1e-3, 1e-12)

    t0 = time.perf_counter()
    it = 0
    trace = [(0, best)]
    while it < max_iters and (time.perf_counter() - t0) < time_limit_s:
        cand = _move(perm, rng)
        val = objective(cand)
        delta = val - cur
        if delta <= 0 or rng.random() < np.exp(-delta / max(temp, 1e-15)):
            perm, cur = cand, val
            if cur < best:
                best_perm, best = perm.copy(), cur
                trace.append((it, best))
        temp *= alpha
        it += 1
    return SAResult(perm_to_mapping(best_perm, conf), best_perm, best, it,
                    time.perf_counter() - t0, trace)
