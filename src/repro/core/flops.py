"""Parameter and FLOP accounting shared by the configurator, the roofline
analysis and the benchmarks.

Conventions:
  * ``param_count``       — total trainable parameters.
  * ``active_param_count``— params touched per token (MoE: top-k experts).
  * ``train_flops``       — 6 * N_active * tokens (fwd 2N + bwd 4N) plus the
                            attention term 12 * L * d_head*H * s^2-ish when
                            requested explicitly (MODEL_FLOPS in the roofline
                            table uses the plain 6*N*D convention per spec).
"""
from __future__ import annotations

from ..models.config import ModelConfig


def param_count(cfg: ModelConfig) -> int:
    d, L = cfg.d_model, cfg.n_layers
    n = cfg.vocab_size * d                       # embedding
    if not cfg.tie_embeddings:
        n += d * cfg.vocab_size                  # lm head
    n += d                                       # final norm

    per_layer = d                                # ln1
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        per_layer += d * h * hd + 2 * d * kv * hd + h * hd * d
        if cfg.qkv_bias:
            per_layer += h * hd + 2 * kv * hd
        per_layer += d                           # ln2
        if cfg.family == "moe":
            per_layer += d * cfg.n_experts
            per_layer += cfg.n_experts * 3 * d * cfg.d_ff
        else:
            per_layer += 3 * d * cfg.d_ff
    else:                                        # mamba layers
        di, N = cfg.d_inner, cfg.ssm_state
        if cfg.ssm_variant == "mamba2":
            nh = cfg.n_ssm_heads
            conv_dim = di + 2 * N
            per_layer += d * (2 * di + 2 * N + nh) + cfg.ssm_conv * conv_dim \
                + conv_dim + 3 * nh + di + di * d
        else:
            per_layer += d * 2 * di + cfg.ssm_conv * di + di \
                + di * (cfg.dt_rank + 2 * N) + cfg.dt_rank * di + di \
                + di * N + 2 * di + di * d
    n += L * per_layer

    if cfg.hybrid_attn_period:                   # zamba2 shared block
        h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        n += 2 * d + d * h * hd + 2 * d * kv * hd + h * hd * d + 3 * d * cfg.d_ff
    return int(n)


def active_param_count(cfg: ModelConfig) -> int:
    if cfg.family != "moe":
        return param_count(cfg)
    d, L = cfg.d_model, cfg.n_layers
    dense_total = param_count(cfg)
    all_expert = L * cfg.n_experts * 3 * d * cfg.d_ff
    active_expert = L * cfg.experts_per_token * 3 * d * cfg.d_ff
    return int(dense_total - all_expert + active_expert)


def model_flops(cfg: ModelConfig, tokens: int, *, train: bool = True) -> float:
    """The spec's MODEL_FLOPS convention: 6*N*D (dense) / 6*N_active*D."""
    mult = 6.0 if train else 2.0
    return mult * active_param_count(cfg) * tokens


def attention_flops(cfg: ModelConfig, seq: int, tokens: int, *, train: bool = True) -> float:
    """Extra score/value FLOPs not captured by 6*N*D (for MFU context)."""
    if cfg.family == "ssm":
        return 0.0
    L_att = cfg.n_layers if not cfg.hybrid_attn_period else \
        cfg.n_layers // cfg.hybrid_attn_period
    if cfg.family == "hybrid":
        L = L_att
    else:
        L = cfg.n_layers
    per_tok = 0.0
    for i in range(L):
        w = cfg.layer_window(i) if cfg.family != "hybrid" else 0
        span = min(seq, w) if w else seq
        per_tok += 2 * 2 * cfg.n_heads * cfg.hd * span / 2  # qk^T + pv, causal/2
    mult = 3.0 if train else 1.0
    return mult * per_tok * tokens
