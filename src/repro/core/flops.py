"""Parameter and FLOP accounting shared by the configurator, the roofline
analysis and the benchmarks.

Conventions:
  * ``param_count``       — total trainable parameters.
  * ``active_param_count``— params touched per token (MoE: top-k experts).
  * ``train_flops``       — 6 * N_active * tokens (fwd 2N + bwd 4N) plus the
                            attention term 12 * L * d_head*H * s^2-ish when
                            requested explicitly (MODEL_FLOPS in the roofline
                            table uses the plain 6*N*D convention per spec).
"""
from __future__ import annotations

import numpy as np

from ..models.config import ModelConfig


def _per_layer_params(cfg: ModelConfig) -> int:
    """Trainable parameters of one repeated block (hybrid shared block and
    embedding/head/final-norm excluded)."""
    d = cfg.d_model
    per_layer = d                                # ln1
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        per_layer += d * h * hd + 2 * d * kv * hd + h * hd * d
        if cfg.qkv_bias:
            per_layer += h * hd + 2 * kv * hd
        per_layer += d                           # ln2
        if cfg.family == "moe":
            per_layer += d * cfg.n_experts
            per_layer += cfg.n_experts * 3 * d * cfg.d_ff
        else:
            per_layer += 3 * d * cfg.d_ff
    else:                                        # mamba layers
        di, N = cfg.d_inner, cfg.ssm_state
        if cfg.ssm_variant == "mamba2":
            nh = cfg.n_ssm_heads
            conv_dim = di + 2 * N
            per_layer += d * (2 * di + 2 * N + nh) + cfg.ssm_conv * conv_dim \
                + conv_dim + 3 * nh + di + di * d
        else:
            per_layer += d * 2 * di + cfg.ssm_conv * di + di \
                + di * (cfg.dt_rank + 2 * N) + cfg.dt_rank * di + di \
                + di * N + 2 * di + di * d
    return int(per_layer)


def shared_block_params(cfg: ModelConfig) -> int:
    """The zamba2-style weight-tied shared attention block (0 when the
    config has no ``hybrid_attn_period``).  The parameters exist once, but
    the *compute* is paid at every layer that applies the block."""
    if not cfg.hybrid_attn_period:
        return 0
    d = cfg.d_model
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return int(2 * d + d * h * hd + 2 * d * kv * hd + h * hd * d
               + 3 * d * cfg.d_ff)


def param_count(cfg: ModelConfig) -> int:
    d, L = cfg.d_model, cfg.n_layers
    n = cfg.vocab_size * d                       # embedding
    if not cfg.tie_embeddings:
        n += d * cfg.vocab_size                  # lm head
    n += d                                       # final norm
    n += L * _per_layer_params(cfg)
    n += shared_block_params(cfg)                # zamba2 shared block (once)
    return int(n)


def active_param_count(cfg: ModelConfig) -> int:
    if cfg.family != "moe":
        return param_count(cfg)
    d, L = cfg.d_model, cfg.n_layers
    dense_total = param_count(cfg)
    all_expert = L * cfg.n_experts * 3 * d * cfg.d_ff
    active_expert = L * cfg.experts_per_token * 3 * d * cfg.d_ff
    return int(dense_total - all_expert + active_expert)


def model_flops(cfg: ModelConfig, tokens: int, *, train: bool = True) -> float:
    """The spec's MODEL_FLOPS convention: 6*N*D (dense) / 6*N_active*D."""
    mult = 6.0 if train else 2.0
    return mult * active_param_count(cfg) * tokens


def attention_flops(cfg: ModelConfig, seq: int, tokens: int, *, train: bool = True) -> float:
    """Extra score/value FLOPs not captured by 6*N*D (for MFU context)."""
    if cfg.family == "ssm":
        return 0.0
    L_att = cfg.n_layers if not cfg.hybrid_attn_period else \
        cfg.n_layers // cfg.hybrid_attn_period
    if cfg.family == "hybrid":
        L = L_att
    else:
        L = cfg.n_layers
    per_tok = 0.0
    for i in range(L):
        w = cfg.layer_window(i) if cfg.family != "hybrid" else 0
        span = min(seq, w) if w else seq
        per_tok += 2 * 2 * cfg.n_heads * cfg.hd * span / 2  # qk^T + pv, causal/2
    mult = 3.0 if train else 1.0
    return mult * per_tok * tokens


# ---------------------------------------------------------------------------
# Per-layer vectors: the non-uniform pipeline-partition inputs.
#
# The aggregate accessors above collapse the layer sequence into one
# averaged scalar; the partitioner (core/partition.py) and the non-uniform
# profile path (core/simulator.py) need the sequence itself — attention vs.
# SSM vs. MoE vs. dense layers priced individually, with the embedding and
# LM-head GEMMs pinned to the first/last stage instead of amortized 1/pp.
# ---------------------------------------------------------------------------

def attention_layer_mask(cfg: ModelConfig) -> np.ndarray:
    """Boolean mask of layers that compute attention scores: every layer
    for attention families, none for pure SSM, and the shared-block
    application layers (``i % period == period - 1``) for hybrids."""
    L = cfg.n_layers
    if cfg.family == "ssm":
        return np.zeros(L, dtype=bool)
    if cfg.hybrid_attn_period:
        idx = np.arange(L)
        return (idx % cfg.hybrid_attn_period) == cfg.hybrid_attn_period - 1
    return np.ones(L, dtype=bool)


def layer_param_counts(cfg: ModelConfig) -> np.ndarray:
    """Per-layer *resident* parameter counts (float64, length ``n_layers``).

    The hybrid shared block is excluded — it is one weight-tied copy, so a
    pipeline stage holds it once however many of its layers apply it (see
    ``shared_block_params`` + ``attention_layer_mask`` for stage sums).
    Embedding, LM head, and the final norm are likewise accounted at the
    stage level, not here."""
    return np.full(cfg.n_layers, float(_per_layer_params(cfg)))


def layer_active_param_counts(cfg: ModelConfig) -> np.ndarray:
    """Per-layer *compute-active* parameter counts: MoE layers count only
    the routed ``experts_per_token`` experts, and hybrid shared-block
    layers pay the block's GEMMs at every application (the weights are
    tied, the FLOPs are not)."""
    per = layer_param_counts(cfg)
    d = cfg.d_model
    if cfg.family == "moe":
        per = per - cfg.n_experts * 3.0 * d * cfg.d_ff \
            + cfg.experts_per_token * 3.0 * d * cfg.d_ff
    if cfg.hybrid_attn_period:
        per = per + attention_layer_mask(cfg) * float(shared_block_params(cfg))
    return per


def layer_attention_per_token(cfg: ModelConfig, seq: int) -> np.ndarray:
    """Per-layer score/value attention FLOPs per token (forward, the
    ``attention_flops(train=False)`` convention); zero on SSM layers.
    Sums to ``attention_flops(cfg, seq, 1, train=False)``."""
    L = cfg.n_layers
    out = np.zeros(L)
    mask = attention_layer_mask(cfg)
    for i in range(L):
        if not mask[i]:
            continue
        w = cfg.layer_window(i) if cfg.family != "hybrid" else 0
        span = min(seq, w) if w else seq
        out[i] = 2 * 2 * cfg.n_heads * cfg.hd * span / 2
    return out


def embed_cost_per_token(cfg: ModelConfig) -> float:
    """Forward FLOPs per token of one vocabulary GEMM (embedding *or* LM
    head) under the profile's ``2.0 * 2*V*d / pp`` convention: each end
    costs half the folded total."""
    return 2.0 * cfg.vocab_size * cfg.d_model


def layer_cost_per_token(cfg: ModelConfig, seq: int) -> np.ndarray:
    """Per-layer forward-compute cost vector ``c_i`` (FLOPs per token).

    Decomposes the exact totals ``build_profile`` prices — the 6N*D body
    distributed by per-layer active params, plus each layer's own
    score/value attention term — so that stage sums of this vector (plus
    ``embed_cost_per_token`` on the end stages) reproduce the legacy
    aggregate when the split is uniform."""
    a = layer_active_param_counts(cfg)
    n_active = float(active_param_count(cfg))
    body = max(n_active - 2.0 * cfg.vocab_size * cfg.d_model,
               float(int(0.5 * n_active)))
    body_i = 2.0 * body * (a / a.sum())
    att_i = 2.0 * layer_attention_per_token(cfg, seq) / 2
    return body_i + att_i
