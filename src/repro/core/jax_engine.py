"""JAX-native dedication scorer and vmapped multi-chain annealer.

This is the ``backend="jax"`` execution engine of the unified SA core
(``repro.core.annealing``): the Eq. 3-6 mapping score is re-expressed as a
pure function of a flat permutation device array, the move-propose /
score / accept loop becomes a ``lax.scan``, and the scan is ``vmap``-ed
across annealing chains *and* across the same-shape candidate
configurations — one XLA dispatch advances every chain of every candidate.

Bit-parity with the NumPy engine is a hard contract, not a tolerance: the
score mirrors :class:`repro.core.dedication.DedicationEngine` reduction by
reduction (min/max reductions are order-insensitive; the pipeline-chain
hop accumulation replays the reference's left-to-right fold; the tiered
per-stage sum replays NumPy's pairwise summation order via
:func:`np_pairwise_sum`), and it runs in float64 under a scoped
``jax.experimental.enable_x64`` so elementwise IEEE arithmetic matches
NumPy exactly.  ``tests/test_backend_determinism.py`` pins byte-identical
``Plan`` JSON across backends on top of this.

The group-reduce inner step (per-group min-bandwidth scales, per-stage
max compute slowdown) dispatches between the Pallas kernels in
``repro.kernels.group_reduce`` and their pure-jnp references via the
``kernels=`` knob: ``"pallas"`` (native, TPU), ``"interpret"`` (Pallas
interpreter — bit-accurate on CPU, slow), ``"ref"`` (pure jnp), or
``"auto"`` (the ``REPRO_KERNELS`` env var, else pallas on TPU / ref
elsewhere — matching ``repro.kernels.ops``).

One compilation subtlety guards the bit contract: XLA's CPU backend
contracts ``a * b + c`` into a fused multiply-add when the host supports
AVX2/FMA, which differs from NumPy's separate fmul/fadd by 1 ulp on rare
operand combinations — enough to flip an SA accept decision and diverge a
whole chain.  ``xla_allow_excess_precision=false`` does *not* disable the
contraction, so every computation here is AOT-compiled with
``xla_cpu_max_isa=AVX`` (pre-FMA vector ISA) via :func:`_aot_compile`;
eager JAX, which never fuses, already matches NumPy.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

import numpy as np

from .cluster import ClusterSpec, compute_slowdowns
from .dedication import PairCache
from .simulator import Conf, Profile

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from ..kernels.group_reduce import (group_max, group_max_ref,
                                    group_min_scale, group_min_scale_ref)


def np_pairwise_sum(x, n: int):
    """Sum ``x[:n]`` in exactly NumPy's pairwise-summation order.

    ``np.sum`` on a contiguous float64 vector is *not* a left fold: it runs
    an 8-accumulator blocked pairwise scheme, so ``jnp.sum`` (a flat XLA
    reduce) differs from it in the last bits for almost any ``n >= 3``.
    The tiered-cluster combine (``latency._hetero_combine``) sums the
    per-stage compute vector with ``np.sum``, so the JAX scorer replays the
    same association order element by element.  Works on NumPy arrays and
    traced JAX values alike (the loop structure is host-side Python over a
    static length); pinned bit-exact against ``np.sum`` in
    ``tests/test_jax_engine.py``.
    """
    def pw(lo, m):
        if m < 8:
            res = 0.0
            for i in range(m):
                res = res + x[lo + i]
            return res
        if m <= 128:
            r = [x[lo + k] for k in range(8)]
            i = 8
            while i + 8 <= m:
                for k in range(8):
                    r[k] = r[k] + x[lo + i + k]
                i += 8
            res = ((r[0] + r[1]) + (r[2] + r[3])) + \
                ((r[4] + r[5]) + (r[6] + r[7]))
            while i < m:
                res = res + x[lo + i]
                i += 1
            return res
        m2 = (m // 2) - ((m // 2) % 8)
        return pw(lo, m2) + pw(lo + m2, m - m2)

    return pw(0, n)


def _aot_compile(fn, *args):
    """Lower ``fn`` at the avals of ``args`` and compile with fused
    multiply-add contraction disabled on CPU (``xla_cpu_max_isa=AVX`` —
    the last x86 vector ISA without FMA), so the jitted score stays
    bit-identical to the NumPy engine.  Non-CPU backends compile with
    default options (no FMA contraction contract is claimed there)."""
    lowered = jax.jit(fn).lower(*args)
    if jax.default_backend() != "cpu":
        return lowered.compile()
    return lowered.compile(compiler_options={"xla_cpu_max_isa": "AVX"})


def kernels_mode(kernels: str = "auto") -> str:
    """Resolve the group-reduce implementation: 'pallas' | 'interpret' |
    'ref'.  ``"auto"`` defers to the ``REPRO_KERNELS`` env var (the same
    knob ``repro.kernels.ops`` honours), else picks pallas on TPU and the
    pure-jnp reference elsewhere."""
    if kernels in ("pallas", "interpret", "ref"):
        return kernels
    env = os.environ.get("REPRO_KERNELS", "auto")
    if env in ("pallas", "interpret", "ref"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _apply_move(perm, pos, kind, pa, pb):
    """One SA move as an index remap (all three variants are computed and
    the ``kind`` selects — cheap O(n) selects, no dynamic shapes).

    Semantics (shared with ``annealing._move_numpy``): with
    ``i = min(pa, pb)``, ``j = max(pa, pb)`` — migration (kind 0) removes
    the element at ``i`` and reinserts it at ``j``; swap (kind 1)
    exchanges positions ``i`` and ``j``; reverse (kind 2) reverses the
    span ``[i, j]``.
    """
    i = jnp.minimum(pa, pb)
    j = jnp.maximum(pa, pb)
    mig = jnp.where((pos >= i) & (pos < j), pos + 1,
                    jnp.where(pos == j, i, pos))
    swp = jnp.where(pos == i, j, jnp.where(pos == j, i, pos))
    rev = jnp.where((pos >= i) & (pos <= j), i + j - pos, pos)
    src = jnp.where(kind == 0, mig, jnp.where(kind == 1, swp, rev))
    return perm[src]


class JaxDedicationEngine:
    """Batched JAX scorer + vmapped multi-chain SA for one (pp, tp, cp, dp)
    shape.

    One engine serves every same-shape candidate (microbatch variants):
    the shape-only tensors (pair-bandwidth matrices, ring coefficients,
    device slowdowns) are shared device arrays, while the per-candidate
    profile scalars form the vmapped axis.  ``score()`` is the full
    evaluator (bit-identical to ``DedicationEngine.score``, pinned by the
    equivalence suite); :meth:`anneal` runs the vmapped
    chains-x-candidates ``lax.scan``.

    Args:
        confs: same-shape candidate configurations.
        profs: ``profs[i]`` is the profile of ``confs[i]``; the shape-only
            fields (``tp_ref_bw``/``cp_ref_bw``/``msg_dp``/``stage_work``)
            must agree across candidates (asserted — true of
            ``build_profile`` output for one workload).
        bw: ``(G, G)`` profiled bandwidth matrix.
        spec: cluster description.
        kernels: group-reduce implementation knob (see
            :func:`kernels_mode`).
        compute_aware: ``False`` prices every GPU at reference speed even
            on tiered specs (the compute-blind ablation), mirroring
            ``DedicationEngine``.
        pairs: optional prebuilt :class:`~repro.core.dedication.PairCache`
            for this ``(bw, spec)`` — skips the host-side O(G^2)
            construction when the driver already built one.
        device_pairs: optional ``.device_pairs`` of a sibling engine built
            for the *same* ``(bw, spec, compute_aware)`` — shares the big
            (G, G) device buffers across shape groups instead of paying
            the host->device copy (~2.5 GB at 10k GPUs) per group.
    """

    def __init__(self, confs: Sequence[Conf], profs: Sequence[Profile],
                 bw: np.ndarray, spec: ClusterSpec, *,
                 kernels: str = "auto", compute_aware: bool = True,
                 pairs: Optional[PairCache] = None,
                 device_pairs: Optional[dict] = None):
        conf = confs[0]
        shape = (conf.pp, conf.tp, conf.cp, conf.dp, conf.vpp)
        for c in confs[1:]:
            if (c.pp, c.tp, c.cp, c.dp, c.vpp) != shape:
                raise ValueError("JaxDedicationEngine needs same-shape confs")
        p0 = profs[0]
        for p in profs[1:]:
            assert (p.tp_ref_bw, p.cp_ref_bw, p.msg_dp, p.stage_work,
                    p.partition, p.chunk_work) == \
                (p0.tp_ref_bw, p0.cp_ref_bw, p0.msg_dp, p0.stage_work,
                 p0.partition, p0.chunk_work), \
                "profiles vary within shape; shared tensors invalid"
        self.confs = list(confs)
        self.pp, self.tp, self.cp, self.dp, self.vpp = shape
        self.n = conf.n_gpus
        self.nc = self.tp * self.cp * self.dp
        self.tpc = self.tp * self.cp
        self._kmode = kernels_mode(kernels)
        self._tp_ref = float(p0.tp_ref_bw)
        self._cp_ref = float(p0.cp_ref_bw)

        # host-side constants: the (G, G) pair matrices come from the same
        # PairCache construction the NumPy engine shares (bit-identical by
        # design), the small per-shape tensors are built here
        if pairs is None:
            pairs = PairCache.build(bw, spec.gpus_per_node)
        jlt = (np.arange(self.dp)[None, :] < np.arange(self.dp)[:, None])
        intra_coef = np.array(
            [4 * (c - 1) / c * p0.msg_dp if c else 0.0
             for c in range(self.dp + 1)])
        inter_coef = np.array(
            [2 * (c - 1) / c * p0.msg_dp if c else 0.0
             for c in range(self.dp + 1)])
        slow = compute_slowdowns(spec) if compute_aware else None
        self.tiered = slow is not None
        # Non-uniform partitions / interleaved schedules need the per-stage
        # combination even without device tiers (latency._combine_eq34's
        # trigger, mirrored here so both backends stay bit-identical).
        self.nonuniform = p0.partition is not None or conf.vpp > 1

        # per-candidate profile scalars (the vmapped axis); all arithmetic
        # on host NumPy f64 so the values equal the NumPy engine's
        w = (np.asarray(p0.stage_work) if p0.stage_work is not None
             else np.ones(self.pp))
        c_arr = np.array([p.c_fwd + p.c_bwd for p in profs])
        sc = {
            "c": c_arr,
            "tsum_tp": np.array([p.t_tp_fwd + p.t_tp_bwd for p in profs]),
            "tsum_cp": np.array([p.t_cp_fwd + p.t_cp_bwd for p in profs]),
            "hopf": np.array([2.0 * p.msg_pp for p in profs]),
            "r": np.array([c.n_mb / c.pp for c in confs]),
            "cw": (c_arr[:, None] * w[None, :]
                   if self.tiered or self.nonuniform else None),
        }

        # device residency in f64 — arrays must be created inside the
        # scoped x64 context or jnp silently downcasts them to f32.  The
        # (G, G) tensors travel as *arguments* of the jitted functions,
        # never as closure constants: XLA embeds (and constant-folds)
        # captured constants into the executable, which at 10k GPUs means
        # gigabytes of f64 baked into every compile.
        with enable_x64():
            if device_pairs is None:
                device_pairs = {
                    "bw": jnp.asarray(pairs.bw),
                    "bw_noself": jnp.asarray(pairs.bw_noself),
                    "sym_intra": jnp.asarray(pairs.sym_intra),
                    "slow": None if slow is None else jnp.asarray(slow),
                }
            self.device_pairs = device_pairs
            self._env = {
                **device_pairs,
                "jlt": jnp.asarray(jlt),
                "intra_coef": jnp.asarray(intra_coef),
                "inter_coef": jnp.asarray(inter_coef),
            }
            self._sc = {k: (None if v is None else jnp.asarray(v))
                        for k, v in sc.items()}
        self._jit_score = None
        self._batch_cache = {}
        self._anneal_cache = {}

    # -- the pure scoring function (one perm, one candidate's scalars) ----

    def _group_scales(self, sub, ref_bw):
        if self._kmode == "ref":
            return group_min_scale_ref(sub, ref_bw)
        return group_min_scale(sub, ref_bw,
                               interpret=(self._kmode == "interpret"))

    def _group_max(self, vals):
        if self._kmode == "ref":
            return group_max_ref(vals)
        return group_max(vals, interpret=(self._kmode == "interpret"))

    def _score_one(self, perm, sc, env):
        """Full Eq. 3-6 evaluation of one permutation; every reduction
        mirrors ``DedicationEngine`` (see module docstring for why the
        result is bit-identical, not merely close)."""
        pp, tp, cp, dp = self.pp, self.tp, self.cp, self.dp
        nc, tpc = self.nc, self.tpc

        if tp > 1:
            g = perm.reshape(-1, tp)
            sub = env["bw_noself"][g[:, :, None], g[:, None, :]]
            tp_scale = jnp.maximum(1.0, self._group_scales(
                sub, self._tp_ref).max())
        else:
            tp_scale = 1.0

        if cp > 1:
            g = perm.reshape(pp * dp, cp, tp).transpose(0, 2, 1) \
                .reshape(-1, cp)
            sub = env["bw_noself"][g[:, :, None], g[:, None, :]]
            cp_scale = jnp.maximum(1.0, self._group_scales(
                sub, self._cp_ref).max())
        else:
            cp_scale = 1.0

        if pp > 1:
            src = perm[:(pp - 1) * nc].reshape(pp - 1, nc)
            dst = perm[nc:].reshape(pp - 1, nc)
            hop = sc["hopf"] / env["bw"][src, dst]
            t = hop[0]
            for x in range(1, pp - 1):       # reference left-to-right fold
                t = t + hop[x]
            t_pp = jnp.maximum(0.0, t.max())
        else:
            t_pp = 0.0

        # stage-0 DP hierarchical all-reduce (Eq. 6); the only DP groups on
        # the critical path — mirrors DedicationEngine._dp0_times
        ids = perm[:nc].reshape(dp, tpc).T                    # (tpc, dp)
        ii, jj = ids[:, :, None], ids[:, None, :]
        sym = env["sym_intra"][ii, jj]
        member_min = sym.min(axis=2)
        same = jnp.isfinite(sym)
        counts = same.sum(axis=2) + 1  # repro: noqa DET003 -- boolean mask count: integer reduction, exact in any association order
        intra = (env["intra_coef"][counts] / member_min).max(axis=1)
        is_rep = ~(same & env["jlt"]).any(axis=2)
        n_reps = is_rep.sum(axis=1)  # repro: noqa DET003 -- boolean mask count: integer reduction, exact in any association order
        pair = is_rep[:, :, None] & is_rep[:, None, :]
        rep_min = jnp.where(pair, env["bw_noself"][ii, jj],
                            jnp.inf).min(axis=(1, 2))
        inter = env["inter_coef"][n_reps] / rep_min
        t_dp = jnp.maximum(0.0, (intra + inter).max())

        t_tp = sc["tsum_tp"] * tp_scale
        t_cm = t_tp + sc["tsum_cp"] * cp_scale
        if self.tiered or self.nonuniform:
            if self.tiered:
                sv = self._group_max(env["slow"][perm.reshape(pp, nc)])
                c_x = sc["cw"] * sv
            else:
                # homogeneous fleet, non-uniform stage_work: the NumPy
                # engine's stage scales are all 1.0, and cw * 1.0 == cw
                # exactly, so using cw directly preserves bit parity
                c_x = sc["cw"]
            c_max = c_x.max()
            c_sum = np_pairwise_sum(c_x, pp)
            if self.vpp == 1:
                t_bubble = float(pp) * (c_max + t_cm) + t_pp
                return ((t_bubble * sc["r"] + (c_sum - c_max))
                        + float(pp - 1) * t_cm) + t_dp
            # interleaved-1F1B: mirrors _hetero_combine's vpp branch in
            # NumPy's left-to-right association order
            t_bubble = float(pp) * (c_max + t_cm) + float(self.vpp) * t_pp
            return ((t_bubble * sc["r"] + (c_sum - c_max) / float(self.vpp))
                    + float(pp - 1) * t_cm / float(self.vpp)) + t_dp
        t_bubble = float(pp) * (sc["c"] + t_cm) + t_pp
        t_straggler = float(pp - 1) * (sc["c"] + t_cm)
        return (t_bubble * sc["r"] + t_straggler) + t_dp

    # -- public scoring (tests / coarse assignment) -----------------------

    def score(self, perm: np.ndarray, cand: int = 0) -> float:
        """Full JAX evaluation of ``perm`` for candidate ``cand`` — the
        same value as ``DedicationEngine(confs[cand], ...).score(perm)``,
        bitwise."""
        with enable_x64():
            sc = {k: (None if v is None else v[cand])
                  for k, v in self._sc.items()}
            p = jnp.asarray(np.asarray(perm), dtype=jnp.int32)
            if self._jit_score is None:
                self._jit_score = _aot_compile(self._score_one, p, sc,
                                               self._env)
            return float(self._jit_score(p, sc, self._env))

    def score_batch(self, perms: np.ndarray, cand: int = 0) -> np.ndarray:
        """Score a ``(R, n)`` batch of permutations in one vmapped dispatch.

        Element ``r`` equals ``score(perms[r], cand)`` bitwise — the batch
        axis only amortises dispatch and lets XLA pipeline the gathers.
        This is the unit of work the ``--huge`` benchmark's throughput gate
        measures against a loop of NumPy-engine full re-scores.
        """
        with enable_x64():
            sc = {k: (None if v is None else v[cand])
                  for k, v in self._sc.items()}
            p = jnp.asarray(np.asarray(perms), dtype=jnp.int32)
            exe = self._batch_cache.get(p.shape)
            if exe is None:
                exe = _aot_compile(
                    jax.vmap(self._score_one, in_axes=(0, None, None)),
                    p, sc, self._env)
                self._batch_cache[p.shape] = exe
            return np.asarray(exe(p, sc, self._env))

    # -- the vmapped multi-chain annealer ---------------------------------

    def _build_anneal(self, alpha: float):
        pos = jnp.arange(self.n, dtype=jnp.int32)

        def run_chain(init_perm, pas, pbs, kinds, thresh, valid,
                      ppas, ppbs, pkinds, sc, env):
            cur0 = self._score_one(init_perm, sc, env)

            def probe(carry, xs):
                pk, pa, pb = xs
                val = self._score_one(
                    _apply_move(init_perm, pos, pk, pa, pb), sc, env)
                return jnp.maximum(carry, jnp.abs(val - cur0)), None

            mx, _ = jax.lax.scan(probe, 0.0, (pkinds, ppas, ppbs))
            temp0 = jnp.maximum(jnp.maximum(mx, cur0 * 1e-3), 1e-12)

            def step(carry, xs):
                perm, cur, temp, best, bperm, acc, accb = carry
                kind, pa, pb, thr, ok = xs
                cand = _apply_move(perm, pos, kind, pa, pb)
                val = self._score_one(cand, sc, env)
                delta = val - cur
                accept = ok & ((delta <= 0) | (delta < temp * thr))
                perm = jnp.where(accept, cand, perm)
                cur = jnp.where(accept, val, cur)
                acc = acc + accept.astype(acc.dtype)
                imp = accept & (val < best)
                best = jnp.where(imp, val, best)
                bperm = jnp.where(imp, cand, bperm)
                accb = jnp.where(imp, acc, accb)
                temp = jnp.where(ok, temp * alpha, temp)
                return (perm, cur, temp, best, bperm, acc, accb), None

            zero = jnp.zeros((), jnp.int32)
            carry0 = (init_perm, cur0, temp0, cur0, init_perm, zero, zero)
            (_, cur, _, best, bperm, acc, accb), _ = jax.lax.scan(
                step, carry0, (kinds, pas, pbs, thresh, valid))
            return best, bperm, cur, acc, accb

        over_chains = jax.vmap(
            run_chain,
            in_axes=(None, 0, 0, 0, 0, 0, 0, 0, 0, None, None))
        over_cands = jax.vmap(
            over_chains,
            in_axes=(0, 0, 0, None, None, None, 0, 0, None, 0, None))
        return over_cands

    def anneal(self, init_perms: np.ndarray, pas: np.ndarray,
               pbs: np.ndarray, kinds: np.ndarray, thresh: np.ndarray,
               valid: np.ndarray, probe_pas: np.ndarray,
               probe_pbs: np.ndarray, probe_kinds: np.ndarray, *,
               alpha: float = 0.999):
        """Advance every chain of every candidate in one jitted dispatch.

        Args:
            init_perms: ``(C, n)`` start permutation per candidate.
            pas / pbs: ``(C, K, T)`` absolute move positions (island
                offsets already applied per candidate).
            kinds: ``(K, T)`` move kinds, shared across candidates.
            thresh: ``(K, T)`` precomputed ``-log(u)`` accept thresholds.
            valid: ``(K, T)`` per-chain iteration mask (False iterations
                are no-ops — chains may have unequal budgets).
            probe_pas / probe_pbs: ``(C, K, P)`` temperature-probe moves.
            probe_kinds: ``(K, P)``.
            alpha: geometric temperature decay.

        Returns:
            ``(bests, best_perms, finals, accepted, accepted_to_best)``
            NumPy arrays of shapes ``(C, K)``, ``(C, K, n)``, ``(C, K)``,
            ``(C, K)``, ``(C, K)`` — the last two are each chain's total
            accepted moves and the accepted-move count at which it first
            reached its best (0 = never improved on the init), matching
            :func:`~repro.core.annealing._run_chain_numpy` exactly.
        """
        with enable_x64():
            i32 = jnp.int32
            args = (jnp.asarray(init_perms, dtype=i32),
                    jnp.asarray(pas, dtype=i32), jnp.asarray(pbs, dtype=i32),
                    jnp.asarray(kinds, dtype=i32),
                    jnp.asarray(thresh), jnp.asarray(valid),
                    jnp.asarray(probe_pas, dtype=i32),
                    jnp.asarray(probe_pbs, dtype=i32),
                    jnp.asarray(probe_kinds, dtype=i32), self._sc,
                    self._env)
            # AOT executables are shape-specialized; alpha is baked into
            # the scan body, so it joins the cache key too
            key = (np.shape(init_perms), np.shape(pas), np.shape(kinds),
                   np.shape(probe_kinds), alpha)
            exe = self._anneal_cache.get(key)
            if exe is None:
                exe = _aot_compile(self._build_anneal(alpha), *args)
                self._anneal_cache[key] = exe
            best, bperm, fin, acc, accb = exe(*args)
            return (np.asarray(best), np.asarray(bperm, dtype=np.int64),
                    np.asarray(fin), np.asarray(acc, dtype=np.int64),
                    np.asarray(accb, dtype=np.int64))
