"""Latency estimators.

``pipette_latency`` — the paper's refined critical-path model (Eq. 3-6):
memory-efficient 1F1B exposes the inter-stage P2P hidden critical path
(n_mb/pp) times, the DP all-reduce of the *first* stage is the only one on
the critical path, and every communication term is evaluated on the
*profiled* bandwidth matrix.  The hot path is fully vectorized (batched
NumPy group gathers + axis reductions); the original pure-Python loop
implementation is kept as ``pipette_latency_ref`` and is the bit-exact
oracle for the equivalence tests and benchmarks.

``amp_latency`` — the prior art's model (Eq. 1): GPipe-flavoured critical
path (P2P counted once) with document-specified nominal bandwidths.
"""
from __future__ import annotations

import numpy as np

from .cluster import (ClusterSpec, min_group_bw, min_group_bw_batch,
                      ring_allreduce_time)
from .simulator import (Conf, Profile, dp_allreduce_times,
                        dp_allreduce_times_ref)


def _tp_scale(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
              spec: ClusterSpec, ref_bw: float) -> float:
    """Profiled slowdown of the slowest tensor-parallel group vs the nominal
    intra-node bandwidth the per-microbatch T_tp was profiled at.  Keeps the
    estimator honest when a mapping strands a TP group across nodes.

    Vectorized: all ``pp * dp`` TP groups are gathered into one
    ``(pp*dp, tp, tp)`` bandwidth tensor and min-reduced at once.

    Args:
        conf: parallelism configuration.
        mapping: ``(pp, tp, dp)`` worker -> GPU dedication.
        bw: ``(G, G)`` profiled bandwidth matrix, bytes/s.
        spec: cluster description (unused beyond the signature contract).
        ref_bw: bandwidth the per-microbatch T_tp was profiled at.

    Returns:
        Scale >= 1.0 to apply to the profiled T_tp.
    """
    if conf.tp == 1:
        return 1.0
    groups = np.asarray(mapping, dtype=np.intp).transpose(0, 2, 1) \
        .reshape(conf.pp * conf.dp, conf.tp)
    gbw = min_group_bw_batch(bw, groups)
    ok = np.isfinite(gbw) & (gbw > 0)
    with np.errstate(divide="ignore"):
        scales = np.where(ok, ref_bw / gbw, 1.0)
    return float(max(1.0, scales.max()))


def _tp_scale_ref(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                  spec: ClusterSpec, ref_bw: float) -> float:
    """Reference loop implementation of :func:`_tp_scale` (oracle)."""
    if conf.tp == 1:
        return 1.0
    worst = 1.0
    for x in range(conf.pp):
        for z in range(conf.dp):
            group = [int(mapping[x, y, z]) for y in range(conf.tp)]
            gbw = min_group_bw(bw, group)
            if np.isfinite(gbw) and gbw > 0:
                worst = max(worst, ref_bw / gbw)
    return worst


def _t_pp_chain(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                prof: Profile) -> float:
    """Eq. 5: slowest end-to-end pipeline chain, fwd+bwd message per hop.

    Vectorized: hop bandwidths for all ``tp * dp`` chains are gathered as a
    ``(pp-1, tp*dp)`` tensor; the per-chain sum accumulates hop by hop in the
    same left-to-right order as the reference so results are bit-identical.

    Args:
        conf: parallelism configuration.
        mapping: ``(pp, tp, dp)`` worker -> GPU dedication.
        bw: ``(G, G)`` profiled bandwidth matrix, bytes/s.
        prof: profiled quantities (uses ``msg_pp``).

    Returns:
        Seconds of the slowest chain; 0.0 when ``pp == 1``.
    """
    if conf.pp == 1:
        return 0.0
    m = np.asarray(mapping, dtype=np.intp)
    src = m[:-1].reshape(conf.pp - 1, conf.tp * conf.dp)
    dst = m[1:].reshape(conf.pp - 1, conf.tp * conf.dp)
    hop = bw[src, dst]
    t = np.zeros(conf.tp * conf.dp)
    for x in range(conf.pp - 1):
        t = t + 2.0 * prof.msg_pp / hop[x]
    return float(max(0.0, t.max()))


def _t_pp_chain_ref(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                    prof: Profile) -> float:
    """Reference loop implementation of :func:`_t_pp_chain` (oracle)."""
    if conf.pp == 1:
        return 0.0
    worst = 0.0
    for z in range(conf.dp):
        for y in range(conf.tp):
            t = 0.0
            for x in range(conf.pp - 1):
                b = bw[int(mapping[x, y, z]), int(mapping[x + 1, y, z])]
                t += 2.0 * prof.msg_pp / b
            worst = max(worst, t)
    return worst


def _t_dp_first_stage(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                      prof: Profile, spec: ClusterSpec) -> float:
    """Eq. 6: hierarchical-ring all-reduce of stage 1, slowest tp group."""
    return float(dp_allreduce_times(conf, mapping, bw, prof, spec)[0])


def pipette_latency(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                    prof: Profile, spec: ClusterSpec) -> float:
    """Eq. 3-4: T = T_bubble * (n_mb / pp) + T_straggler + T_dp.

    Args:
        conf: parallelism configuration (pp, tp, dp, microbatching).
        mapping: ``(pp, tp, dp)`` worker -> GPU dedication.
        bw: ``(G, G)`` profiled bandwidth matrix, bytes/s.
        prof: profiled per-microbatch quantities (:class:`Profile`).
        spec: cluster description.

    Returns:
        Estimated seconds per training iteration.  Uses the vectorized
        group reductions; bit-identical to :func:`pipette_latency_ref`.
    """
    c = prof.c_fwd + prof.c_bwd
    t_tp = (prof.t_tp_fwd + prof.t_tp_bwd) * _tp_scale(conf, mapping, bw,
                                                       spec, prof.tp_ref_bw)
    t_pp = _t_pp_chain(conf, mapping, bw, prof)
    t_bubble = conf.pp * (c + t_tp) + t_pp
    t_straggler = (conf.pp - 1) * (c + t_tp)
    t_dp = _t_dp_first_stage(conf, mapping, bw, prof, spec)
    return t_bubble * (conf.n_mb / conf.pp) + t_straggler + t_dp


def pipette_latency_ref(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                        prof: Profile, spec: ClusterSpec) -> float:
    """Pure-Python reference scorer (the pre-vectorization implementation).

    Kept as the oracle for equivalence tests and the moves/sec benchmark
    baseline; semantics identical to :func:`pipette_latency`.
    """
    c = prof.c_fwd + prof.c_bwd
    t_tp = (prof.t_tp_fwd + prof.t_tp_bwd) * _tp_scale_ref(
        conf, mapping, bw, spec, prof.tp_ref_bw)
    t_pp = _t_pp_chain_ref(conf, mapping, bw, prof)
    t_bubble = conf.pp * (c + t_tp) + t_pp
    t_straggler = (conf.pp - 1) * (c + t_tp)
    t_dp = float(dp_allreduce_times_ref(conf, mapping, bw, prof, spec)[0])
    return t_bubble * (conf.n_mb / conf.pp) + t_straggler + t_dp


def amp_latency(conf: Conf, mapping: np.ndarray, spec: ClusterSpec,
                prof: Profile) -> float:
    """Eq. 1 with nominal (document-specified) bandwidths.

    Args:
        conf: parallelism configuration.
        mapping: unused (AMP is mapping-blind); kept for signature parity.
        spec: cluster description (nominal ``inter_bw`` is used).
        prof: profiled per-microbatch quantities.

    Returns:
        Estimated seconds per iteration under the GPipe-flavoured model.
    """
    c = prof.c_fwd + prof.c_bwd
    t_tp = prof.t_tp_fwd + prof.t_tp_bwd
    # nominal uniform matrix: intra for same node, inter otherwise
    t_pp_hop = 2.0 * prof.msg_pp / spec.inter_bw
    t_pp = (conf.pp - 1) * t_pp_hop
    # nominal flat ring over dp
    t_dp = ring_allreduce_time(prof.msg_dp, spec.inter_bw, conf.dp)
    return (conf.n_mb - 1) * (c + t_tp) + conf.pp * (c + t_tp) + t_pp + t_dp


def varuna_latency(conf: Conf, spec: ClusterSpec, prof: Profile) -> float:
    """Varuna-style estimate: pipeline-only focus, nominal bandwidths,
    memory-unaware (used to rank its candidate configs).

    Args:
        conf: parallelism configuration (tp is assumed 1 by the caller).
        spec: cluster description (nominal ``inter_bw`` is used).
        prof: profiled per-microbatch quantities.

    Returns:
        Estimated seconds per iteration.
    """
    c = prof.c_fwd + prof.c_bwd
    t_pp_hop = 2.0 * prof.msg_pp / spec.inter_bw
    bubble = (conf.pp - 1) * (c + t_pp_hop)
    steady = conf.n_mb * c
    t_dp = ring_allreduce_time(prof.msg_dp, spec.inter_bw, conf.dp)
    return steady + bubble + t_dp
