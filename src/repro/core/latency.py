"""Latency estimators.

``pipette_latency`` — the paper's refined critical-path model (Eq. 3-6):
memory-efficient 1F1B exposes the inter-stage P2P hidden critical path
(n_mb/pp) times, the DP all-reduce of the *first* stage is the only one on
the critical path, and every communication term is evaluated on the
*profiled* bandwidth matrix.  4D configurations add a per-microbatch ring
KV-exchange term scaled by the slowest context-parallel group
(``_cp_scale``); at ``cp == 1`` the term is exactly zero.  The hot path is fully vectorized (batched
NumPy group gathers + axis reductions); the original pure-Python loop
implementation is kept as ``pipette_latency_ref`` and is the bit-exact
oracle for the equivalence tests and benchmarks.

``amp_latency`` — the prior art's model (Eq. 1): GPipe-flavoured critical
path (P2P counted once) with document-specified nominal bandwidths.
"""
from __future__ import annotations

import numpy as np

from typing import Optional, Sequence

from .cluster import (ClusterSpec, compute_slowdowns, min_group_bw,
                      min_group_bw_batch, ring_allreduce_time)
from .simulator import (Conf, Profile, default_mapping, dp_allreduce_times,
                        dp_allreduce_times_ref, mapping4)


def _tp_scale(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
              spec: ClusterSpec, ref_bw: float) -> float:
    """Profiled slowdown of the slowest tensor-parallel group vs the nominal
    intra-node bandwidth the per-microbatch T_tp was profiled at.  Keeps the
    estimator honest when a mapping strands a TP group across nodes.

    Vectorized: all ``pp * cp * dp`` TP groups are gathered into one
    ``(pp*cp*dp, tp, tp)`` bandwidth tensor and min-reduced at once.

    Args:
        conf: parallelism configuration.
        mapping: ``(pp, tp, dp)`` or ``(pp, tp, cp, dp)`` worker -> GPU
            dedication.
        bw: ``(G, G)`` profiled bandwidth matrix, bytes/s.
        spec: cluster description (unused beyond the signature contract).
        ref_bw: bandwidth the per-microbatch T_tp was profiled at.

    Returns:
        Scale >= 1.0 to apply to the profiled T_tp.
    """
    if conf.tp == 1:
        return 1.0
    groups = mapping4(conf, mapping).transpose(0, 2, 3, 1) \
        .reshape(conf.pp * conf.cp * conf.dp, conf.tp)
    gbw = min_group_bw_batch(bw, groups)
    ok = np.isfinite(gbw) & (gbw > 0)
    with np.errstate(divide="ignore"):
        scales = np.where(ok, ref_bw / gbw, 1.0)
    return float(max(1.0, scales.max()))


def _tp_scale_ref(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                  spec: ClusterSpec, ref_bw: float) -> float:
    """Reference loop implementation of :func:`_tp_scale` (oracle)."""
    if conf.tp == 1:
        return 1.0
    m4 = mapping4(conf, mapping)
    worst = 1.0
    for x in range(conf.pp):
        for k in range(conf.cp):
            for z in range(conf.dp):
                group = [int(m4[x, y, k, z]) for y in range(conf.tp)]
                gbw = min_group_bw(bw, group)
                if np.isfinite(gbw) and gbw > 0:
                    worst = max(worst, ref_bw / gbw)
    return worst


def _cp_scale(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
              ref_bw: float) -> float:
    """Profiled slowdown of the slowest context-parallel (ring KV-exchange)
    group vs the bandwidth T_cp was profiled at — the cp analogue of
    :func:`_tp_scale`.

    Vectorized: all ``pp * tp * dp`` cp groups are gathered into one
    ``(pp*tp*dp, cp, cp)`` bandwidth tensor and min-reduced at once.

    Args:
        conf: parallelism configuration (``cp > 1`` expected; 1.0 otherwise).
        mapping: worker -> GPU dedication (any mapping4-compatible shape).
        bw: ``(G, G)`` profiled bandwidth matrix, bytes/s.
        ref_bw: bandwidth the per-microbatch T_cp was profiled at.

    Returns:
        Scale >= 1.0 to apply to the profiled T_cp.
    """
    if conf.cp == 1:
        return 1.0
    groups = mapping4(conf, mapping).transpose(0, 1, 3, 2) \
        .reshape(conf.pp * conf.tp * conf.dp, conf.cp)
    gbw = min_group_bw_batch(bw, groups)
    ok = np.isfinite(gbw) & (gbw > 0)
    with np.errstate(divide="ignore"):
        scales = np.where(ok, ref_bw / gbw, 1.0)
    return float(max(1.0, scales.max()))


def _cp_scale_ref(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                  ref_bw: float) -> float:
    """Reference loop implementation of :func:`_cp_scale` (oracle)."""
    if conf.cp == 1:
        return 1.0
    m4 = mapping4(conf, mapping)
    worst = 1.0
    for x in range(conf.pp):
        for y in range(conf.tp):
            for z in range(conf.dp):
                group = [int(m4[x, y, k, z]) for k in range(conf.cp)]
                gbw = min_group_bw(bw, group)
                if np.isfinite(gbw) and gbw > 0:
                    worst = max(worst, ref_bw / gbw)
    return worst


def _pp_hop_bw(conf: Conf, mapping: np.ndarray, bw: np.ndarray) -> np.ndarray:
    """Hop bandwidths of every pipeline chain: ``(pp-1, tp*cp*dp)`` gather.

    Pure function of the mapping and bandwidth matrix (no profile), so
    callers scoring many microbatch variants of one shape can cache it.
    """
    m = mapping4(conf, mapping)
    n_chains = conf.tp * conf.cp * conf.dp
    src = m[:-1].reshape(conf.pp - 1, n_chains)
    dst = m[1:].reshape(conf.pp - 1, n_chains)
    return bw[src, dst]


def _t_pp_from_hops(conf: Conf, hop: np.ndarray, msg_pp: float) -> float:
    """Eq. 5 accumulation over pre-gathered hop bandwidths; the per-chain
    sum runs hop by hop in the reference's left-to-right order so results
    are bit-identical to :func:`_t_pp_chain_ref`."""
    t = np.zeros(conf.tp * conf.cp * conf.dp)
    for x in range(conf.pp - 1):
        t = t + 2.0 * msg_pp / hop[x]
    return float(max(0.0, t.max()))


def _t_pp_chain(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                prof: Profile) -> float:
    """Eq. 5: slowest end-to-end pipeline chain, fwd+bwd message per hop.

    Vectorized: hop bandwidths for all ``tp * dp`` chains are gathered as a
    ``(pp-1, tp*dp)`` tensor (:func:`_pp_hop_bw`), then accumulated by
    :func:`_t_pp_from_hops`.

    Args:
        conf: parallelism configuration.
        mapping: ``(pp, tp, dp)`` worker -> GPU dedication.
        bw: ``(G, G)`` profiled bandwidth matrix, bytes/s.
        prof: profiled quantities (uses ``msg_pp``).

    Returns:
        Seconds of the slowest chain; 0.0 when ``pp == 1``.
    """
    if conf.pp == 1:
        return 0.0
    return _t_pp_from_hops(conf, _pp_hop_bw(conf, mapping, bw), prof.msg_pp)


def _t_pp_chain_ref(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                    prof: Profile) -> float:
    """Reference loop implementation of :func:`_t_pp_chain` (oracle)."""
    if conf.pp == 1:
        return 0.0
    m4 = mapping4(conf, mapping)
    worst = 0.0
    for z in range(conf.dp):
        for k in range(conf.cp):
            for y in range(conf.tp):
                t = 0.0
                for x in range(conf.pp - 1):
                    b = bw[int(m4[x, y, k, z]), int(m4[x + 1, y, k, z])]
                    t += 2.0 * prof.msg_pp / b
                worst = max(worst, t)
    return worst


def _t_dp_first_stage(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                      prof: Profile, spec: ClusterSpec) -> float:
    """Eq. 6: hierarchical-ring all-reduce of stage 1, slowest tp group."""
    return float(dp_allreduce_times(conf, mapping, bw, prof, spec)[0])


def _stage_compute_scale(conf: Conf, mapping: np.ndarray,
                         spec: ClusterSpec) -> Optional[np.ndarray]:
    """Per-stage compute slowdown of a mapping on a tiered cluster.

    Stage ``x``'s GEMM work is evenly sharded over its ``tp * cp * dp``
    member GPUs, so its per-microbatch compute time stretches by the
    *slowest* member's :func:`~repro.core.cluster.compute_slowdowns` factor
    (Megatron-LM's observation that the slowest rank sets stage time).
    Returns ``None`` for compute-uniform specs — the signal to take the
    historical scalar Eq. 3-4 path bit-for-bit.

    Args:
        conf: parallelism configuration.
        mapping: any mapping4-compatible worker -> GPU dedication.
        spec: cluster description (tier table consulted).

    Returns:
        ``(pp,)`` max member slowdown per stage, or ``None``.
    """
    slow = compute_slowdowns(spec)
    if slow is None:
        return None
    return slow[mapping4(conf, mapping)].reshape(conf.pp, -1).max(axis=1)


def _hetero_combine(conf: Conf, prof: Profile, t_cm: float, t_pp: float,
                    t_dp: float, stage_scale: np.ndarray) -> float:
    """Eq. 3-4 generalised to per-stage compute times.

    Per-stage compute ``c_x = (c_fwd + c_bwd) * stage_work_x * scale_x``;
    the steady state is throughput-bound by the slowest stage (``c_max``)
    while the fill/drain pays every stage once (``sum c_x``):

        T = (pp * (c_max + t_cm) + t_pp) * (n_mb / pp)
            + (sum_x c_x - c_max) + (pp - 1) * t_cm + t_dp

    With uniform stages (``c_x == c``) this reduces *algebraically* to the
    scalar formula — but compute-uniform specs never reach here (they take
    the scalar branch), so homogeneous results stay bit-identical.  This
    is what the dedication engine exploits: herding slow GPUs into few
    (and light) stages shrinks ``sum c_x`` and ``c_max``.

    Interleaved-1F1B (``conf.vpp > 1``) shrinks the fill/drain terms by
    ``1/vpp`` — each warmup slot is one *chunk*, not a full stage — while
    paying the inter-stage hop ``vpp`` times per microbatch:

        T = (pp * (c_max + t_cm) + vpp * t_pp) * (n_mb / pp)
            + (sum_x c_x - c_max) / vpp + (pp - 1) * t_cm / vpp + t_dp
    """
    c = prof.c_fwd + prof.c_bwd
    w = (np.asarray(prof.stage_work) if prof.stage_work is not None
         else np.ones(conf.pp))
    c_x = c * w * stage_scale
    c_max = float(c_x.max())
    c_sum = float(c_x.sum())  # repro: noqa DET003 -- this IS the reference pairwise reduction: np_pairwise_sum replays ndarray.sum's association order element for element, pinned bit-exact in tests/test_jax_engine.py
    if conf.vpp == 1:
        t_bubble = conf.pp * (c_max + t_cm) + t_pp
        return (t_bubble * (conf.n_mb / conf.pp) + (c_sum - c_max)
                + (conf.pp - 1) * t_cm + t_dp)
    t_bubble = conf.pp * (c_max + t_cm) + conf.vpp * t_pp
    return (t_bubble * (conf.n_mb / conf.pp)
            + (c_sum - c_max) / conf.vpp
            + (conf.pp - 1) * t_cm / conf.vpp + t_dp)


def _combine_eq34(conf: Conf, prof: Profile, tp_scale: float, t_pp: float,
                  t_dp: float, cp_scale: float = 1.0,
                  stage_scale: Optional[np.ndarray] = None) -> float:
    """Eq. 3-4 scalar combination shared by every scorer of this model:
    ``T = T_bubble * (n_mb / pp) + T_straggler + T_dp``.

    The per-microbatch communication folds the TP all-reduce and (for 4D
    configurations) the ring KV-exchange of context parallelism; at
    ``cp == 1`` the profiled ``t_cp_*`` terms are exactly 0, so the 3D
    value is reproduced bit-for-bit.  ``stage_scale`` (tiered clusters
    only) switches to the per-stage :func:`_hetero_combine`; a non-uniform
    partition or interleaved schedule on a homogeneous fleet takes that
    path too, with unit scales (per-stage work still differs)."""
    c = prof.c_fwd + prof.c_bwd
    t_tp = (prof.t_tp_fwd + prof.t_tp_bwd) * tp_scale
    t_cm = t_tp + (prof.t_cp_fwd + prof.t_cp_bwd) * cp_scale
    if stage_scale is None and (prof.partition is not None or conf.vpp > 1):
        stage_scale = np.ones(conf.pp)
    if stage_scale is not None:
        return _hetero_combine(conf, prof, t_cm, t_pp, t_dp, stage_scale)
    t_bubble = conf.pp * (c + t_cm) + t_pp
    t_straggler = (conf.pp - 1) * (c + t_cm)
    return t_bubble * (conf.n_mb / conf.pp) + t_straggler + t_dp


def pipette_latency(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                    prof: Profile, spec: ClusterSpec) -> float:
    """Eq. 3-4: T = T_bubble * (n_mb / pp) + T_straggler + T_dp.

    Args:
        conf: parallelism configuration (pp, tp, cp, dp, microbatching).
        mapping: ``(pp, tp, dp)`` or ``(pp, tp, cp, dp)`` worker -> GPU
            dedication.
        bw: ``(G, G)`` profiled bandwidth matrix, bytes/s.
        prof: profiled per-microbatch quantities (:class:`Profile`).
        spec: cluster description.

    Returns:
        Estimated seconds per training iteration.  Uses the vectorized
        group reductions; bit-identical to :func:`pipette_latency_ref`.
        On tiered specs the compute term additionally prices each stage at
        its slowest member GPU (:func:`_stage_compute_scale`).
    """
    scale = _tp_scale(conf, mapping, bw, spec, prof.tp_ref_bw)
    cscale = _cp_scale(conf, mapping, bw, prof.cp_ref_bw)
    t_pp = _t_pp_chain(conf, mapping, bw, prof)
    t_dp = _t_dp_first_stage(conf, mapping, bw, prof, spec)
    sscale = _stage_compute_scale(conf, mapping, spec)
    return _combine_eq34(conf, prof, scale, t_pp, t_dp, cscale, sscale)


def default_mapping_latencies(confs: Sequence[Conf],
                              profiles: Sequence[Profile], bw: np.ndarray,
                              spec: ClusterSpec) -> np.ndarray:
    """Eq. 3-6 latency of every candidate's *default* (node-major) mapping
    in one cached pass.

    The mapping-dependent bandwidth reductions — the TP-group slowdown, the
    inter-stage hop-bandwidth gather (:func:`_pp_hop_bw`), and the stage-0
    DP all-reduce (whose ``msg_dp`` is a ``(pp, tp)``-only quantity) —
    depend only on the ``(pp, tp, dp)`` shape under the default mapping, so
    they are computed once per shape and reused across every microbatch
    variant.  Only the Eq. 5 hop accumulation (whose ``msg_pp`` varies with
    ``bs_micro``) and the Eq. 3-4 scalar combination (:func:`_combine_eq34`)
    run per candidate.  Each output is bit-identical to
    ``pipette_latency(conf, default_mapping(conf), ...)``.

    Precondition (asserted): profiles within one ``(pp, tp, cp, dp)`` shape
    share ``tp_ref_bw``, ``cp_ref_bw`` and ``msg_dp`` — true of
    :func:`~repro.core.simulator.build_profile` output for a single
    workload, where all three are shape-only quantities.

    Args:
        confs: candidate configurations.
        profiles: ``profiles[i]`` is the :class:`Profile` of ``confs[i]``.
        bw: ``(G, G)`` profiled bandwidth matrix, bytes/s.
        spec: cluster description.

    Returns:
        ``(len(confs),)`` array of estimated seconds per iteration.
    """
    bw = np.asarray(bw)
    out = np.empty(len(confs))
    cache = {}
    for i, (conf, prof) in enumerate(zip(confs, profiles)):
        # vpp is part of the shape key: stage_work/partition differ across
        # vpp variants of the same (pp, tp, cp, dp)
        shape = (conf.pp, conf.tp, conf.cp, conf.dp, conf.vpp)
        entry = cache.get(shape)
        if entry is None:
            m = default_mapping(conf)
            scale = _tp_scale(conf, m, bw, spec, prof.tp_ref_bw)
            cscale = _cp_scale(conf, m, bw, prof.cp_ref_bw)
            hop = _pp_hop_bw(conf, m, bw) if conf.pp > 1 else None
            t_dp = float(dp_allreduce_times(conf, m, bw, prof, spec)[0])
            sscale = _stage_compute_scale(conf, m, spec)
            entry = cache[shape] = (scale, cscale, hop, t_dp, sscale,
                                    (prof.tp_ref_bw, prof.cp_ref_bw,
                                     prof.msg_dp, prof.stage_work,
                                     prof.partition, prof.chunk_work))
        scale, cscale, hop, t_dp, sscale, src_fields = entry
        assert (prof.tp_ref_bw, prof.cp_ref_bw, prof.msg_dp,
                prof.stage_work, prof.partition,
                prof.chunk_work) == src_fields, \
            f"profiles vary within shape {shape}; per-shape cache invalid"
        t_pp = 0.0 if conf.pp == 1 \
            else _t_pp_from_hops(conf, hop, prof.msg_pp)
        out[i] = _combine_eq34(conf, prof, scale, t_pp, t_dp, cscale, sscale)
    return out


def pipette_latency_ref(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                        prof: Profile, spec: ClusterSpec) -> float:
    """Pure-Python reference scorer (the pre-vectorization implementation).

    Kept as the oracle for equivalence tests and the moves/sec benchmark
    baseline; semantics identical to :func:`pipette_latency` (including the
    per-stage compute path on tiered specs, recomputed here with explicit
    loops).
    """
    c = prof.c_fwd + prof.c_bwd
    t_tp = (prof.t_tp_fwd + prof.t_tp_bwd) * _tp_scale_ref(
        conf, mapping, bw, spec, prof.tp_ref_bw)
    t_cm = t_tp + (prof.t_cp_fwd + prof.t_cp_bwd) * _cp_scale_ref(
        conf, mapping, bw, prof.cp_ref_bw)
    t_pp = _t_pp_chain_ref(conf, mapping, bw, prof)
    t_dp = float(dp_allreduce_times_ref(conf, mapping, bw, prof, spec)[0])
    slow = compute_slowdowns(spec)
    if slow is not None:
        m4 = mapping4(conf, mapping)
        scale = np.empty(conf.pp)
        for x in range(conf.pp):
            scale[x] = max(float(slow[int(g)]) for g in m4[x].flat)
        return _hetero_combine(conf, prof, t_cm, t_pp, t_dp, scale)
    if prof.partition is not None or conf.vpp > 1:
        return _hetero_combine(conf, prof, t_cm, t_pp, t_dp,
                               np.ones(conf.pp))
    t_bubble = conf.pp * (c + t_cm) + t_pp
    t_straggler = (conf.pp - 1) * (c + t_cm)
    return t_bubble * (conf.n_mb / conf.pp) + t_straggler + t_dp


def amp_latency(conf: Conf, mapping: np.ndarray, spec: ClusterSpec,
                prof: Profile) -> float:
    """Eq. 1 with nominal (document-specified) bandwidths.

    Args:
        conf: parallelism configuration.
        mapping: unused (AMP is mapping-blind); kept for signature parity.
        spec: cluster description (nominal ``inter_bw`` is used).
        prof: profiled per-microbatch quantities.

    Returns:
        Estimated seconds per iteration under the GPipe-flavoured model.
    """
    c = prof.c_fwd + prof.c_bwd
    t_tp = prof.t_tp_fwd + prof.t_tp_bwd
    # nominal uniform matrix: intra for same node, inter otherwise
    t_pp_hop = 2.0 * prof.msg_pp / spec.inter_bw
    t_pp = (conf.pp - 1) * t_pp_hop
    # nominal flat ring over dp
    t_dp = ring_allreduce_time(prof.msg_dp, spec.inter_bw, conf.dp)
    return (conf.n_mb - 1) * (c + t_tp) + conf.pp * (c + t_tp) + t_pp + t_dp


def varuna_latency(conf: Conf, spec: ClusterSpec, prof: Profile) -> float:
    """Varuna-style estimate: pipeline-only focus, nominal bandwidths,
    memory-unaware (used to rank its candidate configs).

    Args:
        conf: parallelism configuration (tp is assumed 1 by the caller).
        spec: cluster description (nominal ``inter_bw`` is used).
        prof: profiled per-microbatch quantities.

    Returns:
        Estimated seconds per iteration.
    """
    c = prof.c_fwd + prof.c_bwd
    t_pp_hop = 2.0 * prof.msg_pp / spec.inter_bw
    bubble = (conf.pp - 1) * (c + t_pp_hop)
    steady = conf.n_mb * c
    t_dp = ring_allreduce_time(prof.msg_dp, spec.inter_bw, conf.dp)
    return steady + bubble + t_dp
