"""Latency estimators.

``pipette_latency`` — the paper's refined critical-path model (Eq. 3-6):
memory-efficient 1F1B exposes the inter-stage P2P hidden critical path
(n_mb/pp) times, the DP all-reduce of the *first* stage is the only one on
the critical path, and every communication term is evaluated on the
*profiled* bandwidth matrix.

``amp_latency`` — the prior art's model (Eq. 1): GPipe-flavoured critical
path (P2P counted once) with document-specified nominal bandwidths.
"""
from __future__ import annotations

import numpy as np

from .cluster import ClusterSpec, min_group_bw, ring_allreduce_time
from .simulator import Conf, Profile, dp_allreduce_times


def _tp_scale(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
              spec: ClusterSpec, ref_bw: float) -> float:
    """Profiled slowdown of the slowest tensor-parallel group vs the nominal
    intra-node bandwidth the per-microbatch T_tp was profiled at.  Keeps the
    estimator honest when a mapping strands a TP group across nodes."""
    if conf.tp == 1:
        return 1.0
    worst = 1.0
    for x in range(conf.pp):
        for z in range(conf.dp):
            group = [int(mapping[x, y, z]) for y in range(conf.tp)]
            gbw = min_group_bw(bw, group)
            if np.isfinite(gbw) and gbw > 0:
                worst = max(worst, ref_bw / gbw)
    return worst


def _t_pp_chain(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                prof: Profile) -> float:
    """Eq. 5: slowest end-to-end pipeline chain, fwd+bwd message per hop."""
    if conf.pp == 1:
        return 0.0
    worst = 0.0
    for z in range(conf.dp):
        for y in range(conf.tp):
            t = 0.0
            for x in range(conf.pp - 1):
                b = bw[int(mapping[x, y, z]), int(mapping[x + 1, y, z])]
                t += 2.0 * prof.msg_pp / b
            worst = max(worst, t)
    return worst


def _t_dp_first_stage(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                      prof: Profile, spec: ClusterSpec) -> float:
    """Eq. 6: hierarchical-ring all-reduce of stage 1, slowest tp group."""
    return float(dp_allreduce_times(conf, mapping, bw, prof, spec)[0])


def pipette_latency(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                    prof: Profile, spec: ClusterSpec) -> float:
    """Eq. 3-4: T = T_bubble * (n_mb / pp) + T_straggler + T_dp."""
    c = prof.c_fwd + prof.c_bwd
    t_tp = (prof.t_tp_fwd + prof.t_tp_bwd) * _tp_scale(conf, mapping, bw,
                                                       spec, prof.tp_ref_bw)
    t_pp = _t_pp_chain(conf, mapping, bw, prof)
    t_bubble = conf.pp * (c + t_tp) + t_pp
    t_straggler = (conf.pp - 1) * (c + t_tp)
    t_dp = _t_dp_first_stage(conf, mapping, bw, prof, spec)
    return t_bubble * (conf.n_mb / conf.pp) + t_straggler + t_dp


def amp_latency(conf: Conf, mapping: np.ndarray, spec: ClusterSpec,
                prof: Profile) -> float:
    """Eq. 1 with nominal (document-specified) bandwidths."""
    c = prof.c_fwd + prof.c_bwd
    t_tp = prof.t_tp_fwd + prof.t_tp_bwd
    # nominal uniform matrix: intra for same node, inter otherwise
    t_pp_hop = 2.0 * prof.msg_pp / spec.inter_bw
    t_pp = (conf.pp - 1) * t_pp_hop
    # nominal flat ring over dp
    t_dp = ring_allreduce_time(prof.msg_dp, spec.inter_bw, conf.dp)
    return (conf.n_mb - 1) * (c + t_tp) + conf.pp * (c + t_tp) + t_pp + t_dp


def varuna_latency(conf: Conf, spec: ClusterSpec, prof: Profile) -> float:
    """Varuna-style estimate: pipeline-only focus, nominal bandwidths,
    memory-unaware (used to rank its candidate configs)."""
    c = prof.c_fwd + prof.c_bwd
    t_pp_hop = 2.0 * prof.msg_pp / spec.inter_bw
    bubble = (conf.pp - 1) * (c + t_pp_hop)
    steady = conf.n_mb * c
    t_dp = ring_allreduce_time(prof.msg_dp, spec.inter_bw, conf.dp)
    return steady + bubble + t_dp
