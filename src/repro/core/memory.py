"""Per-GPU memory: ground truth, the analytical baseline [20], and the
paper's MLP estimator (§VI).

Ground truth models what a Megatron-style framework actually allocates:
weights + optimizer state, 1F1B in-flight activations, logits workspace,
and the framework/library overheads ([21]) that the analytical baseline
misses — CUDA/runtime context, collective buffers, workspace, allocator
fragmentation, and a reproducible per-config residual.  The MLP estimator
is trained ONLY on configs using <= ``fit_nodes`` nodes (paper: 4 nodes /
32 GPUs) and must extrapolate to the full cluster.

Heterogeneous fleets: peak *usage* is tier-independent (the model shards
work, not hardware), so the estimator and its feature layout are untouched
by device tiers — only the capacity side moves.
``MemoryEstimator.fits_spec`` checks the prediction against each GPU's own
memory (the ``spec.mem_floor`` of the tier table), which is what the
search pipeline budgets against by default.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from . import flops as F
from .cluster import ClusterSpec
from .mlp import mlp_forward_jit, pad_batch_rows
from .partition import Partition, uniform_partition
from .simulator import Conf, Workload, ring_kv_block_bytes


# ---------------------------------------------------------------------------
# ground truth (the "measured" per-GPU peak)
# ---------------------------------------------------------------------------

BYTES_PER_PARAM_STATE = 18.0       # bf16 param+grad, fp32 master+m+v


def _stage_params(cfg: ModelConfig, pp: int) -> float:
    total = F.param_count(cfg)
    embed = 2 * cfg.vocab_size * cfg.d_model
    body = (total - embed) / pp
    return body + embed / min(pp, 2)           # first/last stage holds embed


def _act_bytes_per_mb(cfg: ModelConfig, conf: Conf, seq: int) -> float:
    """In-flight activation bytes of one microbatch; context parallelism
    shards the sequence axis, shrinking activations by ``cp`` (exact no-op
    at ``cp == 1``)."""
    layers_stage = -(-cfg.n_layers // conf.pp)
    per_layer = seq * conf.bs_micro * (34 * cfg.d_model +
                                       5 * max(cfg.n_heads, 1) * seq)
    return layers_stage * per_layer / conf.tp / conf.cp


def _ring_kv_bytes(cfg: ModelConfig, conf: Conf, seq: int) -> float:
    """Ring-attention KV-exchange buffers (Fujii et al. 2411.06465): the
    local K+V block in bf16 (the same :func:`~repro.core.simulator.
    ring_kv_block_bytes` message the latency model prices), double-buffered
    (in-flight recv + resident), per layer on the stage.  Exactly 0 when
    ``cp == 1``."""
    if conf.cp <= 1:
        return 0.0
    layers_stage = -(-cfg.n_layers // conf.pp)
    block = ring_kv_block_bytes(cfg, conf.bs_micro, seq, conf.cp)
    return 2.0 * layers_stage * block


def _config_residual(cfg: ModelConfig, conf: Conf, spec: ClusterSpec,
                     partition: Optional[Partition] = None) -> float:
    """Reproducible 'library variance' component, up to 0.6 GB.

    The hash key only grows ``|cp`` / ``|vpp`` / ``|part`` segments when
    those degrees are active, so every 3D uniform-split configuration
    keeps its historical residual bit-for-bit."""
    key = f"{cfg.name}|{conf.pp}|{conf.tp}|{conf.dp}|{conf.bs_micro}|{spec.name}"
    if conf.cp > 1:
        key += f"|cp{conf.cp}"
    if conf.vpp > 1:
        key += f"|vpp{conf.vpp}"
    if partition is not None:
        key += f"|part{','.join(str(b) for b in partition.boundaries)}"
    h = int(hashlib.sha1(key.encode()).hexdigest()[:8], 16)
    return (h % 1000) / 1000.0 * 0.6e9


def _stage_param_array(cfg: ModelConfig, part: Partition, pp: int,
                       vpp: int) -> np.ndarray:
    """Per-physical-stage resident parameter counts under a chunk
    partition: stage ``x`` hosts chunks ``x, x + pp, ...`` plus the
    weight-tied hybrid shared block (once, if any hosted layer applies
    it), the embedding on stage 0, and the LM head + final norm on the
    last stage."""
    chunk_params = part.stage_sums(F.layer_param_counts(cfg))
    stage_params = chunk_params.reshape(vpp, pp).sum(axis=0)
    sb = float(F.shared_block_params(cfg))
    if sb:
        mask = F.attention_layer_mask(cfg).astype(np.float64)
        has = (part.stage_sums(mask) > 0).reshape(vpp, pp).any(axis=0)
        stage_params = stage_params + has * sb
    embed = float(cfg.vocab_size * cfg.d_model)
    stage_params[0] += embed
    stage_params[pp - 1] += embed + cfg.d_model    # LM head + final norm
    return stage_params


def _layer_act_bytes(cfg: ModelConfig, seq: int, bs_micro: int) -> np.ndarray:
    """Per-layer in-flight activation bytes of one microbatch: the
    ``34 * d`` residual/MLP term on every layer, the ``5 * heads * seq``
    score workspace only on layers that compute attention."""
    per = np.full(cfg.n_layers, 34.0 * cfg.d_model)
    per = per + F.attention_layer_mask(cfg) * \
        (5.0 * max(cfg.n_heads, 1) * seq)
    return seq * bs_micro * per


def _ground_truth_nonuniform(w: Workload, conf: Conf, spec: ClusterSpec,
                             partition: Optional[Partition]) -> float:
    """Worst-stage peak bytes under a non-uniform partition and/or
    interleaved-1F1B.  Per stage: resident weights from the true layer
    assignment, in-flight activations with the per-chunk interleaved
    multiplicity (chunk ``v`` of a stage keeps ``min(pp*vpp - v*pp - x,
    n_mb)`` microbatches alive); the worst stage's total is the number
    the capacity prune must respect."""
    cfg = w.cfg
    pp, vpp = conf.pp, conf.vpp
    n_chunks = pp * vpp
    part = partition if partition is not None \
        else uniform_partition(cfg.n_layers, n_chunks)
    weights_x = _stage_param_array(cfg, part, pp, vpp) / conf.tp \
        * BYTES_PER_PARAM_STATE
    chunk_act = part.stage_sums(_layer_act_bytes(cfg, w.seq, conf.bs_micro)) \
        / conf.tp / conf.cp
    v = np.arange(vpp)[:, None]
    x = np.arange(pp)[None, :]
    inflight = np.minimum(n_chunks - (v * pp + x), conf.n_mb)
    acts_x = (chunk_act.reshape(vpp, pp) * inflight).sum(axis=0)
    wa = float((weights_x + acts_x).max())

    sizes = np.asarray(part.sizes).reshape(vpp, pp).sum(axis=0)
    layers_stage = int(sizes.max())
    ring_kv = 0.0
    if conf.cp > 1:
        block = ring_kv_block_bytes(cfg, conf.bs_micro, w.seq, conf.cp)
        ring_kv = 2.0 * layers_stage * block
    logits = conf.bs_micro * w.seq * cfg.vocab_size * 4.0 * 2 \
        / conf.tp / conf.cp
    framework = (1.1e9                                  # runtime context
                 + 0.15e9                               # collective buffers
                 + 8e6 * (conf.tp + conf.pp)            # per-communicator
                 + 8e6 * (conf.cp - 1)                  # cp ring communicator
                 + 8e6 * (conf.vpp - 1)                 # per-chunk buffers
                 + 24e6 * np.log2(conf.dp + 1)          # ring channels
                 + 0.45e9)                              # kernel workspace
    frag = 0.06 * wa
    residual = _config_residual(cfg, conf, spec, partition)
    return wa + ring_kv + logits + framework + frag + residual


def ground_truth_memory(w: Workload, conf: Conf, spec: ClusterSpec,
                        partition: Optional[Partition] = None) -> float:
    """'Measured' peak bytes per GPU for this configuration.

    With a non-uniform ``partition`` (or ``conf.vpp > 1``) the peak is the
    *worst stage's* (:func:`_ground_truth_nonuniform`); the default is the
    bit-exact legacy uniform-split model."""
    if partition is not None or conf.vpp > 1:
        return _ground_truth_nonuniform(w, conf, spec, partition)
    cfg = w.cfg
    weights = _stage_params(cfg, conf.pp) / conf.tp * BYTES_PER_PARAM_STATE
    inflight = min(conf.pp, conf.n_mb)
    acts = _act_bytes_per_mb(cfg, conf, w.seq) * inflight
    ring_kv = _ring_kv_bytes(cfg, conf, w.seq)
    logits = conf.bs_micro * w.seq * cfg.vocab_size * 4.0 * 2 \
        / conf.tp / conf.cp
    framework = (1.1e9                                  # runtime context
                 + 0.15e9                               # collective buffers
                 + 8e6 * (conf.tp + conf.pp)            # per-communicator
                 + 8e6 * (conf.cp - 1)                  # cp ring communicator
                 + 24e6 * np.log2(conf.dp + 1)          # ring channels
                 + 0.45e9)                              # kernel workspace
    frag = 0.06 * (weights + acts)
    residual = _config_residual(cfg, conf, spec)
    return weights + acts + ring_kv + logits + framework + frag + residual


def rank_state_bytes(cfg: ModelConfig, conf: Conf,
                     partition: Optional[Partition] = None) -> np.ndarray:
    """Per-GPU resident parameter + optimizer-state bytes, by pipeline stage.

    Entry ``x`` is what one GPU serving physical stage ``x`` holds on disk
    and in HBM across restarts: its chunk layers' parameters (interleaved
    stages host chunks ``x, x + pp, ...``), the embedding / LM-head /
    shared-block extras, divided by ``tp`` (tensor parallelism shards every
    weight) and multiplied by :data:`BYTES_PER_PARAM_STATE` (bf16
    param+grad plus fp32 master/m/v).  dp and cp *replicate* this state, so
    the number is per-GPU regardless of those degrees — it is the shard a
    migrated rank must fetch when a re-plan changes its stage or tp slice
    (the migration-cost model in :mod:`~repro.core.migration`).

    Args:
        cfg: model configuration.
        conf: parallelism configuration.
        partition: non-uniform chunk partition (``None`` = the uniform
            ceil-first split).

    Returns:
        ``(pp,)`` float64 array of bytes per GPU.
    """
    part = partition if partition is not None \
        else uniform_partition(cfg.n_layers, conf.pp * conf.vpp)
    stage_params = _stage_param_array(cfg, part, conf.pp, conf.vpp)
    return stage_params / conf.tp * BYTES_PER_PARAM_STATE


def analytical_estimate(w: Workload, conf: Conf) -> float:
    """The baseline estimator [20]: weights + one microbatch of activations.

    It ignores 1F1B in-flight multiplicity, logits workspace and every
    framework/library overhead — which is why it underestimates badly
    (paper Fig. 7: 59-66% MAPE)."""
    cfg = w.cfg
    weights = _stage_params(cfg, conf.pp) / conf.tp * BYTES_PER_PARAM_STATE
    acts = _act_bytes_per_mb(cfg, conf, w.seq)
    return weights + acts


# ---------------------------------------------------------------------------
# MLP estimator (Eq. 7)
# ---------------------------------------------------------------------------

def _features(cfg: ModelConfig, conf: Conf, *,
              with_cp: bool = False) -> np.ndarray:
    return _features_batch(cfg, [conf], with_cp=with_cp)[0]


def _features_batch(cfg: ModelConfig, confs: Sequence[Conf], *,
                    with_cp: bool = False) -> np.ndarray:
    """Feature matrix for many configurations in one shot.

    The single source of the feature order; the scalar :func:`_features` is
    its one-row special case (bit-for-bit — same elementwise ``np.log``
    over float64).  ``with_cp`` appends an 11th ``log(cp)`` column —
    estimators fit on the 3D space (``with_cp=False``, the default) keep
    the historical 10-column layout and therefore reproduce their
    predictions exactly.

    Args:
        cfg: model configuration (shared by all rows).
        confs: parallelism configurations.
        with_cp: include the context-parallel degree as a feature.

    Returns:
        ``(len(confs), 10 or 11)`` float64 array.
    """
    v = np.asarray(
        [[c.n_gpus, cfg.n_layers, cfg.d_model, max(cfg.n_heads, 1),
          c.tp, c.pp, c.dp, c.bs_micro, c.bs_mini, c.bs_global]
         + ([c.cp] if with_cp else [])
         for c in confs], np.float64)
    return np.log(v)


@dataclass
class MemoryEstimator:
    """MLP(n_gpus, n_layers, n_hidden, n_heads, tp, pp, dp, bs_micro,
    bs_mini, bs_global) -> peak bytes, with a soft safety margin.

    ``residual=True`` is a beyond-paper variant: the MLP learns
    log(actual / analytical) instead of log(actual), anchoring the
    extrapolation to the analytical power-law structure (EXPERIMENTS.md
    §Fig7 reports both)."""
    params: list
    x_mean: np.ndarray
    x_std: np.ndarray
    y_mean: float
    y_std: float
    soft_margin: float = 0.92
    residual: bool = False
    workload_seq: int = 2048
    # 4D support: True when the fit included the log(cp) feature column.
    with_cp: bool = False
    # Fit provenance (0 = unknown/legacy) — lets runtime.elastic.replan
    # detect that the cluster it is re-planning for no longer matches the
    # hardware this estimator was fit on.
    fit_gpu_mem: float = 0.0
    fit_gpus_per_node: int = 0

    def predict_batch(self, cfg: ModelConfig,
                      confs: Sequence[Conf]) -> np.ndarray:
        """Predicted peak bytes/GPU for many configurations at once.

        One jitted :func:`~repro.core.mlp.mlp_forward_jit` call on the whole
        ``(N, F)`` feature matrix (zero-padded to a power-of-two row bucket so
        varying candidate-set sizes reuse a handful of XLA traces).  Row ``i``
        is bit-identical to ``predict(cfg, confs[i])`` — the scalar API is a
        one-row special case of this path.

        Args:
            cfg: model configuration shared by every candidate.
            confs: parallelism configurations to score.

        Returns:
            ``(len(confs),)`` float64 array of predicted peak bytes/GPU.
        """
        if not len(confs):
            return np.zeros(0)
        if not self.with_cp and any(c.cp > 1 for c in confs):
            raise ValueError(
                "estimator was fit on the 3D (cp=1) feature space but got a "
                "cp>1 configuration; refit with fit_memory_estimator("
                "max_cp=...) to score 4D candidates")
        x = (_features_batch(cfg, confs, with_cp=self.with_cp)
             - self.x_mean) / self.x_std
        xb = pad_batch_rows(x.astype(np.float32))
        out = mlp_forward_jit(self.params, jnp.asarray(xb))
        y = np.asarray(out[:len(confs), 0], np.float64)
        pred = np.exp(y * self.y_std + self.y_mean)
        if self.residual:
            pred = pred * np.asarray(
                [analytical_estimate(Workload(cfg, self.workload_seq,
                                              c.bs_global), c)
                 for c in confs])
        return pred

    def predict(self, cfg: ModelConfig, conf: Conf) -> float:
        """Scalar API, re-expressed over :meth:`predict_batch`."""
        return float(self.predict_batch(cfg, [conf])[0])

    def fits(self, cfg: ModelConfig, conf: Conf, mem_limit: float) -> bool:
        return self.predict(cfg, conf) <= mem_limit * self.soft_margin

    def fits_spec(self, cfg: ModelConfig, conf: Conf,
                  spec: ClusterSpec) -> bool:
        """Capacity check against every GPU's *own* memory.

        Pipette's 1:1 dedication places a worker on every GPU, and the
        predicted peak is a worst-GPU number — so "each GPU's capacity"
        collapses to the tightest device tier (``spec.mem_floor``, which is
        exactly ``gpu_mem`` on homogeneous specs).  This is the check the
        search pipeline applies by default on tiered clusters."""
        return self.fits(cfg, conf, spec.mem_floor)


def enumerate_confs(n_gpus: int, bs_global: int, *, max_tp: int = 0,
                    n_layers: int = 10 ** 9, max_cp: int = 1, seq: int = 0,
                    max_vpp: int = 1, strict: bool = True) -> List[Conf]:
    """All valid (pp, tp, cp, dp, bs_micro) with ``pp*tp*cp*dp == n_gpus``.

    With the default ``max_cp=1`` the context-parallel axis collapses and
    the enumeration order is the historical 3D one.  ``strict`` (default)
    drops configurations the memory-efficient 1F1B schedule cannot fill
    (``n_mb < pp``): the pipeline would idle below depth and the Eq. 3-6
    exposure count ``n_mb / pp`` goes sub-1, silently mis-scoring them
    (Megatron-LM's schedule-validity constraint).  Pass ``strict=False``
    to reproduce the unfiltered space (ablations / legacy comparisons).

    Args:
        n_gpus: total GPU count to factorize.
        bs_global: global batch size (dp must divide it; every divisor of
            the minibatch becomes a microbatch candidate).
        max_tp: optional upper bound on tensor parallelism (0 = unbounded).
        n_layers: pp may not exceed the layer count.
        max_cp: upper bound on context parallelism (1 = 3D space).
        seq: sequence length; required for ``max_cp > 1`` (ring attention
            needs ``seq % cp == 0``), ignored otherwise.
        max_vpp: upper bound on the interleaved-1F1B virtual-pipeline
            factor.  The default (1) emits only plain-1F1B configurations
            in the historical order; larger values append, right after
            each base configuration, its ``vpp`` variants that satisfy
            Megatron's interleaving constraints (``pp > 1``,
            ``n_mb % pp == 0``, ``n_layers >= pp * vpp``).
        strict: filter schedule-invalid ``n_mb < pp`` configurations.

    Returns:
        List of :class:`~repro.core.simulator.Conf`; every entry satisfies
        ``conf.valid()`` and, under ``strict``, ``conf.schedulable()``.
    """
    out = []
    for pp in range(1, n_gpus + 1):
        if n_gpus % pp or pp > n_layers:
            continue
        rest = n_gpus // pp
        for tp in range(1, rest + 1):
            if rest % tp or (max_tp and tp > max_tp):
                continue
            rest_cd = rest // tp
            for cp in range(1, min(max_cp, rest_cd) + 1):
                if rest_cd % cp:
                    continue
                if cp > 1 and (seq <= 0 or seq % cp):
                    continue
                dp = rest_cd // cp
                if bs_global % dp:
                    continue
                bs_mini = bs_global // dp
                for mb in range(1, bs_mini + 1):
                    if bs_mini % mb:
                        continue
                    conf = Conf(pp, tp, dp, mb, bs_global, cp=cp)
                    if strict and conf.n_mb < pp:
                        continue
                    out.append(conf)
                    for vpp in range(2, max_vpp + 1):
                        if pp <= 1 or pp * vpp > n_layers:
                            continue
                        cv = Conf(pp, tp, dp, mb, bs_global, cp=cp, vpp=vpp)
                        if not cv.schedulable():
                            continue
                        out.append(cv)
    return out


def profile_memory_dataset(workloads: Sequence[Workload], spec: ClusterSpec,
                           *, fit_nodes: int = 4,
                           max_cp: int = 1) -> Tuple[np.ndarray, np.ndarray, list]:
    """Profiled (features, log-bytes) pairs from configs on <= fit_nodes.

    ``max_cp > 1`` extends the profiled space to 4D (and switches the
    feature layout to the 11-column ``with_cp`` variant).

    Profiling deliberately uses ``strict=False``: peak memory is
    well-defined for any allocatable configuration (the profiler runs a
    single microbatch, not a full 1F1B iteration), and the extra ``n_mb <
    pp`` points anchor the fit exactly where the batch-size features are
    most extreme.  Only the *search* applies the schedule-validity gate."""
    xs, ys, meta = [], [], []
    with_cp = max_cp > 1
    for w in workloads:
        for g_nodes in range(1, fit_nodes + 1):
            g = g_nodes * spec.gpus_per_node
            for conf in enumerate_confs(g, w.bs_global,
                                        max_tp=spec.gpus_per_node,
                                        n_layers=w.cfg.n_layers,
                                        max_cp=max_cp, seq=w.seq,
                                        strict=False):
                if conf.bs_micro > 16:
                    continue
                xs.append(_features(w.cfg, conf, with_cp=with_cp))
                ys.append(np.log(ground_truth_memory(w, conf, spec)))
                meta.append((w, conf))
    return np.asarray(xs), np.asarray(ys), meta


def fit_memory_estimator(workloads: Sequence[Workload], spec: ClusterSpec, *,
                         fit_nodes: int = 4, steps: int = 20_000,
                         hidden: int = 200, depth: int = 5,
                         seed: int = 0, residual: bool = False,
                         max_cp: int = 1) -> MemoryEstimator:
    """Train the §VI MLP memory estimator on small-scale profiles.

    Args:
        workloads: workloads to profile (configs on <= ``fit_nodes`` nodes).
        spec: cluster description.
        fit_nodes: profiling budget in nodes (paper: 4 nodes / 32 GPUs);
            the estimator must extrapolate beyond it.
        steps / hidden / depth: MLP training schedule and architecture
            (paper: 5 layers x 200 hidden units).
        seed: init/training seed.
        residual: beyond-paper variant — learn log(actual / analytical)
            instead of log(actual), anchoring extrapolation.
        max_cp: profile the 4D space up to this context-parallel degree and
            include the log(cp) feature.  The default (1) reproduces the 3D
            estimator bit-for-bit; such an estimator refuses cp>1 queries.

    Returns:
        Fitted :class:`MemoryEstimator`.
    """
    import jax
    import jax.numpy as jnp
    from .mlp import init_mlp, train_mlp

    x, y, meta = profile_memory_dataset(workloads, spec, fit_nodes=fit_nodes,
                                        max_cp=max_cp)
    if residual:
        base = np.array([np.log(analytical_estimate(w, c)) for w, c in meta])
        y = y - base
    xm, xs = x.mean(0), x.std(0) + 1e-9
    ym, ys = y.mean(), y.std() + 1e-9
    xn = ((x - xm) / xs).astype(np.float32)
    yn = ((y - ym) / ys).astype(np.float32)
    sizes = [x.shape[1]] + [hidden] * (depth - 1) + [1]
    params = init_mlp(jax.random.PRNGKey(seed), sizes)
    params = train_mlp(params, jnp.asarray(xn), jnp.asarray(yn), steps=steps)
    return MemoryEstimator(params, xm, xs, float(ym), float(ys),
                           residual=residual,
                           workload_seq=workloads[0].seq,
                           with_cp=max_cp > 1,
                           fit_gpu_mem=spec.gpu_mem,
                           fit_gpus_per_node=spec.gpus_per_node)


def mape(pred: Iterable[float], true: Iterable[float]) -> float:
    """Mean absolute percentage error (%), the paper's estimator metric."""
    p = np.asarray(list(pred), float)
    t = np.asarray(list(true), float)
    return float(np.mean(np.abs(p - t) / t) * 100.0)
