"""Migration-cost model: what switching from one plan to another *costs*.

Elastic re-planning under churn cannot score candidate plans by step time
alone: a plan that is 3% faster but re-shards every checkpoint shard
across the cluster loses to a 1%-faster plan reachable by moving two
ranks.  This module prices the switch.

The unit of migration is a GPU's **resident state identity**: the set of
model layers whose parameter/optimizer shards it holds and its tensor-
parallel slice of them — ``(layers of its stage's chunks, tp rank, tp
degree)``.  dp and cp replicate that state (dp replicates weights across
minibatch shards, cp across sequence shards), so moving a GPU between dp
or cp positions of the same ``(stage, tp)`` slot is *free*: nothing has
to be re-fetched.  A GPU "moves" when its state identity under the new
plan differs from the old one — then it must fetch its new shard
(:func:`~repro.core.memory.rank_state_bytes`) from surviving replicas or
the checkpoint before training resumes.

Downtime is modelled as a restart barrier (process re-spawn, collective
re-initialisation, data-loader reposition — paid once if *anything*
moved) plus the aggregate shard transfer through the cluster's inter-node
fabric (each healthy node contributes one ``inter_bw`` link of ingress).

:meth:`repro.core.plan.Plan.diff` is the artifact-level entry point;
``python -m repro.plan diff a.json b.json`` surfaces it on the CLI, and
the churn simulator (:mod:`repro.runtime.churn`) integrates these
downtimes into whole-trace throughput.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ..models.config import ModelConfig
from .memory import rank_state_bytes
from .partition import Partition, uniform_partition
from .simulator import Conf, mapping4

#: Default restart barrier seconds paid once whenever any rank moves:
#: process re-spawn + NCCL/collective re-init + checkpoint metadata load.
DEFAULT_RESTART_S = 10.0


@dataclass(frozen=True)
class PlanDiff:
    """What migrating from plan A (incumbent) to plan B costs.

    Attributes:
        ranks_total: GPUs participating in plan B.
        ranks_moved: GPUs present in both plans whose resident state
            identity changed — they must re-fetch their shard.
        ranks_added: GPUs in plan B that were not in plan A (node
            joins/returns); each fetches its full shard.
        ranks_removed: GPUs in plan A absent from plan B (preemptions);
            their state is simply abandoned, no transfer.
        bytes_migrated: total parameter+optimizer bytes fetched by moved
            and added ranks (their *new* shard sizes).
        downtime_s: estimated training stall for the switch (restart
            barrier + aggregate shard transfer).
        conf_changed: the parallelism configuration itself differs.
    """
    ranks_total: int
    ranks_moved: int
    ranks_added: int
    ranks_removed: int
    bytes_migrated: float
    downtime_s: float
    conf_changed: bool

    @property
    def is_noop(self) -> bool:
        """True when nothing moves: plan B resumes without a stall."""
        return self.ranks_moved == 0 and self.ranks_added == 0


def _stage_layer_sets(cfg: ModelConfig, conf: Conf,
                      partition: Optional[Partition]
                      ) -> Tuple[Tuple[int, ...], ...]:
    """Per physical stage, the sorted tuple of layer ids it hosts (its
    chunks ``x, x + pp, ...`` under the Megatron interleaved layout)."""
    part = partition if partition is not None \
        else uniform_partition(cfg.n_layers, conf.pp * conf.vpp)
    slices = part.stage_slices()
    out = []
    for x in range(conf.pp):
        layers = []
        for v in range(conf.vpp):
            s = slices[v * conf.pp + x]
            layers.extend(range(s.start, s.stop))
        out.append(tuple(sorted(layers)))
    return tuple(out)


def state_keys(cfg: ModelConfig, conf: Conf, mapping: np.ndarray,
               partition: Optional[Partition] = None
               ) -> Dict[int, Tuple]:
    """GPU id -> resident state identity ``(stage layers, tp rank, tp)``.

    Two GPUs (possibly the same GPU under two plans) hold byte-identical
    parameter/optimizer shards iff their keys are equal — the predicate
    behind :func:`diff_assignments`' moved-rank count.
    """
    m4 = mapping4(conf, mapping)
    layer_sets = _stage_layer_sets(cfg, conf, partition)
    keys: Dict[int, Tuple] = {}
    for x in range(conf.pp):
        key_base = layer_sets[x]
        for y in range(conf.tp):
            key = (key_base, y, conf.tp)
            for g in m4[x, y].reshape(-1):
                keys[int(g)] = key
    return keys


def _stage_of(cfg: ModelConfig, conf: Conf, mapping: np.ndarray
              ) -> Dict[int, int]:
    """GPU id -> physical stage index under ``mapping``."""
    m4 = mapping4(conf, mapping)
    return {int(g): x for x in range(conf.pp)
            for g in m4[x].reshape(-1)}


def diff_assignments(cfg: ModelConfig,
                     conf_a: Conf, mapping_a: np.ndarray,
                     conf_b: Conf, mapping_b: np.ndarray, *,
                     partition_a: Optional[Partition] = None,
                     partition_b: Optional[Partition] = None,
                     b_to_a: Optional[Sequence[int]] = None,
                     n_nodes: Optional[int] = None,
                     inter_bw: float = 12.5e9,
                     restart_s: float = DEFAULT_RESTART_S) -> PlanDiff:
    """Migration cost of switching from assignment A to assignment B.

    Args:
        cfg: model configuration (shared — shards are priced on it).
        conf_a / mapping_a / partition_a: the incumbent plan's
            configuration, worker mapping and chunk partition.
        conf_b / mapping_b / partition_b: the successor plan's.
        b_to_a: for fleets whose GPU id spaces differ (shrink/grow),
            entry ``i`` is plan-B GPU ``i``'s id in plan A's numbering, or
            ``-1`` for a brand-new GPU.  Default: identity on the common
            prefix (``with_nodes`` truncation semantics), new ids beyond
            plan A's range.
        n_nodes: healthy node count of plan B's fleet (aggregate ingress
            capacity of the transfer phase); inferred as ``ranks_total /
            8`` when omitted — pass it for non-default node widths.
        inter_bw: per-node inter-node bandwidth, bytes/s.
        restart_s: fixed restart barrier paid once if anything moved.

    Returns:
        :class:`PlanDiff`; ``diff(A, A)`` is exactly a no-op.
    """
    keys_a = state_keys(cfg, conf_a, mapping_a, partition_a)
    keys_b = state_keys(cfg, conf_b, mapping_b, partition_b)
    n_b = conf_b.n_gpus
    if b_to_a is None:
        b_to_a = [g if g < conf_a.n_gpus else -1 for g in range(n_b)]
    if len(b_to_a) != n_b:
        raise ValueError(
            f"b_to_a must map every plan-B GPU: expected {n_b} entries, "
            f"got {len(b_to_a)}")
    shard_b = rank_state_bytes(cfg, conf_b, partition_b)
    stage_b = _stage_of(cfg, conf_b, mapping_b)

    moved = added = 0
    fetch_bytes = []
    mapped_a = set()
    for g_b in range(n_b):
        g_a = int(b_to_a[g_b])
        bytes_g = float(shard_b[stage_b[g_b]])
        if g_a < 0 or g_a not in keys_a:
            added += 1
            fetch_bytes.append(bytes_g)
            continue
        mapped_a.add(g_a)
        if keys_a[g_a] != keys_b[g_b]:
            moved += 1
            fetch_bytes.append(bytes_g)
    removed = len([g for g in keys_a if g not in mapped_a])

    bytes_migrated = math.fsum(fetch_bytes)
    nodes = n_nodes if n_nodes is not None else max(1, n_b // 8)
    downtime = 0.0
    if moved + added:
        downtime = restart_s + bytes_migrated / (nodes * inter_bw)
    return PlanDiff(ranks_total=n_b, ranks_moved=moved, ranks_added=added,
                    ranks_removed=removed, bytes_migrated=bytes_migrated,
                    downtime_s=downtime,
                    conf_changed=conf_a != conf_b)


def resolve_model(name: str) -> ModelConfig:
    """A :class:`ModelConfig` from a Plan's recorded provenance name.

    Looks the name up in the architecture registry; ``<name>-smoke`` (the
    ``reduced()`` naming convention) resolves through the base config's
    :meth:`~repro.models.config.ModelConfig.reduced`.  Raises ``KeyError``
    for names the registry cannot produce — callers with an out-of-registry
    config pass it explicitly instead.
    """
    from .. import configs
    try:
        return configs.get(name)
    except KeyError:
        if name.endswith("-smoke"):
            return configs.get(name[:-len("-smoke")]).reduced()
        raise
