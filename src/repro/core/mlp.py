"""Minimal JAX MLP + Adam used by the memory estimator (paper §VI: five
layers, 200 hidden units, trained on profiled configurations)."""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def init_mlp(key, sizes: List[int]):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, k1 = jax.random.split(key)
        w = jax.random.normal(k1, (a, b), jnp.float32) * np.sqrt(2.0 / a)
        params.append({"w": w, "b": jnp.zeros((b,), jnp.float32)})
    return params


def mlp_forward(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i + 1 < len(params):
            x = jax.nn.gelu(x)
    return x


# Jitted forward shared by every estimator instance.  jax.jit caches one
# trace per (param tree structure, batch shape); callers that pad batches to
# power-of-two buckets therefore hit a handful of traces total, and repeated
# ``configure()`` calls reuse them instead of re-tracing per candidate.
mlp_forward_jit = jax.jit(mlp_forward)


def pad_batch_rows(x: np.ndarray, minimum: int = 8) -> np.ndarray:
    """Zero-pad ``x`` along axis 0 to the next power-of-two row count.

    Bounds the number of distinct batch shapes :data:`mlp_forward_jit` ever
    sees (log2 of the largest batch), so candidate-set sizes that vary from
    call to call do not each pay an XLA retrace.  Row ``i`` of the padded
    forward is bit-identical to row ``i`` of the unpadded one (row-wise
    independence of the matmuls).

    Args:
        x: ``(n, f)`` feature matrix.
        minimum: smallest bucket size.

    Returns:
        ``(m, f)`` array with ``m = max(minimum, 2**ceil(log2(n)))``.
    """
    n = x.shape[0]
    m = max(minimum, 1 << (n - 1).bit_length())
    if m == n:
        return x
    return np.concatenate(
        [x, np.zeros((m - n,) + x.shape[1:], x.dtype)], axis=0)


@functools.partial(jax.jit, static_argnames=("steps", "lr"))
def train_mlp(params, x, y, *, steps: int = 20_000, lr: float = 1e-3):
    """Full-batch Adam regression on (x, y) with cosine LR decay."""
    def loss_fn(p):
        pred = mlp_forward(p, x)[:, 0]
        return jnp.mean((pred - y) ** 2)

    def adam_step(state, _):
        p, m, v, t = state
        g = jax.grad(loss_fn)(p)
        t = t + 1
        cur_lr = lr * (0.02 + 0.98 * 0.5 *
                       (1 + jnp.cos(jnp.pi * t / steps)))
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree.map(lambda a: a / (1 - 0.9 ** t), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.999 ** t), v)
        p = jax.tree.map(
            lambda a, mm, vv: a - cur_lr * mm / (jnp.sqrt(vv) + 1e-8),
            p, mh, vh)
        return (p, m, v, t), None

    zeros = jax.tree.map(jnp.zeros_like, params)
    state = (params, zeros, jax.tree.map(jnp.zeros_like, params),
             jnp.zeros((), jnp.float32))
    state, _ = jax.lax.scan(adam_step, state, None, length=steps)
    return state[0]
