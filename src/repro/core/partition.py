"""Non-uniform pipeline partitions: the `Partition` artifact and the DP
balanced-partition solver.

The paper's Eq. 3-6 model (and the seed's whole stack) assumes a ceil/floor
uniform layer split per pipeline stage.  That is exactly wrong for the
model zoo this repo carries: kimi_k2 interleaves cheap routed-MoE layers
with a vocabulary GEMM ~2.5 layer-equivalents heavy at each end, and
zamba2/falcon_mamba hybrids apply a shared attention block every
``hybrid_attn_period``-th layer, making those layers several times more
expensive than their mamba neighbours.  This module turns the per-layer
cost vector (``core/flops.py``) into stage boundaries that minimize the
*heaviest* stage — the quantity the 1F1B steady state is paced by
(``_hetero_combine``'s ``c_max``).

Solver contract (locked by ``tests/test_partition.py``):

* exact DP over contiguous splits, O(pp * L^2) — minimizes the max stage
  cost, tie-broken by the minimal sum of squared stage costs;
* reconstruction walks left-to-right taking the *largest* stage size among
  optimal continuations, so a uniform cost vector (zero endpoint costs)
  degenerates to exactly the legacy ceil-first split of
  ``stage_work(n_layers, pp)``;
* ``head_cost`` / ``tail_cost`` model work pinned to the end stages (the
  embedding and LM-head GEMMs) that the uniform model amortized ``1/pp``.

Everything here is pure host-side NumPy/Python — deterministic by
construction, no RNG, no wall clock.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from . import flops as F
from ..models.config import ModelConfig

#: Schedule names a Conf can carry (``Conf.schedule``); the plan verifier's
#: PLN009 rule rejects anything else.
SCHEDULES = ("1f1b", "interleaved-1f1b")

#: Partition modes a SearchSpace can request.
PARTITION_MODES = ("uniform", "dp")


@dataclass(frozen=True)
class Partition:
    """A contiguous layer-to-stage assignment.

    ``boundaries`` are cumulative layer counts: stage ``x`` owns layers
    ``[boundaries[x-1], boundaries[x])`` (with an implicit leading 0), so
    ``len(boundaries) == pp`` and ``boundaries[-1] == n_layers``.
    """
    n_layers: int
    boundaries: Tuple[int, ...]

    def __post_init__(self):
        if self.n_layers <= 0:
            raise ValueError("n_layers must be positive")
        b = self.boundaries
        if not b or b[-1] != self.n_layers:
            raise ValueError("boundaries must cover exactly n_layers")
        if b[0] < 1 or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError("boundaries must be strictly increasing")

    @property
    def pp(self) -> int:
        return len(self.boundaries)

    @property
    def sizes(self) -> Tuple[int, ...]:
        """Per-stage layer counts."""
        prev, out = 0, []
        for b in self.boundaries:
            out.append(b - prev)
            prev = b
        return tuple(out)

    def stage_slices(self) -> Tuple[slice, ...]:
        prev, out = 0, []
        for b in self.boundaries:
            out.append(slice(prev, b))
            prev = b
        return tuple(out)

    def stage_sums(self, per_layer: np.ndarray) -> np.ndarray:
        """Sum a per-layer vector over each stage."""
        csum = np.concatenate(([0.0], np.cumsum(np.asarray(per_layer,
                                                           np.float64))))
        b = np.asarray((0,) + self.boundaries)
        return csum[b[1:]] - csum[b[:-1]]

    def is_uniform(self) -> bool:
        """True iff this is exactly the legacy ceil-first split."""
        return self == uniform_partition(self.n_layers, self.pp)

    def to_json_dict(self) -> dict:
        return {"n_layers": self.n_layers,
                "boundaries": list(self.boundaries)}

    @classmethod
    def from_json_dict(cls, d: dict) -> "Partition":
        return cls(n_layers=int(d["n_layers"]),
                   boundaries=tuple(int(x) for x in d["boundaries"]))


def uniform_partition(n_layers: int, pp: int) -> Partition:
    """The legacy ceil-first split: the first ``n_layers % pp`` stages get
    ``ceil(n_layers / pp)`` layers, the rest ``floor`` (matches
    ``stage_work``'s two-value convention)."""
    base, rem = divmod(n_layers, pp)
    sizes = [base + 1 if x < rem else base for x in range(pp)]
    return Partition(n_layers, tuple(np.cumsum(sizes).tolist()))


def balanced_partition(costs: Sequence[float], pp: int, *,
                       head_cost: float = 0.0,
                       tail_cost: float = 0.0) -> Partition:
    """Exact DP min-max contiguous partition of ``costs`` into ``pp``
    stages; ``head_cost``/``tail_cost`` are added to stage 0 / stage pp-1.

    Objective is lexicographic ``(max stage cost, sum of squared stage
    costs)``; among optimal splits the reconstruction prefers the largest
    leading stage, so uniform costs with zero endpoints return exactly
    ``uniform_partition`` (the degeneration contract)."""
    c = np.asarray(costs, dtype=np.float64)
    L = len(c)
    if not 1 <= pp <= L:
        raise ValueError(f"need 1 <= pp <= n_layers, got pp={pp}, L={L}")
    csum = np.concatenate(([0.0], np.cumsum(c)))

    def seg(i: int, j: int, s: int) -> float:
        cost = float(csum[j] - csum[i])
        if s == 0:
            cost += head_cost
        if s == pp - 1:
            cost += tail_cost
        return cost

    inf = float("inf")
    # f[s][i] = best (max, sumsq) splitting layers[i:] into stages s..pp-1
    f: list = [dict() for _ in range(pp + 1)]
    f[pp] = {L: (0.0, 0.0)}
    for s in range(pp - 1, -1, -1):
        lo = s                      # at least one layer per earlier stage
        hi = L - (pp - s)           # leave one layer per later stage
        for i in range(lo, hi + 1):
            best = (inf, inf)
            for j in range(i + 1, L - (pp - s - 1) + 1):
                nxt = f[s + 1].get(j)
                if nxt is None:
                    continue
                cost = seg(i, j, s)
                cand = (max(cost, nxt[0]), cost * cost + nxt[1])
                if cand < best:
                    best = cand
            f[s][i] = best

    bounds = []
    i = 0
    for s in range(pp):
        target = f[s][i]
        pick = None
        for j in range(i + 1, L - (pp - s - 1) + 1):
            nxt = f[s + 1].get(j)
            if nxt is None:
                continue
            cost = seg(i, j, s)
            if (max(cost, nxt[0]), cost * cost + nxt[1]) == target:
                pick = j            # keep scanning: largest j wins ties
        assert pick is not None, "DP reconstruction lost the optimum"
        bounds.append(pick)
        i = pick
    return Partition(L, tuple(bounds))


def make_partition(cfg: ModelConfig, pp: int, seq: int,
                   mode: str = "uniform") -> Partition:
    """Build the partition for one pipeline depth.

    ``"uniform"`` is the legacy ceil-first split; ``"dp"`` balances the
    per-layer cost vector with the embedding/LM-head GEMMs pinned to the
    end stages."""
    if mode not in PARTITION_MODES:
        raise ValueError(f"unknown partition mode {mode!r} "
                         f"(choose from {PARTITION_MODES})")
    if mode == "uniform":
        return uniform_partition(cfg.n_layers, pp)
    e = F.embed_cost_per_token(cfg)
    return balanced_partition(F.layer_cost_per_token(cfg, seq), pp,
                              head_cost=e, tail_cost=e)


def resolve_partition(cfg: ModelConfig, pp: int, seq: int,
                      mode: str = "uniform") -> Optional[Partition]:
    """``make_partition``, degenerated: returns None whenever the chosen
    boundaries equal the legacy ceil-first split, so every consumer can
    gate its bit-exact historical path on ``partition is None``."""
    if mode == "uniform" or pp <= 1:
        return None
    part = make_partition(cfg, pp, seq, mode)
    return None if part.is_uniform() else part


class PartitionCache:
    """Memoizes ``resolve_partition`` per pipeline depth (the partition
    depends only on ``pp`` for a fixed workload + mode)."""

    def __init__(self, cfg: ModelConfig, seq: int, mode: str = "uniform"):
        if mode not in PARTITION_MODES:
            raise ValueError(f"unknown partition mode {mode!r} "
                             f"(choose from {PARTITION_MODES})")
        self.cfg, self.seq, self.mode = cfg, seq, mode
        self._by_pp: Dict[int, Optional[Partition]] = {}

    def get(self, pp: int) -> Optional[Partition]:
        if pp not in self._by_pp:
            self._by_pp[pp] = resolve_partition(self.cfg, pp, self.seq,
                                                self.mode)
        return self._by_pp[pp]
