"""The Planner API: declarative request -> pluggable strategy -> Plan.

One search pipeline (enumerate -> memory-prune -> pre-score -> dedicate,
Alg. 1) serves initial configuration, baseline comparison, and elastic
re-planning — so the public API is built around three pieces:

1. a **declarative request**: :class:`SearchSpace` (strategy-agnostic
   space knobs), :class:`Budget` (SA budget), and
   :class:`PlanRequest` (workload + cluster + space + budget + seed),
   replacing the historical 15-kwarg ``configure()`` pile;
2. a **pluggable strategy**: the :class:`Strategy` protocol, implemented
   by :class:`PipetteStrategy` (the five-stage pipeline),
   :class:`ExhaustiveStrategy` (the PPT-L ``dedicate=False`` ablation),
   and the AMP / Varuna / Megatron-LM baselines re-homed behind the same
   interface — ``Planner(strategy).plan(request, bw)`` is the one entry
   point for all of them;
3. a **serializable artifact**: :class:`Plan` — best conf + mapping +
   latency + memory prediction, the ranked top-k, the deterministic
   overhead counters, and provenance (bandwidth-matrix digest, estimator
   fit provenance, seed, strategy name) — with a byte-reproducible JSON
   round trip (:meth:`Plan.save` / :meth:`Plan.load`) consumed by
   ``launch.mesh.mesh_from_plan``, ``runtime.elastic.replan``, and
   ``runtime.trainer``.

The legacy ``configure()`` remains as a thin, bit-exact shim over
``Planner(PipetteStrategy())`` (see ``search.py``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from dataclasses import dataclass, field
from typing import ClassVar, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from .baselines import amp_configure, mlm_configure, varuna_configure
from .cluster import ClusterSpec, tier_fingerprint
from .memory import MemoryEstimator
from .partition import PARTITION_MODES, Partition
from .search import Candidate, Overhead, SearchResult, run_search
from .simulator import Conf, Workload

# 2: heterogeneous-compute provenance — ``provenance.tiers`` records the
#    device-tier table digest, the table itself, and the node assignment
#    (null for homogeneous clusters).
# 3: backend-selectable SA core — ``provenance.budget`` grows ``backend``
#    (null = historical per-candidate driver, "numpy"/"jax" = the unified
#    MovePlan core) and ``hierarchical`` (island search; null = auto by
#    fleet size).
# 4: non-uniform pipeline partitions + interleaved-1F1B — confs grow
#    ``vpp``, candidates grow ``partition`` (the resolved stage-boundary
#    artifact, null = uniform layering) and ``schedule`` ("1f1b" /
#    "interleaved-1f1b"), ``provenance.space`` grows ``partition`` and
#    ``max_vpp``.
# 5: planning-as-a-service — ``provenance.budget`` grows ``warm_start``
#    (the incumbent GPU permutation that seeded every SA chain; null =
#    cold start), ``provenance`` grows ``lineage`` (how the serving layer
#    produced this plan: warm-start source fingerprint + neighbor
#    distance; null = a direct cold search), and ``overhead`` grows the
#    deterministic accepted-move counters ``sa_accepted`` /
#    ``sa_accepted_to_best`` (the warm-start economy metric).  Any
#    further change to the serialized shape MUST bump this
#    (tests/test_plan_golden.py enforces it).
PLAN_SCHEMA_VERSION = 5


# ---------------------------------------------------------------------------
# the declarative request
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SearchSpace:
    """Strategy-agnostic description of the candidate space.

    Attributes:
        max_cp: open the context-parallel axis up to this degree (1 —
            the default — is the paper's 3D space).
        max_tp: cap on tensor parallelism (0 = unbounded); useful to keep
            TP groups inside a node (``spec.gpus_per_node``).
        max_micro: skip configurations with ``bs_micro`` above this.
        fixed_micro: restrict to one microbatch size (ablations).
        partition: layer-to-stage partitioning mode — ``"uniform"``
            (the historical ceil-first split) or ``"dp"`` (the balanced
            min-max dynamic program over per-layer cost vectors).
        max_vpp: open interleaved-1F1B up to this many virtual pipeline
            chunks per stage (1 — the default — is plain 1F1B only).
    """
    max_cp: int = 1
    max_tp: int = 0
    max_micro: int = 16
    fixed_micro: Optional[int] = None
    partition: str = "uniform"
    max_vpp: int = 1

    def __post_init__(self):
        if self.max_cp < 1:
            raise ValueError(f"max_cp must be >= 1, got {self.max_cp}")
        if self.max_tp < 0 or self.max_micro < 1:
            raise ValueError("max_tp must be >= 0 and max_micro >= 1")
        if self.partition not in PARTITION_MODES:
            raise ValueError(
                f"partition must be one of {PARTITION_MODES}, "
                f"got {self.partition!r}")
        if self.max_vpp < 1:
            raise ValueError(f"max_vpp must be >= 1, got {self.max_vpp}")


@dataclass(frozen=True)
class Budget:
    """SA dedication budget (per candidate, split across chains).

    Attributes:
        sa_seconds / sa_iters: wall-clock / iteration caps per candidate
            (whichever bites first; use a large ``sa_seconds`` with a small
            ``sa_iters`` for deterministic, iteration-bound runs).
        n_chains: independent SA restarts per candidate, best-of.
        sa_topk: anneal only the ``k`` best pre-scored candidates; the
            rest keep their default mapping (``None`` = anneal every
            survivor).
        backend: SA execution engine.  ``None`` (default) keeps the
            historical per-candidate ``anneal``/``anneal_multistart``
            driver, bit-exact with its regression fixtures; ``"numpy"`` /
            ``"jax"`` select the unified :mod:`~repro.core.annealing`
            core (precomputed :class:`~repro.core.annealing.MovePlan`,
            exact chain budget split, optional hierarchical island
            search) executed incrementally on the host or as one vmapped
            ``lax.scan`` dispatch — the two produce byte-identical plans.
        hierarchical: island-decomposed search (coarse inter-island
            arrangement + within-island refinement; unified backends
            only).  ``None`` = auto: hierarchical at >= 2048 GPUs.
        warm_start: incumbent flat GPU permutation to seed every SA chain
            with (``None`` = cold start from the coarse/identity
            assignment).  Must be a permutation of ``range(n_gpus)``; the
            plan server derives it from a cached neighbor plan's mapping
            via :func:`~repro.core.dedication.mapping_to_perm`.  The seed
            only sets the *starting point* — move schedules are unchanged,
            and SA tracks best-so-far from the initial permutation, so a
            warm-started search never returns a worse plan than the
            incumbent it started from.
    """
    sa_seconds: float = 1.0
    sa_iters: int = 8_000
    n_chains: int = 1
    sa_topk: Optional[int] = None
    backend: Optional[str] = None
    hierarchical: Optional[bool] = None
    warm_start: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.sa_seconds <= 0 or self.sa_iters < 1 or self.n_chains < 1:
            raise ValueError("sa_seconds/sa_iters/n_chains must be positive")
        if self.backend not in (None, "numpy", "jax"):
            raise ValueError(
                f"backend must be None, 'numpy' or 'jax', "
                f"got {self.backend!r}")
        if self.hierarchical is not None \
                and not isinstance(self.hierarchical, bool):
            raise ValueError("hierarchical must be None or a bool")
        if self.warm_start is not None:
            ws = tuple(int(x) for x in self.warm_start)
            if sorted(ws) != list(range(len(ws))):
                raise ValueError(
                    "warm_start must be a permutation of range(n), got "
                    f"{self.warm_start!r}")
            object.__setattr__(self, "warm_start", ws)


@dataclass(frozen=True)
class PlanRequest:
    """Everything a strategy needs to produce a Plan, as one value.

    Attributes:
        workload: model config + sequence length + global batch.
        spec: cluster description.
        space: candidate-space knobs (:class:`SearchSpace`).
        budget: SA budget (:class:`Budget`).
        seed: RNG seed; given it, every strategy is deterministic (under an
            iteration-bound budget).
    """
    workload: Workload
    spec: ClusterSpec
    space: SearchSpace = field(default_factory=SearchSpace)
    budget: Budget = field(default_factory=Budget)
    seed: int = 0


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

@runtime_checkable
class Strategy(Protocol):
    """A configurator: turns a :class:`PlanRequest` + bandwidth matrix into
    a ranked :class:`~repro.core.search.SearchResult`.

    ``name`` identifies the strategy in Plan provenance and CLI output.
    """
    name: str

    def search(self, req: PlanRequest,
               bw: np.ndarray) -> SearchResult: ...      # pragma: no cover


@dataclass(frozen=True)
class PipetteStrategy:
    """The paper's five-stage pipeline (Alg. 1): enumerate -> memory-prune
    -> profile -> pre-score -> SA worker dedication."""
    estimator: Optional[MemoryEstimator] = None
    mem_limit: Optional[float] = None
    name: ClassVar[str] = "pipette"

    def search(self, req: PlanRequest, bw: np.ndarray) -> SearchResult:
        return run_search(req, bw, estimator=self.estimator,
                          mem_limit=self.mem_limit, dedicate=True)


@dataclass(frozen=True)
class ExhaustiveStrategy:
    """The PPT-L ablation: latency + memory estimators over the exhaustive
    enumeration, identity (default) mapping — no SA dedication."""
    estimator: Optional[MemoryEstimator] = None
    mem_limit: Optional[float] = None
    name: ClassVar[str] = "exhaustive"

    def search(self, req: PlanRequest, bw: np.ndarray) -> SearchResult:
        return run_search(req, bw, estimator=self.estimator,
                          mem_limit=self.mem_limit, dedicate=False)


@dataclass(frozen=True)
class AMPStrategy:
    """AMP baseline [8]: Eq. 1 latency model on nominal bandwidths,
    memory-unaware, 3D space only (the profiled ``bw`` is ignored)."""
    name: ClassVar[str] = "amp"

    def search(self, req: PlanRequest, bw: np.ndarray) -> SearchResult:
        return amp_configure(req.workload, req.spec,
                             max_micro=req.space.max_micro)


@dataclass(frozen=True)
class VarunaStrategy:
    """Varuna baseline [12]: pipeline + data parallelism only (tp = 1),
    memory-unaware, 3D space only (the profiled ``bw`` is ignored)."""
    name: ClassVar[str] = "varuna"

    def search(self, req: PlanRequest, bw: np.ndarray) -> SearchResult:
        return varuna_configure(req.workload, req.spec,
                                max_micro=req.space.max_micro)


@dataclass(frozen=True)
class MegatronStrategy:
    """Megatron-LM manual heuristic [14]: tp = gpus-per-node, then the
    "expert" trial-runs the most promising configs on the cluster.

    The trial runs execute on ``bw_true`` when given (the simulator's
    ground-truth matrix — the paper's setting, where manual tuning runs on
    the real cluster, not the profiled snapshot); otherwise on the ``bw``
    handed to :meth:`search`.
    """
    trials: int = 6
    bw_true: Optional[np.ndarray] = None
    name: ClassVar[str] = "megatron-lm"

    def search(self, req: PlanRequest, bw: np.ndarray) -> SearchResult:
        return mlm_configure(req.workload, req.spec, self.scoring_bw(bw),
                             max_micro=req.space.max_micro,
                             trials=self.trials, seed=req.seed)

    def scoring_bw(self, bw: np.ndarray) -> np.ndarray:
        """The matrix the trial runs actually execute on — what Plan
        provenance must fingerprint (not the ignored profiled ``bw``)."""
        return self.bw_true if self.bw_true is not None else bw


#: Strategy constructors by name (CLI / provenance lookup).
STRATEGIES = {
    "pipette": PipetteStrategy,
    "exhaustive": ExhaustiveStrategy,
    "amp": AMPStrategy,
    "varuna": VarunaStrategy,
    "megatron-lm": MegatronStrategy,
}


# ---------------------------------------------------------------------------
# provenance
# ---------------------------------------------------------------------------

def bw_fingerprint(bw: np.ndarray) -> str:
    """SHA-256 digest of a bandwidth matrix (shape + float64 bytes).

    Recorded in Plan provenance so a plan can be matched against the
    interconnect snapshot it was computed for — a re-profiled cluster
    yields a different digest, signalling the plan may be stale.
    """
    a = np.ascontiguousarray(bw, np.float64)
    h = hashlib.sha256()
    h.update(repr(a.shape).encode())
    h.update(a.tobytes())
    return h.hexdigest()


def tier_provenance(spec: ClusterSpec) -> Optional[dict]:
    """Device-tier provenance of a cluster spec (``None`` when homogeneous):
    the :func:`~repro.core.cluster.tier_fingerprint` digest plus the tier
    table and node assignment themselves, so a plan records exactly which
    fleet composition it priced — a re-tiered cluster (node swapped,
    host degraded) yields a different digest, signalling staleness."""
    digest = tier_fingerprint(spec)
    if digest is None:
        return None
    return {"digest": digest,
            "tiers": [{"flops": t.flops, "mem": t.mem,
                       "efficiency": t.efficiency, "name": t.name}
                      for t in spec.tiers],
            "node_tiers": [int(t) for t in spec.node_tiers]}


def estimator_provenance(est: Optional[MemoryEstimator]) -> Optional[dict]:
    """Fit provenance of a memory estimator (``None`` for memory-unaware
    strategies): which feature space it was fit on and against which
    hardware ground truth — the same fields
    :func:`repro.runtime.elastic.replan` uses for staleness detection."""
    if est is None:
        return None
    return {"with_cp": bool(est.with_cp),
            "residual": bool(est.residual),
            "soft_margin": float(est.soft_margin),
            "workload_seq": int(est.workload_seq),
            "fit_gpu_mem": float(est.fit_gpu_mem),
            "fit_gpus_per_node": int(est.fit_gpus_per_node)}


@dataclass(frozen=True)
class Provenance:
    """Where a Plan came from — enough to audit it without re-running.

    Attributes:
        strategy: producing strategy's ``name``.
        seed: the request seed.
        bw_digest: :func:`bw_fingerprint` of the profiled matrix.
        cluster: cluster spec name; ``n_gpus`` its size at plan time.
        model / seq / bs_global: the workload.
        space / budget: the request's search-space and budget knobs.
        estimator: :func:`estimator_provenance` dict, or ``None``.
        tiers: :func:`tier_provenance` dict (device-tier table digest +
            node assignment), or ``None`` for homogeneous clusters.
        lineage: how the serving layer produced this plan, or ``None``
            for a direct cold search.  The plan server records
            ``{"warm_start_from": <fingerprint>, "distance": <float>}``
            when the search was seeded from a cached neighbor plan, and
            an elastic replan records ``{"replan_of": <incumbent
            fingerprint>, "warm_start_projected": <bool>, "survivors":
            <count>}`` — enough to audit which incumbent a warm start /
            replan descended from.  Free-form dict, serialized as-is
            (keys inside it are not schema-pinned).
    """
    strategy: str
    seed: int
    bw_digest: str
    cluster: str
    n_gpus: int
    model: str
    seq: int
    bs_global: int
    space: SearchSpace
    budget: Budget
    estimator: Optional[dict] = None
    tiers: Optional[dict] = None
    lineage: Optional[dict] = None


# ---------------------------------------------------------------------------
# the serializable Plan artifact
# ---------------------------------------------------------------------------

class PlanLoadError(ValueError):
    """A plan artifact could not be read: corrupt JSON, an unknown schema
    version, or a structurally broken document.

    One typed error for every way :meth:`Plan.load` can fail, carrying the
    offending ``path`` (``None`` when loading from an in-memory dict) so
    callers — the CLI, the plan server's cache — can report *which* file
    is bad and fall back (e.g. drop the cache entry and re-search) without
    fishing through ``json.JSONDecodeError`` / ``KeyError`` /
    ``ValueError`` separately.
    """

    def __init__(self, message: str, *, path: Optional[str] = None):
        super().__init__(message)
        self.path = path


def _num_out(x: float):
    """JSON-safe float: NaN -> None, inf -> "inf" (strict-JSON friendly)."""
    x = float(x)
    if math.isnan(x):
        return None
    if math.isinf(x):
        return "inf" if x > 0 else "-inf"
    return x


def _num_in(x) -> float:
    if x is None:
        return float("nan")
    if isinstance(x, str):
        return float(x)
    return float(x)


def _budget_out(b: Budget) -> dict:
    d = dataclasses.asdict(b)
    if d["warm_start"] is not None:
        d["warm_start"] = list(d["warm_start"])    # tuple -> JSON array
    return d


def _conf_out(conf: Conf) -> dict:
    return {"pp": conf.pp, "tp": conf.tp, "cp": conf.cp, "dp": conf.dp,
            "vpp": conf.vpp, "bs_micro": conf.bs_micro,
            "bs_global": conf.bs_global}


def _conf_in(d: dict) -> Conf:
    return Conf(pp=d["pp"], tp=d["tp"], dp=d["dp"], bs_micro=d["bs_micro"],
                bs_global=d["bs_global"], cp=d.get("cp", 1),
                vpp=d.get("vpp", 1))


def _mapping_out(mapping: np.ndarray) -> dict:
    m = np.asarray(mapping)
    return {"dtype": str(m.dtype), "shape": list(m.shape),
            "data": m.reshape(-1).tolist()}


def _mapping_in(d: dict) -> np.ndarray:
    return np.asarray(d["data"], dtype=np.dtype(d["dtype"])) \
        .reshape(tuple(d["shape"]))


def _candidate_out(c: Candidate) -> dict:
    return {"conf": _conf_out(c.conf), "mapping": _mapping_out(c.mapping),
            "latency": _num_out(c.latency), "mem_pred": _num_out(c.mem_pred),
            "partition": (None if c.partition is None
                          else c.partition.to_json_dict()),
            "schedule": c.schedule}


def _candidate_in(d: dict) -> Candidate:
    part = d.get("partition")
    return Candidate(conf=_conf_in(d["conf"]),
                     mapping=_mapping_in(d["mapping"]),
                     latency=_num_in(d["latency"]),
                     mem_pred=_num_in(d["mem_pred"]),
                     partition=(None if part is None
                                else Partition.from_json_dict(part)),
                     schedule=d.get("schedule", "1f1b"))


@dataclass(frozen=True, eq=False)
class Plan:
    """A serializable training-configuration plan.

    The first-class artifact the launch/runtime/checkpoint layers consume:
    the chosen parallelism configuration and worker dedication, the latency
    and memory predictions behind the choice, the ranked top-k fallbacks,
    the deterministic search counters, and full provenance.  ``save``/
    ``load`` round-trip it through canonical JSON — byte-identical across
    runs for the same request + seed (wall-clock overhead timings are
    deliberately *not* serialized; they stay on the in-process
    :attr:`overhead`).

    Attributes:
        conf: best configuration (``None`` when nothing survived — e.g.
            every candidate was memory-pruned).
        mapping: worker -> GPU dedication of the best candidate,
            ``(pp, tp, dp)`` or ``(pp, tp, cp, dp)``.
        latency: estimated seconds/iteration of the best candidate.
        mem_pred: predicted peak bytes/GPU (NaN without an estimator).
        ranked: top-k candidates, fastest first (fallbacks: e.g. step to
            ``ranked[1]`` when the best OOMs in practice, Fig. 5b style).
        overhead: :class:`~repro.core.search.Overhead`; only its
            deterministic counters are serialized.
        provenance: :class:`Provenance`.
        result: the full in-process :class:`~repro.core.search.SearchResult`
            (every candidate, wall-clock timings).  Not serialized —
            ``None`` after :meth:`load`.
        partition: resolved layer-to-stage :class:`Partition` of the best
            candidate (``None`` = uniform layering — the historical split).
        schedule: pipeline schedule of the best candidate ("1f1b" or
            "interleaved-1f1b").
    """
    conf: Optional[Conf]
    mapping: Optional[np.ndarray]
    latency: float
    mem_pred: float
    ranked: Tuple[Candidate, ...]
    overhead: Overhead
    provenance: Provenance
    result: Optional[SearchResult] = field(default=None, repr=False)
    partition: Optional[Partition] = None
    schedule: str = "1f1b"

    @property
    def feasible(self) -> bool:
        """True when the search found at least one runnable candidate."""
        return self.conf is not None

    @classmethod
    def from_search(cls, res: SearchResult, req: PlanRequest,
                    bw: np.ndarray, *, strategy: str,
                    estimator: Optional[MemoryEstimator] = None,
                    keep_top: int = 10,
                    lineage: Optional[dict] = None) -> "Plan":
        """Freeze a :class:`SearchResult` into a Plan artifact."""
        w = req.workload
        prov = Provenance(strategy=strategy, seed=req.seed,
                          bw_digest=bw_fingerprint(bw),
                          cluster=req.spec.name, n_gpus=req.spec.n_gpus,
                          model=w.cfg.name, seq=w.seq,
                          bs_global=w.bs_global, space=req.space,
                          budget=req.budget,
                          estimator=estimator_provenance(estimator),
                          tiers=tier_provenance(req.spec),
                          lineage=lineage)
        best = res.best
        return cls(conf=best.conf if best else None,
                   mapping=(np.asarray(best.mapping).copy()
                            if best else None),
                   latency=best.latency if best else float("inf"),
                   mem_pred=best.mem_pred if best else float("nan"),
                   ranked=tuple(res.top(keep_top)),
                   overhead=res.overhead, provenance=prov, result=res,
                   partition=best.partition if best else None,
                   schedule=best.schedule if best else "1f1b")

    # -- JSON round trip ----------------------------------------------------

    def to_json_dict(self) -> dict:
        """Canonical JSON-ready dict (deterministic field content)."""
        prov = self.provenance
        return {
            "version": PLAN_SCHEMA_VERSION,
            "strategy": prov.strategy,
            "best": (None if self.conf is None else
                     {"conf": _conf_out(self.conf),
                      "mapping": _mapping_out(self.mapping),
                      "latency": _num_out(self.latency),
                      "mem_pred": _num_out(self.mem_pred),
                      "partition": (None if self.partition is None
                                    else self.partition.to_json_dict()),
                      "schedule": self.schedule}),
            "ranked": [_candidate_out(c) for c in self.ranked],
            "overhead": self.overhead.counts(),
            "provenance": {
                "seed": prov.seed,
                "bw_digest": prov.bw_digest,
                "cluster": prov.cluster,
                "n_gpus": prov.n_gpus,
                "model": prov.model,
                "seq": prov.seq,
                "bs_global": prov.bs_global,
                "space": dataclasses.asdict(prov.space),
                "budget": _budget_out(prov.budget),
                "estimator": prov.estimator,
                "tiers": prov.tiers,
                "lineage": prov.lineage,
            },
        }

    def to_json(self) -> str:
        """Canonical JSON text: sorted keys, fixed separators, trailing
        newline — byte-identical for identical plan content."""
        return json.dumps(self.to_json_dict(), sort_keys=True, indent=2,
                          allow_nan=False) + "\n"

    def save(self, path) -> str:
        """Write the canonical JSON artifact; returns the path written."""
        with open(path, "w") as f:
            f.write(self.to_json())
        return str(path)

    @classmethod
    def from_json_dict(cls, d: dict) -> "Plan":
        if d.get("version") != PLAN_SCHEMA_VERSION:
            raise PlanLoadError(
                f"unsupported plan schema version {d.get('version')!r} "
                f"(this build reads version {PLAN_SCHEMA_VERSION})")
        p = d["provenance"]
        prov = Provenance(strategy=d["strategy"], seed=p["seed"],
                          bw_digest=p["bw_digest"], cluster=p["cluster"],
                          n_gpus=p["n_gpus"], model=p["model"],
                          seq=p["seq"], bs_global=p["bs_global"],
                          space=SearchSpace(**p["space"]),
                          budget=Budget(**p["budget"]),
                          estimator=p["estimator"],
                          tiers=p["tiers"],
                          lineage=p["lineage"])
        best = d["best"]
        best_part = None if best is None else best.get("partition")
        return cls(
            conf=None if best is None else _conf_in(best["conf"]),
            mapping=None if best is None else _mapping_in(best["mapping"]),
            latency=(float("inf") if best is None
                     else _num_in(best["latency"])),
            mem_pred=(float("nan") if best is None
                      else _num_in(best["mem_pred"])),
            ranked=tuple(_candidate_in(c) for c in d["ranked"]),
            overhead=Overhead(**d["overhead"]),
            provenance=prov, result=None,
            partition=(None if best_part is None
                       else Partition.from_json_dict(best_part)),
            schedule=("1f1b" if best is None
                      else best.get("schedule", "1f1b")))

    @classmethod
    def load(cls, path) -> "Plan":
        """Read a Plan back from :meth:`save` output.

        Raises:
            PlanLoadError: corrupt JSON, unknown schema version, or a
                structurally broken document — one typed error carrying
                the offending ``path``, whatever went wrong underneath.
        """
        try:
            with open(path) as f:
                doc = json.load(f)
        except json.JSONDecodeError as e:
            raise PlanLoadError(
                f"plan artifact is not valid JSON: {e}",
                path=str(path)) from e
        try:
            return cls.from_json_dict(doc)
        except PlanLoadError as e:
            if e.path is None:
                e.path = str(path)
            raise
        except (KeyError, TypeError, ValueError) as e:
            raise PlanLoadError(
                f"plan artifact is structurally invalid: {e!r}",
                path=str(path)) from e

    # -- migration cost -----------------------------------------------------

    def diff(self, other: "Plan", *, cfg=None,
             survivors: Optional[Tuple[int, ...]] = None,
             n_nodes: Optional[int] = None,
             inter_bw: float = 12.5e9,
             restart_s: Optional[float] = None) -> "PlanDiff":
        """Migration cost of switching from this plan to ``other``.

        ``self`` is the incumbent, ``other`` the successor:
        ``a.diff(b)`` prices the ranks that must re-fetch their
        parameter/optimizer shards to go live on ``b`` (see
        :mod:`repro.core.migration` for the model).  Both plans must be
        feasible.

        Args:
            cfg: the shared :class:`~repro.models.config.ModelConfig`;
                resolved from ``provenance.model`` through the
                architecture registry when omitted (the two plans must
                then record the same model name).
            survivors: when the fleets differ (shrink/grow), successor
                GPU ``i`` (for ``i < len(survivors)``) is incumbent GPU
                ``survivors[i]``; successor GPUs beyond that are new.
                Default: identity on the common id prefix — the
                ``with_nodes`` truncation convention.
            n_nodes: healthy node count of the successor fleet (sets the
                aggregate transfer bandwidth); inferred from the GPU
                count when omitted.
            inter_bw: per-node inter-node bandwidth, bytes/s.
            restart_s: restart barrier seconds (``None`` = the model
                default, :data:`~repro.core.migration.DEFAULT_RESTART_S`).
        """
        from .migration import (DEFAULT_RESTART_S, diff_assignments,
                                resolve_model)
        if not (self.feasible and other.feasible):
            raise ValueError("Plan.diff needs two feasible plans")
        if cfg is None:
            a, b = self.provenance.model, other.provenance.model
            if a != b:
                raise ValueError(
                    f"plans record different models ({a!r} vs {b!r}); "
                    f"pass cfg explicitly")
            cfg = resolve_model(a)
        b_to_a = None
        if survivors is not None:
            n_b = other.conf.n_gpus
            b_to_a = [int(survivors[g]) if g < len(survivors) else -1
                      for g in range(n_b)]
        return diff_assignments(
            cfg, self.conf, self.mapping, other.conf, other.mapping,
            partition_a=self.partition, partition_b=other.partition,
            b_to_a=b_to_a, n_nodes=n_nodes, inter_bw=inter_bw,
            restart_s=DEFAULT_RESTART_S if restart_s is None else restart_s)

    def fingerprint(self) -> str:
        """SHA-256 of the canonical JSON artifact — a content identity
        (replan lineage records it as ``replan_of``; note the plan
        *server*'s cache keys on the request fingerprint instead)."""
        return hashlib.sha256(self.to_json().encode()).hexdigest()


# ---------------------------------------------------------------------------
# the one entry point
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Planner:
    """``Planner(strategy).plan(request, bw)`` — the single configurator
    entry point, shared by Pipette, its ablations, and every baseline.

    Example:
        >>> req = PlanRequest(w, spec, SearchSpace(max_cp=2), Budget())
        >>> plan = Planner(PipetteStrategy(estimator=est)).plan(req, bw)
        >>> plan.save("plan.json")          # consumed by launch/runtime
    """
    strategy: Strategy

    def plan(self, req: PlanRequest, bw: np.ndarray, *,
             keep_top: int = 10, lineage: Optional[dict] = None) -> Plan:
        """Run the strategy and freeze its result into a :class:`Plan`.

        Args:
            req: declarative request.
            bw: ``(G, G)`` profiled bandwidth matrix.
            keep_top: how many ranked fallback candidates the Plan keeps
                (the full ranking stays on ``plan.result``).
            lineage: serving-layer provenance recorded on the plan (e.g.
                which cached neighbor seeded a warm start); ``None`` for
                a direct cold search.
        """
        res = self.strategy.search(req, bw)
        # provenance must fingerprint the matrix the strategy actually
        # scored against (MegatronStrategy may substitute its bw_true)
        scoring_bw = getattr(self.strategy, "scoring_bw", None)
        return Plan.from_search(
            res, req, scoring_bw(bw) if scoring_bw is not None else bw,
            strategy=self.strategy.name,
            estimator=getattr(self.strategy, "estimator", None),
            keep_top=keep_top, lineage=lineage)
