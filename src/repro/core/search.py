"""Algorithm 1 — the Pipette configurator, as a staged array pipeline.

``run_search()`` — the engine behind ``Planner(PipetteStrategy())`` and the
legacy ``configure()`` shim — runs five batched stages instead of a
per-candidate loop:

1. **enumerate** — all (pp, tp, cp, dp, bs_micro) with ``pp*tp*cp*dp = G``
   (``cp`` up to the ``max_cp`` knob; 1 keeps the paper's 3D space), plus
   the microbatch / schedule-validity filters, collected up front;
2. **memory-prune** — one jitted
   :meth:`~repro.core.memory.MemoryEstimator.predict_batch` call on the
   whole ``(N, F)`` feature matrix, pruned as a vector (the seed code
   re-entered JAX once per candidate with an un-jitted one-row forward, so
   search overhead was dominated by dispatch);
3. **profile** — :class:`~repro.core.simulator.ProfileCache` builds each
   surviving ``(pp, tp, bs_micro)`` profile once (a ``Profile`` does not
   depend on ``dp``, and its ``(pp, tp)``-only fields are shared across
   microbatch variants); pruned configs never pay profile construction;
4. **pre-score** — every survivor's default mapping is scored in one cached
   pass (:func:`~repro.core.latency.default_mapping_latencies`);
5. **dedicate** — SA worker dedication on every survivor, or, with
   ``sa_topk=k``, only on the ``k`` most promising by pre-score so the SA
   budget concentrates where it matters; the rest keep their default
   mapping and pre-scored latency.

The SA stage uses the incremental :class:`~repro.core.dedication.
DedicationEngine`; its permutation-position index tensors depend only on the
(pp, tp, cp, dp) shape, so they are built once per shape and shared across
every microbatch variant of that shape.

Stages 1-4 are reified as :class:`BatchSearchContext` so *near-identical
requests* (same workload + cluster + space shape, different microbatch
caps / budgets / seeds) can share one enumeration, one jitted
``predict_batch`` forward, one profile cache and one pre-score pass — the
plan service batches grouped requests through a single context.  Per
request, the context filters the shared enumeration by the request's own
microbatch predicates (order-preserving, so the filtered list is exactly
what a standalone enumeration would produce) and indexes the shared
per-conf arrays — every per-conf value is computed independently of its
batch neighbours, so a batched search is **bit-identical** to a standalone
``run_search`` of the same request.  ``run_search`` itself is now a
single-request context: one code path, trivially consistent."""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cluster import ClusterSpec
from .dedication import (DedicationEngine, GroupIndex, PairCache, SAResult,
                         anneal, anneal_multistart)
from .latency import default_mapping_latencies
from .memory import MemoryEstimator, enumerate_confs, ground_truth_memory
from .partition import Partition
from .simulator import Conf, ProfileCache, Workload, default_mapping

if TYPE_CHECKING:                              # pragma: no cover
    from .plan import PlanRequest


@dataclass
class Candidate:
    """One surviving configuration: (Conf, Map, T) plus the memory estimate.

    Attributes:
        conf: parallelism configuration.
        mapping: ``(pp, tp, dp)`` (or ``(pp, tp, cp, dp)`` when
            ``conf.cp > 1``) worker -> GPU dedication.
        latency: estimated seconds/iteration (Eq. 3-6).
        mem_pred: predicted peak bytes/GPU (``nan`` without an estimator).
        partition: resolved non-uniform chunk partition (None = the legacy
            uniform split, which is also what a "dp"-mode search records
            when the DP solver degenerates to the ceil-first boundaries).
        schedule: pipeline schedule name (``conf.schedule``; recorded for
            Plan provenance).
        sa: the :class:`~repro.core.dedication.SAResult` behind ``mapping``
            when this candidate was annealed (None for default-mapping
            candidates).  In-process diagnostics only — never serialized
            into a Plan; its accepted-move counters feed the warm-start
            economy metrics in :class:`Overhead`.
    """
    conf: Conf
    mapping: np.ndarray
    latency: float
    mem_pred: float
    partition: Optional[Partition] = None
    schedule: str = "1f1b"
    sa: Optional[SAResult] = field(default=None, repr=False)


@dataclass
class Overhead:
    """Typed search-overhead breakdown (the paper's Table II axis).

    The ``*_s`` fields are wall-clock phase timings of the staged pipeline;
    ``n_enumerated``/``n_candidates`` are the deterministic size counters.
    ``sa_accepted`` is the total number of accepted SA moves across every
    annealed candidate and chain; ``sa_accepted_to_best`` is the accepted
    moves the *winning* candidate's best chain needed before landing on its
    final mapping — the "search economy" a warm start buys (a seeded chain
    that starts at a good incumbent accepts fewer moves to reach an equal
    or better plan).  Both are deterministic under iteration-bound budgets
    and serialize with the plan.  ``as_dict()`` keeps the benchmarks'
    JSON/CSV output format, and ``__getitem__`` preserves the historical
    ``overhead["sa_s"]`` dict-style access so existing callers keep working
    — but unlike the stringly-typed dict, a typo in attribute access now
    fails loudly at the call site.
    """
    total_s: float = 0.0
    sa_s: float = 0.0
    mem_estimator_s: float = 0.0
    enumerate_s: float = 0.0
    profile_s: float = 0.0
    prescore_s: float = 0.0
    n_enumerated: int = 0
    n_candidates: int = 0
    sa_accepted: int = 0
    sa_accepted_to_best: int = 0

    def as_dict(self) -> dict:
        """Plain-dict view (benchmark JSON/CSV output)."""
        return dataclasses.asdict(self)

    def counts(self) -> dict:
        """Only the deterministic counters — what a serialized
        :class:`~repro.core.plan.Plan` records (wall-clock timings are
        process-local measurements, excluded so the artifact is
        byte-reproducible)."""
        return {"n_enumerated": self.n_enumerated,
                "n_candidates": self.n_candidates,
                "sa_accepted": self.sa_accepted,
                "sa_accepted_to_best": self.sa_accepted_to_best}

    def __getitem__(self, key: str):
        return self.as_dict()[key]


@dataclass
class SearchResult:
    """Ranked output of a configurator search (``Planner.plan`` /
    ``configure``).

    Attributes:
        best: lowest-latency candidate (``None`` if nothing survived).
        ranked: all candidates, fastest first.
        overhead: typed timing breakdown (:class:`Overhead`).

    Example:
        >>> res = configure(w, spec, bw, sa_seconds=0.2)
        >>> res.best.conf.n_gpus == spec.n_gpus
        True
        >>> [str(c.conf) for c in res.top(3)]       # Fig. 5b style top-k
        ['pp4·tp8·dp2·mb2(n_mb=16)', ...]
    """
    best: Optional[Candidate]
    ranked: List[Candidate]
    overhead: Overhead = field(default_factory=Overhead)

    def top(self, k: int = 10) -> List[Candidate]:
        """First ``k`` candidates by estimated latency (fastest first)."""
        return self.ranked[:k]


class BatchSearchContext:
    """Stages 1-4 of Algorithm 1, run once and shared across requests.

    The context is built for one (workload, cluster, search-space *shape*)
    group with *union* microbatch caps; each member request then calls
    :meth:`search`, which filters the shared enumeration down to exactly
    the confs that request would have enumerated standalone and runs only
    stage 5 (SA dedication) per request.  Because every per-conf quantity
    (memory prediction row, profile, default-mapping pre-score) is
    computed independently of its batch neighbours, a batched search is
    bit-identical to a standalone :func:`run_search` of the same request
    — the plan service relies on this to coalesce near-identical requests
    through one jitted ``predict_batch`` forward without changing a
    single plan byte.

    Attributes:
        n_predict_batches: how many jitted ``predict_batch`` forwards this
            context has issued (0 without an estimator, else exactly 1) —
            observable proof of request batching for tests and benchmarks.
        build_s / enumerate_s / mem_estimator_s / profile_s / prescore_s:
            wall-clock timings of the shared stages; every member request's
            :class:`Overhead` reports these same (un-amortized) values.
    """

    def __init__(self, workload: Workload, spec: ClusterSpec,
                 bw: np.ndarray, *, partition: str = "uniform",
                 max_cp: int = 1, max_tp: int = 0, max_vpp: int = 1,
                 max_micro: int = 16, fixed_micro: Optional[int] = None,
                 estimator: Optional[MemoryEstimator] = None,
                 mem_limit: Optional[float] = None) -> None:
        t0 = time.perf_counter()
        self.workload = workload
        self.spec = spec
        self.bw = bw
        self.partition = partition
        self.max_cp, self.max_tp, self.max_vpp = max_cp, max_tp, max_vpp
        self.max_micro, self.fixed_micro = max_micro, fixed_micro
        self.estimator = estimator
        self.mem_limit = (mem_limit if mem_limit is not None
                          else spec.mem_floor)
        self.n_predict_batches = 0
        w = workload

        # stage 1: enumerate the whole (union) search space up front
        confs = [conf for conf in enumerate_confs(spec.n_gpus, w.bs_global,
                                                  n_layers=w.cfg.n_layers,
                                                  max_cp=max_cp,
                                                  max_tp=max_tp,
                                                  seq=w.seq,
                                                  max_vpp=max_vpp)
                 if conf.bs_micro <= max_micro
                 and (fixed_micro is None or conf.bs_micro == fixed_micro)]
        self._confs = confs
        self.enumerate_s = time.perf_counter() - t0

        # partition-aware profile cache; also the resolver of each conf's
        # chunk partition (None = uniform -> every legacy bit-exact path)
        self._prof_cache = ProfileCache(w, spec, partition)

        # stage 2: batched memory pruning — one jitted forward for all
        # confs in the union
        tm = time.perf_counter()
        if estimator is not None and confs:
            preds = estimator.predict_batch(w.cfg, confs)
            self.n_predict_batches = 1
            # The estimator was fit on the uniform-split ground truth; a
            # non-uniform partition / interleaved schedule shifts the
            # worst-stage peak, so rescale its prediction by the
            # ground-truth ratio.  Uniform plain-1F1B configs skip this
            # entirely (ratio would be exactly 1), keeping legacy
            # predictions bit-identical.
            for i, c in enumerate(confs):
                part = self._prof_cache.partition_for(c)
                if part is None and c.vpp == 1:
                    continue
                legacy = ground_truth_memory(
                    w, dataclasses.replace(c, vpp=1), spec)
                actual = ground_truth_memory(w, c, spec, partition=part)
                preds[i] *= actual / legacy
            self._keep = np.asarray(
                preds <= self.mem_limit * estimator.soft_margin, dtype=bool)
            self._mem_preds = preds
        else:
            self._keep = np.ones(len(confs), dtype=bool)
            self._mem_preds = np.full(len(confs), float("nan"))
        self.mem_estimator_s = time.perf_counter() - tm

        # stage 3: profiles only for union survivors, memoized per
        # (pp, tp, cp, bs_micro, vpp, partition)
        tp0 = time.perf_counter()
        surv = [i for i in range(len(confs)) if self._keep[i]]
        self._profiles = {i: self._prof_cache.get(confs[i]) for i in surv}
        self.profile_s = time.perf_counter() - tp0

        # stage 4: one cached pass over every union survivor's default
        # mapping; per-conf values are independent, so indexing this by a
        # request's conf subset reproduces its standalone pre-score
        ts0 = time.perf_counter()
        self._base_lat = np.full(len(confs), float("nan"))
        if surv:
            self._base_lat[surv] = default_mapping_latencies(
                [confs[i] for i in surv], [self._profiles[i] for i in surv],
                bw, spec)
        self.prescore_s = time.perf_counter() - ts0
        self.build_s = time.perf_counter() - t0

    @classmethod
    def for_requests(cls, reqs: Sequence["PlanRequest"], bw: np.ndarray, *,
                     estimator: Optional[MemoryEstimator] = None,
                     mem_limit: Optional[float] = None
                     ) -> "BatchSearchContext":
        """Build a context covering every request in ``reqs``.

        The requests must share workload, cluster spec, and the
        search-space *shape* knobs (``partition``/``max_cp``/``max_tp``/
        ``max_vpp``); the microbatch knobs are unioned (``max_micro`` =
        group max; ``fixed_micro`` kept only when every request pins the
        same value, else the union enumerates all microbatches and each
        request re-applies its own pin in :meth:`search`).
        """
        if not reqs:
            raise ValueError("for_requests needs at least one request")
        r0 = reqs[0]
        for r in reqs[1:]:
            if r.workload != r0.workload or r.spec != r0.spec:
                raise ValueError(
                    "batched requests must share workload and cluster spec")
            if (r.space.partition != r0.space.partition
                    or r.space.max_cp != r0.space.max_cp
                    or r.space.max_tp != r0.space.max_tp
                    or r.space.max_vpp != r0.space.max_vpp):
                raise ValueError("batched requests must share the "
                                 "search-space shape knobs (partition/"
                                 "max_cp/max_tp/max_vpp)")
        fixed = {r.space.fixed_micro for r in reqs}
        return cls(r0.workload, r0.spec, bw,
                   partition=r0.space.partition, max_cp=r0.space.max_cp,
                   max_tp=r0.space.max_tp, max_vpp=r0.space.max_vpp,
                   max_micro=max(r.space.max_micro for r in reqs),
                   fixed_micro=(fixed.pop() if len(fixed) == 1 else None),
                   estimator=estimator, mem_limit=mem_limit)

    def _check(self, req: "PlanRequest") -> None:
        """Reject a request whose standalone enumeration would not be an
        in-order subset of this context's union enumeration."""
        space = req.space
        if req.workload != self.workload or req.spec != self.spec:
            raise ValueError(
                "request workload/cluster does not match this batch context")
        if (space.partition != self.partition
                or space.max_cp != self.max_cp
                or space.max_tp != self.max_tp
                or space.max_vpp != self.max_vpp):
            raise ValueError("request search-space shape does not match "
                             "this batch context")
        if space.max_micro > self.max_micro:
            raise ValueError(
                f"request max_micro={space.max_micro} exceeds the "
                f"context's union cap {self.max_micro}")
        if (self.fixed_micro is not None
                and space.fixed_micro != self.fixed_micro):
            raise ValueError(
                f"request fixed_micro={space.fixed_micro!r} conflicts with "
                f"the context's pinned fixed_micro={self.fixed_micro}")

    def search(self, req: "PlanRequest", *,
               dedicate: bool = True) -> SearchResult:
        """Run stage 5 (SA dedication + ranking) for one member request.

        Filters the shared union enumeration by the request's own
        microbatch predicates (order-preserving — the filtered list is
        exactly what the request would have enumerated standalone), then
        indexes the shared predictions/profiles/pre-scores and anneals.
        ``budget.warm_start``, when set, must be a permutation of the
        cluster's GPU ids; it seeds every SA chain with that incumbent
        mapping (both the unified NumPy/JAX backends and the legacy
        per-candidate path).
        """
        t0 = time.perf_counter()
        self._check(req)
        space, budget, seed = req.space, req.budget, req.seed
        sa_seconds, sa_iters = budget.sa_seconds, budget.sa_iters
        n_chains, sa_topk = budget.n_chains, budget.sa_topk
        spec, bw = self.spec, self.bw

        warm_perm: Optional[np.ndarray] = None
        warm = getattr(budget, "warm_start", None)
        if warm is not None:
            warm_perm = np.asarray(warm, dtype=np.int64)
            n = spec.n_gpus
            if (warm_perm.shape != (n,)
                    or not np.array_equal(np.sort(warm_perm),
                                          np.arange(n))):
                raise ValueError(
                    f"budget.warm_start must be a permutation of the {n} "
                    f"cluster GPU ids, got shape {warm_perm.shape}")

        # per-request view of the shared stages
        idx = [i for i, c in enumerate(self._confs)
               if c.bs_micro <= space.max_micro
               and (space.fixed_micro is None
                    or c.bs_micro == space.fixed_micro)]
        n_enumerated = len(idx)
        surv_idx = [i for i in idx if self._keep[i]]
        survivors = [self._confs[i] for i in surv_idx]
        profiles = [self._profiles[i] for i in surv_idx]
        base_lat = self._base_lat[surv_idx]
        mem_preds = self._mem_preds[surv_idx]

        # stage 5: SA dedication — exhaustive, or concentrated on the
        # top-k by pre-score
        sa_time = 0.0
        cands: List[Candidate] = []
        if dedicate and survivors:
            if sa_topk is None or sa_topk >= len(survivors):
                sa_set = set(range(len(survivors)))
            else:
                order = np.argsort(base_lat, kind="stable")
                sa_set = set(int(i) for i in order[:max(sa_topk, 0)])
            if budget.backend is not None:
                # unified backend-selectable core: one MovePlan executed
                # by the incremental NumPy engine or the vmapped JAX
                # annealer (byte-identical results); candidates batched
                # per shape; warm_start is read off the budget inside
                from .annealing import dedicate_candidates
                ts = time.perf_counter()
                sa_res = dedicate_candidates(survivors, profiles,
                                             sorted(sa_set), bw, spec,
                                             budget, seed)
                sa_time = time.perf_counter() - ts
                for i, conf in enumerate(survivors):
                    if i in sa_res:
                        cands.append(Candidate(conf, sa_res[i].mapping,
                                               sa_res[i].latency,
                                               float(mem_preds[i]),
                                               sa=sa_res[i]))
                    else:
                        cands.append(Candidate(conf, default_mapping(conf),
                                               float(base_lat[i]),
                                               float(mem_preds[i])))
                survivors = []        # handled; skip the legacy loop
            index_cache: Dict[Tuple[int, int, int, int], GroupIndex] = {}
            pair_cache: Optional[PairCache] = None
            for i, (conf, prof) in enumerate(zip(survivors, profiles)):
                if i not in sa_set:
                    cands.append(Candidate(conf, default_mapping(conf),
                                           float(base_lat[i]),
                                           float(mem_preds[i])))
                    continue
                shape = (conf.pp, conf.tp, conf.cp, conf.dp)
                gidx = index_cache.get(shape)
                if gidx is None:
                    gidx = index_cache[shape] = GroupIndex.build(conf)
                if pair_cache is None:
                    # the O(G^2) pair matrices depend only on (bw, spec)
                    # — one build serves every annealed candidate
                    pair_cache = PairCache.build(bw, spec.gpus_per_node)
                engine = DedicationEngine(conf, bw, prof, spec, index=gidx,
                                          pairs=pair_cache)
                ts = time.perf_counter()
                if n_chains > 1:
                    res = anneal_multistart(conf, bw, prof, spec,
                                            n_chains=n_chains,
                                            time_limit_s=sa_seconds,
                                            max_iters=sa_iters, seed=seed,
                                            init_perm=warm_perm,
                                            engine=engine)
                else:
                    res = anneal(conf, bw, prof, spec,
                                 time_limit_s=sa_seconds,
                                 max_iters=sa_iters, seed=seed,
                                 init_perm=warm_perm, engine=engine)
                sa_time += time.perf_counter() - ts
                cands.append(Candidate(conf, res.mapping, res.latency,
                                       float(mem_preds[i]), sa=res))
        else:
            for i, conf in enumerate(survivors):
                cands.append(Candidate(conf, default_mapping(conf),
                                       float(base_lat[i]),
                                       float(mem_preds[i])))

        # record partition + schedule provenance on every candidate
        for c in cands:
            c.partition = self._prof_cache.partition_for(c.conf)
            c.schedule = c.conf.schedule

        cands.sort(key=lambda c: c.latency)
        sa_accepted = sum(c.sa.accepted for c in cands if c.sa is not None)  # repro: noqa DET004 -- accepted-move counters are ints; integer addition is order-independent
        best = cands[0] if cands else None
        sa_accepted_to_best = (best.sa.accepted_to_best
                               if best is not None and best.sa is not None
                               else 0)
        return SearchResult(
            best=best,
            ranked=cands,
            overhead=Overhead(
                total_s=self.build_s + (time.perf_counter() - t0),
                sa_s=sa_time, mem_estimator_s=self.mem_estimator_s,
                enumerate_s=self.enumerate_s, profile_s=self.profile_s,
                prescore_s=self.prescore_s,
                n_enumerated=n_enumerated,
                n_candidates=len(cands),
                sa_accepted=int(sa_accepted),
                sa_accepted_to_best=int(sa_accepted_to_best)))


def run_search(req: "PlanRequest", bw: np.ndarray, *,
               estimator: Optional[MemoryEstimator] = None,
               mem_limit: Optional[float] = None,
               dedicate: bool = True) -> SearchResult:
    """Pipette (Algorithm 1) over a declarative :class:`~repro.core.plan.
    PlanRequest`: enumerate -> memory-prune -> profile -> pre-score ->
    dedicate -> rank.

    This is the engine behind both :class:`~repro.core.plan.PipetteStrategy`
    (``dedicate=True``) and :class:`~repro.core.plan.ExhaustiveStrategy`
    (``dedicate=False``, the PPT-L ablation).  The legacy kwarg entry point
    :func:`configure` is a thin, bit-exact shim over it.  Internally this
    builds a single-request :class:`BatchSearchContext` — the same code
    path the plan service uses to batch grouped requests, so standalone
    and batched searches cannot drift apart.

    Args:
        req: declarative request — workload, cluster spec, search space
            (``max_cp``/``max_tp``/``max_micro``/``fixed_micro``), budget
            (``sa_seconds``/``sa_iters``/``n_chains``/``sa_topk``, plus
            ``warm_start`` to seed every SA chain with an incumbent
            permutation), seed.
        bw: ``(G, G)`` profiled bandwidth matrix from
            :func:`~repro.core.cluster.profile_bandwidth`.
        estimator: optional MLP memory estimator; prunes configs predicted
            to exceed ``mem_limit * soft_margin`` (one batched forward for
            the whole enumeration).  Must have been fit with
            ``max_cp > 1`` (:func:`~repro.core.memory.fit_memory_estimator`)
            to score a 4D search.
        mem_limit: per-GPU memory budget in bytes (default
            ``req.spec.mem_floor`` — every GPU hosts a worker, so the
            budget must respect the *tightest* device tier; identical to
            ``gpu_mem`` on homogeneous specs).
        dedicate: ``False`` gives the PPT-L ablation (latency+memory
            estimators only, identity mapping).

    Returns:
        :class:`SearchResult` with the best candidate and the full ranking.
    """
    space = req.space
    ctx = BatchSearchContext(req.workload, req.spec, bw,
                             partition=space.partition,
                             max_cp=space.max_cp, max_tp=space.max_tp,
                             max_vpp=space.max_vpp,
                             max_micro=space.max_micro,
                             fixed_micro=space.fixed_micro,
                             estimator=estimator, mem_limit=mem_limit)
    return ctx.search(req, dedicate=dedicate)


def configure(w: Workload, spec: ClusterSpec, bw: np.ndarray, *,
              estimator: Optional[MemoryEstimator] = None,
              mem_limit: Optional[float] = None,
              sa_seconds: float = 1.0, sa_iters: int = 8_000,
              n_chains: int = 1, sa_topk: Optional[int] = None,
              max_micro: int = 16, fixed_micro: Optional[int] = None,
              max_cp: int = 1, max_tp: int = 0,
              partition: str = "uniform", max_vpp: int = 1,
              seed: int = 0,
              dedicate: bool = True) -> SearchResult:
    """Legacy kwarg entry point — a thin shim over the Planner API.

    Packs the kwarg pile into a declarative
    :class:`~repro.core.plan.PlanRequest` and runs it through
    ``Planner(PipetteStrategy(...))`` (or ``ExhaustiveStrategy`` when
    ``dedicate=False``).  Bit-exact with calling the Planner directly —
    same best conf, mapping, latency, and full ranking (enforced by
    ``tests/test_planner_api.py``) — so every historical caller keeps
    working unchanged.

    Args:
        w: workload (model config, sequence length, global batch).
        spec: cluster description.
        bw: ``(G, G)`` profiled bandwidth matrix.
        estimator / mem_limit: memory-pruning inputs (see
            :func:`run_search`).
        sa_seconds / sa_iters / n_chains / sa_topk: SA budget
            (:class:`~repro.core.plan.Budget`).
        max_micro / fixed_micro / max_cp / max_tp / partition / max_vpp:
            search-space knobs (:class:`~repro.core.plan.SearchSpace`).
        seed: RNG seed; the whole search is deterministic given it.
        dedicate: ``False`` gives the PPT-L ablation (identity mapping).

    Returns:
        The full :class:`SearchResult` (the Planner's in-process view;
        use the Planner directly to get the serializable ``Plan``).
    """
    from .plan import (Budget, ExhaustiveStrategy, Planner, PlanRequest,
                       PipetteStrategy, SearchSpace)
    req = PlanRequest(
        workload=w, spec=spec,
        space=SearchSpace(max_cp=max_cp, max_tp=max_tp, max_micro=max_micro,
                          fixed_micro=fixed_micro, partition=partition,
                          max_vpp=max_vpp),
        budget=Budget(sa_seconds=sa_seconds, sa_iters=sa_iters,
                      n_chains=n_chains, sa_topk=sa_topk),
        seed=seed)
    strategy = (PipetteStrategy(estimator=estimator, mem_limit=mem_limit)
                if dedicate
                else ExhaustiveStrategy(estimator=estimator,
                                        mem_limit=mem_limit))
    return Planner(strategy).plan(req, bw).result
