"""Algorithm 1 — the Pipette configurator, as a staged array pipeline.

``run_search()`` — the engine behind ``Planner(PipetteStrategy())`` and the
legacy ``configure()`` shim — runs five batched stages instead of a
per-candidate loop:

1. **enumerate** — all (pp, tp, cp, dp, bs_micro) with ``pp*tp*cp*dp = G``
   (``cp`` up to the ``max_cp`` knob; 1 keeps the paper's 3D space), plus
   the microbatch / schedule-validity filters, collected up front;
2. **memory-prune** — one jitted
   :meth:`~repro.core.memory.MemoryEstimator.predict_batch` call on the
   whole ``(N, F)`` feature matrix, pruned as a vector (the seed code
   re-entered JAX once per candidate with an un-jitted one-row forward, so
   search overhead was dominated by dispatch);
3. **profile** — :class:`~repro.core.simulator.ProfileCache` builds each
   surviving ``(pp, tp, bs_micro)`` profile once (a ``Profile`` does not
   depend on ``dp``, and its ``(pp, tp)``-only fields are shared across
   microbatch variants); pruned configs never pay profile construction;
4. **pre-score** — every survivor's default mapping is scored in one cached
   pass (:func:`~repro.core.latency.default_mapping_latencies`);
5. **dedicate** — SA worker dedication on every survivor, or, with
   ``sa_topk=k``, only on the ``k`` most promising by pre-score so the SA
   budget concentrates where it matters; the rest keep their default
   mapping and pre-scored latency.

The SA stage uses the incremental :class:`~repro.core.dedication.
DedicationEngine`; its permutation-position index tensors depend only on the
(pp, tp, cp, dp) shape, so they are built once per shape and shared across
every microbatch variant of that shape."""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

import numpy as np

from .cluster import ClusterSpec
from .dedication import (DedicationEngine, GroupIndex, PairCache, anneal,
                         anneal_multistart)
from .latency import default_mapping_latencies
from .memory import MemoryEstimator, enumerate_confs, ground_truth_memory
from .partition import Partition
from .simulator import Conf, ProfileCache, Workload, default_mapping

if TYPE_CHECKING:                              # pragma: no cover
    from .plan import PlanRequest


@dataclass
class Candidate:
    """One surviving configuration: (Conf, Map, T) plus the memory estimate.

    Attributes:
        conf: parallelism configuration.
        mapping: ``(pp, tp, dp)`` (or ``(pp, tp, cp, dp)`` when
            ``conf.cp > 1``) worker -> GPU dedication.
        latency: estimated seconds/iteration (Eq. 3-6).
        mem_pred: predicted peak bytes/GPU (``nan`` without an estimator).
        partition: resolved non-uniform chunk partition (None = the legacy
            uniform split, which is also what a "dp"-mode search records
            when the DP solver degenerates to the ceil-first boundaries).
        schedule: pipeline schedule name (``conf.schedule``; recorded for
            Plan provenance).
    """
    conf: Conf
    mapping: np.ndarray
    latency: float
    mem_pred: float
    partition: Optional[Partition] = None
    schedule: str = "1f1b"


@dataclass
class Overhead:
    """Typed search-overhead breakdown (the paper's Table II axis).

    The ``*_s`` fields are wall-clock phase timings of the staged pipeline;
    ``n_enumerated``/``n_candidates`` are the deterministic size counters.
    ``as_dict()`` keeps the benchmarks' JSON/CSV output format, and
    ``__getitem__`` preserves the historical ``overhead["sa_s"]`` dict-style
    access so existing callers keep working — but unlike the stringly-typed
    dict, a typo in attribute access now fails loudly at the call site.
    """
    total_s: float = 0.0
    sa_s: float = 0.0
    mem_estimator_s: float = 0.0
    enumerate_s: float = 0.0
    profile_s: float = 0.0
    prescore_s: float = 0.0
    n_enumerated: int = 0
    n_candidates: int = 0

    def as_dict(self) -> dict:
        """Plain-dict view (benchmark JSON/CSV output)."""
        return dataclasses.asdict(self)

    def counts(self) -> dict:
        """Only the deterministic counters — what a serialized
        :class:`~repro.core.plan.Plan` records (wall-clock timings are
        process-local measurements, excluded so the artifact is
        byte-reproducible)."""
        return {"n_enumerated": self.n_enumerated,
                "n_candidates": self.n_candidates}

    def __getitem__(self, key: str):
        return self.as_dict()[key]


@dataclass
class SearchResult:
    """Ranked output of a configurator search (``Planner.plan`` /
    ``configure``).

    Attributes:
        best: lowest-latency candidate (``None`` if nothing survived).
        ranked: all candidates, fastest first.
        overhead: typed timing breakdown (:class:`Overhead`).

    Example:
        >>> res = configure(w, spec, bw, sa_seconds=0.2)
        >>> res.best.conf.n_gpus == spec.n_gpus
        True
        >>> [str(c.conf) for c in res.top(3)]       # Fig. 5b style top-k
        ['pp4·tp8·dp2·mb2(n_mb=16)', ...]
    """
    best: Optional[Candidate]
    ranked: List[Candidate]
    overhead: Overhead = field(default_factory=Overhead)

    def top(self, k: int = 10) -> List[Candidate]:
        """First ``k`` candidates by estimated latency (fastest first)."""
        return self.ranked[:k]


def run_search(req: "PlanRequest", bw: np.ndarray, *,
               estimator: Optional[MemoryEstimator] = None,
               mem_limit: Optional[float] = None,
               dedicate: bool = True) -> SearchResult:
    """Pipette (Algorithm 1) over a declarative :class:`~repro.core.plan.
    PlanRequest`: enumerate -> memory-prune -> profile -> pre-score ->
    dedicate -> rank.

    This is the engine behind both :class:`~repro.core.plan.PipetteStrategy`
    (``dedicate=True``) and :class:`~repro.core.plan.ExhaustiveStrategy`
    (``dedicate=False``, the PPT-L ablation).  The legacy kwarg entry point
    :func:`configure` is a thin, bit-exact shim over it.

    Args:
        req: declarative request — workload, cluster spec, search space
            (``max_cp``/``max_tp``/``max_micro``/``fixed_micro``), budget
            (``sa_seconds``/``sa_iters``/``n_chains``/``sa_topk``), seed.
        bw: ``(G, G)`` profiled bandwidth matrix from
            :func:`~repro.core.cluster.profile_bandwidth`.
        estimator: optional MLP memory estimator; prunes configs predicted
            to exceed ``mem_limit * soft_margin`` (one batched forward for
            the whole enumeration).  Must have been fit with
            ``max_cp > 1`` (:func:`~repro.core.memory.fit_memory_estimator`)
            to score a 4D search.
        mem_limit: per-GPU memory budget in bytes (default
            ``req.spec.mem_floor`` — every GPU hosts a worker, so the
            budget must respect the *tightest* device tier; identical to
            ``gpu_mem`` on homogeneous specs).
        dedicate: ``False`` gives the PPT-L ablation (latency+memory
            estimators only, identity mapping).

    Returns:
        :class:`SearchResult` with the best candidate and the full ranking.
    """
    w, spec, space, budget = req.workload, req.spec, req.space, req.budget
    sa_seconds, sa_iters = budget.sa_seconds, budget.sa_iters
    n_chains, sa_topk = budget.n_chains, budget.sa_topk
    seed = req.seed

    t0 = time.perf_counter()
    mem_limit = mem_limit if mem_limit is not None else spec.mem_floor

    # stage 1: enumerate the whole search space up front
    confs = [conf for conf in enumerate_confs(spec.n_gpus, w.bs_global,
                                              n_layers=w.cfg.n_layers,
                                              max_cp=space.max_cp,
                                              max_tp=space.max_tp,
                                              seq=w.seq,
                                              max_vpp=space.max_vpp)
             if conf.bs_micro <= space.max_micro
             and (space.fixed_micro is None
                  or conf.bs_micro == space.fixed_micro)]
    enum_s = time.perf_counter() - t0

    # partition-aware profile cache; also the resolver of each conf's
    # chunk partition (None = uniform -> every legacy bit-exact path)
    prof_cache = ProfileCache(w, spec, space.partition)

    # stage 2: batched memory pruning — one jitted forward for all confs
    tm = time.perf_counter()
    if estimator is not None and confs:
        preds = estimator.predict_batch(w.cfg, confs)
        # The estimator was fit on the uniform-split ground truth; a
        # non-uniform partition / interleaved schedule shifts the
        # worst-stage peak, so rescale its prediction by the ground-truth
        # ratio.  Uniform plain-1F1B configs skip this entirely (ratio
        # would be exactly 1), keeping legacy predictions bit-identical.
        for i, c in enumerate(confs):
            part = prof_cache.partition_for(c)
            if part is None and c.vpp == 1:
                continue
            legacy = ground_truth_memory(
                w, dataclasses.replace(c, vpp=1), spec)
            actual = ground_truth_memory(w, c, spec, partition=part)
            preds[i] *= actual / legacy
        keep = preds <= mem_limit * estimator.soft_margin
        survivors = [c for c, k in zip(confs, keep) if k]
        mem_preds = preds[keep]
    else:
        survivors = confs
        mem_preds = np.full(len(confs), float("nan"))
    mem_time = time.perf_counter() - tm

    # stage 3: profiles only for survivors, memoized per
    # (pp, tp, cp, bs_micro, vpp, partition)
    tp0 = time.perf_counter()
    profiles = [prof_cache.get(c) for c in survivors]
    profile_s = time.perf_counter() - tp0

    # stage 4: one cached pass over every survivor's default mapping
    ts0 = time.perf_counter()
    base_lat = default_mapping_latencies(survivors, profiles, bw, spec)
    prescore_s = time.perf_counter() - ts0

    # stage 5: SA dedication — exhaustive, or concentrated on the top-k
    sa_time = 0.0
    cands: List[Candidate] = []
    if dedicate and survivors:
        if sa_topk is None or sa_topk >= len(survivors):
            sa_set = set(range(len(survivors)))
        else:
            order = np.argsort(base_lat, kind="stable")
            sa_set = set(int(i) for i in order[:max(sa_topk, 0)])
        if budget.backend is not None:
            # unified backend-selectable core: one MovePlan executed by
            # the incremental NumPy engine or the vmapped JAX annealer
            # (byte-identical results); candidates batched per shape
            from .annealing import dedicate_candidates
            ts = time.perf_counter()
            sa_res = dedicate_candidates(survivors, profiles,
                                         sorted(sa_set), bw, spec, budget,
                                         seed)
            sa_time = time.perf_counter() - ts
            for i, conf in enumerate(survivors):
                if i in sa_res:
                    cands.append(Candidate(conf, sa_res[i].mapping,
                                           sa_res[i].latency,
                                           float(mem_preds[i])))
                else:
                    cands.append(Candidate(conf, default_mapping(conf),
                                           float(base_lat[i]),
                                           float(mem_preds[i])))
            survivors = []            # handled; skip the legacy loop
        index_cache: Dict[Tuple[int, int, int, int], GroupIndex] = {}
        pair_cache: Optional[PairCache] = None
        for i, (conf, prof) in enumerate(zip(survivors, profiles)):
            if i not in sa_set:
                cands.append(Candidate(conf, default_mapping(conf),
                                       float(base_lat[i]),
                                       float(mem_preds[i])))
                continue
            shape = (conf.pp, conf.tp, conf.cp, conf.dp)
            idx = index_cache.get(shape)
            if idx is None:
                idx = index_cache[shape] = GroupIndex.build(conf)
            if pair_cache is None:
                # the O(G^2) pair matrices depend only on (bw, spec) —
                # one build serves every annealed candidate
                pair_cache = PairCache.build(bw, spec.gpus_per_node)
            engine = DedicationEngine(conf, bw, prof, spec, index=idx,
                                      pairs=pair_cache)
            ts = time.perf_counter()
            if n_chains > 1:
                res = anneal_multistart(conf, bw, prof, spec,
                                        n_chains=n_chains,
                                        time_limit_s=sa_seconds,
                                        max_iters=sa_iters, seed=seed,
                                        engine=engine)
            else:
                res = anneal(conf, bw, prof, spec, time_limit_s=sa_seconds,
                             max_iters=sa_iters, seed=seed, engine=engine)
            sa_time += time.perf_counter() - ts
            cands.append(Candidate(conf, res.mapping, res.latency,
                                   float(mem_preds[i])))
    else:
        for i, conf in enumerate(survivors):
            cands.append(Candidate(conf, default_mapping(conf),
                                   float(base_lat[i]), float(mem_preds[i])))

    # record partition + schedule provenance on every candidate
    for c in cands:
        c.partition = prof_cache.partition_for(c.conf)
        c.schedule = c.conf.schedule

    cands.sort(key=lambda c: c.latency)
    return SearchResult(
        best=cands[0] if cands else None,
        ranked=cands,
        overhead=Overhead(total_s=time.perf_counter() - t0,
                          sa_s=sa_time, mem_estimator_s=mem_time,
                          enumerate_s=enum_s, profile_s=profile_s,
                          prescore_s=prescore_s,
                          n_enumerated=len(confs),
                          n_candidates=len(cands)))


def configure(w: Workload, spec: ClusterSpec, bw: np.ndarray, *,
              estimator: Optional[MemoryEstimator] = None,
              mem_limit: Optional[float] = None,
              sa_seconds: float = 1.0, sa_iters: int = 8_000,
              n_chains: int = 1, sa_topk: Optional[int] = None,
              max_micro: int = 16, fixed_micro: Optional[int] = None,
              max_cp: int = 1, max_tp: int = 0,
              partition: str = "uniform", max_vpp: int = 1,
              seed: int = 0,
              dedicate: bool = True) -> SearchResult:
    """Legacy kwarg entry point — a thin shim over the Planner API.

    Packs the kwarg pile into a declarative
    :class:`~repro.core.plan.PlanRequest` and runs it through
    ``Planner(PipetteStrategy(...))`` (or ``ExhaustiveStrategy`` when
    ``dedicate=False``).  Bit-exact with calling the Planner directly —
    same best conf, mapping, latency, and full ranking (enforced by
    ``tests/test_planner_api.py``) — so every historical caller keeps
    working unchanged.

    Args:
        w: workload (model config, sequence length, global batch).
        spec: cluster description.
        bw: ``(G, G)`` profiled bandwidth matrix.
        estimator / mem_limit: memory-pruning inputs (see
            :func:`run_search`).
        sa_seconds / sa_iters / n_chains / sa_topk: SA budget
            (:class:`~repro.core.plan.Budget`).
        max_micro / fixed_micro / max_cp / max_tp / partition / max_vpp:
            search-space knobs (:class:`~repro.core.plan.SearchSpace`).
        seed: RNG seed; the whole search is deterministic given it.
        dedicate: ``False`` gives the PPT-L ablation (identity mapping).

    Returns:
        The full :class:`SearchResult` (the Planner's in-process view;
        use the Planner directly to get the serializable ``Plan``).
    """
    from .plan import (Budget, ExhaustiveStrategy, Planner, PlanRequest,
                       PipetteStrategy, SearchSpace)
    req = PlanRequest(
        workload=w, spec=spec,
        space=SearchSpace(max_cp=max_cp, max_tp=max_tp, max_micro=max_micro,
                          fixed_micro=fixed_micro, partition=partition,
                          max_vpp=max_vpp),
        budget=Budget(sa_seconds=sa_seconds, sa_iters=sa_iters,
                      n_chains=n_chains, sa_topk=sa_topk),
        seed=seed)
    strategy = (PipetteStrategy(estimator=estimator, mem_limit=mem_limit)
                if dedicate
                else ExhaustiveStrategy(estimator=estimator,
                                        mem_limit=mem_limit))
    return Planner(strategy).plan(req, bw).result
