"""Algorithm 1 — the Pipette configurator.

Enumerates (pp, tp, dp) with pp*tp*dp = G and every microbatch divisor,
prunes configurations the memory estimator rejects, runs SA worker
dedication on each survivor scored by the latency estimator, and returns
the best (Conf, Map, T) plus a ranked list (for the Fig. 5b style top-k
analyses)."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from .cluster import ClusterSpec
from .dedication import SAResult, anneal
from .latency import pipette_latency
from .memory import MemoryEstimator, enumerate_confs
from .simulator import Conf, Profile, Workload, build_profile, default_mapping


@dataclass
class Candidate:
    conf: Conf
    mapping: np.ndarray
    latency: float
    mem_pred: float


@dataclass
class SearchResult:
    best: Optional[Candidate]
    ranked: List[Candidate]
    overhead: dict = field(default_factory=dict)

    def top(self, k: int = 10) -> List[Candidate]:
        return self.ranked[:k]


def configure(w: Workload, spec: ClusterSpec, bw: np.ndarray, *,
              estimator: Optional[MemoryEstimator] = None,
              mem_limit: Optional[float] = None,
              sa_seconds: float = 1.0, sa_iters: int = 8_000,
              max_micro: int = 16, fixed_micro: Optional[int] = None,
              seed: int = 0,
              dedicate: bool = True) -> SearchResult:
    """Pipette (Algorithm 1).  ``dedicate=False`` gives the PPT-L ablation
    (latency+memory estimators only, identity mapping)."""
    t0 = time.perf_counter()
    mem_limit = mem_limit if mem_limit is not None else spec.gpu_mem
    g = spec.n_gpus
    cands: List[Candidate] = []
    mem_time = 0.0
    sa_time = 0.0

    for conf in enumerate_confs(g, w.bs_global, n_layers=w.cfg.n_layers):
        if conf.bs_micro > max_micro:
            continue
        if fixed_micro is not None and conf.bs_micro != fixed_micro:
            continue
        prof = build_profile(w, spec, conf)
        tm = time.perf_counter()
        if estimator is not None:
            pred = estimator.predict(w.cfg, conf)
            mem_time += time.perf_counter() - tm
            if pred > mem_limit * estimator.soft_margin:
                continue
        else:
            pred = float("nan")
        if dedicate:
            ts = time.perf_counter()
            res = anneal(conf, bw, prof, spec, time_limit_s=sa_seconds,
                         max_iters=sa_iters, seed=seed)
            sa_time += time.perf_counter() - ts
            cands.append(Candidate(conf, res.mapping, res.latency, pred))
        else:
            m = default_mapping(conf)
            lat = pipette_latency(conf, m, bw, prof, spec)
            cands.append(Candidate(conf, m, lat, pred))

    cands.sort(key=lambda c: c.latency)
    return SearchResult(
        best=cands[0] if cands else None,
        ranked=cands,
        overhead={"total_s": time.perf_counter() - t0,
                  "sa_s": sa_time, "mem_estimator_s": mem_time,
                  "n_candidates": len(cands)})
