"""Algorithm 1 — the Pipette configurator.

Enumerates (pp, tp, dp) with pp*tp*dp = G and every microbatch divisor,
prunes configurations the memory estimator rejects, runs SA worker
dedication on each survivor scored by the latency estimator, and returns
the best (Conf, Map, T) plus a ranked list (for the Fig. 5b style top-k
analyses).

The SA stage uses the incremental :class:`~repro.core.dedication.
DedicationEngine`; its permutation-position index tensors depend only on the
(pp, tp, dp) shape, so they are built once per shape and shared across every
microbatch variant of that shape (``enumerate_confs`` yields many)."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .cluster import ClusterSpec
from .dedication import (DedicationEngine, GroupIndex, SAResult, anneal,
                         anneal_multistart)
from .latency import pipette_latency
from .memory import MemoryEstimator, enumerate_confs
from .simulator import Conf, Profile, Workload, build_profile, default_mapping


@dataclass
class Candidate:
    """One surviving configuration: (Conf, Map, T) plus the memory estimate.

    Attributes:
        conf: parallelism configuration.
        mapping: ``(pp, tp, dp)`` worker -> GPU dedication.
        latency: estimated seconds/iteration (Eq. 3-6).
        mem_pred: predicted peak bytes/GPU (``nan`` without an estimator).
    """
    conf: Conf
    mapping: np.ndarray
    latency: float
    mem_pred: float


@dataclass
class SearchResult:
    """Ranked output of :func:`configure`.

    Attributes:
        best: lowest-latency candidate (``None`` if nothing survived).
        ranked: all candidates, fastest first.
        overhead: timing breakdown — ``total_s``, ``sa_s``,
            ``mem_estimator_s``, ``n_candidates``.

    Example:
        >>> res = configure(w, spec, bw, sa_seconds=0.2)
        >>> res.best.conf.n_gpus == spec.n_gpus
        True
        >>> [str(c.conf) for c in res.top(3)]       # Fig. 5b style top-k
        ['pp4·tp8·dp2·mb2(n_mb=16)', ...]
    """
    best: Optional[Candidate]
    ranked: List[Candidate]
    overhead: dict = field(default_factory=dict)

    def top(self, k: int = 10) -> List[Candidate]:
        """First ``k`` candidates by estimated latency (fastest first)."""
        return self.ranked[:k]


def configure(w: Workload, spec: ClusterSpec, bw: np.ndarray, *,
              estimator: Optional[MemoryEstimator] = None,
              mem_limit: Optional[float] = None,
              sa_seconds: float = 1.0, sa_iters: int = 8_000,
              n_chains: int = 1,
              max_micro: int = 16, fixed_micro: Optional[int] = None,
              seed: int = 0,
              dedicate: bool = True) -> SearchResult:
    """Pipette (Algorithm 1): enumerate -> memory-prune -> dedicate -> rank.

    Args:
        w: workload (model config, sequence length, global batch).
        spec: cluster description.
        bw: ``(G, G)`` profiled bandwidth matrix from
            :func:`~repro.core.cluster.profile_bandwidth`.
        estimator: optional MLP memory estimator; prunes configs predicted
            to exceed ``mem_limit * soft_margin``.
        mem_limit: per-GPU memory budget in bytes (default ``spec.gpu_mem``).
        sa_seconds / sa_iters: total SA budget per candidate (split across
            chains when ``n_chains > 1``).
        n_chains: independent SA restarts per candidate, best-of
            (see :func:`~repro.core.dedication.anneal_multistart`).
        max_micro: skip configurations with ``bs_micro`` above this.
        fixed_micro: restrict to one microbatch size (ablations).
        seed: RNG seed; the whole search is deterministic given it.
        dedicate: ``False`` gives the PPT-L ablation (latency+memory
            estimators only, identity mapping).

    Returns:
        :class:`SearchResult` with the best candidate and the full ranking.
    """
    t0 = time.perf_counter()
    mem_limit = mem_limit if mem_limit is not None else spec.gpu_mem
    g = spec.n_gpus
    cands: List[Candidate] = []
    mem_time = 0.0
    sa_time = 0.0
    index_cache: Dict[Tuple[int, int, int], GroupIndex] = {}

    for conf in enumerate_confs(g, w.bs_global, n_layers=w.cfg.n_layers):
        if conf.bs_micro > max_micro:
            continue
        if fixed_micro is not None and conf.bs_micro != fixed_micro:
            continue
        prof = build_profile(w, spec, conf)
        tm = time.perf_counter()
        if estimator is not None:
            pred = estimator.predict(w.cfg, conf)
            mem_time += time.perf_counter() - tm
            if pred > mem_limit * estimator.soft_margin:
                continue
        else:
            pred = float("nan")
        if dedicate:
            shape = (conf.pp, conf.tp, conf.dp)
            idx = index_cache.get(shape)
            if idx is None:
                idx = index_cache[shape] = GroupIndex.build(conf)
            engine = DedicationEngine(conf, bw, prof, spec, index=idx)
            ts = time.perf_counter()
            if n_chains > 1:
                res = anneal_multistart(conf, bw, prof, spec,
                                        n_chains=n_chains,
                                        time_limit_s=sa_seconds,
                                        max_iters=sa_iters, seed=seed,
                                        engine=engine)
            else:
                res = anneal(conf, bw, prof, spec, time_limit_s=sa_seconds,
                             max_iters=sa_iters, seed=seed, engine=engine)
            sa_time += time.perf_counter() - ts
            cands.append(Candidate(conf, res.mapping, res.latency, pred))
        else:
            m = default_mapping(conf)
            lat = pipette_latency(conf, m, bw, prof, spec)
            cands.append(Candidate(conf, m, lat, pred))

    cands.sort(key=lambda c: c.latency)
    return SearchResult(
        best=cands[0] if cands else None,
        ranked=cands,
        overhead={"total_s": time.perf_counter() - t0,
                  "sa_s": sa_time, "mem_estimator_s": mem_time,
                  "n_candidates": len(cands)})
