"""Discrete-event simulator of 3D/4D-parallel training iterations.

This plays the role of the *real cluster* in the paper's evaluation
(DESIGN.md §2): configurations recommended by Pipette and the baselines are
"run" here, and both latency models (Pipette Eq. 3-6, AMP Eq. 1) are scored
against it.  It simulates the memory-efficient 1F1B schedule event-by-event
over the heterogeneous bandwidth matrix, including the effects the
first-order models do NOT capture — per-link p2p chains, fwd/bwd link
contention, per-op jitter and warmup transients — so estimator MAPEs are
meaningful.

Beyond the paper, :class:`Conf` carries a fourth, *context-parallel* degree
``cp`` (ring attention over sequence shards, Fujii et al. 2411.06465): each
cp rank holds ``seq / cp`` tokens and exchanges KV blocks around the cp ring
every layer.  ``cp == 1`` is a strict special case — every quantity below is
bit-identical to the historical 3D implementation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from ..models.config import ModelConfig
from . import flops as F
from .cluster import (ClusterSpec, compute_slowdowns, min_group_bw,
                      min_group_bw_batch, ring_allreduce_time)
from .partition import Partition, PartitionCache, uniform_partition


# ---------------------------------------------------------------------------
# configuration / workload
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Conf:
    """A 4D parallelism configuration: (pp, tp, cp, dp) plus microbatching.

    ``cp`` (context parallelism: ring attention over sequence shards)
    defaults to 1, which reproduces the paper's 3D search space exactly —
    every historical ``Conf(pp, tp, dp, bs_micro, bs_global)`` call keeps
    its meaning.

    ``vpp`` is the interleaved-1F1B virtual-pipeline factor (Megatron-LM's
    ``virtual_pipeline_model_parallel_size``): each physical stage hosts
    ``vpp`` non-adjacent model chunks, shrinking the fill/drain bubble by
    ``~1/vpp`` at the price of ``vpp``× the inter-stage traffic.  ``vpp ==
    1`` is plain 1F1B — the bit-exact historical schedule.
    """
    pp: int
    tp: int
    dp: int
    bs_micro: int
    bs_global: int
    cp: int = 1
    vpp: int = 1

    @property
    def n_gpus(self) -> int:
        return self.pp * self.tp * self.cp * self.dp

    @property
    def bs_mini(self) -> int:
        return self.bs_global // self.dp

    @property
    def n_mb(self) -> int:
        return self.bs_mini // self.bs_micro

    def valid(self) -> bool:
        """Divisibility and an explicit non-empty-schedule check.

        ``n_mb == 0`` (a microbatch larger than the minibatch) is rejected
        here rather than relying on every caller to notice that Eq. 3-6
        degenerate at zero microbatches.
        """
        return (min(self.pp, self.tp, self.cp, self.dp,
                    self.bs_micro, self.vpp) >= 1 and
                self.bs_global % self.dp == 0 and
                self.bs_mini % self.bs_micro == 0 and
                self.n_mb >= 1)

    def schedulable(self) -> bool:
        """True when the schedule can fill the pipeline: memory-efficient
        1F1B needs at least ``pp`` microbatches, otherwise the Eq. 3-6
        exposure count ``n_mb / pp`` drops below one and the model scores a
        schedule that cannot exist (see ``enumerate_confs``'s strict gate).
        Interleaved-1F1B (``vpp > 1``) additionally requires ``pp > 1`` and
        ``n_mb % pp == 0`` (Megatron-LM's interleaving constraint); the
        ``n_layers >= pp * vpp`` chunking bound is checked where the model
        is known (``enumerate_confs``).
        """
        ok = self.valid() and self.n_mb >= self.pp
        if self.vpp > 1:
            ok = ok and self.pp > 1 and self.n_mb % self.pp == 0
        return ok

    @property
    def schedule(self) -> str:
        """The pipeline schedule this configuration runs (PLN009 names)."""
        return "interleaved-1f1b" if self.vpp > 1 else "1f1b"

    def __str__(self):
        cp = f"·cp{self.cp}" if self.cp > 1 else ""
        vpp = f"·vpp{self.vpp}" if self.vpp > 1 else ""
        return (f"pp{self.pp}·tp{self.tp}{cp}{vpp}·dp{self.dp}"
                f"·mb{self.bs_micro}(n_mb={self.n_mb})")


@dataclass(frozen=True)
class Workload:
    cfg: ModelConfig
    seq: int
    bs_global: int
    grad_bytes: int = 4            # fp32 main grads (Megatron default)


def default_mapping(conf: Conf) -> np.ndarray:
    """Identity (node-major) worker dedication: tp contiguous, then cp,
    then dp, then pp — the standard Megatron-LM order extended with the
    context axis between tp and dp.

    Args:
        conf: parallelism configuration.

    Returns:
        ``(pp, tp, dp)`` integer mapping with GPU ids ``0..n_gpus-1`` when
        ``cp == 1`` (the historical shape), else ``(pp, tp, cp, dp)``.
    """
    g = np.arange(conf.n_gpus)
    if conf.cp == 1:
        # worker (x, y, z) -> gpu x*(dp*tp) + z*tp + y
        return g.reshape(conf.pp, conf.dp, conf.tp).transpose(0, 2, 1)
    # worker (x, y, k, z) -> gpu x*(dp*cp*tp) + z*(cp*tp) + k*tp + y
    return g.reshape(conf.pp, conf.dp, conf.cp,
                     conf.tp).transpose(0, 3, 2, 1)


def mapping4(conf: Conf, mapping: np.ndarray) -> np.ndarray:
    """Canonical ``(pp, tp, cp, dp)`` view of a worker mapping.

    Accepts the legacy 3D ``(pp, tp, dp)`` shape (valid only when
    ``cp == 1``, where it is the same memory layout) as well as the 4D
    shape or anything reshapeable to it; every mapping consumer in
    ``latency``/``simulator``/``dedication`` normalizes through here.
    """
    return np.asarray(mapping, dtype=np.intp).reshape(
        conf.pp, conf.tp, conf.cp, conf.dp)


def stage_work(n_layers: int, pp: int) -> Tuple[float, ...]:
    """Relative per-stage compute work, normalised to the heaviest stage.

    The contiguous layer split gives the first ``n_layers % pp`` stages
    ``ceil(n_layers / pp)`` layers and the rest one fewer; the profiled
    per-microbatch compute (:func:`build_profile`) is priced at the heaviest
    stage, so entry ``x`` is ``layers_x / ceil(n_layers / pp)`` — all 1.0
    when ``pp`` divides ``n_layers``.

    This is the *uniform-split* special case of ``Profile.stage_work``:
    non-uniform partitions (``build_profile(..., partition=...)``) replace
    it with per-stage cost fractions from the per-layer cost vector, and
    the same consumers (``_hetero_combine``, ``DedicationEngine``,
    ``jax_engine``, the simulator) price arbitrary per-stage work.  The
    homogeneous *uniform* model keeps the paper's single-scalar
    formulation bit-for-bit.
    """
    full = -(-n_layers // pp)
    base, rem = n_layers // pp, n_layers % pp
    return tuple((base + 1 if x < rem else base) / full for x in range(pp))


def ring_kv_block_bytes(cfg: ModelConfig, bs_micro: int, seq: int,
                        cp: int) -> float:
    """Bytes of the K+V block one cp rank passes per ring-attention step
    (bf16): ``2 (K and V) * bs_micro * seq/cp * kv_dim * 2 bytes``.

    The single source of the block-size formula — both the latency/profile
    side (:func:`_profile_dynamic`) and the memory ground truth
    (``memory._ring_kv_bytes``) must price the same message, or estimator
    MAPEs silently drift.
    """
    kv_dim = max(cfg.n_kv_heads, 1) * cfg.hd if cfg.n_heads else cfg.d_model
    return 2 * bs_micro * (seq / cp) * kv_dim * 2.0


# ---------------------------------------------------------------------------
# profiled per-microbatch quantities (Alg. 1 uses these as inputs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Profile:
    c_fwd: float                   # per-microbatch fwd compute seconds
    c_bwd: float
    t_tp_fwd: float                # per-microbatch TP all-reduce seconds, fwd
    t_tp_bwd: float
    msg_pp: float                  # bytes of one inter-stage activation
    msg_dp: float                  # per-GPU gradient bytes (stage share)
    stage_params: float            # params on the largest stage
    tp_ref_bw: float = 300e9       # bandwidth T_tp was profiled at
    # --- context parallelism (all exactly 0 / unused when cp == 1) ---
    t_cp_fwd: float = 0.0          # per-microbatch ring KV-exchange s, fwd
    t_cp_bwd: float = 0.0
    msg_cp: float = 0.0            # bytes of one KV block sent per ring step
    cp_ref_bw: float = 300e9       # bandwidth T_cp was profiled at
    # --- heterogeneous compute / non-uniform partitions ---
    # per-stage relative work; the uniform split's layer-count ratios
    # (:func:`stage_work`) or, with a partition, per-stage cost fractions
    # normalised to the heaviest stage.  None (legacy direct
    # constructions) means uniform stages
    stage_work: Optional[Tuple[float, ...]] = None
    # --- non-uniform pipeline partition / interleaved-1F1B ---
    # cumulative chunk boundaries (``pp * vpp`` entries; == stage
    # boundaries for plain 1F1B).  None = the legacy uniform split, the
    # trigger for every consumer's bit-exact historical path
    partition: Optional[Tuple[int, ...]] = None
    # per virtual-chunk work fractions, same normalisation as
    # ``stage_work`` (chunks of one stage sum to its stage_work entry);
    # only set when vpp > 1
    chunk_work: Optional[Tuple[float, ...]] = None


def _profile_static(w: Workload, spec: ClusterSpec,
                    conf: Conf) -> Tuple[float, float, float, tuple]:
    """The :class:`Profile` fields that depend only on ``(pp, tp)``.

    ``stage_params``, ``msg_dp``, ``tp_ref_bw`` and the per-stage work
    vector are independent of ``bs_micro`` (and of ``dp``), so
    :class:`ProfileCache` shares them across every microbatch variant of a
    parallelism shape.

    Returns:
        ``(stage_params, msg_dp, tp_ref_bw, stage_work)``.
    """
    cfg = w.cfg
    tp_ref_bw = spec.intra_bw if conf.tp <= spec.gpus_per_node \
        else spec.inter_bw
    p_total = F.param_count(cfg)
    stage_params = (p_total - 2 * cfg.vocab_size * cfg.d_model) / conf.pp \
        + 2 * cfg.vocab_size * cfg.d_model / min(conf.pp, 2)
    msg_dp = stage_params / conf.tp * w.grad_bytes
    return stage_params, msg_dp, tp_ref_bw, stage_work(cfg.n_layers, conf.pp)


def _profile_nonuniform(w: Workload, spec: ClusterSpec, conf: Conf,
                        static: Tuple[float, float, float, tuple],
                        partition: Optional[Partition]) -> Profile:
    """:func:`_profile_dynamic` for non-uniform partitions and/or
    interleaved-1F1B: per-chunk costs from the per-layer cost vector, the
    compute scalar priced at the heaviest *physical* stage, and the
    embedding/LM-head GEMMs pinned to the end chunks instead of amortized
    ``1/pp``.  ``partition`` is at chunk granularity (``pp * vpp``
    boundaries); None means uniform chunking."""
    cfg = w.cfg
    stage_params, msg_dp, tp_ref_bw, _ = static
    pp, vpp = conf.pp, conf.vpp
    n_chunks = pp * vpp
    part = partition if partition is not None \
        else uniform_partition(cfg.n_layers, n_chunks)
    if part.pp != n_chunks:
        raise ValueError(f"partition has {part.pp} stages; conf {conf} "
                         f"needs pp*vpp = {n_chunks}")
    if part.n_layers != cfg.n_layers:
        raise ValueError(f"partition covers {part.n_layers} layers; "
                         f"model has {cfg.n_layers}")
    tokens_mb = conf.bs_micro * w.seq / conf.cp     # per cp-rank tokens
    ftok = part.stage_sums(F.layer_cost_per_token(cfg, w.seq))
    e = F.embed_cost_per_token(cfg)
    ftok[0] += e                                    # embedding
    ftok[-1] += e                                   # LM head
    # physical stage x runs chunks x, x+pp, ... (Megatron interleaving)
    stage_ftok = ftok.reshape(vpp, pp).sum(axis=0)
    f_max = float(stage_ftok.max())
    eff_mb = conf.bs_micro / (conf.bs_micro + 1.0)
    thru = spec.gpu_flops * spec.efficiency * 1.25 * eff_mb * conf.tp
    c_fwd = f_max * tokens_mb / thru
    c_bwd = 2.0 * c_fwd
    stage_w = tuple((stage_ftok / f_max).tolist())
    chunk_w = tuple((ftok / f_max).tolist()) if vpp > 1 else None

    # comm terms priced at the heaviest physical stage's layer count
    sizes = np.asarray(part.sizes).reshape(vpp, pp).sum(axis=0)
    layers_stage = int(sizes.max())
    msg_tp = conf.bs_micro * w.seq * cfg.d_model * 2 / conf.cp
    t_ar = ring_allreduce_time(msg_tp, tp_ref_bw, conf.tp)
    t_tp = 2 * layers_stage * t_ar
    msg_pp = conf.bs_micro * w.seq * cfg.d_model * 2.0 / conf.cp
    if conf.cp > 1:
        msg_cp = ring_kv_block_bytes(cfg, conf.bs_micro, w.seq, conf.cp)
        cp_ref_bw = spec.intra_bw if conf.tp * conf.cp <= spec.gpus_per_node \
            else spec.inter_bw
        t_cp_fwd = layers_stage * (conf.cp - 1) * msg_cp / cp_ref_bw
        t_cp_bwd = 2.0 * t_cp_fwd
    else:
        msg_cp, t_cp_fwd, t_cp_bwd, cp_ref_bw = 0.0, 0.0, 0.0, tp_ref_bw
    return Profile(c_fwd, c_bwd, t_tp, 2 * t_tp, msg_pp, msg_dp,
                   stage_params, tp_ref_bw, t_cp_fwd, t_cp_bwd, msg_cp,
                   cp_ref_bw, stage_w, tuple(part.boundaries), chunk_w)


def _profile_dynamic(w: Workload, spec: ClusterSpec, conf: Conf,
                     static: Tuple[float, float, float, tuple],
                     partition: Optional[Partition] = None) -> Profile:
    """The ``(bs_micro, cp)``-dependent remainder of :func:`build_profile`.

    Context parallelism shards every per-microbatch quantity over the
    sequence axis: each cp rank computes/communicates ``1 / cp`` of the
    tokens (``tokens_mb / cp`` is an exact float at ``cp == 1``, so the 3D
    numbers are reproduced bit-for-bit), and a ring KV-exchange term
    appears (``cp - 1`` steps per layer, Fujii et al. 2411.06465).

    A non-uniform ``partition`` (or ``conf.vpp > 1``) routes to
    :func:`_profile_nonuniform`; the default path below is the bit-exact
    legacy uniform-split formulation.
    """
    if partition is not None or conf.vpp > 1:
        return _profile_nonuniform(w, spec, conf, static, partition)
    cfg = w.cfg
    stage_params, msg_dp, tp_ref_bw, stage_w = static
    layers_stage = -(-cfg.n_layers // conf.pp)
    tokens_mb = conf.bs_micro * w.seq / conf.cp     # per cp-rank tokens
    n_active = F.active_param_count(cfg)
    body = n_active - 2 * cfg.vocab_size * cfg.d_model
    body = max(body, int(0.5 * n_active))
    stage_flops_fwd = 2.0 * (body * layers_stage / cfg.n_layers) * tokens_mb
    # ring attention: seq/cp local queries attend over the full sequence
    stage_flops_fwd += 2.0 * F.attention_flops(cfg, w.seq, tokens_mb, train=False) \
        * layers_stage / cfg.n_layers / 2
    # embedding + head flops live on first/last stage; fold in evenly
    stage_flops_fwd += 2.0 * 2 * cfg.vocab_size * cfg.d_model * tokens_mb / conf.pp
    # GEMM batch-efficiency: small microbatches underutilise the GPU
    # (this is why AMP-style memory-blind searches drift toward large
    # bs_micro and recommend OOM configs — §VI / Fig. 5b)
    eff_mb = conf.bs_micro / (conf.bs_micro + 1.0)
    thru = spec.gpu_flops * spec.efficiency * 1.25 * eff_mb * conf.tp
    c_fwd = stage_flops_fwd / thru
    c_bwd = 2.0 * c_fwd

    # Megatron TP: 2 all-reduces per layer per direction.  When a TP group
    # cannot fit inside a node, its ring bottlenecks on the (nominal)
    # inter-node link — visible to every configurator.
    msg_tp = conf.bs_micro * w.seq * cfg.d_model * 2 / conf.cp
    t_ar = ring_allreduce_time(msg_tp, tp_ref_bw, conf.tp)
    t_tp = 2 * layers_stage * t_ar
    msg_pp = conf.bs_micro * w.seq * cfg.d_model * 2.0 / conf.cp

    # Ring-attention KV exchange: cp-1 steps per layer, each passing the
    # local K+V block (bf16) around the cp ring; backward additionally
    # returns dK/dV.  Zero when cp == 1 so the 3D path is untouched.
    if conf.cp > 1:
        msg_cp = ring_kv_block_bytes(cfg, conf.bs_micro, w.seq, conf.cp)
        cp_ref_bw = spec.intra_bw if conf.tp * conf.cp <= spec.gpus_per_node \
            else spec.inter_bw
        t_cp_fwd = layers_stage * (conf.cp - 1) * msg_cp / cp_ref_bw
        t_cp_bwd = 2.0 * t_cp_fwd
    else:
        msg_cp, t_cp_fwd, t_cp_bwd, cp_ref_bw = 0.0, 0.0, 0.0, tp_ref_bw
    return Profile(c_fwd, c_bwd, t_tp, 2 * t_tp, msg_pp, msg_dp,
                   stage_params, tp_ref_bw, t_cp_fwd, t_cp_bwd, msg_cp,
                   cp_ref_bw, stage_w)


def build_profile(w: Workload, spec: ClusterSpec, conf: Conf,
                  partition: Optional[Partition] = None) -> Profile:
    """Derive the profiled per-microbatch quantities for one configuration.

    Stands in for the paper's on-cluster profiling stage: per-microbatch
    fwd/bwd compute (with the GEMM batch-efficiency penalty for tiny
    microbatches), per-microbatch TP all-reduce time at the nominal group
    bandwidth, and the inter-stage / data-parallel message sizes.

    Args:
        w: workload (model config, sequence length, global batch).
        spec: cluster description.
        conf: parallelism configuration being profiled.
        partition: optional non-uniform chunk partition (``pp * vpp``
            boundaries).  None keeps the bit-exact legacy uniform split
            (unless ``conf.vpp > 1``, which needs per-chunk pricing).

    Returns:
        :class:`Profile` consumed by the latency estimators and simulator.
    """
    return _profile_dynamic(w, spec, conf, _profile_static(w, spec, conf),
                            partition)


class ProfileCache:
    """Memoized :func:`build_profile` for one ``(workload, spec)`` pair.

    A :class:`Profile` is fully determined by ``(pp, tp, cp, bs_micro, vpp,
    partition)`` — it does not depend on ``dp`` — so the configurator's
    enumeration (which yields many ``dp``/microbatch variants per shape)
    hits the cache heavily.  The cache key includes the *partition
    identity* (the resolved chunk boundaries, or None for the uniform
    split): two partition modes producing different boundaries at the same
    ``(pp, tp, cp, bs_micro)`` can never alias a stale profile.  The
    ``(pp, tp)``-only fields (:func:`_profile_static`) are additionally
    shared across microbatch and context-parallel variants; the
    ``(bs_micro, cp)``-dependent remainder is built lazily on first use.
    Returned profiles are bit-identical to :func:`build_profile`.

    Example:
        >>> cache = ProfileCache(w, spec)
        >>> cache.get(conf) == build_profile(w, spec, conf)
        True
    """

    def __init__(self, w: Workload, spec: ClusterSpec,
                 partition: str = "uniform"):
        self.w = w
        self.spec = spec
        self._parts = PartitionCache(w.cfg, w.seq, partition)
        self._static: Dict[Tuple[int, int],
                           Tuple[float, float, float, tuple]] = {}
        self._full: Dict[tuple, Profile] = {}

    def partition_for(self, conf: Conf) -> Optional[Partition]:
        """The resolved chunk partition for ``conf`` (None = uniform)."""
        return self._parts.get(conf.pp * conf.vpp)

    def get(self, conf: Conf) -> Profile:
        """The :class:`Profile` for ``conf``, computed at most once per
        ``(pp, tp, cp, bs_micro, vpp, partition boundaries)``."""
        part = self.partition_for(conf)
        key = (conf.pp, conf.tp, conf.cp, conf.bs_micro, conf.vpp,
               None if part is None else part.boundaries)
        prof = self._full.get(key)
        if prof is None:
            skey = key[:2]
            static = self._static.get(skey)
            if static is None:
                static = self._static[skey] = \
                    _profile_static(self.w, self.spec, conf)
            prof = self._full[key] = \
                _profile_dynamic(self.w, self.spec, conf, static, part)
        return prof


# ---------------------------------------------------------------------------
# 1F1B schedule simulation
# ---------------------------------------------------------------------------

def _one_f_one_b_order(pp: int, s: int, n_mb: int):
    warm = min(pp - s, n_mb)
    ops = [("f", m) for m in range(warm)]
    nf = warm
    for m in range(n_mb):
        ops.append(("b", m))
        if nf < n_mb:
            ops.append(("f", nf))
            nf += 1
    return ops


def hier_allreduce_batch(ids: np.ndarray, bw: np.ndarray, msg_bytes: float,
                         spec: ClusterSpec) -> np.ndarray:
    """Batched hierarchical-ring all-reduce time for many groups at once.

    Each row of ``ids`` is one data-parallel communicator group.  The
    hierarchical schedule is the reference one: a phases=4 reduce-scatter /
    all-gather ring inside every node-local sub-group (bottlenecked by that
    sub-group's slowest link), then a phases=2 ring across one representative
    GPU per node (the first group member on each node).

    Args:
        ids: ``(n_groups, m)`` GPU ids, one communicator group per row.
        bw: ``(G, G)`` bandwidth matrix in bytes/s.
        msg_bytes: gradient bytes each rank contributes.
        spec: cluster description (for the GPU -> node map).

    Returns:
        ``(n_groups,)`` seconds, bit-identical to the scalar reference
        (``dp_allreduce_times_ref``'s inner loop) applied per row.
    """
    ids = np.asarray(ids, dtype=np.intp)
    n_groups, m = ids.shape
    if m <= 1:
        return np.zeros(n_groups)
    sub = bw[ids[:, :, None], ids[:, None, :]]            # (n_groups, m, m)
    node = ids // spec.gpus_per_node
    same = node[:, :, None] == node[:, None, :]
    eye = np.eye(m, dtype=bool)[None, :, :]
    off = same & ~eye
    # Per-member min over same-node links in both directions; the member that
    # attains its node-cluster's global min reproduces the reference ring time
    # exactly (the ring coefficient is constant inside a cluster).
    masked = np.where(off, sub, np.inf)
    member_min = np.minimum(masked.min(axis=2), masked.min(axis=1))
    counts = same.sum(axis=2)                              # (n_groups, m)
    with np.errstate(divide="ignore", invalid="ignore"):
        intra_vals = 4 * (counts - 1) / counts * msg_bytes / member_min
    intra_t = np.where(counts > 1, intra_vals, 0.0).max(axis=1)

    # Representatives: first group member on each node (insertion order of the
    # reference dict) — membership matters because rep-to-rep links differ.
    j_lt_i = np.arange(m)[None, None, :] < np.arange(m)[None, :, None]
    is_rep = ~(same & j_lt_i).any(axis=2)
    n_reps = is_rep.sum(axis=1)
    pair = is_rep[:, :, None] & is_rep[:, None, :] & ~eye
    rep_min = np.where(pair, sub, np.inf).min(axis=(1, 2))
    with np.errstate(divide="ignore", invalid="ignore"):
        inter_vals = 2 * (n_reps - 1) / n_reps * msg_bytes / rep_min
    inter_t = np.where(n_reps > 1, inter_vals, 0.0)
    return intra_t + inter_t


def dp_allreduce_times(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                       prof: Profile, spec: ClusterSpec) -> np.ndarray:
    """Hierarchical-ring DP all-reduce seconds per pipeline stage (Eq. 6
    structure, evaluated on an arbitrary bandwidth matrix).

    Vectorized: all ``pp * tp * cp`` data-parallel groups are gathered and
    reduced in one batch (see :func:`hier_allreduce_batch`); per stage the
    slowest (tp, cp) slice wins.  Matches :func:`dp_allreduce_times_ref`
    bit-for-bit.

    Args:
        conf: parallelism configuration.
        mapping: ``(pp, tp, dp)`` or ``(pp, tp, cp, dp)`` worker -> GPU
            dedication.
        bw: ``(G, G)`` bandwidth matrix in bytes/s.
        prof: profiled per-microbatch quantities (uses ``msg_dp``).
        spec: cluster description.

    Returns:
        ``(pp,)`` all-reduce seconds per pipeline stage.
    """
    ids = mapping4(conf, mapping).reshape(conf.pp * conf.tp * conf.cp,
                                          conf.dp)
    t = hier_allreduce_batch(ids, np.asarray(bw), prof.msg_dp, spec)
    return np.maximum(t.reshape(conf.pp, conf.tp * conf.cp).max(axis=1), 0.0)


def dp_allreduce_times_ref(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                           prof: Profile, spec: ClusterSpec) -> np.ndarray:
    """Reference (pure-Python loop) implementation of
    :func:`dp_allreduce_times`; kept as the equivalence/benchmark oracle."""
    m4 = mapping4(conf, mapping)
    out = np.zeros(conf.pp)
    for x in range(conf.pp):
        worst = 0.0
        for y in range(conf.tp):
            for k in range(conf.cp):
                group = [int(m4[x, y, k, z]) for z in range(conf.dp)]
                nodes: Dict[int, list] = {}
                for gpu in group:
                    nodes.setdefault(spec.node_of(gpu), []).append(gpu)
                intra_t = 0.0
                for gs in nodes.values():
                    if len(gs) > 1:
                        t = ring_allreduce_time(prof.msg_dp,
                                                min_group_bw(bw, gs),
                                                len(gs), phases=4)
                        intra_t = max(intra_t, t)
                reps = [gs[0] for gs in nodes.values()]
                inter_t = 0.0
                if len(reps) > 1:
                    inter_t = ring_allreduce_time(prof.msg_dp,
                                                  min_group_bw(bw, reps),
                                                  len(reps), phases=2)
                worst = max(worst, intra_t + inter_t)
        out[x] = worst
    return out


def simulate_iteration(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                       prof: Profile, spec: ClusterSpec, *,
                       jitter: float = 0.015, contention: float = 0.05,
                       seed: int = 0) -> Dict:
    """Event-driven 1F1B iteration on an arbitrary bandwidth matrix.

    Models what the first-order estimators do not: per-link p2p chains,
    fwd/bwd link contention, per-op jitter and warmup transients.  With
    ``conf.cp > 1`` every forward/backward op additionally carries the ring
    KV-exchange time of its slowest cp group, evaluated on the true links.
    On a tiered spec every op plays back at its ranks' *true* speed: the
    (stage, replica) compute time stretches by the slowest member GPU's
    :func:`~repro.core.cluster.compute_slowdowns` factor and shrinks by the
    stage's relative layer work (``prof.stage_work``) — so compute-aware
    dedication wins are measurable here, not just in the model.

    Args:
        conf: parallelism configuration.
        mapping: ``(pp, tp, dp)`` or ``(pp, tp, cp, dp)`` worker -> GPU
            dedication.
        bw: bandwidth matrix to "run" on (usually the ground truth).
        prof: profiled per-microbatch quantities.
        spec: cluster description.
        jitter: per-op lognormal-ish duration noise.
        contention: fractional slowdown of contended steady-state hops.
        seed: RNG seed for the jitter.

    Returns:
        Dict with ``total`` seconds plus per-stage/per-link breakdowns
        (``stage_finish``, ``t_dp``, ``t_pp``).
    """
    if conf.vpp > 1:
        return _simulate_interleaved(conf, mapping, bw, prof, spec,
                                     jitter=jitter, contention=contention,
                                     seed=seed)
    pp, tp, cp, dp, n_mb = conf.pp, conf.tp, conf.cp, conf.dp, conf.n_mb
    rng = np.random.default_rng(seed * 131071 + conf.n_gpus)

    m4 = mapping4(conf, mapping)

    # per-replica p2p link times between adjacent stages (slowest tp/cp pair)
    t_pp = np.zeros((dp, max(pp - 1, 1)))
    if pp > 1:
        link = bw[m4[:-1], m4[1:]].reshape(pp - 1, tp * cp, dp).min(axis=1)
        t_pp = (prof.msg_pp / link).T

    # actual TP time uses true intra-group links (model uses nominal);
    # per (stage, replica) the slowest cp slice wins
    groups = m4.transpose(0, 2, 3, 1).reshape(pp * cp * dp, tp)
    gbw = min_group_bw_batch(bw, groups)
    scale = np.where(np.isfinite(gbw) & (gbw > 0), prof.tp_ref_bw / gbw, 1.0)
    t_tpf = (prof.t_tp_fwd * scale).reshape(pp, cp, dp).max(axis=1).T

    # ring KV-exchange time on the true cp-group links (worst tp slice)
    t_cpf = np.zeros((dp, pp))
    if cp > 1:
        cgroups = m4.transpose(0, 1, 3, 2).reshape(pp * tp * dp, cp)
        cgbw = min_group_bw_batch(bw, cgroups)
        cscale = np.where(np.isfinite(cgbw) & (cgbw > 0),
                          prof.cp_ref_bw / cgbw, 1.0)
        t_cpf = (prof.t_cp_fwd * cscale).reshape(pp, tp, dp).max(axis=1).T

    # per-(replica, stage) compute at each rank's true speed: the slowest
    # (tp, cp) member sets the stage's GEMM time (the work is evenly
    # sharded, so everyone waits on it), lighter stages do less work.
    # Homogeneous specs fill these with the profiled scalars exactly.
    slow = compute_slowdowns(spec)
    c_fwd_zs = np.full((dp, pp), prof.c_fwd)
    c_bwd_zs = np.full((dp, pp), prof.c_bwd)
    if slow is not None:
        sw = np.asarray(prof.stage_work if prof.stage_work is not None
                        else np.ones(pp))
        stage_slow = slow[m4].reshape(pp, tp * cp, dp).max(axis=1)
        c_scale = (stage_slow * sw[:, None]).T          # (dp, pp)
        c_fwd_zs = prof.c_fwd * c_scale
        c_bwd_zs = prof.c_bwd * c_scale
    elif prof.partition is not None:
        # non-uniform partition on a homogeneous fleet: stages still do
        # different amounts of work (the legacy np.full path above stays
        # untouched for partition-None profiles)
        sw = np.asarray(prof.stage_work if prof.stage_work is not None
                        else np.ones(pp))
        c_fwd_zs = prof.c_fwd * np.broadcast_to(sw, (dp, pp))
        c_bwd_zs = prof.c_bwd * np.broadcast_to(sw, (dp, pp))

    finish_stage = np.zeros((dp, pp))
    for z in range(dp):
        orders = [_one_f_one_b_order(pp, s, n_mb) for s in range(pp)]
        ptr = [0] * pp
        t_stage = [0.0] * pp
        done_f: Dict[Tuple[int, int], float] = {}
        done_b: Dict[Tuple[int, int], float] = {}
        remaining = sum(len(o) for o in orders)
        while remaining:
            progressed = False
            for s in range(pp):
                while ptr[s] < len(orders[s]):
                    op, m = orders[s][ptr[s]]
                    if op == "f":
                        if s == 0:
                            ready = 0.0
                        else:
                            dep = done_f.get((s - 1, m))
                            if dep is None:
                                break
                            cont = 1.0 + (contention if m >= pp else 0.0)
                            ready = dep + t_pp[z, s - 1] * cont
                        dur = c_fwd_zs[z, s] + t_tpf[z, s] + t_cpf[z, s]
                    else:
                        if s == pp - 1:
                            dep = done_f.get((s, m))
                        else:
                            dep = done_b.get((s + 1, m))
                        if dep is None:
                            break
                        ready = dep if s == pp - 1 else dep + t_pp[z, s] * (1 + contention)
                        dur = c_bwd_zs[z, s] + 2 * t_tpf[z, s] + 2 * t_cpf[z, s]
                    if m == 0:
                        dur *= 1.03          # warmup transient
                    dur *= 1.0 + jitter * rng.standard_normal()
                    start = max(t_stage[s], ready)
                    end = start + max(dur, 0.0)
                    if op == "f":
                        done_f[(s, m)] = end
                    else:
                        done_b[(s, m)] = end
                    t_stage[s] = end
                    ptr[s] += 1
                    remaining -= 1
                    progressed = True
            if not progressed:
                raise RuntimeError("1F1B schedule deadlock (invalid order)")
        finish_stage[z] = t_stage

    t_dp = dp_allreduce_times(conf, mapping, bw, prof, spec)
    stage_finish = finish_stage.max(axis=0)          # DP sync couples replicas
    total = float((stage_finish + t_dp).max())
    return {"total": total, "stage_finish": stage_finish, "t_dp": t_dp,
            "t_pp": t_pp}


def _simulate_interleaved(conf: Conf, mapping: np.ndarray, bw: np.ndarray,
                          prof: Profile, spec: ClusterSpec, *,
                          jitter: float, contention: float,
                          seed: int) -> Dict:
    """Event-driven interleaved-1F1B (``conf.vpp > 1``) iteration.

    The schedule is plain 1F1B over the *virtual* pipeline of depth
    ``P = pp * vpp``; virtual stage ``s`` runs on physical stage
    ``s % pp`` (Megatron-LM's chunk layout), so all ``vpp`` chunks hosted
    on one physical stage share that stage's serial compute clock.  Each
    hop between consecutive virtual stages is a real p2p transfer — the
    wrap hop ``pp-1 -> 0`` included — which is where interleaving pays
    ``vpp``× the inter-stage traffic for its ``~1/vpp`` bubble.
    """
    pp, tp, cp, dp, n_mb = conf.pp, conf.tp, conf.cp, conf.dp, conf.n_mb
    vpp = conf.vpp
    P = pp * vpp
    rng = np.random.default_rng(seed * 131071 + conf.n_gpus)

    m4 = mapping4(conf, mapping)

    # per-replica p2p hop times leaving each physical stage; column pp-1 is
    # the wrap hop pp-1 -> 0 carrying chunk-boundary activations
    t_hop = np.zeros((dp, pp))
    if pp > 1:
        link = bw[m4[:-1], m4[1:]].reshape(pp - 1, tp * cp, dp).min(axis=1)
        t_hop[:, :pp - 1] = (prof.msg_pp / link).T
    wlink = bw[m4[-1], m4[0]].reshape(tp * cp, dp).min(axis=0)
    t_hop[:, pp - 1] = prof.msg_pp / wlink

    # TP/cp comm per *chunk*: the profiled per-microbatch terms cover the
    # heaviest stage's full layer count, split across its vpp chunks
    groups = m4.transpose(0, 2, 3, 1).reshape(pp * cp * dp, tp)
    gbw = min_group_bw_batch(bw, groups)
    scale = np.where(np.isfinite(gbw) & (gbw > 0), prof.tp_ref_bw / gbw, 1.0)
    t_tpf = (prof.t_tp_fwd * scale).reshape(pp, cp, dp).max(axis=1).T / vpp

    t_cpf = np.zeros((dp, pp))
    if cp > 1:
        cgroups = m4.transpose(0, 1, 3, 2).reshape(pp * tp * dp, cp)
        cgbw = min_group_bw_batch(bw, cgroups)
        cscale = np.where(np.isfinite(cgbw) & (cgbw > 0),
                          prof.cp_ref_bw / cgbw, 1.0)
        t_cpf = (prof.t_cp_fwd * cscale).reshape(pp, tp, dp).max(axis=1).T \
            / vpp

    # per-(replica, virtual chunk) compute; tiered fleets stretch each
    # chunk by its physical stage's slowest member
    cw = np.asarray(prof.chunk_work if prof.chunk_work is not None
                    else [1.0 / vpp] * P)
    phys_of = np.arange(P) % pp
    c_f = np.broadcast_to(prof.c_fwd * cw, (dp, P)).copy()
    c_b = np.broadcast_to(prof.c_bwd * cw, (dp, P)).copy()
    slow = compute_slowdowns(spec)
    if slow is not None:
        stage_slow = slow[m4].reshape(pp, tp * cp, dp).max(axis=1)  # (pp, dp)
        c_f *= stage_slow[phys_of].T
        c_b *= stage_slow[phys_of].T

    finish_stage = np.zeros((dp, pp))
    for z in range(dp):
        orders = [_one_f_one_b_order(P, s, n_mb) for s in range(P)]
        ptr = [0] * P
        t_phys = [0.0] * pp          # shared serial clock per physical stage
        done_f: Dict[Tuple[int, int], float] = {}
        done_b: Dict[Tuple[int, int], float] = {}
        remaining = sum(len(o) for o in orders)
        while remaining:
            progressed = False
            for s in range(P):
                phys = phys_of[s]
                while ptr[s] < len(orders[s]):
                    op, m = orders[s][ptr[s]]
                    if op == "f":
                        if s == 0:
                            ready = 0.0
                        else:
                            dep = done_f.get((s - 1, m))
                            if dep is None:
                                break
                            cont = 1.0 + (contention if m >= P else 0.0)
                            ready = dep + t_hop[z, phys_of[s - 1]] * cont
                        dur = c_f[z, s] + t_tpf[z, phys] + t_cpf[z, phys]
                    else:
                        if s == P - 1:
                            dep = done_f.get((s, m))
                        else:
                            dep = done_b.get((s + 1, m))
                        if dep is None:
                            break
                        ready = dep if s == P - 1 \
                            else dep + t_hop[z, phys] * (1 + contention)
                        dur = c_b[z, s] + 2 * t_tpf[z, phys] \
                            + 2 * t_cpf[z, phys]
                    if m == 0:
                        dur *= 1.03          # warmup transient
                    dur *= 1.0 + jitter * rng.standard_normal()
                    start = max(t_phys[phys], ready)
                    end = start + max(dur, 0.0)
                    if op == "f":
                        done_f[(s, m)] = end
                    else:
                        done_b[(s, m)] = end
                    t_phys[phys] = end
                    ptr[s] += 1
                    remaining -= 1
                    progressed = True
            if not progressed:
                raise RuntimeError("interleaved-1F1B schedule deadlock "
                                   "(invalid order)")
        finish_stage[z] = t_phys

    t_dp = dp_allreduce_times(conf, mapping, bw, prof, spec)
    stage_finish = finish_stage.max(axis=0)          # DP sync couples replicas
    total = float((stage_finish + t_dp).max())
    return {"total": total, "stage_finish": stage_finish, "t_dp": t_dp,
            "t_pp": t_hop}


def measure(conf: Conf, mapping: np.ndarray, w: Workload, spec: ClusterSpec,
            bw_true: np.ndarray, *, seed: int = 0,
            partition: Optional[Partition] = None) -> float:
    """'Run' one training iteration on the simulated cluster.

    Args:
        conf: parallelism configuration.
        mapping: ``(pp, tp, dp)`` or ``(pp, tp, cp, dp)`` worker -> GPU
            dedication.
        w: workload (profiled on the fly via :func:`build_profile`).
        spec: cluster description.
        bw_true: ground-truth bandwidth matrix.
        seed: simulator jitter seed.
        partition: optional non-uniform chunk partition, forwarded to
            :func:`build_profile`.

    Returns:
        Measured seconds for the iteration.
    """
    prof = build_profile(w, spec, conf, partition=partition)
    return simulate_iteration(conf, mapping, bw_true, prof, spec,
                              seed=seed)["total"]
