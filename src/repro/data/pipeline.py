"""Deterministic synthetic tokenized data pipeline.

Design goals of a production loader, scaled to this container:
  * stateless addressing — ``batch_at(step)`` is a pure function of
    (seed, step, topology), so resume-after-failure is exact without
    loader checkpoints and every DP rank can compute its own shard;
  * learnable structure — an order-2 noisy Markov stream so integration
    tests can assert loss decreases;
  * background prefetch with a bounded queue.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


def _mix(*xs: int) -> np.random.Generator:
    seed = 0x9E3779B97F4A7C15
    for x in xs:
        seed = (seed ^ (x + 0x9E3779B9)) * 0xBF58476D1CE4E5B9 % (1 << 63)
    return np.random.default_rng(seed)


@dataclass(frozen=True)
class SyntheticCorpus:
    vocab_size: int
    seed: int = 0
    noise: float = 0.05

    def sequence(self, idx: int, length: int) -> np.ndarray:
        """Deterministic order-2 Markov sequence #idx."""
        rng = _mix(self.seed, idx)
        v = self.vocab_size
        a = int(rng.integers(1, v))
        c = int(rng.integers(0, v))
        toks = np.empty(length + 1, np.int64)
        toks[0] = rng.integers(0, v)
        toks[1] = rng.integers(0, v)
        for t in range(2, length + 1):
            nxt = (a * toks[t - 1] + 3 * toks[t - 2] + c) % v
            if rng.random() < self.noise:
                nxt = rng.integers(0, v)
            toks[t] = nxt
        return toks.astype(np.int32)


@dataclass(frozen=True)
class LoaderConfig:
    global_batch: int
    seq_len: int
    dp_rank: int = 0
    dp_size: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.dp_size == 0
        return self.global_batch // self.dp_size


class DataLoader:
    """Sharded, deterministic, prefetching loader over SyntheticCorpus."""

    def __init__(self, corpus: SyntheticCorpus, cfg: LoaderConfig,
                 prefetch: int = 2):
        self.corpus = corpus
        self.cfg = cfg
        self.prefetch = prefetch

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        base = step * c.global_batch + c.dp_rank * c.local_batch
        seqs = np.stack([self.corpus.sequence(base + i, c.seq_len)
                         for i in range(c.local_batch)])
        return {"tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32)}

    def iterate(self, start_step: int = 0,
                stop_step: Optional[int] = None) -> Iterator[Dict[str, np.ndarray]]:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def producer():
            s = start_step
            while not stop.is_set() and (stop_step is None or s < stop_step):
                q.put((s, self.batch_at(s)))
                s += 1
            q.put(None)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                item = q.get()
                if item is None:
                    return
                yield item[1]
        finally:
            stop.set()
