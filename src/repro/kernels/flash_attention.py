"""Blocked flash attention (fwd) as a Pallas TPU kernel.

TPU adaptation (DESIGN.md §2): the online-softmax tiles live in VMEM via
explicit BlockSpecs; the MXU sees (block_q x head_dim) @ (head_dim x
block_k) matmuls with hardware-aligned 128-multiples; the KV-block axis is
the innermost (sequential) grid dimension so the running (m, l, acc) state
stays resident in VMEM scratch between iterations.  GQA is handled in the
BlockSpec index maps (query head h reads KV head h // group) — no repeated
KV materialisation in HBM.

Supports causal masking and sliding windows (gemma3 local layers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, n_k: int):
    i = pl.program_id(2)            # q block
    j = pl.program_id(3)            # kv block (sequential, innermost)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (bq, bk)
    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = jnp.full((block_q, block_k), True)
    if causal:
        ok &= q_pos >= k_pos
    if window > 0:
        ok &= q_pos - k_pos < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(j == n_k - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        out = acc_scr[...] / l[:, None]
        # rows with every key masked -> 0, not the mean of V
        out = jnp.where(m_scr[...][:, None] <= NEG_INF * 0.5, 0.0, out)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, H, Sq, D); k, v: (B, KV, Sk, D) -> (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    _, kv, sk, _ = k.shape
    g = h // kv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    n_q, n_k = sq // block_q, sk // block_k
    grid = (b, h, n_q, n_k)

    kernel = functools.partial(
        _kernel, scale=1.0 / (d ** 0.5), causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            _scratch((block_q,)),
            _scratch((block_q,)),
            _scratch((block_q, d)),
        ],
        interpret=interpret,
    )(q, k, v)


def _scratch(shape):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, jnp.float32)
    except Exception:                                    # pragma: no cover
        return pl.MemorySpace.ANY(shape, jnp.float32)
