"""Group-reduce kernels for the JAX dedication scorer.

The vmapped SA core spends its inner step reducing many small gathered
bandwidth sub-matrices — per communicator group, the min link bandwidth
turned into a slowdown scale (TP / CP groups), and per pipeline stage the
max member compute slowdown.  Both reductions are fused here as Pallas
kernels: one VMEM-resident ``(block, m, m)`` (or ``(block, m)``) tile per
grid step, reduced and rescaled without materialising the masked
intermediates the pure-jnp path creates.

Each kernel has a pure-jnp reference (``*_ref``) computing the identical
values with the identical elementwise ops — min and max are
order-insensitive and the divide is elementwise, so the Pallas output is
bit-equal to the reference on every backend (pinned by
``tests/test_jax_engine.py``).  On CPU the kernels run under
``interpret=True``; native TPU lowering would want f32 inputs and
(8, 128)-aligned tiles, which the tiny group sizes here do not provide —
the scorer therefore defaults to the reference path off-TPU (see
``repro.core.jax_engine``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# per-group min-bandwidth -> slowdown scale
# ---------------------------------------------------------------------------

def group_min_scale_ref(sub: jax.Array, ref_bw) -> jax.Array:
    """Per-group slowdown scales from gathered bandwidth sub-matrices.

    Args:
        sub: ``(n_groups, m, m)`` pairwise link bandwidths of each
            communicator group (self links pre-masked to ``inf``).
        ref_bw: scalar bandwidth the profiled time was measured at.

    Returns:
        ``(n_groups,)`` scales: ``ref_bw / min(sub)`` where the group min
        is finite and positive, else 1.0 (the degenerate-link guard of
        ``latency._tp_scale``).
    """
    gbw = sub.min(axis=(1, 2))
    ok = jnp.isfinite(gbw) & (gbw > 0)
    return jnp.where(ok, ref_bw / gbw, 1.0)


def _min_scale_kernel(sub_ref, refbw_ref, o_ref):
    sub = sub_ref[...]
    gbw = sub.min(axis=(1, 2))
    ok = jnp.isfinite(gbw) & (gbw > 0)
    o_ref[...] = jnp.where(ok, refbw_ref[0] / gbw, 1.0)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def group_min_scale(sub: jax.Array, ref_bw, *, block: int = 128,
                    interpret: bool = False) -> jax.Array:
    """Pallas version of :func:`group_min_scale_ref` (bit-equal output)."""
    n, m, _ = sub.shape
    b = min(block, n)
    pad = (-n) % b
    if pad:
        # padded groups reduce to an all-inf min -> masked to scale 1.0,
        # then sliced away
        sub = jnp.pad(sub, ((0, pad), (0, 0), (0, 0)),
                      constant_values=jnp.inf)
    refbw = jnp.full((1,), ref_bw, dtype=sub.dtype)
    out = pl.pallas_call(
        _min_scale_kernel,
        grid=((n + pad) // b,),
        in_specs=[pl.BlockSpec((b, m, m), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), sub.dtype),
        interpret=interpret,
    )(sub, refbw)
    return out[:n]


# ---------------------------------------------------------------------------
# per-stage max member slowdown
# ---------------------------------------------------------------------------

def group_max_ref(vals: jax.Array) -> jax.Array:
    """Row-wise max: ``(n_rows, m) -> (n_rows,)`` (per-stage compute
    slowdown reduce of the tiered-cluster path)."""
    return vals.max(axis=1)


def _max_kernel(v_ref, o_ref):
    o_ref[...] = v_ref[...].max(axis=1)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def group_max(vals: jax.Array, *, block: int = 128,
              interpret: bool = False) -> jax.Array:
    """Pallas version of :func:`group_max_ref` (bit-equal output)."""
    n, m = vals.shape
    b = min(block, n)
    pad = (-n) % b
    if pad:
        vals = jnp.pad(vals, ((0, pad), (0, 0)),
                       constant_values=-jnp.inf)
    out = pl.pallas_call(
        _max_kernel,
        grid=((n + pad) // b,),
        in_specs=[pl.BlockSpec((b, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), vals.dtype),
        interpret=interpret,
    )(vals)
    return out[:n]
