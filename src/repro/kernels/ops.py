"""Public jit'd wrappers that dispatch between the Pallas kernels (TPU
target) and the pure-jnp references.

On the TPU backend the Pallas path compiles natively; on CPU the kernels
run under ``interpret=True`` (bit-accurate but slow) or fall back to the
reference, so the same model code lowers everywhere.  The multi-pod
dry-run always lowers the reference path — Pallas cannot lower to the CPU
backend and kernel-side FLOPs/bytes are identical for roofline purposes
(see DESIGN.md §5).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _fa
from .rmsnorm import rmsnorm as _rms
from .selective_scan import selective_scan as _scan


def _mode() -> str:
    """'pallas' | 'interpret' | 'ref'."""
    env = os.environ.get("REPRO_KERNELS", "auto")
    if env in ("pallas", "interpret", "ref"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              block_q: int = 128, block_k: int = 128):
    """q: (B, H, Sq, D); k, v: (B, KV, Sk, D)."""
    m = _mode()
    if m == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    return _fa(q, k, v, causal=causal, window=window, block_q=block_q,
               block_k=block_k, interpret=(m == "interpret"))


def rmsnorm(x, w, *, eps: float = 1e-5):
    m = _mode()
    if m == "ref":
        return ref.rmsnorm_ref(x, w, eps=eps)
    return _rms(x, w, eps=eps, interpret=(m == "interpret"))


def selective_scan(x, dt, b, c, a, *, chunk: int = 64, block_d: int = 256):
    m = _mode()
    if m == "ref":
        return ref.selective_scan_ref(x, dt, b, c, a)
    return _scan(x, dt, b, c, a, chunk=chunk, block_d=block_d,
                 interpret=(m == "interpret"))
