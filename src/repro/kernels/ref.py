"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    """q: (B, H, Sq, D); k, v: (B, KV, Sk, D).  O(S^2) softmax attention."""
    b, h, sq, d = q.shape
    _, kv, sk, _ = k.shape
    g = h // kv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32).reshape(b, kv, g, sq, d) * scale
    s = jnp.einsum("bkgqd,bkcd->bkgqc", qf, k.astype(jnp.float32))
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    ok = jnp.full((sq, sk), True)
    if causal:
        ok &= q_pos >= k_pos
    if window > 0:
        ok &= q_pos - k_pos < window
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bkcd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)


def rmsnorm_ref(x, w, *, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def selective_scan_ref(x, dt, B, C, A):
    """Time-major naive recurrence.  x, dt: (b, S, D); B, C: (b, S, N);
    A: (D, N).  Returns (y (b,S,D) fp32, h_final (b,D,N) fp32)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp
        a = jnp.exp(dtt[..., None] * Af)                # (b, D, N)
        h = a * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    b, s, d = x.shape
    h0 = jnp.zeros((b, d, A.shape[-1]), jnp.float32)
    hf, y = jax.lax.scan(step, h0, (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
                                    Bf.swapaxes(0, 1), Cf.swapaxes(0, 1)))
    return y.swapaxes(0, 1), hf
