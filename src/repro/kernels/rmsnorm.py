"""Fused RMSNorm as a Pallas TPU kernel.

One VMEM-resident (block_rows, d) tile per grid step; the mean-square
reduction and the scale multiply fuse into a single HBM round-trip (the
unfused jnp version reads x twice and materialises the normalised
intermediate).  Reduction in fp32 regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., d); w: (d,)."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    while rows % br:
        br -= 1
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(shape)
