"""Mamba1 selective scan as a Pallas TPU kernel.

TPU adaptation (DESIGN.md §2): instead of the CUDA kernel's warp-level
parallel scan, the state tile h (block_d, N) stays resident in VMEM across
the sequential chunk grid dimension; within a chunk the recurrence runs
time-step-by-time-step but fully vectorised over (channels x state) — the
layout the VPU wants (channel rows x 128-wide state lanes).  HBM traffic is
one read of (x, dt, B, C) and one write of y per token: the per-timestep
hidden state trajectory (b, S, D, N) — the term that makes naive
implementations memory-bound — never leaves VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, o_ref, hout_ref, h_scr, *,
            chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)                    # (bd, N)
    x = x_ref[0].astype(jnp.float32)                      # (chunk, bd)
    dt = dt_ref[0].astype(jnp.float32)                    # (chunk, bd)
    bmat = b_ref[0].astype(jnp.float32)                   # (chunk, N)
    cmat = c_ref[0].astype(jnp.float32)                   # (chunk, N)

    def step(t, h):
        at = jnp.exp(dt[t][:, None] * a)                  # (bd, N)
        h = at * h + (dt[t] * x[t])[:, None] * bmat[t][None, :]
        o_ref[0, t, :] = (h * cmat[t][None, :]).sum(axis=-1).astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ci == n_chunks - 1)
    def _flush():
        hout_ref[0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def selective_scan(x, dt, b, c, a, *, chunk: int = 64, block_d: int = 256,
                   interpret: bool = False):
    """x, dt: (B, S, D); b, c: (B, S, N); a: (D, N).

    Returns (y (B, S, D) fp32, h_final (B, D, N) fp32)."""
    bs, s, d = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    bd = min(block_d, d)
    while d % bd:
        bd -= 1
    n_chunks = s // chunk
    grid = (bs, d // bd, n_chunks)

    kern = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    y, h_fin = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b_, di, ci: (b_, ci, di)),
            pl.BlockSpec((1, chunk, bd), lambda b_, di, ci: (b_, ci, di)),
            pl.BlockSpec((1, chunk, n), lambda b_, di, ci: (b_, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, di, ci: (b_, ci, 0)),
            pl.BlockSpec((bd, n), lambda b_, di, ci: (di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b_, di, ci: (b_, ci, di)),
            pl.BlockSpec((1, bd, n), lambda b_, di, ci: (b_, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bs, s, d), jnp.float32),
            jax.ShapeDtypeStruct((bs, d, n), jnp.float32),
        ],
        scratch_shapes=[_scratch((bd, n))],
        interpret=interpret,
    )(x, dt, b, c, a)
    return y, h_fin


def _scratch(shape):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, jnp.float32)
    except Exception:                                     # pragma: no cover
        return pl.MemorySpace.ANY(shape, jnp.float32)
