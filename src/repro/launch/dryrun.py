import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) cell this lowers and
compiles the real train/prefill/serve step against ShapeDtypeStruct
stand-ins on the production mesh (16x16 single-pod, 2x16x16 multi-pod),
prints ``compiled.memory_analysis()`` / ``cost_analysis()`` and records
the roofline terms (structured HLO walk, launch/hlo_cost.py) to a JSON
artifact under --out.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both
"""

V5E_PEAK_FLOPS = 197e12          # bf16 / chip
V5E_HBM_BW = 819e9               # bytes/s / chip
V5E_ICI_BW = 50e9                # bytes/s / link

# §Perf hillclimb variants (EXPERIMENTS.md); baseline = no variant.
PERF_VARIANTS = {
    # MoE combine via fp32-accumulating einsum instead of materialising an
    # fp32 (T*k, d) tensor (kills fp32 cotangents through the MoE too)
    "moe-bf16": {"cfg": {"moe_combine_f32_materialize": False}},
    # Megatron-style sequence parallelism for the residual stream: saved
    # layer-boundary activations shard over the model axis
    "seqpar": {"cfg": {"seq_shard_residuals": True}},
    # mamba selective-scan working dtype bf16 (state carry stays fp32)
    "scan-bf16": {"cfg": {"scan_dtype": "bfloat16"}},
    # ZeRO-1: params replicated over data (no per-layer FSDP gathers);
    # optimizer moments sharded over the data axis instead
    "zero1": {"fsdp": False, "zero1": True},
    "seqpar-zero1": {"cfg": {"seq_shard_residuals": True},
                     "fsdp": False, "zero1": True},
    "moe-bf16-seqpar": {"cfg": {"moe_combine_f32_materialize": False,
                                "seq_shard_residuals": True}},
    # index-buffer MoE dispatch: no k-times activation repeat in HBM
    "moe-gather": {"cfg": {"moe_gather_dispatch": True}},
    "moe-gather-bf16": {"cfg": {"moe_gather_dispatch": True,
                                "moe_combine_f32_materialize": False}},
    # no activation recomputation: saves the remat fwd pass (collectives,
    # flops) at the cost of saved-activation capacity
    "noremat": {"cfg": {"remat": False}},
    # re-configure parallelism on the SAME mesh (the paper's own lever):
    # pipeline parallelism over the 'model' axis, tp=1, dp over 'data'
    "pp16": {"pp": True},
}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             n_micro: int, fsdp: bool, variant: str = "",
             tag: str = "") -> dict:
    import jax

    from .. import configs
    from ..models.config import SHAPES
    from ..models.sharding import ShardCtx
    from ..optim.adamw import AdamW
    from . import hlo_cost, specs as SP
    from .mesh import make_production_mesh
    from .steps import make_decode_step, make_prefill_step, make_train_step
    from ..core import flops as F

    cfg = configs.get(arch)
    var = PERF_VARIANTS.get(variant, {})
    if var.get("cfg"):
        cfg = cfg.replace(**var["cfg"])
    if "fsdp" in var:
        fsdp = var["fsdp"]
    zero1 = bool(var.get("zero1"))
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "n_micro": n_micro, "fsdp": fsdp, "tag": tag,
              "variant": variant}

    if shape_name == "long_500k" and not cfg.is_subquadratic:
        result["skipped"] = ("pure full-attention arch: 500k dense KV cache "
                             "excluded per assignment spec")
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    dp = ("pod", "data") if multi_pod else ("data",)
    # FSDP weight sharding only makes sense when training (serving would
    # re-gather weights every layer)
    use_fsdp = fsdp and shape.kind == "train"
    ctx = ShardCtx(mesh=mesh, dp=dp, tp="model",
                   fsdp=("data",) if use_fsdp else ())

    t0 = time.perf_counter()
    with jax.set_mesh(mesh):
        if var.get("pp") and shape.kind == "train":
            from .pp_step import make_pp_train_step
            opt = AdamW(lr=1e-4)
            step, p, o, b = make_pp_train_step(cfg, mesh, opt,
                                               pipe_axis="model",
                                               data_axis="data", n_mb=16)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(p, o, b)
            tokens = shape.global_batch * shape.seq_len
            result["model_flops"] = F.model_flops(cfg, tokens, train=True)
            result["attn_flops"] = F.attention_flops(cfg, shape.seq_len,
                                                     tokens, train=True)
        elif shape.kind == "train":
            opt = AdamW(lr=1e-4)
            step = make_train_step(cfg, ctx, opt, n_micro=n_micro)
            p = SP.params_spec(cfg, ctx)
            o = SP.opt_spec(cfg, ctx, opt, zero1=zero1)
            b = SP.batch_spec(cfg, shape, ctx)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(p, o, b)
            tokens = shape.global_batch * shape.seq_len
            result["model_flops"] = F.model_flops(cfg, tokens, train=True)
            result["attn_flops"] = F.attention_flops(cfg, shape.seq_len,
                                                     tokens, train=True)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, ctx)
            p = SP.params_spec(cfg, ctx)
            b = SP.batch_spec(cfg, shape, ctx)
            lowered = jax.jit(step).lower(p, b)
            tokens = shape.global_batch * shape.seq_len
            result["model_flops"] = F.model_flops(cfg, tokens, train=False)
            result["attn_flops"] = F.attention_flops(cfg, shape.seq_len,
                                                     tokens, train=False)
        else:
            step = make_decode_step(cfg, ctx)
            p = SP.params_spec(cfg, ctx)
            token, cache, pos = SP.decode_inputs(cfg, shape, ctx)
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                p, cache, token, pos)
            tokens = shape.global_batch
            result["model_flops"] = F.model_flops(cfg, tokens, train=False)
            result["attn_flops"] = F.attention_flops(cfg, shape.seq_len,
                                                     tokens, train=False)
        t_lower = time.perf_counter() - t0

        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

        mem = compiled.memory_analysis()
        print(f"[{arch}|{shape_name}|{mesh_name}] memory_analysis:", mem)
        cost = compiled.cost_analysis() or {}
        print(f"[{arch}|{shape_name}|{mesh_name}] cost_analysis flops:",
              cost.get("flops"), "bytes:", cost.get("bytes accessed"))

        t0 = time.perf_counter()
        text = compiled.as_text()
        costs = hlo_cost.analyze(text)
        t_parse = time.perf_counter() - t0
        # persist the (compressed) HLO so cost-model improvements can
        # re-analyze without recompiling
        try:
            import zstandard as zstd
            hlo_path = out_dir / (f"{arch}__{shape_name}__{mesh_name}"
                                  + (f"-{tag}" if tag else "") + ".hlo.zst")
            hlo_path.write_bytes(zstd.ZstdCompressor(level=6).compress(
                text.encode()))
        except Exception:
            pass

    per_dev_flops = costs.flops
    per_dev_bytes = costs.bytes
    per_dev_coll = costs.total_collective
    result.update({
        "n_devices": int(n_dev),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "parse_s": round(t_parse, 2),
        "hlo_bytes_len": len(text),
        "xla_cost_flops_per_dev": float(cost.get("flops", 0.0) or 0.0),
        "xla_cost_bytes_per_dev": float(cost.get("bytes accessed", 0.0) or 0.0),
        "flops_per_dev": per_dev_flops,
        "hbm_bytes_per_dev": per_dev_bytes,
        "collective_bytes_per_dev": per_dev_coll,
        "collective_bytes_native": costs.collective_bytes_native,
        "t_collective_native": costs.collective_bytes_native / V5E_ICI_BW,
        "collectives": {k: v for k, v in costs.collective_bytes.items()},
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        # roofline terms (seconds)
        "t_compute": per_dev_flops / V5E_PEAK_FLOPS,
        "t_memory": per_dev_bytes / V5E_HBM_BW,
        "t_collective": per_dev_coll / V5E_ICI_BW,
    })
    terms = {"compute": result["t_compute"], "memory": result["t_memory"],
             "collective": result["t_collective"]}
    result["bottleneck"] = max(terms, key=terms.get)
    hlo_total = per_dev_flops * n_dev
    result["useful_flops_ratio"] = (result["model_flops"] / hlo_total
                                    if hlo_total else 0.0)
    bytes_per_dev = mem.argument_size_in_bytes + mem.temp_size_in_bytes
    result["bytes_per_device"] = bytes_per_dev
    result["fits_v5e_16g"] = bool(bytes_per_dev <= 16 * 2 ** 30)
    return result


def cell_path(out_dir: Path, arch: str, shape: str, mesh: str, tag: str = "") -> Path:
    suffix = f"-{tag}" if tag else ""
    return out_dir / f"{arch}__{shape}__{mesh}{suffix}.json"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--n-micro", type=int, default=4)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--tag", default="", help="artifact suffix for perf variants")
    ap.add_argument("--variant", default="", choices=[""] + list(PERF_VARIANTS),
                    help="named §Perf variant (see PERF_VARIANTS)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args(argv)

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        from .. import configs
        from ..models.config import SHAPES
        meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
        cells = [(a, s, m) for a in configs.ARCHS for s in SHAPES
                 for m in meshes]
        failures = []
        for arch, shape, mesh in cells:
            path = cell_path(out_dir, arch, shape,
                             "2x16x16" if mesh == "multipod" else "16x16",
                             args.tag)
            if path.exists() and not args.force:
                print("skip (cached):", path.name)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh,
                   "--out", str(out_dir), "--n-micro", str(args.n_micro)]
            if args.no_fsdp:
                cmd.append("--no-fsdp")
            if args.tag:
                cmd += ["--tag", args.tag]
            print(">>>", " ".join(cmd[3:]))
            try:
                r = subprocess.run(cmd, timeout=args.timeout)
                if r.returncode != 0:
                    failures.append((arch, shape, mesh, r.returncode))
            except subprocess.TimeoutExpired:
                failures.append((arch, shape, mesh, "timeout"))
        print("failures:", failures if failures else "none")
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch and --shape required (or --all)"
    multi = args.mesh == "multipod"
    mesh_name = "2x16x16" if multi else "16x16"
    if args.variant and not args.tag:
        args.tag = args.variant
    res = run_cell(args.arch, args.shape, multi, out_dir, args.n_micro,
                   fsdp=not args.no_fsdp, variant=args.variant, tag=args.tag)
    path = cell_path(out_dir, args.arch, args.shape, mesh_name, args.tag)
    path.write_text(json.dumps(res, indent=2))
    print(json.dumps({k: v for k, v in res.items()
                      if k not in ("collectives", "memory")}, indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
