"""Batched generation driver: prefill a batch of prompts, then
greedy-decode with donated KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.generate --arch qwen2-7b --smoke \
        --batch 4 --prompt-len 64 --gen 32

(Previously ``repro.launch.serve``; renamed so "serve" unambiguously
means the plan server — ``python -m repro.service``.)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from .. import configs
    from ..models import model as M
    from ..models.frontends import vlm_patch_embeddings
    from ..models.sharding import ShardCtx
    from .steps import make_decode_step

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    ctx = ShardCtx()
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)

    total = args.prompt_len + args.gen
    # ring caches need prompt_len % window == 0; round up if needed
    plan_window = cfg.sliding_window if cfg.local_global_period else 0
    if plan_window and args.prompt_len % plan_window:
        args.prompt_len += plan_window - args.prompt_len % plan_window
        total = args.prompt_len + args.gen

    img = None
    s_text = args.prompt_len
    if cfg.frontend == "vlm":
        img = vlm_patch_embeddings(key, args.batch, cfg.n_img_tokens,
                                   cfg.d_model)
        s_text = max(args.prompt_len - cfg.n_img_tokens, 8)
    prompts = jax.random.randint(key, (args.batch, s_text), 0,
                                 cfg.vocab_size, jnp.int32)

    t0 = time.perf_counter()
    last_logits, cache = jax.jit(
        lambda p, t, i: M.prefill(p, cfg, ctx, t, i),
        static_argnums=())(params, prompts, img)
    # grow caches to hold the generated tokens
    def grow(c):
        out = {}
        for k, v in c.items():
            if k in ("k", "v"):
                pad = [(0, 0)] * v.ndim
                pad[2] = (0, args.gen)
                out[k] = jnp.pad(v, pad)
            else:
                out[k] = v
        return out
    cache = grow(cache)
    t_prefill = time.perf_counter() - t0

    step = jax.jit(make_decode_step(cfg, ctx), donate_argnums=(1,))
    tok = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]
    toks = [tok]
    t0 = time.perf_counter()
    pos0 = args.prompt_len if cfg.frontend != "vlm" else s_text + cfg.n_img_tokens
    for i in range(args.gen - 1):
        tok, logits, cache = step(params, cache, tok, jnp.int32(pos0 + i))
        toks.append(tok)
    gen = jnp.concatenate(toks, axis=1)
    gen.block_until_ready()
    t_decode = time.perf_counter() - t0
    print(f"[generate] {cfg.name}: prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill:.2f}s; decoded {args.gen-1} steps in {t_decode:.2f}s "
          f"({t_decode/max(args.gen-1,1)*1e3:.0f} ms/tok)")
    print("[generate] sample:", np.asarray(gen[0, :16]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
