"""Structured cost model over compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` visits each while-loop body ONCE, so scanned
programs (layers scan, microbatch accumulation) under-report FLOPs/bytes by
the trip count.  This module parses the HLO text, walks the computation
graph, multiplies loop bodies by their trip counts (recovered from the loop
condition's comparison constant), and accounts:

  * flops   — dot / convolution ops (2 * prod(out) * K),
  * bytes   — operand + output bytes of every non-trivial op (fusions count
              their boundary traffic only: exactly the HBM model),
  * collectives — per-kind operand bytes of all-reduce / all-gather /
              reduce-scatter / all-to-all / collective-permute, with ring
              traffic multipliers.

All numbers are PER DEVICE (the compiled module is the per-partition SPMD
program); callers scale by device count where totals are needed.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$|^(?:ENTRY\s+)?%?([\w.\-]+)\s+\{")
_CALL_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "copy", "after-all", "partition-id", "replica-id", "iota",
         "custom-call"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(type_str: str) -> Tuple[Optional[str], List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclass
class Op:
    name: str
    opcode: str
    out_type: str
    operands_str: str
    attrs: str


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    # bytes assuming native-dtype reductions: the CPU backend accumulates
    # bf16 dots in f32 and hoists the convert past the all-reduce, doubling
    # matmul-psum bytes vs a TPU lowering; this counts those at bf16.
    collective_bytes_native: float = 0.0

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes_native += other.collective_bytes_native * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v * mult

    @property
    def total_collective(self) -> float:
        return sum(self.collective_bytes.values())  # repro: noqa DET004 -- fold order is the dict's insertion order, fixed by the HLO text; identical module -> identical fold


def _split_operands_attrs(rest: str) -> Tuple[str, str]:
    """rest starts right after the opcode's '('. Returns (operands, attrs)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def parse_hlo(text: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            s = line.strip()
            if s.endswith("{") and ("->" in s or s.startswith("ENTRY") or
                                    s.startswith("%") or "(" in s):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", s)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, out_type, opcode = m.groups()
        after = line[m.end():]
        operands, attrs = _split_operands_attrs(after)
        comps[cur].append(Op(name, opcode, out_type, operands, attrs))
    return comps


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _trip_count(comps: Dict[str, List[Op]], while_op: Op,
                cond_name: Optional[str]) -> int:
    """Trip count: prefer the scheduler's known_trip_count backend_config,
    else the condition computation's comparison constant."""
    m = _TRIP_RE.search(while_op.attrs)
    if m:
        return int(m.group(1))
    ops = comps.get(cond_name or "", [])
    consts = []
    for op in ops:
        if op.opcode == "constant":
            mm = re.match(r"^\s*(-?\d+)\s*$", op.operands_str)
            if mm:
                consts.append(int(mm.group(1)))
    pos = [v for v in consts if v > 0]
    return max(pos) if pos else 1


def _group_size(attrs: str, default: int = 0) -> int:
    m = _GROUPS_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    return default


_REF_RE = re.compile(r"%([\w.\-]+)")

# ops whose HBM traffic is slice-sized, not operand-sized (XLA executes
# them in place / as indexed access):
#   dynamic-slice : read slice only            -> 2 * out
#   gather        : read gathered rows only    -> 2 * out (+ indices)
#   dynamic-update-slice: rewrite slice region -> 2 * update operand
#   scatter       : touch update region only   -> 3 * update operand
_INDEXED = {"dynamic-slice", "gather", "dynamic-update-slice", "scatter"}


def _indexed_bytes(op_kind: str, out_bytes: int, operand_shapes: List[str]) -> int:
    if op_kind in ("dynamic-slice", "gather"):
        return 2 * out_bytes
    if op_kind == "dynamic-update-slice":
        upd = _shape_bytes(operand_shapes[1]) if len(operand_shapes) > 1 else out_bytes
        return 2 * upd
    if op_kind == "scatter":
        upd = _shape_bytes(operand_shapes[-1]) if operand_shapes else out_bytes
        return 3 * upd
    return 0


def _operand_shapes(op: Op, shapes: Dict[str, str]) -> List[str]:
    """Operand type strings via the per-computation name -> type map
    (the scheduled-HLO printer omits inline operand types)."""
    inline = _SHAPE_RE.findall(op.operands_str)
    if inline:
        return [f"{dt}[{dims}]" for dt, dims in inline]
    return [shapes[r] for r in _REF_RE.findall(op.operands_str) if r in shapes]


def _operand_bytes(op: Op, shapes: Dict[str, str]) -> int:
    return sum(_shape_bytes(s) for s in _operand_shapes(op, shapes))  # repro: noqa DET004 -- _shape_bytes returns int byte counts; integer sum is exact in any order


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    out_elems = 1
    for d in _first_shape(op.out_type)[1]:
        out_elems *= d
    ops_shapes = _operand_shapes(op, shapes)
    if not ops_shapes:
        return 0.0
    lhs_dims = _first_shape(ops_shapes[0])[1]
    cm = _CONTRACT_RE.search(op.attrs)
    k = 1
    if cm and cm.group(1):
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    return 2.0 * out_elems * k


_GROUPS_COUNT_RE = re.compile(r"feature_group_count=(\d+)")
_WINDOW_RE = re.compile(r"window=\{[^}]*size=([0-9x]+)")
_DIMLABELS_RE = re.compile(r"dim_labels=([\w?]+)_([\w?]+)->")


def _conv_flops(op: Op, shapes: Dict[str, str]) -> float:
    """HloCostAnalysis convention: 2 * out_elems * window_prod *
    (lhs_feature_dim / feature_group_count)."""
    out_elems = 1
    for d in _first_shape(op.out_type)[1]:
        out_elems *= d
    ops_shapes = _operand_shapes(op, shapes)
    if not ops_shapes:
        return 0.0
    win = 1
    m = _WINDOW_RE.search(op.attrs)
    if m:
        for w in m.group(1).split("x"):
            win *= int(w)
    lhs_dims = _first_shape(ops_shapes[0])[1]
    feat = 1
    dl = _DIMLABELS_RE.search(op.attrs)
    if dl and "f" in dl.group(1) and len(lhs_dims) == len(dl.group(1)):
        feat = lhs_dims[dl.group(1).index("f")]
    g = _GROUPS_COUNT_RE.search(op.attrs)
    groups = int(g.group(1)) if g else 1
    return 2.0 * out_elems * win * feat / max(groups, 1)


_RING_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}


def _cost_of(comps: Dict[str, List[Op]], name: str,
             memo: Dict[str, Costs]) -> Costs:
    if name in memo:
        return memo[name]
    memo[name] = Costs()          # break cycles defensively
    total = Costs()
    shapes = {op.name: op.out_type for op in comps.get(name, [])}
    for op in comps.get(name, []):
        base = op.opcode
        for c in COLLECTIVES:
            if op.opcode.startswith(c):
                base = c          # normalise -start/-done async forms
                break
        if base == "while":
            body = _CALL_RE.search(op.attrs)
            cond = _COND_RE.search(op.attrs)
            trips = _trip_count(comps, op, cond.group(1) if cond else None)
            if body:
                total.add(_cost_of(comps, body.group(1), memo), max(trips, 1))
            continue
        if base == "fusion":
            callee = _CALL_RE.search(op.attrs)
            cname = callee.group(1) if callee else ""
            if cname:
                inner = _cost_of(comps, cname, memo)
                total.flops += inner.flops
                for k, v in inner.collective_bytes.items():
                    total.collective_bytes[k] = total.collective_bytes.get(k, 0) + v
            total.bytes += _fusion_bytes(op, shapes, comps.get(cname, []))
            continue
        if base in ("call", "conditional", "async-start"):
            for callee in _CALL_RE.findall(op.attrs):
                total.add(_cost_of(comps, callee, memo))
            continue
        if base in COLLECTIVES:
            if op.opcode.endswith("-done"):
                continue          # counted at -start
            b = _operand_bytes(op, shapes) * _RING_MULT[base]
            total.collective_bytes[base] = total.collective_bytes.get(base, 0.0) + b
            native = b
            if "dot_general" in op.attrs and "f32[" in op.out_type:
                native = b / 2.0          # bf16 matmul psum upcast by CPU
            total.collective_bytes_native += native
            total.bytes += _operand_bytes(op, shapes) + _shape_bytes(op.out_type)
            continue
        if base in _SKIP:
            continue
        if base in _INDEXED:
            total.bytes += _indexed_bytes(base, _shape_bytes(op.out_type),
                                          _operand_shapes(op, shapes))
            continue
        if base == "dot":
            total.flops += _dot_flops(op, shapes)
        elif base == "convolution":
            total.flops += _conv_flops(op, shapes)
        total.bytes += _operand_bytes(op, shapes) + _shape_bytes(op.out_type)
    memo[name] = total
    return total


def _fusion_bytes(op: Op, shapes: Dict[str, str], callee_ops: List[Op]) -> int:
    """Boundary traffic of a fusion, with indexed access patterns counted
    slice-sized: a parameter consumed (only) by dynamic-slice/gather reads
    the slice; a DUS-rooted fusion whose output aliases the buffer writes
    the update region only."""
    operand_shapes = _operand_shapes(op, shapes)
    out_bytes = _shape_bytes(op.out_type)

    # params feeding indexed ops (through bitcast/copy/convert chains)
    param_order: Dict[str, int] = {}
    feeds: Dict[str, str] = {}
    for cop in callee_ops:
        if cop.opcode == "parameter":
            m = re.match(r"^\s*(\d+)\s*$", cop.operands_str)
            if m:
                param_order[cop.name] = int(m.group(1))
        elif cop.opcode in ("bitcast", "copy", "convert", "reshape"):
            refs = _REF_RE.findall(cop.operands_str)
            if refs:
                feeds[cop.name] = refs[0]

    def root_param(ref: str) -> Optional[str]:
        seen = 0
        while ref in feeds and seen < 10:
            ref = feeds[ref]
            seen += 1
        return ref if ref in param_order else None

    sliced_params: Dict[int, int] = {}       # param idx -> accessed bytes
    dus_update_bytes = 0
    has_dus = False
    cshapes = {c.name: c.out_type for c in callee_ops}
    for cop in callee_ops:
        if cop.opcode in ("dynamic-slice", "gather"):
            refs = _REF_RE.findall(cop.operands_str)
            if refs:
                p = root_param(refs[0])
                if p is not None:
                    idx = param_order[p]
                    sliced_params[idx] = sliced_params.get(idx, 0) + \
                        _shape_bytes(cop.out_type)
        elif cop.opcode == "dynamic-update-slice":
            has_dus = True
            refs = _REF_RE.findall(cop.operands_str)
            if len(refs) > 1:
                upd_shape = cshapes.get(refs[1], "")
                dus_update_bytes += _shape_bytes(upd_shape)
                p = root_param(refs[0])
                if p is not None:
                    sliced_params[param_order[p]] = dus_update_bytes

    total = 0
    for i, s in enumerate(operand_shapes):
        total += sliced_params.get(i, _shape_bytes(s)) if i in sliced_params \
            else _shape_bytes(s)
    if has_dus and dus_update_bytes and out_bytes >= dus_update_bytes:
        total += dus_update_bytes        # in-place write of the slice region
    else:
        total += out_bytes
    return total


def analyze(hlo_text: str, entry: Optional[str] = None) -> Costs:
    comps = parse_hlo(hlo_text)
    if not comps:
        return Costs()
    if entry is None:
        # entry computation is marked ENTRY in the text; find it
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.MULTILINE)
        entry = m.group(1) if m else next(iter(comps))
    # fusions/bodies are reachable from entry; memoised walk
    return _cost_of(comps, entry, {})
