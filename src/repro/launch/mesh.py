"""Production mesh construction (multi-pod dry-run spec) + Pipette-driven
device permutations.

``make_production_mesh`` is a FUNCTION so importing this module never
touches JAX device state.  ``mesh_from_mapping`` applies a Pipette worker
dedication (a device permutation) — the XLA device-assignment analogue of
the paper's logical-worker -> GPU mapping f.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False,
                         devices: Optional[Sequence] = None):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if devices is None:
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    dev = np.asarray(devices).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              permutation: Optional[np.ndarray] = None):
    """Arbitrary mesh with an optional Pipette device permutation."""
    devs = np.array(jax.devices())
    n = int(np.prod(shape))
    if len(devs) < n:
        raise ValueError(f"need {n} devices, have {len(devs)}")
    devs = devs[:n]
    if permutation is not None:
        devs = devs[np.asarray(permutation).reshape(-1)]
    return jax.sharding.Mesh(devs.reshape(tuple(shape)), tuple(axes))


def mesh_from_mapping(conf, mapping: np.ndarray, axes=None):
    """Pipette Map (pp, tp[, cp], dp) -> Mesh whose [x, y(, k), z] device
    is GPU f(...).  Physical adjacency in the cluster is preserved by the
    device order, so the mapping steers which links each axis uses.

    ``axes`` defaults to ``("pipe", "model", "data")`` for a 3D mapping and
    ``("pipe", "model", "context", "data")`` for a 4D one."""
    mapping = np.asarray(mapping)
    if axes is None:
        axes = ("pipe", "model", "context", "data") if mapping.ndim == 4 \
            else ("pipe", "model", "data")
    devs = np.array(jax.devices())[:conf.n_gpus]
    return jax.sharding.Mesh(devs[mapping], tuple(axes))


def mesh_from_plan(plan, axes=None):
    """Build the training Mesh a serialized configurator Plan prescribes —
    no re-search: ``Plan.load(path)`` then this is the whole launch path.

    Args:
        plan: a :class:`~repro.core.plan.Plan` (fresh from ``Planner.plan``
            or reloaded via ``Plan.load``).
        axes: optional axis names, forwarded to :func:`mesh_from_mapping`.

    Raises:
        ValueError: the plan is infeasible (its search found no runnable
            configuration, so there is nothing to build).
    """
    if plan.conf is None:
        raise ValueError(
            f"plan is infeasible (strategy {plan.provenance.strategy!r} "
            f"found no runnable configuration); nothing to build")
    return mesh_from_mapping(plan.conf, plan.mapping, axes=axes)
