"""Pipeline parallelism over a 'pipe' mesh axis via shard_map.

Microbatches rotate through the stages with ``lax.ppermute`` (the JAX
analogue of Megatron's P2P stage links — the communication pattern
Pipette's Eq. 5 prices per hop).  Compute follows the GPipe rotation and
relies on remat for the 1F1B memory profile; the arithmetic is identical
to the sequential model, which the tests assert exactly.  The Pipette
(pp, tp, dp) configuration maps onto a ('pipe', 'data', 'model') mesh
built from the SA worker dedication (launch/mesh.py::mesh_from_mapping).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def stage_params_split(layer_params, pp: int):
    """Stacked (L, ...) layer params -> (pp, L/pp, ...) stage-major."""
    def split(a):
        l = a.shape[0]
        assert l % pp == 0, f"n_layers {l} must divide pp {pp}"
        return a.reshape(pp, l // pp, *a.shape[1:])
    return jax.tree.map(split, layer_params)


def pipeline_loss_fn(embed_fn: Callable, stage_fn: Callable,
                     head_loss_fn: Callable, mesh: Mesh, *,
                     axis: str = "pipe", remat: bool = True,
                     data_axis: str = ""):
    """Builds loss(params, tokens_mb, labels_mb) running pipeline-parallel.

    params = {"stages": (pp, L/pp, ...) sharded over axis,
              "shared": replicated embed/head/etc}
    tokens_mb, labels_mb: (n_mb, mb, S); with ``data_axis`` set, the mb dim
    is data-parallel-sharded over that axis and the loss is pmean'd.
    """
    pp = mesh.shape[axis]

    def local_fn(stages_local, shared, tokens_mb, labels_mb):
        idx = jax.lax.axis_index(axis)
        n_mb = tokens_mb.shape[0]
        stages_local = jax.tree.map(lambda a: a[0], stages_local)
        sfn = jax.checkpoint(stage_fn) if remat else stage_fn
        ticks = n_mb + pp - 1
        perm = [(i, (i + 1) % pp) for i in range(pp)]

        def tick(carry, t):
            state, loss_sum = carry
            mb_in = jnp.clip(t, 0, n_mb - 1)
            mb_out = t - (pp - 1)
            # only stage 0 embeds; only the last stage pays the head/loss
            # (lax.cond on the stage index — per-device branching inside
            # shard_map keeps the 15/16 other ranks idle on these)
            x0 = jax.lax.cond(
                idx == 0,
                lambda: embed_fn(shared, tokens_mb[mb_in]).astype(state.dtype),
                lambda: state)
            inp = jnp.where(idx == 0, x0, state)
            out = sfn(stages_local, inp)
            lbl = labels_mb[jnp.clip(mb_out, 0, n_mb - 1)]
            valid = (idx == pp - 1) & (mb_out >= 0) & (mb_out < n_mb)
            mb_loss = jax.lax.cond(
                valid,
                lambda: head_loss_fn(shared, out, lbl),
                lambda: jnp.zeros((), jnp.float32))
            loss_sum = loss_sum + mb_loss
            state = jax.lax.ppermute(out, axis, perm)
            return (state, loss_sum), None

        dummy = embed_fn(shared, tokens_mb[0])
        (state, loss_sum), _ = jax.lax.scan(
            tick, (jnp.zeros_like(dummy), jnp.zeros((), jnp.float32)),
            jnp.arange(ticks))
        total = jax.lax.psum(loss_sum, axis)       # only last stage nonzero
        if data_axis:
            total = jax.lax.pmean(total, data_axis)
        return total / n_mb

    batch_spec = P(None, data_axis, None) if data_axis else P()
    wrapped = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(axis), P(), batch_spec, batch_spec),
        out_specs=P(),
        check_vma=False)

    def loss(params, tokens_mb, labels_mb):
        return wrapped(params["stages"], params["shared"], tokens_mb,
                       labels_mb)

    return loss
