"""Pipeline-parallel train step on the production mesh (§Perf).

The paper's configurator picks (pp, tp, dp) — this module realises the
pp-heavy configuration on the SAME fixed production mesh by treating the
'model' axis as the pipeline axis: pp=16 (model) x dp=16 (data), tp=1.
Weights are FSDP-sharded over 'data'; microbatches rotate through stages
with collective_permute (launch/pipeline.py).  For collective-bound TP
cells (command-r-plus train_4k: 3.5 TB/dev of TP all-reduces) this trades
them for stage-boundary P2P + FSDP gathers — the napkin math says ~30x
fewer collective bytes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from ..models.layers import rms_norm, swiglu
from ..models.transformer import _proj_qkv, init_params
from ..models.attention import chunked_attention
from ..optim.adamw import AdamW
from .pipeline import pipeline_loss_fn


def _dense_layer(lp, x, cfg: ModelConfig):
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = _proj_qkv(h, lp, cfg, positions, cfg.rope_theta)
    o = chunked_attention(q, k, v, causal=True)
    x = x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + swiglu(h, lp["gate"], lp["up"], lp["down"])


def make_pp_train_step(cfg: ModelConfig, mesh, opt: AdamW, *,
                       pipe_axis: str = "model", data_axis: str = "data",
                       n_mb: int = 16, remat: bool = True):
    """Returns (train_step, params_sds, opt_sds, batch_sds) for lowering."""
    pp = mesh.shape[pipe_axis]
    assert cfg.n_layers % pp == 0 or True

    def embed_fn(shared, toks):
        return shared["tok_embed"][toks]

    def stage_fn(stage, x):
        def body(c, lp):
            return _dense_layer(lp, c, cfg), None
        x, _ = jax.lax.scan(body, x, stage)
        return x

    def head_loss_fn(shared, hfin, labels):
        hfin = rms_norm(hfin, shared["final_norm"], cfg.norm_eps)
        logits = (hfin.astype(jnp.bfloat16) @ shared["lm_head"]).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(jnp.maximum(labels, 0), cfg.padded_vocab,
                                dtype=jnp.float32)
        picked = jnp.einsum("bsv,bsv->bs", logits, onehot)
        return jnp.mean(lse - picked)

    loss_fn = pipeline_loss_fn(embed_fn, stage_fn, head_loss_fn, mesh,
                               axis=pipe_axis, remat=remat,
                               data_axis=data_axis)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(
            params, batch["tokens_mb"], batch["labels_mb"])
        # bf16 grads to the (ZeRO-sharded, fp32) optimizer
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    # ---- spec construction ------------------------------------------
    full = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    drop = {k: v for k, v in full["layers"].items()}
    stages_sds = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct((pp, a.shape[0] // pp) + a.shape[1:],
                                       a.dtype), drop)
    shared_sds = {"tok_embed": full["tok_embed"],
                  "final_norm": full["final_norm"],
                  "lm_head": full["lm_head"]}

    def stage_shard(s):
        # dim0 = pipe; params stay data-replicated inside the pipeline
        parts = [pipe_axis] + [None] * (len(s.shape) - 1)
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, P(*parts)))

    def shared_shard(s):
        parts = [None] * len(s.shape)
        nd = mesh.shape[data_axis]
        cands = [i for i in range(len(s.shape)) if s.shape[i] % nd == 0]
        if cands:
            parts[max(cands, key=lambda i: s.shape[i])] = data_axis
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, P(*parts)))

    params_sds = {"stages": jax.tree.map(stage_shard, stages_sds),
                  "shared": jax.tree.map(shared_shard, shared_sds)}
    opt_sds = jax.eval_shape(opt.init, params_sds)

    def z1_shard(s, psh):
        # ZeRO-1: fp32 moments shard over the data axis too
        parts = list(psh.spec) + [None] * (len(s.shape) - len(psh.spec))
        used = {a for ax in parts if ax is not None
                for a in ((ax,) if isinstance(ax, str) else ax)}
        nd = mesh.shape[data_axis]
        if data_axis not in used:
            cands = [i for i, ax in enumerate(parts) if ax is None
                     and s.shape[i] % nd == 0]
            if cands:
                parts[max(cands, key=lambda i: s.shape[i])] = data_axis
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, P(*parts)))

    pshard = jax.tree.map(lambda s: s.sharding, params_sds)
    rep = NamedSharding(mesh, P())
    opt_sds = type(opt_sds)(
        step=jax.ShapeDtypeStruct((), jnp.int32, sharding=rep),
        m=jax.tree.map(z1_shard, opt_sds.m, pshard),
        v=jax.tree.map(z1_shard, opt_sds.v, pshard))

    gb, seq = 256, 4096
    mb = gb // n_mb
    bs = NamedSharding(mesh, P(None, data_axis, None))
    batch_sds = {
        "tokens_mb": jax.ShapeDtypeStruct((n_mb, mb, seq), jnp.int32,
                                          sharding=bs),
        "labels_mb": jax.ShapeDtypeStruct((n_mb, mb, seq), jnp.int32,
                                          sharding=bs),
    }
    return train_step, params_sds, opt_sds, batch_sds
