"""Re-run the HLO cost model over saved .hlo.zst artifacts and refresh the
JSON roofline terms — no recompilation.

    PYTHONPATH=src python -m repro.launch.reanalyze [--out artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import zstandard as zstd

from . import hlo_cost
from .dryrun import V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_FLOPS


def reanalyze(out_dir: Path) -> int:
    n = 0
    for jpath in sorted(out_dir.glob("*.json")):
        hpath = jpath.with_suffix("").with_suffix("")  # strip .json
        hpath = jpath.parent / (jpath.stem + ".hlo.zst")
        if not hpath.exists():
            continue
        d = json.loads(jpath.read_text())
        if "skipped" in d:
            continue
        text = zstd.ZstdDecompressor().decompress(hpath.read_bytes()).decode()
        costs = hlo_cost.analyze(text)
        d["flops_per_dev"] = costs.flops
        d["hbm_bytes_per_dev"] = costs.bytes
        d["collective_bytes_per_dev"] = costs.total_collective
        d["collective_bytes_native"] = costs.collective_bytes_native
        d["t_collective_native"] = costs.collective_bytes_native / V5E_ICI_BW
        d["collectives"] = dict(costs.collective_bytes)
        d["t_compute"] = costs.flops / V5E_PEAK_FLOPS
        d["t_memory"] = costs.bytes / V5E_HBM_BW
        d["t_collective"] = costs.total_collective / V5E_ICI_BW
        terms = {"compute": d["t_compute"], "memory": d["t_memory"],
                 "collective": d["t_collective"]}
        d["bottleneck"] = max(terms, key=terms.get)
        hlo_total = costs.flops * d["n_devices"]
        d["useful_flops_ratio"] = (d["model_flops"] / hlo_total
                                   if hlo_total else 0.0)
        jpath.write_text(json.dumps(d, indent=2))
        n += 1
    return n


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args(argv)
    n = reanalyze(Path(args.out))
    print(f"re-analyzed {n} artifacts")


if __name__ == "__main__":
    main()
