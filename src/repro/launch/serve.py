"""Deprecated shim: ``repro.launch.serve`` moved to
:mod:`repro.launch.generate` ("serve" now means the plan server,
``python -m repro.service``)."""
from .generate import main  # noqa: F401

if __name__ == "__main__":
    raise SystemExit(main())
