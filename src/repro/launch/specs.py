"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell.

Sharding is attached directly to the ShapeDtypeStructs (weak-type-correct,
shardable, no device allocation), so ``jax.jit(step).lower(**specs)``
needs no separate in_shardings.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import model as M
from ..models.config import ModelConfig, ShapeSpec
from ..models.sharding import ShardCtx, tree_shardings
from ..optim.adamw import AdamW


def _sds(shape, dtype, ctx: ShardCtx, spec: P):
    sharding = NamedSharding(ctx.mesh, spec) if ctx.mesh else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _with_shardings(tree_sds, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_sds, shardings)


def params_spec(cfg: ModelConfig, ctx: ShardCtx):
    sds = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    if ctx.mesh is None:
        return sds
    return _with_shardings(sds, tree_shardings(sds, cfg, ctx))


def opt_spec(cfg: ModelConfig, ctx: ShardCtx, opt: AdamW, *,
             zero1: bool = False):
    p = params_spec(cfg, ctx)
    sds = jax.eval_shape(opt.init, p)
    if ctx.mesh is None:
        return sds
    # m/v inherit the param shardings; step is replicated
    pshard = jax.tree.map(lambda s: s.sharding, p)
    if zero1:
        # ZeRO-1: shard the fp32 moments over the data axis on the largest
        # still-unsharded dim (params themselves stay data-replicated)
        def z1_for(s_leaf, sh):
            parts = list(sh.spec) + [None] * (len(s_leaf.shape) - len(sh.spec))
            used = {a for pp_ in parts if pp_ is not None
                    for a in ((pp_,) if isinstance(pp_, str) else pp_)}
            if "data" not in used:
                cands = [i for i, ax in enumerate(parts) if ax is None
                         and s_leaf.shape[i] % ctx.n("data") == 0]
                if cands:
                    big = max(cands, key=lambda i: s_leaf.shape[i])
                    parts[big] = "data"
            return NamedSharding(ctx.mesh, P(*parts))
        pshard = jax.tree.map(z1_for, sds.m, pshard)
    rep = NamedSharding(ctx.mesh, P())
    return type(sds)(
        step=jax.ShapeDtypeStruct(sds.step.shape, sds.step.dtype, sharding=rep),
        m=_with_shardings(sds.m, pshard),
        v=_with_shardings(sds.v, pshard))


def batch_spec(cfg: ModelConfig, shape: ShapeSpec, ctx: ShardCtx) -> Dict[str, Any]:
    dp = P(ctx.dp if ctx.dp else None)
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.frontend == "vlm":
        s_text = s - cfg.n_img_tokens
        out["tokens"] = _sds((b, s_text), jnp.int32, ctx, dp)
        out["img_embeds"] = _sds((b, cfg.n_img_tokens, cfg.d_model),
                                 jnp.bfloat16, ctx, P(dp[0], None, None))
    else:
        out["tokens"] = _sds((b, s), jnp.int32, ctx, dp)
    if shape.kind == "train":
        out["labels"] = _sds((b, s), jnp.int32, ctx, dp)
    return out


def cache_spec(cfg: ModelConfig, shape: ShapeSpec, ctx: ShardCtx):
    sds = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    if ctx.mesh is None:
        return sds
    pspecs = M.cache_pspecs(cfg, ctx, shape.global_batch)
    out = {}
    for k, v in sds.items():
        spec = pspecs.get(k, P())
        parts = list(spec)[:len(v.shape)]
        while len(parts) < len(v.shape):
            parts.append(None)
        # drop non-dividing axes
        clean = []
        for dim, ax in zip(v.shape, parts):
            if ax is None:
                clean.append(None)
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            n = 1
            for a in axes:
                n *= ctx.n(a)
            clean.append(ax if dim % n == 0 else None)
        out[k] = jax.ShapeDtypeStruct(
            v.shape, v.dtype, sharding=NamedSharding(ctx.mesh, P(*clean)))
    return out


def decode_inputs(cfg: ModelConfig, shape: ShapeSpec, ctx: ShardCtx) -> Tuple:
    b = shape.global_batch
    nd = 1
    for a in (ctx.dp or ()):
        nd *= ctx.n(a)
    tok_spec = P(ctx.dp) if (ctx.mesh and b % max(nd, 1) == 0 and nd > 1) else P(None)
    token = _sds((b, 1), jnp.int32, ctx, tok_spec)
    cache = cache_spec(cfg, shape, ctx)
    pos = _sds((), jnp.int32, ctx, P())
    return token, cache, pos
