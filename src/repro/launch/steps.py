"""jit-able train / prefill / decode steps used by the launcher, the
dry-run and the examples."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ModelConfig
from ..models.sharding import ShardCtx
from ..optim.adamw import AdamW, AdamWState


def make_train_step(cfg: ModelConfig, ctx: ShardCtx, opt: AdamW,
                    n_micro: int = 1):
    """Microbatch-accumulation training step (Pipette's bs_micro knob).

    grads accumulate in fp32 across a lax.scan over n_micro microbatches
    (each fwd+bwd under remat), then one AdamW update."""

    def train_step(params, opt_state: AdamWState, batch: Dict[str, Any]):
        def micro_loss(p, mb):
            return M.loss_fn(p, cfg, ctx, mb)

        if n_micro == 1:
            (loss, aux), grads = jax.value_and_grad(micro_loss, has_aux=True)(
                params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]),
                batch)

            def micro_step(carry, mb):
                gacc, lacc = carry
                (loss, _), g = jax.value_and_grad(micro_loss, has_aux=True)(
                    params, mb)
                gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    gacc, g)
                return (gacc, lacc + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (gsum, lsum), _ = jax.lax.scan(micro_step, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro

        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss}

    return train_step


def make_prefill_step(cfg: ModelConfig, ctx: ShardCtx):
    def prefill_step(params, batch: Dict[str, Any]):
        logits, cache = M.prefill(params, cfg, ctx, batch["tokens"],
                                  batch.get("img_embeds"))
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, ctx: ShardCtx):
    def serve_step(params, cache, token, pos):
        logits, cache = M.decode_step(params, cfg, ctx, token, cache, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, cache
    return serve_step
