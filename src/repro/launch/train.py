"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen2-7b --smoke --steps 50 --configure

``--configure`` runs the Pipette search against the simulated cluster
first and reports the chosen (pp, tp, dp, bs_micro) + worker dedication;
the JAX mesh is then built from the devices available in this process
(data x model), with microbatch accumulation standing in for Pipette's
bs_micro knob.  ``--smoke`` trains the reduced config of the arch so the
full driver runs on CPU.
"""
from __future__ import annotations

import argparse
import time

import jax


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="train the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints/train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--configure", action="store_true",
                    help="run the Pipette search first (simulated cluster)")
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (fault-tolerance demo)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from .. import configs
    from ..core import (MID_RANGE, Budget, Planner, PlanRequest,
                        PipetteStrategy, Workload, profile_bandwidth)
    from ..data.pipeline import DataLoader, LoaderConfig, SyntheticCorpus
    from ..models import model as M
    from ..models.sharding import ShardCtx
    from ..optim.adamw import AdamW, cosine_schedule
    from ..runtime.trainer import TrainLoop, TrainLoopConfig
    from .steps import make_train_step

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.reduced()

    plan = None
    if args.configure:
        spec = MID_RANGE.with_nodes(8)
        w = Workload(cfg, args.seq_len, max(args.global_batch, 64))
        bw, cost = profile_bandwidth(spec)
        req = PlanRequest(workload=w, spec=spec,
                          budget=Budget(sa_seconds=0.2, sa_iters=2000),
                          seed=args.seed)
        plan = Planner(PipetteStrategy()).plan(req, bw)
        print(f"[pipette] profiled {spec.n_gpus} GPUs in {cost:.0f}s (sim); "
              f"best config {plan.conf} est {plan.latency*1e3:.1f} ms/iter")
        print(f"[pipette] worker dedication (stage-major GPU ids):\n"
              f"{plan.mapping.reshape(plan.conf.pp, -1)}")

    ctx = ShardCtx()         # single-host CPU training
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    opt = AdamW(lr=cosine_schedule(args.lr, 20, args.steps))
    opt_state = opt.init(params)
    n_params = sum(p.size for p in jax.tree.leaves(params))  # repro: noqa DET004 -- .size is an int element count; integer sum is exact in any order
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"batch {args.global_batch} x seq {args.seq_len}, "
          f"{args.n_micro} microbatches")

    step_fn = jax.jit(make_train_step(cfg, ctx, opt, n_micro=args.n_micro),
                      donate_argnums=(0, 1))
    corpus = SyntheticCorpus(cfg.vocab_size, seed=args.seed)
    loader = DataLoader(corpus, LoaderConfig(args.global_batch, args.seq_len))

    loop = TrainLoop(
        TrainLoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                        ckpt_dir=args.ckpt_dir, metrics_path=args.metrics),
        step_fn, loader, fail_at_step=args.fail_at, plan=plan)
    t0 = time.perf_counter()
    params, opt_state = loop.run(params, opt_state, resume=args.resume)
    dt = time.perf_counter() - t0
    losses = [h["loss"] for h in loop.history]
    print(f"[train] {len(loop.history)} steps in {dt:.1f}s "
          f"({dt/max(len(loop.history),1):.2f}s/step); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
