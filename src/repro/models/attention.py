"""Chunked flash-pattern attention in pure jnp.

This is simultaneously (a) the numerically-stable oracle for the Pallas
flash-attention kernel and (b) the path the multi-pod dry-run lowers
(Pallas cannot lower to the CPU backend; on TPU ``kernels.ops`` dispatches
to the Pallas kernel instead).  The online-softmax recurrence keeps HLO
bytes realistic — no (Sq, Sk) score matrix is ever materialised beyond a
(chunk_q, chunk_k) tile, exactly like the kernel.

Supports GQA (n_kv_heads <= n_heads), causal masking, sliding windows
(gemma3 local layers) and offset queries (continuation / decode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pick_chunk(s: int, target: int) -> int:
    """Largest divisor of ``s`` that is <= target (>=1)."""
    c = min(s, target)
    while s % c:
        c -= 1
    return c


def chunked_attention(
    q: jax.Array,                 # (b, Sq, H, hd)
    k: jax.Array,                 # (b, Sk, KV, hd)
    v: jax.Array,                 # (b, Sk, KV, hd)
    *,
    causal: bool = True,
    window: int = 0,              # 0 = unbounded; may be a traced scalar
    q_offset: int = 0,            # absolute position of q[0]
    chunk_q: int = 512,
    chunk_k: int = 1024,
    min_q_blocks: int = 1,        # ensure nq % this == 0 (seq sharding)
    block_constrain=None,         # fn(x, block_dim) -> x; shards the q-block dim
) -> jax.Array:
    """Flash-pattern attention, q-block-parallel (vmap) over the outer dim.

    The q-block axis is a real batch dim, so it can be sharded (sequence /
    context parallelism) — the default for archs whose head count does not
    divide the model axis (granite 24H, qwen1.5 20H; DESIGN.md §4)."""
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    scale = 1.0 / (hd ** 0.5)

    cq = _pick_chunk(sq, chunk_q)
    if min_q_blocks > 1:
        while cq > 1 and (sq // cq) % min_q_blocks:
            cq -= 1
        cq = _pick_chunk(sq, cq)
    ck = _pick_chunk(sk, chunk_k)
    nq, nk = sq // cq, sk // ck

    qc = q.reshape(b, nq, cq, kv, g, hd).astype(jnp.float32) * scale
    if block_constrain is not None:
        qc = block_constrain(qc, 1)
    kc = k.reshape(b, nk, ck, kv, hd).astype(jnp.float32).swapaxes(0, 1)
    vc = v.reshape(b, nk, ck, kv, hd).astype(jnp.float32).swapaxes(0, 1)

    q_pos_all = q_offset + jnp.arange(sq, dtype=jnp.int32).reshape(nq, cq)
    k_pos_all = jnp.arange(sk, dtype=jnp.int32).reshape(nk, ck)
    win = jnp.asarray(window, jnp.int32)

    def q_block(q_blk, q_pos):
        # q_blk: (b, cq, kv, g, hd); q_pos: (cq,)
        def kv_step(carry, xs):
            m, l, acc = carry
            k_blk, v_blk, k_pos = xs
            s = jnp.einsum("bqkgd,bckd->bkgqc", q_blk, k_blk)
            delta = q_pos[:, None] - k_pos[None, :]
            ok = jnp.full(delta.shape, True)
            if causal:
                ok &= delta >= 0
            ok &= (win <= 0) | (delta < win)
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p, v_blk)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, kv, g, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (kc, vc, k_pos_all))
        # rows with no allowed key (padded windows / negative offsets) -> 0
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.where(m[..., None] <= NEG_INF * 0.5, 0.0, out)

    outs = jax.vmap(q_block, in_axes=(1, 0), out_axes=1)(qc, q_pos_all)
    # outs: (b, nq, kv, g, cq, hd)
    if block_constrain is not None:
        outs = block_constrain(outs, 1)
    out = outs.transpose(0, 1, 4, 2, 3, 5).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,                 # (b, 1, H, hd)
    k_cache: jax.Array,           # (b, S, KV, hd)
    v_cache: jax.Array,           # (b, S, KV, hd)
    pos,                          # scalar int32: index of the current token
    *,
    window: int = 0,
) -> jax.Array:
    """Single-token attention against a (possibly sharded) KV cache.

    The reductions over S lower to all-reduces when the cache's sequence
    dimension is sharded — flash-decoding's partial-softmax combine, done
    by GSPMD.
    """
    b, _, h, hd = q.shape
    _, s, kvh, _ = k_cache.shape
    g = h // kvh
    scale = 1.0 / (hd ** 0.5)

    qf = q.reshape(b, kvh, g, hd).astype(jnp.float32) * scale
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    sc = jnp.einsum("bkgd,bskd->bkgs", qf, kf)
    k_pos = jnp.arange(s, dtype=jnp.int32)
    ok = k_pos <= pos
    win = jnp.asarray(window, jnp.int32)
    ok &= (win <= 0) | (pos - k_pos < win)
    sc = jnp.where(ok[None, None, None], sc, NEG_INF)
    m = sc.max(axis=-1, keepdims=True)
    p = jnp.exp(sc - m)
    out = jnp.einsum("bkgs,bskd->bkgd", p, vf) / p.sum(-1, keepdims=True)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def reference_attention(q, k, v, *, causal=True, window=0, q_offset=0):
    """O(S^2)-memory oracle used only in tests."""
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    qf = q.reshape(b, sq, kv, g, hd).astype(jnp.float32) / (hd ** 0.5)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qf, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    delta = q_pos[:, None] - k_pos[None, :]
    ok = jnp.full(delta.shape, True)
    if causal:
        ok &= delta >= 0
    if window and window > 0:
        ok &= delta < window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)
