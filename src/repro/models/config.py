"""Model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / SSM / hybrid decoder LMs plus the
VLM/audio frontend stubs.  Every assigned architecture in
``repro.configs`` instantiates this with its exact published numbers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # --- attention variants ---
    sliding_window: int = 0          # 0 = full attention
    local_global_period: int = 0     # gemma3: period p => layers i with i%p==p-1 global
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_theta_global: float = 0.0   # gemma3 global layers use a larger theta
    # --- SSM ---
    ssm_variant: str = ""            # "mamba1" | "mamba2"
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64           # mamba2 channels per head
    hybrid_attn_period: int = 0      # zamba2: shared attn block every k layers
    # --- frontend stubs ---
    frontend: str = ""               # "" | "vlm" | "audio"
    n_img_tokens: int = 0            # vlm: anyres patch embeddings per sample
    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # --- training-system knobs (consumed by launch/, not by the math) ---
    remat: bool = True
    scan_layers: bool = True
    # --- §Perf hillclimb knobs (EXPERIMENTS.md; defaults = baseline) ---
    moe_combine_f32_materialize: bool = True   # baseline: fp32 (T*k, d) combine
    moe_gather_dispatch: bool = False          # index-buffer dispatch (no x-repeat)
    seq_shard_residuals: bool = False          # Megatron-SP saved residuals
    scan_dtype: str = "float32"                # mamba scan working dtype

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Physical vocab rows, padded to a 256 multiple so the embedding
        shards over any mesh axis.  Phantom logits are masked to -inf
        (exact math); only granite's 49155 actually pads."""
        return -(-self.vocab_size // 256) * 256

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        # mamba1 convention: ceil(d_model / 16)
        return -(-self.d_model // 16)

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k decode shape."""
        if self.family in ("ssm", "hybrid"):
            return True
        # mostly-local attention (gemma3 5:1) has a window-bounded cache for
        # all but every p-th layer
        return self.local_global_period > 0

    def layer_is_global_attn(self, i: int) -> bool:
        """gemma3-style local:global pattern; True when layer i is global."""
        if self.local_global_period <= 0:
            return True
        return (i % self.local_global_period) == self.local_global_period - 1

    def layer_window(self, i: int) -> int:
        """Effective sliding window for layer i (0 = full)."""
        if self.local_global_period <= 0:
            return self.sliding_window
        if self.layer_is_global_attn(i):
            return 0
        return self.sliding_window if self.sliding_window else 1024

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.hybrid_attn_period == 0 else 6),
            d_model=128,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32 if self.n_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 8),
            experts_per_token=min(self.experts_per_token, 2),
            capacity_factor=8.0,     # no drops -> exact vs dense oracle
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            local_global_period=self.local_global_period,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_variant == "mamba2" else self.ssm_head_dim,
            hybrid_attn_period=min(self.hybrid_attn_period, 3) if self.hybrid_attn_period else 0,
            n_img_tokens=16 if self.frontend == "vlm" else 0,
            dtype="float32",
            remat=False,
        )
        if self.local_global_period:
            kw["sliding_window"] = 16
        kw.update(overrides)
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape) cell of the assignment matrix."""
    name: str                  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "decode"),
}
