"""Modality frontend stubs.

Per the assignment, [vlm]/[audio] entries specify the transformer BACKBONE
only: ``input_specs()`` provides precomputed frame/patch embeddings.  These
helpers generate synthetic stand-ins with the right shapes for smoke tests
and document the contract the real frontends would satisfy.

  * llava-next (anyres): 4 tiles + base image, 576 patches each -> 2880
    patch embeddings of d_model, already projected by the (stubbed)
    vision tower + mm projector.
  * musicgen: EnCodec tokens; the real model interleaves 4 codebooks with
    a delay pattern — the stub flattens to a single stream over the
    2048-entry codebook vocabulary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def vlm_patch_embeddings(key, batch: int, n_img_tokens: int, d_model: int,
                         dtype=jnp.bfloat16) -> jax.Array:
    """Synthetic anyres patch embeddings (b, n_img, d)."""
    x = jax.random.normal(key, (batch, n_img_tokens, d_model), jnp.float32)
    return (x / (d_model ** 0.5)).astype(dtype)


def audio_tokens(key, batch: int, seq_len: int, vocab: int = 2048) -> jax.Array:
    """Synthetic EnCodec token stream (b, s)."""
    return jax.random.randint(key, (batch, seq_len), 0, vocab, jnp.int32)
