"""Shared layer math: norms, RoPE, activations, initializers.

Pure functions over explicit parameter pytrees (no framework).  Norms and
softmax-adjacent reductions run in fp32 regardless of activation dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = silu(x @ w_gate)
    u = x @ w_up
    return (g * u) @ w_down


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta) -> jax.Array:
    """Inverse frequencies (head_dim//2,). ``theta`` may be a traced scalar."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (jnp.asarray(theta, jnp.float32) ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta) -> jax.Array:
    """Rotate ``x`` (..., seq, heads, head_dim) by position-dependent angles.

    ``positions``: (..., seq) int32.  Uses the interleaved-pair convention
    folded into the rotate-half layout (matches Llama-style checkpoints
    numerically up to a fixed permutation, which is irrelevant here because
    we train from scratch).
    """
    dtype = x.dtype
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                                   # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv          # (..., S, hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]                                    # (..., S, 1, hd/2)
    cos = cos[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Initializers (explicit shapes; return stacked (L, ...) arrays when n is set)
# ---------------------------------------------------------------------------

def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def dense_init(key, d_in: int, d_out: int, *, n: int = 0, dtype=jnp.bfloat16):
    shape = (n, d_in, d_out) if n else (d_in, d_out)
    return _normal(key, shape, 1.0 / np.sqrt(d_in), dtype)


def embed_init(key, vocab: int, d: int, *, dtype=jnp.bfloat16):
    return _normal(key, (vocab, d), 1.0, dtype)


def zeros(shape, dtype=jnp.bfloat16):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.bfloat16):
    return jnp.ones(shape, dtype)
