"""Mamba1 (selective scan) and Mamba2 (SSD) blocks.

TPU adaptation notes (DESIGN.md §2):
  * Mamba1 uses a chunked first-order associative scan: only one
    (b, Q, d_inner, N) tile is live per chunk, and d_inner is sharded over
    the model axis, so the per-device working set stays VMEM-sized.  The
    Pallas kernel (kernels/selective_scan.py) implements the same chunking
    with explicit BlockSpecs.
  * Mamba2 uses the SSD dual form: within-chunk (Q x Q) decay-masked
    attention-like matmuls (MXU-friendly) + a cheap inter-chunk state
    recurrence.  State (b, H, P, N) never materialises a per-timestep
    trajectory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import silu, rms_norm


def _pick_chunk(s: int, target: int) -> int:
    c = min(s, target)
    while s % c:
        c -= 1
    return c


# ---------------------------------------------------------------------------
# causal depthwise conv
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """x: (B, S, C); w: (W, C) depthwise; left-padded causal conv."""
    width, c = w.shape
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    kernel = w[:, None, :].astype(x.dtype)           # (W, 1, C)
    y = jax.lax.conv_general_dilated(
        xp, kernel, window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=c)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def causal_conv1d_step(x_t: jax.Array, cache: jax.Array, w: jax.Array,
                       b: jax.Array | None = None):
    """One decode step.  x_t: (B, C); cache: (B, W-1, C) past inputs."""
    window = jnp.concatenate([cache, x_t[:, None]], axis=1)       # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        y = y + b.astype(jnp.float32)
    new_cache = window[:, 1:]
    return y.astype(x_t.dtype), new_cache


# ---------------------------------------------------------------------------
# Mamba1 selective scan (chunked associative scan)
# ---------------------------------------------------------------------------

def selective_scan(x, dt, B, C, A, *, h0=None, chunk: int = 128,
                   work_dtype=jnp.float32):
    """h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t

    x, dt: (b, S, D);  B, C: (b, S, N);  A: (D, N) (negative real).
    Returns y (b, S, D) fp32 and final state (b, D, N) fp32.
    """
    b, s, d = x.shape
    n = B.shape[-1]
    q = _pick_chunk(s, chunk)
    nc = s // q

    xc = x.astype(jnp.float32).reshape(b, nc, q, d).swapaxes(0, 1)
    dtc = dt.astype(jnp.float32).reshape(b, nc, q, d).swapaxes(0, 1)
    Bc = B.astype(jnp.float32).reshape(b, nc, q, n).swapaxes(0, 1)
    Cc = C.astype(jnp.float32).reshape(b, nc, q, n).swapaxes(0, 1)
    A32 = A.astype(jnp.float32)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_body(h, xs):
        xq, dtq, bq, cq = xs
        a = jnp.exp(dtq[..., None] * A32).astype(work_dtype)      # (b,q,d,n)
        u = ((dtq * xq)[..., None] * bq[:, :, None, :]).astype(work_dtype)
        a_cum, u_scan = jax.lax.associative_scan(combine, (a, u), axis=1)
        h_all = a_cum.astype(jnp.float32) * h[:, None] \
            + u_scan.astype(jnp.float32)                          # (b,q,d,n)
        y = jnp.einsum("bqdn,bqn->bqd", h_all, cq)
        return h_all[:, -1], y

    if h0 is None:
        h0 = jnp.zeros((b, d, n), jnp.float32)
    h_fin, yc = jax.lax.scan(chunk_body, h0, (xc, dtc, Bc, Cc))
    y = yc.swapaxes(0, 1).reshape(b, s, d)
    return y, h_fin


def selective_scan_step(x, dt, B, C, A, h):
    """One decode step.  x, dt: (b, D); B, C: (b, N); h: (b, D, N)."""
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A.astype(jnp.float32))
    h_new = a * h + (dt * x).astype(jnp.float32)[..., None] * B[:, None, :].astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", h_new, C.astype(jnp.float32))
    return y, h_new


# ---------------------------------------------------------------------------
# Mamba2 SSD (chunked dual form)
# ---------------------------------------------------------------------------

def ssd_scan(x, dt, B, C, A, *, h0=None, chunk: int = 128):
    """Mamba2 state-space dual scan.

    x: (b, S, H, P); dt: (b, S, H); B, C: (b, S, N) (single group);
    A: (H,) negative real.  Returns y (b, S, H, P) fp32, final state
    (b, H, P, N) fp32.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = _pick_chunk(s, chunk)
    nc = s // q

    xf = x.astype(jnp.float32).reshape(b, nc, q, h, p).swapaxes(0, 1)
    dtf = dt.astype(jnp.float32).reshape(b, nc, q, h).swapaxes(0, 1)
    Bf = B.astype(jnp.float32).reshape(b, nc, q, n).swapaxes(0, 1)
    Cf = C.astype(jnp.float32).reshape(b, nc, q, n).swapaxes(0, 1)
    A32 = A.astype(jnp.float32)
    causal = jnp.tril(jnp.ones((q, q), jnp.float32))

    def chunk_body(state, xs):
        xq, dtq, bq, cq = xs                                      # per-chunk
        loga = dtq * A32                                          # (b,q,h)
        l = jnp.cumsum(loga, axis=1)                              # inclusive
        # decay(j -> i) = exp(l_i - l_j), j <= i; mask inside the exponent
        # (a masked exp(+big) would overflow to inf and 0*inf = NaN)
        delta = l[:, :, None, :] - l[:, None, :, :]               # (b,i,j,h)
        delta = jnp.where(causal[None, :, :, None] > 0, delta, -jnp.inf)
        decay = jnp.exp(delta)
        cb = jnp.einsum("bin,bjn->bij", cq, bq)                   # (b,q,q)
        m = cb[..., None] * decay                                 # (b,i,j,h)
        xdt = xq * dtq[..., None]                                 # (b,q,h,p)
        y_intra = jnp.einsum("bijh,bjhp->bihp", m, xdt)
        # inter-chunk: h_i gets exp(l_i) * state
        y_inter = jnp.exp(l)[..., None] * jnp.einsum("bhpn,bin->bihp", state, cq)
        # state update: h_last = exp(l_last) state + sum_j exp(l_last - l_j) dt_j x_j B_j
        tail = jnp.exp(l[:, -1:, :] - l)                          # (b,q,h)
        s_new = jnp.exp(l[:, -1])[:, :, None, None] * state + \
            jnp.einsum("bjhp,bjn,bjh->bhpn", xq, bq, dtq * tail)
        return s_new, y_intra + y_inter

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    s_fin, yc = jax.lax.scan(chunk_body, h0, (xf, dtf, Bf, Cf))
    y = yc.swapaxes(0, 1).reshape(b, s, h, p)
    return y, s_fin


def ssd_step(x, dt, B, C, A, state):
    """One decode step.  x: (b,H,P); dt: (b,H); B,C: (b,N); state: (b,H,P,N)."""
    a = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32))  # (b,H)
    upd = jnp.einsum("bhp,bn->bhpn", (x * dt[..., None]).astype(jnp.float32),
                     B.astype(jnp.float32))
    s_new = a[:, :, None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", s_new, C.astype(jnp.float32))
    return y, s_new


# ---------------------------------------------------------------------------
# Full blocks (projections + conv + scan + gate)
# ---------------------------------------------------------------------------

def mamba1_block(x, p, cfg, *, h0=None, conv0=None, single_step=False):
    """x: (B, S, d_model) or (B, d_model) when single_step.

    Params ``p``: in_proj (d, 2*di), conv_w (W, di), conv_b (di,),
    x_proj (di, dt_rank+2N), dt_w (dt_rank, di), dt_bias (di,),
    A_log (di, N), D (di,), out_proj (di, d).
    Returns (y, (h, conv_cache)).
    """
    di, n = cfg.d_inner, cfg.ssm_state
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if single_step:
        xz = x @ p["in_proj"]
        xi, z = jnp.split(xz, 2, axis=-1)                         # (B, di)
        xi, conv_cache = causal_conv1d_step(xi, conv0, p["conv_w"], p["conv_b"])
        xi = silu(xi)
        proj = xi @ p["x_proj"]
        dt, B_, C_ = jnp.split(proj, [cfg.dt_rank, cfg.dt_rank + n], axis=-1)
        dt = jax.nn.softplus(dt @ p["dt_w"] + p["dt_bias"].astype(dt.dtype))
        y, h = selective_scan_step(xi, dt, B_, C_, A, h0)
        y = y + p["D"].astype(jnp.float32) * xi.astype(jnp.float32)
        y = (y * silu(z.astype(jnp.float32)))
        return (y.astype(x.dtype) @ p["out_proj"]), (h, conv_cache)

    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                             # (B, S, di)
    conv_tail = xi[:, -(cfg.ssm_conv - 1):, :]                    # decode cache
    xi = causal_conv1d(xi, p["conv_w"], p["conv_b"])
    xi = silu(xi)
    proj = xi @ p["x_proj"]
    dt, B_, C_ = jnp.split(proj, [cfg.dt_rank, cfg.dt_rank + n], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_w"] + p["dt_bias"].astype(dt.dtype))
    y, h = selective_scan(xi, dt, B_, C_, A, h0=h0,
                          work_dtype=jnp.dtype(cfg.scan_dtype))
    y = y + p["D"].astype(jnp.float32) * xi.astype(jnp.float32)
    y = y * silu(z.astype(jnp.float32))
    return (y.astype(x.dtype) @ p["out_proj"]), (h, conv_tail)


def mamba2_block(x, p, cfg, *, h0=None, conv0=None, single_step=False):
    """Mamba2/SSD block.  Params ``p``: in_proj (d, 2*di+2N+H), conv_w
    (W, di+2N), conv_b, A_log (H,), D (H,), dt_bias (H,), norm_w (di,),
    out_proj (di, d)."""
    di, n, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    nh = di // hd
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    zxbcdt = x @ p["in_proj"]
    splits = [di, 2 * di, 2 * di + n, 2 * di + 2 * n]
    z, xi, B_, C_, dt = jnp.split(zxbcdt, splits, axis=-1)

    if single_step:
        xbc = jnp.concatenate([xi, B_, C_], axis=-1)              # (B, di+2N)
        xbc, conv_cache = causal_conv1d_step(xbc, conv0, p["conv_w"], p["conv_b"])
        xbc = silu(xbc)
        xi, B_, C_ = jnp.split(xbc, [di, di + n], axis=-1)
        dt = jax.nn.softplus(dt + p["dt_bias"].astype(dt.dtype))  # (B, H)
        xh = xi.reshape(*xi.shape[:-1], nh, hd)
        y, h = ssd_step(xh, dt, B_, C_, A, h0)
        y = y + p["D"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
        y = y.reshape(*y.shape[:-2], di)
        y = rms_norm(y * silu(z.astype(jnp.float32)), p["norm_w"], cfg.norm_eps)
        return (y.astype(x.dtype) @ p["out_proj"]), (h, conv_cache)

    xbc = jnp.concatenate([xi, B_, C_], axis=-1)
    conv_tail = xbc[:, -(cfg.ssm_conv - 1):, :]                   # decode cache
    xbc = causal_conv1d(xbc, p["conv_w"], p["conv_b"])
    xbc = silu(xbc)
    xi, B_, C_ = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(dt.dtype))      # (B, S, H)
    xh = xi.reshape(*xi.shape[:-1], nh, hd)
    y, h = ssd_scan(xh, dt, B_, C_, A, h0=h0)
    y = y + p["D"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
    y = y.reshape(*y.shape[:-2], di)
    y = rms_norm(y * silu(z.astype(jnp.float32)), p["norm_w"], cfg.norm_eps)
    return (y.astype(x.dtype) @ p["out_proj"]), (h, conv_tail)
