"""Top-level model API: train forward/loss, prefill, decode.

Decode uses an unrolled per-layer loop so heterogeneous caches stay exact:
full KV rows for global-attention layers, ring buffers for sliding-window
layers (gemma3 locals), SSM state + conv tails for mamba layers, and the
weight-tied shared-attention rows of hybrid archs.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import mamba as mam
from .attention import decode_attention
from .config import ModelConfig
from .layers import rms_norm
from .moe import moe_block
from .sharding import ShardCtx
from .transformer import (_proj_qkv, attn_block, init_params, layer_plan,
                          mlp_block, run_stack)

__all__ = ["init_params", "forward_logits", "loss_fn", "prefill",
           "init_cache", "decode_step", "cache_pspecs"]


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, tokens, img_embeds=None):
    x = params["tok_embed"][tokens]                     # (b, s_text, d)
    if img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return x, positions


def _head(params, cfg: ModelConfig):
    if cfg.tie_embeddings or "lm_head" not in params:
        return params["tok_embed"].T
    return params["lm_head"]


def _project_logits(x, params, cfg: ModelConfig):
    """Final projection with phantom-row masking (padded_vocab is exact)."""
    logits = x.astype(jnp.bfloat16) @ _head(params, cfg)
    if cfg.padded_vocab != cfg.vocab_size:
        bias = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                         0.0, -1e30).astype(logits.dtype)
        logits = logits + bias
    return logits


def forward_logits(params, cfg: ModelConfig, ctx: ShardCtx, tokens,
                   img_embeds=None):
    x, positions = embed_inputs(params, cfg, tokens, img_embeds)
    x, _ = run_stack(x, params, cfg, ctx, positions)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _project_logits(x, params, cfg)


def loss_fn(params, cfg: ModelConfig, ctx: ShardCtx, batch) -> Tuple[jax.Array, Dict]:
    """Mean next-token CE over valid labels (labels < 0 are masked)."""
    logits = forward_logits(params, cfg, ctx, batch["tokens"],
                            batch.get("img_embeds"))
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    safe = jnp.maximum(labels, 0)
    onehot = jax.nn.one_hot(safe, cfg.padded_vocab, dtype=jnp.float32)
    picked = jnp.einsum("bsv,bsv->bs", lf, onehot)
    nll = lse - picked
    mask = (labels >= 0).astype(jnp.float32)
    n = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / n
    return loss, {"loss": loss, "tokens": n}


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, ctx: ShardCtx, tokens, img_embeds=None):
    """Full-sequence pass that returns (last_token_logits, cache)."""
    x, positions = embed_inputs(params, cfg, tokens, img_embeds)
    x, raw = run_stack(x, params, cfg, ctx, positions, collect_cache=True)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = _project_logits(x, params, cfg)

    plan, meta = layer_plan(cfg)
    cache: Dict[str, Any] = {}
    if cfg.family in ("ssm", "hybrid"):
        if cfg.hybrid_attn_period:
            (h, conv_tail), shared_kv = raw
            if shared_kv:
                cache["k"], cache["v"] = shared_kv
        else:
            h, conv_tail = raw
        cache["ssm"] = h
        cache["conv"] = conv_tail
    else:
        k, v = raw                                       # (L, b, S, KV, hd)
        full_rows = [i for i, e in enumerate(plan) if e["cache"][0] == "full"]
        ring_rows = [(i, e["cache"][2]) for i, e in enumerate(plan)
                     if e["cache"][0] == "ring"]
        if full_rows:
            idx = np.array(full_rows)
            cache["k"], cache["v"] = k[idx], v[idx]
        if ring_rows:
            w = ring_rows[0][1]
            idx = np.array([i for i, _ in ring_rows])
            s = k.shape[2]
            assert s % w == 0, "prefill length must be a multiple of the window"
            cache["k_ring"], cache["v_ring"] = k[idx, :, -w:], v[idx, :, -w:]
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    plan, meta = layer_plan(cfg)
    kv, hd = cfg.n_kv_heads, cfg.hd
    cache: Dict[str, Any] = {}
    n_full = meta["full"] + len(meta["shared_at"])
    if n_full:
        cache["k"] = jnp.zeros((n_full, batch, seq_len, kv, hd), dtype)
        cache["v"] = jnp.zeros((n_full, batch, seq_len, kv, hd), dtype)
    if meta["ring"]:
        w = next((e["cache"][2] for e in plan
                  if e.get("cache", ("",))[0] == "ring"), 0)
        cache["k_ring"] = jnp.zeros((meta["ring"], batch, w, kv, hd), dtype)
        cache["v_ring"] = jnp.zeros((meta["ring"], batch, w, kv, hd), dtype)
    if meta["ssm"]:
        if cfg.ssm_variant == "mamba2":
            cache["ssm"] = jnp.zeros((meta["ssm"], batch, cfg.n_ssm_heads,
                                      cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
            conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        else:
            cache["ssm"] = jnp.zeros((meta["ssm"], batch, cfg.d_inner,
                                      cfg.ssm_state), jnp.float32)
            conv_dim = cfg.d_inner
        cache["conv"] = jnp.zeros((meta["ssm"], batch, cfg.ssm_conv - 1,
                                   conv_dim), dtype)
    return cache


def cache_pspecs(cfg: ModelConfig, ctx: ShardCtx, batch: int) -> Dict[str, P]:
    """Sharding for the decode cache: batch over dp when divisible, the
    sequence dim of full KV rows over the model axis (flash-decoding
    combine), SSM channels over the model axis."""
    dp = ctx.dp if ctx.dp else None
    nd = 1
    for a in (ctx.dp or ()):
        nd *= ctx.n(a)
    bspec = dp if (batch % max(nd, 1) == 0 and nd > 1) else None
    seq_axes = ctx.tp if bspec is not None else (ctx.tp,) + tuple(ctx.dp)
    specs = {}
    specs["k"] = P(None, bspec, seq_axes, None, None)
    specs["v"] = specs["k"]
    specs["k_ring"] = P(None, bspec, None, None, None)
    specs["v_ring"] = specs["k_ring"]
    if cfg.ssm_variant == "mamba2":
        specs["ssm"] = P(None, bspec, ctx.tp, None, None)
    else:
        specs["ssm"] = P(None, bspec, ctx.tp, None)
    specs["conv"] = P(None, bspec, None, ctx.tp)
    return specs


def _decode_attn(x, lp, cfg, ctx, cache, entry, pos, shared_row=None):
    """One attention layer decode step; returns (out, cache updates)."""
    b = x.shape[0]
    positions = jnp.broadcast_to(pos[None], (b, 1)).astype(jnp.int32)
    q, k, v = _proj_qkv(x, lp, cfg, positions, entry["theta"])
    kind, *rest = entry["cache"]
    if kind == "full":
        row = rest[0] if shared_row is None else shared_row
        ck, cv = cache["k"][row], cache["v"][row]
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, pos, 0, 0))
        o = decode_attention(q, ck, cv, pos)
        upd = {"k": (row, ck), "v": (row, cv)}
    else:
        row, w = rest
        slot = jnp.mod(pos, w)
        ck, cv = cache["k_ring"][row], cache["v_ring"][row]
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
        o = decode_attention(q, ck, cv, jnp.minimum(pos, w - 1))
        upd = {"k_ring": (row, ck), "v_ring": (row, cv)}
    return jnp.einsum("bshk,hkd->bsd", o, lp["wo"]), upd


def _segments(plan, shared_at=()):
    """Group consecutive layers with identical (kind, cache-kind, window,
    theta) into scannable segments, breaking after shared-attention
    application points.  Returns [(sig, [indices], entry)]."""
    segs = []
    breaks = set(shared_at)
    prev_broke = True
    for i, e in enumerate(plan):
        sig = (e["kind"], e.get("cache", ("ssm",))[0],
               e.get("cache", (None, None, 0))[2]
               if e.get("cache", ("", 0))[0] == "ring" else 0,
               e["theta"])
        if segs and segs[-1][0] == sig and not prev_broke:
            segs[-1][1].append(i)
        else:
            segs.append((sig, [i], e))
        prev_broke = i in breaks
    return segs


def _decode_layer_body(x, lp, ck, cv, cfg, ctx, pos, *, kind, cache_kind,
                       window, theta):
    """One decode layer (works per-row inside a scan).  Returns
    (x, new_ck, new_cv)."""
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    b = x.shape[0]
    positions = jnp.broadcast_to(pos[None], (b, 1)).astype(jnp.int32)
    q, k, v = _proj_qkv(h, lp, cfg, positions, theta)
    if cache_kind == "full":
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, pos, 0, 0))
        o = decode_attention(q, ck, cv, pos)
    else:
        w = window
        slot = jnp.mod(pos, w)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, slot, 0, 0))
        o = decode_attention(q, ck, cv, jnp.minimum(pos, w - 1))
    x = x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if kind == "moe":
        moe_p = {"router": lp["router"], "gate": lp["e_gate"],
                 "up": lp["e_up"], "down": lp["e_down"]}
        m = moe_block(h, moe_p, k=cfg.experts_per_token,
                      n_experts=cfg.n_experts,
                      capacity_factor=cfg.capacity_factor,
                      mesh=ctx.mesh, data_axes=ctx.dp,
                      model_axis=ctx.tp, fsdp=False)
    else:
        m = mlp_block(h, lp)
    return x + m, ck, cv


def decode_step(params, cfg: ModelConfig, ctx: ShardCtx, token, cache, pos):
    """token: (b, 1) int32; pos: scalar int32.  Returns (logits, cache).

    Lowered as one lax.scan per homogeneous layer segment (dense archs:
    a single scan; gemma3: alternating local/global segments; hybrids:
    mamba segments + unrolled weight-tied shared attention) so decode
    compiles stay small at 512-way SPMD."""
    plan, meta = layer_plan(cfg)
    x = params["tok_embed"][token]                      # (b, 1, d)
    new_cache = dict(cache)
    shared_seen = 0

    for sig, idxs, entry in _segments(plan, meta["shared_at"]):
        kind, cache_kind, window, theta = sig
        i0, i1 = idxs[0], idxs[-1] + 1
        seg_params = jax.tree.map(lambda a: a[i0:i1], params["layers"])
        if kind in ("attn", "moe"):
            ckey, vkey = ("k", "v") if cache_kind == "full" else \
                ("k_ring", "v_ring")
            r0 = plan[i0]["cache"][1]
            r1 = r0 + len(idxs)

            def body(xc, xs):
                lp, ck, cv = xs
                xc, ck, cv = _decode_layer_body(
                    xc, lp, ck, cv, cfg, ctx, pos, kind=kind,
                    cache_kind=cache_kind, window=window, theta=theta)
                return xc, (ck, cv)

            x, (cks, cvs) = jax.lax.scan(
                body, x, (seg_params, new_cache[ckey][r0:r1],
                          new_cache[vkey][r0:r1]))
            new_cache[ckey] = new_cache[ckey].at[r0:r1].set(cks)
            new_cache[vkey] = new_cache[vkey].at[r0:r1].set(cvs)
        else:                                            # mamba segment
            r0 = plan[i0]["ssm_row"]
            r1 = r0 + len(idxs)
            blk = mam.mamba2_block if kind == "mamba2" else mam.mamba1_block

            def mbody(xc, xs):
                lp, hs, cc = xs
                h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
                y, (hs2, cc2) = blk(h[:, 0], lp, cfg, h0=hs, conv0=cc,
                                    single_step=True)
                return xc + y[:, None], (hs2, cc2.astype(cc.dtype))

            x, (hss, ccs) = jax.lax.scan(
                mbody, x, (seg_params, new_cache["ssm"][r0:r1],
                           new_cache["conv"][r0:r1]))
            new_cache["ssm"] = new_cache["ssm"].at[r0:r1].set(hss)
            new_cache["conv"] = new_cache["conv"].at[r0:r1].set(ccs)

        # hybrid: weight-tied shared attention after every k-th layer
        if (i1 - 1) in meta["shared_at"]:
            sh = params["shared"]
            hh = rms_norm(x, sh["ln1"], cfg.norm_eps)
            entry_s = {"theta": cfg.rope_theta,
                       "cache": ("full", meta["full"] + shared_seen)}
            a, upd = _decode_attn(hh, sh, cfg, ctx, new_cache, entry_s, pos)
            for key, (row, arr) in upd.items():
                new_cache[key] = jax.lax.dynamic_update_slice(
                    new_cache[key], arr[None], (row,) + (0,) * arr.ndim)
            x = x + a
            hh = rms_norm(x, sh["ln2"], cfg.norm_eps)
            x = x + mlp_block(hh, sh)
            shared_seen += 1

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _project_logits(x, params, cfg)
    return logits[:, 0], new_cache
