"""Top-k MoE with capacity-based, sort-free dispatch.

Sharding strategy (TPU-native adaptation, see DESIGN.md §2):
  * activations are sharded over the data axes and *replicated* over the
    model axis (standard replicated-activation TP),
  * expert weights are sharded E -> model axis (expert parallelism) and
    optionally d_ff -> data axis (FSDP),
  * under ``shard_map`` every device routes its data-shard's tokens to the
    experts it owns — no all-to-all is needed; the per-token combine is a
    single psum over the model axis (same bytes as one TP all-reduce).

Dispatch is one-hot + cumsum (no sort): slot-within-expert comes from an
exclusive running count, tokens beyond capacity are dropped (standard
capacity semantics; tests use capacity_factor=8 to compare exactly against
the dense oracle).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import silu


def router_topk(x, w_router, k: int):
    """Softmax-normalised top-k gates.  x: (T, d) -> (T, k) ids + gates."""
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    gates_all = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(gates_all, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return ids.astype(jnp.int32), gates


def _capacity(n_tokens: int, k: int, n_experts: int, cf: float) -> int:
    return max(4, int(-(-n_tokens * k * cf // n_experts)))


def moe_apply_local(
    x,                       # (T, d) tokens owned by this shard
    w_router,                # (d, E) replicated
    w_gate, w_up,            # (E_loc, d, f)
    w_down,                  # (E_loc, f, d)
    *,
    k: int,
    n_experts: int,          # global E
    expert_offset,           # first expert id owned by this shard
    capacity_factor: float,
    f32_combine: bool = True,
    gather_dispatch: bool = False,
) -> jax.Array:
    """Routes all local tokens, computes only the local expert slice.

    Returns this shard's partial output (T, d); summing partials over all
    expert shards yields the full MoE output.

    ``gather_dispatch`` (§Perf): scatter token *indices* into the capacity
    buffer and gather activations, instead of materialising the k-times
    repeated activations and scatter-adding them — HBM traffic for the
    dispatch drops from O(T*k*d) to O(E*cap*d).
    """
    t, d = x.shape
    e_loc = w_gate.shape[0]
    cap = _capacity(t, k, n_experts, capacity_factor)

    ids, gates = router_topk(x, w_router, k)          # (T, k)
    flat_ids = ids.reshape(-1)                        # (T*k,)
    flat_gates = gates.reshape(-1)

    local_ids = flat_ids - expert_offset              # (T*k,) may be out of range
    onehot = jax.nn.one_hot(local_ids, e_loc, dtype=jnp.int32)   # 0 rows if not ours
    # exclusive running count of the *assigned* expert -> slot within expert
    excl = jnp.cumsum(onehot, axis=0) - onehot                   # (T*k, E_loc)
    slot = (excl * onehot).sum(axis=-1)                          # (T*k,)
    mine = (local_ids >= 0) & (local_ids < e_loc)
    keep = mine & (slot < cap)

    safe_e = jnp.where(keep, local_ids, 0)
    safe_s = jnp.where(keep, slot, 0)

    if gather_dispatch:
        sentinel = t * k
        flat_pos = jnp.where(keep, jnp.arange(t * k, dtype=jnp.int32),
                             sentinel)
        pos_buf = jnp.full((e_loc, cap), sentinel, jnp.int32)
        pos_buf = pos_buf.at[safe_e, safe_s].min(flat_pos, mode="drop")
        valid = pos_buf < sentinel
        tok_idx = jnp.minimum(pos_buf // k, t - 1)
        buf = jnp.where(valid[..., None], x[tok_idx], 0)
    else:
        xk = jnp.repeat(x, k, axis=0)                 # (T*k, d)
        contrib = jnp.where(keep[:, None], xk, 0)
        buf = jnp.zeros((e_loc, cap, d), x.dtype)
        buf = buf.at[safe_e, safe_s].add(contrib, mode="drop")

    h = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    y_buf = jnp.einsum("ecf,efd->ecd", silu(h) * u, w_down)      # (E_loc, cap, d)

    y_rows = y_buf[safe_e, safe_s]                    # (T*k, d)
    y_rows = jnp.where(keep[:, None], y_rows, 0)
    if f32_combine:
        # baseline: materialises an fp32 (T*k, d) tensor (and fp32
        # cotangents through the MoE) — §Perf iteration 1 removes this
        y = (y_rows.astype(jnp.float32) * flat_gates[:, None]) \
            .reshape(t, k, d).sum(1)
    else:
        y = jnp.einsum("tkd,tk->td", y_rows.reshape(t, k, d),
                       flat_gates.reshape(t, k).astype(y_rows.dtype),
                       preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def moe_block(
    x,                       # (B, S, d) global
    params,                  # dict: router (d,E), gate/up (E,d,f), down (E,f,d)
    *,
    k: int,
    n_experts: int,
    capacity_factor: float,
    mesh: Optional[jax.sharding.Mesh] = None,
    data_axes: Sequence[str] = (),
    model_axis: str = "model",
    fsdp: bool = False,
    f32_combine: bool = True,
    gather_dispatch: bool = False,
) -> jax.Array:
    """MoE layer.  With a mesh, runs the shard_map EP path; otherwise local."""
    b, s, d = x.shape

    if mesh is None:
        y = moe_apply_local(
            x.reshape(-1, d), params["router"], params["gate"], params["up"],
            params["down"], k=k, n_experts=n_experts, expert_offset=0,
            capacity_factor=capacity_factor, f32_combine=f32_combine,
            gather_dispatch=gather_dispatch)
        return y.reshape(b, s, d)

    da = tuple(data_axes)
    ma = model_axis
    n_model = mesh.shape[ma]
    # pad the expert dim to the EP axis (granite: 40 -> 48); the router never
    # routes to padded slots, so the math is exact and the published param
    # count is untouched.
    e_pad = -(-n_experts // n_model) * n_model
    w_gate, w_up, w_down = params["gate"], params["up"], params["down"]
    if e_pad != n_experts:
        pad = e_pad - n_experts
        zpad = lambda w: jnp.concatenate(
            [w, jnp.zeros((pad,) + w.shape[1:], w.dtype)], axis=0)
        w_gate, w_up, w_down = zpad(w_gate), zpad(w_up), zpad(w_down)
    e_per = e_pad // n_model

    x_spec = P(da, None, None)
    w3_spec = P(ma, None, da if fsdp else None)      # (E, d, f)
    wd_spec = P(ma, da if fsdp else None, None)      # (E, f, d)

    def body(x_loc, w_router, w_gate, w_up, w_down):
        if fsdp:
            for ax in reversed(da):
                w_gate = jax.lax.all_gather(w_gate, ax, axis=2, tiled=True)
                w_up = jax.lax.all_gather(w_up, ax, axis=2, tiled=True)
                w_down = jax.lax.all_gather(w_down, ax, axis=1, tiled=True)
        my = jax.lax.axis_index(ma) * e_per
        bl, sl, _ = x_loc.shape
        y = moe_apply_local(
            x_loc.reshape(-1, d), w_router, w_gate, w_up, w_down,
            k=k, n_experts=n_experts, expert_offset=my,
            capacity_factor=capacity_factor, f32_combine=f32_combine,
            gather_dispatch=gather_dispatch)
        y = jax.lax.psum(y, ma)
        return y.reshape(bl, sl, d)

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), w3_spec, w3_spec, wd_spec),
        out_specs=x_spec,
        check_vma=False,
    )
    return fn(x, params["router"], w_gate, w_up, w_down)
