"""Sharding policy: logical partition specs for params/activations.

Rules (DESIGN.md §2, §5):
  * TP ("model" axis): attention heads (fall back to head_dim when the head
    count does not divide the axis — every assigned arch has head_dim % 16
    == 0), MLP hidden, expert dim, vocab.
  * FSDP ("data" axis, never "pod" — cross-pod all-gathers would ride the
    slow DCN): the d_model-ish dim of each weight.
  * Activations: batch over ("pod","data"); residual stream replicated over
    "model".
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Optional[Mesh] = None
    dp: Tuple[str, ...] = ()          # batch axes, e.g. ("pod", "data")
    tp: str = ""                      # model axis
    fsdp: Tuple[str, ...] = ()        # weight-shard axes (subset of dp)

    @property
    def active(self) -> bool:
        return self.mesh is not None

    def n(self, axis: str) -> int:
        return self.mesh.shape[axis] if self.mesh else 1

    def constrain(self, x, spec: P):
        if not self.active:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


def _div(dim: int, ctx: ShardCtx, axis) -> bool:
    if not ctx.active or not axis:
        return False
    ns = ctx.n(axis) if isinstance(axis, str) else 1
    if not isinstance(axis, str):
        for a in axis:
            ns *= ctx.n(a)
    return dim % ns == 0


def head_specs(ctx: ShardCtx, n_heads: int, head_dim: int, layer_stacked: bool):
    """(wq-like) (L, d, H, hd): put 'model' on H when it divides the axis.

    When it does not (granite 24H, qwen1.5 20H on a 16-way axis) the heads
    stay REPLICATED over the model axis: sharding head_dim instead would
    turn every attention score block into a model-axis all-reduce
    (contraction over hd), which dominates the step.  Head-padding is the
    beyond-paper alternative evaluated in EXPERIMENTS.md §Perf."""
    lead = (None,) if layer_stacked else ()
    f = ctx.fsdp if ctx.fsdp else None
    if _div(n_heads, ctx, ctx.tp):
        return P(*lead, f, ctx.tp, None), P(*lead, ctx.tp, None, f)   # in-proj, out-proj
    return P(*lead, f, None, None), P(*lead, None, None, f)


def param_spec(name: str, shape, cfg, ctx: ShardCtx) -> P:
    """Partition spec for one named parameter (leaf names are unique)."""
    if not ctx.active:
        return P()
    t, f = ctx.tp, (ctx.fsdp if ctx.fsdp else None)
    L = (None,)  # stacked-layer leading dim
    nm = ctx.n(t)
    hs_in, hs_out = head_specs(ctx, cfg.n_heads or 1, cfg.hd or 1, True)

    table = {
        # embeddings / head (padded_vocab always divides the model axis)
        "tok_embed": P(t, f),
        "lm_head": P(f, t),
        "final_norm": P(None),
        # attention (stacked)
        "wq": hs_in, "wk": hs_in, "wv": hs_in, "wo": hs_out,
        "bq": P(*L, None, None), "bk": P(*L, None, None), "bv": P(*L, None, None),
        "ln1": P(*L, None), "ln2": P(*L, None),
        # dense mlp
        "gate": P(*L, f, t), "up": P(*L, f, t), "down": P(*L, t, f),
        # moe
        "router": P(*L, None, None),
        "e_gate": P(*L, t, None, f), "e_up": P(*L, t, None, f),
        "e_down": P(*L, t, f, None),
        # mamba
        "in_proj": P(*L, f, t), "out_proj": P(*L, t, f),
        "conv_w": P(*L, None, t), "conv_b": P(*L, t),
        "x_proj": P(*L, t, None), "dt_w": P(*L, None, t), "dt_bias": P(*L, t),
        "A_log": P(*L, t, None) if name == "A_log" and len(shape) == 3 else P(*L, t),
        "D": P(*L, t), "norm_w": P(*L, t),
    }
    if name in table:
        spec = table[name]
        # trim/pad to rank
        parts = list(spec)
        if len(parts) > len(shape):
            parts = parts[len(parts) - len(shape):]
        while len(parts) < len(shape):
            parts.append(None)
        # drop axes that do not divide
        clean = []
        for dim, ax in zip(shape, parts):
            if ax is None:
                clean.append(None)
            else:
                axes = (ax,) if isinstance(ax, str) else tuple(ax)
                n = 1
                for a in axes:
                    n *= ctx.n(a)
                clean.append(ax if dim % n == 0 else None)
        return P(*clean)
    return P(*([None] * len(shape)))


def tree_pspecs(params, cfg, ctx: ShardCtx):
    """Map leaf name -> PartitionSpec across an arbitrarily nested dict."""
    def walk(node, prefix):
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        return param_spec(prefix, node.shape, cfg, ctx)
    return walk(params, "")


def tree_shardings(params, cfg, ctx: ShardCtx):
    specs = tree_pspecs(params, cfg, ctx)
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
