"""Decoder stack covering dense / MoE / SSM / hybrid families.

Training & prefill lower as a single ``lax.scan`` over stacked layer
parameters (+ per-layer remat), keeping the HLO compact enough to compile
512-way GSPMD partitions.  Decode is an unrolled loop so heterogeneous
per-layer caches (full KV vs ring-buffer vs SSM state) stay exact.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import mamba as mam
from .attention import chunked_attention
from .config import ModelConfig
from .layers import apply_rope, dense_init, ones, rms_norm, swiglu, zeros
from .moe import moe_block
from .sharding import ShardCtx


# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig) -> List[Dict[str, Any]]:
    """Static per-layer description (kind, cache slot, window, theta)."""
    plan = []
    full_rows = ring_rows = ssm_rows = 0
    for i in range(cfg.n_layers):
        if cfg.family == "ssm" or (cfg.family == "hybrid"):
            kind = cfg.ssm_variant or "mamba1"
            entry = {"kind": kind, "ssm_row": ssm_rows, "window": 0, "theta": cfg.rope_theta}
            ssm_rows += 1
        elif cfg.family == "moe":
            entry = {"kind": "moe", "window": cfg.layer_window(i),
                     "theta": cfg.rope_theta}
        else:
            entry = {"kind": "attn", "window": cfg.layer_window(i),
                     "theta": cfg.rope_theta}
        if entry["kind"] in ("attn", "moe"):
            if cfg.local_global_period and cfg.layer_is_global_attn(i) and cfg.rope_theta_global:
                entry["theta"] = cfg.rope_theta_global
            if entry["window"] > 0:
                entry["cache"] = ("ring", ring_rows, entry["window"])
                ring_rows += 1
            else:
                entry["cache"] = ("full", full_rows)
                full_rows += 1
        plan.append(entry)
    # zamba2-style shared attention applications
    shared_at = []
    if cfg.hybrid_attn_period:
        shared_at = [i for i in range(cfg.n_layers)
                     if i % cfg.hybrid_attn_period == cfg.hybrid_attn_period - 1]
    return plan, {"full": full_rows, "ring": ring_rows, "ssm": ssm_rows,
                  "shared_at": shared_at}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _attn_init(key, cfg: ModelConfig, n: int, dtype):
    ks = jax.random.split(key, 8)
    h, kv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model
    p = {
        "wq": dense_init(ks[0], d, h * hd, n=n, dtype=dtype).reshape((n, d, h, hd) if n else (d, h, hd)),
        "wk": dense_init(ks[1], d, kv * hd, n=n, dtype=dtype).reshape((n, d, kv, hd) if n else (d, kv, hd)),
        "wv": dense_init(ks[2], d, kv * hd, n=n, dtype=dtype).reshape((n, d, kv, hd) if n else (d, kv, hd)),
        "wo": dense_init(ks[3], h * hd, d, n=n, dtype=dtype).reshape((n, h, hd, d) if n else (h, hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((n, h, hd) if n else (h, hd), dtype)
        p["bk"] = zeros((n, kv, hd) if n else (kv, hd), dtype)
        p["bv"] = zeros((n, kv, hd) if n else (kv, hd), dtype)
    return p


def _mlp_init(key, cfg: ModelConfig, n: int, dtype):
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {"gate": dense_init(ks[0], d, f, n=n, dtype=dtype),
            "up": dense_init(ks[1], d, f, n=n, dtype=dtype),
            "down": dense_init(ks[2], f, d, n=n, dtype=dtype)}


def _moe_init(key, cfg: ModelConfig, n: int, dtype):
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    shape3 = lambda a: (n, e) + a if n else (e,) + a
    import numpy as _np
    def einit(k, din, dout):
        x = jax.random.normal(k, shape3((din, dout)), jnp.float32)
        return (x / _np.sqrt(din)).astype(dtype)
    return {"router": dense_init(ks[0], d, e, n=n, dtype=jnp.float32),
            "e_gate": einit(ks[1], d, f), "e_up": einit(ks[2], d, f),
            "e_down": einit(ks[3], f, d)}


def _mamba_init(key, cfg: ModelConfig, n: int, dtype):
    ks = jax.random.split(key, 10)
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    W = cfg.ssm_conv
    lead = (n,) if n else ()
    if cfg.ssm_variant == "mamba2":
        nh = cfg.n_ssm_heads
        conv_dim = di + 2 * N
        return {
            "in_proj": dense_init(ks[0], d, 2 * di + 2 * N + nh, n=n, dtype=dtype),
            "conv_w": dense_init(ks[1], W, conv_dim, n=n, dtype=dtype),
            "conv_b": zeros(lead + (conv_dim,), dtype),
            "A_log": jnp.broadcast_to(jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)), lead + (nh,)),
            "D": ones(lead + (nh,), jnp.float32),
            "dt_bias": zeros(lead + (nh,), jnp.float32),
            "norm_w": ones(lead + (di,), dtype),
            "out_proj": dense_init(ks[2], di, d, n=n, dtype=dtype),
        }
    dtr = cfg.dt_rank
    a0 = jnp.broadcast_to(jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32)), lead + (di, N))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, n=n, dtype=dtype),
        "conv_w": dense_init(ks[1], W, di, n=n, dtype=dtype),
        "conv_b": zeros(lead + (di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * N, n=n, dtype=dtype),
        "dt_w": dense_init(ks[3], dtr, di, n=n, dtype=dtype),
        "dt_bias": zeros(lead + (di,), jnp.float32),
        "A_log": a0,
        "D": ones(lead + (di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, n=n, dtype=dtype),
    }


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    n = cfg.n_layers
    vp = cfg.padded_vocab
    vmask = (jnp.arange(vp) < cfg.vocab_size)
    params: Dict[str, Any] = {
        "tok_embed": dense_init(keys[0], vp, cfg.d_model, dtype=dtype)
        * vmask[:, None].astype(dtype),
        "final_norm": ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, vp, dtype=dtype) \
            * vmask[None, :].astype(dtype)

    layers: Dict[str, Any] = {"ln1": ones((n, cfg.d_model), dtype)}
    if cfg.family in ("dense", "vlm", "audio"):
        layers.update(_attn_init(keys[2], cfg, n, dtype))
        layers["ln2"] = ones((n, cfg.d_model), dtype)
        layers.update(_mlp_init(keys[3], cfg, n, dtype))
    elif cfg.family == "moe":
        layers.update(_attn_init(keys[2], cfg, n, dtype))
        layers["ln2"] = ones((n, cfg.d_model), dtype)
        layers.update(_moe_init(keys[3], cfg, n, dtype))
    elif cfg.family in ("ssm", "hybrid"):
        layers.update(_mamba_init(keys[2], cfg, n, dtype))
    params["layers"] = layers

    if cfg.hybrid_attn_period:
        shared = {"ln1": ones((cfg.d_model,), dtype)}
        shared.update(_attn_init(keys[4], cfg, 0, dtype))
        shared["ln2"] = ones((cfg.d_model,), dtype)
        shared.update(_mlp_init(keys[5], cfg, 0, dtype))
        params["shared"] = shared
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _proj_qkv(x, p, cfg, positions, theta):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def attn_block(x, p, cfg, ctx: ShardCtx, positions, window, theta):
    """Full-sequence attention.  Returns (out, (k, v)) for cache capture.

    When the head count divides the model axis, heads are TP-sharded by
    weight-sharding propagation.  Otherwise (granite 24H, qwen1.5 20H on a
    16-way axis) the q-block dim is sharded over the model axis instead —
    sequence/context parallelism with replicated KV."""
    q, k, v = _proj_qkv(x, p, cfg, positions, theta)
    bc = None
    min_blocks = 1
    if ctx.active and cfg.n_heads % ctx.n(ctx.tp) != 0:
        n_model = ctx.n(ctx.tp)
        if q.shape[1] % n_model == 0 and q.shape[1] >= n_model:
            min_blocks = n_model

            def bc(t, dim):
                spec = [None] * t.ndim
                spec[0] = ctx.dp if ctx.dp else None
                spec[dim] = ctx.tp
                return ctx.constrain(t, P(*spec))
    o = chunked_attention(q, k, v, causal=True, window=window,
                          min_q_blocks=min_blocks, block_constrain=bc)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (k, v)


def mlp_block(x, p):
    return swiglu(x, p["gate"], p["up"], p["down"])


def shared_attn_apply(x, shared, cfg, ctx, positions, theta):
    h = rms_norm(x, shared["ln1"], cfg.norm_eps)
    a, _ = attn_block(h, shared, cfg, ctx, positions, 0, theta)
    x = x + a
    h = rms_norm(x, shared["ln2"], cfg.norm_eps)
    return x + mlp_block(h, shared)


# ---------------------------------------------------------------------------
# forward (train / prefill): scan over layers
# ---------------------------------------------------------------------------

def _residual_spec(ctx: ShardCtx, cfg=None) -> P:
    if cfg is not None and cfg.seq_shard_residuals:
        # Megatron-style sequence parallelism for the residual stream /
        # saved layer-boundary activations (§Perf): GSPMD inserts
        # all-gather at QKV and reduce-scatter after the out-projections
        return P(ctx.dp if ctx.dp else None, ctx.tp, None)
    return P(ctx.dp if ctx.dp else None, None, None)


def _layer_body(cfg: ModelConfig, ctx: ShardCtx, collect_cache: bool):
    """Returns body(x, (lp, window, theta, positions)) -> (x, cache_ys)."""

    def body(x, lp, window, theta, positions):
        x = ctx.constrain(x, _residual_spec(ctx, cfg))
        kind = cfg.family
        cache_ys = ()
        if kind in ("dense", "vlm", "audio", "moe"):
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            a, kv_cache = attn_block(h, lp, cfg, ctx, positions, window, theta)
            x = x + a
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if kind == "moe":
                moe_p = {"router": lp["router"], "gate": lp["e_gate"],
                         "up": lp["e_up"], "down": lp["e_down"]}
                m = moe_block(h, moe_p, k=cfg.experts_per_token,
                              n_experts=cfg.n_experts,
                              capacity_factor=cfg.capacity_factor,
                              mesh=ctx.mesh, data_axes=ctx.dp,
                              model_axis=ctx.tp, fsdp=bool(ctx.fsdp),
                              f32_combine=cfg.moe_combine_f32_materialize,
                              gather_dispatch=cfg.moe_gather_dispatch)
            else:
                m = mlp_block(h, lp)
            x = x + m
            if collect_cache:
                cache_ys = kv_cache
        else:  # ssm / hybrid scanned layers are mamba blocks
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            blk = mam.mamba2_block if cfg.ssm_variant == "mamba2" else mam.mamba1_block
            y, (hstate, conv_tail) = blk(h, lp, cfg)
            x = x + y
            if collect_cache:
                cache_ys = (hstate, conv_tail)
        return x, cache_ys

    return body


def _window_theta_arrays(cfg: ModelConfig):
    plan, _ = layer_plan(cfg)
    win = np.array([e["window"] for e in plan], np.int32)
    th = np.array([e["theta"] for e in plan], np.float32)
    return jnp.asarray(win), jnp.asarray(th)


def run_stack(x, params, cfg: ModelConfig, ctx: ShardCtx, positions,
              collect_cache: bool = False):
    """x: (b, s, d) -> (b, s, d) [, stacked per-layer cache]."""
    body = _layer_body(cfg, ctx, collect_cache)
    win, th = _window_theta_arrays(cfg)
    _, meta = layer_plan(cfg)

    def scan_fn(carry, xs):
        lp, w, t = xs
        fn = body
        if cfg.remat:
            fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        return fn(carry, lp, w, t, positions)

    if cfg.scan_layers and not meta["shared_at"]:
        x, caches = jax.lax.scan(scan_fn, x, (params["layers"], win, th))
        return x, caches

    if meta["shared_at"]:
        # hybrid: segment the scan at shared-attention points so the shared
        # block runs between scans (keeps scanned body homogeneous).
        period = cfg.hybrid_attn_period
        caches, shared_kv = [], []
        i = 0
        while i < cfg.n_layers:
            j = min(i + period, cfg.n_layers)
            seg = jax.tree.map(lambda a: a[i:j], params["layers"])
            x, c = jax.lax.scan(scan_fn, x, (seg, win[i:j], th[i:j]))
            caches.append(c)
            if (j - 1) in meta["shared_at"]:
                hq = rms_norm(x, params["shared"]["ln1"], cfg.norm_eps)
                a, kv = attn_block(hq, params["shared"], cfg, ctx, positions,
                                   0, cfg.rope_theta)
                x = x + a
                hq = rms_norm(x, params["shared"]["ln2"], cfg.norm_eps)
                x = x + mlp_block(hq, params["shared"])
                if collect_cache:
                    shared_kv.append(kv)
            i = j
        if collect_cache:
            cat = lambda *xs: jnp.concatenate(xs, 0)
            caches = jax.tree.map(cat, *caches) if len(caches) > 1 else caches[0]
            sk = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *shared_kv) if shared_kv else ()
            return x, (caches, sk)
        return x, ()

    # unrolled (small configs / debugging)
    caches = []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        x, c = body(x, lp, win[i], th[i], positions)
        caches.append(c)
    if collect_cache and caches and caches[0] != ():
        caches = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *caches)
    return x, caches
