"""Functional AdamW with optional ZeRO-1-style state sharding.

State (m, v in fp32) inherits the parameters' partition specs, so with FSDP
sharding rules the optimizer state is already ZeRO-sharded over the data
axis; update math runs in fp32 and casts back to the param dtype.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(jnp.zeros((), jnp.int32), zeros,
                          jax.tree.map(jnp.copy, zeros))

    def _lr(self, step):
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)

    def update(self, grads, state: AdamWState, params):
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.grad_clip > 0:
            norm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(g32)))  # repro: noqa DET004 -- fold order is the treedef's leaf order, fixed for a given model; the whole expression compiles into one jitted graph
            scale = jnp.minimum(1.0, self.grad_clip / (norm + 1e-9))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr = self._lr(step)
        bc1 = 1.0 - self.b1 ** t
        bc2 = 1.0 - self.b2 ** t

        def upd(p, g, m, v):
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, g32, state.m, state.v)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, AdamWState(step, new_m, new_v)


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor_frac: float = 0.1) -> Callable:
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac) * 0.5 *
                      (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup, warm, cos)
    return lr
