"""PowerSGD-style low-rank gradient compression with error feedback.

Distributed-optimization trick for the DP all-reduce (lineage: the paper's
group's Optimus-CC [15] compresses 3D-parallel training communication).
Matrix-shaped gradient blocks are factored G ~= P Q^T (rank r) so the DP
all-reduce moves r(m+n) instead of m*n values; the residual is fed back
into the next step so the compression error stays bounded.

Under pjit the all-reduce itself is implicit (GSPMD inserts it for the
mean over the data axis); compressing BEFORE that reduction shrinks
exactly those collectives.  ``compress_grads``/``decompress_grads`` are
pure so they compose with any optimizer.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class PowerSGD:
    rank: int = 4
    min_compress_size: int = 65536   # small tensors ride uncompressed

    def _eligible(self, g: jax.Array) -> bool:
        return g.ndim >= 2 and g.size >= self.min_compress_size

    def init_error(self, params) -> Any:
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32)
            if self._eligible(p) else jnp.zeros((), jnp.float32), params)

    def compress(self, grads, errors, key) -> Tuple[Any, Any]:
        """Returns (compressed_or_raw tree, new_errors)."""
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(errors)
        keys = jax.random.split(key, len(flat_g))
        out_g, out_e = [], []
        for g, e, k in zip(flat_g, flat_e, keys):
            if not self._eligible(g):
                out_g.append(g)
                out_e.append(e)
                continue
            m = g.reshape(g.shape[0], -1).astype(jnp.float32)
            if e.ndim:
                m = m + e.reshape(m.shape)
            r = min(self.rank, *m.shape)
            q = jax.random.normal(k, (m.shape[1], r), jnp.float32)
            p = m @ q                                  # (rows, r)
            p, _ = jnp.linalg.qr(p)                    # orthonormal basis
            qt = m.T @ p                               # (cols, r)
            approx = p @ qt.T
            out_g.append((p, qt, g.shape, g.dtype))
            out_e.append((m - approx).reshape(g.shape))
        return jax.tree.unflatten(treedef, out_g), \
            jax.tree.unflatten(treedef, out_e)

    def decompress(self, compressed) -> Any:
        def dec(leaf):
            if isinstance(leaf, tuple) and len(leaf) == 4:
                p, qt, shape, dtype = leaf
                return (p @ qt.T).reshape(shape).astype(dtype)
            return leaf
        return jax.tree.map(dec, compressed,
                            is_leaf=lambda l: isinstance(l, tuple) and len(l) == 4)

    def roundtrip(self, grads, errors, key):
        """compress -> decompress with error feedback; returns
        (approx_grads, new_errors).  The compressed factors are what the
        DP all-reduce would carry."""
        comp, new_e = self.compress(grads, errors, key)
        return self.decompress(comp), new_e

    def compression_ratio(self, params) -> float:
        full = comp = 0
        for p in jax.tree.leaves(params):
            full += p.size
            if self._eligible(p):
                m = p.reshape(p.shape[0], -1)
                r = min(self.rank, *m.shape)
                comp += r * (m.shape[0] + m.shape[1])
            else:
                comp += p.size
        return full / max(comp, 1)
