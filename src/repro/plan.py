"""Plan CLI: build and inspect serializable configurator Plan artifacts.

    # search a named model config on a simulated cluster, write the Plan
    python -m repro.plan plan --config qwen2-7b --reduced \
        --cluster mid-range --nodes 2 --seq 128 --bs-global 64 \
        -o plan.json

    # pretty-print a saved Plan (no search, no JAX compile)
    python -m repro.plan show plan.json

    # price the migration from one plan to another: ranks moved,
    # parameter/optimizer bytes re-fetched, estimated downtime
    python -m repro.plan diff a.json b.json

    # statically verify an artifact against a cluster — no re-search
    # (schema, conf arithmetic, 1F1B schedulability, mapping permutation,
    # memory floor, bandwidth/tier digests)
    python -m repro.plan lint plan.json --cluster mid-range --nodes 2

The emitted JSON is the same artifact ``Planner.plan`` produces in
process: byte-reproducible for a fixed request + seed (use ``--sa-iters``
with the default large ``--sa-seconds`` cap for iteration-bound,
deterministic SA), and consumable by ``launch.mesh.mesh_from_plan`` /
``runtime.trainer.TrainLoop(plan=...)`` without re-running the search.
"""
from __future__ import annotations

import argparse
import math
import sys

from repro import configs
from repro.core import (HIGH_END, MID_RANGE, MID_RANGE_DEGRADED,
                        MIXED_A100_V100, STRATEGIES, TPU_POD, Budget,
                        ExhaustiveStrategy, MegatronStrategy, Plan, Planner,
                        PlanRequest, PipetteStrategy, SearchSpace, Workload,
                        fit_memory_estimator, profile_bandwidth,
                        true_bandwidth_matrix)

CLUSTERS = {"mid-range": MID_RANGE, "high-end": HIGH_END,
            "tpu-pod": TPU_POD,
            "mixed-a100-v100": MIXED_A100_V100,
            "mid-range-degraded": MID_RANGE_DEGRADED}


def _fmt_bytes(x: float) -> str:
    return "-" if (x is None or math.isnan(x)) else f"{x / 1e9:.2f} GB"


def _fmt_ms(x: float) -> str:
    return "-" if (x is None or math.isinf(x)) else f"{x * 1e3:.2f} ms"


def cmd_plan(args: argparse.Namespace) -> int:
    cfg = configs.get(args.config)
    if args.reduced:
        cfg = cfg.reduced()
    spec = CLUSTERS[args.cluster]
    if args.nodes:
        spec = spec.with_nodes(args.nodes)
    w = Workload(cfg, args.seq, args.bs_global)
    bw, cost_s = profile_bandwidth(spec)
    print(f"[profile] {spec.name}: {spec.n_gpus} GPUs "
          f"(~{cost_s:.0f}s on a real cluster)", file=sys.stderr)

    estimator = None
    if args.fit_estimator and args.strategy not in ("pipette", "exhaustive"):
        # the baselines are memory-unaware by design: fitting would burn
        # minutes and then be silently discarded by the dispatch below
        print(f"error: --fit-estimator has no effect with "
              f"--strategy {args.strategy} (memory-unaware baseline); "
              f"drop the flag or use pipette/exhaustive", file=sys.stderr)
        return 2
    if args.fit_estimator:
        estimator = fit_memory_estimator(
            [w], spec, fit_nodes=min(2, spec.n_nodes),
            steps=args.fit_estimator, residual=True, max_cp=args.max_cp)
        print(f"[memest] MLP fit on <=2-node profiles "
              f"({args.fit_estimator} steps)", file=sys.stderr)

    # one registry (repro.core.plan.STRATEGIES) drives both the CLI
    # choices and the dispatch — only construction args differ per kind
    cls = STRATEGIES[args.strategy]
    if cls in (PipetteStrategy, ExhaustiveStrategy):
        # mem_floor == gpu_mem on homogeneous clusters; on tiered ones it
        # budgets for the tightest device tier
        strategy = cls(estimator=estimator, mem_limit=spec.mem_floor)
    elif cls is MegatronStrategy:
        # megatron-lm: trial runs happen on the ground-truth links
        strategy = cls(bw_true=true_bandwidth_matrix(spec))
    else:
        strategy = cls()

    req = PlanRequest(
        workload=w, spec=spec,
        space=SearchSpace(max_cp=args.max_cp, max_tp=args.max_tp,
                          max_micro=args.max_micro,
                          partition=args.partition, max_vpp=args.max_vpp),
        budget=Budget(sa_seconds=args.sa_seconds, sa_iters=args.sa_iters,
                      sa_topk=args.sa_topk),
        seed=args.seed)
    plan = Planner(strategy).plan(req, bw, keep_top=args.topk)
    if not plan.feasible:
        print(f"[plan] INFEASIBLE: {strategy.name} found no runnable "
              f"configuration for {spec.n_gpus} GPUs", file=sys.stderr)
        plan.save(args.output)      # still record the (empty) outcome
        return 1
    print(f"[plan] {strategy.name}: best {plan.conf} "
          f"est {_fmt_ms(plan.latency)}/iter "
          f"mem {_fmt_bytes(plan.mem_pred)}", file=sys.stderr)
    print(plan.save(args.output))
    return 0


def cmd_show(args: argparse.Namespace) -> int:
    plan = Plan.load(args.path)
    p = plan.provenance
    print(f"plan: strategy={p.strategy} seed={p.seed}")
    print(f"workload: {p.model} seq={p.seq} bs_global={p.bs_global}")
    print(f"cluster: {p.cluster} ({p.n_gpus} GPUs) "
          f"bw sha256:{p.bw_digest[:16]}…")
    print(f"space: max_cp={p.space.max_cp} max_tp={p.space.max_tp} "
          f"max_micro={p.space.max_micro} fixed_micro={p.space.fixed_micro} "
          f"partition={p.space.partition} max_vpp={p.space.max_vpp}")
    print(f"budget: sa_seconds={p.budget.sa_seconds} "
          f"sa_iters={p.budget.sa_iters} n_chains={p.budget.n_chains} "
          f"sa_topk={p.budget.sa_topk}")
    if p.tiers is not None:
        names = [t["name"] or f"tier{i}"
                 for i, t in enumerate(p.tiers["tiers"])]
        counts = [p.tiers["node_tiers"].count(i) for i in range(len(names))]
        mix = " + ".join(f"{c}x {n}" for n, c in zip(names, counts))
        print(f"tiers: {mix} (digest sha256:{p.tiers['digest'][:16]}…)")
    if p.estimator is None:
        print("estimator: none (memory-unaware)")
    else:
        e = p.estimator
        print(f"estimator: with_cp={e['with_cp']} residual={e['residual']} "
              f"fit_gpu_mem={e['fit_gpu_mem'] / 1e9:.0f}GB "
              f"fit_gpus_per_node={e['fit_gpus_per_node']}")
    o = plan.overhead
    print(f"search: {o.n_enumerated} enumerated -> "
          f"{o.n_candidates} candidates")
    if not plan.feasible:
        print("result: INFEASIBLE — no runnable configuration")
        return 1
    print(f"\nbest: {plan.conf}  est {_fmt_ms(plan.latency)}/iter  "
          f"mem {_fmt_bytes(plan.mem_pred)}")
    if plan.partition is not None or plan.schedule != "1f1b":
        sizes = ("uniform" if plan.partition is None else
                 ",".join(str(s) for s in plan.partition.sizes))
        print(f"schedule: {plan.schedule}  chunk layers: {sizes}")
    print("mapping (stages x workers/stage):")
    print(plan.mapping.reshape(plan.conf.pp, -1))
    print(f"\n{'#':>3s} {'config':30s} {'est/iter':>10s} {'mem':>10s}")
    for i, c in enumerate(plan.ranked):
        print(f"{i + 1:3d} {str(c.conf):30s} {_fmt_ms(c.latency):>10s} "
              f"{_fmt_bytes(c.mem_pred):>10s}")
    return 0


def cmd_diff(args: argparse.Namespace) -> int:
    import json

    plan_a = Plan.load(args.a)
    plan_b = Plan.load(args.b)
    cfg = None
    if args.config:
        cfg = configs.get(args.config)
        if args.reduced:
            cfg = cfg.reduced()
    try:
        d = plan_a.diff(plan_b, cfg=cfg,
                        inter_bw=args.inter_bw * 1e9,
                        restart_s=args.restart_s)
    except (KeyError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if args.format == "json":
        doc = {"ranks_total": d.ranks_total,
               "ranks_moved": d.ranks_moved,
               "ranks_added": d.ranks_added,
               "ranks_removed": d.ranks_removed,
               "bytes_migrated": d.bytes_migrated,
               "downtime_s": d.downtime_s,
               "conf_changed": d.conf_changed}
        print(json.dumps(doc, indent=2, sort_keys=True))
    else:
        print(f"migration {args.a} -> {args.b}:")
        print(f"  conf: {plan_a.conf} -> {plan_b.conf}"
              f"{'' if d.conf_changed else ' (unchanged)'}")
        print(f"  ranks: {d.ranks_total} total, {d.ranks_moved} moved, "
              f"{d.ranks_added} added, {d.ranks_removed} removed")
        print(f"  bytes migrated: {_fmt_bytes(d.bytes_migrated)}")
        print(f"  est downtime: {d.downtime_s:.2f} s"
              f"{' (no-op: resumes without a stall)' if d.is_noop else ''}")
    return 0


def cmd_lint(args: argparse.Namespace) -> int:
    # deliberately avoids Plan.load: the verifier diagnoses artifacts the
    # loader would refuse (unknown schema, malformed blocks)
    import json

    import numpy as np

    from repro.analysis import verify_plan_file

    spec = None
    if args.cluster:
        spec = CLUSTERS[args.cluster]
        if args.nodes:
            spec = spec.with_nodes(args.nodes)
    bw = np.load(args.bw) if args.bw else None
    issues = verify_plan_file(args.path, spec=spec, bw=bw)
    errors = [i for i in issues if i.severity == "error"]
    if args.format == "json":
        print(json.dumps([{"rule": i.rule, "severity": i.severity,
                           "where": i.where, "message": i.message}
                          for i in issues], indent=2, sort_keys=True))
    else:
        for i in issues:
            print(i)
        against = spec.name if spec is not None else "recorded provenance"
        verdict = ("FAIL — plan cannot execute as recorded"
                   if errors else "OK — static checks pass")
        print(f"[lint] {args.path} vs {against}: {len(errors)} error(s), "
              f"{sum(1 for i in issues if i.severity == 'warning')} "
              f"warning(s) -> {verdict}", file=sys.stderr)
    return 1 if errors else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.plan",
        description="Build / inspect serializable configurator plans.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("plan", help="run a strategy, write a Plan JSON")
    p.add_argument("--config", required=True,
                   help="model config name (repro.configs registry)")
    p.add_argument("--reduced", action="store_true",
                   help="use the tiny same-family smoke config")
    p.add_argument("--cluster", choices=sorted(CLUSTERS),
                   default="mid-range")
    p.add_argument("--nodes", type=int, default=0,
                   help="override the cluster's node count")
    p.add_argument("--seq", type=int, default=2048)
    p.add_argument("--bs-global", type=int, default=256)
    p.add_argument("--strategy", default="pipette",
                   choices=sorted(STRATEGIES))
    p.add_argument("--max-cp", type=int, default=1)
    p.add_argument("--max-tp", type=int, default=0)
    p.add_argument("--max-micro", type=int, default=16)
    p.add_argument("--partition", choices=("uniform", "dp"),
                   default="uniform",
                   help="layer-to-stage split: historical uniform, or the "
                        "balanced min-max DP over per-layer costs")
    p.add_argument("--max-vpp", type=int, default=1,
                   help="open interleaved-1F1B up to this many virtual "
                        "pipeline chunks per stage (1 = plain 1F1B only)")
    p.add_argument("--sa-seconds", type=float, default=60.0,
                   help="SA wall-clock cap per candidate (default large "
                        "so --sa-iters bounds it deterministically)")
    p.add_argument("--sa-iters", type=int, default=2000)
    p.add_argument("--sa-topk", type=int, default=None)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--topk", type=int, default=10,
                   help="ranked fallback candidates kept in the artifact")
    p.add_argument("--fit-estimator", type=int, default=0, metavar="STEPS",
                   help="fit the MLP memory estimator first (0 = skip; "
                        "memory-unaware search)")
    p.add_argument("-o", "--output", default="plan.json")
    p.set_defaults(fn=cmd_plan)

    s = sub.add_parser("show", help="pretty-print a saved Plan JSON")
    s.add_argument("path")
    s.set_defaults(fn=cmd_show)

    d = sub.add_parser(
        "diff", help="migration cost of switching plan A -> plan B "
                     "(ranks moved, bytes migrated, est downtime)")
    d.add_argument("a", help="incumbent Plan JSON")
    d.add_argument("b", help="successor Plan JSON")
    d.add_argument("--config", default=None,
                   help="model config name (default: resolve the plans' "
                        "recorded provenance.model from the registry)")
    d.add_argument("--reduced", action="store_true",
                   help="use the --config's reduced() smoke variant")
    d.add_argument("--inter-bw", type=float, default=12.5,
                   help="per-node inter-node bandwidth, GB/s "
                        "(default 12.5)")
    d.add_argument("--restart-s", type=float, default=None,
                   help="restart barrier seconds (default: model default)")
    d.add_argument("--format", choices=("text", "json"), default="text")
    d.set_defaults(fn=cmd_diff)

    v = sub.add_parser(
        "lint", help="statically verify a Plan JSON against a cluster "
                     "(no re-search; exit 1 on executability errors)")
    v.add_argument("path")
    v.add_argument("--cluster", choices=sorted(CLUSTERS), default=None,
                   help="check against this simulated cluster preset "
                        "(default: self-check against recorded provenance)")
    v.add_argument("--nodes", type=int, default=0,
                   help="override the preset's node count")
    v.add_argument("--bw", default=None, metavar="FILE.npy",
                   help="profiled bandwidth matrix to verify the "
                        "recorded digest against")
    v.add_argument("--format", choices=("text", "json"), default="text")
    v.set_defaults(fn=cmd_lint)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except BrokenPipeError:
        # stdout went away (e.g. `... | head`); exit quietly like a
        # well-behaved unix tool instead of tracebacking
        try:
            sys.stdout.close()
        except Exception:
            pass
        return 0


if __name__ == "__main__":
    raise SystemExit(main())
