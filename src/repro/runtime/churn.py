"""Trace-driven churn simulation: continuous replanning on a moving fleet.

Real clusters are not static: spot preemptions, node returns, degraded
links and straggling hosts arrive as a *stream*, and a configurator for
real-world clusters (the paper's premise) must be judged on how much
training it sustains across that stream — not on single-step latency at
one fleet snapshot.  This module provides the three pieces:

1. a **seeded, replayable trace**: :func:`generate_trace` draws
   preempt / return / degrade-link / straggler events from independent
   exponential arrival processes (in the style of the seeded
   ``degraded_host_spec`` fleet generators) into a :class:`ChurnTrace`
   whose canonical JSON round-trips byte-identically — the same seed is
   the same trace, forever;
2. a **fleet state machine**: :class:`FleetState` folds events into the
   effective cluster — surviving nodes keep their device tiers
   (:meth:`~repro.core.cluster.ClusterSpec.with_node_subset`), stragglers
   become compute tiers (:meth:`~repro.core.cluster.ClusterSpec.
   with_compute_factors`), degraded links scale the ground-truth
   bandwidth submatrix.  Nodes are ordered by *join time* (survivors
   first, returners appended), so an incumbent plan's GPU permutation
   projects onto the new fleet as a prefix — exactly the
   ``Budget.warm_start`` convention :func:`~repro.core.dedication.
   project_perm` implements;
3. a **replay scorer**: :func:`simulate_churn` replays a trace against a
   replanning policy (warm incremental vs from-scratch), measuring each
   segment's step time with the event-driven cluster simulator and
   charging each replan its migration downtime — the score is the
   **throughput integral** (samples processed over the whole trace).
   Reshard accounting is double-entry: the per-transition
   :class:`~repro.core.migration.PlanDiff` and an independent
   :class:`ResidentState` ledger (per-GPU resident shard identities keyed
   by *base* fleet ids, carried across the whole trace) must agree, and
   ``benchmarks/bench_churn.py`` gates CI on both that consistency and on
   warm-beats-cold.

CLI::

    python -m repro.runtime.churn --nodes 16 --seed 0 --horizon 1800
    python -m repro.runtime.churn --trace trace.json --policies warm,cold
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.cluster import ClusterSpec, MID_RANGE, true_bandwidth_matrix
from ..core.memory import rank_state_bytes
from ..core.migration import diff_assignments, state_keys
from ..core.plan import Plan
from ..core.search import Candidate
from ..core.simulator import (ProfileCache, Workload, mapping4,
                              simulate_iteration)
from .elastic import replan_on

EVENT_KINDS = ("preempt", "return", "degrade_link", "straggler")


# ---------------------------------------------------------------------------
# the event stream
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ChurnEvent:
    """One fleet event.

    Attributes:
        t: event time, seconds from trace start.
        kind: one of :data:`EVENT_KINDS`.  ``preempt`` takes ``node``
            down; ``return`` brings it back (state lost — a returning
            spot instance re-fetches its shard); ``degrade_link`` scales
            the ``node``/``peer`` inter-node links by ``factor``
            (``1.0`` restores); ``straggler`` scales ``node``'s compute
            by ``factor`` (``1.0`` recovers).
        node: the subject node id in the *base* fleet.
        peer: the other endpoint for ``degrade_link`` (else ``-1``).
        factor: link/compute multiplier (unused for preempt/return).
    """
    t: float
    kind: str
    node: int
    peer: int = -1
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(
                f"kind must be one of {EVENT_KINDS}, got {self.kind!r}")
        # normalize numeric types so to_json() is canonical regardless of
        # whether callers passed ints or floats
        object.__setattr__(self, "t", float(self.t))
        object.__setattr__(self, "node", int(self.node))
        object.__setattr__(self, "peer", int(self.peer))
        object.__setattr__(self, "factor", float(self.factor))

    def to_json_dict(self) -> dict:
        return {"t": self.t, "kind": self.kind, "node": self.node,
                "peer": self.peer, "factor": self.factor}

    @classmethod
    def from_json_dict(cls, d: dict) -> "ChurnEvent":
        return cls(t=float(d["t"]), kind=d["kind"], node=int(d["node"]),
                   peer=int(d.get("peer", -1)),
                   factor=float(d.get("factor", 1.0)))


@dataclass(frozen=True)
class ChurnTrace:
    """A replayable event stream over a fixed base fleet.

    ``to_json`` is canonical (sorted keys, fixed separators, trailing
    newline): the same generator seed produces byte-identical text, and
    ``from_json(to_json(x)) == x`` exactly — the determinism contract
    tests pin.
    """
    n_nodes: int
    horizon_s: float
    seed: int
    min_nodes: int
    events: Tuple[ChurnEvent, ...]

    def __post_init__(self):
        object.__setattr__(self, "n_nodes", int(self.n_nodes))
        object.__setattr__(self, "horizon_s", float(self.horizon_s))
        object.__setattr__(self, "seed", int(self.seed))
        object.__setattr__(self, "min_nodes", int(self.min_nodes))
        object.__setattr__(self, "events", tuple(self.events))

    def to_json_dict(self) -> dict:
        return {"n_nodes": self.n_nodes, "horizon_s": self.horizon_s,
                "seed": self.seed, "min_nodes": self.min_nodes,
                "events": [e.to_json_dict() for e in self.events]}

    def to_json(self) -> str:
        return json.dumps(self.to_json_dict(), sort_keys=True, indent=2,
                          allow_nan=False) + "\n"

    @classmethod
    def from_json_dict(cls, d: dict) -> "ChurnTrace":
        return cls(n_nodes=int(d["n_nodes"]),
                   horizon_s=float(d["horizon_s"]), seed=int(d["seed"]),
                   min_nodes=int(d["min_nodes"]),
                   events=tuple(ChurnEvent.from_json_dict(e)
                                for e in d["events"]))

    def save(self, path) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return str(path)

    @classmethod
    def load(cls, path) -> "ChurnTrace":
        with open(path) as f:
            return cls.from_json_dict(json.load(f))


def generate_trace(spec: ClusterSpec, *, horizon_s: float = 3600.0,
                   seed: int = 0, min_nodes: int = 2,
                   preempt_interval_s: float = 900.0,
                   outage_s: float = 400.0,
                   degrade_interval_s: float = 1200.0,
                   degrade_duration_s: float = 500.0,
                   straggler_interval_s: float = 1200.0,
                   straggler_duration_s: float = 500.0) -> ChurnTrace:
    """Draw a seeded event stream for ``spec``'s fleet.

    Four independent arrival processes with exponential inter-arrival
    times: preemptions (each schedules the node's return after an
    ``outage_s``-scaled stay-down), link degradations and stragglers
    (each schedules its own recovery).  Preemptions respect
    ``min_nodes``: a draw that would take the up-count to the floor is
    dropped, not resampled — so the event count stays a pure function of
    the seed.  Events are sorted by ``(t, kind, node, peer)``; the whole
    trace is a deterministic function of ``(spec.n_nodes, seed,
    rates)``.
    """
    if spec.n_nodes <= min_nodes:
        raise ValueError(
            f"fleet of {spec.n_nodes} nodes cannot churn above a "
            f"min_nodes={min_nodes} floor")
    rng = np.random.default_rng(seed)
    events: List[ChurnEvent] = []

    # preempt/return pairs (spot reclaims)
    down_until: Dict[int, float] = {}
    t = float(rng.exponential(preempt_interval_s))
    while t < horizon_s:
        up = [n for n in range(spec.n_nodes) if down_until.get(n, -1.0) < t]
        if len(up) > min_nodes:
            node = int(up[int(rng.integers(len(up)))])
            stay_down = float(outage_s * (0.5 + rng.random()))
            events.append(ChurnEvent(t, "preempt", node))
            if t + stay_down < horizon_s:
                events.append(ChurnEvent(t + stay_down, "return", node))
            down_until[node] = t + stay_down
        t += float(rng.exponential(preempt_interval_s))

    # link degradations (with recovery)
    t = float(rng.exponential(degrade_interval_s))
    while t < horizon_s:
        a = int(rng.integers(spec.n_nodes))
        b = int(rng.integers(spec.n_nodes - 1))
        b = b if b < a else b + 1
        factor = float(0.3 + 0.5 * rng.random())
        events.append(ChurnEvent(t, "degrade_link", a, peer=b,
                                 factor=factor))
        recover = t + float(degrade_duration_s * (0.5 + rng.random()))
        if recover < horizon_s:
            events.append(ChurnEvent(recover, "degrade_link", a, peer=b,
                                     factor=1.0))
        t += float(rng.exponential(degrade_interval_s))

    # stragglers (with recovery)
    t = float(rng.exponential(straggler_interval_s))
    while t < horizon_s:
        node = int(rng.integers(spec.n_nodes))
        factor = float(0.4 + 0.5 * rng.random())
        events.append(ChurnEvent(t, "straggler", node, factor=factor))
        recover = t + float(straggler_duration_s * (0.5 + rng.random()))
        if recover < horizon_s:
            events.append(ChurnEvent(recover, "straggler", node,
                                     factor=1.0))
        t += float(rng.exponential(straggler_interval_s))

    events.sort(key=lambda e: (e.t, e.kind, e.node, e.peer))
    return ChurnTrace(n_nodes=spec.n_nodes, horizon_s=horizon_s, seed=seed,
                      min_nodes=min_nodes, events=tuple(events))


# ---------------------------------------------------------------------------
# fleet state
# ---------------------------------------------------------------------------

class FleetState:
    """Folds a trace prefix into the effective cluster.

    Nodes are kept in *join order*: the initial fleet ``[0..n)``, minus
    preempted nodes, with returners appended at the tail.  That ordering
    is what makes incumbent warm-starts a prefix projection — a surviving
    GPU's position in the new fleet preserves its relative order in the
    old one, and every new GPU sits after all survivors.
    """

    def __init__(self, spec: ClusterSpec):
        self.spec = spec
        self.nodes: List[int] = list(range(spec.n_nodes))
        self.link_factors: Dict[Tuple[int, int], float] = {}
        self.compute: Dict[int, float] = {
            n: 1.0 for n in range(spec.n_nodes)}

    def apply(self, ev: ChurnEvent) -> None:
        if ev.kind == "preempt":
            if ev.node in self.nodes:
                self.nodes.remove(ev.node)
        elif ev.kind == "return":
            if ev.node not in self.nodes:
                self.nodes.append(ev.node)
        elif ev.kind == "degrade_link":
            pair = (min(ev.node, ev.peer), max(ev.node, ev.peer))
            if ev.factor >= 1.0:
                self.link_factors.pop(pair, None)
            else:
                self.link_factors[pair] = ev.factor
        elif ev.kind == "straggler":
            self.compute[ev.node] = ev.factor
        else:  # pragma: no cover - ChurnEvent validates kinds
            raise ValueError(f"unknown event kind {ev.kind!r}")

    def gpu_ids(self) -> List[int]:
        """Base-fleet GPU ids of the current fleet, in node-join order —
        index ``i`` is effective GPU ``i``'s identity in the base fleet."""
        return [g for n in self.nodes for g in self.spec.node_gpus(n)]

    def effective_spec(self) -> ClusterSpec:
        s = self.spec.with_node_subset(self.nodes)
        return s.with_compute_factors(
            [self.compute[n] for n in self.nodes])

    def effective_bw(self, bw_true: np.ndarray) -> np.ndarray:
        """The ground-truth bandwidth submatrix of the current fleet,
        with degraded inter-node links scaled down."""
        gpus = np.asarray(self.gpu_ids())
        sub = bw_true[np.ix_(gpus, gpus)].copy()
        pos = {n: i for i, n in enumerate(self.nodes)}
        gpn = self.spec.gpus_per_node
        for (a, b), f in sorted(self.link_factors.items()):
            if a not in pos or b not in pos:
                continue
            ia = np.arange(pos[a] * gpn, (pos[a] + 1) * gpn)
            ib = np.arange(pos[b] * gpn, (pos[b] + 1) * gpn)
            sub[np.ix_(ia, ib)] *= f
            sub[np.ix_(ib, ia)] *= f
        return sub


# ---------------------------------------------------------------------------
# replanning policies + the replay scorer
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ReplanPolicy:
    """How to respond to a fleet event.

    ``warm=True`` is the incremental policy: each replan warm-starts SA
    from the incumbent mapping projected onto the survivors and selects
    by ``latency + migration_weight * downtime``.  ``warm=False`` is the
    from-scratch baseline: cold SA, pure-fastest selection, whatever
    resharding that implies.

    ``backend`` defaults to the unified ``"numpy"`` SA core rather than
    the legacy per-candidate driver because the unified core *guards* its
    warm seed — the incumbent permutation is used only when it scores
    better than the coarse init — so warm-starting can shift but never
    degrade a candidate's SA outcome, keeping the cross-candidate
    ranking honest.
    """
    name: str
    warm: bool
    migration_weight: float = 0.0
    sa_seconds: float = 0.25
    sa_iters: int = 400
    partition: str = "uniform"
    max_vpp: int = 1
    backend: str = "numpy"
    seed: int = 0


#: warm incremental replanning.  ``migration_weight`` has units of
#: 1/steps — it converts downtime seconds into a per-step latency
#: penalty, so it should be ~``1 / (expected steps between events)``:
#: with millisecond step times and minutes-long segments that is about
#: 1e-5, letting a 10 s restart barrier tip only near-tie candidates.
WARM_POLICY = ReplanPolicy("warm", True, migration_weight=2e-5)
#: from-scratch baseline.
COLD_POLICY = ReplanPolicy("cold", False)
POLICIES = {"warm": WARM_POLICY, "cold": COLD_POLICY}


class ResidentState:
    """Independent reshard ledger: which shard each *base* GPU holds.

    Carried across the whole trace, so it catches accounting drift that a
    single-transition :class:`~repro.core.migration.PlanDiff` cannot —
    the bench gate asserts the two agree on every transition.  A departed
    GPU's entry is dropped (spot reclaim loses the instance), so a
    returning node re-fetches its shard — matching ``PlanDiff``'s
    added-rank accounting.
    """

    def __init__(self):
        self.keys: Dict[int, tuple] = {}

    def transition(self, cfg, cand: Candidate,
                   gpus: Sequence[int]) -> Tuple[int, int, float]:
        """Fold in a new assignment; returns (moved, added, bytes)."""
        new_keys = state_keys(cfg, cand.conf, cand.mapping, cand.partition)
        shard = rank_state_bytes(cfg, cand.conf, cand.partition)
        m4 = mapping4(cand.conf, cand.mapping)
        stage_of = {int(g): x for x in range(cand.conf.pp)
                    for g in m4[x].reshape(-1)}
        moved = added = 0
        fetched = 0.0
        for local, base in enumerate(gpus):
            old = self.keys.get(base)
            if old == new_keys[local]:
                continue
            if old is None:
                added += 1
            else:
                moved += 1
            fetched += float(shard[stage_of[local]])
        self.keys = {base: new_keys[local]
                     for local, base in enumerate(gpus)}
        return moved, added, fetched


@dataclass
class PolicyReport:
    """Outcome of replaying one trace under one policy."""
    policy: str
    samples: float                  # the throughput integral
    downtime_s: float
    replans: int
    ranks_moved: int
    bytes_migrated: float
    resident_bytes: float           # independent ledger's total
    resident_moved: int
    segments: List[dict] = field(default_factory=list)

    def to_json_dict(self) -> dict:
        return dataclasses.asdict(self)


def _measure_step(w: Workload, spec: ClusterSpec, bw: np.ndarray,
                  cand: Candidate, partition_mode: str, *,
                  jitter: float, contention: float, seed: int) -> float:
    """Ground-truth seconds/step of a candidate on the effective fleet,
    via the event-driven cluster simulator."""
    prof = ProfileCache(w, spec, partition_mode).get(cand.conf)
    return float(simulate_iteration(
        cand.conf, cand.mapping, bw, prof, spec,
        jitter=jitter, contention=contention, seed=seed)["total"])


def simulate_churn(w: Workload, spec: ClusterSpec, trace: ChurnTrace,
                   policy: ReplanPolicy, *, day: int = 0,
                   jitter: float = 0.0, contention: float = 0.05,
                   sim_seed: int = 0) -> PolicyReport:
    """Replay ``trace`` under ``policy``; score the throughput integral.

    At t=0 both policies cold-plan the full fleet (no incumbent exists).
    At each event the fleet state advances and the policy replans on the
    effective spec/bandwidth; the segment until the next event
    contributes ``(duration - downtime) / step_time * bs_global``
    samples, where ``step_time`` is measured by the event-driven
    simulator (the "real cluster") and ``downtime`` comes from the
    migration model's :class:`~repro.core.migration.PlanDiff` for the
    transition actually taken.

    Each replan draws a fresh SA seed (``policy.seed + replan index``) —
    both policies see the identical seed stream, so the comparison
    isolates warm-start/migration-aware selection.  Reusing one seed for
    every replan would let the *from-scratch* policy accidentally
    reproduce its previous mapping verbatim whenever the spec barely
    changed (SA is deterministic), crediting it with incremental
    behaviour it does not have.
    """
    if trace.n_nodes != spec.n_nodes:
        raise ValueError(
            f"trace was generated for {trace.n_nodes} nodes, "
            f"spec has {spec.n_nodes}")
    bw_true = true_bandwidth_matrix(spec, day)
    state = FleetState(spec)
    ledger = ResidentState()
    report = PolicyReport(policy=policy.name, samples=0.0, downtime_s=0.0,
                          replans=0, ranks_moved=0, bytes_migrated=0.0,
                          resident_bytes=0.0, resident_moved=0)

    def plan_now(incumbent: Optional[Plan],
                 survivors: Optional[List[int]], plan_idx: int):
        eff_spec = state.effective_spec()
        eff_bw = state.effective_bw(bw_true)
        ep = replan_on(
            w, eff_spec, eff_bw,
            incumbent=incumbent if policy.warm else None,
            migration_weight=policy.migration_weight if policy.warm else 0.0,
            survivors=survivors if policy.warm else None,
            sa_seconds=policy.sa_seconds, sa_iters=policy.sa_iters,
            partition=policy.partition, max_vpp=policy.max_vpp,
            backend=policy.backend, seed=policy.seed + plan_idx)
        cand = ep.chosen if ep.chosen is not None else ep.plan.ranked[0]
        # the incumbent artifact for the *next* replan reflects the
        # candidate actually going live, not necessarily plan.best
        live = dataclasses.replace(
            ep.plan, conf=cand.conf, mapping=cand.mapping,
            latency=cand.latency, mem_pred=cand.mem_pred,
            partition=cand.partition, schedule=cand.schedule)
        return cand, live, eff_spec, eff_bw

    cand, live, eff_spec, eff_bw = plan_now(None, None, 0)
    step = _measure_step(w, eff_spec, eff_bw, cand, policy.partition,
                         jitter=jitter, contention=contention,
                         seed=sim_seed)
    r_moved, r_added, r_bytes = ledger.transition(
        w.cfg, cand, state.gpu_ids())
    prev_gpus = state.gpu_ids()
    t_prev, pending_downtime = 0.0, 0.0

    def close_segment(t_now: float):
        productive = max(0.0, (t_now - t_prev) - pending_downtime)
        report.samples += productive / step * w.bs_global
        report.downtime_s += min(pending_downtime, t_now - t_prev)
        report.segments.append(
            {"t0": t_prev, "t1": t_now, "step_time": step,
             "downtime": pending_downtime,
             "conf": repr(cand.conf)})

    for ev in trace.events:
        close_segment(ev.t)
        state.apply(ev)
        old_conf, old_mapping, old_part = (cand.conf, cand.mapping,
                                           cand.partition)
        incumbent = live
        # survivors: previous-fleet GPU positions of the new fleet's
        # surviving GPUs, in new order (join-order keeps this a prefix)
        old_pos = {base: i for i, base in enumerate(prev_gpus)}
        new_gpus = state.gpu_ids()
        survivors = [old_pos[g] for g in new_gpus if g in old_pos]
        cand, live, eff_spec, eff_bw = plan_now(incumbent, survivors,
                                                report.replans + 1)
        step = _measure_step(w, eff_spec, eff_bw, cand, policy.partition,
                             jitter=jitter, contention=contention,
                             seed=sim_seed)
        b_to_a = [old_pos.get(g, -1) for g in new_gpus]
        d = diff_assignments(
            w.cfg, old_conf, old_mapping, cand.conf, cand.mapping,
            partition_a=old_part, partition_b=cand.partition,
            b_to_a=b_to_a, n_nodes=eff_spec.n_nodes,
            inter_bw=spec.inter_bw)
        r_moved, r_added, r_bytes = ledger.transition(w.cfg, cand,
                                                      new_gpus)
        report.replans += 1
        report.ranks_moved += d.ranks_moved
        report.bytes_migrated += d.bytes_migrated
        report.resident_moved += r_moved
        report.resident_bytes += r_bytes
        pending_downtime = d.downtime_s
        t_prev = ev.t
        prev_gpus = new_gpus

    close_segment(trace.horizon_s)
    return report


# ---------------------------------------------------------------------------
# replay CLI
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.runtime.churn",
        description="Replay a churn trace against replanning policies "
                    "and report the throughput integral.")
    ap.add_argument("--trace", help="replay this trace JSON instead of "
                                    "generating one")
    ap.add_argument("--trace-out", help="save the (generated) trace here")
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--model", default="gpt-1.1b")
    ap.add_argument("--full", action="store_true",
                    help="use the full model (default: reduced() smoke "
                         "variant)")
    ap.add_argument("--seq", type=int, default=2048)
    ap.add_argument("--bs-global", type=int, default=64)
    ap.add_argument("--horizon", type=float, default=1800.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--min-nodes", type=int, default=2)
    ap.add_argument("--policies", default="warm,cold",
                    help="comma-separated subset of %s" % (
                        sorted(POLICIES),))
    ap.add_argument("--migration-weight", type=float, default=None,
                    help="override the warm policy's migration weight")
    ap.add_argument("--sa-iters", type=int, default=None,
                    help="override per-replan SA iterations")
    ap.add_argument("--jitter", type=float, default=0.0)
    ap.add_argument("--out", help="write the JSON report here")
    args = ap.parse_args(argv)

    from .. import configs
    cfg = configs.get(args.model)
    if not args.full:
        cfg = cfg.reduced()
    w = Workload(cfg, seq=args.seq, bs_global=args.bs_global)

    if args.trace:
        trace = ChurnTrace.load(args.trace)
        spec = MID_RANGE.with_nodes(trace.n_nodes)
    else:
        spec = MID_RANGE.with_nodes(args.nodes)
        trace = generate_trace(spec, horizon_s=args.horizon,
                               seed=args.seed, min_nodes=args.min_nodes)
    if args.trace_out:
        trace.save(args.trace_out)
    print(f"trace: {len(trace.events)} events over {trace.horizon_s:.0f}s "
          f"on {trace.n_nodes} nodes (seed {trace.seed})")

    reports = {}
    for name in args.policies.split(","):
        pol = POLICIES[name.strip()]
        if args.migration_weight is not None and pol.warm:
            pol = dataclasses.replace(
                pol, migration_weight=args.migration_weight)
        if args.sa_iters is not None:
            pol = dataclasses.replace(pol, sa_iters=args.sa_iters)
        rep = simulate_churn(w, spec, trace, pol, jitter=args.jitter)
        reports[pol.name] = rep
        print(f"{pol.name:>6}: {rep.samples:12.0f} samples, "
              f"{rep.downtime_s:7.1f}s down, {rep.replans} replans, "
              f"{rep.ranks_moved} ranks moved, "
              f"{rep.bytes_migrated / 1e9:.2f} GB migrated")

    if args.out:
        doc = {name: r.to_json_dict() for name, r in reports.items()}
        with open(args.out, "w") as f:
            json.dump(doc, f, sort_keys=True, indent=2)
            f.write("\n")
        print(f"report -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
