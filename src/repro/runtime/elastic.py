"""Elastic scaling: when the healthy device count changes (node failure,
capacity change), re-run the Pipette search for the new G, rebuild the
mesh with the new worker dedication, and reshard the checkpoint.

This is the paper's configurator promoted to a *runtime* fault-tolerance
mechanism, expressed through the Planner API: ``replan`` shrinks the spec
to the healthy node count, re-profiles the interconnect, validates (and if
stale, refits) the memory estimator, then runs
``Planner(PipetteStrategy(...)).plan(request, bw)`` — the same entry point
that produced the initial configuration — and hands the resulting
serializable :class:`~repro.core.plan.Plan` to
``launch.mesh.mesh_from_plan`` / the checkpoint reshard.

Replanning is *incremental* when an ``incumbent`` plan is supplied: the
incumbent's GPU permutation is projected onto the surviving ranks
(:func:`~repro.core.dedication.project_perm`) and seeds every SA chain via
``Budget.warm_start``, and candidates are selected by ``step_time +
migration_weight * downtime`` (:mod:`repro.core.migration`) instead of
step time alone — so a marginally faster plan that reshards the whole
fleet loses to a near-peer reachable by moving two ranks.  The
trace-driven churn simulator (:mod:`repro.runtime.churn`) drives this
entry point once per fleet event.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.cluster import ClusterSpec, profile_bandwidth
from ..core.dedication import mapping_to_perm, project_perm
from ..core.memory import MemoryEstimator, fit_memory_estimator
from ..core.migration import PlanDiff, diff_assignments
from ..core.plan import (Budget, ExhaustiveStrategy, Plan, Planner,
                         PlanRequest, PipetteStrategy, SearchSpace)
from ..core.search import Candidate, SearchResult
from ..core.simulator import ProfileCache, Workload
from ..core.latency import pipette_latency

# The declarative-request knobs ``replan(**search_kw)`` accepts, derived
# from the dataclasses themselves so a new SearchSpace/Budget field is
# routable the day it lands (the historical hardcoded tuples silently
# rejected ``partition``/``max_vpp``/``backend``/... for two releases).
# ``sa_seconds`` stays an explicit ``replan`` parameter (its elastic
# default differs from the Budget default), so it is carved out here.
_SPACE_KEYS = frozenset(f.name for f in dataclasses.fields(SearchSpace))
_BUDGET_KEYS = frozenset(f.name for f in dataclasses.fields(Budget)) \
    - {"sa_seconds"}
assert not (_SPACE_KEYS & _BUDGET_KEYS), \
    "SearchSpace and Budget field names must stay disjoint for the " \
    "replan() kwarg split to be unambiguous"


@dataclass
class ElasticPlan:
    """Outcome of a re-plan: the serializable Plan plus re-profile context.

    ``result`` (the full in-process :class:`SearchResult`) is kept for
    callers that inspect the complete ranking; ``plan`` is the artifact the
    launch layer consumes (``plan.save`` to persist it with the
    checkpoint).  ``plan.best`` stays the *fastest* candidate; when an
    incumbent was supplied, ``chosen`` is the candidate minimizing
    ``latency + migration_weight * downtime`` (it may differ from the
    fastest) and ``migration`` prices the switch from the incumbent to
    ``chosen``."""
    result: SearchResult
    n_gpus: int
    bw: np.ndarray
    refit_estimator: bool = False
    plan: Optional[Plan] = None
    chosen: Optional[Candidate] = None
    migration: Optional[PlanDiff] = None


def _estimator_stale(est: MemoryEstimator, spec: ClusterSpec,
                     max_cp: int = 1) -> bool:
    """True when ``est`` was fit on hardware that no longer matches
    ``spec`` — a resized node count is fine (the features extrapolate over
    GPU count by design, in both directions: ``n_gpus`` enters the feature
    vector, ``gpus_per_node`` is what the fit is conditioned on), but a
    different per-GPU memory or node width changes the ground truth the
    fit learned, so its predictions are invalid for the new cluster.  A
    3D-fit estimator asked to score a 4D re-plan (``max_cp > 1`` without
    ``with_cp``) is stale for the same reason: it cannot price cp>1
    candidates.  The partition mode and ``max_vpp`` deliberately do *not*
    stale an estimator: they change which layers each stage holds, not the
    feature layout the fit learned (vpp/partition enter the *analytical*
    term, which needs no fit).  Estimators without hardware provenance
    (legacy ``fit_gpu_mem == 0``) are trusted on that axis as before."""
    if max_cp > 1 and not est.with_cp:
        return True
    if est.fit_gpu_mem == 0.0 and est.fit_gpus_per_node == 0:  # repro: noqa DET005 -- 0.0 is the exact stored legacy-provenance sentinel, assigned literally and never computed
        return False
    return (est.fit_gpu_mem != spec.gpu_mem or
            est.fit_gpus_per_node != spec.gpus_per_node)


def _split_request_kwargs(search_kw: dict) -> Tuple[dict, dict]:
    """Route ``replan(**kw)`` extras to SearchSpace vs Budget by the
    dataclasses' own field lists; unknown keys raise ``TypeError``."""
    space_kw = {k: search_kw.pop(k) for k in sorted(_SPACE_KEYS)
                if k in search_kw}
    budget_kw = {k: search_kw.pop(k) for k in sorted(_BUDGET_KEYS)
                 if k in search_kw}
    if search_kw:
        raise TypeError(f"unknown replan() keywords: {sorted(search_kw)}")
    return space_kw, budget_kw


def _rescore_with_perm(w: Workload, new_spec: ClusterSpec, bw: np.ndarray,
                       perm: np.ndarray, space: SearchSpace,
                       template: Candidate) -> Optional[Candidate]:
    """Price ``template``'s configuration under the mapping induced by
    (the relevant prefix of) ``perm`` on the new interconnect.  Returns
    ``None`` when the conf cannot be profiled on ``new_spec``."""
    conf = template.conf
    if conf.n_gpus > len(perm):
        return None
    from ..core.dedication import perm_to_mapping
    mapping = perm_to_mapping(np.asarray(perm[:conf.n_gpus]), conf)
    try:
        prof = ProfileCache(w, new_spec, space.partition).get(conf)
    except ValueError:
        return None
    lat = pipette_latency(conf, mapping, bw, prof, new_spec)
    return Candidate(conf=conf, mapping=mapping, latency=lat,
                     mem_pred=template.mem_pred,
                     partition=template.partition,
                     schedule=template.schedule)


def _score_stay_candidate(w: Workload, new_spec: ClusterSpec,
                          bw: np.ndarray, incumbent: Plan,
                          survivors: Sequence[int],
                          space: SearchSpace) -> Optional[Candidate]:
    """The zero/low-migration fallback: the incumbent's own configuration
    and (projected) mapping, re-scored on the new interconnect.

    Only exists when the event preserved the incumbent's GPU count (all
    incumbent GPUs survive, none added) — a shrink invalidates the conf,
    and a grow would leave the new nodes idle.  Returns ``None``
    otherwise, or when the incumbent cannot be re-scored (e.g. its conf no
    longer enumerates)."""
    conf = incumbent.conf
    n_new = new_spec.n_gpus
    if conf is None or conf.n_gpus != len(survivors) or n_new != len(
            survivors):
        return None
    perm = project_perm(mapping_to_perm(incumbent.mapping),
                        survivors, n_new)
    return _rescore_with_perm(
        w, new_spec, bw, perm, space,
        Candidate(conf=conf, mapping=incumbent.mapping,
                  latency=float("nan"), mem_pred=incumbent.mem_pred,
                  partition=incumbent.partition,
                  schedule=incumbent.schedule))


def replan_on(w: Workload, new_spec: ClusterSpec, bw: np.ndarray, *,
              estimator: Optional[MemoryEstimator] = None,
              incumbent: Optional[Plan] = None,
              migration_weight: float = 0.0,
              survivors: Optional[Sequence[int]] = None,
              sa_seconds: float = 0.5, seed: int = 0,
              refit_steps: int = 2_000, mem_limit: Optional[float] = None,
              dedicate: bool = True, **search_kw) -> ElasticPlan:
    """Re-plan on an already-mutated spec + profiled matrix.

    The core behind :func:`replan`, split out so the churn simulator can
    hand in event-stream specs (:meth:`ClusterSpec.with_node_subset`,
    :meth:`ClusterSpec.with_compute_factors`) and its own bandwidth
    submatrices instead of a fresh ``profile_bandwidth`` snapshot.

    Args:
        w: the workload being trained.
        new_spec: the post-event cluster.
        bw: ``(G, G)`` profiled bandwidth matrix for ``new_spec``.
        estimator: memory estimator; refit when stale for ``new_spec``.
        incumbent: the currently-running plan.  When given, its GPU
            permutation — projected onto ``survivors`` — warm-starts every
            SA chain, replan lineage is recorded on the new plan, and the
            returned ``chosen``/``migration`` price the switch.
        migration_weight: seconds-per-second-of-downtime weight in the
            selection objective ``latency + migration_weight * downtime``.
            ``0`` selects purely by step time (but still warm-starts).
            With step times in seconds and downtime dominated by the
            restart barrier, a weight around ``1 / expected steps between
            events`` amortizes the stall over the replan's lifetime.
        survivors: incumbent GPU ids still present, in new-fleet order
            (new GPU ``i`` is incumbent GPU ``survivors[i]`` for ``i <
            len(survivors)``; new GPUs follow).  Default: identity on the
            common prefix — the ``with_nodes`` truncation convention.
        sa_seconds / seed / refit_steps / mem_limit / dedicate: as on
            :func:`replan`.
        **search_kw: any :class:`SearchSpace` or :class:`Budget` field
            (routed by the dataclasses' own field lists).
    """
    space_kw, budget_kw = _split_request_kwargs(search_kw)
    space = SearchSpace(**space_kw)
    budget = Budget(sa_seconds=sa_seconds, **budget_kw)

    n_new = new_spec.n_gpus
    if survivors is None:
        n_old = incumbent.conf.n_gpus if (
            incumbent is not None and incumbent.conf is not None) else n_new
        survivors = list(range(min(n_old, n_new)))
    survivors = [int(s) for s in survivors]

    lineage = None
    if incumbent is not None and incumbent.feasible:
        projected = budget.warm_start is None
        if projected:
            perm = project_perm(mapping_to_perm(incumbent.mapping),
                                survivors, n_new)
            budget = dataclasses.replace(
                budget, warm_start=tuple(int(x) for x in perm))
        lineage = {"replan_of": incumbent.fingerprint(),
                   "warm_start_projected": projected,
                   "survivors": len(survivors)}

    refit = estimator is not None and _estimator_stale(
        estimator, new_spec, space.max_cp)
    if refit:
        estimator = fit_memory_estimator(
            [w], new_spec, fit_nodes=min(2, new_spec.n_nodes),
            steps=refit_steps, residual=estimator.residual,
            max_cp=space.max_cp)
    req = PlanRequest(workload=w, spec=new_spec, space=space, budget=budget,
                      seed=seed)
    strategy = (PipetteStrategy(estimator=estimator, mem_limit=mem_limit)
                if dedicate
                else ExhaustiveStrategy(estimator=estimator,
                                        mem_limit=mem_limit))
    plan = Planner(strategy).plan(req, bw, lineage=lineage)
    if not plan.feasible:
        raise RuntimeError(
            f"no feasible configuration for {new_spec.n_gpus} GPUs — "
            f"memory limit too tight for every (pp, tp, cp, dp, bs_micro)")

    chosen, migration = _select(w, new_spec, bw, plan, incumbent,
                                migration_weight, survivors, space)
    return ElasticPlan(plan.result, n_new, bw, refit_estimator=refit,
                       plan=plan, chosen=chosen, migration=migration)


def _select(w: Workload, new_spec: ClusterSpec, bw: np.ndarray, plan: Plan,
            incumbent: Optional[Plan], migration_weight: float,
            survivors: Sequence[int], space: SearchSpace
            ) -> Tuple[Candidate, Optional[PlanDiff]]:
    """Pick the go-live candidate: fastest when there is no incumbent,
    else the minimizer of ``latency + migration_weight * downtime`` over
    the ranked candidates, the stay-put fallback, and each ranked
    configuration re-mapped onto the incumbent's projected permutation.

    The aligned variants are the heart of incremental replanning: SA's
    dedication is near-indifferent between permutations on a uniform
    interconnect, so the ranked mappings land arbitrarily far from the
    incumbent and reshard everything.  Re-pricing every ranked conf under
    the incumbent-aligned mapping offers the selector a same-speed,
    low-migration version of each configuration — the issue's "1%-faster
    plan reachable by moving two ranks".  SA's mapping still wins whenever
    its latency edge exceeds the amortized migration cost (heterogeneous
    interconnects, degraded links)."""
    ranked: List[Candidate] = list(plan.ranked)
    if incumbent is None or not incumbent.feasible:
        return ranked[0], None
    stay = _score_stay_candidate(w, new_spec, bw, incumbent, survivors,
                                 space)
    if stay is not None:
        ranked.append(stay)
    if migration_weight > 0 and incumbent.conf is not None:
        proj = project_perm(mapping_to_perm(incumbent.mapping),
                            survivors, new_spec.n_gpus)
        seen_confs = set()
        for cand in list(plan.ranked):
            if cand.conf in seen_confs:
                continue
            seen_confs.add(cand.conf)
            aligned = _rescore_with_perm(w, new_spec, bw, proj, space,
                                         cand)
            if aligned is not None and not np.array_equal(
                    aligned.mapping, cand.mapping):
                ranked.append(aligned)
    b_to_a = [survivors[g] if g < len(survivors) else -1
              for g in range(new_spec.n_gpus)]
    best_i, best_key, diffs = 0, None, []
    for i, cand in enumerate(ranked):
        d = diff_assignments(
            w.cfg, incumbent.conf, incumbent.mapping, cand.conf,
            cand.mapping, partition_a=incumbent.partition,
            partition_b=cand.partition, b_to_a=b_to_a,
            n_nodes=new_spec.n_nodes, inter_bw=new_spec.inter_bw)
        diffs.append(d)
        key = (cand.latency + migration_weight * d.downtime_s,
               cand.latency, i)
        if best_key is None or key < best_key:
            best_i, best_key = i, key
    return ranked[best_i], diffs[best_i]


def replan(w: Workload, spec: ClusterSpec,
           healthy_nodes: Union[int, Sequence[int]], *,
           estimator: Optional[MemoryEstimator] = None,
           incumbent: Optional[Plan] = None,
           migration_weight: float = 0.0,
           sa_seconds: float = 0.5, seed: int = 0,
           refit_steps: int = 2_000, mem_limit: Optional[float] = None,
           dedicate: bool = True, **search_kw) -> ElasticPlan:
    """Re-plan for a degraded/grown cluster of ``healthy_nodes`` nodes.

    Steps: resize the spec to the healthy node count and re-profile the
    (changed) interconnect; validate the memory estimator against the new
    hardware (refit on ``refit_steps`` training steps when ``gpu_mem`` or
    ``gpus_per_node`` changed — a fit from the original spec would silently
    mis-predict peaks on different GPUs); then run
    ``Planner(PipetteStrategy()).plan`` on the new GPU count.  The returned
    :class:`ElasticPlan` carries the serializable Plan whose mapping the
    runtime feeds to ``launch.mesh.mesh_from_plan`` before restoring the
    checkpoint with the new partition specs.

    Args:
        healthy_nodes: either a node *count* — ``spec.with_nodes``
            semantics, truncating (shrink) or cycling (grow) the tier
            pattern — or an explicit sequence of surviving node ids of
            ``spec`` (``spec.with_node_subset`` semantics: "node 3 of 16
            died" keeps nodes ``[0..2, 4..15]`` with their own tiers).
        incumbent / migration_weight: incremental-replan knobs, see
            :func:`replan_on`.  With a node-id sequence, the surviving
            GPU map is derived from it automatically.
        **search_kw: any :class:`SearchSpace` field (``max_cp``,
            ``max_tp``, ``max_micro``, ``fixed_micro``, ``partition``,
            ``max_vpp``) or :class:`Budget` field (``sa_iters``,
            ``n_chains``, ``sa_topk``, ``backend``, ``hierarchical``,
            ``warm_start``) — the split is derived from the dataclass
            fields themselves; anything else raises ``TypeError``.
    """
    survivors = None
    if isinstance(healthy_nodes, (int, np.integer)):
        new_spec = spec.with_nodes(int(healthy_nodes))
    else:
        nodes = [int(i) for i in healthy_nodes]
        new_spec = spec.with_node_subset(nodes)
        survivors = [g for node in nodes for g in spec.node_gpus(node)]
    bw, _ = profile_bandwidth(new_spec)
    return replan_on(w, new_spec, bw, estimator=estimator,
                     incumbent=incumbent, migration_weight=migration_weight,
                     survivors=survivors, sa_seconds=sa_seconds, seed=seed,
                     refit_steps=refit_steps, mem_limit=mem_limit,
                     dedicate=dedicate, **search_kw)
