"""Elastic scaling: when the healthy device count changes (node failure,
capacity change), re-run the Pipette search for the new G, rebuild the
mesh with the new worker dedication, and reshard the checkpoint.

This is the paper's configurator promoted to a *runtime* fault-tolerance
mechanism: the same Algorithm 1 that picked the initial configuration
re-plans after topology changes, and the same latency estimator scores
candidate mappings against the re-profiled bandwidth matrix.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..core.cluster import ClusterSpec, profile_bandwidth
from ..core.memory import MemoryEstimator, fit_memory_estimator
from ..core.search import SearchResult, configure
from ..core.simulator import Workload


@dataclass
class ElasticPlan:
    result: SearchResult
    n_gpus: int
    bw: np.ndarray
    refit_estimator: bool = False


def _estimator_stale(est: MemoryEstimator, spec: ClusterSpec,
                     max_cp: int = 1) -> bool:
    """True when ``est`` was fit on hardware that no longer matches
    ``spec`` — a shrunk node count is fine (the features extrapolate over
    GPU count by design), but a different per-GPU memory or node width
    changes the ground truth the fit learned, so its predictions are
    invalid for the new cluster.  A 3D-fit estimator asked to score a 4D
    re-plan (``max_cp > 1`` without ``with_cp``) is stale for the same
    reason: it cannot price cp>1 candidates.  Estimators without hardware
    provenance (legacy ``fit_gpu_mem == 0``) are trusted on that axis as
    before."""
    if max_cp > 1 and not est.with_cp:
        return True
    if est.fit_gpu_mem == 0.0 and est.fit_gpus_per_node == 0:
        return False
    return (est.fit_gpu_mem != spec.gpu_mem or
            est.fit_gpus_per_node != spec.gpus_per_node)


def replan(w: Workload, spec: ClusterSpec, healthy_nodes: int, *,
           estimator: Optional[MemoryEstimator] = None,
           sa_seconds: float = 0.5, seed: int = 0,
           refit_steps: int = 2_000, **configure_kw) -> ElasticPlan:
    """Re-plan for a degraded/grown cluster of ``healthy_nodes`` nodes.

    Steps: re-profile the (changed) interconnect, validate the memory
    estimator against the new hardware (refit on ``refit_steps`` training
    steps when ``gpu_mem`` or ``gpus_per_node`` changed — a fit from the
    original spec would silently mis-predict peaks on different GPUs),
    re-run Algorithm 1 on the new GPU count, and return the plan whose
    mapping the runtime feeds to ``launch.mesh.mesh_from_mapping`` before
    restoring the checkpoint with the new partition specs.

    Extra keyword arguments are forwarded to
    :func:`~repro.core.search.configure` (e.g. ``sa_topk``, ``max_cp``)."""
    new_spec = spec.with_nodes(healthy_nodes)
    bw, _ = profile_bandwidth(new_spec)
    refit = estimator is not None and _estimator_stale(
        estimator, new_spec, configure_kw.get("max_cp", 1))
    if refit:
        estimator = fit_memory_estimator(
            [w], new_spec, fit_nodes=min(2, healthy_nodes),
            steps=refit_steps, residual=estimator.residual,
            max_cp=configure_kw.get("max_cp", 1))
    res = configure(w, new_spec, bw, estimator=estimator,
                    sa_seconds=sa_seconds, seed=seed, **configure_kw)
    if res.best is None:
        raise RuntimeError(
            f"no feasible configuration for {new_spec.n_gpus} GPUs — "
            f"memory limit too tight for every (pp, tp, cp, dp, bs_micro)")
    return ElasticPlan(res, new_spec.n_gpus, bw, refit_estimator=refit)
