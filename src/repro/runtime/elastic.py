"""Elastic scaling: when the healthy device count changes (node failure,
capacity change), re-run the Pipette search for the new G, rebuild the
mesh with the new worker dedication, and reshard the checkpoint.

This is the paper's configurator promoted to a *runtime* fault-tolerance
mechanism: the same Algorithm 1 that picked the initial configuration
re-plans after topology changes, and the same latency estimator scores
candidate mappings against the re-profiled bandwidth matrix.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..core.cluster import ClusterSpec, profile_bandwidth
from ..core.memory import MemoryEstimator
from ..core.search import SearchResult, configure
from ..core.simulator import Workload


@dataclass
class ElasticPlan:
    result: SearchResult
    n_gpus: int
    bw: np.ndarray


def replan(w: Workload, spec: ClusterSpec, healthy_nodes: int, *,
           estimator: Optional[MemoryEstimator] = None,
           sa_seconds: float = 0.5, seed: int = 0) -> ElasticPlan:
    """Re-plan for a degraded/grown cluster of ``healthy_nodes`` nodes.

    Steps: re-profile the (changed) interconnect, re-run Algorithm 1 on
    the new GPU count, return the plan whose mapping the runtime feeds to
    ``launch.mesh.mesh_from_mapping`` before restoring the checkpoint with
    the new partition specs."""
    new_spec = spec.with_nodes(healthy_nodes)
    bw, _ = profile_bandwidth(new_spec)
    res = configure(w, new_spec, bw, estimator=estimator,
                    sa_seconds=sa_seconds, seed=seed)
    if res.best is None:
        raise RuntimeError(
            f"no feasible configuration for {new_spec.n_gpus} GPUs — "
            f"memory limit too tight for every (pp, tp, dp, bs_micro)")
    return ElasticPlan(res, new_spec.n_gpus, bw)
