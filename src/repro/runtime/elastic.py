"""Elastic scaling: when the healthy device count changes (node failure,
capacity change), re-run the Pipette search for the new G, rebuild the
mesh with the new worker dedication, and reshard the checkpoint.

This is the paper's configurator promoted to a *runtime* fault-tolerance
mechanism, expressed through the Planner API: ``replan`` shrinks the spec
to the healthy node count, re-profiles the interconnect, validates (and if
stale, refits) the memory estimator, then runs
``Planner(PipetteStrategy(...)).plan(request, bw)`` — the same entry point
that produced the initial configuration — and hands the resulting
serializable :class:`~repro.core.plan.Plan` to
``launch.mesh.mesh_from_plan`` / the checkpoint reshard.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..core.cluster import ClusterSpec, profile_bandwidth
from ..core.memory import MemoryEstimator, fit_memory_estimator
from ..core.plan import (Budget, ExhaustiveStrategy, Plan, Planner,
                         PlanRequest, PipetteStrategy, SearchSpace)
from ..core.search import SearchResult
from ..core.simulator import Workload


@dataclass
class ElasticPlan:
    """Outcome of a re-plan: the serializable Plan plus re-profile context.

    ``result`` (the full in-process :class:`SearchResult`) is kept for
    callers that inspect the complete ranking; ``plan`` is the artifact the
    launch layer consumes (``plan.save`` to persist it with the
    checkpoint)."""
    result: SearchResult
    n_gpus: int
    bw: np.ndarray
    refit_estimator: bool = False
    plan: Optional[Plan] = None


def _estimator_stale(est: MemoryEstimator, spec: ClusterSpec,
                     max_cp: int = 1) -> bool:
    """True when ``est`` was fit on hardware that no longer matches
    ``spec`` — a shrunk node count is fine (the features extrapolate over
    GPU count by design), but a different per-GPU memory or node width
    changes the ground truth the fit learned, so its predictions are
    invalid for the new cluster.  A 3D-fit estimator asked to score a 4D
    re-plan (``max_cp > 1`` without ``with_cp``) is stale for the same
    reason: it cannot price cp>1 candidates.  Estimators without hardware
    provenance (legacy ``fit_gpu_mem == 0``) are trusted on that axis as
    before."""
    if max_cp > 1 and not est.with_cp:
        return True
    if est.fit_gpu_mem == 0.0 and est.fit_gpus_per_node == 0:  # repro: noqa DET005 -- 0.0 is the exact stored legacy-provenance sentinel, assigned literally and never computed
        return False
    return (est.fit_gpu_mem != spec.gpu_mem or
            est.fit_gpus_per_node != spec.gpus_per_node)


def replan(w: Workload, spec: ClusterSpec, healthy_nodes: int, *,
           estimator: Optional[MemoryEstimator] = None,
           sa_seconds: float = 0.5, seed: int = 0,
           refit_steps: int = 2_000, mem_limit: Optional[float] = None,
           dedicate: bool = True, **search_kw) -> ElasticPlan:
    """Re-plan for a degraded/grown cluster of ``healthy_nodes`` nodes.

    Steps: shrink the spec to the healthy node count and re-profile the
    (changed) interconnect; validate the memory estimator against the new
    hardware (refit on ``refit_steps`` training steps when ``gpu_mem`` or
    ``gpus_per_node`` changed — a fit from the original spec would silently
    mis-predict peaks on different GPUs); then run
    ``Planner(PipetteStrategy()).plan`` on the new GPU count.  The returned
    :class:`ElasticPlan` carries the serializable Plan whose mapping the
    runtime feeds to ``launch.mesh.mesh_from_plan`` before restoring the
    checkpoint with the new partition specs.

    Extra keyword arguments are the declarative-request knobs: search-space
    keys (``max_cp``, ``max_tp``, ``max_micro``, ``fixed_micro``) and
    budget keys (``sa_iters``, ``n_chains``, ``sa_topk``); anything else
    raises ``TypeError``."""
    new_spec = spec.with_nodes(healthy_nodes)
    bw, _ = profile_bandwidth(new_spec)
    # split the kwargs by destination dataclass; defaults live only on
    # SearchSpace/Budget themselves (never re-stated here)
    space = SearchSpace(**{k: search_kw.pop(k)
                           for k in ("max_cp", "max_tp", "max_micro",
                                     "fixed_micro") if k in search_kw})
    budget = Budget(sa_seconds=sa_seconds,
                    **{k: search_kw.pop(k)
                       for k in ("sa_iters", "n_chains", "sa_topk")
                       if k in search_kw})
    if search_kw:
        raise TypeError(f"unknown replan() keywords: {sorted(search_kw)}")
    refit = estimator is not None and _estimator_stale(
        estimator, new_spec, space.max_cp)
    if refit:
        estimator = fit_memory_estimator(
            [w], new_spec, fit_nodes=min(2, healthy_nodes),
            steps=refit_steps, residual=estimator.residual,
            max_cp=space.max_cp)
    req = PlanRequest(workload=w, spec=new_spec, space=space, budget=budget,
                      seed=seed)
    strategy = (PipetteStrategy(estimator=estimator, mem_limit=mem_limit)
                if dedicate
                else ExhaustiveStrategy(estimator=estimator,
                                        mem_limit=mem_limit))
    plan = Planner(strategy).plan(req, bw)
    if not plan.feasible:
        raise RuntimeError(
            f"no feasible configuration for {new_spec.n_gpus} GPUs — "
            f"memory limit too tight for every (pp, tp, cp, dp, bs_micro)")
    return ElasticPlan(plan.result, new_spec.n_gpus, bw,
                       refit_estimator=refit, plan=plan)
