"""Fault-tolerant training loop.

Production behaviours, exercised by the integration tests:
  * auto-resume from the latest checkpoint (bitwise-deterministic restart:
    the data pipeline is stateless-addressable by step);
  * periodic async checkpoints with keep-k retention;
  * straggler watchdog — EWMA step-time monitor that fires a callback
    (on a real cluster: re-profile links + re-run Pipette's worker
    dedication; here the hook is injectable for tests);
  * failure injection for tests (raise mid-run, restart, verify losses
    continue bitwise);
  * elastic re-plan — on device-count change, ask Pipette for a new Plan
    and reshard the checkpoint (runtime/elastic.py);
  * plan provenance — a :class:`~repro.core.plan.Plan` handed to the loop
    is persisted as ``plan.json`` next to the checkpoints, so a restarted
    (or post-mortem'd) run knows exactly which configuration, worker
    dedication, strategy, and bandwidth snapshot it was launched under.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Callable, Optional

from ..checkpoint.manager import CheckpointManager

if TYPE_CHECKING:                              # pragma: no cover
    from ..core.plan import Plan


@dataclass
class StragglerWatchdog:
    """EWMA step-time monitor.  trigger() fires when a step exceeds
    ``threshold`` x the EWMA — the Pipette-re-dedication hook."""
    alpha: float = 0.1
    threshold: float = 2.0
    warmup_steps: int = 5
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    _ewma: float = field(default=0.0, init=False)
    _n: int = field(default=0, init=False)
    events: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup_steps:
            self._ewma = dt if self._ewma == 0 else \
                (1 - self.alpha) * self._ewma + self.alpha * dt
            return False
        fired = dt > self.threshold * self._ewma
        if fired:
            self.events.append((step, dt, self._ewma))
            if self.on_straggler:
                self.on_straggler(step, dt, self._ewma)
        else:
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * dt
        return fired


@dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    log_every: int = 10
    metrics_path: Optional[str] = None


class TrainLoop:
    def __init__(self, cfg: TrainLoopConfig, step_fn, loader,
                 watchdog: Optional[StragglerWatchdog] = None,
                 fail_at_step: Optional[int] = None,
                 plan: Optional["Plan"] = None):
        """step_fn(params, opt_state, batch) -> (params, opt_state, metrics)

        ``plan``: the serialized configurator decision this run executes
        (from ``Planner.plan`` or ``Plan.load``).  Persisted to
        ``<ckpt_dir>/plan.json`` on ``run()`` so restarts and audits see
        the same artifact the launcher consumed."""
        self.cfg = cfg
        self.step_fn = step_fn
        self.loader = loader
        self.watchdog = watchdog or StragglerWatchdog()
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.fail_at_step = fail_at_step
        self.plan = plan
        self.history: list = []

    def plan_path(self) -> str:
        return os.path.join(str(self.cfg.ckpt_dir), "plan.json")

    def run(self, params, opt_state, *, resume: bool = True):
        if self.plan is not None:
            os.makedirs(str(self.cfg.ckpt_dir), exist_ok=True)
            self.plan.save(self.plan_path())
        start = 0
        if resume:
            latest = self.ckpt.latest_step()
            if latest is not None:
                (params, opt_state), _ = self.ckpt.restore((params, opt_state),
                                                           latest)
                start = latest
        metrics_file = (open(self.cfg.metrics_path, "a")
                        if self.cfg.metrics_path else None)
        try:
            for step in range(start, self.cfg.total_steps):
                if self.fail_at_step is not None and step == self.fail_at_step:
                    self.fail_at_step = None
                    raise RuntimeError(f"injected failure at step {step}")
                batch = self.loader.batch_at(step)
                t0 = time.perf_counter()
                params, opt_state, metrics = self.step_fn(params, opt_state,
                                                          batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                self.watchdog.observe(step, dt)
                rec = {"step": step, "loss": loss, "dt": round(dt, 4)}
                self.history.append(rec)
                if metrics_file and step % self.cfg.log_every == 0:
                    metrics_file.write(json.dumps(rec) + "\n")
                    metrics_file.flush()
                if (step + 1) % self.cfg.ckpt_every == 0 or \
                        (step + 1) == self.cfg.total_steps:
                    self.ckpt.save(step + 1, (params, opt_state))
            self.ckpt.wait()
            return params, opt_state
        finally:
            if metrics_file:
                metrics_file.close()
