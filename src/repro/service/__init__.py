"""Planning-as-a-service: the async plan server, cache, and client.

A fleet runs *many* training jobs against the same cluster; most plan
requests are identical or near-identical (same workload and fleet,
different microbatch caps or budgets).  This package turns the Planner
into a long-running local daemon that exploits that redundancy:

- :class:`~repro.service.server.PlanServer` — asyncio TCP server
  (newline-delimited JSON on localhost) with a four-layer request path:
  plan cache -> in-flight coalescing -> request batching (one
  :class:`~repro.core.search.BatchSearchContext` per group) ->
  warm-started annealing seeded from the nearest cached neighbor;
- :class:`~repro.service.cache.PlanCache` — LRU + disk store keyed by
  the canonical request fingerprint; hits return byte-identical plans;
- :class:`~repro.service.client.PlanClient` — blocking stdlib client
  with pipelined multi-request submission;
- ``python -m repro.service`` — the ``serve`` / ``submit`` /
  ``cache ls|evict|stats`` CLI.

Everything is standard library + the existing core; no new dependencies.
"""
from .cache import PlanCache
from .client import PlanClient, ServiceError
from .server import PlanServer
from .wire import (AdmissionError, WireError, cluster_digest,
                   decode_plan_request, encode_plan_request,
                   incumbent_perm, request_fingerprint, request_meta,
                   workload_digest)

__all__ = [
    "AdmissionError", "PlanCache", "PlanClient", "PlanServer",
    "ServiceError", "WireError", "cluster_digest", "decode_plan_request",
    "encode_plan_request", "incumbent_perm", "request_fingerprint",
    "request_meta", "workload_digest",
]
