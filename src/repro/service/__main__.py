"""Plan-service CLI: run the daemon, submit requests, manage the cache.

    # start the server (ephemeral port, announced via the port file)
    python -m repro.service serve --cache-dir .plan-cache \
        --port-file plan-server.port

    # submit a request; the canonical plan JSON lands in plan.json and
    # the meta line (cache=miss|hit|coalesced fingerprint=...) on stdout
    python -m repro.service submit --port-file plan-server.port \
        --config qwen2-7b --reduced --cluster mid-range --nodes 2 \
        --seq 128 --bs-global 64 --sa-iters 60 -o plan.json

    # inspect / manage the fleet cache
    python -m repro.service cache stats --port-file plan-server.port
    python -m repro.service cache ls --port-file plan-server.port
    python -m repro.service cache evict <fingerprint> --port-file ...

    # stop the daemon
    python -m repro.service shutdown --port-file plan-server.port
"""
from __future__ import annotations

import argparse
import sys

from repro import configs
from repro.core import (STRATEGIES, Budget, PlanRequest, SearchSpace,
                        Workload)
from repro.plan import CLUSTERS
from repro.service.client import PlanClient, ServiceError
from repro.service.server import PlanServer


def _client(args: argparse.Namespace) -> PlanClient:
    return PlanClient(host=args.host, port=args.port,
                      port_file=args.port_file, timeout=args.timeout)


def cmd_serve(args: argparse.Namespace) -> int:
    server = PlanServer(host=args.host, port=args.port or 0,
                        cache_dir=args.cache_dir,
                        max_entries=args.max_entries,
                        warm_start=not args.no_warm_start,
                        warm_max_distance=args.warm_max_distance,
                        batch_window=args.batch_window,
                        port_file=args.port_file)
    try:
        server.run()
    except KeyboardInterrupt:
        pass
    return 0


def cmd_submit(args: argparse.Namespace) -> int:
    cfg = configs.get(args.config)
    if args.reduced:
        cfg = cfg.reduced()
    spec = CLUSTERS[args.cluster]
    if args.nodes:
        spec = spec.with_nodes(args.nodes)
    req = PlanRequest(
        workload=Workload(cfg, args.seq, args.bs_global),
        spec=spec,
        space=SearchSpace(max_cp=args.max_cp, max_tp=args.max_tp,
                          max_micro=args.max_micro,
                          fixed_micro=args.fixed_micro,
                          partition=args.partition, max_vpp=args.max_vpp),
        budget=Budget(sa_seconds=args.sa_seconds, sa_iters=args.sa_iters,
                      n_chains=args.n_chains, sa_topk=args.sa_topk,
                      backend=args.backend),
        seed=args.seed)
    try:
        resp = _client(args).submit(req, strategy=args.strategy,
                                    day=args.day)
    except ServiceError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    meta = resp["meta"]
    warm = meta.get("warm_start_from")
    print(f"cache={meta['cache']} fingerprint={meta['fingerprint']}"
          + (f" warm_start_from={warm}" if warm else ""))
    if args.output:
        with open(args.output, "w") as f:
            f.write(resp["plan"])
        print(args.output)
    else:
        sys.stdout.write(resp["plan"])
    return 0


def cmd_cache(args: argparse.Namespace) -> int:
    client = _client(args)
    if args.cache_cmd == "stats":
        stats = client.stats()
        cache = stats.pop("cache")
        for k in sorted(stats):
            print(f"{k}: {stats[k]}")
        for k in sorted(cache):
            print(f"cache.{k}: {cache[k]}")
        return 0
    if args.cache_cmd == "ls":
        entries = client.cache_ls()
        for e in entries:
            print(f"{e.get('fingerprint', '?')} "
                  f"strategy={e.get('strategy')} model={e.get('model')} "
                  f"seq={e.get('seq')} bs_global={e.get('bs_global')} "
                  f"n_gpus={e.get('n_gpus')} day={e.get('day')}"
                  + (" warm" if e.get("warm_started") else ""))
        print(f"{len(entries)} entr{'y' if len(entries) == 1 else 'ies'}",
              file=sys.stderr)
        return 0
    if args.cache_cmd == "evict":
        gone = client.cache_evict(args.fingerprint)
        print("evicted" if gone else "not found")
        return 0 if gone else 1
    raise AssertionError(args.cache_cmd)


def cmd_ping(args: argparse.Namespace) -> int:
    _client(args).ping()
    print("ok")
    return 0


def cmd_shutdown(args: argparse.Namespace) -> int:
    _client(args).shutdown()
    print("shutdown requested")
    return 0


def _add_client_args(p: argparse.ArgumentParser) -> None:
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=None)
    p.add_argument("--port-file", default=None,
                   help="file the server wrote its bound port to")
    p.add_argument("--timeout", type=float, default=300.0)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="plan server / client (planning-as-a-service)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("serve", help="run the plan server")
    s.add_argument("--host", default="127.0.0.1")
    s.add_argument("--port", type=int, default=0,
                   help="0 = ephemeral (see --port-file)")
    s.add_argument("--port-file", default=None,
                   help="write the bound port here once listening")
    s.add_argument("--cache-dir", default=None,
                   help="persistent plan-cache directory")
    s.add_argument("--max-entries", type=int, default=256)
    s.add_argument("--no-warm-start", action="store_true")
    s.add_argument("--warm-max-distance", type=float, default=2.0)
    s.add_argument("--batch-window", type=float, default=0.05,
                   help="seconds to group near-identical requests "
                        "(0 disables batching)")
    s.set_defaults(fn=cmd_serve)

    s = sub.add_parser("submit", help="request a plan from the server")
    _add_client_args(s)
    s.add_argument("--config", required=True,
                   help="model config name (repro.configs)")
    s.add_argument("--reduced", action="store_true")
    s.add_argument("--cluster", default="mid-range",
                   choices=sorted(CLUSTERS))
    s.add_argument("--nodes", type=int, default=0)
    s.add_argument("--seq", type=int, default=2048)
    s.add_argument("--bs-global", type=int, default=64)
    s.add_argument("--strategy", default="pipette",
                   choices=sorted(STRATEGIES))
    s.add_argument("--day", type=int, default=0)
    s.add_argument("--seed", type=int, default=0)
    s.add_argument("--max-cp", type=int, default=1)
    s.add_argument("--max-tp", type=int, default=0)
    s.add_argument("--max-micro", type=int, default=16)
    s.add_argument("--fixed-micro", type=int, default=None)
    s.add_argument("--partition", default="uniform")
    s.add_argument("--max-vpp", type=int, default=1)
    s.add_argument("--sa-seconds", type=float, default=60.0)
    s.add_argument("--sa-iters", type=int, default=200)
    s.add_argument("--n-chains", type=int, default=1)
    s.add_argument("--sa-topk", type=int, default=None)
    s.add_argument("--backend", default=None,
                   choices=["numpy", "jax"])
    s.add_argument("-o", "--output", default=None,
                   help="write the plan JSON here (default: stdout)")
    s.set_defaults(fn=cmd_submit)

    s = sub.add_parser("cache", help="inspect / manage the plan cache")
    cache_sub = s.add_subparsers(dest="cache_cmd", required=True)
    for name in ("stats", "ls"):
        c = cache_sub.add_parser(name)
        _add_client_args(c)
        c.set_defaults(fn=cmd_cache)
    c = cache_sub.add_parser("evict")
    c.add_argument("fingerprint")
    _add_client_args(c)
    c.set_defaults(fn=cmd_cache)

    s = sub.add_parser("ping", help="liveness check")
    _add_client_args(s)
    s.set_defaults(fn=cmd_ping)

    s = sub.add_parser("shutdown", help="stop the server")
    _add_client_args(s)
    s.set_defaults(fn=cmd_shutdown)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except BrokenPipeError:
        return 0
    except (ConnectionError, OSError) as e:
        print(f"error: cannot reach plan server: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    sys.exit(main())
