"""The fleet-wide plan cache: fingerprint -> canonical plan bytes.

Two layers behind one interface:

- an in-memory LRU (``max_entries``) holding the exact canonical JSON
  text of each plan — a cache hit returns those bytes untouched, so a
  hit is **byte-identical** to the response that populated it;
- an optional on-disk store (``<fingerprint>.plan.json`` + a
  ``.meta.json`` sidecar) so a restarted server inherits the fleet's
  plan history.  Disk writes are atomic (temp file + ``os.replace``);
  a corrupt or unreadable entry is dropped and counted, never served.

The cache also answers the warm-start question: :meth:`PlanCache.nearest`
scans entries sharing the request's cluster digest / strategy from the
same or the immediately preceding day and returns the closest workload by
log-scale distance over (seq, global batch, d_model, n_layers) — the
incumbent whose mapping seeds the new search's SA chains.  Ties break by
(distance, day recency, fingerprint) so the lookup is fully
deterministic.
"""
from __future__ import annotations

import json
import math
import os
from collections import OrderedDict
from pathlib import Path
from typing import Dict, List, Optional, Tuple

#: metadata fields every entry must carry to be servable
_REQUIRED_META = ("fingerprint", "cluster_digest", "strategy", "day")


class PlanCache:
    """LRU + disk plan cache keyed by request fingerprint.

    Args:
        cache_dir: directory for the persistent layer (``None`` =
            memory-only).  Created on first write.
        max_entries: in-memory LRU capacity; evicted entries stay on disk
            (the disk layer is the fleet history, bounded only by
            explicit ``evict``).
    """

    def __init__(self, cache_dir=None, *, max_entries: int = 256):
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.cache_dir = Path(cache_dir) if cache_dir is not None else None
        self.max_entries = max_entries
        self._mem: "OrderedDict[str, Tuple[dict, str]]" = OrderedDict()
        self.counters: Dict[str, int] = {
            "hits": 0, "misses": 0, "puts": 0, "lru_evictions": 0,
            "evictions": 0, "corrupt_dropped": 0,
        }

    # -- paths --------------------------------------------------------------

    def _plan_path(self, fp: str) -> Path:
        return self.cache_dir / f"{fp}.plan.json"

    def _meta_path(self, fp: str) -> Path:
        return self.cache_dir / f"{fp}.meta.json"

    # -- core ---------------------------------------------------------------

    def get(self, fp: str) -> Optional[str]:
        """The cached plan text for ``fp``, or ``None``.  Disk entries are
        promoted into the LRU on hit; corrupt entries are dropped."""
        hit = self._mem.get(fp)
        if hit is not None:
            self._mem.move_to_end(fp)
            self.counters["hits"] += 1
            return hit[1]
        loaded = self._load_disk(fp)
        if loaded is not None:
            meta, text = loaded
            self._insert(fp, meta, text)
            self.counters["hits"] += 1
            return text
        self.counters["misses"] += 1
        return None

    def get_meta(self, fp: str) -> Optional[dict]:
        hit = self._mem.get(fp)
        if hit is not None:
            return hit[0]
        loaded = self._load_disk(fp)
        return None if loaded is None else loaded[0]

    def put(self, fp: str, meta: dict, text: str) -> None:
        """Insert a plan (canonical JSON text) under its fingerprint."""
        self.counters["puts"] += 1
        self._insert(fp, meta, text)
        if self.cache_dir is not None:
            self.cache_dir.mkdir(parents=True, exist_ok=True)
            self._atomic_write(self._plan_path(fp), text)
            self._atomic_write(self._meta_path(fp),
                               json.dumps(meta, sort_keys=True) + "\n")

    def evict(self, fp: str) -> bool:
        """Drop ``fp`` from both layers; True if anything was removed."""
        removed = self._mem.pop(fp, None) is not None
        if self.cache_dir is not None:
            for p in (self._plan_path(fp), self._meta_path(fp)):
                try:
                    os.remove(p)
                    removed = True
                except FileNotFoundError:
                    pass
        if removed:
            self.counters["evictions"] += 1
        return removed

    def entries(self) -> List[dict]:
        """Every entry's metadata (memory ∪ disk), fingerprint-sorted."""
        metas = {fp: meta for fp, (meta, _) in self._mem.items()}
        if self.cache_dir is not None and self.cache_dir.is_dir():
            for p in self.cache_dir.glob("*.meta.json"):
                fp = p.name[:-len(".meta.json")]
                if fp in metas:
                    continue
                loaded = self._load_disk(fp)
                if loaded is not None:
                    metas[fp] = loaded[0]
        return [metas[fp] for fp in sorted(metas)]

    def stats(self) -> dict:
        disk = 0
        if self.cache_dir is not None and self.cache_dir.is_dir():
            disk = sum(1 for _ in self.cache_dir.glob("*.plan.json"))
        return {**self.counters, "memory_entries": len(self._mem),
                "disk_entries": disk, "max_entries": self.max_entries}

    # -- warm-start neighbor lookup -----------------------------------------

    def nearest(self, meta: dict, *, exclude: str = "",
                max_distance: float = math.inf
                ) -> Optional[Tuple[str, float]]:
        """The cached entry closest to ``meta`` in workload space.

        Candidates must share ``cluster_digest`` and ``strategy`` (an
        incumbent mapping only transfers within the same fleet) and be
        feasible (carry a best mapping).  The bandwidth realisation drifts
        day to day, so candidates must come from the same *or the
        immediately preceding* day — a replan just after midnight may
        still warm-start from last night's incumbent (interconnect drift
        is gradual; the SA seed only sets a starting point), but older
        snapshots are rejected.  Same-day neighbors win ties over
        previous-day ones.  Distance is the sum of absolute log-ratios
        over (seq, bs_global, d_model, n_layers) — 0 for the same
        workload with different budget/space knobs, growing smoothly as
        the neighbor's shape diverges.  Returns ``(fingerprint,
        distance)`` or ``None``.
        """
        best: Optional[Tuple[float, int, str]] = None
        for cand in self.entries():
            fp = cand.get("fingerprint")
            if not fp or fp == exclude:
                continue
            if any(cand.get(k) != meta.get(k)
                   for k in ("cluster_digest", "strategy")):
                continue
            try:
                day_diff = int(meta.get("day")) - int(cand.get("day"))
            except (TypeError, ValueError):
                continue
            if day_diff not in (0, 1):
                continue
            if not cand.get("feasible", True):
                continue
            try:
                dist = math.fsum(
                    abs(math.log(float(cand[k]) / float(meta[k])))
                    for k in ("seq", "bs_global", "d_model", "n_layers"))
            except (KeyError, TypeError, ValueError, ZeroDivisionError):
                continue
            if dist > max_distance:
                continue
            key = (dist, day_diff, fp)
            if best is None or key < best:
                best = key
        return None if best is None else (best[2], best[0])

    # -- internals ----------------------------------------------------------

    def _insert(self, fp: str, meta: dict, text: str) -> None:
        self._mem[fp] = (meta, text)
        self._mem.move_to_end(fp)
        while len(self._mem) > self.max_entries:
            self._mem.popitem(last=False)
            self.counters["lru_evictions"] += 1

    def _load_disk(self, fp: str) -> Optional[Tuple[dict, str]]:
        if self.cache_dir is None:
            return None
        plan_p, meta_p = self._plan_path(fp), self._meta_path(fp)
        try:
            text = plan_p.read_text()
            meta = json.loads(meta_p.read_text())
            # both documents must parse and the sidecar must describe
            # this fingerprint — anything else is corruption
            json.loads(text)
            if (not isinstance(meta, dict)
                    or any(k not in meta for k in _REQUIRED_META)
                    or meta["fingerprint"] != fp):
                raise ValueError("meta sidecar does not match entry")
        except FileNotFoundError:
            return None
        except (OSError, ValueError):
            self.counters["corrupt_dropped"] += 1
            for p in (plan_p, meta_p):
                try:
                    os.remove(p)
                except OSError:
                    pass
            return None
        return meta, text

    @staticmethod
    def _atomic_write(path: Path, text: str) -> None:
        tmp = path.with_suffix(path.suffix + ".tmp")
        tmp.write_text(text)
        os.replace(tmp, path)
