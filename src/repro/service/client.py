"""Blocking client for the plan server (stdlib sockets, one line per op).

``PlanClient`` speaks the newline-delimited JSON protocol of
:class:`~repro.service.server.PlanServer`.  Requests sent through
:meth:`PlanClient.request_many` are pipelined on one connection with
``id`` correlation — the way to *provably* land N requests inside the
server's coalescing / batching window from a single client.
"""
from __future__ import annotations

import json
import socket
from typing import List, Optional

from ..core import PlanRequest
from .wire import encode_plan_request


class ServiceError(RuntimeError):
    """The server answered ``ok: false``; carries the structured error."""

    def __init__(self, error: dict):
        code = error.get("code", "unknown")
        super().__init__(f"[{code}] {error.get('message', '')}")
        self.code = code
        self.error = error


class PlanClient:
    """Client for one plan server.

    Args:
        host / port: server address.  ``port=None`` reads ``port_file``
            (the file ``PlanServer(port_file=...)`` writes on bind).
        timeout: socket timeout in seconds for each exchange.
    """

    def __init__(self, host: str = "127.0.0.1",
                 port: Optional[int] = None, *,
                 port_file=None, timeout: float = 120.0):
        if port is None:
            if port_file is None:
                raise ValueError("need a port or a port_file")
            with open(port_file) as f:
                port = int(f.read().strip())
        self.host, self.port, self.timeout = host, int(port), timeout

    # -- transport ----------------------------------------------------------

    def request_many(self, objs: List[dict]) -> List[dict]:
        """Send every request on ONE connection, pipelined; responses are
        correlated by ``id`` and returned in request order."""
        tagged = [{**o, "id": i} for i, o in enumerate(objs)]
        by_id: dict = {}
        with socket.create_connection((self.host, self.port),
                                      timeout=self.timeout) as s:
            f = s.makefile("rwb")
            for o in tagged:
                f.write((json.dumps(o) + "\n").encode())
            f.flush()
            for _ in tagged:
                line = f.readline()
                if not line:
                    raise ConnectionError(
                        "plan server closed the connection mid-exchange")
                resp = json.loads(line.decode())
                by_id[resp.get("id")] = resp
        missing = [i for i in range(len(tagged)) if i not in by_id]
        if missing:
            raise ConnectionError(
                f"no response for pipelined request(s) {missing}")
        return [by_id[i] for i in range(len(tagged))]

    def request(self, obj: dict) -> dict:
        return self.request_many([obj])[0]

    @staticmethod
    def _checked(resp: dict) -> dict:
        if not resp.get("ok"):
            raise ServiceError(resp.get("error", {}))
        return resp

    # -- ops ----------------------------------------------------------------

    def ping(self) -> bool:
        return bool(self._checked(self.request({"op": "ping"}))["ok"])

    def stats(self) -> dict:
        return self._checked(self.request({"op": "stats"}))["stats"]

    def cache_ls(self) -> List[dict]:
        return self._checked(self.request({"op": "cache_ls"}))["entries"]

    def cache_evict(self, fingerprint: str) -> bool:
        return self._checked(self.request(
            {"op": "cache_evict", "fingerprint": fingerprint}))["evicted"]

    def shutdown(self) -> None:
        self._checked(self.request({"op": "shutdown"}))

    def submit(self, req: PlanRequest, *, strategy: str = "pipette",
               day: int = 0) -> dict:
        """Plan a typed request; returns the full response
        (``resp["plan"]`` is the canonical plan JSON text,
        ``resp["meta"]["cache"]`` one of ``hit|miss|coalesced``).

        Raises:
            ServiceError: structured server rejection (``admission``,
                ``bad-request``, ``verifier``, ``internal``).
        """
        return self._checked(self.request(
            encode_plan_request(req, strategy=strategy, day=day)))

    def submit_many(self, reqs: List[PlanRequest], *,
                    strategy: str = "pipette", day: int = 0) -> List[dict]:
        """Pipeline several typed requests on one connection — all of
        them reach the server inside one batching window."""
        resps = self.request_many(
            [encode_plan_request(r, strategy=strategy, day=day)
             for r in reqs])
        return [self._checked(r) for r in resps]
