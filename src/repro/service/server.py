"""The async plan server: cache -> coalesce -> batch -> warm-start -> search.

One asyncio TCP server (newline-delimited JSON over localhost) serving
``plan`` / ``ping`` / ``stats`` / ``cache_ls`` / ``cache_evict`` /
``shutdown`` ops.  A plan request flows through four layers, cheapest
first:

1. **cache** — the request fingerprint is looked up in the
   :class:`~repro.service.cache.PlanCache`; a hit is verified by the
   static plan verifier against the live spec and returned byte-identical
   without touching any Strategy (a verifier error drops the entry and
   falls through to a cold search);
2. **in-flight coalescing** — N identical concurrent requests share one
   search: the first creates a future under the fingerprint, the rest
   await it (``meta.cache == "coalesced"``);
3. **request batching** — with ``batch_window > 0``, near-identical
   requests (same workload + cluster + search-space shape, pipette or
   exhaustive) arriving within the window are grouped and run through one
   :class:`~repro.core.search.BatchSearchContext` — a single enumeration
   and one jitted ``predict_batch`` forward serve the whole group, each
   member's plan still bit-identical to its standalone search;
4. **warm-started annealing** — a cold pipette search first asks the
   cache for its nearest neighbor (same cluster/strategy, same or
   previous day, closest workload); the neighbor's best mapping seeds
   every SA chain via
   ``Budget.warm_start``, and the plan records the lineage
   (``provenance.lineage.warm_start_from``).

Searches execute on a single worker thread (``ThreadPoolExecutor(1)``) so
concurrent requests cannot interleave JAX dispatch; the event loop stays
free to accept, coalesce, and answer cache hits while a search runs.
Admission is typed: a request whose cluster spec fails ``ClusterSpec``
validation is rejected with a structured ``admission`` error before any
search work happens.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..analysis.plan_verifier import verify_plan_dict
from ..core import (BatchSearchContext, MegatronStrategy, Plan, Planner,
                    PlanRequest, profile_bandwidth, true_bandwidth_matrix)
from ..core.plan import STRATEGIES
from .cache import PlanCache
from .wire import (AdmissionError, WireError, cluster_digest,
                   decode_plan_request, incumbent_perm, request_meta)

#: strategies whose searches can share a BatchSearchContext
_BATCHABLE = ("pipette", "exhaustive")


@dataclasses.dataclass
class _Member:
    """One request waiting in a batch group."""
    req: PlanRequest
    meta: dict
    lineage: Optional[dict]
    future: "asyncio.Future"


class PlanServer:
    """The planning-as-a-service daemon.  See module docstring.

    Args:
        host / port: bind address; port 0 picks an ephemeral port
            (written to ``port_file`` when given, so shell clients can
            discover it).
        cache_dir: persistent cache directory (``None`` = memory-only).
        max_entries: in-memory LRU capacity of the plan cache.
        warm_start: enable nearest-neighbor warm-started annealing.
        warm_max_distance: log-scale workload distance beyond which a
            neighbor is not worth seeding from.
        batch_window: seconds to hold a batchable request open for
            grouping (0 disables batching).
        estimator: optional memory estimator shared by every pipette /
            exhaustive search (and their batched contexts).
        plan_fn: test hook — replaces the single-request compute path
            (``fn(req, strategy_name, day, lineage) -> Plan``); batching
            is disabled while set.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 cache: Optional[PlanCache] = None, cache_dir=None,
                 max_entries: int = 256, warm_start: bool = True,
                 warm_max_distance: float = 2.0,
                 batch_window: float = 0.0, estimator=None,
                 plan_fn=None, port_file=None):
        self.host, self.port = host, port
        self.cache = cache if cache is not None else PlanCache(
            cache_dir, max_entries=max_entries)
        self.warm_start = warm_start
        self.warm_max_distance = warm_max_distance
        self.batch_window = batch_window if plan_fn is None else 0.0
        self.estimator = estimator
        self.plan_fn = plan_fn
        self.port_file = port_file
        self.counters: Dict[str, int] = {
            "requests": 0, "cache_hits": 0, "cache_invalid": 0,
            "coalesced": 0, "searches_run": 0, "batch_groups": 0,
            "batched_members": 0, "predict_batches": 0,
            "warm_starts": 0, "admission_rejects": 0, "bad_requests": 0,
        }
        self._inflight: Dict[str, "asyncio.Future"] = {}
        self._groups: Dict[tuple, List[_Member]] = {}
        self._bw_cache: Dict[Tuple[str, int], np.ndarray] = {}
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._ready = threading.Event()
        self._stop: Optional[asyncio.Event] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None

    # -- lifecycle ----------------------------------------------------------

    async def serve(self) -> None:
        """Bind, announce readiness, and serve until ``shutdown``."""
        self._loop = asyncio.get_running_loop()
        self._stop = asyncio.Event()
        server = await asyncio.start_server(self._handle_conn, self.host,
                                            self.port)
        self.port = server.sockets[0].getsockname()[1]
        if self.port_file is not None:
            with open(self.port_file, "w") as f:
                f.write(f"{self.port}\n")
        self._ready.set()
        try:
            async with server:
                await self._stop.wait()
        finally:
            self._ready.clear()
            self._pool.shutdown(wait=True)

    def run(self) -> None:
        """Blocking entry point (the CLI ``serve`` command)."""
        asyncio.run(self.serve())

    def start_in_thread(self, timeout: float = 30.0) -> threading.Thread:
        """Run the server on a daemon thread; returns once it is bound
        (``self.port`` holds the resolved port)."""
        t = threading.Thread(target=self.run, daemon=True,
                             name="plan-server")
        t.start()
        if not self._ready.wait(timeout):
            raise RuntimeError("plan server failed to start")
        return t

    def stop(self) -> None:
        """Request shutdown from any thread."""
        if self._loop is not None and self._stop is not None:
            self._loop.call_soon_threadsafe(self._stop.set)

    # -- connection handling ------------------------------------------------

    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        # requests on one connection are served concurrently (a cache hit
        # must not queue behind a long search), with a write lock keeping
        # response lines whole; clients correlate via the echoed "id"
        wlock = asyncio.Lock()
        tasks: List[asyncio.Task] = []
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                tasks.append(asyncio.ensure_future(
                    self._serve_line(line, writer, wlock)))
        finally:
            for t in tasks:
                try:
                    await t
                except Exception:
                    pass
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _serve_line(self, line: bytes, writer: asyncio.StreamWriter,
                          wlock: asyncio.Lock) -> None:
        shutdown = False
        obj: dict = {}
        try:
            decoded = json.loads(line.decode())
            if not isinstance(decoded, dict):
                raise WireError("request must be a JSON object")
            obj = decoded
        except (UnicodeDecodeError, ValueError) as e:
            self.counters["bad_requests"] += 1
            resp = {"ok": False, "error": {"code": "bad-request",
                                           "message": f"invalid JSON: {e}"}}
        else:
            try:
                resp = await self._dispatch(obj)
            except AdmissionError as e:
                self.counters["admission_rejects"] += 1
                resp = {"ok": False,
                        "error": {"code": "admission", "message": str(e)}}
            except WireError as e:
                self.counters["bad_requests"] += 1
                resp = {"ok": False,
                        "error": {"code": "bad-request", "message": str(e)}}
            except Exception as e:
                resp = {"ok": False,
                        "error": {"code": "internal",
                                  "message": f"{type(e).__name__}: {e}"}}
            shutdown = bool(resp.pop("_shutdown", False))
        if "id" in obj:
            resp["id"] = obj["id"]
        data = (json.dumps(resp, sort_keys=True) + "\n").encode()
        async with wlock:
            try:
                writer.write(data)
                await writer.drain()
            except (ConnectionError, OSError):
                pass
        if shutdown and self._stop is not None:
            self._stop.set()

    # -- ops ----------------------------------------------------------------

    async def _dispatch(self, obj: dict) -> dict:
        op = obj.get("op", "plan")
        if op == "ping":
            return {"ok": True, "op": "ping"}
        if op == "stats":
            return {"ok": True,
                    "stats": {**self.counters, "cache": self.cache.stats()}}
        if op == "cache_ls":
            return {"ok": True, "entries": self.cache.entries()}
        if op == "cache_evict":
            fp = obj.get("fingerprint")
            if not isinstance(fp, str) or not fp:
                raise WireError("cache_evict needs a 'fingerprint' string")
            return {"ok": True, "evicted": self.cache.evict(fp)}
        if op == "shutdown":
            return {"ok": True, "op": "shutdown", "_shutdown": True}
        if op == "plan":
            return await self._plan_op(obj)
        raise WireError(f"unknown op {op!r}")

    async def _plan_op(self, obj: dict) -> dict:
        t0 = time.perf_counter()
        self.counters["requests"] += 1
        req, strategy, day = decode_plan_request(obj)
        meta = request_meta(req, strategy, day)
        fp = meta["fingerprint"]

        # layer 1: the plan cache — hits are verified, then returned
        # byte-identical without invoking any Strategy
        text = self.cache.get(fp)
        if text is not None:
            errors = [str(i) for i in verify_plan_dict(json.loads(text),
                                                       spec=req.spec)
                      if i.severity == "error"]
            if errors:
                self.counters["cache_invalid"] += 1
                self.cache.evict(fp)
            else:
                self.counters["cache_hits"] += 1
                return self._ok(text, fp, "hit", None, t0)

        # layer 2: in-flight coalescing — identical concurrent requests
        # share one search
        fut = self._inflight.get(fp)
        if fut is not None:
            self.counters["coalesced"] += 1
            text, lineage, err = await asyncio.shield(fut)
            if err is not None:
                return {"ok": False, "error": err}
            return self._ok(text, fp, "coalesced", lineage, t0)

        assert self._loop is not None
        fut = self._loop.create_future()
        self._inflight[fp] = fut
        try:
            text, lineage, err = await self._produce(req, strategy, day,
                                                     meta)
            fut.set_result((text, lineage, err))
        except BaseException as e:
            fut.set_result((None, None,
                            {"code": "internal",
                             "message": f"{type(e).__name__}: {e}"}))
            raise
        finally:
            self._inflight.pop(fp, None)
        if err is not None:
            return {"ok": False, "error": err}
        return self._ok(text, fp, "miss", lineage, t0)

    def _ok(self, text: str, fp: str, cache: str,
            lineage: Optional[dict], t0: float) -> dict:
        meta = {"cache": cache, "fingerprint": fp,
                "elapsed_s": time.perf_counter() - t0}
        if lineage is not None:
            meta["warm_start_from"] = lineage.get("warm_start_from")
        return {"ok": True, "plan": text, "meta": meta}

    # -- the compute path ---------------------------------------------------

    async def _produce(self, req: PlanRequest, strategy: str, day: int,
                       meta: dict):
        """Compute (directly or via a batch group) -> verify -> cache.

        Returns ``(plan_text, lineage, error_dict_or_None)``.
        """
        warm_req, lineage = self._warm(req, strategy, day, meta)
        if (self.batch_window > 0 and strategy in _BATCHABLE):
            plan = await self._via_group(warm_req, strategy, day, meta,
                                         lineage)
        else:
            self.counters["searches_run"] += 1
            plan = await self._loop.run_in_executor(
                self._pool, self._compute_one, warm_req, strategy, day,
                lineage)
        text = plan.to_json()
        errors = [str(i) for i in verify_plan_dict(json.loads(text),
                                                   spec=req.spec)
                  if i.severity == "error"]
        if errors:
            return None, None, {"code": "verifier",
                                "message": "computed plan failed "
                                           "verification",
                                "issues": errors}
        self.cache.put(meta["fingerprint"],
                       {**meta, "feasible": plan.feasible,
                        "warm_started": lineage is not None},
                       text)
        return text, lineage, None

    def _warm(self, req: PlanRequest, strategy: str, day: int,
              meta: dict) -> Tuple[PlanRequest, Optional[dict]]:
        """Seed a cold pipette request from its nearest cached neighbor."""
        if (not self.warm_start or strategy != "pipette"
                or req.budget.warm_start is not None):
            return req, None
        nb = self.cache.nearest(meta, exclude=meta["fingerprint"],
                                max_distance=self.warm_max_distance)
        if nb is None:
            return req, None
        nfp, dist = nb
        ntext = self.cache.get(nfp)
        if ntext is None:
            return req, None
        try:
            perm = incumbent_perm(json.loads(ntext))
        except ValueError:
            return req, None
        if perm is None or perm.shape != (req.spec.n_gpus,):
            return req, None
        warm = dataclasses.replace(
            req, budget=dataclasses.replace(
                req.budget, warm_start=tuple(int(x) for x in perm)))
        self.counters["warm_starts"] += 1
        return warm, {"warm_start_from": nfp, "distance": dist}

    def _compute_one(self, req: PlanRequest, strategy: str, day: int,
                     lineage: Optional[dict]) -> Plan:
        """Single-request compute (worker thread)."""
        if self.plan_fn is not None:
            return self.plan_fn(req, strategy, day, lineage)
        bw = self._bandwidth(req, day)
        return Planner(self._strategy(strategy, req)).plan(
            req, bw, lineage=lineage)

    def _strategy(self, name: str, req: PlanRequest):
        cls = STRATEGIES[name]
        if name in _BATCHABLE:
            return cls(estimator=self.estimator,
                       mem_limit=req.spec.mem_floor)
        if name == "megatron-lm":
            return MegatronStrategy(
                bw_true=true_bandwidth_matrix(req.spec))
        return cls()

    def _bandwidth(self, req: PlanRequest, day: int) -> np.ndarray:
        key = (cluster_digest(req.spec), day)
        bw = self._bw_cache.get(key)
        if bw is None:
            bw, _ = profile_bandwidth(req.spec, day)
            self._bw_cache[key] = bw
        return bw

    # -- batching -----------------------------------------------------------

    @staticmethod
    def _group_key(meta: dict, req: PlanRequest, strategy: str,
                   day: int) -> tuple:
        s = req.space
        return (meta["workload_digest"], meta["cluster_digest"], strategy,
                day, s.partition, s.max_cp, s.max_tp, s.max_vpp)

    async def _via_group(self, req: PlanRequest, strategy: str, day: int,
                         meta: dict, lineage: Optional[dict]) -> Plan:
        """Join (or open) the batch group for this request's shape."""
        assert self._loop is not None
        key = self._group_key(meta, req, strategy, day)
        member = _Member(req, meta, lineage, self._loop.create_future())
        group = self._groups.get(key)
        if group is None:
            self._groups[key] = [member]
            self._loop.create_task(self._close_group(key, strategy, day))
        else:
            group.append(member)
        plan, err = await member.future
        if err is not None:
            raise err
        return plan

    async def _close_group(self, key: tuple, strategy: str,
                           day: int) -> None:
        """Hold the window open, then run the whole group as one
        BatchSearchContext job on the worker thread."""
        await asyncio.sleep(self.batch_window)
        members = self._groups.pop(key, [])
        if not members:
            return
        self.counters["batch_groups"] += 1
        self.counters["batched_members"] += len(members)
        self.counters["searches_run"] += len(members)
        try:
            plans, n_pred = await self._loop.run_in_executor(
                self._pool, self._compute_group, members, strategy, day)
            self.counters["predict_batches"] += n_pred
            for m, plan in zip(members, plans):
                m.future.set_result((plan, None))
        except Exception as e:
            for m in members:
                if not m.future.done():
                    m.future.set_result((None, e))

    def _compute_group(self, members: List[_Member], strategy: str,
                       day: int):
        """Worker-thread body: one shared context, one search per member.

        Bit-identical to running each member standalone — the context's
        stages 1-4 are per-conf independent and the per-member stage 5 is
        exactly ``run_search``'s (see BatchSearchContext).
        """
        reqs = [m.req for m in members]
        spec = reqs[0].spec
        bw = self._bandwidth(reqs[0], day)
        ctx = BatchSearchContext.for_requests(
            reqs, bw, estimator=self.estimator, mem_limit=spec.mem_floor)
        dedicate = strategy == "pipette"
        plans = []
        for m in members:
            res = ctx.search(m.req, dedicate=dedicate)
            plans.append(Plan.from_search(
                res, m.req, bw, strategy=strategy,
                estimator=self.estimator, lineage=m.lineage))
        return plans, ctx.n_predict_batches
