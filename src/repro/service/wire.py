"""Wire format of the plan service: request/response dicts, fingerprints.

Everything a client sends is one newline-delimited JSON object; a plan
request carries the workload, the cluster (a named preset or an inline
spec), the search-space and budget knobs, the seed, the strategy name,
and the bandwidth realisation ``day``.  This module decodes those dicts
into the typed Planner request — and, crucially, computes the **canonical
fingerprints** the plan cache is keyed on:

- :func:`workload_digest` — SHA-256 of the canonical workload wire dict;
- :func:`cluster_digest` — SHA-256 of the spec's scalar fields plus its
  :func:`~repro.core.cluster.tier_table_fingerprint` (so two specs that
  price identically share a digest, and a re-tiered fleet changes it);
- :func:`request_fingerprint` — SHA-256 over (workload digest, cluster
  digest, space, budget, seed, strategy, day): the full determinism
  domain of a plan.  Identical fingerprints MUST produce byte-identical
  plans, which is exactly what makes the cache sound.

Two error types separate "you sent garbage" from "your cluster is
invalid": :class:`WireError` (malformed request -> ``bad-request``) and
:class:`AdmissionError` (the spec/workload failed the typed constructor
validation -> the server's structured ``admission`` rejection).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Optional, Tuple

import numpy as np

from ..core import Budget, PlanRequest, SearchSpace, Workload, mapping_to_perm
from ..core.cluster import ClusterSpec, DeviceTier, tier_table_fingerprint
from ..core.plan import STRATEGIES, _budget_out
from ..core.simulator import Conf  # noqa: F401  (re-export convenience)
from ..models.config import ModelConfig


class WireError(ValueError):
    """Malformed service request (missing/mistyped fields, unknown model
    or strategy name) — maps to the ``bad-request`` error code."""


class AdmissionError(ValueError):
    """The request decoded, but its cluster spec or workload failed the
    typed validation (``ClusterSpec``/``DeviceTier`` named-field checks)
    — maps to the server's structured ``admission`` rejection."""


def canonical_json(obj) -> str:
    """Canonical compact JSON: sorted keys, no whitespace — the hashing
    normal form for every fingerprint below."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      allow_nan=False)


def _sha256(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()


# ---------------------------------------------------------------------------
# workload
# ---------------------------------------------------------------------------

def workload_to_wire(w: Workload) -> dict:
    """Serialize a workload: the full inline model config + the scalars."""
    return {"config": dataclasses.asdict(w.cfg), "seq": int(w.seq),
            "bs_global": int(w.bs_global), "grad_bytes": int(w.grad_bytes)}


def workload_from_wire(d: dict) -> Workload:
    """Decode a workload wire dict.

    ``config`` is either an inline :class:`~repro.models.config.ModelConfig`
    field dict or a registered config name (``repro.configs.get``).
    """
    if not isinstance(d, dict):
        raise WireError(f"workload must be an object, got {type(d).__name__}")
    cfg = d.get("config")
    if isinstance(cfg, str):
        from ..configs import get as get_config
        try:
            model = get_config(cfg)
        except KeyError:
            raise WireError(f"unknown model config name {cfg!r}") from None
    elif isinstance(cfg, dict):
        try:
            model = ModelConfig(**cfg)
        except (TypeError, ValueError) as e:
            raise WireError(f"bad inline model config: {e}") from e
    else:
        raise WireError("workload.config must be a name or a config object")
    try:
        return Workload(model, int(d["seq"]), int(d["bs_global"]),
                        grad_bytes=int(d.get("grad_bytes", 4)))
    except (KeyError, TypeError, ValueError) as e:
        raise WireError(f"bad workload: {e!r}") from e


def workload_digest(w: Workload) -> str:
    """SHA-256 of the canonical workload wire dict (name-decoded configs
    and inline configs with identical fields share a digest)."""
    return _sha256(canonical_json(workload_to_wire(w)))


# ---------------------------------------------------------------------------
# cluster
# ---------------------------------------------------------------------------

_SPEC_SCALARS = ("name", "n_nodes", "gpus_per_node", "intra_bw", "inter_bw",
                 "gpu_flops", "gpu_mem", "efficiency", "heterogeneity",
                 "slow_frac", "seed")


def spec_to_wire(spec: ClusterSpec) -> dict:
    """Serialize a cluster spec inline (scalars + tier table)."""
    d = {k: getattr(spec, k) for k in _SPEC_SCALARS}
    d["tiers"] = [[t.flops, t.mem, t.efficiency, t.name] for t in spec.tiers]
    d["node_tiers"] = [int(t) for t in spec.node_tiers]
    return d


def spec_from_wire(d: dict) -> ClusterSpec:
    """Decode a cluster wire dict: ``{"preset": name, "nodes": n}`` or an
    inline spec (:func:`spec_to_wire` shape).

    Raises:
        WireError: structurally malformed / unknown preset.
        AdmissionError: the spec fails the typed ``ClusterSpec`` /
            ``DeviceTier`` validation — the named-field message is
            preserved for the structured rejection.
    """
    if not isinstance(d, dict):
        raise WireError(f"cluster must be an object, got {type(d).__name__}")
    preset = d.get("preset")
    if preset is not None:
        from ..plan import CLUSTERS
        if preset not in CLUSTERS:
            raise WireError(
                f"unknown cluster preset {preset!r} "
                f"(known: {sorted(CLUSTERS)})")
        spec = CLUSTERS[preset]
        nodes = d.get("nodes")
        if nodes is not None:
            try:
                spec = spec.with_nodes(int(nodes))
            except (TypeError, ValueError) as e:
                raise AdmissionError(f"bad node count {nodes!r}: {e}") from e
        return spec
    try:
        tiers = tuple(DeviceTier(*t) for t in d.get("tiers", ()))
        kwargs = {k: d[k] for k in _SPEC_SCALARS if k in d}
        return ClusterSpec(tiers=tiers,
                           node_tiers=tuple(int(t)
                                            for t in d.get("node_tiers", ())),
                           **kwargs)
    except (ValueError,) as e:
        raise AdmissionError(str(e)) from e
    except TypeError as e:
        raise WireError(f"bad cluster spec: {e}") from e


def cluster_digest(spec: ClusterSpec) -> str:
    """SHA-256 over the spec scalars + the tier-table fingerprint.

    The tier table is folded in through
    :func:`~repro.core.cluster.tier_table_fingerprint` — the same recipe
    the plan verifier uses — so the digest moves whenever the fleet
    composition does."""
    doc = {k: getattr(spec, k) for k in _SPEC_SCALARS}
    doc["tier_fp"] = (tier_table_fingerprint(
        [(t.flops, t.mem, t.efficiency, t.name) for t in spec.tiers],
        spec.node_tiers) if spec.tiers else None)
    return _sha256(canonical_json(doc))


# ---------------------------------------------------------------------------
# the full plan request
# ---------------------------------------------------------------------------

def encode_plan_request(req: PlanRequest, *, strategy: str = "pipette",
                        day: int = 0) -> dict:
    """Typed request -> wire dict (the client-side encoder)."""
    return {"op": "plan",
            "workload": workload_to_wire(req.workload),
            "cluster": spec_to_wire(req.spec),
            "space": dataclasses.asdict(req.space),
            "budget": _budget_out(req.budget),
            "seed": int(req.seed),
            "strategy": strategy,
            "day": int(day)}


def decode_plan_request(d: dict) -> Tuple[PlanRequest, str, int]:
    """Wire dict -> ``(PlanRequest, strategy_name, day)``.

    Raises:
        WireError / AdmissionError — see module docstring.
    """
    strategy = d.get("strategy", "pipette")
    if strategy not in STRATEGIES:
        raise WireError(f"unknown strategy {strategy!r} "
                        f"(known: {sorted(STRATEGIES)})")
    workload = workload_from_wire(d.get("workload"))
    spec = spec_from_wire(d.get("cluster"))
    try:
        space = SearchSpace(**(d.get("space") or {}))
        budget = Budget(**(d.get("budget") or {}))
    except TypeError as e:
        raise WireError(f"bad space/budget knobs: {e}") from e
    except ValueError as e:
        raise AdmissionError(str(e)) from e
    try:
        seed = int(d.get("seed", 0))
        day = int(d.get("day", 0))
    except (TypeError, ValueError) as e:
        raise WireError(f"seed/day must be integers: {e}") from e
    return (PlanRequest(workload=workload, spec=spec, space=space,
                        budget=budget, seed=seed),
            strategy, day)


def request_fingerprint(req: PlanRequest, strategy: str, day: int) -> str:
    """The cache key: SHA-256 over the full determinism domain of a plan
    — workload digest, cluster digest, space, budget (including any
    explicit ``warm_start``), seed, strategy, day."""
    doc = {"workload": workload_digest(req.workload),
           "cluster": cluster_digest(req.spec),
           "space": dataclasses.asdict(req.space),
           "budget": _budget_out(req.budget),
           "seed": int(req.seed),
           "strategy": strategy,
           "day": int(day)}
    return _sha256(canonical_json(doc))


def request_meta(req: PlanRequest, strategy: str, day: int) -> dict:
    """The sidecar metadata a cache entry records: the fingerprint plus
    the coarse workload coordinates the nearest-neighbor warm-start
    lookup measures distance over."""
    w = req.workload
    return {"fingerprint": request_fingerprint(req, strategy, day),
            "workload_digest": workload_digest(w),
            "cluster_digest": cluster_digest(req.spec),
            "strategy": strategy,
            "day": int(day),
            "model": w.cfg.name,
            "seq": int(w.seq),
            "bs_global": int(w.bs_global),
            "d_model": int(w.cfg.d_model),
            "n_layers": int(w.cfg.n_layers),
            "n_gpus": int(req.spec.n_gpus)}


def incumbent_perm(plan_dict: dict) -> Optional[np.ndarray]:
    """Extract the flat GPU permutation behind a serialized plan's best
    mapping (the warm-start seed), or ``None`` for infeasible plans or
    undecodable documents.  The permutation is shape-agnostic: SA reshapes
    it per candidate conf, so one incumbent seeds every chain of a
    neighboring search."""
    try:
        best = plan_dict.get("best")
        if best is None:
            return None
        m = best["mapping"]
        mapping = np.asarray(m["data"],
                             dtype=np.dtype(m["dtype"])) \
            .reshape(tuple(m["shape"]))
        return mapping_to_perm(mapping)
    except (KeyError, TypeError, ValueError):
        return None
