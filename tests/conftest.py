import os
import sys

# Tests run single-device (the dry-run sets its own 512-device flag in a
# subprocess); make sure nothing leaks XLA_FLAGS into this process.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
