"""Suppression fixture: one malformed noqa, one unused noqa."""
import time


def stamp():
    return time.time()  # repro: noqa


def quiet():
    return 7  # repro: noqa DET001 -- nothing to suppress on this line
