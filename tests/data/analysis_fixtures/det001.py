"""DET001 fixture: legacy process-global RNG draw."""
import numpy as np


def roll():
    return np.random.rand(3)
