"""DET002 fixture: wall-clock read reaching a returned value."""
import time


def manifest():
    return {"stamp": time.time()}
