"""DET003 fixture: plain array sum where pairwise order must be pinned."""
import numpy as np


def stage_total(c_x):
    return float(np.sum(c_x))
