"""DET004 fixture: builtin left-fold sum over floats."""


def normalize(fractions):
    return float(sum(fractions))
