"""DET005 fixture: exact float-literal equality on a computed value."""


def is_unit(x):
    return x * x == 1.0
