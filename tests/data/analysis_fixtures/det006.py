"""DET006 fixture: set iteration feeding order-sensitive accumulation."""


def gather(xs):
    out = []
    for x in set(xs):
        out.append(x * 2.0)
    return out
