"""DET007 fixture: host-side effect inside a jitted function."""
import jax


@jax.jit
def step(x):
    print("tracing", x)
    return x * 2
