"""Exclusion fixture: has a violation but the config excludes this file."""
import numpy as np


def roll():
    return np.random.rand(2)
