"""Suppression fixture: a real finding silenced with a reasoned noqa."""
import time


def overhead():
    return time.time()  # repro: noqa DET002 -- fixture exercising reasoned suppressions
