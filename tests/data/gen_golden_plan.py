"""Regenerate ``golden_plan_v5.json`` — the checked-in Plan JSON fixture.

The fixture is the serialized Plan of a fixed, iteration-bound (fully
deterministic) Pipette search on the mixed A100/V100 16x1 cluster, so it
exercises the heterogeneous tier-provenance fields.  Regenerate ONLY on an
*intentional* schema change, together with a PLAN_SCHEMA_VERSION bump
(tests/test_plan_golden.py refuses shape changes without one):

    PYTHONPATH=src python tests/data/gen_golden_plan.py
"""
import pathlib

from repro.core import (Budget, Planner, PlanRequest, PipetteStrategy,
                        SearchSpace, Workload, profile_bandwidth)
from repro.core.cluster import A100_TIER, V100_TIER, mixed_fleet_spec
from repro.models.config import ModelConfig

OUT = pathlib.Path(__file__).parent / "golden_plan_v5.json"

GPT = ModelConfig(name="g12", family="dense", n_layers=12, d_model=1024,
                  n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=32000)
SPEC = mixed_fleet_spec("mixed-a100-v100-16x1", 16, (A100_TIER, V100_TIER),
                        (0.5, 0.5), gpus_per_node=1, seed=47)
REQ = PlanRequest(workload=Workload(GPT, 2048, 32), spec=SPEC,
                  space=SearchSpace(max_micro=2),
                  budget=Budget(sa_seconds=60.0, sa_iters=50, sa_topk=2),
                  seed=9)


def main() -> None:
    bw, _ = profile_bandwidth(SPEC)
    plan = Planner(PipetteStrategy()).plan(REQ, bw)
    plan.save(OUT)
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
