"""Regenerate ``homog_regression.json`` — the pre-heterogeneity behaviour pin.

The fixture freezes, bit-for-bit (float hex), what the configurator produced
for *homogeneous* clusters before per-GPU device tiers existed:

* a MID_RANGE 3D search (ranked confs + latencies + best mapping),
* the same request with ``max_cp=2`` (4D),
* ``ground_truth_memory`` over a conf grid,
* ``pipette_latency`` of a few default mappings.

``tests/test_hetero_regression.py`` replays the same requests and compares
against this file, guaranteeing the heterogeneous-compute refactor is an
exact no-op for single-tier/scalar specs.  Regenerate ONLY when an
intentional model change lands (and say so in the commit):

    PYTHONPATH=src python tests/data/gen_regression_fixture.py
"""
import json
import pathlib

import numpy as np

from repro.core import (MID_RANGE, Conf, Workload, configure,
                        ground_truth_memory, pipette_latency,
                        profile_bandwidth, build_profile, default_mapping)
from repro.configs.gpt_paper import GPT_3_1B

OUT = pathlib.Path(__file__).parent / "homog_regression.json"

SEARCH_KW = dict(sa_seconds=60.0, sa_iters=60, sa_topk=4, max_micro=4,
                 seed=3)


def _search_block(max_cp: int) -> dict:
    spec = MID_RANGE
    w = Workload(GPT_3_1B, 2048, 256)
    bw, _ = profile_bandwidth(spec)
    res = configure(w, spec, bw, max_cp=max_cp, **SEARCH_KW)
    return {
        "ranked": [
            {"conf": [c.conf.pp, c.conf.tp, c.conf.cp, c.conf.dp,
                      c.conf.bs_micro, c.conf.bs_global],
             "latency": c.latency.hex()}
            for c in res.ranked
        ],
        "best_mapping": np.asarray(res.best.mapping).reshape(-1).tolist(),
    }


def _memory_block() -> dict:
    spec = MID_RANGE
    w = Workload(GPT_3_1B, 2048, 256)
    out = {}
    for conf in [Conf(4, 8, 4, 2, 256), Conf(2, 8, 8, 1, 256),
                 Conf(8, 4, 4, 1, 256), Conf(1, 8, 16, 4, 256),
                 Conf(4, 4, 4, 2, 256, cp=2), Conf(2, 4, 8, 1, 256, cp=2)]:
        key = f"{conf.pp},{conf.tp},{conf.cp},{conf.dp},{conf.bs_micro}"
        out[key] = ground_truth_memory(w, conf, spec).hex()
    return out


def _latency_block() -> dict:
    spec = MID_RANGE
    w = Workload(GPT_3_1B, 2048, 256)
    bw, _ = profile_bandwidth(spec)
    out = {}
    for conf in [Conf(4, 8, 4, 2, 256), Conf(8, 4, 4, 1, 256),
                 Conf(4, 4, 4, 2, 256, cp=2)]:
        prof = build_profile(w, spec, conf)
        lat = pipette_latency(conf, default_mapping(conf), bw, prof, spec)
        key = f"{conf.pp},{conf.tp},{conf.cp},{conf.dp},{conf.bs_micro}"
        out[key] = lat.hex()
    return out


def main() -> None:
    fixture = {
        "search_3d": _search_block(max_cp=1),
        "search_4d_max_cp2": _search_block(max_cp=2),
        "ground_truth_memory": _memory_block(),
        "default_mapping_latency": _latency_block(),
    }
    OUT.write_text(json.dumps(fixture, indent=1, sort_keys=True) + "\n")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
