"""4D (context-parallel) configurator: enumeration gates, cp=1
bit-exactness, cp>1 estimator/engine/simulator equivalences, and the
long-context scenario the 3D space cannot serve."""
import numpy as np
import pytest

from repro.core import (MID_RANGE, Conf, Workload, build_profile, configure,
                        default_mapping, dp_allreduce_times,
                        dp_allreduce_times_ref, enumerate_confs,
                        fit_memory_estimator, ground_truth_memory, measure,
                        pipette_latency, pipette_latency_ref,
                        profile_bandwidth, true_bandwidth_matrix)
from repro.core.dedication import (DedicationEngine, GroupIndex, _move_span,
                                   perm_to_mapping)
from repro.core.latency import default_mapping_latencies
from repro.core.simulator import ProfileCache, mapping4
from repro.configs.gemma3_12b import CONFIG as GEMMA3
from repro.models.config import ModelConfig

GPT = ModelConfig(name="g", family="dense", n_layers=24, d_model=1920,
                  n_heads=20, n_kv_heads=20, d_ff=7680, vocab_size=51200)
SPEC = MID_RANGE.with_nodes(4)
SEQ = 2048


# ---------------------------------------------------------------------------
# enumeration: schedule validity (the bugfix) + the 4D gates
# ---------------------------------------------------------------------------

def test_enumerate_drops_unschedulable_confs():
    """The motivating bug: at G=8, bs=8 the unfiltered space contains 10
    configurations with n_mb < pp that memory-efficient 1F1B cannot fill."""
    loose = enumerate_confs(8, 8, strict=False)
    strict = enumerate_confs(8, 8)
    bad = [c for c in loose if c.n_mb < c.pp]
    assert len(bad) == 10
    assert len(loose) - len(strict) == 10
    assert strict == [c for c in loose if c.n_mb >= c.pp]


def test_every_enumerated_conf_valid_and_schedulable():
    """Property (non-hypothesis twin of the test_memory_estimator one):
    every conf from a strict enumeration is valid and 1F1B-schedulable,
    including in 4D."""
    for g, bs, max_cp in [(8, 8, 1), (16, 64, 1), (32, 128, 4),
                          (64, 256, 8), (24, 48, 2)]:
        confs = enumerate_confs(g, bs, n_layers=32, max_cp=max_cp, seq=SEQ)
        assert confs
        for c in confs:
            assert c.pp * c.tp * c.cp * c.dp == g
            assert c.valid() and c.schedulable()
            assert c.n_mb >= c.pp
            assert SEQ % c.cp == 0
        assert len({(c.pp, c.tp, c.cp, c.dp, c.bs_micro)
                    for c in confs}) == len(confs)


def test_enumerate_cp_requires_seq():
    """cp > 1 without a sequence length (or with a non-dividing one) is
    never emitted: ring attention needs seq % cp == 0."""
    assert all(c.cp == 1 for c in enumerate_confs(16, 16, max_cp=4))
    confs = enumerate_confs(16, 16, max_cp=4, seq=6)
    assert {c.cp for c in confs} <= {1, 2}      # 4 does not divide 6


def test_conf_valid_rejects_zero_microbatches():
    assert not Conf(1, 1, 1, 4, 0).valid()          # n_mb == 0
    assert not Conf(1, 1, 2, 1, 3).valid()          # dp does not divide
    assert Conf(1, 1, 1, 1, 1).valid()
    assert not Conf(4, 1, 1, 1, 2).schedulable()    # n_mb=2 < pp=4
    assert Conf(2, 1, 1, 1, 2).schedulable()


def test_cp1_enumeration_is_the_3d_space():
    """max_cp=1 (the default) must reproduce the 3D enumeration exactly —
    same confs, same order — whether or not seq is supplied."""
    a = enumerate_confs(32, 64, n_layers=24)
    b = enumerate_confs(32, 64, n_layers=24, max_cp=1, seq=SEQ)
    assert a == b
    assert all(c.cp == 1 for c in a)


# ---------------------------------------------------------------------------
# cp > 1 model equivalences (vectorized == reference == engine)
# ---------------------------------------------------------------------------

def _cp_cases():
    return [Conf(2, 2, 2, 2, 64, cp=4), Conf(1, 4, 2, 1, 16, cp=4),
            Conf(4, 2, 1, 2, 32, cp=4), Conf(2, 4, 2, 2, 32, cp=2),
            Conf(1, 1, 4, 1, 8, cp=8)]


def test_cp_latency_vectorized_matches_reference_exactly():
    rng = np.random.default_rng(0)
    bw = true_bandwidth_matrix(SPEC)
    for conf in _cp_cases():
        prof = build_profile(Workload(GPT, SEQ, conf.bs_global), SPEC, conf)
        assert prof.t_cp_fwd > 0 and prof.msg_cp > 0
        for _ in range(8):
            m = perm_to_mapping(rng.permutation(conf.n_gpus), conf)
            assert m.shape == (conf.pp, conf.tp, conf.cp, conf.dp)
            vec = pipette_latency(conf, m, bw, prof, SPEC)
            ref = pipette_latency_ref(conf, m, bw, prof, SPEC)
            assert vec == ref, (str(conf), vec - ref)
            assert np.array_equal(
                dp_allreduce_times(conf, m, bw, prof, SPEC),
                dp_allreduce_times_ref(conf, m, bw, prof, SPEC))


def test_cp_engine_score_and_delta_match_latency():
    """Full scores and incremental move re-scores of the 4D engine are
    bit-equal to pipette_latency, across accepted and rejected moves."""
    rng = np.random.default_rng(1)
    bw = true_bandwidth_matrix(SPEC)
    for conf in _cp_cases():
        prof = build_profile(Workload(GPT, SEQ, conf.bs_global), SPEC, conf)
        idx = GroupIndex.build(conf)
        assert idx.pos_cp is not None and idx.pos_cp.shape == \
            (conf.pp * conf.tp * conf.dp, conf.cp)
        eng = DedicationEngine(conf, bw, prof, SPEC, index=idx)
        perm = rng.permutation(conf.n_gpus)
        assert eng.score(perm) == pipette_latency(
            conf, perm_to_mapping(perm, conf), bw, prof, SPEC)
        for _ in range(60):
            cand, touched = _move_span(perm, rng)
            val, pending = eng.propose(cand, touched)
            want = pipette_latency(conf, perm_to_mapping(cand, conf), bw,
                                   prof, SPEC)
            assert val == want, (str(conf), val - want)
            if rng.random() < 0.6:
                eng.commit(pending)
                perm = cand


def test_cp_default_mapping_latencies_match_scalar():
    bw = true_bandwidth_matrix(SPEC)
    w = Workload(GPT, SEQ, 64)
    confs = [c for c in enumerate_confs(SPEC.n_gpus, w.bs_global,
                                        n_layers=GPT.n_layers, max_cp=4,
                                        seq=SEQ) if c.bs_micro <= 4]
    assert any(c.cp > 1 for c in confs)
    cache = ProfileCache(w, SPEC)
    profiles = [cache.get(c) for c in confs]
    batch = default_mapping_latencies(confs, profiles, bw, SPEC)
    for i, (conf, prof) in enumerate(zip(confs, profiles)):
        assert batch[i] == pipette_latency(conf, default_mapping(conf), bw,
                                           prof, SPEC), str(conf)


def test_mapping4_accepts_legacy_and_4d_shapes():
    c3 = Conf(2, 2, 2, 1, 8)
    m3 = default_mapping(c3)
    assert m3.shape == (2, 2, 2)
    assert mapping4(c3, m3).shape == (2, 2, 1, 2)
    assert np.array_equal(mapping4(c3, m3)[:, :, 0, :], m3)
    c4 = Conf(2, 2, 2, 1, 8, cp=2)
    m4 = default_mapping(c4)
    assert m4.shape == (2, 2, 2, 2)
    assert sorted(m4.reshape(-1).tolist()) == list(range(16))
    assert np.array_equal(mapping4(c4, m4), m4)


def test_cp_profile_shards_sequence():
    """cp shrinks per-rank compute/messages; the KV-exchange term appears
    only for cp > 1 and grows with the ring size."""
    w = Workload(GPT, SEQ, 64)
    p1 = build_profile(w, SPEC, Conf(2, 2, 2, 2, 64))
    p2 = build_profile(w, SPEC, Conf(2, 2, 1, 2, 64, cp=2))
    p4 = build_profile(w, SPEC, Conf(2, 2, 1, 2, 64, cp=4))
    assert p1.t_cp_fwd == 0.0 and p1.msg_cp == 0.0
    assert p2.msg_pp == p1.msg_pp / 2
    assert p2.c_fwd < p1.c_fwd
    assert p2.t_cp_fwd > 0
    assert p4.t_cp_fwd > p2.t_cp_fwd        # more ring steps
    assert p4.msg_cp < p2.msg_cp            # smaller KV blocks


# ---------------------------------------------------------------------------
# memory: cp terms + the with_cp estimator contract
# ---------------------------------------------------------------------------

def test_cp_shrinks_activation_memory():
    w = Workload(GPT, SEQ, 64)
    base = ground_truth_memory(w, Conf(2, 2, 2, 2, 64), SPEC)
    cp2 = ground_truth_memory(w, Conf(2, 2, 1, 2, 64, cp=2), SPEC)
    assert cp2 < base


def test_3d_estimator_refuses_cp_configs():
    w = Workload(GPT, 1024, 32)
    est = fit_memory_estimator([w], MID_RANGE, fit_nodes=1, steps=300)
    assert not est.with_cp
    with pytest.raises(ValueError, match="cp"):
        est.predict_batch(w.cfg, [Conf(1, 2, 1, 1, 32, cp=4)])


# ---------------------------------------------------------------------------
# the headline scenario: long context is infeasible in 3D, feasible in 4D
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def long_ctx():
    cfg = GEMMA3.reduced()
    spec = MID_RANGE.with_nodes(2)          # 16 GPUs x 32 GB
    w = Workload(cfg, 65536, 2)             # gemma3-class long context
    return cfg, spec, w


def test_long_context_infeasible_in_3d(long_ctx):
    cfg, spec, w = long_ctx
    confs = enumerate_confs(spec.n_gpus, w.bs_global,
                            max_tp=spec.gpus_per_node,
                            n_layers=cfg.n_layers, seq=w.seq)
    assert confs                              # the space is non-empty...
    assert all(ground_truth_memory(w, c, spec) > spec.gpu_mem
               for c in confs)                # ...but everything OOMs


def test_long_context_feasible_with_cp(long_ctx):
    cfg, spec, w = long_ctx
    confs = enumerate_confs(spec.n_gpus, w.bs_global,
                            max_tp=spec.gpus_per_node,
                            n_layers=cfg.n_layers, max_cp=8, seq=w.seq)
    feas = [c for c in confs
            if ground_truth_memory(w, c, spec) <= spec.gpu_mem]
    assert feas
    assert all(c.cp > 1 for c in feas)


def test_configure_4d_finds_long_context_config(long_ctx):
    """End-to-end acceptance: the 4D search (cp-aware estimator included)
    returns a memory-feasible recommendation where the 3D search returns
    nothing."""
    cfg, spec, w = long_ctx
    ws = [Workload(cfg, w.seq, bsg) for bsg in (2, 4, 8)]
    est = fit_memory_estimator(ws, spec, fit_nodes=2, steps=2500,
                               residual=True, max_cp=8)
    assert est.with_cp
    bw, _ = profile_bandwidth(spec)
    kw = dict(estimator=est, max_tp=spec.gpus_per_node,
              sa_seconds=0.05, sa_iters=300)
    res3 = configure(w, spec, bw, **kw)
    assert res3.best is None                  # 3D: everything pruned
    res4 = configure(w, spec, bw, max_cp=8, **kw)
    assert res4.best is not None
    assert res4.best.conf.cp > 1
    assert ground_truth_memory(w, res4.best.conf, spec) <= spec.gpu_mem
    assert res4.best.conf.n_gpus == spec.n_gpus
    assert sorted(res4.best.mapping.reshape(-1).tolist()) == \
        list(range(spec.n_gpus))
    # the recommendation actually runs on the simulated cluster
    t = measure(res4.best.conf, res4.best.mapping, w, spec,
                true_bandwidth_matrix(spec))
    assert np.isfinite(t) and t > 0
