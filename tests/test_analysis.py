"""Tests for the determinism linter (``repro.analysis``).

Covers: one fixture file per rule, golden JSON diagnostics, suppression
handling (valid / malformed / unused), config scoping and exclusion,
escape hatches, the CLI, and — the acceptance gate — that ``src/`` lints
clean under the repo's own ``pyproject.toml`` with every suppression
carrying a reason.
"""
import json
from pathlib import Path

import pytest

from repro.analysis import (
    RULES,
    lint_file,
    lint_paths,
    load_config,
    render_json,
    render_text,
)
from repro.analysis.cli import main as cli_main
from repro.analysis.config import AnalysisConfig
from repro.analysis.linter import lint_source

TESTS = Path(__file__).resolve().parent
REPO = TESTS.parent
FIXTURES = TESTS / "data" / "analysis_fixtures"


@pytest.fixture(scope="module")
def fixture_config():
    return load_config(FIXTURES / "fixture_pyproject.toml")


def _open_rules(diags):
    return sorted(d.rule for d in diags if not d.suppressed)


# ---------------------------------------------------------------- registry

def test_rule_registry_is_complete():
    expected = {f"DET{i:03d}" for i in range(1, 8)}
    expected |= {"SYN001", "SUP001", "SUP002"}
    assert set(RULES) == expected
    for rule_id, rule in RULES.items():
        assert rule.id == rule_id
        assert rule.name and rule.summary


# ------------------------------------------------- one violation per rule

@pytest.mark.parametrize("rule_id, fname", [
    ("DET001", "det001.py"),
    ("DET002", "det002.py"),
    ("DET003", "det003.py"),
    ("DET004", "det004.py"),
    ("DET005", "det005.py"),
    ("DET006", "det006.py"),
    ("DET007", "det007.py"),
])
def test_fixture_flags_exactly_its_rule(rule_id, fname, fixture_config):
    diags = lint_file(FIXTURES / fname, fixture_config)
    assert _open_rules(diags) == [rule_id]


def test_syntax_error_is_a_diagnostic_not_a_crash():
    diags = lint_source("def broken(:\n    pass\n", "broken.py")
    assert _open_rules(diags) == ["SYN001"]


# ------------------------------------------------------- golden JSON output

def test_golden_json_diagnostics(fixture_config):
    diags = lint_paths([FIXTURES], fixture_config, relative_to=FIXTURES)
    got = render_json(diags)
    expected = (FIXTURES / "expected.json").read_text(encoding="utf-8")
    assert got == expected
    # and it really is machine-readable
    records = json.loads(got)
    assert all(set(r) >= {"path", "line", "col", "rule", "message",
                          "suppressed", "reason"} for r in records)


def test_excluded_file_is_skipped(fixture_config):
    diags = lint_paths([FIXTURES], fixture_config, relative_to=FIXTURES)
    assert not any(d.path == "excluded.py" for d in diags)
    # same file, default config (no exclusion) -> DET001 fires
    diags = lint_file(FIXTURES / "excluded.py", AnalysisConfig())
    assert _open_rules(diags) == ["DET001"]


# ------------------------------------------------------------- suppressions

def test_reasoned_suppression_silences_and_records_reason(fixture_config):
    diags = lint_file(FIXTURES / "suppressed.py", fixture_config)
    assert _open_rules(diags) == []
    sup = [d for d in diags if d.suppressed]
    assert len(sup) == 1
    assert sup[0].rule == "DET002"
    assert sup[0].reason == "fixture exercising reasoned suppressions"


def test_malformed_and_unused_suppressions_are_findings(fixture_config):
    diags = lint_file(FIXTURES / "bad_suppress.py", fixture_config)
    # the reason-less noqa does NOT suppress, and is itself flagged;
    # the noqa with no matching finding is flagged as stale
    assert _open_rules(diags) == ["DET002", "SUP001", "SUP002"]


def test_suppression_must_name_the_right_rule():
    src = ("import time\n"
           "t = time.time()  # repro: noqa DET001 -- wrong rule named\n")
    diags = lint_source(src, "mod.py")
    # DET002 stays open, and the DET001 noqa is unused
    assert _open_rules(diags) == ["DET002", "SUP002"]


def test_noqa_in_docstring_or_string_is_ignored():
    src = '"""docs mention # repro: noqa DET001 -- example"""\nx = 1\n'
    assert lint_source(src, "mod.py") == []


# ------------------------------------------------------------ escape hatches

def test_det004_integer_escapes():
    assert _open_rules(lint_source(
        "xs = [[1], [2, 3]]\nn = sum(len(x) for x in xs)\n", "m.py")) == []
    assert _open_rules(lint_source(
        "n = sum(1 for _ in range(5))\n", "m.py")) == []
    assert _open_rules(lint_source(
        "xs = [0.5, 0.25]\ns = sum(x for x in xs)\n", "m.py")) == ["DET004"]


def test_det003_scoping_and_int_escape():
    cfg = AnalysisConfig(det003_paths=("scored.py",))
    src = "def f(a):\n    return float(a.sum())\n"
    assert _open_rules(lint_source(src, "scored.py", cfg)) == ["DET003"]
    assert _open_rules(lint_source(src, "elsewhere.py", cfg)) == []
    # integer reductions are exact in any association order
    src_int = "def f(mask):\n    return int(mask.sum())\n"
    assert _open_rules(lint_source(src_int, "scored.py", cfg)) == []


def test_det002_allows_monotonic_timers():
    src = ("import time\n"
           "t0 = time.perf_counter()\n"
           "t1 = time.monotonic()\n")
    assert lint_source(src, "m.py") == []


def test_det006_order_free_consumers_are_fine():
    assert _open_rules(lint_source(
        "xs = [3, 1]\nm = max(set(xs))\n", "m.py")) == []
    assert _open_rules(lint_source(
        "xs = [3, 1]\nys = sorted(set(xs))\n", "m.py")) == []


def test_import_alias_resolution():
    src = ("from time import time as now\n"
           "def f():\n"
           "    return now()\n")
    assert _open_rules(lint_source(src, "m.py")) == ["DET002"]
    # a local shadowing the name kills the match
    shadowed = ("def f(time):\n"
                "    time = 0.0\n"
                "    return time\n")
    assert lint_source(shadowed, "m.py") == []


def test_rule_disable_via_config():
    cfg = AnalysisConfig(disable=frozenset({"DET005"}))
    assert lint_source("def f(x):\n    return x == 1.0\n", "m.py", cfg) == []


# --------------------------------------------------------------------- CLI

def test_cli_exit_codes(capsys):
    assert cli_main([str(FIXTURES / "det001.py"), "--no-config"]) == 1
    capsys.readouterr()
    assert cli_main([str(FIXTURES / "suppressed.py"), "--no-config"]) == 0
    capsys.readouterr()
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    assert "DET001" in out and "SUP002" in out
    assert cli_main([]) == 2                       # no paths
    assert cli_main(["/no/such/file.py"]) == 2
    assert cli_main([str(FIXTURES / "det001.py"),
                     "--select", "NOPE999"]) == 2


def test_cli_select_narrows_rules(capsys):
    rc = cli_main([str(FIXTURES / "det001.py"), "--no-config",
                   "--select", "DET005"])
    assert rc == 0
    capsys.readouterr()


def test_cli_json_output(capsys):
    rc = cli_main([str(FIXTURES), "--format", "json",
                   "--config", str(FIXTURES / "fixture_pyproject.toml"),
                   "--relative-to", str(FIXTURES)])
    assert rc == 1
    records = json.loads(capsys.readouterr().out)
    assert any(r["rule"] == "DET001" and r["path"] == "det001.py"
               for r in records)
    # JSON mode always includes suppressed findings, reasons attached
    assert any(r["suppressed"] and r["reason"] for r in records)


def test_render_text_shape(fixture_config):
    diags = lint_file(FIXTURES / "det001.py", fixture_config,
                      display_path="det001.py")
    lines = render_text(diags)
    assert lines == [
        "det001.py:6:12: DET001 process-global legacy RNG "
        "'numpy.random.rand': draws depend on hidden module state; use a "
        "seeded np.random.default_rng(seed) passed explicitly"]


# --------------------------------------------- the acceptance-criteria gate

def test_src_tree_lints_clean_with_reasoned_suppressions():
    """`python -m repro.analysis src/` must exit 0: zero unsuppressed
    violations, and every suppression carries a reason."""
    cfg = load_config(REPO / "pyproject.toml")
    diags = lint_paths([REPO / "src"], cfg, relative_to=REPO)
    open_diags = [d for d in diags if not d.suppressed]
    assert open_diags == [], render_text(open_diags)
    for d in diags:
        if d.suppressed:
            assert d.reason.strip(), f"reason-less suppression: {d}"
