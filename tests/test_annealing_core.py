"""Unified SA core: budget split, MovePlan, move semantics, chain parity.

Covers the `anneal_multistart` budget-split fix (exact divmod totals,
zero-iteration chains, `time_limit_s=0` score-only behavior), the
host-precomputed :class:`~repro.core.annealing.MovePlan` (determinism,
bounds, exact split, single-island degeneration), the shared move
semantics of the NumPy and JAX executors, and chain-for-chain bit parity
between the two backends at the engine level."""
import numpy as np
import pytest

from repro.core import (ClusterSpec, Conf, DedicationEngine, Workload,
                        anneal_multistart, build_profile, make_move_plan,
                        perm_to_mapping, profile_bandwidth)
from repro.core.annealing import (_ALPHA, _move_numpy, _run_chain_numpy,
                                  build_islands, coarse_assign,
                                  coarse_orderings)
from repro.configs.gpt_paper import GPT_3_1B

SPEC = ClusterSpec("tiny-2x4", 2, gpus_per_node=4, seed=1)
W = Workload(GPT_3_1B, 2048, 32)
CONF = Conf(2, 2, 2, 2, 32)


@pytest.fixture(scope="module")
def setup():
    bw, _ = profile_bandwidth(SPEC)
    prof = build_profile(W, SPEC, CONF)
    return bw, prof


# ---------------------------------------------------------------------------
# anneal_multistart budget split (the satellite fix)
# ---------------------------------------------------------------------------

def test_multistart_iters_exact_when_chains_exceed_budget(setup):
    """n_chains > max_iters must NOT run n_chains extra iterations (the
    historical ``max(1, max_iters // n_chains)`` bug)."""
    bw, prof = setup
    res = anneal_multistart(CONF, bw, prof, SPEC, n_chains=5,
                            time_limit_s=60.0, max_iters=2, seed=0)
    assert res.iters == 2


@pytest.mark.parametrize("max_iters,n_chains", [(7, 3), (1, 4), (60, 4),
                                                (9, 9), (10, 1)])
def test_multistart_iters_sum_exactly(setup, max_iters, n_chains):
    bw, prof = setup
    res = anneal_multistart(CONF, bw, prof, SPEC, n_chains=n_chains,
                            time_limit_s=60.0, max_iters=max_iters, seed=3)
    assert res.iters == max_iters


def test_multistart_zero_time_limit_is_score_only(setup):
    """time_limit_s=0: every chain gets a zero wall-clock budget — defined
    as score-only, returning the initial permutation untouched."""
    bw, prof = setup
    res = anneal_multistart(CONF, bw, prof, SPEC, n_chains=3,
                            time_limit_s=0.0, max_iters=100, seed=0)
    assert res.iters == 0
    assert np.array_equal(res.perm, np.arange(CONF.n_gpus))
    eng = DedicationEngine(CONF, bw, prof, SPEC)
    assert res.latency == eng.score(np.arange(CONF.n_gpus))


def test_multistart_deterministic_after_fix(setup):
    bw, prof = setup
    kw = dict(n_chains=3, time_limit_s=60.0, max_iters=50, seed=8)
    a = anneal_multistart(CONF, bw, prof, SPEC, **kw)
    b = anneal_multistart(CONF, bw, prof, SPEC, **kw)
    assert a.latency == b.latency and np.array_equal(a.perm, b.perm)
    assert a.chain_latencies == b.chain_latencies


# ---------------------------------------------------------------------------
# MovePlan
# ---------------------------------------------------------------------------

def test_move_plan_exact_split_and_masks():
    plan = make_move_plan([8], 10, 4, seed=0)
    assert plan.chain_iters.tolist() == [3, 3, 2, 2]
    assert int(plan.chain_iters.sum()) == 10
    assert plan.valid.shape == (4, 3)
    assert (plan.valid.sum(axis=1) == plan.chain_iters).all()


def test_move_plan_zero_budget_chains():
    plan = make_move_plan([8], 2, 5, seed=0)
    assert plan.chain_iters.tolist() == [1, 1, 0, 0, 0]
    assert not plan.valid[2:].any()


def test_move_plan_deterministic_and_bounded():
    sizes = [6, 10, 4]
    a = make_move_plan(sizes, 200, 3, seed=42)
    b = make_move_plan(sizes, 200, 3, seed=42)
    for f in ("kind", "isl", "oa", "ob", "thresh", "probe_kind",
              "probe_isl", "probe_oa", "probe_ob"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    ln = np.asarray(sizes)[a.isl]
    assert (a.oa >= 0).all() and (a.oa < ln).all()
    assert (a.ob >= 0).all() and (a.ob < ln).all()
    assert (a.oa != a.ob).all()
    assert set(np.unique(a.kind)) <= {0, 1, 2}
    assert (a.thresh >= 0).all()


def test_move_plan_single_island_skips_island_draw():
    """One island (flat and degenerate-hierarchical) must consume the same
    RNG stream regardless of how the caller arrived at it — the island
    draw is skipped, so the schedules are identical arrays."""
    a = make_move_plan([16], 50, 2, seed=7)
    b = make_move_plan((16,), 50, 2, seed=7)
    assert (a.isl == 0).all()
    for f in ("kind", "oa", "ob", "thresh"):
        assert np.array_equal(getattr(a, f), getattr(b, f))


def test_move_plan_rejects_degenerate_islands():
    with pytest.raises(ValueError):
        make_move_plan([4, 1], 10, 1, seed=0)
    with pytest.raises(ValueError):
        make_move_plan([8], 10, 0, seed=0)


# ---------------------------------------------------------------------------
# move semantics: NumPy executor == JAX index arithmetic
# ---------------------------------------------------------------------------

def test_move_numpy_semantics():
    perm = np.arange(6)
    mig, t = _move_numpy(perm, 0, 1, 4)      # remove at 1, reinsert at 4
    assert mig.tolist() == [0, 2, 3, 4, 1, 5]
    assert t.tolist() == [1, 2, 3, 4]
    swp, t = _move_numpy(perm, 1, 4, 1)      # order-insensitive positions
    assert swp.tolist() == [0, 4, 2, 3, 1, 5]
    assert sorted(t.tolist()) == [1, 4]
    rev, t = _move_numpy(perm, 2, 1, 4)
    assert rev.tolist() == [0, 4, 3, 2, 1, 5]


def test_moves_match_jax_apply_move():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.core.jax_engine import _apply_move

    rng = np.random.default_rng(0)
    pos = jnp.arange(12, dtype=jnp.int32)
    for _ in range(64):
        perm = rng.permutation(12)
        kind = int(rng.integers(3))
        pa = int(rng.integers(12))
        pb = int(rng.integers(11))
        pb += pb >= pa
        want, touched = _move_numpy(perm, kind, pa, pb)
        got = np.asarray(_apply_move(jnp.asarray(perm, dtype=jnp.int32),
                                     pos, kind, pa, pb))
        assert got.tolist() == want.tolist()
        # touched covers every changed position
        changed = np.nonzero(want != perm)[0]
        assert set(changed) <= set(touched.tolist())


# ---------------------------------------------------------------------------
# chain-for-chain backend parity at the engine level
# ---------------------------------------------------------------------------

def test_numpy_and_jax_chains_bit_identical(setup):
    pytest.importorskip("jax")
    from repro.core.jax_engine import JaxDedicationEngine

    bw, prof = setup
    islands = build_islands(SPEC, hierarchical=False)
    plan = make_move_plan([len(i) for i in islands], 30, 3, seed=5)
    eng = DedicationEngine(CONF, bw, prof, SPEC)
    init, offsets, _ = coarse_assign(eng, islands,
                                     coarse_orderings(islands, SPEC))
    np_best = []
    np_perms = []
    np_acc = []
    np_accb = []
    for k in range(3):
        b, p, _, ac, ab = _run_chain_numpy(eng, init, offsets, plan, k,
                                           _ALPHA)
        np_best.append(b)
        np_perms.append(p)
        np_acc.append(ac)
        np_accb.append(ab)

    jeng = JaxDedicationEngine([CONF], [prof], bw, SPEC)
    pas = (offsets[plan.isl] + plan.oa)[None]
    pbs = (offsets[plan.isl] + plan.ob)[None]
    ppas = (offsets[plan.probe_isl] + plan.probe_oa)[None]
    ppbs = (offsets[plan.probe_isl] + plan.probe_ob)[None]
    bests, perms, _, accs, accbs = jeng.anneal(
        init[None], pas, pbs, plan.kind, plan.thresh, plan.valid,
        ppas, ppbs, plan.probe_kind, alpha=_ALPHA)
    for k in range(3):
        assert float(bests[0, k]).hex() == float(np_best[k]).hex(), k
        assert np.array_equal(perms[0, k], np_perms[k]), k
        # the accepted-move counters are part of the parity contract too
        # (the warm-start economy gate reads them from either backend)
        assert int(accs[0, k]) == np_acc[k], k
        assert int(accbs[0, k]) == np_accb[k], k


def test_chain_result_never_worse_than_init(setup):
    bw, prof = setup
    eng = DedicationEngine(CONF, bw, prof, SPEC)
    plan = make_move_plan([CONF.n_gpus], 40, 1, seed=2)
    init = np.arange(CONF.n_gpus)
    b, p, it, acc, acc_best = _run_chain_numpy(eng, init,
                                               np.zeros(1, np.int64),
                                               plan, 0, _ALPHA)
    assert b <= eng.score(init)
    assert b == eng.score(p)        # reported best matches its permutation
    assert it == 40
    assert 0 <= acc_best <= acc <= it
    assert perm_to_mapping(p, CONF).shape == (2, 2, 2)


# ---------------------------------------------------------------------------
# the shared PairCache
# ---------------------------------------------------------------------------

def test_pair_cache_bit_identical_to_masked_construction(setup):
    """PairCache.build's pass-cheap construction (copy + diagonal fill;
    inf canvas + per-node blocks) must reproduce the historical
    full-matrix boolean-mask construction bit for bit."""
    from repro.core import PairCache
    bw, _ = setup
    bw64 = np.asarray(bw, dtype=float)
    g = bw64.shape[0]
    eye_g = np.eye(g, dtype=bool)
    node = np.arange(g) // SPEC.gpus_per_node
    same = node[:, None] == node[None, :]
    want_noself = np.where(eye_g, np.inf, bw64)
    bw_intra = np.where(same & ~eye_g, bw64, np.inf)
    want_sym = np.minimum(bw_intra, bw_intra.T)
    pairs = PairCache.build(bw, SPEC.gpus_per_node)
    assert np.array_equal(pairs.bw, bw64)
    assert np.array_equal(pairs.bw_noself, want_noself)
    assert np.array_equal(pairs.sym_intra, want_sym)


def test_pair_cache_shared_engine_scores_bit_identical(setup):
    bw, prof = setup
    from repro.core import PairCache
    pairs = PairCache.build(bw, SPEC.gpus_per_node)
    eng = DedicationEngine(CONF, bw, prof, SPEC)
    shared = DedicationEngine(CONF, bw, prof, SPEC, pairs=pairs)
    assert shared._bw_noself is pairs.bw_noself     # no rebuild
    rng = np.random.default_rng(0)
    for _ in range(4):
        perm = rng.permutation(CONF.n_gpus)
        assert float(shared.score(perm)).hex() == \
            float(eng.score(perm)).hex()


def test_pair_cache_mismatch_rejected(setup):
    bw, prof = setup
    from repro.core import PairCache
    pairs = PairCache.build(bw, SPEC.gpus_per_node + 1)
    with pytest.raises(ValueError, match="PairCache"):
        DedicationEngine(CONF, bw, prof, SPEC, pairs=pairs)
