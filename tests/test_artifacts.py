"""Integration checks over the shipped dry-run artifacts: the 40-cell x
2-mesh matrix is complete, terms are sane, and the re-analysis path is
idempotent (skipped when artifacts are absent, e.g. on a fresh clone)."""
import json
from pathlib import Path

import pytest

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"

pytestmark = pytest.mark.skipif(
    not ART.exists() or not list(ART.glob("*.json")),
    reason="dry-run artifacts not generated (run repro.launch.dryrun --all)")


def _baseline_cells():
    import sys
    sys.path.insert(0, str(ART.parent.parent))
    from benchmarks.roofline import load_cells
    return load_cells(ART)


def test_matrix_complete():
    cells = _baseline_cells()
    assert len(cells) == 80                     # 10 archs x 4 shapes x 2 meshes
    skips = [c for c in cells if "skipped" in c]
    assert len(skips) == 14                     # 7 full-attn archs x 2 meshes
    for c in skips:
        assert c["shape"] == "long_500k"


def test_terms_sane():
    cells = [c for c in _baseline_cells() if "skipped" not in c]
    assert len(cells) == 66
    for c in cells:
        assert c["flops_per_dev"] > 0, c["arch"]
        assert c["hbm_bytes_per_dev"] > 0
        assert c["t_compute"] >= 0 and c["t_memory"] > 0
        assert c["bottleneck"] in ("compute", "memory", "collective")
        assert 0 < c["useful_flops_ratio"] <= 1.5, (c["arch"], c["shape"])
        if c["shape"] == "train_4k":
            # training must communicate (grad sync at minimum)
            assert c["collective_bytes_per_dev"] > 0
        assert c["n_devices"] == (512 if c["mesh"] == "2x16x16" else 256)


def test_multipod_shards_the_pod_axis():
    """Multi-pod per-device bytes must not exceed single-pod for train
    cells (DP over the pod axis halves per-device state)."""
    cells = {(c["arch"], c["shape"], c["mesh"]): c
             for c in _baseline_cells() if "skipped" not in c}
    for (arch, shape, mesh), c in cells.items():
        if shape != "train_4k" or mesh != "16x16":
            continue
        multi = cells.get((arch, shape, "2x16x16"))
        assert multi is not None, arch
        assert multi["bytes_per_device"] <= c["bytes_per_device"] * 1.05, arch


def test_reanalysis_idempotent(tmp_path):
    import shutil
    pytest.importorskip("zstandard")   # reanalyze reads .hlo.zst artifacts
    from repro.launch.reanalyze import reanalyze
    src = next(p for p in ART.glob("*.json")
               if p.with_name(p.stem + ".hlo.zst").exists())
    shutil.copy(src, tmp_path / src.name)
    shutil.copy(src.with_name(src.stem + ".hlo.zst"),
                tmp_path / (src.stem + ".hlo.zst"))
    before = json.loads((tmp_path / src.name).read_text())
    assert reanalyze(tmp_path) == 1
    after = json.loads((tmp_path / src.name).read_text())
    assert after["flops_per_dev"] == pytest.approx(before["flops_per_dev"])
    assert after["bottleneck"] == before["bottleneck"]


def test_report_renders():
    from benchmarks.roofline import markdown_table, roofline_rows
    cells = _baseline_cells()
    for mesh in ("16x16", "2x16x16"):
        rows = roofline_rows(cells, mesh)
        table = markdown_table(rows)
        assert table.count("\n") >= 40
        assert "SKIP" in table
