"""Extra attention/RoPE/decode invariants (hypothesis + targeted cases)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (chunked_attention, decode_attention,
                                    reference_attention)
from repro.models.layers import apply_rope

# optional dep: skip the module without failing collection; assigning the
# names (instead of `from hypothesis import ...` after a statement) keeps
# every real import at the top of the file (ruff E402)
hyp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
given, settings = hyp.given, hyp.settings

KEY = jax.random.PRNGKey(3)


@settings(max_examples=10, deadline=None)
@given(sq=st.sampled_from([32, 48, 64]), kv=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2]), cq=st.sampled_from([8, 16, 32]))
def test_chunked_attention_matches_reference(sq, kv, g, cq):
    h, hd, b = kv * g, 16, 2
    ks = jax.random.split(jax.random.PRNGKey(sq * 100 + kv * 10 + g), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd))
    k = jax.random.normal(ks[1], (b, sq, kv, hd))
    v = jax.random.normal(ks[2], (b, sq, kv, hd))
    out = chunked_attention(q, k, v, chunk_q=cq, chunk_k=cq)
    ref = reference_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5,
                               atol=3e-5)


def test_q_offset_matches_suffix_of_full():
    """Attention of a query suffix with q_offset equals the suffix of the
    full computation (continuation semantics)."""
    b, s, h, kv, hd = 1, 64, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    full = chunked_attention(q, k, v, chunk_q=16, chunk_k=16)
    tail = chunked_attention(q[:, 48:], k, v, q_offset=48, chunk_q=16,
                             chunk_k=16)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, 48:]),
                               rtol=3e-5, atol=3e-5)


def test_decode_attention_matches_full_row():
    b, s, h, kv, hd = 2, 32, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q_all = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    full = reference_attention(q_all, k, v)
    for pos in (0, 7, 31):
        out = decode_attention(q_all[:, pos:pos + 1], k, v, jnp.int32(pos))
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(full[:, pos]), rtol=3e-5,
                                   atol=3e-5)


def test_decode_attention_sliding_window():
    b, s, h, kv, hd = 1, 32, 2, 2, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    pos, win = 20, 8
    out = decode_attention(q, k, v, jnp.int32(pos), window=win)
    # reference: zero out everything outside [pos-win+1, pos]
    mask = np.zeros(s, bool)
    mask[pos - win + 1:pos + 1] = True
    kf = np.asarray(k)
    vf = np.asarray(v)
    qf = np.asarray(q)[:, 0].reshape(b, kv, 1, hd)
    sc = np.einsum("bkgd,bskd->bkgs", qf / np.sqrt(hd), kf)
    sc[..., ~mask] = -1e30
    p = np.exp(sc - sc.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    ref = np.einsum("bkgs,bskd->bkgd", p, vf).reshape(b, 1, h, hd)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=3e-5, atol=3e-5)


def test_rope_preserves_norm_and_relative_phase():
    b, s, h, hd = 1, 16, 2, 32
    x = jax.random.normal(KEY, (b, s, h, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    y = apply_rope(x, pos, 1e4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, hd))
    def dot_at(p, d):
        rq = apply_rope(q, jnp.full((1, 1), p), 1e4)
        rk = apply_rope(k, jnp.full((1, 1), p + d), 1e4)
        return float(jnp.sum(rq * rk))
    assert dot_at(3, 5) == pytest.approx(dot_at(11, 5), rel=1e-4)
    assert dot_at(0, 2) == pytest.approx(dot_at(9, 2), rel=1e-4)


def test_empty_window_rows_are_zero():
    """Rows whose window excludes every key (can happen with ring padding)
    must come out exactly zero, not NaN."""
    b, s, h, kv, hd = 1, 16, 2, 2, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    out = chunked_attention(q, k, v, causal=True, q_offset=-4,
                            chunk_q=8, chunk_k=8)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(np.asarray(out[:, :4]), 0.0, atol=1e-6)
