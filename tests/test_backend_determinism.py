"""Backend determinism: byte-identical Plan JSON across SA backends.

The unified SA core promises that ``backend="numpy"`` and
``backend="jax"`` are the *same algorithm* with two executors: given one
``PlanRequest`` and seed, the serialized Plan artifacts must be
byte-identical except for the single ``provenance.budget.backend`` field
that legitimately records which executor ran.  Re-running either backend
must also reproduce its own bytes exactly, and the Pallas group-reduce
kernel (interpret mode on CPU) must not perturb the plan relative to the
pure-jnp fallback."""
import json

import pytest

from repro.core import (Budget, Planner, PlanRequest, PipetteStrategy,
                        SearchSpace, Workload, profile_bandwidth)
from repro.core.cluster import (A100_TIER, V100_TIER, MID_RANGE,
                                mixed_fleet_spec)
from repro.models.config import ModelConfig

pytest.importorskip("jax")

GPT = ModelConfig(name="g12", family="dense", n_layers=12, d_model=1024,
                  n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=32000)
MIXED = mixed_fleet_spec("det-mixed-16x1", 16, (A100_TIER, V100_TIER),
                         (0.5, 0.5), gpus_per_node=1, seed=31)


def _req(spec, backend, hierarchical=None, n_chains=2):
    return PlanRequest(
        workload=Workload(GPT, 2048, 32), spec=spec,
        space=SearchSpace(max_micro=2),
        budget=Budget(sa_seconds=60.0, sa_iters=40, n_chains=n_chains,
                      sa_topk=2, backend=backend,
                      hierarchical=hierarchical),
        seed=11)


def _plan_json(spec, backend, **kw):
    bw, _ = profile_bandwidth(spec)
    return Planner(PipetteStrategy()).plan(_req(spec, backend, **kw),
                                           bw).to_json()


def _strip_backend(text):
    d = json.loads(text)
    assert d["provenance"]["budget"].pop("backend") in ("numpy", "jax")
    return json.dumps(d, sort_keys=True)


@pytest.mark.parametrize("spec", [MID_RANGE, MIXED],
                         ids=["uniform", "mixed"])
def test_numpy_and_jax_plans_byte_identical(spec):
    """Same request + seed, both executors: identical plans except the
    recorded backend name itself."""
    a = _plan_json(spec, "numpy")
    b = _plan_json(spec, "jax")
    assert a != b                       # the backend field does differ...
    assert _strip_backend(a) == _strip_backend(b)   # ...and nothing else


def test_backends_agree_under_hierarchical_search():
    a = _plan_json(MIXED, "numpy", hierarchical=True)
    b = _plan_json(MIXED, "jax", hierarchical=True)
    assert _strip_backend(a) == _strip_backend(b)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_same_backend_rerun_is_byte_identical(backend):
    assert _plan_json(MIXED, backend) == _plan_json(MIXED, backend)


def test_multi_chain_plans_agree_chain_for_chain():
    """n_chains > 1 exercises the per-chain RNG streams and the winner
    argmin on both executors."""
    a = _plan_json(MIXED, "numpy", n_chains=3)
    b = _plan_json(MIXED, "jax", n_chains=3)
    assert _strip_backend(a) == _strip_backend(b)


def test_pallas_interpret_matches_ref_kernels(monkeypatch):
    """REPRO_KERNELS routes the jax backend's group reduces through the
    Pallas interpreter; the plan must not move by a single byte."""
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    a = _plan_json(MIXED, "jax")
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    b = _plan_json(MIXED, "jax")
    assert a == b


def test_legacy_default_backend_differs_only_in_budget_fields():
    """backend=None keeps the historical stage-5 loop: it must still
    produce a *valid* plan for the same request (pinned elsewhere by the
    hex-float regression suite), and the new budget knobs default null."""
    bw, _ = profile_bandwidth(MIXED)
    plan = Planner(PipetteStrategy()).plan(_req(MIXED, None), bw)
    d = plan.to_json_dict()
    assert d["provenance"]["budget"]["backend"] is None
    assert d["provenance"]["budget"]["hierarchical"] is None
    assert plan.feasible
