"""Continuous replanning under churn: trace determinism, the migration-cost
model (``Plan.diff`` / ``diff_assignments``), warm-start projection, fleet
state folding, and the warm-vs-cold replay quality gate."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import (MID_RANGE, MIXED_A100_V100, Conf, Workload,
                        default_mapping, diff_assignments, project_perm,
                        rank_state_bytes, state_keys)
from repro.models.config import ModelConfig
from repro.runtime.churn import (COLD_POLICY, WARM_POLICY, ChurnEvent,
                                 ChurnTrace, FleetState, generate_trace,
                                 simulate_churn)


def _cfg():
    return ModelConfig(name="g", family="dense", n_layers=16, d_model=1024,
                       n_heads=16, n_kv_heads=16, d_ff=4096,
                       vocab_size=32000)


# ---------------------------------------------------------------------------
# trace generation + determinism
# ---------------------------------------------------------------------------

def test_trace_same_seed_is_byte_identical():
    spec = MID_RANGE.with_nodes(8)
    a = generate_trace(spec, horizon_s=3600, seed=11)
    b = generate_trace(spec, horizon_s=3600, seed=11)
    assert a == b
    assert a.to_json() == b.to_json()
    assert generate_trace(spec, horizon_s=3600, seed=12).to_json() \
        != a.to_json()


def test_trace_json_round_trip_is_exact(tmp_path):
    spec = MID_RANGE.with_nodes(6)
    tr = generate_trace(spec, horizon_s=1800, seed=5)
    assert len(tr.events) > 0
    back = ChurnTrace.from_json_dict(json.loads(tr.to_json()))
    assert back == tr
    assert back.to_json() == tr.to_json()
    p = tmp_path / "trace.json"
    tr.save(p)
    assert ChurnTrace.load(p) == tr


def test_trace_respects_min_nodes_floor():
    spec = MID_RANGE.with_nodes(4)
    tr = generate_trace(spec, horizon_s=20000, seed=0, min_nodes=3,
                        preempt_interval_s=200.0)
    state = FleetState(spec)
    for ev in tr.events:
        state.apply(ev)
        assert len(state.nodes) >= 3


def test_trace_events_sorted_and_validated():
    spec = MID_RANGE.with_nodes(4)
    tr = generate_trace(spec, horizon_s=5000, seed=2)
    ts = [e.t for e in tr.events]
    assert ts == sorted(ts)
    assert all(e.kind in ("preempt", "return", "degrade_link", "straggler")
               for e in tr.events)
    with pytest.raises(ValueError, match="kind"):
        ChurnEvent(1.0, "meteor", 0)


# ---------------------------------------------------------------------------
# migration-cost model
# ---------------------------------------------------------------------------

def test_diff_self_is_exact_noop():
    cfg = _cfg()
    conf = Conf(pp=4, tp=2, dp=2, bs_micro=1, bs_global=64)
    m = default_mapping(conf)
    d = diff_assignments(cfg, conf, m, conf, m)
    assert d.is_noop
    assert (d.ranks_moved, d.ranks_added, d.ranks_removed) == (0, 0, 0)
    assert d.bytes_migrated == 0.0
    assert d.downtime_s == 0.0
    assert not d.conf_changed


def test_diff_dp_and_cp_moves_are_free_stage_moves_are_not():
    """dp/cp replicate parameters, so swapping GPUs inside one
    (stage, tp) slot fetches nothing; swapping across stages re-fetches
    both shards."""
    cfg = _cfg()
    conf = Conf(pp=4, tp=2, dp=2, bs_micro=1, bs_global=64)
    m = default_mapping(conf)
    dp_swap = m.copy()
    dp_swap[0, 0, 0], dp_swap[0, 0, 1] = m[0, 0, 1], m[0, 0, 0]
    d = diff_assignments(cfg, conf, m, conf, dp_swap)
    assert d.is_noop and d.bytes_migrated == 0.0

    stage_swap = m.copy()
    stage_swap[0, 0, 0], stage_swap[1, 0, 0] = m[1, 0, 0], m[0, 0, 0]
    d = diff_assignments(cfg, conf, m, conf, stage_swap)
    assert d.ranks_moved == 2
    shard = rank_state_bytes(cfg, conf)
    assert d.bytes_migrated == pytest.approx(float(shard[0] + shard[1]))
    assert d.downtime_s > 0


def test_diff_is_symmetric_on_a_fixed_fleet():
    """Same conf, same GPU set: migrating A -> B moves exactly the ranks
    that B -> A moves, and fetches the same bytes (shard sizes match
    per-slot)."""
    cfg = _cfg()
    conf = Conf(pp=2, tp=2, dp=4, bs_micro=1, bs_global=64)
    rng = np.random.default_rng(7)
    a = default_mapping(conf)
    b = a.reshape(-1)[rng.permutation(conf.n_gpus)].reshape(a.shape)
    d_ab = diff_assignments(cfg, conf, a, conf, b)
    d_ba = diff_assignments(cfg, conf, b, conf, a)
    assert d_ab.ranks_moved == d_ba.ranks_moved
    assert d_ab.bytes_migrated == pytest.approx(d_ba.bytes_migrated)
    assert d_ab.ranks_added == d_ba.ranks_added == 0


def test_diff_shrink_counts_removed_and_grow_counts_added():
    cfg = _cfg()
    big = Conf(pp=4, tp=2, dp=2, bs_micro=1, bs_global=64)    # 16 GPUs
    small = Conf(pp=2, tp=2, dp=2, bs_micro=1, bs_global=64)  # 8 GPUs
    d = diff_assignments(cfg, big, default_mapping(big),
                         small, default_mapping(small))
    assert d.ranks_total == 8
    assert d.ranks_removed == 8
    assert d.conf_changed
    d = diff_assignments(cfg, small, default_mapping(small),
                         big, default_mapping(big))
    assert d.ranks_total == 16
    assert d.ranks_added == 8


def test_state_keys_identify_replicated_shards():
    cfg = _cfg()
    conf = Conf(pp=2, tp=2, dp=2, bs_micro=1, bs_global=64)
    keys = state_keys(cfg, conf, default_mapping(conf))
    assert len(keys) == conf.n_gpus
    # dp peers of one (stage, tp) slot share a key; tp peers do not
    m4 = default_mapping(conf).reshape(conf.pp, conf.tp, conf.dp)
    assert keys[int(m4[0, 0, 0])] == keys[int(m4[0, 0, 1])]
    assert keys[int(m4[0, 0, 0])] != keys[int(m4[0, 1, 0])]
    assert keys[int(m4[0, 0, 0])] != keys[int(m4[1, 0, 0])]


def test_plan_diff_round_trips_through_save_load(tmp_path):
    """Artifact-level diff: two saved plans, loaded back, price the same
    migration as their in-memory originals — and diff(self) is a no-op."""
    from repro.core import (Budget, Planner, PlanRequest, PipetteStrategy,
                            SearchSpace, profile_bandwidth)

    cfg = _cfg()
    w = Workload(cfg, 1024, 64)
    spec = MID_RANGE.with_nodes(2)
    bw, _ = profile_bandwidth(spec)
    mk = lambda seed: Planner(PipetteStrategy()).plan(
        PlanRequest(workload=w, spec=spec, space=SearchSpace(max_tp=2),
                    budget=Budget(sa_seconds=1.0, sa_iters=60), seed=seed),
        bw)
    pa, pb = mk(0), mk(3)
    pa.save(tmp_path / "a.json")
    pb.save(tmp_path / "b.json")
    from repro.core.plan import Plan
    la, lb = Plan.load(tmp_path / "a.json"), Plan.load(tmp_path / "b.json")
    d_mem = pa.diff(pb, cfg=cfg)
    d_disk = la.diff(lb, cfg=cfg)
    assert d_mem == d_disk
    assert la.diff(la, cfg=cfg).is_noop


def test_project_perm_keeps_survivor_order_and_appends_fresh():
    perm = np.array([3, 1, 7, 5, 0, 6, 2, 4])
    # survivors: old ids 1, 5, 7, 0 -> new ids 0, 1, 2, 3; two new GPUs
    out = project_perm(perm, [1, 5, 7, 0], 6)
    # relative incumbent order of survivors: 1 (pos 1), 7 (pos 2),
    # 5 (pos 3), 0 (pos 4) -> new ids 0, 2, 1, 3, then fresh 4, 5
    assert out.tolist() == [0, 2, 1, 3, 4, 5]
    assert sorted(out.tolist()) == list(range(6))
    # full survival is a pure renumbering
    same = project_perm(perm, list(range(8)), 8)
    assert same.tolist() == perm.tolist()
    with pytest.raises(ValueError, match="duplicate"):
        project_perm(perm, [1, 1], 4)
    with pytest.raises(ValueError, match="smaller"):
        project_perm(perm, [0, 1, 2], 2)


# ---------------------------------------------------------------------------
# fleet state folding
# ---------------------------------------------------------------------------

def test_fleet_state_subset_keeps_tiers_and_join_order():
    spec = MIXED_A100_V100.with_nodes(6)
    state = FleetState(spec)
    state.apply(ChurnEvent(1.0, "preempt", 2))
    state.apply(ChurnEvent(2.0, "preempt", 0))
    state.apply(ChurnEvent(3.0, "return", 2))
    assert state.nodes == [1, 3, 4, 5, 2]        # survivors, then returner
    eff = state.effective_spec()
    assert eff.n_nodes == 5
    assert eff.node_tiers == tuple(spec.node_tiers[i]
                                   for i in (1, 3, 4, 5, 2))


def test_fleet_state_straggler_and_link_factors():
    spec = MID_RANGE.with_nodes(4)
    bw = np.full((spec.n_gpus, spec.n_gpus), 100.0)
    state = FleetState(spec)
    state.apply(ChurnEvent(1.0, "straggler", 1, factor=0.5))
    eff = state.effective_spec()
    assert eff.tiers  # straggler forces a tiered spec
    slow = eff.tiers[eff.node_tiers[1]]
    fast = eff.tiers[eff.node_tiers[0]]
    assert slow.flops == pytest.approx(fast.flops * 0.5)
    # recovery restores the scalar (untier-ed) spec
    state.apply(ChurnEvent(2.0, "straggler", 1, factor=1.0))
    assert not state.effective_spec().tiers

    state.apply(ChurnEvent(3.0, "degrade_link", 0, peer=2, factor=0.25))
    sub = state.effective_bw(bw)
    gpn = spec.gpus_per_node
    assert sub[0, 2 * gpn] == pytest.approx(25.0)
    assert sub[2 * gpn, 0] == pytest.approx(25.0)
    assert sub[0, gpn] == pytest.approx(100.0)
    state.apply(ChurnEvent(4.0, "degrade_link", 0, peer=2, factor=1.0))
    assert state.effective_bw(bw)[0, 2 * gpn] == pytest.approx(100.0)


def test_fleet_state_gpu_ids_follow_node_order():
    spec = MID_RANGE.with_nodes(3)
    state = FleetState(spec)
    state.apply(ChurnEvent(1.0, "preempt", 0))
    state.apply(ChurnEvent(2.0, "return", 0))
    gpn = spec.gpus_per_node
    assert state.gpu_ids() == (
        list(range(gpn, 3 * gpn)) + list(range(gpn)))


# ---------------------------------------------------------------------------
# the replay quality gate (small fleet; the 16-node gate runs in
# benchmarks/bench_churn.py)
# ---------------------------------------------------------------------------

def test_warm_incremental_beats_cold_on_seeded_trace():
    """The tentpole gate in miniature: on a seeded preempt/return trace
    with G-preserving events, warm incremental replanning (projected
    warm-start + migration-aware selection) sustains at least the
    throughput of from-scratch replanning, with no more downtime — and
    both policies' PlanDiff accounting matches the independent
    resident-state ledger exactly."""
    from repro import configs
    cfg = configs.get("gpt-1.1b").reduced()
    w = Workload(cfg, 2048, 64)
    spec = MID_RANGE.with_nodes(4)
    trace = generate_trace(spec, horizon_s=1200, seed=3, min_nodes=2,
                           preempt_interval_s=400.0,
                           degrade_interval_s=500.0,
                           straggler_interval_s=500.0)
    assert any(e.kind == "preempt" for e in trace.events)
    warm = dataclasses.replace(WARM_POLICY, sa_iters=150, sa_seconds=0.1)
    cold = dataclasses.replace(COLD_POLICY, sa_iters=150, sa_seconds=0.1)
    rw = simulate_churn(w, spec, trace, warm)
    rc = simulate_churn(w, spec, trace, cold)
    assert rw.replans == rc.replans == len(trace.events)
    assert rw.samples > rc.samples
    assert rw.downtime_s <= rc.downtime_s
    for rep in (rw, rc):
        assert rep.bytes_migrated == pytest.approx(rep.resident_bytes)
        assert rep.ranks_moved == rep.resident_moved
