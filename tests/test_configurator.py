"""End-to-end configurator behaviour (Algorithm 1) vs the baselines on the
simulated clusters — the paper's headline claims at test scale."""
import numpy as np
import pytest

from repro.core import (MID_RANGE, Conf, DedicationEngine, GroupIndex,
                        Workload, amp_configure, amp_latency, anneal,
                        build_profile, configure, default_mapping,
                        enumerate_confs, fit_memory_estimator,
                        ground_truth_memory, measure, mlm_configure,
                        pipette_latency, profile_bandwidth,
                        true_bandwidth_matrix, varuna_configure)
from repro.models.config import ModelConfig

GPT = ModelConfig(name="gpt-1.1b", family="dense", n_layers=24, d_model=1920,
                  n_heads=20, n_kv_heads=20, d_ff=7680, vocab_size=51200)
SPEC = MID_RANGE.with_nodes(4)
W = Workload(GPT, 2048, 128)


@pytest.fixture(scope="module")
def bw():
    return true_bandwidth_matrix(SPEC), profile_bandwidth(SPEC)[0]


def test_configure_returns_valid_best(bw):
    bw_true, bw_meas = bw
    res = configure(W, SPEC, bw_meas, sa_seconds=0.08, sa_iters=800)
    best = res.best
    assert best is not None
    assert best.conf.pp * best.conf.tp * best.conf.dp == SPEC.n_gpus
    assert best.conf.valid()
    assert sorted(best.mapping.reshape(-1).tolist()) == \
        list(range(SPEC.n_gpus))
    assert res.ranked == sorted(res.ranked, key=lambda c: c.latency)
    assert res.overhead["n_candidates"] > 10


def test_pipette_not_slower_than_baselines(bw):
    """Measured on the simulator, Pipette's pick must be at least as fast
    as AMP's and Varuna's picks (Fig. 6 direction)."""
    bw_true, bw_meas = bw
    res = configure(W, SPEC, bw_meas, sa_seconds=0.15, sa_iters=2000, seed=2)
    t_ppt = measure(res.best.conf, res.best.mapping, W, SPEC, bw_true)
    amp = amp_configure(W, SPEC)
    t_amp = measure(amp.best.conf, amp.best.mapping, W, SPEC, bw_true)
    vr = varuna_configure(W, SPEC)
    t_vr = measure(vr.best.conf, vr.best.mapping, W, SPEC, bw_true)
    assert t_ppt <= t_amp * 1.02
    assert t_ppt <= t_vr * 1.02


def test_latency_estimator_beats_amp_model(bw):
    """Fig. 5a: MAPE of Pipette's estimator << AMP's model across a diverse
    config sample."""
    bw_true, bw_meas = bw
    errs_p, errs_a = [], []
    from repro.core.memory import enumerate_confs
    sample = [c for c in enumerate_confs(SPEC.n_gpus, W.bs_global,
                                         n_layers=GPT.n_layers)
              if c.bs_micro <= 8][::3][:20]
    for conf in sample:
        prof = build_profile(W, SPEC, conf)
        m = default_mapping(conf)
        truth = measure(conf, m, W, SPEC, bw_true)
        errs_p.append(abs(pipette_latency(conf, m, bw_meas, prof, SPEC)
                          - truth) / truth)
        errs_a.append(abs(amp_latency(conf, m, SPEC, prof) - truth) / truth)
    assert np.mean(errs_p) < np.mean(errs_a)
    assert np.mean(errs_p) < 0.10          # paper: 5.87%


def test_mlm_heuristic_memory_safe(bw):
    bw_true, _ = bw
    res = mlm_configure(W, SPEC, bw_true)
    assert res.best is not None
    assert res.best.conf.tp == SPEC.gpus_per_node
    assert ground_truth_memory(W, res.best.conf, SPEC) <= SPEC.gpu_mem


def test_predict_batch_matches_scalar_bitwise():
    """The batched jitted forward must reproduce the scalar ``predict`` API
    to float32 bit-equality on a large random config sample."""
    est = fit_memory_estimator([W], SPEC, fit_nodes=1, steps=1500,
                               residual=True)
    pool = [c for g in (8, 16, 24, 32, 48, 64) for bsg in (64, 128, 256)
            for c in enumerate_confs(g, bsg, n_layers=GPT.n_layers)
            if c.bs_micro <= 16]
    rng = np.random.default_rng(0)
    confs = [pool[i] for i in rng.choice(len(pool), size=240, replace=False)]
    with np.errstate(over="ignore"):       # extrapolation may saturate exp
        batch = est.predict_batch(W.cfg, confs)
        scalar = np.array([est.predict(W.cfg, c) for c in confs])
    assert batch.shape == (240,)
    assert batch.astype(np.float32).tobytes() == \
        scalar.astype(np.float32).tobytes()


def test_sa_topk_matches_exhaustive_best(bw):
    """Concentrating the SA budget on the top-k pre-scored candidates must
    find the same best as annealing every survivor (small cluster,
    iteration-bound so the SA trajectories are deterministic)."""
    _, bw_meas = bw
    kw = dict(sa_seconds=60.0, sa_iters=250, max_micro=4, seed=3)
    full = configure(W, SPEC, bw_meas, **kw)
    topk = configure(W, SPEC, bw_meas, sa_topk=8, **kw)
    assert topk.best.conf == full.best.conf
    assert topk.best.latency == full.best.latency
    # the knob prunes SA work, not candidates: the ranking stays complete
    assert topk.overhead["n_candidates"] == full.overhead["n_candidates"]


def test_ranked_order_matches_prerefactor_reference(bw):
    """The staged pipeline must rank exactly like the pre-refactor
    per-candidate loop (same confs, bit-equal latencies) for a fixed seed,
    with and without SA dedication."""
    _, bw_meas = bw
    kw = dict(max_micro=4, seed=5)
    res_sa = configure(W, SPEC, bw_meas, sa_seconds=60.0, sa_iters=120, **kw)
    res_plain = configure(W, SPEC, bw_meas, dedicate=False, **kw)

    cands_sa, cands_plain = [], []
    index_cache = {}
    for conf in enumerate_confs(SPEC.n_gpus, W.bs_global,
                                n_layers=GPT.n_layers):
        if conf.bs_micro > 4:
            continue
        prof = build_profile(W, SPEC, conf)
        m = default_mapping(conf)
        cands_plain.append((conf, pipette_latency(conf, m, bw_meas, prof,
                                                  SPEC)))
        shape = (conf.pp, conf.tp, conf.dp)
        idx = index_cache.get(shape)
        if idx is None:
            idx = index_cache[shape] = GroupIndex.build(conf)
        engine = DedicationEngine(conf, bw_meas, prof, SPEC, index=idx)
        r = anneal(conf, bw_meas, prof, SPEC, time_limit_s=60.0,
                   max_iters=120, seed=5, engine=engine)
        cands_sa.append((conf, r.latency))
    cands_sa.sort(key=lambda t: t[1])
    cands_plain.sort(key=lambda t: t[1])

    assert [c.conf for c in res_sa.ranked] == [c for c, _ in cands_sa]
    assert [c.latency for c in res_sa.ranked] == [t for _, t in cands_sa]
    assert [c.conf for c in res_plain.ranked] == [c for c, _ in cands_plain]
    assert [c.latency for c in res_plain.ranked] == \
        [t for _, t in cands_plain]


def test_configure_with_memory_estimator_prunes(bw):
    """With a tight memory limit the search must drop OOM configs."""
    _, bw_meas = bw
    from repro.core import fit_memory_estimator
    est = fit_memory_estimator([W], SPEC, fit_nodes=2, steps=2500,
                               residual=True)
    res_all = configure(W, SPEC, bw_meas, dedicate=False)
    res_lim = configure(W, SPEC, bw_meas, estimator=est,
                        mem_limit=SPEC.gpu_mem, dedicate=False)
    assert 0 < res_lim.overhead["n_candidates"] <= \
        res_all.overhead["n_candidates"]
    for c in res_lim.top(10):
        assert ground_truth_memory(W, c.conf, SPEC) <= SPEC.gpu_mem * 1.25
