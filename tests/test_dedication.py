"""SA worker dedication: move validity (hypothesis property tests),
objective improvement, and end-to-end behaviour on a heterogeneous
cluster."""
import numpy as np
import pytest

from repro.core import (MID_RANGE, Conf, Workload, anneal, build_profile,
                        default_mapping, perm_to_mapping,
                        true_bandwidth_matrix)
from repro.core.dedication import _move
from repro.core.latency import pipette_latency
from repro.models.config import ModelConfig

# optional dep: skip the module without failing collection; assigning the
# names (instead of `from hypothesis import ...` after a statement) keeps
# every real import at the top of the file (ruff E402)
hyp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
given, settings = hyp.given, hyp.settings

GPT = ModelConfig(name="g", family="dense", n_layers=24, d_model=1920,
                  n_heads=20, n_kv_heads=20, d_ff=7680, vocab_size=51200)


@settings(max_examples=60, deadline=None)
@given(n=st.integers(4, 128), seed=st.integers(0, 10_000), moves=st.integers(1, 30))
def test_moves_preserve_permutation(n, seed, moves):
    """migration/swap/reverse always yield a bijection (Eq. 2)."""
    rng = np.random.default_rng(seed)
    p = np.arange(n)
    for _ in range(moves):
        p = _move(p, rng)
        assert sorted(p.tolist()) == list(range(n))


@settings(max_examples=20, deadline=None)
@given(pp=st.sampled_from([2, 4]), tp=st.sampled_from([1, 2]),
       dp=st.sampled_from([2, 4]))
def test_perm_to_mapping_bijective(pp, tp, dp):
    conf = Conf(pp, tp, dp, 1, 64 * dp)
    perm = np.random.default_rng(0).permutation(conf.n_gpus)
    m = perm_to_mapping(perm, conf)
    assert m.shape == (pp, tp, dp)
    assert sorted(m.reshape(-1).tolist()) == list(range(conf.n_gpus))


def test_sa_improves_on_heterogeneous_cluster():
    spec = MID_RANGE.with_nodes(4)
    w = Workload(GPT, 2048, 128)
    conf = Conf(4, 4, 2, 2, 128)
    bw = true_bandwidth_matrix(spec)
    prof = build_profile(w, spec, conf)
    m0 = default_mapping(conf)
    base = pipette_latency(conf, m0, bw, prof, spec)
    res = anneal(conf, bw, prof, spec, time_limit_s=1.0, max_iters=3000,
                 seed=1)
    assert res.latency <= base * (1 + 1e-9)
    # the best-so-far trace is monotone non-increasing
    vals = [v for _, v in res.trace]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_sa_respects_tp_locality():
    """With the profiled-TP-scale term, SA must not strand a tensor-parallel
    group across nodes (the §IV rationale for intra-server TP)."""
    spec = MID_RANGE.with_nodes(4)
    w = Workload(GPT, 2048, 128)
    conf = Conf(2, 8, 2, 2, 128)
    bw = true_bandwidth_matrix(spec)
    prof = build_profile(w, spec, conf)
    res = anneal(conf, bw, prof, spec, time_limit_s=1.0, max_iters=4000,
                 seed=3)
    for x in range(conf.pp):
        for z in range(conf.dp):
            nodes = {int(res.mapping[x, y, z]) // spec.gpus_per_node
                     for y in range(conf.tp)}
            assert len(nodes) == 1, "TP group split across nodes"
