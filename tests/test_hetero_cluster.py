"""Heterogeneous-compute cluster specs: construction-time validation,
per-GPU device views, the seeded mixed-fleet / degraded-host generators,
and the single-tier degeneration guarantee."""
import dataclasses

import numpy as np
import pytest

from repro.core import (MID_RANGE, MID_RANGE_DEGRADED, MIXED_A100_V100,
                        ClusterSpec, DeviceTier, compute_slowdowns,
                        tier_fingerprint)
from repro.core.cluster import (A100_TIER, V100_TIER, degraded_host_spec,
                                mixed_fleet_spec)


# ---------------------------------------------------------------------------
# construction-time validation (bad specs fail here, not in the bandwidth
# generator)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw, match", [
    (dict(n_nodes=0), "n_nodes"),
    (dict(n_nodes=-3), "n_nodes"),
    (dict(gpus_per_node=0), "gpus_per_node"),
    (dict(intra_bw=0.0), "intra_bw"),
    (dict(inter_bw=-1e9), "inter_bw"),
    (dict(gpu_flops=0.0), "gpu_flops"),
    (dict(gpu_mem=-1.0), "gpu_mem"),
    (dict(efficiency=0.0), "efficiency"),
    (dict(efficiency=1.5), "efficiency"),
    (dict(heterogeneity=-0.1), "heterogeneity"),
    (dict(slow_frac=1.5), "slow_frac"),
])
def test_spec_rejects_bad_scalars(kw, match):
    base = dict(name="bad", n_nodes=2)
    base.update(kw)
    with pytest.raises(ValueError, match=match):
        ClusterSpec(**base)


def test_spec_rejects_tier_table_without_assignment():
    with pytest.raises(ValueError, match="together"):
        ClusterSpec("bad", 2, tiers=(V100_TIER,))
    with pytest.raises(ValueError, match="together"):
        ClusterSpec("bad", 2, node_tiers=(0, 0))


def test_spec_rejects_wrong_assignment_length():
    with pytest.raises(ValueError, match="every node"):
        ClusterSpec("bad", 3, tiers=(V100_TIER,), node_tiers=(0, 0))


def test_spec_rejects_out_of_range_tier_index():
    with pytest.raises(ValueError, match="out of range"):
        ClusterSpec("bad", 2, tiers=(V100_TIER,), node_tiers=(0, 1))
    with pytest.raises(ValueError, match="out of range"):
        ClusterSpec("bad", 2, tiers=(V100_TIER,), node_tiers=(0, -1))


def test_device_tier_rejects_non_positive_fields():
    with pytest.raises(ValueError, match="DeviceTier"):
        DeviceTier(flops=0.0, mem=32e9)
    with pytest.raises(ValueError, match="DeviceTier"):
        DeviceTier(flops=1e12, mem=-1.0)
    with pytest.raises(ValueError, match="DeviceTier"):
        DeviceTier(flops=1e12, mem=32e9, efficiency=0.0)


def test_with_nodes_revalidates():
    with pytest.raises(ValueError, match="n_nodes"):
        MID_RANGE.with_nodes(0)


def test_spec_accepts_list_inputs_and_stays_hashable():
    s = ClusterSpec("ok", 2, tiers=[V100_TIER], node_tiers=[0, 0])
    assert isinstance(s.tiers, tuple) and isinstance(s.node_tiers, tuple)
    hash(s)                                  # frozen + tuple fields


# ---------------------------------------------------------------------------
# per-GPU device views
# ---------------------------------------------------------------------------

def test_homogeneous_per_gpu_views_match_scalars():
    s = MID_RANGE
    assert np.all(s.per_gpu_flops() == s.gpu_flops)
    assert np.all(s.per_gpu_mem() == s.gpu_mem)
    assert np.all(s.per_gpu_throughput() == s.gpu_flops * s.efficiency)
    assert s.mem_floor == s.gpu_mem
    assert not s.has_tiers
    assert compute_slowdowns(s) is None


def test_tiered_per_gpu_views_follow_node_assignment():
    s = MIXED_A100_V100
    flops = s.per_gpu_flops()
    mem = s.per_gpu_mem()
    for g in range(s.n_gpus):
        tier = s.tiers[s.node_tiers[s.node_of(g)]]
        assert flops[g] == tier.flops
        assert mem[g] == tier.mem
        assert s.tier_of(g) == tier
    assert s.mem_floor == min(A100_TIER.mem, V100_TIER.mem)
    slow = compute_slowdowns(s)
    assert slow is not None and slow.shape == (s.n_gpus,)
    # reference is the fastest (A100) tier: its GPUs sit at exactly 1.0
    assert slow.min() == 1.0
    assert slow.max() == pytest.approx(A100_TIER.throughput
                                       / V100_TIER.throughput)


def test_single_tier_spec_degenerates_to_scalar():
    """A tier table whose only tier matches the reference scalars is
    indistinguishable from the scalar spec (compute_slowdowns -> None)."""
    s = ClusterSpec("one", 4, tiers=(DeviceTier(MID_RANGE.gpu_flops,
                                                MID_RANGE.gpu_mem,
                                                MID_RANGE.efficiency),),
                    node_tiers=(0,) * 4)
    assert compute_slowdowns(s) is None
    assert s.mem_floor == MID_RANGE.gpu_mem


def test_with_nodes_keeps_tier_pattern():
    s = MIXED_A100_V100
    shrunk = s.with_nodes(5)
    assert shrunk.node_tiers == s.node_tiers[:5]
    grown = s.with_nodes(20)
    assert grown.node_tiers[:16] == s.node_tiers
    assert grown.node_tiers[16:] == s.node_tiers[:4]
    assert MID_RANGE.with_nodes(4).node_tiers == ()


# ---------------------------------------------------------------------------
# seeded generators
# ---------------------------------------------------------------------------

def test_mixed_fleet_spec_counts_and_determinism():
    s = mixed_fleet_spec("m", 10, (A100_TIER, V100_TIER), (0.5, 0.5),
                         seed=3)
    assert s.node_tiers.count(0) == 5 and s.node_tiers.count(1) == 5
    assert s == mixed_fleet_spec("m", 10, (A100_TIER, V100_TIER),
                                 (0.5, 0.5), seed=3)
    other = mixed_fleet_spec("m", 10, (A100_TIER, V100_TIER), (0.5, 0.5),
                             seed=4)
    assert other.node_tiers != s.node_tiers       # seeded shuffle
    # reference scalars pinned to the fastest tier => slowdowns >= 1
    assert s.gpu_flops == A100_TIER.flops
    assert compute_slowdowns(s).min() >= 1.0


def test_mixed_fleet_spec_rejects_bad_fractions():
    with pytest.raises(ValueError, match="fractions"):
        mixed_fleet_spec("m", 4, (A100_TIER, V100_TIER), (0.5,))
    with pytest.raises(ValueError, match="at least one tier"):
        mixed_fleet_spec("m", 4, ())
    with pytest.raises(ValueError, match="positive"):
        mixed_fleet_spec("m", 4, (A100_TIER, V100_TIER), (0.0, 0.0))


def test_mixed_fleet_zero_fraction_tier_stays_absent():
    """Remainder nodes must never land on a tier the caller excluded with
    fraction 0.0 (3 nodes over (0, 0.5, 0.5) leaves a remainder)."""
    third = DeviceTier(50e12, 16e9, 0.4, name="t3")
    s = mixed_fleet_spec("m", 3, (A100_TIER, V100_TIER, third),
                         (0.0, 0.5, 0.5), seed=1)
    assert 0 not in s.node_tiers
    assert s.node_tiers.count(1) + s.node_tiers.count(2) == 3


def test_degraded_host_spec():
    s = degraded_host_spec(MID_RANGE, degraded_frac=0.25, flops_factor=0.5,
                           seed=5)
    assert s.node_tiers.count(1) == 4             # 25% of 16 nodes
    healthy, degraded = s.tiers
    assert healthy.flops == MID_RANGE.gpu_flops
    assert degraded.flops == MID_RANGE.gpu_flops * 0.5
    assert s == degraded_host_spec(MID_RANGE, degraded_frac=0.25,
                                   flops_factor=0.5, seed=5)
    slow = compute_slowdowns(s)
    assert set(np.unique(slow)) == {1.0, 2.0}
    with pytest.raises(ValueError, match="homogeneous base"):
        degraded_host_spec(s)
    with pytest.raises(ValueError, match="degraded_frac"):
        degraded_host_spec(MID_RANGE, degraded_frac=0.0)
    assert MID_RANGE_DEGRADED.node_tiers.count(1) == 4


# ---------------------------------------------------------------------------
# tier provenance digest
# ---------------------------------------------------------------------------

def test_tier_fingerprint():
    assert tier_fingerprint(MID_RANGE) is None
    d = tier_fingerprint(MIXED_A100_V100)
    assert isinstance(d, str) and len(d) == 64
    assert d == tier_fingerprint(MIXED_A100_V100)
    # any change to the table or the assignment changes the digest
    moved = dataclasses.replace(
        MIXED_A100_V100,
        node_tiers=MIXED_A100_V100.node_tiers[::-1])
    assert tier_fingerprint(moved) != d
    retiered = dataclasses.replace(
        MIXED_A100_V100,
        tiers=(A100_TIER, dataclasses.replace(V100_TIER, mem=16e9)))
    assert tier_fingerprint(retiered) != d
