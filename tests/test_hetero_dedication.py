"""Compute-aware worker dedication on tiered clusters.

Covers the three contracts of the heterogeneous-compute engine:

1. **bit-equality** — the incremental :class:`DedicationEngine` equals
   :func:`pipette_latency` and the pure-Python ``pipette_latency_ref``
   oracle on tiered specs, through long propose/commit chains;
2. **the headline** (acceptance criterion) — on a seeded mixed A100/V100
   16-node cluster, compute-aware dedication yields *strictly lower
   simulated* iteration latency than compute-blind dedication of the same
   configuration;
3. **plumbing** — search-level integration: per-GPU memory floor, tier
   provenance on the Plan, compute-aware scores inside ``run_search``.
"""
import numpy as np
import pytest

from repro.core import (MIXED_A100_V100, Budget, Conf, DedicationEngine,
                        Planner, PlanRequest, PipetteStrategy, SearchSpace,
                        Workload, anneal_multistart, build_profile,
                        configure, default_mapping, pipette_latency,
                        pipette_latency_ref, profile_bandwidth,
                        true_bandwidth_matrix)
from repro.core.cluster import (A100_TIER, V100_TIER, compute_slowdowns,
                                mixed_fleet_spec, tier_fingerprint)
from repro.core.dedication import _move_span, perm_to_mapping
from repro.core.simulator import measure
from repro.models.config import ModelConfig

GPT = ModelConfig(name="g12", family="dense", n_layers=12, d_model=1024,
                  n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=32000)

# The headline scenario: 16 single-GPU nodes, half A100 / half V100 in a
# seeded shuffle.  pp=8 over 12 layers leaves four light (1-layer) stages
# next to four heavy (2-layer) stages — exactly where slow GPUs hurt least.
MIXED_16 = mixed_fleet_spec("mixed-a100-v100-16x1", 16,
                            (A100_TIER, V100_TIER), (0.5, 0.5),
                            gpus_per_node=1, seed=47)
HEADLINE_CONF = Conf(8, 1, 2, 2, 32)
W = Workload(GPT, 2048, 32)


# ---------------------------------------------------------------------------
# engine == model == reference, bit for bit, on tiered specs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("conf", [
    Conf(4, 8, 4, 2, 256),               # 3D on the 128-GPU mixed preset
    Conf(4, 4, 4, 2, 256, cp=2),         # 4D
])
def test_engine_matches_model_and_ref_on_tiered_spec(conf):
    spec = MIXED_A100_V100
    w = Workload(GPT, 2048, 256)
    bw, _ = profile_bandwidth(spec)
    prof = build_profile(w, spec, conf)
    eng = DedicationEngine(conf, bw, prof, spec)
    rng = np.random.default_rng(11)
    perm = np.arange(conf.n_gpus)
    eng.score(perm)
    for trial in range(120):
        cand, touched = _move_span(perm, rng)
        val, pending = eng.propose(cand, touched)
        m = perm_to_mapping(cand, conf)
        assert val == pipette_latency(conf, m, bw, prof, spec)
        if trial % 10 == 0:
            assert val == pipette_latency_ref(conf, m, bw, prof, spec)
        if trial % 3 == 0:
            eng.commit(pending)
            perm = cand
    assert eng.score(perm) == pipette_latency(
        conf, perm_to_mapping(perm, conf), bw, prof, spec)


def test_compute_blind_engine_ignores_tiers():
    """``compute_aware=False`` prices every GPU at reference speed: its
    scores equal the same spec with the tier table erased."""
    import dataclasses
    spec = MIXED_16
    flat = dataclasses.replace(spec, tiers=(), node_tiers=())
    conf = HEADLINE_CONF
    bw, _ = profile_bandwidth(spec)
    prof = build_profile(W, spec, conf)
    blind = DedicationEngine(conf, bw, prof, spec, compute_aware=False)
    ref = DedicationEngine(conf, bw, prof, flat)
    perm = np.random.default_rng(0).permutation(conf.n_gpus)
    assert blind.score(perm) == ref.score(perm)


def test_hetero_latency_penalises_slow_stages():
    """A mapping that herds V100s onto light stages scores strictly better
    than one spreading them over every stage — the signal SA climbs."""
    spec = MIXED_16
    conf = HEADLINE_CONF
    bw, _ = profile_bandwidth(spec)
    prof = build_profile(W, spec, conf)
    slow = compute_slowdowns(spec)
    fast_first = np.argsort(slow, kind="stable")     # A100s, then V100s
    herded = perm_to_mapping(fast_first, conf)       # V100s on late (light)
    spread = default_mapping(conf)
    assert pipette_latency(conf, herded, bw, prof, spec) < \
        pipette_latency(conf, spread, bw, prof, spec)


# ---------------------------------------------------------------------------
# the headline: aware beats blind in the *simulator*
# ---------------------------------------------------------------------------

def test_compute_aware_beats_blind_in_simulator():
    """Acceptance criterion: on the seeded mixed A100/V100 16-node cluster,
    compute-aware SA dedication of HEADLINE_CONF simulates strictly faster
    than compute-blind SA dedication of the same conf (same budget, same
    seed), and than the default node-major assignment."""
    spec = MIXED_16
    conf = HEADLINE_CONF
    bw, _ = profile_bandwidth(spec)
    bw_true = true_bandwidth_matrix(spec)
    prof = build_profile(W, spec, conf)
    kw = dict(n_chains=4, time_limit_s=30.0, max_iters=40_000, seed=0)
    aware = anneal_multistart(conf, bw, prof, spec, **kw)
    blind = anneal_multistart(conf, bw, prof, spec, compute_aware=False,
                              **kw)
    sim_aware = measure(conf, aware.mapping, W, spec, bw_true, seed=1)
    sim_blind = measure(conf, blind.mapping, W, spec, bw_true, seed=1)
    sim_default = measure(conf, default_mapping(conf), W, spec, bw_true,
                          seed=1)
    assert sim_aware < sim_blind
    assert sim_aware < sim_default
    # the win is structural (slow GPUs herded onto light stages), not noise
    assert sim_aware < 0.9 * sim_blind


# ---------------------------------------------------------------------------
# search / plan integration
# ---------------------------------------------------------------------------

def test_search_prunes_against_tightest_tier():
    """Without an explicit mem_limit the search must budget for the
    *smallest* GPU (every GPU hosts a worker): the default limit on the
    mixed preset is the V100's 32 GB, not the A100 reference's 80 GB."""
    from repro.core.search import run_search

    class Probe:
        """Estimator stub predicting a constant peak for every conf."""
        soft_margin = 1.0
        with_cp = False

        def __init__(self, pred):
            self.pred = pred

        def predict_batch(self, cfg, confs):
            return np.full(len(confs), self.pred)

    assert MIXED_16.mem_floor == V100_TIER.mem
    req = PlanRequest(workload=W, spec=MIXED_16,
                      space=SearchSpace(max_micro=2),
                      budget=Budget(sa_seconds=60.0, sa_iters=5, sa_topk=1))
    bw, _ = profile_bandwidth(MIXED_16)
    # a 40 GB peak fits the A100 reference (80 GB) but not the V100 floor
    # (32 GB): everything must be pruned
    res = run_search(req, bw, estimator=Probe(40e9))
    assert res.best is None and not res.ranked
    # under the floor, the tiered pipeline runs end-to-end
    res = run_search(req, bw, estimator=Probe(10e9))
    assert res.best is not None


def test_estimator_fits_spec_uses_per_gpu_capacity():
    """fits_spec budgets for the tightest tier: a peak that fits the A100
    reference but not the V100 floor must be rejected on the mixed fleet
    and accepted on an all-A100 fleet of the same shape."""
    import dataclasses

    from repro.core import MemoryEstimator
    est = MemoryEstimator.__new__(MemoryEstimator)
    est.soft_margin = 1.0
    est.predict = lambda cfg, conf: 40e9          # between 32 GB and 80 GB
    conf = HEADLINE_CONF
    assert not est.fits_spec(GPT, conf, MIXED_16)
    all_a100 = dataclasses.replace(
        MIXED_16, node_tiers=(0,) * MIXED_16.n_nodes)
    assert est.fits_spec(GPT, conf, all_a100)


def test_plan_records_tier_provenance():
    spec = MIXED_16
    bw, _ = profile_bandwidth(spec)
    req = PlanRequest(workload=W, spec=spec,
                      space=SearchSpace(max_micro=2),
                      budget=Budget(sa_seconds=60.0, sa_iters=20,
                                    sa_topk=2), seed=5)
    plan = Planner(PipetteStrategy()).plan(req, bw)
    tiers = plan.provenance.tiers
    assert tiers is not None
    assert tiers["digest"] == tier_fingerprint(spec)
    assert [t["name"] for t in tiers["tiers"]] == ["a100", "v100"]
    assert tiers["node_tiers"] == [int(t) for t in spec.node_tiers]
    d = plan.to_json_dict()
    assert d["provenance"]["tiers"]["digest"] == tier_fingerprint(spec)
    # homogeneous plans keep the key, with null
    import dataclasses
    flat = dataclasses.replace(spec, tiers=(), node_tiers=())
    req_h = PlanRequest(workload=W, spec=flat,
                        space=SearchSpace(max_micro=2),
                        budget=Budget(sa_seconds=60.0, sa_iters=20,
                                      sa_topk=2), seed=5)
    plan_h = Planner(PipetteStrategy()).plan(req_h, bw)
    assert plan_h.provenance.tiers is None
    assert plan_h.to_json_dict()["provenance"]["tiers"] is None


def test_elastic_replan_keeps_tier_pattern():
    """Losing nodes on a mixed fleet re-plans against the surviving tier
    mix: the shrunk spec keeps the tier pattern and the resulting Plan's
    provenance records the new (different) tier digest."""
    from repro.runtime.elastic import replan
    ep = replan(W, MIXED_16, 12, sa_seconds=60.0, sa_iters=20, sa_topk=2,
                max_micro=2)
    assert ep.n_gpus == 12
    shrunk = MIXED_16.with_nodes(12)
    assert ep.plan.provenance.tiers["digest"] == tier_fingerprint(shrunk)
    assert ep.plan.provenance.tiers["digest"] != tier_fingerprint(MIXED_16)


def test_configure_on_tiered_spec_scores_compute_aware():
    """configure() on a tiered spec must rank with the compute-aware model:
    the best candidate's recorded latency equals a fresh pipette_latency
    (which prices per-stage compute) of its mapping."""
    spec = MIXED_16
    bw, _ = profile_bandwidth(spec)
    res = configure(W, spec, bw, sa_seconds=60.0, sa_iters=40, sa_topk=2,
                    max_micro=2, seed=2)
    best = res.best
    prof = build_profile(W, spec, best.conf)
    assert best.latency == pipette_latency(best.conf, best.mapping, bw,
                                           prof, spec)
