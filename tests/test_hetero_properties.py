"""Property tests (hypothesis) for heterogeneous-compute specs.

The load-bearing property: a *single-tier* spec whose tier equals the
reference scalars is bit-exact with the plain scalar spec across random
configurations — latency, memory ground truth, and dedication-engine
scores.  This is the degeneration guarantee the whole refactor rests on.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (MID_RANGE, ClusterSpec, Conf, DeviceTier, Workload,
                        build_profile, ground_truth_memory, pipette_latency,
                        profile_bandwidth)
from repro.core.cluster import compute_slowdowns
from repro.core.dedication import DedicationEngine
from repro.models.config import ModelConfig

hyp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
given, settings = hyp.given, hyp.settings

GPT = ModelConfig(name="g", family="dense", n_layers=24, d_model=1024,
                  n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=32000)


def _single_tier(spec: ClusterSpec) -> ClusterSpec:
    """The tiered twin of a scalar spec: one tier, equal to the scalars."""
    return dataclasses.replace(
        spec,
        tiers=(DeviceTier(spec.gpu_flops, spec.gpu_mem, spec.efficiency),),
        node_tiers=(0,) * spec.n_nodes)


@settings(max_examples=40, deadline=None)
@given(pp=st.sampled_from([1, 2, 4]), tp=st.sampled_from([1, 2, 4]),
       dp=st.sampled_from([1, 2]), mb=st.sampled_from([1, 2, 4]),
       perm_seed=st.integers(0, 2 ** 16))
def test_single_tier_spec_bit_exact_vs_scalar(pp, tp, dp, mb, perm_seed):
    n_gpus = pp * tp * dp
    scalar = MID_RANGE.with_nodes(-(-n_gpus // MID_RANGE.gpus_per_node))
    tiered = _single_tier(scalar)
    assert compute_slowdowns(tiered) is None

    conf = Conf(pp, tp, dp, mb, 16 * dp * mb)
    w = Workload(GPT, 512, conf.bs_global)
    prof_s = build_profile(w, scalar, conf)
    prof_t = build_profile(w, tiered, conf)
    assert prof_s == prof_t

    assert ground_truth_memory(w, conf, scalar).hex() == \
        ground_truth_memory(w, conf, tiered).hex()

    bw, _ = profile_bandwidth(scalar)
    perm = np.random.default_rng(perm_seed).permutation(scalar.n_gpus)
    mapping = perm[:n_gpus].reshape(conf.pp, conf.dp,
                                    conf.tp).transpose(0, 2, 1)
    lat_s = pipette_latency(conf, mapping, bw, prof_s, scalar)
    lat_t = pipette_latency(conf, mapping, bw, prof_t, tiered)
    assert lat_s.hex() == lat_t.hex()


@settings(max_examples=15, deadline=None)
@given(pp=st.sampled_from([2, 4]), tp=st.sampled_from([1, 2]),
       mb=st.sampled_from([1, 2]), perm_seed=st.integers(0, 2 ** 16))
def test_single_tier_engine_scores_bit_exact(pp, tp, mb, perm_seed):
    dp = 2
    n_gpus = pp * tp * dp
    spec = MID_RANGE.with_nodes(max(1, -(-n_gpus // MID_RANGE.gpus_per_node)))
    tiered = _single_tier(spec)
    conf = Conf(pp, tp, dp, mb, 16 * dp * mb)
    w = Workload(GPT, 512, conf.bs_global)
    prof = build_profile(w, spec, conf)
    assert prof == build_profile(w, tiered, conf)
    bw, _ = profile_bandwidth(spec)
    # permutation over the conf's worker count, drawn from the cluster GPUs
    perm = np.random.default_rng(perm_seed).permutation(
        spec.n_gpus)[:n_gpus]
    eng_s = DedicationEngine(conf, bw, prof, spec)
    eng_t = DedicationEngine(conf, bw, prof, tiered)
    assert eng_s.score(perm).hex() == eng_t.score(perm).hex()


@settings(max_examples=20, deadline=None)
@given(factor=st.floats(0.2, 0.9), frac_idx=st.integers(1, 3),
       seed=st.integers(0, 99))
def test_slower_tier_never_speeds_up_the_model(factor, frac_idx, seed):
    """Degrading some hosts can only increase (or keep) estimated latency
    vs the healthy scalar spec — never decrease it."""
    from repro.core.cluster import degraded_host_spec
    base = MID_RANGE.with_nodes(4)
    spec = degraded_host_spec(base, degraded_frac=frac_idx / 4,
                              flops_factor=factor, seed=seed)
    conf = Conf(4, 8, 1, 2, 32)
    w = Workload(GPT, 512, 32)
    prof = build_profile(w, base, conf)
    assert prof == build_profile(w, spec, conf)   # same reference profile
    bw, _ = profile_bandwidth(base)
    from repro.core import default_mapping
    m = default_mapping(conf)
    assert pipette_latency(conf, m, bw, prof, spec) >= \
        pipette_latency(conf, m, bw, prof, base)
