"""Hierarchical (island-decomposed) worker dedication.

Pins the three structural guarantees of the hierarchical search layer:
island decomposition is a *partition* of the flat position space (the
concatenated islands round-trip to ``arange(n)``), refinement never
worsens the coarse inter-island assignment's simulated latency (SA best
starts at the coarse permutation), and single-island specs degenerate
bit-exactly to the flat path (the MovePlan skips the island draw, so the
RNG streams coincide)."""
import numpy as np
import pytest

from repro.core import (Budget, ClusterSpec, Conf, DedicationEngine,
                        Workload, build_islands, build_profile,
                        coarse_assign, coarse_orderings,
                        dedicate_candidates, perm_to_mapping,
                        pipette_latency, profile_bandwidth)
from repro.core.annealing import HIER_AUTO_GPUS
from repro.core.cluster import A100_TIER, V100_TIER, mixed_fleet_spec
from repro.configs.gpt_paper import GPT_3_1B

W = Workload(GPT_3_1B, 2048, 32)
MIXED = mixed_fleet_spec("hier-mixed-32x4", 32, (A100_TIER, V100_TIER),
                         (0.5, 0.5), gpus_per_node=4, seed=13)
UNIFORM = ClusterSpec("hier-uni-2x4", 2, gpus_per_node=4, seed=1)


def _setup(spec, conf):
    bw, _ = profile_bandwidth(spec)
    prof = build_profile(W, spec, conf)
    return bw, prof


# ---------------------------------------------------------------------------
# island decomposition
# ---------------------------------------------------------------------------

def test_flat_mode_is_one_island():
    islands = build_islands(MIXED, hierarchical=False)
    assert len(islands) == 1
    assert np.array_equal(islands[0], np.arange(MIXED.n_gpus))


@pytest.mark.parametrize("cap", [8, 16, 64, 256])
def test_islands_partition_position_space(cap):
    """Round-trip: the islands are disjoint and cover every position —
    sorting the concatenation reproduces the flat arange exactly."""
    islands = build_islands(MIXED, hierarchical=True, max_island_gpus=cap)
    cat = np.concatenate(islands)
    assert np.array_equal(np.sort(cat), np.arange(MIXED.n_gpus))
    for isl in islands:
        assert len(isl) >= 2                 # SA needs two positions
        # islands never split a node
        nodes = np.asarray(isl) // MIXED.gpus_per_node
        for n in np.unique(nodes):
            assert (nodes == n).sum() == MIXED.gpus_per_node


def test_islands_respect_tier_boundaries():
    """Each island is tier-pure: coarse assignment reasons about whole
    islands, so mixing tiers inside one would hide heterogeneity."""
    islands = build_islands(MIXED, hierarchical=True, max_island_gpus=16)
    assert len(islands) > 1
    tiers = np.asarray(MIXED.node_tiers)
    for isl in islands:
        node_tiers = tiers[np.asarray(isl) // MIXED.gpus_per_node]
        assert len(set(node_tiers.tolist())) == 1


def test_uniform_small_spec_is_single_island():
    islands = build_islands(UNIFORM, hierarchical=True)
    assert len(islands) == 1


# ---------------------------------------------------------------------------
# coarse inter-island assignment
# ---------------------------------------------------------------------------

def test_coarse_assign_offsets_and_value():
    conf = Conf(4, 2, 16, 1, 32)
    bw, prof = _setup(MIXED, conf)
    eng = DedicationEngine(conf, bw, prof, MIXED)
    islands = build_islands(MIXED, hierarchical=True, max_island_gpus=32)
    orderings = coarse_orderings(islands, MIXED)
    assert orderings and all(
        sorted(o) == list(range(len(islands))) for o in orderings)
    init, offsets, value = coarse_assign(eng, islands, orderings)
    # the init permutation is a permutation, offsets delimit the islands
    assert np.array_equal(np.sort(init), np.arange(MIXED.n_gpus))
    assert offsets.shape == (len(islands),)
    assert value == eng.score(init)
    # the coarse winner is the best of the scored orderings
    for o in orderings:
        cand = np.concatenate([islands[i] for i in o])
        assert value <= eng.score(cand)


# ---------------------------------------------------------------------------
# refinement and degeneration
# ---------------------------------------------------------------------------

def _dedicate(spec, conf, budget):
    bw, prof = _setup(spec, conf)
    res = dedicate_candidates([conf], [prof], [0], bw, spec, budget,
                              seed=7)
    return res[0], bw, prof


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_refinement_never_worsens_coarse(backend):
    if backend == "jax":
        pytest.importorskip("jax")
    conf = Conf(4, 2, 16, 1, 32)
    res, bw, prof = _dedicate(MIXED, conf, Budget(
        sa_seconds=60.0, sa_iters=60, n_chains=2, backend=backend,
        hierarchical=True))
    (_, coarse), (_, refined) = res.trace[0], res.trace[-1]
    assert refined <= coarse
    assert res.latency == refined
    # the reported latency is the true simulated latency of the mapping
    eng = DedicationEngine(conf, bw, prof, MIXED)
    assert res.latency == eng.score(res.perm)
    assert res.latency == pipette_latency(conf, res.mapping, bw, prof,
                                          MIXED)
    assert np.array_equal(res.mapping,
                          perm_to_mapping(res.perm, conf))


def test_single_island_hierarchical_degenerates_to_flat():
    """On a spec that decomposes into one island, hierarchical=True and
    False must be byte-identical — same RNG stream, same trajectory."""
    conf = Conf(2, 2, 2, 8, 32)
    kw = dict(sa_seconds=60.0, sa_iters=50, n_chains=2, backend="numpy")
    a, _, _ = _dedicate(UNIFORM, conf, Budget(hierarchical=True, **kw))
    b, _, _ = _dedicate(UNIFORM, conf, Budget(hierarchical=False, **kw))
    assert a.latency.hex() == b.latency.hex()
    assert np.array_equal(a.perm, b.perm)
    assert a.trace == b.trace
    assert a.chain_latencies == b.chain_latencies


def test_hierarchical_auto_threshold():
    """hierarchical=None resolves by fleet size (>= HIER_AUTO_GPUS)."""
    assert HIER_AUTO_GPUS == 2048
    conf = Conf(2, 2, 2, 8, 32)
    kw = dict(sa_seconds=60.0, sa_iters=30, n_chains=1, backend="numpy")
    auto, _, _ = _dedicate(UNIFORM, conf, Budget(hierarchical=None, **kw))
    flat, _, _ = _dedicate(UNIFORM, conf, Budget(hierarchical=False, **kw))
    assert auto.latency.hex() == flat.latency.hex()
    assert np.array_equal(auto.perm, flat.perm)
