"""Structured HLO cost model: exact FLOP accounting through scans, indexed
op traffic, trip-count recovery."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze, parse_hlo


def test_scan_flops_exact():
    def body(x, w):
        return x @ w, None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jnp.ones((128, 128), jnp.bfloat16)
    ws = jnp.ones((8, 128, 128), jnp.bfloat16)
    c = analyze(jax.jit(f).lower(x, ws).compile().as_text())
    assert c.flops == pytest.approx(2 * 128 ** 3 * 8, rel=1e-6)


def test_nested_scan_flops_exact():
    def inner(x, w):
        return x @ w, None

    def outer(x, ws):
        def step(c, _):
            y, _ = jax.lax.scan(inner, c, ws)
            return y, None
        y, _ = jax.lax.scan(step, x, None, length=3)
        return y

    x = jnp.ones((64, 64))
    ws = jnp.ones((4, 64, 64))
    c = analyze(jax.jit(outer).lower(x, ws).compile().as_text())
    assert c.flops == pytest.approx(2 * 64 ** 3 * 4 * 3, rel=1e-6)


def test_scan_stacking_bytes_not_quadratic():
    """ys-stacking via dynamic-update-slice must count slice-sized traffic,
    not whole-buffer traffic per iteration."""
    def f(ws):
        def body(c, w):
            y = c @ w
            return y, y
        _, ys = jax.lax.scan(body, jnp.ones((64, 64)), ws)
        return ys

    n = 64
    ws = jnp.ones((n, 64, 64))
    c = analyze(jax.jit(f).lower(ws).compile().as_text())
    buffer_bytes = n * 64 * 64 * 4
    # quadratic accounting would charge ~n * buffer = n^2 slices
    assert c.bytes < 8 * n * (64 * 64 * 4) + 10 * buffer_bytes


def test_collective_detection_and_flops_unchanged():
    txt = """
HloModule test, entry_computation_layout={()->f32[8]{0}}

ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %ar = f32[8]{0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %out = f32[8]{0} copy(%ar)
}
"""
    c = analyze(txt, entry="main")
    assert c.collective_bytes.get("all-reduce") == pytest.approx(2 * 8 * 4)


def test_trip_count_from_backend_config():
    txt = """
HloModule t

%body (x: s32[]) -> s32[] {
  %x = s32[] parameter(0)
  %one = s32[] constant(1)
  ROOT %y = s32[] add(%x, %one)
}

%cond (x2: s32[]) -> pred[] {
  %x2 = s32[] parameter(0)
  %n = s32[] constant(17)
  ROOT %lt = pred[] compare(%x2, %n), direction=LT
}

ENTRY %main (a: s32[]) -> s32[] {
  %a = s32[] parameter(0)
  ROOT %w = s32[] while(%a), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"17"}}
}
"""
    comps = parse_hlo(txt)
    assert set(comps) >= {"body", "cond", "main"}
    from repro.launch.hlo_cost import _trip_count
    w = [op for op in comps["main"] if op.opcode == "while"][0]
    assert _trip_count(comps, w, "cond") == 17


def test_trip_count_from_condition_constant():
    txt = """
HloModule t

%cond (x2: s32[]) -> pred[] {
  %x2 = s32[] parameter(0)
  %n = s32[] constant(23)
  ROOT %lt = pred[] compare(%x2, %n), direction=LT
}
"""
    comps = parse_hlo(txt)
    from repro.launch.hlo_cost import Op, _trip_count
    fake = Op("w", "while", "s32[]", "%a", "condition=%cond, body=%b")
    assert _trip_count(comps, fake, "cond") == 23
