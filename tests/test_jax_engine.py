"""JAX scorer equivalence: bit parity with the NumPy engine and kernels.

The JAX engine's contract is *bit*-equality with
:class:`~repro.core.dedication.DedicationEngine` (not a tolerance): f64
under scoped x64, matching reduction order, and a replica of NumPy's
pairwise summation for the tiered per-stage sum.  Checked across uniform,
mixed-tier and degraded-host specs, against the vectorized engine, the
batched ``pipette_latency`` and the pure-Python reference, plus the
Pallas group-reduce kernels (interpret mode) against their jnp
references."""
import numpy as np
import pytest

from repro.core import (MID_RANGE, MIXED_A100_V100, MID_RANGE_DEGRADED,
                        DedicationEngine, Workload, build_profile,
                        perm_to_mapping, pipette_latency,
                        pipette_latency_ref, profile_bandwidth)
from repro.core.memory import enumerate_confs
from repro.core.simulator import ProfileCache
from repro.configs.gpt_paper import GPT_3_1B

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.jax_engine import (JaxDedicationEngine,  # noqa: E402
                                   kernels_mode, np_pairwise_sum)
from repro.kernels.group_reduce import (group_max,  # noqa: E402
                                        group_max_ref, group_min_scale,
                                        group_min_scale_ref)

W = Workload(GPT_3_1B, 2048, 256)
SPECS = {"uniform": MID_RANGE, "mixed": MIXED_A100_V100,
         "degraded": MID_RANGE_DEGRADED}


def _confs(spec, k=3):
    """A few 4D shapes exercising every term: pp>1, tp>1, cp>1 included."""
    out = [c for c in enumerate_confs(spec.n_gpus, W.bs_global,
                                      n_layers=GPT_3_1B.n_layers, max_cp=2,
                                      seq=W.seq)
           if c.pp > 1 and c.tp > 1]
    out.sort(key=lambda c: (c.cp == 1, c.pp, c.tp))   # cp>1 first
    return out[:k]


# ---------------------------------------------------------------------------
# the NumPy pairwise-sum replica
# ---------------------------------------------------------------------------

def test_np_pairwise_sum_bit_exact_vs_np_sum():
    rng = np.random.default_rng(0)
    for n in list(range(1, 40)) + [63, 64, 65, 127, 128, 129, 200, 300]:
        x = rng.standard_normal(n) * rng.uniform(1e-3, 1e3)
        assert float(np_pairwise_sum(x, n)).hex() == \
            float(np.sum(x)).hex(), n


def test_np_pairwise_sum_traced_matches_host():
    x = np.random.default_rng(1).standard_normal(37)
    from jax.experimental import enable_x64
    with enable_x64():
        got = float(jax.jit(lambda v: np_pairwise_sum(v, 37))(jnp.asarray(x)))
    assert got.hex() == float(np.sum(x)).hex()


# ---------------------------------------------------------------------------
# full-score equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(SPECS))
def test_jax_score_bit_identical_to_numpy_engine(kind):
    spec = SPECS[kind]
    bw, _ = profile_bandwidth(spec)
    confs = _confs(spec)
    cache = ProfileCache(W, spec)
    profs = [cache.get(c) for c in confs]
    rng = np.random.default_rng(5)
    # group by shape: one engine per shape, matching the driver
    by_shape = {}
    for c, p in zip(confs, profs):
        by_shape.setdefault((c.pp, c.tp, c.cp, c.dp), []).append((c, p))
    for shape, group in by_shape.items():
        cs = [c for c, _ in group]
        ps = [p for _, p in group]
        jeng = JaxDedicationEngine(cs, ps, bw, spec)
        for ci, (conf, prof) in enumerate(group):
            eng = DedicationEngine(conf, bw, prof, spec)
            for _ in range(4):
                perm = rng.permutation(spec.n_gpus)
                want = eng.score(perm)
                got = jeng.score(perm, ci)
                assert float(got).hex() == float(want).hex(), (shape, ci)
                # and both equal the batch latency evaluator
                lat = pipette_latency(conf, perm_to_mapping(perm, conf),
                                      bw, prof, spec)
                assert float(lat).hex() == float(want).hex()


@pytest.mark.parametrize("kind", sorted(SPECS))
def test_jax_score_matches_pure_python_reference(kind):
    """The pure-Python reference recomputes Eq. 3-6 scalar by scalar, so
    parity is a pinned tolerance, not bitwise."""
    spec = SPECS[kind]
    bw, _ = profile_bandwidth(spec)
    conf = _confs(spec, 1)[0]
    prof = build_profile(W, spec, conf)
    jeng = JaxDedicationEngine([conf], [prof], bw, spec)
    rng = np.random.default_rng(9)
    for _ in range(3):
        perm = rng.permutation(spec.n_gpus)
        ref = pipette_latency_ref(conf, perm_to_mapping(perm, conf), bw,
                                  prof, spec)
        assert jeng.score(perm) == pytest.approx(ref, rel=1e-12)


def test_compute_blind_engine_matches():
    spec = MIXED_A100_V100
    bw, _ = profile_bandwidth(spec)
    conf = _confs(spec, 1)[0]
    prof = build_profile(W, spec, conf)
    eng = DedicationEngine(conf, bw, prof, spec, compute_aware=False)
    jeng = JaxDedicationEngine([conf], [prof], bw, spec,
                               compute_aware=False)
    perm = np.random.default_rng(2).permutation(spec.n_gpus)
    assert float(jeng.score(perm)).hex() == float(eng.score(perm)).hex()


def test_score_batch_matches_scalar_scores():
    """One vmapped dispatch over a batch of permutations equals the
    per-permutation path bitwise (the --huge throughput gate's contract)."""
    spec = MIXED_A100_V100
    bw, _ = profile_bandwidth(spec)
    confs = _confs(spec)
    by_shape = {}
    for c in confs:
        by_shape.setdefault((c.pp, c.tp, c.cp, c.dp), []).append(c)
    cs = next(iter(by_shape.values()))
    cache = ProfileCache(W, spec)
    ps = [cache.get(c) for c in cs]
    jeng = JaxDedicationEngine(cs, ps, bw, spec)
    rng = np.random.default_rng(3)
    perms = np.stack([rng.permutation(spec.n_gpus) for _ in range(5)])
    for ci, (conf, prof) in enumerate(zip(cs, ps)):
        eng = DedicationEngine(conf, bw, prof, spec)
        batch = jeng.score_batch(perms, ci)
        assert batch.shape == (5,)
        for r, perm in enumerate(perms):
            assert float(batch[r]).hex() == float(eng.score(perm)).hex()
            assert float(batch[r]).hex() == \
                float(jeng.score(perm, ci)).hex()


def test_shared_pairs_and_device_pairs_do_not_change_scores():
    """Engines fed a prebuilt PairCache / a sibling's device buffers (the
    dedicate_candidates sharing path) score bit-identically to
    self-building engines."""
    from repro.core import PairCache
    spec = MIXED_A100_V100
    bw, _ = profile_bandwidth(spec)
    conf = _confs(spec, 1)[0]
    prof = build_profile(W, spec, conf)
    pairs = PairCache.build(bw, spec.gpus_per_node)
    own = JaxDedicationEngine([conf], [prof], bw, spec)
    shared = JaxDedicationEngine([conf], [prof], bw, spec, pairs=pairs,
                                 device_pairs=own.device_pairs)
    assert shared.device_pairs is own.device_pairs
    eng_own = DedicationEngine(conf, bw, prof, spec)
    eng_shared = DedicationEngine(conf, bw, prof, spec, pairs=pairs)
    perm = np.random.default_rng(4).permutation(spec.n_gpus)
    want = float(eng_own.score(perm)).hex()
    assert float(eng_shared.score(perm)).hex() == want
    assert float(own.score(perm)).hex() == want
    assert float(shared.score(perm)).hex() == want


# ---------------------------------------------------------------------------
# Pallas kernels vs pure-jnp fallback (interpret mode on CPU)
# ---------------------------------------------------------------------------

def _random_sub(rng, n, m):
    sub = rng.uniform(0.5, 300.0, size=(n, m, m)) * 1e9
    di = np.arange(m)
    sub[:, di, di] = np.inf                     # self links masked upstream
    sub[rng.integers(n), 0, min(1, m - 1)] = 0.0  # degenerate link
    return sub


@pytest.mark.parametrize("n,m", [(1, 2), (7, 4), (128, 8), (130, 2)])
def test_group_min_scale_interpret_matches_ref(n, m):
    from jax.experimental import enable_x64
    sub = _random_sub(np.random.default_rng(n * 31 + m), n, m)
    with enable_x64():
        ref = np.asarray(group_min_scale_ref(jnp.asarray(sub), 25e9))
        pal = np.asarray(group_min_scale(jnp.asarray(sub), 25e9,
                                         interpret=True))
    assert ref.shape == pal.shape == (n,)
    assert (ref == pal).all()                   # bit-equal, not approx


@pytest.mark.parametrize("n,m", [(1, 3), (9, 16), (128, 4), (257, 8)])
def test_group_max_interpret_matches_ref(n, m):
    from jax.experimental import enable_x64
    vals = np.random.default_rng(n * 17 + m).uniform(1.0, 3.0, size=(n, m))
    with enable_x64():
        ref = np.asarray(group_max_ref(jnp.asarray(vals)))
        pal = np.asarray(group_max(jnp.asarray(vals), interpret=True))
    assert (ref == pal).all()


def test_engine_kernel_modes_agree():
    spec = MIXED_A100_V100
    bw, _ = profile_bandwidth(spec)
    conf = _confs(spec, 1)[0]
    prof = build_profile(W, spec, conf)
    perm = np.random.default_rng(3).permutation(spec.n_gpus)
    vals = [JaxDedicationEngine([conf], [prof], bw, spec,
                                kernels=m).score(perm)
            for m in ("ref", "interpret")]
    assert float(vals[0]).hex() == float(vals[1]).hex()


def test_kernels_mode_resolution(monkeypatch):
    assert kernels_mode("ref") == "ref"
    assert kernels_mode("interpret") == "interpret"
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    assert kernels_mode("auto") == "interpret"
    monkeypatch.delenv("REPRO_KERNELS")
    assert kernels_mode("auto") in ("pallas", "ref")
