"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (requirement c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.selective_scan import selective_scan

# optional dep: skip the module without failing collection; assigning the
# names (instead of `from hypothesis import ...` after a statement) keeps
# every real import at the top of the file (ruff E402)
hyp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
given, settings = hyp.given, hyp.settings

KEY = jax.random.PRNGKey(7)


FA_CASES = [
    # (b, h, kv, sq, sk, d, causal, window, dtype)
    (2, 4, 2, 128, 128, 32, True, 0, jnp.float32),
    (1, 4, 4, 256, 256, 64, True, 0, jnp.float32),
    (2, 2, 1, 128, 256, 32, False, 0, jnp.float32),
    (1, 4, 2, 256, 256, 32, True, 64, jnp.float32),
    (1, 8, 2, 128, 128, 128, True, 0, jnp.bfloat16),
    (1, 2, 2, 64, 192, 16, True, 48, jnp.float32),
]


@pytest.mark.parametrize("case", FA_CASES, ids=[str(c[:8]) for c in FA_CASES])
def test_flash_attention_sweep(case):
    b, h, kv, sq, sk, d, causal, window, dtype = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kv, sk, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kv, sk, d), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    oracle = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oracle, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("block_q,block_k", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shapes(block_q, block_k):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    out = flash_attention(q, k, v, block_q=block_q, block_k=block_k,
                          interpret=True)
    oracle = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(rows=st.integers(1, 70), d=st.sampled_from([32, 128, 384]),
       bf16=st.booleans())
def test_rmsnorm_sweep(rows, d, bf16):
    dtype = jnp.bfloat16 if bf16 else jnp.float32
    ks = jax.random.split(jax.random.PRNGKey(rows * 1000 + d), 2)
    x = (jax.random.normal(ks[0], (rows, d), jnp.float32) * 3).astype(dtype)
    w = jax.random.normal(ks[1], (d,), jnp.float32).astype(dtype)
    out = rmsnorm(x, w, interpret=True, block_rows=16)
    oracle = ref.rmsnorm_ref(x, w)
    tol = 1e-5 if not bf16 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oracle, np.float32),
                               rtol=tol, atol=tol)


SCAN_CASES = [
    # (b, s, d, n, chunk, block_d)
    (2, 64, 32, 8, 16, 16),
    (1, 96, 16, 4, 32, 16),
    (2, 128, 64, 16, 64, 32),
    (1, 50, 24, 8, 25, 24),
]


@pytest.mark.parametrize("case", SCAN_CASES, ids=[str(c) for c in SCAN_CASES])
def test_selective_scan_sweep(case):
    b, s, d, n, chunk, block_d = case
    ks = jax.random.split(jax.random.PRNGKey(sum(case)), 5)
    x = jax.random.normal(ks[0], (b, s, d)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d))) * 0.1
    bb = jax.random.normal(ks[2], (b, s, n))
    cc = jax.random.normal(ks[3], (b, s, n))
    a = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.3)
    y, h = selective_scan(x, dt, bb, cc, a, chunk=chunk, block_d=block_d,
                          interpret=True)
    yr, hr = ref.selective_scan_ref(x, dt, bb, cc, a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-4,
                               atol=2e-4)


def test_models_chunked_scan_matches_kernel_oracle():
    """models/mamba.selective_scan (associative-scan form) agrees with the
    kernel's sequential oracle — two independent derivations."""
    from repro.models.mamba import selective_scan as assoc_scan
    ks = jax.random.split(KEY, 5)
    b, s, d, n = 2, 64, 16, 8
    x = jax.random.normal(ks[0], (b, s, d)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d))) * 0.1
    bb = jax.random.normal(ks[2], (b, s, n))
    cc = jax.random.normal(ks[3], (b, s, n))
    a = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.3)
    y1, h1 = assoc_scan(x, dt, bb, cc, a, chunk=16)
    y2, h2 = ref.selective_scan_ref(x, dt, bb, cc, a)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4,
                               atol=2e-4)


def test_ssd_matches_naive_recurrence():
    """Mamba2 SSD chunked dual form vs direct per-step recurrence."""
    from repro.models.mamba import ssd_scan, ssd_step
    ks = jax.random.split(KEY, 5)
    b, s, h, p, n = 1, 32, 2, 8, 4
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.2
    bb = jax.random.normal(ks[2], (b, s, n))
    cc = jax.random.normal(ks[3], (b, s, n))
    a = -jnp.exp(jax.random.normal(ks[4], (h,)) * 0.3)
    y, hf = ssd_scan(x, dt, bb, cc, a, chunk=8)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        yt, state = ssd_step(x[:, t], dt[:, t], bb[:, t], cc[:, t], a, state)
        ys.append(yt)
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(state), rtol=2e-4,
                               atol=2e-4)
