"""Unit checks of the paper's equations (1), (3)-(6) against hand
calculations, and of the simulator's agreement with the model."""
import numpy as np
import pytest

from repro.core import (MID_RANGE, Conf, Workload, build_profile,
                        default_mapping, true_bandwidth_matrix)
from repro.core.cluster import ring_allreduce_time, min_group_bw
from repro.core.latency import amp_latency, pipette_latency, _t_pp_chain
from repro.core.simulator import Profile, simulate_iteration, dp_allreduce_times
from repro.models.config import ModelConfig

GPT = ModelConfig(name="g", family="dense", n_layers=24, d_model=1920,
                  n_heads=20, n_kv_heads=20, d_ff=7680, vocab_size=51200)
SPEC = MID_RANGE.with_nodes(8)
W = Workload(GPT, 2048, 256)


def uniform_bw(spec, value=10e9):
    g = spec.n_gpus
    bw = np.full((g, g), value)
    node = np.arange(g) // spec.gpus_per_node
    same = node[:, None] == node[None, :]
    bw[same] = spec.intra_bw
    np.fill_diagonal(bw, spec.intra_bw * 4)
    return bw


def test_pipette_latency_hand_computed():
    """T = T_bubble * n_mb/pp + T_straggler + T_dp with uniform links."""
    conf = Conf(4, 8, 2, 2, 256)
    prof = Profile(c_fwd=0.010, c_bwd=0.020, t_tp_fwd=0.001, t_tp_bwd=0.002,
                   msg_pp=8e6, msg_dp=1e8, stage_params=1e8)
    bw = uniform_bw(SPEC)
    m = default_mapping(conf)
    c, t_tp = 0.030, 0.003
    # Eq. 5: chain of pp-1 hops, 2*msg per hop; every hop is inter-node
    t_pp = (conf.pp - 1) * 2 * 8e6 / 10e9
    t_bubble = conf.pp * (c + t_tp) + t_pp
    t_straggler = (conf.pp - 1) * (c + t_tp)
    # Eq. 6: dp group of 2 spans nodes -> single inter-node ring of 2
    t_dp = dp_allreduce_times(conf, m, bw, prof, SPEC)[0]
    expected = t_bubble * conf.n_mb / conf.pp + t_straggler + t_dp
    got = pipette_latency(conf, m, bw, prof, SPEC)
    assert got == pytest.approx(expected, rel=1e-9)


def test_amp_latency_hand_computed():
    conf = Conf(4, 8, 2, 2, 256)
    prof = Profile(0.010, 0.020, 0.001, 0.002, 8e6, 1e8, 1e8)
    c, t_tp = 0.030, 0.003
    expected = (conf.n_mb - 1) * (c + t_tp) + conf.pp * (c + t_tp) \
        + (conf.pp - 1) * 2 * 8e6 / SPEC.inter_bw \
        + ring_allreduce_time(1e8, SPEC.inter_bw, conf.dp)
    got = amp_latency(conf, default_mapping(conf), SPEC, prof)
    assert got == pytest.approx(expected, rel=1e-9)


def test_hidden_critical_path_scales_with_n_mb():
    """Pipette's model charges the P2P chain n_mb/pp times; AMP once.
    The gap grows linearly with n_mb — the §V hidden critical path."""
    prof = Profile(0.010, 0.020, 0.001, 0.002, 16e6, 1e8, 1e8)
    bw = uniform_bw(SPEC)
    gaps = []
    for mb_count in (16, 32, 64):
        conf = Conf(8, 4, 2, 128 // mb_count, 256)
        m = default_mapping(conf)
        gaps.append(pipette_latency(conf, m, bw, prof, SPEC) -
                    amp_latency(conf, m, SPEC, prof))
    # strictly increasing communication term (compute terms nearly cancel)
    assert gaps[0] < gaps[1] < gaps[2]


def test_simulator_close_to_model_on_uniform_cluster():
    """With jitter/contention off and uniform links the event-driven sim
    should be within a few % of the closed-form model."""
    bw = uniform_bw(SPEC)
    for conf in [Conf(8, 2, 4, 1, 256), Conf(4, 8, 2, 2, 256),
                 Conf(2, 8, 4, 4, 256)]:
        prof = build_profile(W, SPEC, conf)
        m = default_mapping(conf)
        sim = simulate_iteration(conf, m, bw, prof, SPEC, jitter=0,
                                 contention=0)["total"]
        est = pipette_latency(conf, m, bw, prof, SPEC)
        assert sim == pytest.approx(est, rel=0.08), conf


def test_simulator_close_to_model_on_boundary_schedules():
    """Estimator/simulator agreement exactly where the schedule-validity
    gate bites: n_mb == pp (zero steady-state slack), pp == 1 (no pipeline
    at all), and cp > 1 (ring KV-exchange on every op)."""
    bw = uniform_bw(SPEC)
    cases = [Conf(8, 8, 1, 4, 32),            # n_mb == pp == 8
             Conf(4, 4, 4, 4, 64),            # n_mb == pp == 4
             Conf(1, 8, 8, 2, 256),           # pp == 1
             Conf(4, 4, 2, 2, 16, cp=2),      # 4D, n_mb == pp == 4
             Conf(4, 4, 2, 4, 128, cp=2),     # 4D, steady state (n_mb 16)
             Conf(2, 4, 2, 2, 64, cp=4)]      # 4D, deeper ring
    for conf in cases:
        assert conf.schedulable(), conf
        w = Workload(GPT, 2048, conf.bs_global)
        prof = build_profile(w, SPEC, conf)
        m = default_mapping(conf)
        sim = simulate_iteration(conf, m, bw, prof, SPEC, jitter=0,
                                 contention=0)["total"]
        est = pipette_latency(conf, m, bw, prof, SPEC)
        assert sim == pytest.approx(est, rel=0.10), conf


def test_eq5_takes_slowest_chain():
    conf = Conf(2, 1, 1, 1, 1)
    prof = Profile(0.01, 0.02, 0, 0, msg_pp=10e6, msg_dp=1, stage_params=1)
    g = SPEC.n_gpus
    bw = uniform_bw(SPEC, 10e9)
    m = np.array([[[0]], [[8]]])       # stage0 gpu0 -> stage1 gpu8
    bw[0, 8] = 2e9                     # slow that specific link
    assert _t_pp_chain(conf, m, bw, prof) == pytest.approx(2 * 10e6 / 2e9)


def test_dp_allreduce_hierarchical_structure():
    """Eq. 6: intra-node phase uses 4(n-1)/n, inter-node 2(n-1)/n with the
    slowest participating link."""
    conf = Conf(1, 1, 16, 1, 16)
    prof = Profile(0, 0, 0, 0, 0, msg_dp=8e7, stage_params=1)
    bw = uniform_bw(SPEC, 10e9)
    m = np.arange(16).reshape(1, 1, 16)     # two nodes of 8
    t = dp_allreduce_times(conf, m, bw, prof, SPEC)[0]
    intra = 4 * (8 - 1) / 8 * 8e7 / SPEC.intra_bw
    inter = 2 * (2 - 1) / 2 * 8e7 / 10e9
    assert t == pytest.approx(intra + inter, rel=1e-9)


def test_heterogeneity_visible_in_matrix():
    bw = true_bandwidth_matrix(SPEC)
    inter = bw[bw < SPEC.intra_bw * 0.5]
    assert inter.max() / inter.min() > 1.8   # Fig. 3-scale spread


def test_min_group_bw_singleton_is_inf():
    """A 1-GPU 'group' has no links: min_group_bw returns inf, and both
    scalar and batched forms agree."""
    from repro.core.cluster import min_group_bw_batch
    bw = uniform_bw(SPEC)
    assert min_group_bw(bw, [3]) == float("inf")
    assert min_group_bw(bw, []) == float("inf")
    batch = min_group_bw_batch(bw, np.array([[0], [5]]))
    assert np.all(np.isinf(batch)) and batch.shape == (2,)


def test_ring_allreduce_singleton_and_inf_guard():
    """n == 1 early-outs to exactly 0.0 before the bandwidth is touched
    (so a singleton min_group_bw inf is safe), while an inf/0 bandwidth
    reaching a real ring (n > 1) raises instead of silently pricing a
    0-second collective."""
    bw = uniform_bw(SPEC)
    assert ring_allreduce_time(1e9, min_group_bw(bw, [7]), 1) == 0.0
    assert ring_allreduce_time(1e9, float("inf"), 0) == 0.0
    with pytest.raises(ValueError, match="finite positive"):
        ring_allreduce_time(1e9, float("inf"), 2)
    with pytest.raises(ValueError, match="finite positive"):
        ring_allreduce_time(1e9, 0.0, 4)
    # finite case unchanged
    assert ring_allreduce_time(8e7, 1e10, 4) == \
        pytest.approx(2 * 3 / 4 * 8e7 / 1e10)


def test_tp_scale_guards_singleton_semantics():
    """_tp_scale/_cp_scale treat a non-finite group bandwidth as scale 1.0
    (documented inf semantics at the call sites)."""
    from repro.core.latency import _cp_scale, _tp_scale
    conf = Conf(1, 1, 1, 1, 4, cp=1)
    m = default_mapping(conf)
    bw = uniform_bw(SPEC)
    assert _tp_scale(conf, m, bw, SPEC, 300e9) == 1.0     # tp == 1
    assert _cp_scale(conf, m, bw, 300e9) == 1.0           # cp == 1
