"""Memory estimator (§VI): ground-truth structure, the analytical
baseline's systematic underestimation, MLP fit quality, and config
enumeration properties."""
import pytest

from repro.core import (MID_RANGE, Conf, Workload, analytical_estimate,
                        enumerate_confs, fit_memory_estimator,
                        ground_truth_memory, mape)
from repro.models.config import ModelConfig

# optional dep: skip the module without failing collection; assigning the
# names (instead of `from hypothesis import ...` after a statement) keeps
# every real import at the top of the file (ruff E402)
hyp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
given, settings = hyp.given, hyp.settings


def gpt(l, d, h, name="m"):
    return ModelConfig(name=f"{name}-{l}-{d}", family="dense", n_layers=l,
                       d_model=d, n_heads=h, n_kv_heads=h, d_ff=4 * d,
                       vocab_size=51200)


SPEC = MID_RANGE


@settings(max_examples=40, deadline=None)
@given(g_exp=st.integers(3, 7), bs_exp=st.integers(6, 9),
       max_cp=st.sampled_from([1, 2, 4]))
def test_enumerate_confs_products(g_exp, bs_exp, max_cp):
    g, bs = 2 ** g_exp, 2 ** bs_exp
    confs = enumerate_confs(g, bs, n_layers=32, max_cp=max_cp, seq=2048)
    assert confs, "search space must be non-empty"
    for c in confs:
        assert c.pp * c.tp * c.cp * c.dp == g
        assert bs % c.dp == 0
        assert c.bs_mini % c.bs_micro == 0
        assert c.cp <= max_cp and 2048 % c.cp == 0
        # the strict (default) enumeration only emits valid,
        # 1F1B-schedulable configurations (n_mb >= pp)
        assert c.valid()
        assert c.schedulable() and c.n_mb >= c.pp
    assert len({(c.pp, c.tp, c.cp, c.dp, c.bs_micro)
                for c in confs}) == len(confs)
    # the escape hatch restores the unfiltered space as a superset
    loose = enumerate_confs(g, bs, n_layers=32, max_cp=max_cp, seq=2048,
                            strict=False)
    assert set(confs) <= set(loose)
    assert all(c.n_mb < c.pp for c in set(loose) - set(confs))


def test_analytical_systematically_underestimates():
    """The [20]-style baseline misses framework overheads + 1F1B inflight
    activations: it must underestimate ground truth (Fig. 7 behaviour)."""
    w = Workload(gpt(24, 1920, 20), 2048, 256)
    under = total = 0
    for conf in enumerate_confs(64, 256, n_layers=24)[:160]:
        if conf.bs_micro > 8:
            continue
        total += 1
        if analytical_estimate(w, conf) < ground_truth_memory(w, conf, SPEC):
            under += 1
    assert under / total > 0.95


def test_memory_ground_truth_monotonicity():
    w = Workload(gpt(24, 1920, 20), 2048, 256)
    base = Conf(4, 4, 4, 2, 256)
    more_micro = Conf(4, 4, 4, 4, 256)
    more_tp = Conf(4, 8, 2, 2, 256)
    assert ground_truth_memory(w, more_micro, SPEC) > \
        ground_truth_memory(w, base, SPEC)
    assert ground_truth_memory(w, more_tp, SPEC) < \
        ground_truth_memory(w, base, SPEC)


def test_mlp_estimator_beats_analytical():
    """Train on <=2 nodes, validate on 8-node configs (extrapolation).
    At this toy scale the reproducible 'library variance' noise floor
    dominates absolute MAPE; the robust claim (paper Fig. 7 direction) is
    MLP << analytical."""
    models = [gpt(12, 768, 12, "a"), gpt(16, 1024, 16, "b"),
              gpt(20, 1280, 20, "c")]
    ws = [Workload(m, 1024, bsg) for m in models
          for bsg in (16, 32, 64, 128)]
    est = fit_memory_estimator(ws, SPEC, fit_nodes=2, steps=6000,
                               residual=True)
    w = Workload(models[0], 1024, 64)
    preds, anas, trues = [], [], []
    for conf in enumerate_confs(64, w.bs_global, n_layers=w.cfg.n_layers):
        if conf.bs_micro > 8:
            continue
        trues.append(ground_truth_memory(w, conf, SPEC))
        preds.append(est.predict(w.cfg, conf))
        anas.append(analytical_estimate(w, conf))
    m_mlp, m_ana = mape(preds, trues), mape(anas, trues)
    assert m_mlp < 0.6 * m_ana, (m_mlp, m_ana)
    assert m_mlp < 50.0, m_mlp


def test_estimator_soft_margin_blocks_oom():
    models = [gpt(12, 768, 12)]
    ws = [Workload(models[0], 1024, 64)]
    est = fit_memory_estimator(ws, SPEC, fit_nodes=1, steps=2000,
                               residual=True)
    w = ws[0]
    conf = enumerate_confs(8, 64, n_layers=12)[0]
    limit = est.predict(w.cfg, conf)
    assert not est.fits(w.cfg, conf, limit * 0.5)
    assert est.fits(w.cfg, conf, limit * 2.0)
