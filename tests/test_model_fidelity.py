"""Model-fidelity regression: the Eq. 3-6 latency model vs the
discrete-event simulator on a pinned, seeded scenario grid.

Each scenario draws a seeded sample of schedulable configurations, scores
their default mappings with :func:`pipette_latency` on the *measured*
matrix, plays them back in the simulator on the *true* matrix, and asserts
the MAPE stays under a checked-in threshold.  The grid covers the paper's
3D space, the 4D (cp > 1) extension, and mixed-tier (heterogeneous
compute) clusters — so a future model edit that silently degrades any of
the three surfaces fails here, with the measured number in the message.

Thresholds carry ~2x headroom over the values measured when they were
pinned (1.6 / 1.0 / 3.5 / 2.6 / 7.4 %); everything is deterministic given
the seeds, so a breach means the model or simulator actually moved.
"""
import numpy as np
import pytest

from repro.core import (MID_RANGE, Workload, build_profile, default_mapping,
                        pipette_latency, profile_bandwidth,
                        true_bandwidth_matrix)
from repro.core.cluster import A100_TIER, V100_TIER, mixed_fleet_spec
from repro.core.memory import enumerate_confs, mape
from repro.core.simulator import measure
from repro.models.config import ModelConfig

GPT = ModelConfig(name="g24", family="dense", n_layers=24, d_model=1024,
                  n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=32000)

MIXED_16x1 = mixed_fleet_spec("mixed-16x1", 16, (A100_TIER, V100_TIER),
                              (0.5, 0.5), gpus_per_node=1, seed=47)
MIXED_16x4 = mixed_fleet_spec("mixed-16x4", 16, (A100_TIER, V100_TIER),
                              (0.5, 0.5), gpus_per_node=4, seed=47)

#: (id, spec, workload, max_cp, require_cp, MAPE threshold %)
SCENARIOS = [
    ("mid-range-3d", MID_RANGE.with_nodes(2), Workload(GPT, 2048, 64),
     1, False, 5.0),
    ("mid-range-4d-cp", MID_RANGE.with_nodes(2), Workload(GPT, 2048, 64),
     4, True, 5.0),
    ("mixed-16x1-tiered", MIXED_16x1, Workload(GPT, 2048, 32),
     1, False, 8.0),
    ("mixed-16x4-tiered", MIXED_16x4, Workload(GPT, 2048, 64),
     1, False, 8.0),
    ("mixed-16x4-4d-cp", MIXED_16x4, Workload(GPT, 2048, 64),
     4, True, 15.0),
]


@pytest.mark.parametrize(
    "spec, w, max_cp, require_cp, threshold",
    [s[1:] for s in SCENARIOS], ids=[s[0] for s in SCENARIOS])
def test_latency_model_mape_vs_simulator(spec, w, max_cp, require_cp,
                                         threshold):
    bw_meas, _ = profile_bandwidth(spec)
    bw_true = true_bandwidth_matrix(spec)
    confs = [c for c in enumerate_confs(spec.n_gpus, w.bs_global,
                                        n_layers=w.cfg.n_layers,
                                        max_cp=max_cp, seq=w.seq)
             if c.bs_micro <= 4 and (not require_cp or c.cp > 1)]
    assert len(confs) >= 8, "scenario grid too small to be meaningful"
    rng = np.random.default_rng(0)
    sel = [confs[i] for i in rng.choice(len(confs), size=10, replace=False)]
    preds, sims = [], []
    for conf in sel:
        prof = build_profile(w, spec, conf)
        m = default_mapping(conf)
        preds.append(pipette_latency(conf, m, bw_meas, prof, spec))
        sims.append(measure(conf, m, w, spec, bw_true, seed=3))
    err = mape(preds, sims)
    assert err <= threshold, (
        f"latency-model MAPE {err:.2f}% exceeds the pinned {threshold}% "
        f"on {spec.name}: the model drifted from the simulator")
