"""Per-arch smoke tests (reduced configs, assignment requirement f) and
model-math correctness: prefill/decode consistency, MoE dense-oracle
equivalence, causality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.models.moe import moe_apply_local, router_topk
from repro.models.sharding import ShardCtx
from repro.models.frontends import vlm_patch_embeddings

CTX = ShardCtx()
KEY = jax.random.PRNGKey(0)

ALL_ARCHS = sorted(configs.ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train_step(arch):
    """Reduced same-family config: one fwd/loss + grad step, finite, right
    shapes (requirement f)."""
    cfg = configs.get(arch).reduced()
    params = M.init_params(cfg, KEY)
    b, s = 2, 24
    img = None
    if cfg.frontend == "vlm":
        img = vlm_patch_embeddings(KEY, b, cfg.n_img_tokens, cfg.d_model,
                                   dtype=jnp.float32)
        labels = jax.random.randint(KEY, (b, s + cfg.n_img_tokens), 0,
                                    cfg.vocab_size, jnp.int32)
    else:
        labels = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size, jnp.int32)
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks, "labels": labels}
    if img is not None:
        batch["img_embeds"] = img

    def loss_of(p):
        return M.loss_fn(p, cfg, CTX, batch)[0]

    loss, grads = jax.value_and_grad(loss_of)(params)
    assert jnp.isfinite(loss), arch
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch

    logits = M.forward_logits(params, cfg, CTX, toks, img)
    s_total = s + (cfg.n_img_tokens if cfg.frontend == "vlm" else 0)
    assert logits.shape == (b, s_total, cfg.padded_vocab)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_prefill_decode_consistency(arch):
    """decode_step at position n must reproduce forward_logits[:, n]."""
    cfg = configs.get(arch).reduced()
    if cfg.frontend == "vlm":
        pytest.skip("vlm decode covered via dense path (image in prefill)")
    b = 2
    window = cfg.sliding_window if cfg.local_global_period else 0
    s = 4 * window if window else 16
    toks = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab_size, jnp.int32)
    params = M.init_params(cfg, KEY)

    full = M.forward_logits(params, cfg, CTX, toks)
    last, cache = M.prefill(params, cfg, CTX, toks[:, :s])
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full[:, s - 1]),
                               rtol=2e-3, atol=2e-3)

    # grow full-attention caches by one slot and decode the next token
    grown = {}
    for k, v in cache.items():
        if k in ("k", "v"):
            pad = [(0, 0)] * v.ndim
            pad[2] = (0, 1)
            grown[k] = jnp.pad(v, pad)
        else:
            grown[k] = v
    logits, _ = M.decode_step(params, cfg, CTX, toks[:, s:s + 1], grown,
                              jnp.int32(s))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, s]),
                               rtol=2e-3, atol=2e-3)


def test_moe_matches_dense_oracle():
    """capacity_factor high enough -> no drops -> exactly the weighted sum
    of the top-k experts."""
    t, d, f, e, k = 24, 16, 32, 8, 2
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (t, d), jnp.float32)
    router = jax.random.normal(ks[1], (d, e), jnp.float32)
    wg = jax.random.normal(ks[2], (e, d, f), jnp.float32) / np.sqrt(d)
    wu = jax.random.normal(ks[3], (e, d, f), jnp.float32) / np.sqrt(d)
    wd = jax.random.normal(ks[4], (e, f, d), jnp.float32) / np.sqrt(f)

    y = moe_apply_local(x, router, wg, wu, wd, k=k, n_experts=e,
                        expert_offset=0, capacity_factor=float(e))

    ids, gates = router_topk(x, router, k)
    silu = lambda z: z * jax.nn.sigmoid(z)
    y_ref = np.zeros((t, d), np.float32)
    for ti in range(t):
        for kk in range(k):
            eid = int(ids[ti, kk])
            h = silu(x[ti] @ wg[eid]) * (x[ti] @ wu[eid])
            y_ref[ti] += float(gates[ti, kk]) * np.asarray(h @ wd[eid])
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    """With capacity 0-ish, outputs shrink toward zero (drops happen)."""
    t, d, f, e, k = 64, 8, 8, 4, 2
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (t, d), jnp.float32)
    router = jax.random.normal(ks[1], (d, e), jnp.float32)
    wg = jax.random.normal(ks[2], (e, d, f), jnp.float32)
    wu = jax.random.normal(ks[3], (e, d, f), jnp.float32)
    wd = jax.random.normal(ks[4], (e, f, d), jnp.float32)
    y_full = moe_apply_local(x, router, wg, wu, wd, k=k, n_experts=e,
                             expert_offset=0, capacity_factor=8.0)
    y_tight = moe_apply_local(x, router, wg, wu, wd, k=k, n_experts=e,
                              expert_offset=0, capacity_factor=0.2)
    assert float(jnp.abs(y_tight).sum()) < float(jnp.abs(y_full).sum())


def test_causality():
    """Changing a future token must not affect past logits."""
    cfg = configs.get("qwen2-7b").reduced()
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size, jnp.int32)
    l1 = M.forward_logits(params, cfg, CTX, toks)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % cfg.vocab_size)
    l2 = M.forward_logits(params, cfg, CTX, toks2)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]),
                               rtol=1e-5, atol=1e-5)


def test_sliding_window_matches_reference():
    from repro.models.attention import chunked_attention, reference_attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, 64, 2, 16), jnp.float32)
    for w in (0, 8, 17):
        out = chunked_attention(q, k, v, causal=True, window=w, chunk_q=16,
                                chunk_k=16)
        ref = reference_attention(q, k, v, causal=True, window=w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_param_counts_match_published():
    from repro.core.flops import active_param_count, param_count
    expected = {
        "llava-next-mistral-7b": 7.2e9, "musicgen-large": 3.2e9,
        "kimi-k2-1t-a32b": 1.04e12, "qwen2-7b": 7.6e9,
        "command-r-plus-104b": 1.07e11, "qwen1.5-4b": 3.9e9,
        "gemma3-12b": 1.28e10, "falcon-mamba-7b": 7.3e9,
        "zamba2-7b": 6.7e9, "granite-moe-3b-a800m": 3.4e9,
    }
    for name, n in expected.items():
        got = param_count(configs.get(name))
        assert abs(got - n) / n < 0.06, (name, got, n)
    assert active_param_count(configs.get("kimi-k2-1t-a32b")) < 35e9
