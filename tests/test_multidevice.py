"""Multi-device behaviour (shard_map MoE, GSPMD equivalence, pipeline
parallelism) — run in subprocesses with forced host device counts because
jax fixes the device count at first init."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

# Three tests drive the explicit-mesh API (jax.sharding.AxisType +
# jax.set_mesh, jax >= 0.6); on older runtimes the multi-device mesh path
# is unavailable, so they skip cleanly instead of failing in the
# subprocess (which runs the same jax as this process).
MODERN_MESH = hasattr(jax.sharding, "AxisType") and hasattr(jax, "set_mesh")
needs_modern_mesh = pytest.mark.skipif(
    not MODERN_MESH,
    reason="multi-device mesh API unavailable: jax.sharding.AxisType / "
           f"jax.set_mesh missing on jax {jax.__version__}")


def run_py(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@needs_modern_mesh
def test_moe_shard_map_matches_single_device():
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models.config import ModelConfig
        from repro.models import model as M
        from repro.models.sharding import ShardCtx, tree_shardings

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        ctx = ShardCtx(mesh=mesh, dp=("data",), tp="model", fsdp=("data",))
        cfg = ModelConfig(name="moe", family="moe", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=96, vocab_size=256,
                          head_dim=16, n_experts=8, experts_per_token=2,
                          capacity_factor=8.0, dtype="float32", remat=False)
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        toks = jax.random.randint(key, (4, 32), 0, 256)
        batch = {"tokens": toks, "labels": toks}
        loss_ref, _ = M.loss_fn(params, cfg, ShardCtx(), batch)
        ps = jax.device_put(params, tree_shardings(params, cfg, ctx))
        bs = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
        with jax.set_mesh(mesh):
            loss_sh = jax.jit(lambda p, b: M.loss_fn(p, cfg, ctx, b)[0])(ps, bs)
        diff = abs(float(loss_ref) - float(loss_sh))
        assert diff < 1e-5, diff
        print("OK", diff)
    """)
    assert "OK" in out


@needs_modern_mesh
def test_uneven_head_seq_sharding_matches():
    """granite-style head count (not divisible by model axis): the
    seq-sharded attention path must agree with single-device math."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models.config import ModelConfig
        from repro.models import model as M
        from repro.models.sharding import ShardCtx, tree_shardings

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        ctx = ShardCtx(mesh=mesh, dp=("data",), tp="model", fsdp=())
        cfg = ModelConfig(name="d", family="dense", n_layers=2, d_model=60,
                          n_heads=3, n_kv_heads=3, d_ff=128, vocab_size=256,
                          head_dim=20, dtype="float32", remat=False)
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        toks = jax.random.randint(key, (4, 32), 0, 256)
        batch = {"tokens": toks, "labels": toks}
        loss_ref, _ = M.loss_fn(params, cfg, ShardCtx(), batch)
        ps = jax.device_put(params, tree_shardings(params, cfg, ctx))
        bs = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
        with jax.set_mesh(mesh):
            loss_sh = jax.jit(lambda p, b: M.loss_fn(p, cfg, ctx, b)[0])(ps, bs)
        diff = abs(float(loss_ref) - float(loss_sh))
        assert diff < 1e-5, diff
        print("OK", diff)
    """)
    assert "OK" in out


@needs_modern_mesh
def test_pipeline_parallel_loss_and_grads_match():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.pipeline import pipeline_loss_fn, stage_params_split

        pp, L, d, V, mb, n_mb, S = 4, 8, 32, 64, 2, 8, 16
        mesh = jax.make_mesh((pp,), ("pipe",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 4)
        layers = {"w": jax.random.normal(ks[0], (L, d, d)) * 0.05}
        shared = {"embed": jax.random.normal(ks[1], (V, d)) * 0.1,
                  "head": jax.random.normal(ks[2], (d, V)) * 0.1}
        embed_fn = lambda sh, t: sh["embed"][t]
        def stage_fn(st, x):
            h, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, st["w"])
            return h
        def head_loss_fn(sh, h, lbl):
            lg = h @ sh["head"]
            lse = jax.nn.logsumexp(lg, -1)
            pick = jnp.take_along_axis(lg, lbl[..., None], -1)[..., 0]
            return jnp.mean(lse - pick)
        toks = jax.random.randint(ks[3], (n_mb, mb, S), 0, V)
        lbls = jax.random.randint(ks[3], (n_mb, mb, S), 0, V)
        def ref_loss(layers):
            tot = 0.0
            for i in range(n_mb):
                h = embed_fn(shared, toks[i])
                h, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), h,
                                    layers["w"])
                tot += head_loss_fn(shared, h, lbls[i])
            return tot / n_mb
        params = {"stages": stage_params_split(layers, pp), "shared": shared}
        loss_fn = pipeline_loss_fn(embed_fn, stage_fn, head_loss_fn, mesh)
        with jax.set_mesh(mesh):
            lp = jax.jit(loss_fn)(params, toks, lbls)
            gp = jax.jit(jax.grad(loss_fn))(params, toks, lbls)
        lr = ref_loss(layers)
        assert abs(float(lp - lr)) < 1e-5
        gr = jax.grad(ref_loss)(layers)
        np.testing.assert_allclose(
            np.asarray(gp["stages"]["w"]).reshape(L, d, d),
            np.asarray(gr["w"]), rtol=3e-4, atol=3e-5)
        print("OK")
    """, devices=4)
    assert "OK" in out


def test_pipette_mapping_builds_mesh():
    """The SA mapping feeds jax Mesh construction (device assignment)."""
    out = run_py("""
        import numpy as np, jax
        from repro.core import Conf
        from repro.launch.mesh import mesh_from_mapping
        conf = Conf(2, 2, 2, 1, 16)
        rng = np.random.default_rng(0)
        mapping = rng.permutation(8).reshape(2, 2, 2)
        mesh = mesh_from_mapping(conf, mapping)
        ids = np.vectorize(lambda d: d.id)(mesh.devices)
        assert (ids == mapping).all()
        assert mesh.axis_names == ("pipe", "model", "data")
        print("OK")
    """)
    assert "OK" in out
