"""Non-uniform pipeline partitions + interleaved-1F1B battery.

Four contracts:

1. **solver** — the balanced-partition DP minimizes the max stage cost and
   degenerates to the legacy ceil-first split on uniform cost vectors;
2. **bit-exact legacy path** — ``partition="dp"`` on a uniform-cost model
   (and plain 1F1B everywhere) reproduces the historical plan *bytes*, on
   the legacy driver and both unified SA backends;
3. **parity** — with a real partition and/or ``vpp > 1``, the latency
   reference, the incremental NumPy engine, and the jitted JAX engine all
   score bit-identically;
4. **the win** — on the hybrid (zamba2) and MoE (kimi-k2) configs at
   ``pp = 8`` the DP split beats the honest uniform split in the
   discrete-event simulator.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (ClusterSpec, Conf, Workload, build_profile,
                        enumerate_confs, ground_truth_memory, make_partition,
                        measure, pipette_latency, pipette_latency_ref,
                        profile_bandwidth, resolve_partition,
                        true_bandwidth_matrix, uniform_partition)
from repro.core.partition import Partition, PartitionCache, balanced_partition
from repro.core.simulator import ProfileCache, default_mapping
from repro.configs.kimi_k2_1t_a32b import CONFIG as KIMI
from repro.configs.zamba2_7b import CONFIG as ZAMBA
from repro.models.config import ModelConfig

#: Uniform-cost model: dense, no MoE/hybrid structure, and a vocabulary
#: small enough that the embedding endpoint cost stays below one layer's
#: cost.  16 layers divide evenly at every pp a 16-GPU cluster can
#: enumerate, so the DP solver returns exactly the ceil-first split (and
#: ``resolve_partition`` returns None) for all of them.
DENSE = ModelConfig(name="d16", family="dense", n_layers=16, d_model=256,
                    n_heads=8, n_kv_heads=8, d_ff=1024, vocab_size=512)


# ----------------------------------------------------------------- solver

@pytest.mark.parametrize("L,pp", [(10, 4), (12, 4), (81, 8), (61, 8),
                                  (7, 3), (16, 16), (9, 1)])
def test_uniform_costs_degenerate_to_ceil_first(L, pp):
    part = balanced_partition(np.ones(L), pp)
    assert part == uniform_partition(L, pp)
    assert part.is_uniform()


def test_solver_isolates_heavy_layer():
    # one 5x layer: the DP must give it a small stage instead of pairing
    # it with 2+ neighbours (uniform would put it in a 3-layer stage)
    part = balanced_partition([1, 1, 1, 1, 5, 1, 1, 1, 1, 1], 4)
    sums = part.stage_sums(np.array([1, 1, 1, 1, 5, 1, 1, 1, 1, 1],
                                    float))
    uni = uniform_partition(10, 4)
    uni_sums = uni.stage_sums(np.array([1, 1, 1, 1, 5, 1, 1, 1, 1, 1],
                                       float))
    assert sums.max() < uni_sums.max()
    assert part.sizes[np.argmax(sums)] <= 2


def test_endpoint_costs_shrink_end_stages():
    part = balanced_partition(np.ones(12), 4, head_cost=2.0, tail_cost=2.0)
    sizes = part.sizes
    assert sizes[0] < sizes[1] and sizes[-1] < sizes[1]
    assert sum(sizes) == 12


def test_partition_validation():
    with pytest.raises(ValueError):
        Partition(10, (3, 3, 8, 10))            # not strictly increasing
    with pytest.raises(ValueError):
        Partition(10, (3, 6, 8))                # does not cover n_layers
    with pytest.raises(ValueError):
        Partition(10, (0, 6, 8, 10))            # empty first stage
    with pytest.raises(ValueError):
        balanced_partition(np.ones(4), 5)       # pp > n_layers


def test_partition_json_roundtrip():
    part = make_partition(ZAMBA, 8, 2048, "dp")
    back = Partition.from_json_dict(part.to_json_dict())
    assert back == part and back.sizes == part.sizes


def test_resolve_partition_degenerates_to_none():
    # uniform mode, pp=1, and uniform-cost models all resolve to None —
    # the single predicate the bit-exact legacy path gates on
    assert resolve_partition(ZAMBA, 8, 2048, "uniform") is None
    assert resolve_partition(ZAMBA, 1, 2048, "dp") is None
    for pp in (2, 4, 8, 16):
        assert resolve_partition(DENSE, pp, 128, "dp") is None
    # non-divisible pp: the embed head cost makes a shorter first stage
    # strictly better, so the DP legitimately deviates from ceil-first
    assert resolve_partition(DENSE, 3, 128, "dp") is not None
    assert resolve_partition(ZAMBA, 8, 2048, "dp") is not None


def test_partition_cache_memoizes():
    cache = PartitionCache(ZAMBA, 2048, "dp")
    assert cache.get(8) is cache.get(8)
    assert cache.get(8) == resolve_partition(ZAMBA, 8, 2048, "dp")


# -------------------------------------------------- solver property suite

def test_solver_properties_random_costs():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=60, deadline=None)
    @hyp.given(costs=st.lists(st.floats(0.1, 10.0), min_size=2,
                              max_size=24),
               pp=st.integers(1, 6), seed=st.integers(0, 10))
    def prop(costs, pp, seed):
        hyp.assume(pp <= len(costs))
        c = np.asarray(costs)
        part = balanced_partition(c, pp)
        # structural validity + coverage
        assert part.pp == pp and sum(part.sizes) == len(costs)
        assert all(s >= 1 for s in part.sizes)
        # optimality: never worse than the uniform split's max stage
        uni = uniform_partition(len(costs), pp)
        assert part.stage_sums(c).max() <= uni.stage_sums(c).max() + 1e-12
        # constant vectors degenerate to the ceil-first split exactly
        const = balanced_partition(np.full(len(costs), float(costs[0])), pp)
        assert const == uni

    prop()


# ------------------------------------- legacy path stays bit-exact (e2e)

@pytest.mark.parametrize("backend", [None, "numpy", "jax"])
def test_dp_mode_on_uniform_model_reproduces_legacy_plan_bytes(backend):
    """``SearchSpace(partition="dp")`` on a uniform-cost model resolves
    every candidate's partition to None, so the whole search — enumerate,
    prune, profile, pre-score, SA — replays the historical trajectory and
    the Plan artifact serializes to identical bytes."""
    from repro.core import (Budget, Planner, PipetteStrategy, PlanRequest,
                            SearchSpace)
    spec = ClusterSpec(name="t", n_nodes=4, gpus_per_node=4)
    w = Workload(DENSE, 128, 64)
    bw, _ = profile_bandwidth(spec)
    budget = Budget(sa_seconds=60.0, sa_iters=40, sa_topk=2,
                    backend=backend)
    base = Planner(PipetteStrategy()).plan(
        PlanRequest(w, spec, SearchSpace(), budget, seed=3), bw)
    dp = Planner(PipetteStrategy()).plan(
        PlanRequest(w, spec, SearchSpace(partition="dp"), budget, seed=3),
        bw)
    b, d = base.to_json_dict(), dp.to_json_dict()
    assert d["provenance"]["space"]["partition"] == "dp"
    # identical modulo the recorded space knob itself
    d["provenance"]["space"]["partition"] = "uniform"
    assert b == d


def test_explicit_uniform_partition_profile_differs_from_legacy():
    """An *explicit* uniform Partition goes through the per-stage cost
    path (honest comparator); only ``partition is None`` is the legacy
    aggregate — the two must not alias in the ProfileCache."""
    spec = ClusterSpec(name="t", n_nodes=16, gpus_per_node=8)
    w = Workload(ZAMBA, 2048, 256)
    conf = Conf(8, 4, 4, 2, 256)
    legacy = build_profile(w, spec, conf)
    honest = build_profile(w, spec, conf,
                           partition=uniform_partition(ZAMBA.n_layers, 8))
    assert legacy.partition is None
    assert honest.partition == uniform_partition(ZAMBA.n_layers, 8).boundaries
    assert honest.stage_work is not None


def test_profile_cache_keys_on_partition_identity():
    spec = ClusterSpec(name="t", n_nodes=16, gpus_per_node=8)
    w = Workload(ZAMBA, 2048, 256)
    conf = Conf(8, 4, 4, 2, 256)
    uni_cache = ProfileCache(w, spec)                  # mode "uniform"
    dp_cache = ProfileCache(w, spec, "dp")
    p_uni, p_dp = uni_cache.get(conf), dp_cache.get(conf)
    assert p_uni.partition is None
    assert p_dp.partition == make_partition(ZAMBA, 8, 2048, "dp").boundaries
    assert p_uni.stage_work != p_dp.stage_work
    # bit-identical to the direct constructor with the same partition
    part = dp_cache.partition_for(conf)
    direct = build_profile(w, spec, conf, partition=part)
    assert p_dp == direct
    # memoized: same object back, including across dp variants
    assert dp_cache.get(conf) is p_dp
    assert dp_cache.get(dataclasses.replace(conf, dp=8, tp=1)) is not p_dp


# ------------------------------------------------- scorer parity (bitwise)

@pytest.mark.parametrize("vpp", [1, 2])
def test_numpy_jax_ref_parity_nonuniform(vpp):
    from repro.core.dedication import DedicationEngine
    from repro.core.jax_engine import JaxDedicationEngine
    spec = ClusterSpec(name="t", n_nodes=16, gpus_per_node=8)
    w = Workload(ZAMBA, 2048, 256)
    bw = true_bandwidth_matrix(spec)
    conf = Conf(8, 4, 4, 2, 256, vpp=vpp)
    part = make_partition(ZAMBA, 8 * vpp, 2048, "dp")
    prof = build_profile(w, spec, conf, partition=part)
    npe = DedicationEngine(conf, bw, prof, spec)
    jxe = JaxDedicationEngine([conf], [prof], bw, spec)
    rng = np.random.default_rng(0)
    m4 = default_mapping(conf).reshape(conf.pp, conf.tp, conf.cp, conf.dp)
    ref = pipette_latency_ref(conf, m4, bw, prof, spec)
    fast = pipette_latency(conf, m4, bw, prof, spec)
    assert float(ref).hex() == float(fast).hex()
    for _ in range(4):
        perm = rng.permutation(spec.n_gpus)
        a, b = npe.score(perm), jxe.score(perm, 0)
        assert float(a).hex() == float(b).hex()


def test_vpp1_formula_reduces_to_plain():
    """With vpp=1 the interleaved formula must be the plain hetero
    combine; build the same profile both ways and compare."""
    spec = ClusterSpec(name="t", n_nodes=16, gpus_per_node=8)
    w = Workload(ZAMBA, 2048, 256)
    bw = true_bandwidth_matrix(spec)
    conf = Conf(8, 4, 4, 2, 256)
    part = make_partition(ZAMBA, 8, 2048, "dp")
    prof = build_profile(w, spec, conf, partition=part)
    m4 = default_mapping(conf).reshape(conf.pp, conf.tp, conf.cp, conf.dp)
    lat = pipette_latency(conf, m4, bw, prof, spec)
    assert np.isfinite(lat) and lat > 0


# ----------------------------------------------- vpp schedule + enumerate

def test_vpp_schedulability():
    # interleaving needs pp > 1 and n_mb divisible by pp
    assert not Conf(1, 4, 4, 2, 256, vpp=2).schedulable()
    ok = Conf(8, 4, 4, 2, 256, vpp=2)      # n_mb = 32, 32 % 8 == 0
    assert ok.schedulable() and ok.schedule == "interleaved-1f1b"
    assert Conf(8, 4, 4, 2, 256).schedule == "1f1b"
    bad = Conf(8, 4, 4, 2, 96, vpp=2)      # n_mb = 12, 12 % 8 != 0
    assert not bad.schedulable()


def test_enumerate_confs_appends_vpp_variants():
    base = enumerate_confs(128, 256, n_layers=32)
    vpp = enumerate_confs(128, 256, n_layers=32, max_vpp=2)
    assert [c for c in vpp if c.vpp == 1] == base     # order preserved
    extra = [c for c in vpp if c.vpp > 1]
    assert extra and all(c.pp > 1 and c.schedulable() for c in extra)
    assert all(c.pp * c.vpp <= 32 for c in extra)


def test_interleaved_simulator_runs_and_is_deterministic():
    spec = ClusterSpec(name="t", n_nodes=16, gpus_per_node=8)
    w = Workload(ZAMBA, 2048, 256)
    bw = true_bandwidth_matrix(spec)
    conf = Conf(8, 4, 4, 2, 256, vpp=2)
    part = make_partition(ZAMBA, 16, 2048, "dp")
    m = default_mapping(conf)
    a = measure(conf, m, w, spec, bw, seed=1, partition=part)
    b = measure(conf, m, w, spec, bw, seed=1, partition=part)
    assert float(a).hex() == float(b).hex()
    assert np.isfinite(a) and a > 0


# --------------------------------------------------------- memory (worst
# stage) and the residual-key regression

def test_memory_worst_stage_and_residual_keying():
    spec = ClusterSpec(name="t", n_nodes=16, gpus_per_node=8)
    w = Workload(ZAMBA, 2048, 64)
    conf = Conf(8, 4, 4, 2, 64)
    m_dp = ground_truth_memory(w, conf, spec,
                               partition=make_partition(ZAMBA, 8, 2048,
                                                        "dp"))
    m_uni = ground_truth_memory(w, conf, spec,
                                partition=uniform_partition(81, 8))
    # different partitions must not alias each other's residual cache
    assert m_dp != m_uni
    # the balanced split's worst stage is no heavier than uniform's
    assert m_dp <= m_uni
    # vpp adds framework overhead for the extra model chunks
    conf_v = dataclasses.replace(conf, vpp=2)
    m_vpp = ground_truth_memory(w, conf_v, spec,
                                partition=make_partition(ZAMBA, 16, 2048,
                                                         "dp"))
    assert np.isfinite(m_vpp) and m_vpp > 0


# ------------------------------------------------------------ the win

@pytest.mark.parametrize("cfg", [ZAMBA, KIMI], ids=lambda c: c.name)
def test_dp_beats_uniform_simulated_at_pp8(cfg):
    """The headline gate: on the hybrid and MoE configs the DP split must
    be no slower than the *honest* uniform split (same per-stage cost
    model, uniform boundaries) in the discrete-event simulator."""
    spec = ClusterSpec(name="t", n_nodes=16, gpus_per_node=8)
    w = Workload(cfg, 2048, 64)
    conf = Conf(8, 4, 4, 2, 64)
    bw = true_bandwidth_matrix(spec)
    m = default_mapping(conf)
    part_u = uniform_partition(cfg.n_layers, 8)
    part_dp = make_partition(cfg, 8, 2048, "dp")
    assert part_dp != part_u
    sim_u = measure(conf, m, w, spec, bw, seed=1, partition=part_u)
    sim_dp = measure(conf, m, w, spec, bw, seed=1, partition=part_dp)
    assert sim_dp <= sim_u


def test_dp_beats_uniform_estimated_at_pp8():
    """Same direction in the first-order estimator (the search objective):
    a balanced split can only lower the paced ``c_max`` term."""
    spec = ClusterSpec(name="t", n_nodes=16, gpus_per_node=8)
    bw = true_bandwidth_matrix(spec)
    for cfg in (ZAMBA, KIMI):
        w = Workload(cfg, 2048, 64)
        conf = Conf(8, 4, 4, 2, 64)
        m4 = default_mapping(conf).reshape(conf.pp, conf.tp, conf.cp,
                                           conf.dp)
        p_u = build_profile(w, spec, conf,
                            partition=uniform_partition(cfg.n_layers, 8))
        p_dp = build_profile(w, spec, conf,
                             partition=make_partition(cfg, 8, 2048, "dp"))
        lat_u = pipette_latency(conf, m4, bw, p_u, spec)
        lat_dp = pipette_latency(conf, m4, bw, p_dp, spec)
        assert lat_dp <= lat_u
