"""Golden-file contract for the serialized Plan schema (version 5).

Three locks:

1. the checked-in fixture (``tests/data/golden_plan_v5.json``) loads and
   re-serializes **byte-for-byte** — the wire format cannot drift silently;
2. regenerating the same request live reproduces the fixture bytes —
   plans are deterministic artifacts, not process-local snapshots;
3. the serialized *shape* (every key path) is pinned: adding/removing/
   renaming any field fails here until ``PLAN_SCHEMA_VERSION`` is bumped
   (and the fixture regenerated via ``tests/data/gen_golden_plan.py``).
"""
import json
from pathlib import Path

import pytest

from repro.core import Plan, PlanLoadError, profile_bandwidth
from repro.core.plan import PLAN_SCHEMA_VERSION

GOLDEN = Path(__file__).parent / "data" / "golden_plan_v5.json"

#: Every key path of the version-5 schema.  ``[]`` marks list elements.
#: CHANGING THIS SET == CHANGING THE WIRE FORMAT: bump PLAN_SCHEMA_VERSION,
#: regenerate the fixture, and rename it (golden_plan_v<N>.json).
SCHEMA_V5_PATHS = frozenset({
    "best.conf.bs_global", "best.conf.bs_micro", "best.conf.cp",
    "best.conf.dp", "best.conf.pp", "best.conf.tp", "best.conf.vpp",
    "best.latency",
    "best.mapping.data[]", "best.mapping.dtype", "best.mapping.shape[]",
    "best.mem_pred", "best.partition", "best.schedule",
    "overhead.n_candidates", "overhead.n_enumerated",
    "overhead.sa_accepted", "overhead.sa_accepted_to_best",
    "provenance.bs_global",
    "provenance.budget.backend", "provenance.budget.hierarchical",
    "provenance.budget.n_chains",
    "provenance.budget.sa_iters", "provenance.budget.sa_seconds",
    "provenance.budget.sa_topk", "provenance.budget.warm_start",
    "provenance.bw_digest",
    "provenance.cluster", "provenance.estimator", "provenance.lineage",
    "provenance.model",
    "provenance.n_gpus", "provenance.seed", "provenance.seq",
    "provenance.space.fixed_micro", "provenance.space.max_cp",
    "provenance.space.max_micro", "provenance.space.max_tp",
    "provenance.space.max_vpp", "provenance.space.partition",
    "provenance.tiers.digest", "provenance.tiers.node_tiers[]",
    "provenance.tiers.tiers[].efficiency", "provenance.tiers.tiers[].flops",
    "provenance.tiers.tiers[].mem", "provenance.tiers.tiers[].name",
    "ranked[].conf.bs_global", "ranked[].conf.bs_micro", "ranked[].conf.cp",
    "ranked[].conf.dp", "ranked[].conf.pp", "ranked[].conf.tp",
    "ranked[].conf.vpp",
    "ranked[].latency", "ranked[].mapping.data[]", "ranked[].mapping.dtype",
    "ranked[].mapping.shape[]", "ranked[].mem_pred",
    "ranked[].partition", "ranked[].schedule",
    "strategy", "version",
})


def _paths(o, pre=""):
    out = set()
    if isinstance(o, dict):
        for k, v in o.items():
            out |= _paths(v, f"{pre}.{k}" if pre else k)
    elif isinstance(o, list):
        for v in o[:1]:
            out |= _paths(v, pre + "[]")
    else:
        out.add(pre)
    return out


def test_golden_plan_loads_and_roundtrips_byte_for_byte():
    text = GOLDEN.read_text()
    plan = Plan.load(GOLDEN)
    assert plan.to_json() == text
    assert plan.feasible
    # tier provenance (the v2 addition) is populated in the fixture
    tiers = plan.provenance.tiers
    assert tiers is not None and len(tiers["digest"]) == 64
    assert {t["name"] for t in tiers["tiers"]} == {"a100", "v100"}
    # the v3 additions: backend selection is recorded (null = legacy SA)
    assert plan.provenance.budget.backend is None
    assert plan.provenance.budget.hierarchical is None
    # the v4 additions: partition/schedule provenance (uniform search →
    # no partition, plain 1F1B) and the vpp degree on every conf
    assert plan.partition is None
    assert plan.schedule == "1f1b"
    assert plan.conf.vpp == 1
    assert plan.provenance.space.partition == "uniform"
    assert plan.provenance.space.max_vpp == 1
    # the v5 additions: cold search → no warm-start seed, no serving
    # lineage; the accepted-move counters are recorded and consistent
    assert plan.provenance.budget.warm_start is None
    assert plan.provenance.lineage is None
    assert plan.overhead.sa_accepted >= plan.overhead.sa_accepted_to_best >= 0


def test_golden_plan_reproduced_live_byte_for_byte(tmp_path):
    """The same request regenerated today must produce the exact fixture
    bytes — the Plan artifact is deterministic end to end."""
    from tests.data.gen_golden_plan import REQ, SPEC
    from repro.core import Planner, PipetteStrategy

    bw, _ = profile_bandwidth(SPEC)
    plan = Planner(PipetteStrategy()).plan(REQ, bw)
    assert plan.to_json() == GOLDEN.read_text()


def test_schema_version_must_bump_on_shape_change():
    live = _paths(json.loads(GOLDEN.read_text()))
    if PLAN_SCHEMA_VERSION == 5:
        assert live == SCHEMA_V5_PATHS, (
            "the serialized Plan shape changed but PLAN_SCHEMA_VERSION is "
            "still 5 — bump it, regenerate tests/data/golden_plan_v5.json "
            "under the new name, and update SCHEMA_V5_PATHS\n"
            f"added: {sorted(live - SCHEMA_V5_PATHS)}\n"
            f"removed: {sorted(SCHEMA_V5_PATHS - live)}")
    else:
        pytest.fail(
            "PLAN_SCHEMA_VERSION moved past 5: retire this guard by "
            "pinning the new shape and fixture (see gen_golden_plan.py)")


def test_loader_rejects_other_schema_versions():
    d = json.loads(GOLDEN.read_text())
    for bad in (1, 2, 3, 4, PLAN_SCHEMA_VERSION + 1, None):
        d["version"] = bad
        with pytest.raises(PlanLoadError, match="schema version"):
            Plan.from_json_dict(d)
        # PlanLoadError subclasses ValueError, so pre-existing callers
        # catching the historical type keep working
        with pytest.raises(ValueError, match="schema version"):
            Plan.from_json_dict(d)


def test_load_errors_are_typed_and_carry_the_path(tmp_path):
    bad_json = tmp_path / "corrupt.plan.json"
    bad_json.write_text("{not json")
    with pytest.raises(PlanLoadError, match="not valid JSON") as ei:
        Plan.load(bad_json)
    assert ei.value.path == str(bad_json)

    wrong_version = tmp_path / "old.plan.json"
    d = json.loads(GOLDEN.read_text())
    d["version"] = 3
    wrong_version.write_text(json.dumps(d))
    with pytest.raises(PlanLoadError, match="schema version") as ei:
        Plan.load(wrong_version)
    assert ei.value.path == str(wrong_version)

    broken = tmp_path / "broken.plan.json"
    d = json.loads(GOLDEN.read_text())
    del d["provenance"]["bw_digest"]
    broken.write_text(json.dumps(d))
    with pytest.raises(PlanLoadError, match="structurally invalid") as ei:
        Plan.load(broken)
    assert ei.value.path == str(broken)
