"""Tests for the static plan verifier (``repro.plan lint``).

The pristine golden fixture must pass; seeded mutations of it — corrupted
mapping permutation, wrong digests, unknown schema version, out-of-memory
confs, unschedulable pipelines — must each be flagged by the intended PLN
rule, without re-running any search.
"""
import copy
import json
from pathlib import Path

import pytest

from repro.analysis import verify_plan_dict, verify_plan_file
from repro.core import profile_bandwidth
from repro.core.cluster import A100_TIER, V100_TIER, mixed_fleet_spec

TESTS = Path(__file__).resolve().parent
GOLDEN = TESTS / "data" / "golden_plan_v5.json"

# the live spec the golden fixture was generated against
# (tests/data/gen_golden_plan.py)
SPEC = mixed_fleet_spec("mixed-a100-v100-16x1", 16, (A100_TIER, V100_TIER),
                        (0.5, 0.5), gpus_per_node=1, seed=47)


@pytest.fixture(scope="module")
def golden():
    return json.loads(GOLDEN.read_text(encoding="utf-8"))


def _errors(issues):
    return sorted({i.rule for i in issues if i.severity == "error"})


# ------------------------------------------------------------ pristine plan

def test_pristine_golden_passes(golden):
    issues = verify_plan_dict(golden)
    assert _errors(issues) == []
    assert not any(i.severity == "warning" for i in issues)


def test_pristine_golden_passes_against_live_spec(golden):
    """With the generating spec and bandwidth matrix in hand, the digest
    cross-checks go live and still pass."""
    bw, _ = profile_bandwidth(SPEC)
    issues = verify_plan_dict(golden, spec=SPEC, bw=bw)
    assert _errors(issues) == []
    # the bandwidth digest was actually checked against the matrix, so no
    # format-only note (the golden has mem_pred=null, so a PLN005 note
    # about the skipped OOM check is expected and fine)
    assert not any("format only" in i.message for i in issues)


def test_verify_plan_file_matches_dict_path(golden):
    assert _errors(verify_plan_file(GOLDEN)) == []


# -------------------------------------------------- seeded mutation classes

def _mutate(golden, fn):
    m = copy.deepcopy(golden)
    fn(m)
    return verify_plan_dict(m)


def test_corrupted_mapping_duplicate_entry(golden):
    def fn(m):
        m["best"]["mapping"]["data"][0] = m["best"]["mapping"]["data"][1]
    assert "PLN004" in _errors(_mutate(golden, fn))


def test_corrupted_mapping_out_of_range_rank(golden):
    def fn(m):
        m["best"]["mapping"]["data"][3] = 999
    assert "PLN004" in _errors(_mutate(golden, fn))


def test_mapping_shape_conf_mismatch(golden):
    def fn(m):
        m["best"]["mapping"]["shape"] = [2, 2, 1, 4]
    assert "PLN004" in _errors(_mutate(golden, fn))


def test_unknown_schema_version(golden):
    issues = _mutate(golden, lambda m: m.__setitem__("version", 99))
    assert "PLN001" in _errors(issues)


def test_wrong_tier_digest(golden):
    def fn(m):
        m["provenance"]["tiers"]["digest"] = "0" * 64
    assert "PLN007" in _errors(_mutate(golden, fn))


def test_wrong_bw_digest_format(golden):
    def fn(m):
        m["provenance"]["bw_digest"] = "not-a-sha256"
    assert "PLN006" in _errors(_mutate(golden, fn))


def test_bw_matrix_mismatch_against_live_matrix(golden):
    bw, _ = profile_bandwidth(SPEC)
    m = copy.deepcopy(golden)
    issues = verify_plan_dict(m, spec=SPEC, bw=bw * 1.01)
    assert "PLN006" in _errors(issues)


def test_oom_conf_flagged(golden):
    def fn(m):
        m["best"]["mem_pred"] = 5.0e10          # > the 32 GB V100 floor
    assert "PLN005" in _errors(_mutate(golden, fn))


def test_unschedulable_pipeline(golden):
    # golden best is pp=8; bs_micro=4 gives n_mb = 32/(4*dp) < pp
    def fn(m):
        m["best"]["conf"]["bs_micro"] = 4
    assert "PLN003" in _errors(_mutate(golden, fn))


def test_degree_product_mismatch(golden):
    def fn(m):
        m["best"]["conf"]["tp"] = 2             # product != n_gpus now
    errs = _errors(_mutate(golden, fn))
    assert "PLN002" in errs


def test_spec_cross_check(golden):
    wrong = mixed_fleet_spec("mixed-a100-v100-16x1", 32,
                             (A100_TIER, V100_TIER), (0.5, 0.5),
                             gpus_per_node=1, seed=47)
    issues = verify_plan_dict(golden, spec=wrong)
    assert "PLN008" in _errors(issues)


def test_ranked_candidates_are_checked_too(golden):
    def fn(m):
        m["ranked"][-1]["mapping"]["data"][0] = \
            m["ranked"][-1]["mapping"]["data"][1]
    issues = _mutate(golden, fn)
    bad = [i for i in issues if i.rule == "PLN004"]
    assert bad and all("ranked" in i.where for i in bad)


def test_unknown_schedule_name(golden):
    def fn(m):
        m["best"]["schedule"] = "gpipe"
    assert "PLN009" in _errors(_mutate(golden, fn))


def test_schedule_vpp_inconsistency(golden):
    # vpp=1 conf claiming interleaved-1f1b, and vpp=2 claiming plain 1f1b
    def claims_interleaved(m):
        m["best"]["schedule"] = "interleaved-1f1b"
    assert "PLN009" in _errors(_mutate(golden, claims_interleaved))

    def claims_plain(m):
        m["best"]["conf"]["vpp"] = 2
    assert "PLN009" in _errors(_mutate(golden, claims_plain))


def _with_partition(m):
    """Attach a valid uniform partition to the golden best (pp=8, 12
    layers → ceil-first boundaries)."""
    m["best"]["partition"] = {
        "n_layers": 12, "boundaries": [2, 4, 6, 8, 9, 10, 11, 12]}


def test_valid_partition_passes(golden):
    issues = _mutate(golden, _with_partition)
    assert "PLN009" not in _errors(issues)


def test_partition_boundaries_not_increasing(golden):
    def fn(m):
        _with_partition(m)
        m["best"]["partition"]["boundaries"][3] = 6   # ties the previous
    assert "PLN009" in _errors(_mutate(golden, fn))


def test_partition_does_not_cover_all_layers(golden):
    def fn(m):
        _with_partition(m)
        m["best"]["partition"]["boundaries"][-1] = 11  # one layer dropped
    assert "PLN009" in _errors(_mutate(golden, fn))


def test_partition_chunk_count_mismatch(golden):
    def fn(m):
        _with_partition(m)
        del m["best"]["partition"]["boundaries"][0]    # 7 chunks, pp=8
    assert "PLN009" in _errors(_mutate(golden, fn))


def test_partition_malformed_dict(golden):
    def fn(m):
        m["best"]["partition"] = {"boundaries": [2, 4]}  # no n_layers
    assert "PLN009" in _errors(_mutate(golden, fn))


def test_malformed_json_file(tmp_path):
    p = tmp_path / "broken.json"
    p.write_text("{not json", encoding="utf-8")
    issues = verify_plan_file(p)
    assert _errors(issues) == ["PLN000"]


def test_infeasible_plan_is_not_an_error(golden):
    m = copy.deepcopy(golden)
    m["best"] = None
    m["ranked"] = []
    assert _errors(verify_plan_dict(m)) == []


# --------------------------------------------------------------------- CLI

def test_cli_lint_pristine_and_mutated(tmp_path, capsys):
    from repro.plan import main as plan_main
    assert plan_main(["lint", str(GOLDEN)]) == 0
    captured = capsys.readouterr()
    assert "OK" in captured.err                 # verdict line on stderr

    m = json.loads(GOLDEN.read_text(encoding="utf-8"))
    m["best"]["conf"]["bs_micro"] = 4
    bad = tmp_path / "mutated.json"
    bad.write_text(json.dumps(m), encoding="utf-8")
    assert plan_main(["lint", str(bad)]) == 1
    captured = capsys.readouterr()
    assert "PLN003" in captured.out
    assert "FAIL" in captured.err


def test_cli_lint_json_format(capsys):
    from repro.plan import main as plan_main
    assert plan_main(["lint", str(GOLDEN), "--format", "json"]) == 0
    issues = json.loads(capsys.readouterr().out)
    assert isinstance(issues, list)
    assert not any(i["severity"] == "error" for i in issues)
    assert all({"rule", "severity", "where", "message"} <= set(i)
               for i in issues)
